"""Predictor subsystem: generative models, oracle bit-for-bit regression,
online (r, p) estimation, adaptive re-planning parity, cache migration."""

import dataclasses
import json
import math
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core.batch import simulate_batch
from repro.core.simulator import (NeverTrust, SimResult, ThresholdTrust,
                                  simulate)
from repro.core.traces import (FALSE_PRED, FAULT_PRED, FAULT_UNPRED,
                               EventTrace, Exponential, Weibull,
                               make_event_trace, make_event_trace_bank)
from repro.core.waste import Platform
from repro.experiments import (DistributionSpec, EvalCache, ExperimentSpec,
                               PredictorSpec, ScenarioSpec, StrategySpec,
                               SweepSpec, build_strategy, evaluate_strategies,
                               list_strategies, run_experiment)
from repro.experiments.runner import (_candidate_key, _cell_persist_key,
                                      _persistable_key)
from repro.predictors import (AdaptiveConfig, BurstyPredictor,
                              DriftingPredictor, LeadTimePredictor,
                              OnlineRPEstimator, OraclePredictor,
                              build_predictor, list_predictors, maybe_replan)

SMALL = ScenarioSpec(n=32, dist=DistributionSpec("weibull", {"shape": 0.7}),
                     mu_ind=32 * 1e5, c=600.0, d=60.0, r=600.0,
                     time_base_years_total=0.1, start=0.0, n_traces=4,
                     seed=3)


def assert_same(got: SimResult, want: SimResult, context=""):
    for f in dataclasses.fields(SimResult):
        g, w = getattr(got, f.name), getattr(want, f.name)
        assert g == w, f"{context}: {f.name}: batch {g} != scalar {w}"


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

def test_predictor_registry():
    assert {"oracle", "lead_time", "drifting", "bursty"} <= \
        set(list_predictors())
    for name in list_predictors():
        model = build_predictor(name, 0.8, 0.7)
        stream = model.predict(np.array([100.0, 5000.0, 20000.0]),
                               mu=100.0, horizon=50_000.0,
                               rng=np.random.default_rng(0),
                               false_dist=Exponential(1.0))
        assert stream.kinds.shape == (3,)
    with pytest.raises(KeyError):
        build_predictor("no_such_model", 0.8, 0.7)
    assert "adaptive" in list_strategies()


# ---------------------------------------------------------------------------
# Oracle: bit-for-bit the legacy stamping
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("window", [0.0, 1200.0])
def test_oracle_reproduces_stamped_traces(window):
    for seed in (0, 5):
        a = make_event_trace(Weibull(0.7, 1.0), 100.0, 0.8, 0.7, 50_000.0,
                             np.random.default_rng(seed), window=window)
        b = make_event_trace(Weibull(0.7, 1.0), 100.0, 0.8, 0.7, 50_000.0,
                             np.random.default_rng(seed), window=window,
                             predictor_model=OraclePredictor(0.8, 0.7))
        assert np.array_equal(a.times, b.times)
        assert np.array_equal(a.kinds, b.kinds)
        assert (a.windows is None) == (b.windows is None)
        if a.windows is not None:
            assert np.array_equal(a.windows, b.windows)


def test_oracle_bank_reproduces_stamped_bank():
    kw = dict(mu=100.0, recall=0.8, precision=0.7, horizon=20_000.0)
    a = make_event_trace_bank(Exponential(1.0), kw["mu"], kw["recall"],
                              kw["precision"], kw["horizon"],
                              np.random.default_rng(3), n_traces=6)
    b = make_event_trace_bank(Exponential(1.0), kw["mu"], kw["recall"],
                              kw["precision"], kw["horizon"],
                              np.random.default_rng(3), n_traces=6,
                              predictor_model=OraclePredictor(0.8, 0.7))
    for ta, tb in zip(a, b):
        assert np.array_equal(ta.times, tb.times)
        assert np.array_equal(ta.kinds, tb.kinds)


def test_scenario_oracle_spec_is_bit_for_bit():
    osc = SMALL.replace(predictor=PredictorSpec("oracle"))
    for batched in (False, True):
        for ta, tb in zip(SMALL.make_traces(batched=batched),
                          osc.make_traces(batched=batched)):
            assert np.array_equal(ta.times, tb.times)
            assert np.array_equal(ta.kinds, tb.kinds)


def test_pinned_means_unchanged_through_predictor_refactor():
    """The PR-2 pinned regression means, reproduced on the oracle-spec
    scenario: trace generation did not drift when the stamping moved into
    the predictor subsystem."""
    osc = SMALL.replace(predictor=PredictorSpec("oracle"))
    traces = osc.make_traces()
    strategies = [build_strategy("rfo", osc),
                  build_strategy("optimal_prediction", osc),
                  build_strategy("young", osc)]
    means = evaluate_strategies(traces, osc.platform, osc.time_base, osc.cp,
                                strategies, seed=7)
    want = [119433.55140339246, 103766.19817640496, 126397.87625327974]
    assert means == pytest.approx(want, rel=1e-12)


# ---------------------------------------------------------------------------
# The generative models
# ---------------------------------------------------------------------------

def test_lead_time_windows_and_recall_adjustment():
    model = LeadTimePredictor(0.8, 0.7, lead_mean=500.0, min_lead=200.0)
    tr = make_event_trace(Exponential(1.0), 100.0, 0.8, 0.7, 200_000.0,
                          np.random.default_rng(1), predictor_model=model)
    assert tr.windows is not None
    pred_w = tr.windows[tr.kinds == FAULT_PRED]
    false_w = tr.windows[tr.kinds == FALSE_PRED]
    assert pred_w.size and false_w.size
    # Every surviving prediction carries a lead >= min_lead (exponential
    # memorylessness: E[lead | lead >= 200] = 200 + 500) ...
    assert (pred_w >= 200.0).all()
    assert pred_w.mean() == pytest.approx(700.0, rel=0.1)
    # ... and short-lead predictions were downgraded: effective recall
    # r * P(lead >= min_lead) = 0.8 * exp(-200/500) ~ 0.536.
    n_faults = int((tr.kinds != FALSE_PRED).sum())
    eff_recall = int((tr.kinds == FAULT_PRED).sum()) / n_faults
    assert eff_recall == pytest.approx(0.8 * math.exp(-200.0 / 500.0),
                                       abs=0.06)


def test_drifting_recall_moves_over_the_trace():
    model = DriftingPredictor(0.9, 0.9, recall_end=0.2, precision_end=0.3)
    tr = make_event_trace(Exponential(1.0), 100.0, 0.9, 0.9, 400_000.0,
                          np.random.default_rng(2), predictor_model=model)
    half = 200_000.0
    def recall_of(sel):
        k = tr.kinds[sel]
        faults = (k != FALSE_PRED).sum()
        return (k == FAULT_PRED).sum() / max(1, faults)
    assert recall_of(tr.times < half) > recall_of(tr.times >= half) + 0.2


def test_drifting_ramp_respects_drift_window():
    model = DriftingPredictor(0.9, 0.9, recall_end=0.1,
                              drift_start=300_000.0, drift_span=1.0)
    tr = make_event_trace(Exponential(1.0), 100.0, 0.9, 0.9, 400_000.0,
                          np.random.default_rng(4), predictor_model=model)
    def recall_of(sel):
        k = tr.kinds[sel]
        return (k == FAULT_PRED).sum() / max(1, (k != FALSE_PRED).sum())
    # Flat at the nominal value before the ramp, at the end value after.
    assert recall_of(tr.times < 300_000.0) == pytest.approx(0.9, abs=0.05)
    assert recall_of(tr.times > 301_000.0) == pytest.approx(0.1, abs=0.05)


def test_bursty_preserves_rate_but_clusters():
    bursty = BurstyPredictor(0.8, 0.7, burst_size=5.0, burst_gap=50.0)
    tr = make_event_trace(Exponential(1.0), 100.0, 0.8, 0.7, 400_000.0,
                          np.random.default_rng(4), predictor_model=bursty)
    oracle = make_event_trace(Exponential(1.0), 100.0, 0.8, 0.7, 400_000.0,
                              np.random.default_rng(4))
    n_b = int((tr.kinds == FALSE_PRED).sum())
    n_o = int((oracle.kinds == FALSE_PRED).sum())
    assert n_b == pytest.approx(n_o, rel=0.35)       # same long-run rate
    gaps = np.diff(tr.times[tr.kinds == FALSE_PRED])
    assert gaps.std() / gaps.mean() > 1.3            # clustered (CV >> 1)


def test_predictor_models_only_draw_from_their_rng():
    """Two generations from equal seeds are identical (reproducibility)."""
    for name in list_predictors():
        model = build_predictor(name, 0.7, 0.6)
        tr1 = make_event_trace(Exponential(1.0), 100.0, 0.7, 0.6, 100_000.0,
                               np.random.default_rng(9),
                               predictor_model=model)
        tr2 = make_event_trace(Exponential(1.0), 100.0, 0.7, 0.6, 100_000.0,
                               np.random.default_rng(9),
                               predictor_model=model)
        assert np.array_equal(tr1.times, tr2.times), name
        assert np.array_equal(tr1.kinds, tr2.kinds), name


# ---------------------------------------------------------------------------
# Spec integration
# ---------------------------------------------------------------------------

def test_predictor_spec_round_trip_and_dotted_paths():
    sc = SMALL.replace(predictor=PredictorSpec("drifting",
                                               {"precision_end": 0.3}))
    again = ScenarioSpec.from_dict(json.loads(json.dumps(sc.to_dict())))
    assert again == sc and again.key() == sc.key()
    assert sc.key() != SMALL.key()

    sc2 = sc.replace(**{"predictor.params.precision_end": 0.5})
    assert sc2.predictor.params["precision_end"] == 0.5
    sc3 = SMALL.replace(**{"predictor.name": "bursty"})
    assert sc3.predictor.name == "bursty"


def test_predictor_sweep_axis_coercion():
    sweep = SweepSpec.from_dict({
        "axes": {"predictor": [{"name": "oracle"},
                               {"name": "bursty",
                                "params": {"burst_size": 3.0}}]}})
    cells = list(sweep.cells(SMALL))
    assert cells[0][0]["predictor"] == "oracle"
    assert cells[1][1].predictor.params["burst_size"] == 3.0


def test_predictor_sweep_experiment_round_trips():
    from benchmarks.predictor_sweep import build
    exp = build(quick=True)
    assert ExperimentSpec.from_json(exp.to_json()) == exp


def test_roofline_spec_args_without_jax():
    import benchmarks.roofline as roofline
    from repro.experiments import build_experiment
    exp = build_experiment("roofline", quick=True)
    argv, env = roofline.spec_args(exp)
    assert "--pairs" in argv
    assert "device_count=512" in env["XLA_FLAGS"]
    assert ExperimentSpec.from_json(exp.to_json()) == exp


# ---------------------------------------------------------------------------
# Online estimator
# ---------------------------------------------------------------------------

def test_online_estimator_gate_and_estimates():
    est = OnlineRPEstimator(min_preds=4, min_faults=5)
    assert not est.ready and est.recall is None and est.precision is None
    for confirmed in (True, True, True, False):
        est.observe_prediction(confirmed)
    est.observe_fault(predicted=True)  # already counted via its prediction
    assert est.n_predictions == 4 and est.n_faults == 3
    assert not est.ready               # 3 faults < min_faults
    est.observe_fault(predicted=False)
    est.observe_fault(predicted=False)
    assert est.ready
    assert est.recall == pytest.approx(3 / 5)
    assert est.precision == pytest.approx(3 / 4)


def test_maybe_replan_gate_and_hysteresis():
    plat = Platform(mu=5e4, c=600.0, d=60.0, r=600.0)
    cfg = AdaptiveConfig(prior_recall=0.5, prior_precision=0.5,
                         min_preds=4, min_faults=2, tol=0.05)
    # Below the gate: no plan.
    assert maybe_replan(cfg, plat, 600.0, 2, 1, 1, 0.5, 0.5) is None
    # Gate passed but inside the hysteresis box: no plan.
    assert maybe_replan(cfg, plat, 600.0, 2, 2, 2, 0.5, 0.5) is None
    # Estimates moved: re-plan, threshold = beta_lim = cp / p_hat.
    out = maybe_replan(cfg, plat, 600.0, 8, 2, 2, 0.5, 0.5)
    assert out is not None
    r_hat, p_hat, period, thr = out
    assert r_hat == pytest.approx(0.8) and p_hat == pytest.approx(0.8)
    assert period > plat.c
    assert thr == pytest.approx(600.0 / 0.8)


def test_adaptive_config_validation():
    with pytest.raises(ValueError):
        AdaptiveConfig(0.5, 0.5, min_preds=0)
    with pytest.raises(ValueError):
        AdaptiveConfig(0.5, 0.5, tol=0.0)
    with pytest.raises(ValueError):
        AdaptiveConfig(0.5, 0.5, halflife=0.0)
    # A gate above the EW effective-count ceiling (~1.44 * halflife) can
    # never open — rejected at construction, not silently dead.
    with pytest.raises(ValueError, match="never open"):
        AdaptiveConfig(0.5, 0.5, min_preds=32, min_faults=16, halflife=8.0)
    cfg = AdaptiveConfig(0.5, 0.5, min_preds=8, min_faults=4, halflife=24.0)
    assert 0.0 < cfg.decay < 1.0
    assert AdaptiveConfig(0.5, 0.5).decay == 1.0


# ---------------------------------------------------------------------------
# Windowed (EW) estimator: drift tracking
# ---------------------------------------------------------------------------

def _feed_trace(est: OnlineRPEstimator, trace: EventTrace) -> None:
    for kind in trace.kinds:
        if kind == FALSE_PRED:
            est.observe_prediction(False)
        elif kind == FAULT_PRED:
            est.observe_prediction(True)
        else:
            est.observe_fault(predicted=False)


def test_ew_estimator_tracks_drifting_predictor():
    """Cumulative counters converge to the all-time average; the EW
    variant follows the drifting model down to its end-of-run recall."""
    model = DriftingPredictor(0.9, 0.8, recall_end=0.2,
                              drift_start=0.0, drift_span=200_000.0)
    tr = make_event_trace(Exponential(1.0), 100.0, 0.9, 0.8, 400_000.0,
                          np.random.default_rng(11), predictor_model=model)
    cum = OnlineRPEstimator(min_preds=8, min_faults=8)
    ew = OnlineRPEstimator(min_preds=8, min_faults=8, halflife=64.0)
    _feed_trace(cum, tr)
    _feed_trace(ew, tr)
    assert cum.ready and ew.ready
    # The trace's second half sits flat at the end recall.
    assert abs(ew.recall - 0.2) < abs(cum.recall - 0.2)
    assert ew.recall < cum.recall - 0.1
    # Effective counts saturate at 1/(1 - decay), never beyond.
    assert ew.n_predictions <= 1.0 / (1.0 - ew._decay) + 1e-9
    # Precision did not drift; both estimators should agree roughly.
    assert ew.precision == pytest.approx(cum.precision, abs=0.15)


def test_ew_estimator_none_halflife_is_cumulative():
    a = OnlineRPEstimator(min_preds=2, min_faults=2)
    b = OnlineRPEstimator(min_preds=2, min_faults=2, halflife=None)
    for est in (a, b):
        for confirmed in (True, False, True, True):
            est.observe_prediction(confirmed)
        est.observe_fault(predicted=False)
    assert a.n_true_pred == b.n_true_pred == 3
    assert a.recall == b.recall and a.precision == b.precision


def test_adaptive_halflife_batch_matches_scalar_bit_for_bit():
    p, tb, cp, _, _, _, traces = _parity_case()
    cfg = AdaptiveConfig(prior_recall=0.3, prior_precision=0.95,
                         min_preds=8, min_faults=4, tol=0.03, halflife=24.0)
    t0, thr0 = cfg.plan(p, cp, cfg.prior_recall, cfg.prior_precision)
    trust = ThresholdTrust(thr0)
    batch = simulate_batch(traces, p, tb, [t0], cp=cp, trust=trust,
                           adaptive=cfg, trace_seeds=13)
    for ti, tr in enumerate(traces):
        want = simulate(tr, p, tb, t0, cp=cp, trust=trust, adaptive=cfg,
                        rng=np.random.default_rng(13))
        assert_same(batch.result(0, ti), want, f"EW trace {ti}")


def test_adaptive_halflife_simulation_tracks_drift():
    """End-of-run (r-hat) of the EW adaptive run sits near the drifted
    recall; the cumulative run is pulled up by the stale early phase."""
    p = Platform(mu=2000.0, c=60.0, d=6.0, r=60.0)
    tb = 400_000.0
    model = DriftingPredictor(0.9, 0.8, recall_end=0.2,
                              drift_start=0.0, drift_span=200_000.0)
    tr = make_event_trace(Exponential(1.0), p.mu, 0.9, 0.8, 1_200_000.0,
                          np.random.default_rng(17), predictor_model=model)
    kw = dict(prior_recall=0.9, prior_precision=0.8,
              min_preds=8, min_faults=8, tol=0.03)
    runs = {}
    for name, halflife in (("cum", None), ("ew", 64.0)):
        cfg = AdaptiveConfig(halflife=halflife, **kw)
        t0, thr0 = cfg.plan(p, 60.0, 0.9, 0.8)
        runs[name] = simulate(tr, p, tb, t0, cp=60.0,
                              trust=ThresholdTrust(thr0), adaptive=cfg,
                              rng=np.random.default_rng(23))
    assert runs["ew"].n_replans >= 1
    assert runs["cum"].est_recall > -1.0 and runs["ew"].est_recall > -1.0
    assert abs(runs["ew"].est_recall - 0.2) \
        < abs(runs["cum"].est_recall - 0.2)


def test_adaptive_estimate_mu_parity_and_tracking():
    """Online-MTBF regression (ROADMAP item 6): traces drawn at a third
    of the assumed platform MTBF.  The ``estimate_mu`` run must (a) stay
    bit-for-bit scalar/lane identical, (b) report an est_mu much closer
    to the true MTBF than the stale platform value, and (c) re-plan to a
    different cadence than its mu-blind twin."""
    p = Platform(mu=6000.0, c=60.0, d=6.0, r=60.0)
    true_mu = 2000.0
    tb = 400_000.0
    traces = [make_event_trace(Exponential(1.0), true_mu, 0.85, 0.8,
                               1_200_000.0, np.random.default_rng(40 + i))
              for i in range(2)]
    seeds = [51, 52]
    kw = dict(prior_recall=0.85, prior_precision=0.8, min_preds=8,
              min_faults=8, tol=0.03)
    runs = {}
    for name, est in (("blind", False), ("mu", True)):
        cfg = AdaptiveConfig(estimate_mu=est, **kw)
        t0, thr0 = cfg.plan(p, 60.0, 0.85, 0.8)
        batch = simulate_batch(traces, p, tb, [t0], cp=60.0,
                               trust=ThresholdTrust(thr0), adaptive=cfg,
                               trace_seeds=seeds)
        for ti, tr in enumerate(traces):
            want = simulate(tr, p, tb, t0, cp=60.0,
                            trust=ThresholdTrust(thr0), adaptive=cfg,
                            rng=np.random.default_rng(seeds[ti]))
            assert_same(batch.result(0, ti), want, f"{name} trace {ti}")
        runs[name] = batch
    mu_hat = runs["mu"].est_mu[0]
    assert (mu_hat > 0).all()
    assert (np.abs(mu_hat - true_mu) < np.abs(p.mu - true_mu)).all()
    assert runs["mu"].n_replans.sum() > 0
    assert not np.array_equal(runs["mu"].final_period,
                              runs["blind"].final_period)


# ---------------------------------------------------------------------------
# Adaptive re-planning: scalar / lane-engine bit-for-bit parity
# ---------------------------------------------------------------------------

def _parity_case():
    p = Platform(mu=5e4, c=600.0, d=60.0, r=600.0)
    tb, cp = 3e5, 600.0
    cfg = AdaptiveConfig(prior_recall=0.3, prior_precision=0.95,
                         min_preds=8, min_faults=4, tol=0.03)
    t0, thr0 = cfg.plan(p, cp, cfg.prior_recall, cfg.prior_precision)
    trust = ThresholdTrust(thr0)
    traces = [make_event_trace(Exponential(1.0), p.mu, 0.85, 0.8, 40 * tb,
                               np.random.default_rng(i)) for i in range(4)]
    return p, tb, cp, cfg, t0, trust, traces


@pytest.mark.parametrize("window", [0.0, 1200.0])
def test_adaptive_batch_matches_scalar_bit_for_bit(window):
    p, tb, cp, cfg, t0, trust, traces = _parity_case()
    periods = [t0, 9000.0]
    seeds = [11, 22, 33, 44]
    batch = simulate_batch(traces, p, tb, periods, cp=cp, trust=trust,
                           inexact_window=window, adaptive=cfg,
                           trace_seeds=seeds)
    total_replans = 0
    for ci, period in enumerate(periods):
        for ti, tr in enumerate(traces):
            want = simulate(tr, p, tb, period, cp=cp, trust=trust,
                            inexact_window=window, adaptive=cfg,
                            rng=np.random.default_rng(seeds[ti]))
            assert_same(batch.result(ci, ti), want, f"lane ({ci},{ti})")
            total_replans += want.n_replans
    assert total_replans > 0, "the stale prior must trigger re-plans"


def test_adaptive_mixed_with_static_candidates():
    p, tb, cp, cfg, t0, trust, traces = _parity_case()
    batch = simulate_batch(traces, p, tb, [t0, 9000.0], cp=cp,
                           trust=[trust, NeverTrust()],
                           adaptive=[cfg, None], trace_seeds=7)
    for ti, tr in enumerate(traces):
        want = simulate(tr, p, tb, 9000.0, cp=cp, trust=NeverTrust(),
                        rng=np.random.default_rng(7))
        assert_same(batch.result(1, ti), want, "static lane")
    assert batch.result(1, 0).final_period == 9000.0
    assert batch.result(1, 0).n_replans == 0
    assert batch.result(0, 0).n_replans >= 1


def test_adaptive_never_trust_prior_matches_scalar():
    """A prior whose plan says 'do not trust' (threshold = inf) must still
    re-plan into trusting once the estimates warrant it."""
    p, tb, cp, _, _, _, traces = _parity_case()
    cfg = AdaptiveConfig(prior_recall=0.05, prior_precision=0.2,
                         min_preds=8, min_faults=4, tol=0.03)
    t0, thr0 = cfg.plan(p, cp, cfg.prior_recall, cfg.prior_precision)
    trust = NeverTrust() if math.isinf(thr0) else ThresholdTrust(thr0)
    batch = simulate_batch(traces, p, tb, [t0], cp=cp, trust=trust,
                           adaptive=cfg, trace_seeds=5)
    for ti, tr in enumerate(traces):
        want = simulate(tr, p, tb, t0, cp=cp, trust=trust, adaptive=cfg,
                        rng=np.random.default_rng(5))
        assert_same(batch.result(0, ti), want, f"trace {ti}")


def test_adaptive_requires_threshold_or_never_trust():
    from repro.core.simulator import AlwaysTrust
    p, tb, cp, cfg, t0, _, traces = _parity_case()
    with pytest.raises(ValueError, match="Threshold or Never"):
        simulate(traces[0], p, tb, t0, cp=cp, trust=AlwaysTrust(),
                 adaptive=cfg)
    with pytest.raises(ValueError, match="Threshold or Never"):
        simulate_batch(traces, p, tb, [t0], cp=cp, trust=AlwaysTrust(),
                       adaptive=cfg)


def test_adaptive_runner_engines_agree():
    traces = SMALL.make_traces()
    ad = build_strategy("adaptive", SMALL, min_preds=4, min_faults=2,
                        tol=0.02)
    strategies = [ad, build_strategy("rfo", SMALL)]
    auto = evaluate_strategies(traces, SMALL.platform, SMALL.time_base,
                               SMALL.cp, strategies, seed=7, engine="auto")
    scalar = evaluate_strategies(traces, SMALL.platform, SMALL.time_base,
                                 SMALL.cp, strategies, seed=7,
                                 engine="scalar")
    assert auto == scalar


def test_adaptive_in_run_experiment_with_predictor_axis():
    exp = ExperimentSpec(
        name="t",
        scenario=SMALL,
        sweep=SweepSpec(axes={"predictor": [
            PredictorSpec("oracle").to_dict(),
            PredictorSpec("bursty").to_dict()]}),
        strategies=(StrategySpec("rfo"),
                    StrategySpec("adaptive",
                                 {"min_preds": 4, "min_faults": 2})),
    )
    table = run_experiment(exp)
    assert len(table) == 4
    assert set(table.column("predictor")) == {"oracle", "bursty"}


# ---------------------------------------------------------------------------
# Candidate keys + persistent-cache schema migration (v2 -> v3)
# ---------------------------------------------------------------------------

def test_candidate_key_distinguishes_adaptive():
    base = build_strategy("rfo", SMALL)
    ad = build_strategy("adaptive", SMALL)
    static_twin = dataclasses.replace(ad, adaptive=None)
    assert _candidate_key(ad) != _candidate_key(static_twin)
    assert _candidate_key(base) == _candidate_key(base)
    # Both serialize (AdaptiveConfig has value semantics).
    assert _persistable_key(_candidate_key(ad)) is not None
    k = json.loads(_persistable_key(_candidate_key(ad)))
    # 9-tuple since the silent/verify axis: (..., adaptive, n_verify,
    # verify_cost, keep_ckpts).
    assert len(k) == 9 and k[5] is not None
    assert k[6:] == [0, 0.0, 1]  # fail-stop defaults


def test_cell_persist_key_depends_on_version_and_predictor(monkeypatch):
    from repro.experiments import runner
    k3 = _cell_persist_key(SMALL, False)
    monkeypatch.setattr(runner, "_EVAL_CACHE_VERSION", 2)
    k2 = _cell_persist_key(SMALL, False)
    assert k2 != k3          # v2 stores live under different file names
    monkeypatch.undo()
    kp = _cell_persist_key(SMALL.replace(predictor=PredictorSpec("oracle")),
                           False)
    assert kp != k3          # the predictor field keys separate stores


def test_v2_format_store_is_invalidated_not_misread(tmp_path):
    """A store holding v2-format candidate keys (5 elements, no adaptive
    axis) must degrade to empty — results are recomputed, never misread."""
    v2_key = json.dumps([3000.0, ["never"], 0.0, "instant", 0.0])
    (tmp_path / "ctx.json").write_text(
        json.dumps({"makespans": {v2_key: {"0": 12345.0}}}))
    cache = EvalCache(persist_key="ctx", cache_dir=tmp_path)
    assert len(cache) == 0
    # And flushing new results replaces the store cleanly.
    cache.put(build_strategy("rfo", SMALL), 0, 111.0)
    cache.flush()
    store = json.loads((tmp_path / "ctx.json").read_text())["makespans"]
    assert all(len(json.loads(k)) == 9 for k in store)


def test_v3_store_round_trips_adaptive_candidates(tmp_path):
    traces = SMALL.make_traces()
    ad = build_strategy("adaptive", SMALL, min_preds=4, min_faults=2)
    cold = EvalCache(persist_key="ad", cache_dir=tmp_path)
    first = evaluate_strategies(traces, SMALL.platform, SMALL.time_base,
                                SMALL.cp, [ad], seed=7, cache=cold)
    cold.flush()
    warm = EvalCache(persist_key="ad", cache_dir=tmp_path)
    again = evaluate_strategies(traces, SMALL.platform, SMALL.time_base,
                                SMALL.cp, [ad], seed=7, cache=warm)
    assert again == first
    assert warm.misses == 0 and warm.hits == len(traces)


def test_v3_format_adaptive_key_never_aliases_v4(tmp_path):
    """A v3-format adaptive candidate key (5-element AdaptiveConfig tuple,
    no model_order) decodes cleanly but can never equal a v4 candidate —
    stale pre-model-order results are recomputed, never misread."""
    ad = build_strategy("adaptive", SMALL)
    v4_key = json.loads(_persistable_key(_candidate_key(ad)))
    v3_key = list(v4_key)
    v3_key[5] = v4_key[5][:5]  # drop the model_order element
    (tmp_path / "ctx.json").write_text(json.dumps(
        {"makespans": {json.dumps(v3_key): {"0": 12345.0}}}))
    cache = EvalCache(persist_key="ctx", cache_dir=tmp_path)
    assert len(cache) == 1           # the entry loads (it is well-formed)...
    assert cache.get(ad, 0) is None  # ...but never serves a v4 candidate


def test_v6_engine_tag_keys_separate_stores(monkeypatch):
    """The v6 persist key carries an engine-identity tag: the bit-for-bit
    numpy-family engines keep sharing one store, while pre-v6 stores live
    under different file names — invalidated, never misread."""
    from repro.experiments import runner
    k6 = _cell_persist_key(SMALL, False)
    assert _cell_persist_key(SMALL, False, "scalar") == k6
    assert _cell_persist_key(SMALL, False, "batch") == k6
    monkeypatch.setattr(runner, "_EVAL_CACHE_VERSION", 5)
    assert _cell_persist_key(SMALL, False) != k6


# ---------------------------------------------------------------------------
# Estimator edge cases: empty streams, closed gates, final-event replans
# ---------------------------------------------------------------------------

_EDGE_PLATFORM = Platform(mu=1000.0, c=10.0, d=5.0, r=5.0)


def _edge_trace(times, kinds, horizon=1e7) -> EventTrace:
    return EventTrace(np.asarray(times, dtype=np.float64),
                      np.asarray(kinds, dtype=np.int8), horizon)


def _edge_cfg(**kw) -> AdaptiveConfig:
    base = dict(prior_recall=0.5, prior_precision=0.5, min_preds=1,
                min_faults=1, tol=0.05)
    base.update(kw)
    return AdaptiveConfig(**base)


def _run_edge(trace, cfg, period=50.0, threshold=20.0, time_base=200.0):
    scalar = simulate(trace, _EDGE_PLATFORM, time_base, period, cp=10.0,
                      trust=ThresholdTrust(threshold), adaptive=cfg,
                      rng=np.random.default_rng(0))
    batch = simulate_batch([trace], _EDGE_PLATFORM, time_base, [period],
                           cp=10.0, trust=ThresholdTrust(threshold),
                           adaptive=cfg, trace_seeds=[0])
    assert_same(batch.result(0, 0), scalar, "estimator edge lane")
    return scalar


def test_estimator_zero_prediction_trace():
    """A trace with no predictions at all: the gate never opens, nothing
    divides by zero, and the recall estimate (faults only) is 0."""
    res = _run_edge(_edge_trace([60.0, 130.0],
                                [FAULT_UNPRED, FAULT_UNPRED]), _edge_cfg())
    assert res.n_predictions == 0 and res.n_faults == 2
    assert res.n_replans == 0
    assert res.est_recall == 0.0       # 0 predicted / 2 observed faults
    assert res.est_precision == -1.0   # no predictions: sentinel
    est = OnlineRPEstimator(min_preds=1, min_faults=1)
    est.observe_fault(predicted=False)
    assert not est.ready and est.precision is None
    assert est.recall == 0.0


def test_estimator_gate_never_opens():
    """A confidence gate that can never be satisfied keeps the initial
    plan verbatim (period, threshold) and replans exactly zero times."""
    trace = _edge_trace([30.0, 60.0, 90.0, 130.0],
                        [FAULT_PRED, FALSE_PRED, FAULT_PRED, FAULT_UNPRED])
    res = _run_edge(trace, _edge_cfg(min_preds=10**9))
    assert res.n_replans == 0
    assert res.final_period == 50.0
    assert res.final_threshold == 20.0
    # Both outcome kinds were observed, so the estimates are still reported.
    assert res.est_recall == pytest.approx(2 / 3)
    assert res.est_precision == pytest.approx(2 / 3)


def test_estimator_replan_at_final_event():
    """The gate crossing on the very last trace event must replan exactly
    once (estimates r-hat = p-hat = 1 are legal plan inputs)."""
    res = _run_edge(_edge_trace([120.0], [FAULT_PRED]), _edge_cfg())
    assert res.n_replans == 1
    assert res.est_recall == 1.0 and res.est_precision == 1.0
    assert res.final_period >= _EDGE_PLATFORM.c
    assert math.isfinite(res.final_period)


def test_estimator_event_after_completion_never_replans():
    """A prediction dated past job completion is announced (counted) but
    the machine finishes during the pre-checkpoint advance: the fault gate
    stays closed and no replan fires."""
    res = _run_edge(_edge_trace([1e6], [FALSE_PRED]), _edge_cfg())
    assert res.n_predictions == 1 and res.n_faults == 0
    assert res.n_replans == 0
    assert res.est_precision == 0.0    # one prediction, never confirmed
    assert res.est_recall == -1.0      # no faults observed: sentinel
    # estimate_precision floors at P_HAT_MIN instead of dividing to 0.
    from repro.predictors.estimator import P_HAT_MIN, estimate_precision
    assert estimate_precision(0, 5) == P_HAT_MIN


# ---------------------------------------------------------------------------
# JAX backend: pre-drawn randomness tables (subprocess needs x64)
# ---------------------------------------------------------------------------

_JAX_RNG_CHECK = """
import numpy as np, dataclasses
from repro.core.batch import simulate_batch
from repro.core.simulator import (AlwaysTrust, FixedProbabilityTrust,
                                  SimResult, ThresholdTrust, simulate)
from repro.core.traces import Exponential, make_event_trace
from repro.core.waste import Platform

p = Platform(mu=5e4, c=600.0, d=60.0, r=600.0)
tb, cp = 2e5, 600.0
traces = [make_event_trace(Exponential(1.0), p.mu, 0.6, 0.8, 30 * tb,
                           np.random.default_rng(i)) for i in range(3)]
periods = [3000.0, 9000.0]
seeds = [17, 23, 31]
cases = [(FixedProbabilityTrust(0.5), 0.0),
         (ThresholdTrust(700.0), 1200.0),
         (FixedProbabilityTrust(0.4), 1200.0),
         (AlwaysTrust(), 900.0)]
for trust, w in cases:
    batch = simulate_batch(traces, p, tb, periods, cp=cp, trust=trust,
                           inexact_window=w, trace_seeds=seeds,
                           backend="jax")
    for ci, period in enumerate(periods):
        for ti, tr in enumerate(traces):
            want = simulate(tr, p, tb, period, cp=cp, trust=trust,
                            inexact_window=w,
                            rng=np.random.default_rng(seeds[ti]))
            got = batch.result(ci, ti)
            for f in dataclasses.fields(SimResult):
                assert getattr(got, f.name) == getattr(want, f.name), \\
                    (ci, ti, f.name)
print("JAX-RNG-OK")
"""


@pytest.mark.slow
def test_jax_backend_fixed_probability_and_inexact_subprocess():
    jax = pytest.importorskip("jax")
    del jax
    env = dict(os.environ, JAX_ENABLE_X64="1",
               PYTHONPATH=os.pathsep.join(
                   [os.path.join(os.path.dirname(__file__), "..", "src")]
                   + sys.path))
    proc = subprocess.run([sys.executable, "-c", _JAX_RNG_CHECK], env=env,
                          capture_output=True, text=True, timeout=570)
    assert proc.returncode == 0, proc.stderr
    assert "JAX-RNG-OK" in proc.stdout


def test_jax_backend_runs_adaptive():
    """The flagship jax engine runs adaptive candidates (bitwise parity
    incl. replan sites is asserted in tests/test_jax_engine.py and the
    golden net).  Without x64 the engine refuses loudly instead of
    silently degrading the bitwise contract."""
    pytest.importorskip("jax")
    import jax as _jax
    p = Platform(mu=5e4, c=600.0)
    tr = make_event_trace(Exponential(1.0), p.mu, 0.0, 1.0, 1e4,
                          np.random.default_rng(0))
    cfg = AdaptiveConfig(prior_recall=0.5, prior_precision=0.5)
    kw = dict(trust=ThresholdTrust(1.0), adaptive=cfg, trace_seeds=[0])
    if not _jax.config.jax_enable_x64:
        with pytest.raises(RuntimeError, match="x64"):
            simulate_batch([tr], p, 1e4, [2000.0], backend="jax", **kw)
    else:  # pragma: no cover - depends on session config
        got = simulate_batch([tr], p, 1e4, [2000.0], backend="jax", **kw)
        want = simulate_batch([tr], p, 1e4, [2000.0], **kw)
        assert got.makespan[0, 0] == want.makespan[0, 0]
