"""Substrate layers: optimizer, data pipeline, checkpoint manager, sharding."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # optional test dep: degrade to skip
from hypothesis import given, settings, strategies as st
from jax.sharding import PartitionSpec as P

from repro.ckpt import CheckpointManager, state_bytes
from repro.configs import REGISTRY
from repro.configs.base import InputShape
from repro.data import DataConfig, SyntheticLM
from repro.optim import (AdamWConfig, adamw_init, adamw_update,
                         clip_by_global_norm, cosine_schedule, global_norm,
                         linear_schedule)
from repro.parallel.sharding import (DECODE_RULES, DEFAULT_RULES,
                                     logical_to_spec, spec_tree)


# -- optimizer -------------------------------------------------------------------

def test_adamw_converges_on_quadratic():
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0, clip_norm=100.0)
    params = {"w": jnp.array([5.0, -3.0])}
    state = adamw_init(params, cfg)
    target = jnp.array([1.0, 2.0])
    for _ in range(300):
        grads = jax.grad(
            lambda p: jnp.sum((p["w"] - target) ** 2))(params)
        params, state, _ = adamw_update(params, grads, state, cfg)
    np.testing.assert_allclose(np.asarray(params["w"]), np.asarray(target),
                               atol=1e-2)
    assert int(state["step"]) == 300


def test_clip_by_global_norm():
    tree = {"a": jnp.ones((4,)) * 3.0, "b": jnp.ones((4,)) * 4.0}
    clipped, norm = clip_by_global_norm(tree, 1.0)
    assert float(norm) == pytest.approx(10.0)
    assert float(global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)
    # Below the threshold: untouched.
    same, _ = clip_by_global_norm(tree, 100.0)
    np.testing.assert_allclose(np.asarray(same["a"]), 3.0)


def test_schedules():
    lr = cosine_schedule(1.0, warmup=10, total=110, floor_frac=0.1)
    assert float(lr(jnp.asarray(0))) == pytest.approx(0.0)
    assert float(lr(jnp.asarray(10))) == pytest.approx(1.0, abs=1e-2)
    assert float(lr(jnp.asarray(110))) == pytest.approx(0.1, abs=1e-2)
    lin = linear_schedule(1.0, warmup=10, total=110)
    assert float(lin(jnp.asarray(110))) == pytest.approx(0.0, abs=1e-6)


def test_adamw_bf16_moments():
    cfg = AdamWConfig(lr=0.01, moment_dtype="bfloat16")
    params = {"w": jnp.ones((8,), jnp.bfloat16)}
    state = adamw_init(params, cfg)
    assert state["m"]["w"].dtype == jnp.bfloat16
    grads = {"w": jnp.ones((8,), jnp.bfloat16)}
    params2, state2, _ = adamw_update(params, grads, state, cfg)
    assert params2["w"].dtype == jnp.bfloat16
    assert float(params2["w"][0]) < 1.0


# -- data pipeline -----------------------------------------------------------------

def test_data_determinism_and_resume():
    cfg = REGISTRY["tinyllama-1.1b"].reduced()
    shape = InputShape("t", 32, 4, "train")
    pipe1 = SyntheticLM(cfg, shape, DataConfig(seed=7))
    pipe2 = SyntheticLM(cfg, shape, DataConfig(seed=7))
    for step in (0, 5, 123):
        np.testing.assert_array_equal(
            np.asarray(pipe1.batch_at(step)["tokens"]),
            np.asarray(pipe2.batch_at(step)["tokens"]))
    # Different steps give different data; different seeds differ.
    assert not np.array_equal(np.asarray(pipe1.batch_at(0)["tokens"]),
                              np.asarray(pipe1.batch_at(1)["tokens"]))
    pipe3 = SyntheticLM(cfg, shape, DataConfig(seed=8))
    assert not np.array_equal(np.asarray(pipe1.batch_at(0)["tokens"]),
                              np.asarray(pipe3.batch_at(0)["tokens"]))


def test_data_has_learnable_structure():
    """The bigram injection must be present (loss can go below unigram H)."""
    cfg = REGISTRY["tinyllama-1.1b"].reduced()
    shape = InputShape("t", 256, 4, "train")
    pipe = SyntheticLM(cfg, shape, DataConfig(seed=0))
    toks = np.asarray(pipe.batch_at(0)["tokens"])
    follows = (toks[:, 1:] == (toks[:, :-1] + 17) % cfg.vocab_size).mean()
    assert follows > 0.5  # bigram_prob=0.65 minus collisions


def test_data_modalities():
    for arch in ("hubert-xlarge", "qwen2-vl-72b"):
        cfg = REGISTRY[arch].reduced()
        shape = InputShape("t", 32, 2, "train")
        batch = SyntheticLM(cfg, shape).batch_at(3)
        if arch == "hubert-xlarge":
            assert batch["frames"].shape == (2, 32, cfg.d_model)
        else:
            assert "vision_embeds" in batch and "positions_thw" in batch


# -- checkpoint manager --------------------------------------------------------------

def tiny_state(key=0):
    k = jax.random.PRNGKey(key)
    return {
        "params": {"w": jax.random.normal(k, (64, 32), jnp.bfloat16),
                   "b": jnp.zeros((32,), jnp.float32)},
        "opt": {"m": jax.random.normal(k, (64, 32), jnp.float32)},
        "data_step": jnp.asarray(17, jnp.int32),
    }


def test_full_checkpoint_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    state = tiny_state()
    info = mgr.save(3, state)
    assert info.kind == "full" and os.path.exists(info.path)
    step, restored = mgr.restore(like=state)
    assert step == 3
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_proactive_delta_checkpoint(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    state = tiny_state()
    mgr.save(1, state)
    # Perturb and save a delta.
    state2 = jax.tree.map(
        lambda x: x + (0.01 if jnp.issubdtype(x.dtype, jnp.floating) else 1),
        state)
    info = mgr.save_proactive(2, state2)
    assert info.kind == "proactive"
    step, restored = mgr.restore(like=state)
    assert step == 2
    for a, b in zip(jax.tree.leaves(state2), jax.tree.leaves(restored)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=2e-3)


def test_proactive_payload_smaller(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    state = {"p": jax.random.normal(jax.random.PRNGKey(0), (4096, 64),
                                    jnp.float32)}
    full = mgr.save(1, state)
    state2 = jax.tree.map(lambda x: x * 1.001, state)
    pro = mgr.save_proactive(2, state2)
    assert pro.bytes < 0.45 * full.bytes  # int8+scales vs fp32: ~4x smaller


def test_proactive_without_base_falls_back_to_full(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    info = mgr.save_proactive(1, tiny_state())
    assert info.kind == "full"


def test_gc_keeps_last_two(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    state = tiny_state()
    for s in (1, 2, 3, 4):
        mgr.save(s, state)
    kept = [s for s, k in mgr.checkpoints() if k == "full"]
    assert kept == [3, 4]


def test_modeled_costs(tmp_path):
    mgr = CheckpointManager(str(tmp_path), bandwidth=1e6)
    state = tiny_state()
    c, cp = mgr.modeled_costs(state, n_shards=2)
    assert c == pytest.approx(state_bytes(state) / 2 / 1e6)
    assert cp < c


# -- sharding rules ------------------------------------------------------------------

class FakeMesh:
    """Minimal stand-in exposing .shape (single CPU device tests)."""

    def __init__(self, **shape):
        self.shape = shape


def test_logical_to_spec_basics():
    mesh = FakeMesh(data=4, model=8)
    assert logical_to_spec(("embed", "mlp"), (64, 128), mesh) \
        == P("data", "model")
    # Non-divisible axis replicates.
    assert logical_to_spec(("embed", "mlp"), (62, 128), mesh) \
        == P(None, "model")
    # A mesh axis may only appear once.
    assert logical_to_spec(("mlp", "heads"), (64, 64), mesh) == P("model")


def test_batch_shards_over_pod_and_data():
    mesh = FakeMesh(pod=2, data=4, model=8)
    assert logical_to_spec(("batch", "seq"), (16, 128), mesh) \
        == P(("pod", "data"))
    # Batch not divisible by pod*data falls back to data only.
    assert logical_to_spec(("batch", "seq"), (4, 128), mesh) == P("data")


def test_decode_rules_shard_seq():
    mesh = FakeMesh(data=4, model=8)
    spec = logical_to_spec(("batch", "seq", "kv_heads", None),
                           (16, 1024, 2, 64), mesh, DECODE_RULES)
    assert spec == P("data", "model")  # kv=2 not divisible by 8 -> None


def test_spec_tree_alignment():
    axes = {"w": ("embed", "mlp"), "b": ("mlp",)}
    params = {"w": jnp.zeros((64, 128)), "b": jnp.zeros((128,))}
    mesh = FakeMesh(data=4, model=8)
    specs = spec_tree(axes, params, mesh)
    assert specs["w"] == P("data", "model")
    assert specs["b"] == P("model")
