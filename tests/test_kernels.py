"""Pallas kernels vs pure-jnp oracles (interpret mode), shape/dtype sweeps."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


def rand(key, shape, dtype):
    x = jax.random.normal(key, shape, jnp.float32)
    return x.astype(dtype)


# -- flash attention -------------------------------------------------------------

FLASH_CASES = [
    # (b, sq, skv, h, kv, hd, causal, window, q_offset)
    (2, 128, 128, 4, 4, 64, True, 0, 0),      # MHA causal
    (2, 128, 128, 4, 2, 64, True, 0, 0),      # GQA
    (1, 256, 256, 8, 1, 64, True, 0, 0),      # MQA
    (1, 128, 128, 4, 2, 64, True, 64, 0),     # sliding window
    (2, 128, 256, 4, 2, 32, True, 0, 128),    # continuation (q_offset)
    (2, 128, 128, 4, 4, 64, False, 0, 0),     # bidirectional (encoder)
    (1, 64, 64, 2, 2, 128, True, 0, 0),       # head_dim 128
]


@pytest.mark.parametrize("case", FLASH_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_matches_ref(case, dtype):
    b, sq, skv, h, kv, hd, causal, window, q_offset = case
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = rand(ks[0], (b, sq, h, hd), dtype)
    k = rand(ks[1], (b, skv, kv, hd), dtype)
    v = rand(ks[2], (b, skv, kv, hd), dtype)
    out_ref = ref.flash_attention_ref(q, k, v, causal=causal, window=window,
                                      q_offset=q_offset)
    out = ops.flash_attention(q, k, v, causal=causal, window=window,
                              q_offset=q_offset, impl="pallas_interpret",
                              bq=64, bk=64)
    tol = 2e-6 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(out_ref, np.float32),
                               atol=tol, rtol=tol)


def test_flash_attention_odd_blocks():
    """Block sizes that do not divide seq fall back to smaller divisors."""
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = rand(ks[0], (1, 96, 2, 32), jnp.float32)
    k = rand(ks[1], (1, 96, 2, 32), jnp.float32)
    v = rand(ks[2], (1, 96, 2, 32), jnp.float32)
    out = ops.flash_attention(q, k, v, causal=True, impl="pallas_interpret",
                              bq=64, bk=64)
    out_ref = ref.flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(out_ref),
                               atol=2e-6)


def test_flash_matches_model_reference_path():
    """The model's chunked_attention agrees with the kernel oracle."""
    from repro.models.layers import chunked_attention
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = rand(ks[0], (2, 128, 8, 64), jnp.float32)
    k = rand(ks[1], (2, 128, 2, 64), jnp.float32)
    v = rand(ks[2], (2, 128, 2, 64), jnp.float32)
    a = chunked_attention(q, k, v, causal=True, q_chunk=32, kv_chunk=32)
    b = ref.flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)


# -- decode attention ------------------------------------------------------------

DECODE_CASES = [
    # (b, s, h, kv, hd, window, length)
    (2, 256, 8, 2, 64, 0, 200),
    (2, 256, 8, 8, 64, 0, 17),
    (3, 128, 10, 1, 32, 64, 100),   # ring buffer (recurrentgemma-like GQA)
    (1, 512, 4, 4, 128, 0, 512),
    (2, 128, 4, 2, 64, 128, 40),    # window larger than filled prefix
]


@pytest.mark.parametrize("case", DECODE_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention_matches_ref(case, dtype):
    b, s, h, kv, hd, window, length = case
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    q = rand(ks[0], (b, 1, h, hd), dtype)
    kc = rand(ks[1], (b, s, kv, hd), dtype)
    vc = rand(ks[2], (b, s, kv, hd), dtype)
    lengths = jnp.full((b,), length, jnp.int32)
    out_ref = ref.decode_attention_ref(q, kc, vc, lengths, window=window)
    out = ops.decode_attention(q, kc, vc, lengths, window=window,
                               impl="pallas_interpret", bk=64)
    tol = 2e-6 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(out_ref, np.float32),
                               atol=tol, rtol=tol)


def test_decode_attention_per_batch_lengths():
    ks = jax.random.split(jax.random.PRNGKey(4), 3)
    q = rand(ks[0], (3, 1, 4, 32), jnp.float32)
    kc = rand(ks[1], (3, 128, 2, 32), jnp.float32)
    vc = rand(ks[2], (3, 128, 2, 32), jnp.float32)
    lengths = jnp.array([1, 64, 128], jnp.int32)
    out_ref = ref.decode_attention_ref(q, kc, vc, lengths)
    out = ops.decode_attention(q, kc, vc, lengths,
                               impl="pallas_interpret", bk=32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(out_ref),
                               atol=2e-6)


# -- ckpt delta -------------------------------------------------------------------

@pytest.mark.parametrize("shape", [(1000, 37), (256,), (8, 8, 8),
                                   (4096, 16), (123,)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_quantize_delta_matches_ref(shape, dtype):
    ks = jax.random.split(jax.random.PRNGKey(5), 2)
    base = rand(ks[0], shape, dtype)
    cur = base + 0.01 * rand(ks[1], shape, dtype).astype(dtype)
    q_ref, s_ref = ref.quantize_delta_ref(cur, base)
    q, s = ops.quantize_delta(cur, base, impl="pallas_interpret")
    # Fused divide-vs-reciprocal rounding may flip exact .5 ties by +-1 on a
    # tiny fraction of elements; anything more is a real bug.
    diff = np.abs(np.asarray(q, np.int32) - np.asarray(q_ref, np.int32))
    assert diff.max() <= 1
    assert (diff > 0).mean() < 2e-3
    np.testing.assert_allclose(np.asarray(s), np.asarray(s_ref), rtol=1e-6)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_delta_roundtrip_error_bound(dtype):
    """Reconstruction error <= scale/2 = absmax/254 per block."""
    ks = jax.random.split(jax.random.PRNGKey(6), 2)
    base = rand(ks[0], (513, 17), dtype)
    cur = base + 0.05 * rand(ks[1], (513, 17), dtype).astype(dtype)
    q, s = ops.quantize_delta(cur, base, impl="pallas_interpret")
    rec = ops.dequantize_delta(q, s, base, impl="pallas_interpret")
    delta = np.abs(np.asarray(cur, np.float32) - np.asarray(rec, np.float32))
    bound = float(np.max(np.asarray(s))) * 0.5 + 1e-2 * (
        dtype == jnp.bfloat16)
    assert delta.max() <= bound + 1e-7


def test_quantize_zero_delta():
    x = jnp.ones((512,), jnp.float32)
    q, s = ops.quantize_delta(x, x, impl="pallas_interpret")
    assert int(jnp.abs(q.astype(jnp.int32)).max()) == 0
    rec = ops.dequantize_delta(q, s, x, impl="pallas_interpret")
    np.testing.assert_array_equal(np.asarray(rec), np.asarray(x))


# -- JAX version-compat shim ------------------------------------------------

def test_compiler_params_shim_resolves_both_names():
    """The kernels' compiler-params class must resolve under either the new
    (CompilerParams) or legacy (TPUCompilerParams) pltpu attribute name."""
    from types import SimpleNamespace

    from jax.experimental.pallas import tpu as pltpu

    from repro.kernels.compat import CompilerParams, resolve_compiler_params

    class New:
        pass

    class Old:
        pass

    assert resolve_compiler_params(SimpleNamespace(CompilerParams=New)) is New
    assert resolve_compiler_params(
        SimpleNamespace(TPUCompilerParams=Old)) is Old
    # The new name wins when both exist (it is the non-deprecated one).
    assert resolve_compiler_params(
        SimpleNamespace(CompilerParams=New, TPUCompilerParams=Old)) is New
    with pytest.raises(AttributeError):
        resolve_compiler_params(SimpleNamespace())
    # The module-level alias matches this JAX's pltpu and accepts the
    # argument every kernel passes.
    assert CompilerParams is resolve_compiler_params(pltpu)
    CompilerParams(dimension_semantics=("parallel", "arbitrary"))


# -- kernels wired into the model (attn_impl config knob) -------------------

@pytest.mark.parametrize("arch", ["llama3.2-1b", "recurrentgemma-2b"])
def test_model_level_kernel_parity(arch):
    """forward_train/decode with Pallas(interpret) == reference path."""
    import dataclasses
    from repro.configs import REGISTRY
    from repro.configs.base import InputShape
    from repro.models import (decode_step, forward_train, init_params,
                              make_batch, prefill)
    cfg = dataclasses.replace(REGISTRY[arch].reduced(), dtype="float32",
                              remat=False)
    params, _ = init_params(cfg, jax.random.PRNGKey(0))
    batch = make_batch(cfg, InputShape("t", 32, 2, "train"),
                       jax.random.PRNGKey(1))
    cfgk = dataclasses.replace(cfg, attn_impl="pallas_interpret")
    l_ref, _ = forward_train(cfg, params, batch)
    l_pal, _ = forward_train(cfgk, params, batch)
    np.testing.assert_allclose(np.asarray(l_pal), np.asarray(l_ref),
                               atol=1e-3)
    _, cache = prefill(cfg, params, batch, cache_len=40)
    _, cachek = prefill(cfgk, params, batch, cache_len=40)
    tok = batch["tokens"][:, -1]
    lg_ref, _ = decode_step(cfg, params, tok, cache)
    lg_pal, _ = decode_step(cfgk, params, tok, cachek)
    np.testing.assert_allclose(np.asarray(lg_pal), np.asarray(lg_ref),
                               atol=1e-3)
