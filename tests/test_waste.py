"""Paper §3: first-order waste model, periods, exact Exponential optimum."""

import math

import pytest

pytest.importorskip("hypothesis")  # optional test dep: degrade to skip
from hypothesis import given, settings, strategies as st

from repro.core.waste import (ALPHA_CAP, Platform, clamp_period,
                              expected_makespan_exponential,
                              expected_makespan_first_order, lambert_w,
                              platform_mtbf, t_daly, t_exact_exponential,
                              t_rfo, t_young, waste, waste_fault, waste_ff)

MU_IND = 125.0 * 365.0 * 86400.0  # paper: 125-year individual MTBF


def plat(n: int, c=600.0, d=60.0, r=600.0) -> Platform:
    return Platform(mu=platform_mtbf(MU_IND, n), c=c, d=d, r=r)


# Paper Table 2 rows: N -> (Young, Daly, RFO, Optimal), seconds.
TABLE2 = {
    2**10: (68567, 68573, 67961, 68240),
    2**12: (34584, 34595, 33972, 34189),
    2**14: (17592, 17615, 16968, 17194),
    2**16: (9096, 9142, 8449, 8701),
    2**18: (4848, 4940, 4154, 4458),
    2**19: (3604, 3733, 2869, 3218),
}


@pytest.mark.parametrize("n", sorted(TABLE2))
def test_table2_periods(n):
    """Young/Daly/RFO/exact periods reproduce paper Table 2 (0.1% tol)."""
    p = plat(n)
    young, daly, rfo, opt = TABLE2[n]
    assert t_young(p) == pytest.approx(young, rel=1e-3)
    assert t_daly(p) == pytest.approx(daly, rel=1e-3)
    assert t_rfo(p) == pytest.approx(rfo, rel=1e-3)
    assert t_exact_exponential(p) == pytest.approx(opt, rel=2e-2)


def test_table2_error_pattern():
    """Young/Daly overestimate the optimum, RFO underestimates (paper §3)."""
    for n in TABLE2:
        p = plat(n)
        opt = t_exact_exponential(p)
        assert t_young(p) > opt
        assert t_daly(p) > opt
        assert t_rfo(p) < opt


def test_waste_composition():
    p = plat(2**16)
    t = t_rfo(p)
    wff, wf = waste_ff(t, p.c), waste_fault(t, p)
    assert waste(t, p) == pytest.approx(wff + wf - wff * wf)


def test_waste_ff_requires_c_le_t():
    with pytest.raises(ValueError):
        waste_ff(10.0, 600.0)


@given(st.integers(min_value=2**8, max_value=2**20))
@settings(max_examples=30, deadline=None)
def test_rfo_minimizes_waste(n):
    """T_RFO is the argmin of the first-order waste (convexity, Eq. 12)."""
    p = plat(n)
    t0 = t_rfo(p)
    w0 = waste(t0, p)
    for f in (0.5, 0.8, 0.95, 1.05, 1.25, 2.0):
        t = max(p.c, t0 * f)
        assert waste(t, p) >= w0 - 1e-12


@given(st.floats(min_value=-0.36, max_value=50.0))
@settings(max_examples=200, deadline=None)
def test_lambert_w_identity(z):
    w = lambert_w(z)
    assert w * math.exp(w) == pytest.approx(z, abs=1e-9, rel=1e-9)


def test_exact_exponential_is_optimal():
    """The Lambert-W period beats its neighbourhood on the exact makespan."""
    p = plat(2**16)
    t0 = t_exact_exponential(p)
    m0 = expected_makespan_exponential(t0, 7200.0, p)
    for f in (0.9, 0.95, 1.05, 1.1):
        assert expected_makespan_exponential(t0 * f, 7200.0, p) >= m0


def test_clamp_period():
    p = plat(2**19)
    assert clamp_period(1.0, p) == p.c
    assert clamp_period(1e9, p, enforce_cap=True) == ALPHA_CAP * p.mu
    assert clamp_period(1e9, p) == 1e9  # uncapped by default (paper §3 end)


def test_first_order_makespan_monotone_in_waste():
    p = plat(2**16)
    t = t_rfo(p)
    assert expected_makespan_first_order(t, 1e6, p) > 1e6


def test_platform_mtbf_scaling():
    """Prop. 2: platform MTBF scales as mu_ind / N."""
    assert platform_mtbf(100.0, 4) == 25.0
    with pytest.raises(ValueError):
        platform_mtbf(100.0, 0)
    with pytest.raises(ValueError):
        platform_mtbf(-1.0, 4)
