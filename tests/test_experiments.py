"""Experiment API: spec round-trips, registry coverage, batched-runner
equivalence with the legacy serial evaluation loops."""

import json
import math

import numpy as np
import pytest

from repro.core.policies import Strategy, best_period, evaluate
from repro.core.simulator import simulate
from repro.experiments import (DistributionSpec, EvalCache, ExperimentSpec,
                               ResultTable, ScenarioSpec, StrategySpec,
                               SweepSpec, BestPeriodSearch, build_distribution,
                               build_strategy, evaluate_strategies,
                               list_distributions, list_strategies,
                               run_experiment)
from repro.experiments.runner import best_period_search

# A deliberately small cell: mu = 1e5 s, short job, no start offset, so each
# trace holds a handful of events and the whole module runs in seconds.
SMALL = ScenarioSpec(n=32, dist=DistributionSpec("weibull", {"shape": 0.7}),
                     mu_ind=32 * 1e5, c=600.0, d=60.0, r=600.0,
                     time_base_years_total=0.1, start=0.0, n_traces=4,
                     seed=3)


# ---------------------------------------------------------------------------
# Spec serialization
# ---------------------------------------------------------------------------

def test_scenario_spec_round_trip():
    spec = SMALL.replace(**{"false_pred_dist": DistributionSpec("uniform"),
                            "extras.phi": 0.7})
    again = ScenarioSpec.from_dict(json.loads(json.dumps(spec.to_dict())))
    assert again == spec
    assert again.key() == spec.key()


def test_experiment_spec_round_trip():
    exp = ExperimentSpec(
        name="rt",
        scenario=SMALL,
        sweep=SweepSpec(
            axes={"recall,precision": [(0.85, 0.82), (0.7, 0.4)],
                  "dist.params.shape": [0.5, 0.7]},
            labels={"recall,precision": ["good", "fair"]},
            names={"recall,precision": "predictor"}),
        strategies=(StrategySpec("rfo"),
                    StrategySpec("best_period", {"base": "rfo",
                                                 "n_points": 6})),
        metrics=("makespan", "waste"),
    )
    assert ExperimentSpec.from_json(exp.to_json()) == exp


def test_scenario_replace_dotted_paths():
    spec = SMALL.replace(**{"n": 64, "dist.params.shape": 0.5,
                            "extras.k": 2})
    assert spec.n == 64
    assert spec.dist.params["shape"] == 0.5
    assert spec.extras["k"] == 2
    assert SMALL.dist.params["shape"] == 0.7  # original untouched
    with pytest.raises(KeyError):
        SMALL.replace(no_such_field=1)


def test_scenario_derived_quantities():
    assert SMALL.mu == pytest.approx(1e5)
    assert SMALL.platform.c == 600.0
    assert SMALL.pp.cp == SMALL.cp_ratio * SMALL.c
    assert SMALL.time_base == pytest.approx(0.1 * 365 * 86400 / 32)


# ---------------------------------------------------------------------------
# Sweeps
# ---------------------------------------------------------------------------

def test_sweep_cartesian_order_and_columns():
    sweep = SweepSpec(axes={"n": [16, 32], "cp_ratio": [1.0, 0.1, 2.0]},
                      labels={"cp_ratio": ["equal", "cheap", "expensive"]})
    cells = list(sweep.cells(SMALL))
    assert len(cells) == 6
    # First axis is major; labels replace raw values in columns.
    assert [c["n"] for c, _ in cells] == [16, 16, 16, 32, 32, 32]
    assert [c["cp_ratio"] for c, _ in cells][:3] == \
        ["equal", "cheap", "expensive"]
    assert cells[1][1].cp_ratio == 0.1 and cells[1][1].n == 16


def test_sweep_zip_and_compound_axis():
    sweep = SweepSpec(axes={"recall,precision": [(0.9, 0.9), (0.5, 0.3)],
                            "n": [16, 32]},
                      mode="zip",
                      names={"recall,precision": "predictor"})
    cells = list(sweep.cells(SMALL))
    assert len(cells) == 2
    cols, spec = cells[1]
    assert spec.recall == 0.5 and spec.precision == 0.3 and spec.n == 32
    assert cols["predictor"] == "0.5/0.3"
    with pytest.raises(ValueError):
        SweepSpec(axes={"n": [1, 2], "recall": [0.1]}, mode="zip")


# ---------------------------------------------------------------------------
# Registry coverage
# ---------------------------------------------------------------------------

# Params needed to build each strategy on the SMALL scenario.
_STRATEGY_PARAMS = {
    "fixed_period": {"period": 5000.0},
    "best_period": {"base": "rfo", "n_points": 4},
}

_DISTRIBUTION_PARAMS = {
    "empirical": {"samples": (10.0, 20.0, 30.0)},
    "lanl": {"n_intervals": 50},
}


def test_every_registered_strategy_builds():
    names = list_strategies()
    assert {"young", "daly", "rfo", "optimal_prediction",
            "inexact_prediction", "simple_policy", "best_period",
            "dynamic_rfo", "dynamic_prediction"} <= set(names)
    for name in names:
        built = build_strategy(name, SMALL, **_STRATEGY_PARAMS.get(name, {}))
        assert isinstance(built, (Strategy, BestPeriodSearch)), name
        if isinstance(built, Strategy) and not callable(built.period):
            assert built.period >= SMALL.c


def test_every_registered_distribution_builds_and_samples():
    rng = np.random.default_rng(0)
    for name in list_distributions():
        dist = build_distribution(name, **_DISTRIBUTION_PARAMS.get(name, {}))
        draws = dist.sample(rng, 8)
        assert draws.shape == (8,)
        assert np.all(draws >= 0)
        assert dist.mean > 0


def test_strategy_spec_build_and_display():
    sspec = StrategySpec("inexact_prediction", {"window": 900.0},
                         label="Inexact(900)")
    strat = sspec.build(SMALL)
    assert strat.inexact_window == 900.0
    assert sspec.display == "Inexact(900)"


def test_dynamic_strategy_requires_shape():
    sc = SMALL.replace(dist=DistributionSpec("exponential"))
    with pytest.raises(ValueError):
        build_strategy("dynamic_rfo", sc)
    strat = build_strategy("dynamic_rfo", sc, shape=0.7)
    assert callable(strat.period)
    assert strat.period(0.0) >= sc.c


# ---------------------------------------------------------------------------
# Batched runner == legacy serial loops, bit for bit
# ---------------------------------------------------------------------------

def _legacy_evaluate(strategy, traces, platform, time_base, cp, seed=0):
    """The historical policies.evaluate loop, verbatim."""
    total = 0.0
    for i, trace in enumerate(traces):
        rng = np.random.default_rng(seed + 7919 * i)
        res = simulate(trace, platform, time_base, strategy.period,
                       cp=cp, trust=strategy.trust,
                       inexact_window=strategy.inexact_window, rng=rng)
        total += res.makespan
    return total / max(1, len(traces))


def _strategies_under_test():
    return [build_strategy("rfo", SMALL),
            build_strategy("optimal_prediction", SMALL),
            build_strategy("inexact_prediction", SMALL),
            build_strategy("young", SMALL)]


def test_runner_matches_legacy_evaluate_bit_for_bit():
    traces = SMALL.make_traces()
    plat, tb, cp = SMALL.platform, SMALL.time_base, SMALL.cp
    strategies = _strategies_under_test()
    batched = evaluate_strategies(traces, plat, tb, cp, strategies, seed=7)
    for strat, got in zip(strategies, batched):
        want = _legacy_evaluate(strat, traces, plat, tb, cp, seed=7)
        assert got == want  # exact float equality, not approx


def test_policies_evaluate_wrapper_matches_legacy():
    traces = SMALL.make_traces()
    plat, tb, cp = SMALL.platform, SMALL.time_base, SMALL.cp
    strat = build_strategy("optimal_prediction", SMALL)
    assert evaluate(strat, traces, plat, tb, cp, seed=5) == \
        _legacy_evaluate(strat, traces, plat, tb, cp, seed=5)


def test_cache_dedupes_identical_candidates():
    traces = SMALL.make_traces()
    plat, tb, cp = SMALL.platform, SMALL.time_base, SMALL.cp
    rfo = build_strategy("rfo", SMALL)
    cache = EvalCache()
    m1 = evaluate_strategies(traces, plat, tb, cp, [rfo, rfo], cache=cache)
    assert m1[0] == m1[1]
    assert cache.misses == len(traces)  # the duplicate cost nothing
    # A second call against the warm cache simulates nothing new.
    evaluate_strategies(traces, plat, tb, cp, [rfo], cache=cache)
    assert cache.misses == len(traces)


def test_best_period_matches_legacy_search():
    """The deduped grid search must find the legacy optimum (same period,
    same mean makespan)."""
    traces = SMALL.make_traces()
    plat, tb, cp = SMALL.platform, SMALL.time_base, SMALL.cp
    base = build_strategy("rfo", SMALL)

    # Legacy algorithm, verbatim (pre-dedupe).
    t0 = base.period
    lo = max(plat.c * 1.001, t0 / 8.0)
    hi = max(lo * 1.01, t0 * 8.0)
    grid = np.append(np.geomspace(lo, hi, 12), t0)
    best_t, best_m = t0, math.inf
    for t in grid:
        m = _legacy_evaluate(base.with_period(float(t)), traces, plat, tb, cp)
        if m < best_m:
            best_t, best_m = float(t), m

    refined, got_m = best_period(base, traces, plat, tb, cp, n_points=12)
    assert refined.period == best_t
    assert got_m == best_m
    assert refined.name == "BestPeriod(RFO)"


def test_best_period_search_reuses_cache():
    traces = SMALL.make_traces()
    plat, tb, cp = SMALL.platform, SMALL.time_base, SMALL.cp
    base = build_strategy("rfo", SMALL)
    cache = EvalCache()
    evaluate_strategies(traces, plat, tb, cp, [base], cache=cache)
    sims_before = cache.misses
    best_period_search(base, traces, plat, tb, cp, n_points=6, cache=cache)
    # The grid is the 6 log-spaced points plus the analytic period t0; t0
    # was already simulated, so only the 6 new points cost anything.
    assert cache.misses == sims_before + 6 * len(traces)
    assert cache.hits >= len(traces)


# ---------------------------------------------------------------------------
# run_experiment + ResultTable
# ---------------------------------------------------------------------------

def test_run_experiment_sweep_and_metrics():
    exp = ExperimentSpec(
        name="t",
        scenario=SMALL,
        sweep=SweepSpec(axes={"n": [32, 64]}),
        strategies=(StrategySpec("rfo"), StrategySpec("optimal_prediction")),
        metrics=("makespan", "makespan_days", "waste"),
    )
    table = run_experiment(exp)
    assert len(table) == 4
    assert set(table.columns) >= {"n", "strategy", "period", "makespan",
                                  "makespan_days", "waste"}
    m = table.value("makespan", n=32, strategy="RFO")
    assert table.value("makespan_days", n=32, strategy="RFO") == \
        pytest.approx(m / 86400.0)
    want = _legacy_evaluate(build_strategy("rfo", SMALL),
                            SMALL.make_traces(), SMALL.platform,
                            SMALL.time_base, SMALL.cp, seed=SMALL.seed)
    assert m == want
    # waste = 1 - time_base / makespan
    assert table.value("waste", n=32, strategy="RFO") == \
        pytest.approx(1.0 - SMALL.time_base / m)


def test_run_experiment_analytic_mode():
    exp = ExperimentSpec(
        name="analytic",
        scenario=SMALL.replace(n_traces=0),
        strategies=(StrategySpec("young"), StrategySpec("daly")),
        metrics=(),
    )
    table = run_experiment(exp)
    periods = table.strategy_dict("period")
    assert periods["Young"] > periods["Daly"] * 0  # both present, positive
    assert set(periods) == {"Young", "Daly"}


def test_result_table_helpers():
    table = ResultTable([{"a": 1, "s": "x", "v": 2.0},
                         {"a": 1, "s": "y", "v": 4.0},
                         {"a": 2, "s": "x", "v": 6.0}])
    assert len(table.where(a=1)) == 2
    assert table.value("v", a=2, s="x") == 6.0
    assert table.mean("v", a=1) == 3.0
    with pytest.raises(KeyError):
        table.value("v", a=1)  # ambiguous
    assert json.loads(table.to_json()) == table.rows
    assert "strategy" not in table.columns
    formatted = table.format(["a", "v"])
    assert "6.00" in formatted
