"""Paper §4: predictor algebra, Theorem 1, optimal periods with prediction."""

import math

import numpy as np
import pytest

pytest.importorskip("hypothesis")  # optional test dep: degrade to skip
from hypothesis import given, settings, strategies as st

from repro.core.prediction import (PredictedPlatform, Predictor, beta_lim,
                                   optimal_period_with_prediction, optimal_q,
                                   t_nopred, t_pred, t_pred_asymptotic,
                                   waste1, waste2, waste_simple_policy,
                                   waste_with_prediction)
from repro.core.waste import Platform

MU_IND = 125.0 * 365.0 * 86400.0


def pp(n=2**16, c=600.0, cp=600.0, d=60.0, r=600.0, recall=0.85,
       precision=0.82) -> PredictedPlatform:
    plat = Platform(mu=MU_IND / n, c=c, d=d, r=r)
    return PredictedPlatform(plat, Predictor(recall, precision), cp)


# -- §2.3 event-rate algebra ---------------------------------------------------

def test_event_rates():
    pred = Predictor(recall=0.85, precision=0.82)
    mu = 1000.0
    assert pred.mu_np(mu) == pytest.approx(mu / 0.15)
    assert pred.mu_p(mu) == pytest.approx(0.82 * mu / 0.85)
    inv = 1.0 / pred.mu_p(mu) + 1.0 / pred.mu_np(mu)
    assert pred.mu_e(mu) == pytest.approx(1.0 / inv)
    # False-prediction rate: (1-p) of predictions.
    assert pred.mu_false(mu) == pytest.approx(pred.mu_p(mu) / (1 - 0.82))


def test_event_rates_edge_cases():
    assert Predictor(1.0, 0.9).mu_np(100.0) == math.inf
    assert Predictor(0.0, 0.9).mu_p(100.0) == math.inf
    assert Predictor(0.9, 1.0).mu_false(100.0) == math.inf


# -- §4.1 simple policy ---------------------------------------------------------

@given(st.floats(0.05, 0.99), st.floats(0.05, 0.99),
       st.integers(2**10, 2**19))
@settings(max_examples=50, deadline=None)
def test_optimal_q_is_extreme(r, p, n):
    """Waste is linear in q, so the optimum is always q in {0, 1}."""
    ppl = pp(n=n, recall=r, precision=p)
    t = 2.0 * ppl.platform.c + 1000.0
    w0 = waste_simple_policy(t, 0.0, ppl)
    w1 = waste_simple_policy(t, 1.0, ppl)
    wmid = waste_simple_policy(t, 0.5, ppl)
    assert wmid == pytest.approx(0.5 * (w0 + w1), rel=1e-9)  # linearity
    assert optimal_q(t, ppl) in (0, 1)


# -- §4.2 Theorem 1 --------------------------------------------------------------

def test_beta_lim():
    assert beta_lim(pp(cp=600.0, precision=0.82)) == pytest.approx(600 / 0.82)


def test_waste_branches_coincide_at_beta_lim():
    ppl = pp()
    t = beta_lim(ppl)
    if t >= ppl.platform.c:
        assert waste1(t, ppl) == pytest.approx(waste2(t, ppl), rel=1e-9)


def test_waste2_equals_waste1_when_r0():
    """With recall 0 no proactive action: branches coincide for all T."""
    ppl = pp(recall=1e-12)
    for t in (1000.0, 5000.0, 20000.0):
        assert waste1(t, ppl) == pytest.approx(waste2(t, ppl), rel=1e-6)


def test_theorem1_breakpoint_optimality():
    """Acting iff offset >= C_p/p beats earlier/later thresholds (numeric).

    We evaluate the §4.2 waste integral for a family of threshold policies
    directly (trust iff t >= beta): the expected waste per prediction is
    int_0^beta p (t + D + R) dt + int_beta^T (p (C_p + D + R) + (1-p) C_p) dt.
    The minimizing beta must be C_p / p.
    """
    ppl = pp()
    plat, pred = ppl.platform, ppl.predictor
    p = pred.precision
    t_end = 20000.0

    def pred_cost(beta):
        a = p * (beta**2 / 2 + (plat.d + plat.r) * beta)
        b = (p * (ppl.cp + plat.d + plat.r) + (1 - p) * ppl.cp) \
            * (t_end - beta)
        return a + b

    b_star = beta_lim(ppl)
    c_star = pred_cost(b_star)
    for b in np.linspace(0.0, t_end, 101):
        assert pred_cost(float(b)) >= c_star - 1e-6


# -- §4.3 optimal period ----------------------------------------------------------

@pytest.mark.parametrize("n", [2**10, 2**14, 2**16, 2**19])
@pytest.mark.parametrize("cp_ratio", [1.0, 0.1, 2.0])
def test_t_pred_is_argmin_of_waste2(n, cp_ratio):
    ppl = pp(n=n, cp=600.0 * cp_ratio)
    t0 = t_pred(ppl)
    w0 = waste2(t0, ppl)
    lo = max(ppl.platform.c, beta_lim(ppl))
    for t in np.geomspace(lo, 50 * t0, 200):
        assert waste2(float(t), ppl) >= w0 - 1e-12


def test_optimal_period_beats_grid():
    """The §4.3 closed-form beats a dense grid search on Eq. 15."""
    ppl = pp(n=2**16)
    t_star, w_star, use = optimal_period_with_prediction(ppl)
    grid = np.geomspace(ppl.platform.c, 100 * t_star, 400)
    w_grid = min(waste_with_prediction(float(t), ppl) for t in grid)
    assert w_star <= w_grid + 1e-12
    assert use  # a good predictor should be used at this scale


def test_prediction_reduces_waste():
    """At large scale, the predicted-optimal waste < RFO waste (paper §5)."""
    from repro.core.waste import t_rfo, waste
    for n in (2**16, 2**19):
        ppl = pp(n=n)
        _, w_pred, _ = optimal_period_with_prediction(ppl)
        w_rfo = waste(t_rfo(ppl.platform), ppl.platform)
        assert w_pred < w_rfo


def test_asymptotic_period():
    """T* ~ sqrt(2 mu C / (1-r)) for large mu (paper §4.3 remark)."""
    ppl = pp(n=2**8)  # large mu
    t_star, _, _ = optimal_period_with_prediction(ppl)
    assert t_star == pytest.approx(t_pred_asymptotic(ppl), rel=0.05)


def test_t_nopred_clamped_to_interval():
    ppl = pp(n=2**19, cp=60.0)  # beta_lim = 73 s < C: degenerate interval
    assert t_nopred(ppl) == ppl.platform.c  # clamped to the C lower bound
    ppl2 = pp(n=2**19, cp=6000.0)  # beta_lim = 7317 s, T_RFO ~ 2869 s
    t2 = t_nopred(ppl2)
    assert ppl2.platform.c <= t2 <= beta_lim(ppl2) + 1e-9


def test_degenerate_beta_lim_excludes_waste1_branch():
    """beta_lim < C: the WASTE1 validity interval [C, C_p/p] is empty, so
    the optimum must come from the WASTE2 branch alone — not from comparing
    against WASTE1 evaluated out of domain at T = C."""
    ppl = pp(n=2**19, cp=60.0)
    assert beta_lim(ppl) < ppl.platform.c
    t_star, w_star, use = optimal_period_with_prediction(ppl)
    assert use
    assert t_star == pytest.approx(t_pred(ppl))
    assert w_star == pytest.approx(waste2(t_star, ppl))


@given(st.floats(0.1, 0.95), st.floats(0.1, 0.95),
       st.sampled_from([0.1, 0.5, 1.0, 2.0]), st.integers(2**10, 2**19))
@settings(max_examples=60, deadline=None)
def test_optimal_never_worse_than_no_prediction(r, p, cp_ratio, n):
    """min(WASTE1*, WASTE2*) <= WASTE1* by construction — and the chosen
    branch's waste must match waste_with_prediction at T*.  The WASTE1
    comparison only applies when its validity interval [C, C_p/p] is
    non-empty; otherwise only the WASTE2 branch exists."""
    ppl = pp(n=n, recall=r, precision=p, cp=600.0 * cp_ratio)
    t_star, w_star, use = optimal_period_with_prediction(ppl)
    if beta_lim(ppl) >= ppl.platform.c:
        w1 = waste1(t_nopred(ppl), ppl)
        assert w_star <= w1 + 1e-12
    else:
        assert use
    assert w_star == pytest.approx(
        waste_with_prediction(max(t_star, ppl.platform.c), ppl), rel=1e-6)
