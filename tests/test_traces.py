"""Trace generation: renewal processes, superposition, recall/precision."""

import math

import numpy as np
import pytest

pytest.importorskip("hypothesis")  # optional test dep: degrade to skip
from hypothesis import given, settings, strategies as st

from repro.core.traces import (FALSE_PRED, FAULT_PRED, FAULT_UNPRED,
                               Empirical, Exponential, LogNormalDist,
                               UniformDist, Weibull, lanl_like_log,
                               make_event_trace, renewal_trace,
                               superposed_trace)


@pytest.mark.parametrize("dist", [
    Exponential(100.0), Weibull(0.7, 100.0), Weibull(0.5, 100.0),
    UniformDist(100.0), LogNormalDist(1.0, 100.0),
])
def test_distribution_means(dist):
    rng = np.random.default_rng(0)
    s = dist.sample(rng, 200_000)
    assert s.mean() == pytest.approx(100.0, rel=0.05)
    assert (s >= 0).all()


@pytest.mark.parametrize("dist", [
    Exponential(123.0), Weibull(0.7, 123.0), UniformDist(123.0),
])
def test_rescaled(dist):
    r = dist.rescaled(42.0)
    rng = np.random.default_rng(1)
    assert r.sample(rng, 100_000).mean() == pytest.approx(42.0, rel=0.05)


def test_renewal_trace_rate():
    rng = np.random.default_rng(2)
    t = renewal_trace(Exponential(10.0), 100_000.0, rng)
    assert len(t) == pytest.approx(10_000, rel=0.05)
    assert (np.diff(t) > 0).all()
    assert t[-1] < 100_000.0


@given(st.integers(2, 64))
@settings(max_examples=10, deadline=None)
def test_superposition_mtbf(n):
    """Paper Prop. 2 empirically: N streams of mean mu_ind -> rate N/mu_ind."""
    rng = np.random.default_rng(3)
    mu_ind = 1000.0
    horizon = 50_000.0
    t = superposed_trace(Weibull(0.7, mu_ind), n, horizon, rng)
    expected = horizon * n / mu_ind
    assert len(t) == pytest.approx(expected, rel=0.25)
    assert (np.diff(t) >= 0).all()


def test_event_trace_composition():
    rng = np.random.default_rng(4)
    mu, r, p = 100.0, 0.85, 0.4
    tr = make_event_trace(Exponential(1.0), mu, r, p, horizon=200_000.0,
                          rng=rng)
    kinds = tr.kinds
    n_faults = int((kinds != FALSE_PRED).sum())
    n_pred_faults = int((kinds == FAULT_PRED).sum())
    n_false = int((kinds == FALSE_PRED).sum())
    # Fault rate ~ 1/mu.
    assert n_faults == pytest.approx(200_000 / mu, rel=0.1)
    # Recall: fraction of faults predicted.
    assert n_pred_faults / n_faults == pytest.approx(r, abs=0.03)
    # Precision: true predictions / all predictions.
    assert n_pred_faults / (n_pred_faults + n_false) == pytest.approx(
        p, abs=0.03)
    assert tr.empirical_mtbf() == pytest.approx(mu, rel=0.1)
    # Times sorted.
    assert (np.diff(tr.times) >= 0).all()


def test_event_trace_no_false_preds_when_precision_1():
    rng = np.random.default_rng(5)
    tr = make_event_trace(Exponential(1.0), 100.0, 0.9, 1.0, 50_000.0, rng)
    assert int((tr.kinds == FALSE_PRED).sum()) == 0


def test_event_trace_superposed_matches_platform_rate():
    rng = np.random.default_rng(6)
    tr = make_event_trace(Weibull(0.7, 1.0), 100.0, 0.0, 1.0, 100_000.0,
                          rng, n_processors=32)
    assert tr.n_faults == pytest.approx(1000, rel=0.15)


def test_empirical_distribution():
    emp = Empirical(tuple(float(x) for x in range(1, 101)))
    assert emp.mean == pytest.approx(50.5)
    r = emp.rescaled(101.0)
    assert r.mean == pytest.approx(101.0)
    rng = np.random.default_rng(7)
    s = emp.sample(rng, 10_000)
    assert set(np.unique(s)).issubset(set(float(x) for x in range(1, 101)))


def test_lanl_like_log():
    rng = np.random.default_rng(8)
    emp = lanl_like_log(rng, n_intervals=3010, mu_ind_days=691.0)
    assert len(emp.samples) == 3010
    assert emp.mean == pytest.approx(691.0 * 86400.0, rel=0.2)
    assert min(emp.samples) >= 60.0
