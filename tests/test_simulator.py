"""Discrete-event simulator: mechanics + agreement with the analytic model."""

import numpy as np
import pytest

from repro.core.prediction import (PredictedPlatform, Predictor, beta_lim,
                                   optimal_period_with_prediction)
from repro.core.simulator import (AlwaysTrust, NeverTrust, ThresholdTrust,
                                  simulate)
from repro.core.traces import EventTrace, Exponential, make_event_trace
from repro.core.waste import Platform, t_rfo, waste

MU_IND = 125.0 * 365.0 * 86400.0


def trace_of(times, kinds, horizon=1e9):
    return EventTrace(np.asarray(times, float), np.asarray(kinds, np.int8),
                      horizon)


def test_fault_free_execution():
    """No faults: makespan = ceil(W / (T-C)) periods of T (final ckpt incl.)."""
    p = Platform(mu=1e12, c=10.0, d=1.0, r=2.0)
    res = simulate(trace_of([], []), p, time_base=360.0, period=100.0)
    # 4 chunks of 90 => 4 checkpoints of 10 => 400 s.
    assert res.makespan == pytest.approx(400.0)
    assert res.n_periodic_ckpts == 4
    assert res.waste == pytest.approx(0.1)


def test_single_fault_rollback():
    """One fault mid-period destroys work since the last checkpoint."""
    p = Platform(mu=1e12, c=10.0, d=5.0, r=20.0)
    # Fault at t=150: first period [0,100) saved 90; 50 s into period 2,
    # 50 s destroyed (40 work + 10 in ckpt? no: work till 190 then ckpt).
    res = simulate(trace_of([150.0], [0]), p, time_base=360.0, period=100.0)
    # Timeline: [0,90) work, [90,100) ckpt, [100,150) 50 work destroyed,
    # downtime 5 + recovery 20 -> 175, then remaining 270 work in 3 periods
    # = 3*100, makespan = 175 + 300 = 475.
    assert res.makespan == pytest.approx(475.0)
    assert res.n_faults_hit == 1
    assert res.time_lost == pytest.approx(50.0)
    assert res.time_down == pytest.approx(25.0)


def test_fault_during_checkpoint_rolls_back_to_previous():
    p = Platform(mu=1e12, c=10.0, d=0.0, r=0.0)
    # Fault at t=95 (inside the first checkpoint): the 90 work units since
    # the last save are destroyed.
    res = simulate(trace_of([95.0], [0]), p, time_base=180.0, period=100.0)
    # 90 s of work + 5 s of aborted checkpoint.
    assert res.time_lost == pytest.approx(95.0)
    # 95 (wasted) + 90+10 + 90+10 = 295.
    assert res.makespan == pytest.approx(295.0)


def test_trusted_prediction_saves_work():
    """A true prediction with a proactive ckpt loses only C_p + D + R."""
    p = Platform(mu=1e12, c=10.0, d=2.0, r=3.0)
    cp = 4.0
    res = simulate(trace_of([50.0], [1]), p, time_base=360.0, period=100.0,
                   cp=cp, trust=AlwaysTrust())
    # Proactive ckpt at [46, 50): fault at 50 destroys nothing; D+R=5 -> 55.
    # Remaining 360-46=314 work: period restarts -> 4 more periods
    # (90+10)*3 + 44+10... let the engine count; check the key quantities:
    assert res.n_trusted == 1
    assert res.n_trusted_true == 1
    assert res.time_lost == pytest.approx(0.0)
    assert res.time_prockpt == pytest.approx(cp)
    assert res.time_down == pytest.approx(5.0)


def test_untrusted_prediction_costs_rollback():
    p = Platform(mu=1e12, c=10.0, d=2.0, r=3.0)
    res = simulate(trace_of([50.0], [1]), p, time_base=360.0, period=100.0,
                   cp=4.0, trust=NeverTrust())
    assert res.n_trusted == 0
    assert res.time_lost == pytest.approx(50.0)


def test_false_prediction_costs_cp_only():
    p = Platform(mu=1e12, c=10.0, d=2.0, r=3.0)
    res = simulate(trace_of([50.0], [2]), p, time_base=360.0, period=100.0,
                   cp=4.0, trust=AlwaysTrust())
    assert res.n_trusted == 1
    assert res.n_trusted_true == 0
    assert res.time_lost == pytest.approx(0.0)
    assert res.time_prockpt == pytest.approx(4.0)
    assert res.time_down == pytest.approx(0.0)


def test_threshold_trust_ignores_early_predictions():
    p = Platform(mu=1e12, c=10.0, d=0.0, r=0.0)
    # Prediction at offset 20 < threshold 30: ignored.
    res = simulate(trace_of([20.0], [2]), p, time_base=180.0, period=100.0,
                   cp=4.0, trust=ThresholdTrust(30.0))
    assert res.n_trusted == 0
    res = simulate(trace_of([40.0], [2]), p, time_base=180.0, period=100.0,
                   cp=4.0, trust=ThresholdTrust(30.0))
    assert res.n_trusted == 1


def test_prediction_too_early_in_period_unhonourable():
    """A prediction < C_p after the period start cannot be honoured."""
    p = Platform(mu=1e12, c=10.0, d=0.0, r=0.0)
    res = simulate(trace_of([2.0], [2]), p, time_base=90.0, period=100.0,
                   cp=4.0, trust=AlwaysTrust())
    assert res.n_ignored_by_necessity == 1
    assert res.n_trusted == 0


def test_n_faults_counts_each_materialized_fault_once():
    """A true prediction's fault is tallied exactly once (at announcement,
    consistent with the _EV_FAULT handler counting before advancing)."""
    p = Platform(mu=1e12, c=10.0, d=2.0, r=3.0)
    for trust in (AlwaysTrust(), NeverTrust()):
        res = simulate(trace_of([50.0], [1]), p, time_base=360.0,
                       period=100.0, cp=4.0, trust=trust)
        assert res.n_faults == 1
    # The job completes during the pre-checkpoint advance: the announced
    # fault still counts, like an unpredicted fault popped past completion.
    res = simulate(trace_of([500.0], [1]), p, time_base=360.0, period=100.0,
                   cp=4.0, trust=AlwaysTrust())
    assert res.n_faults == 1
    assert res.n_faults_hit == 0
    # Mixed trace: n_faults equals the number of actual faults processed.
    res = simulate(trace_of([50.0, 120.0, 260.0], [1, 0, 2]), p,
                   time_base=600.0, period=100.0, cp=4.0,
                   trust=AlwaysTrust())
    assert res.n_faults == 2


def test_inexact_prediction_window():
    """InexactPrediction: fault strikes in [date, date+window); work done
    between the proactive save and the actual fault is destroyed."""
    p = Platform(mu=1e12, c=10.0, d=0.0, r=0.0)
    rng = np.random.default_rng(0)
    res = simulate(trace_of([50.0], [1]), p, time_base=360.0, period=100.0,
                   cp=4.0, trust=AlwaysTrust(), inexact_window=20.0, rng=rng)
    assert res.n_trusted_true == 1
    assert 0.0 < res.time_lost < 20.0


def simulated_waste(n, recall, precision, period, trust, n_runs=8, cp=600.0):
    mu = MU_IND / n
    p = Platform(mu=mu, c=600.0, d=60.0, r=600.0)
    time_base = 10_000 * 365 * 86400 / n
    tot = 0.0
    for seed in range(n_runs):
        rng = np.random.default_rng(seed)
        tr = make_event_trace(Exponential(1.0), mu, recall, precision,
                              horizon=30 * time_base, rng=rng)
        res = simulate(tr, p, time_base, period, cp=cp, trust=trust, rng=rng)
        tot += res.waste
    return tot / n_runs


@pytest.mark.slow
def test_simulator_matches_analytic_waste_nopred():
    n = 2**16
    p = Platform(mu=MU_IND / n, c=600.0, d=60.0, r=600.0)
    t = t_rfo(p)
    w_sim = simulated_waste(n, 0.0, 1.0, t, NeverTrust())
    assert w_sim == pytest.approx(waste(t, p), abs=0.02)


@pytest.mark.slow
def test_simulator_matches_analytic_waste_pred():
    n = 2**16
    plat = Platform(mu=MU_IND / n, c=600.0, d=60.0, r=600.0)
    ppl = PredictedPlatform(plat, Predictor(0.85, 0.82), 600.0)
    t, w_analytic, use = optimal_period_with_prediction(ppl)
    assert use
    w_sim = simulated_waste(n, 0.85, 0.82, t, ThresholdTrust(beta_lim(ppl)))
    assert w_sim == pytest.approx(w_analytic, abs=0.02)


@pytest.mark.slow
def test_prediction_beats_rfo_in_simulation():
    """OptimalPrediction < RFO measured waste (paper Tables 3-5 direction)."""
    n = 2**19
    plat = Platform(mu=MU_IND / n, c=600.0, d=60.0, r=600.0)
    ppl = PredictedPlatform(plat, Predictor(0.85, 0.82), 600.0)
    t_pred_, _, _ = optimal_period_with_prediction(ppl)
    w_pred = simulated_waste(n, 0.85, 0.82, t_pred_,
                             ThresholdTrust(beta_lim(ppl)))
    w_rfo = simulated_waste(n, 0.85, 0.82, t_rfo(plat), NeverTrust())
    assert w_pred < w_rfo
