"""Beyond-paper extensions: two-level checkpointing, online estimation,
hazard-aware dynamic periods."""

import math

import numpy as np
import pytest

pytest.importorskip("hypothesis")  # optional test dep: degrade to skip
from hypothesis import given, settings, strategies as st

from repro.configs.base import PlatformConfig
from repro.core.multilevel import (TwoLevelPlatform, optimal_two_level,
                                   simulate_two_level, two_level_stream,
                                   waste_two_level)
from repro.core.simulator import NeverTrust, simulate
from repro.core.traces import EventTrace, Exponential, make_event_trace
from repro.core.waste import Platform, t_rfo, waste
from repro.ft.estimator import AdaptiveScheduler, OnlineEstimator


# ---------------------------------------------------------------------------
# Two-level checkpointing
# ---------------------------------------------------------------------------

def test_two_level_reduces_to_single_level_at_k1():
    """k=1 (every checkpoint durable) == the paper's model with C = C2."""
    p2 = TwoLevelPlatform(mu=10_000.0, phi=0.0, c1=10.0, c2=100.0,
                          r1=10.0, r2=100.0, d=5.0)
    p1 = Platform(mu=10_000.0, c=100.0, d=5.0, r=100.0)
    for t in (500.0, 1000.0, 2000.0):
        assert waste_two_level(t, 1, p2) == pytest.approx(waste(t, p1))


def test_two_level_t1_star_is_argmin():
    p = TwoLevelPlatform(mu=20_000.0, phi=0.7, c1=5.0, c2=120.0,
                         r1=5.0, r2=120.0, d=2.0)
    t1, k, w = optimal_two_level(p)
    assert k >= 2  # cheap local ckpts should be used
    for f in (0.7, 0.9, 1.1, 1.4):
        assert waste_two_level(t1 * f, k, p) >= w - 1e-12
    for kk in (max(1, k - 1), k + 1):
        t1k = max(p.c1, math.sqrt(
            2 * p.mu * ((kk - 1) * p.c1 + p.c2)
            / (kk * (p.phi + (1 - p.phi) * kk))))
        assert waste_two_level(t1k, kk, p) >= w - 1e-12


def test_two_level_beats_single_level_with_soft_faults():
    """With mostly-soft faults and C2 >> C1, hierarchy wins analytically
    AND in simulation."""
    mu, phi = 5_000.0, 0.8
    p2 = TwoLevelPlatform(mu=mu, phi=phi, c1=5.0, c2=150.0,
                          r1=5.0, r2=150.0, d=2.0)
    p1 = Platform(mu=mu, c=150.0, d=2.0, r=150.0)
    t1, k, w2 = optimal_two_level(p2)
    w1 = waste(t_rfo(p1), p1)
    assert w2 < w1

    time_base = 200_000.0
    m2 = m1 = 0.0
    for seed in range(8):
        faults, soft = two_level_stream(p2, 10.0 * time_base,
                                        np.random.default_rng(seed))
        m2 += simulate_two_level(faults, soft, p2, time_base, t1, k).makespan
        trace = EventTrace(faults, np.zeros(len(faults), np.int8), 1e12)
        m1 += simulate(trace, p1, time_base, t_rfo(p1),
                       trust=NeverTrust()).makespan
    assert m2 < m1


def test_two_level_simulation_matches_analytic():
    p = TwoLevelPlatform(mu=8_000.0, phi=0.7, c1=10.0, c2=100.0,
                         r1=10.0, r2=100.0, d=5.0)
    t1, k, w_analytic = optimal_two_level(p)
    time_base = 500_000.0
    wastes = []
    for seed in range(10):
        faults, soft = two_level_stream(p, 10.0 * time_base,
                                        np.random.default_rng(seed))
        wastes.append(
            simulate_two_level(faults, soft, p, time_base, t1, k).waste)
    assert np.mean(wastes) == pytest.approx(w_analytic, abs=0.03)


@given(st.floats(0.0, 1.0), st.floats(2_000.0, 1e6))
@settings(max_examples=30, deadline=None)
def test_two_level_waste_bounded(phi, mu):
    p = TwoLevelPlatform(mu=mu, phi=phi, c1=5.0, c2=100.0, r1=5.0,
                         r2=100.0, d=1.0)
    t1, k, w = optimal_two_level(p)
    assert 0.0 < w
    assert t1 >= p.c1 and k >= 1


# ---------------------------------------------------------------------------
# Online estimation
# ---------------------------------------------------------------------------

def test_estimator_converges_to_true_mtbf():
    est = OnlineEstimator(halflife=30.0)
    rng = np.random.default_rng(0)
    t = 0.0
    for _ in range(400):
        t += rng.exponential(500.0)
        est.observe_fault(t, was_predicted=False)
    assert est.state.mu == pytest.approx(500.0, rel=0.25)


def test_estimator_recall_precision():
    est = OnlineEstimator(halflife=50.0, match_window=5.0)
    rng = np.random.default_rng(1)
    t = 0.0
    r_true, p_true = 0.8, 0.6
    for _ in range(600):
        t += rng.exponential(100.0)
        predicted = rng.random() < r_true
        if predicted:
            est.observe_prediction(t)
        est.observe_fault(t, was_predicted=predicted)
        # False predictions at the right rate: r/p * (1-p) per fault.
        if rng.random() < r_true * (1 - p_true) / p_true:
            est.observe_prediction(t + 20.0)
            est.expire_predictions(t + 40.0)
    st_ = est.state
    assert st_.recall == pytest.approx(r_true, abs=0.1)
    assert st_.precision == pytest.approx(p_true, abs=0.15)


def test_adaptive_scheduler_replans_on_drift():
    prior = PlatformConfig(mu_ind=10_000.0, c=60.0, cp=20.0, d=5.0,
                           r=30.0, recall=0.85, precision=0.82)
    ada = AdaptiveScheduler(prior, n_devices=1, c=60.0, cp=20.0,
                            halflife=10.0)
    t0 = ada.scheduler.period
    # Feed faults 10x more frequent than the prior (recall at its prior
    # rate — feeding all-predicted would legitimately drive r-hat -> 1 and
    # the optimal period -> sqrt(2 mu C / (1-r)) -> infinity).
    rng = np.random.default_rng(2)
    t = 0.0
    for _ in range(60):
        t += rng.exponential(1_000.0)
        ada.estimator.observe_fault(t, was_predicted=rng.random() < 0.85)
    assert ada.maybe_replan()
    assert ada.scheduler.period < t0  # higher rate -> shorter period
    assert ada.n_replans == 1
    # Stable estimates: no further replanning.
    assert not ada.maybe_replan()


def test_adaptive_scheduler_hysteresis():
    prior = PlatformConfig(mu_ind=10_000.0, c=60.0, cp=20.0, d=5.0,
                           r=30.0, recall=0.85, precision=0.82)
    ada = AdaptiveScheduler(prior, n_devices=1, c=60.0, cp=20.0,
                            replan_threshold=0.5)
    # Small drift below the threshold: no replan.
    t = 0.0
    rng = np.random.default_rng(3)
    for _ in range(50):
        t += rng.exponential(9_000.0)
        ada.estimator.observe_fault(t, was_predicted=rng.random() < 0.85)
    assert not ada.maybe_replan()
