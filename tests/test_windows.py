"""Prediction-window axis (arXiv:1302.4558): trace stamping, scalar/batch
bit-for-bit equivalence, window=0 regression to exact dates, waste-formula
continuity at the window thresholds, pinned window_sweep means."""

import dataclasses
import math

import numpy as np
import pytest

from repro.core.batch import simulate_batch, simulate_lanes
from repro.core.prediction import (PredictedPlatform, Predictor, beta_lim,
                                   t_pred, waste2)
from repro.core.simulator import (AlwaysTrust, FixedProbabilityTrust,
                                  NeverTrust, SimResult, ThresholdTrust,
                                  simulate)
from repro.core.traces import (FALSE_PRED, FAULT_PRED, FAULT_UNPRED,
                               EventTrace, Exponential, Weibull,
                               make_event_trace, make_event_trace_bank)
from repro.core.waste import Platform
from repro.core.windows import (WindowPlan, beta_lim_window,
                                optimal_window_plan, t_window_period,
                                waste_window, waste_window_instant,
                                waste_window_within, window_strategy)
from repro.core.waste import t_rfo
from repro.experiments import (DistributionSpec, ScenarioSpec, build_strategy,
                               evaluate_strategies)

MU_IND = 125.0 * 365.0 * 86400.0

WSMALL = ScenarioSpec(n=32, dist=DistributionSpec("weibull", {"shape": 0.7}),
                      mu_ind=32 * 1e5, c=600.0, d=60.0, r=600.0,
                      window=3600.0, time_base_years_total=0.1, start=0.0,
                      n_traces=4, seed=3)


def pp(n=2 ** 16, c=600.0, cp=600.0, d=60.0, r=600.0, recall=0.85,
       precision=0.82) -> PredictedPlatform:
    plat = Platform(mu=MU_IND / n, c=c, d=d, r=r)
    return PredictedPlatform(plat, Predictor(recall, precision), cp)


def trace_of(times, kinds, windows=None, horizon=1e9):
    return EventTrace(np.asarray(times, float), np.asarray(kinds, np.int8),
                      horizon,
                      windows=None if windows is None
                      else np.asarray(windows, float))


def assert_same(got: SimResult, want: SimResult, context=""):
    for f in dataclasses.fields(SimResult):
        g, w = getattr(got, f.name), getattr(want, f.name)
        assert g == w, f"{context}: {f.name}: batch {g} != scalar {w}"


# ---------------------------------------------------------------------------
# Trace layer: window-bearing prediction events
# ---------------------------------------------------------------------------

def test_make_event_trace_stamps_prediction_windows():
    rng = np.random.default_rng(0)
    tr = make_event_trace(Exponential(1.0), 50.0, 0.8, 0.7, 5000.0, rng,
                          window=120.0)
    assert tr.windows is not None
    preds = (tr.kinds == FAULT_PRED) | (tr.kinds == FALSE_PRED)
    assert np.all(tr.windows[preds] == 120.0)
    assert np.all(tr.windows[tr.kinds == FAULT_UNPRED] == 0.0)


def test_window_zero_leaves_traces_unstamped():
    rng = np.random.default_rng(0)
    tr = make_event_trace(Exponential(1.0), 50.0, 0.8, 0.7, 5000.0, rng)
    assert tr.windows is None
    bank = make_event_trace_bank(Exponential(1.0), 50.0, 0.8, 0.7, 5000.0,
                                 np.random.default_rng(1), n_traces=3)
    assert all(tr.windows is None for tr in bank)


def test_event_trace_bank_stamps_windows():
    bank = make_event_trace_bank(Exponential(1.0), 50.0, 0.8, 0.7, 5000.0,
                                 np.random.default_rng(2), n_traces=3,
                                 window=60.0)
    for tr in bank:
        assert tr.windows is not None
        assert np.all(tr.windows[tr.kinds != FAULT_UNPRED] == 60.0)


def test_scenario_spec_window_flows_into_traces():
    spec = WSMALL
    for tr in spec.make_traces():
        assert tr.windows is not None
        preds = tr.kinds != FAULT_UNPRED
        assert np.all(tr.windows[preds] == spec.window)
    plain = spec.replace(window=0.0)
    assert all(tr.windows is None for tr in plain.make_traces())


def test_event_trace_windows_shape_validated():
    with pytest.raises(ValueError):
        EventTrace(np.array([1.0, 2.0]), np.array([0, 1], np.int8), 10.0,
                   windows=np.array([5.0]))


# ---------------------------------------------------------------------------
# Simulator mechanics ("within" mode) + engine equivalence
# ---------------------------------------------------------------------------

def test_within_mode_checkpoints_inside_window():
    """A trusted window prediction keeps proactive-checkpointing every
    window_period until the window closes."""
    p = Platform(mu=1e12, c=10.0, d=2.0, r=3.0)
    # Window [50, 130); T_p = 24 (C_p=4): initial prockpt at [46,50), then
    # work 20 / ckpt 4 cycles at 74, 98, 122 -> 4 proactive ckpts total.
    res = simulate(trace_of([50.0], [2], [80.0]), p, 360.0, 200.0, cp=4.0,
                   trust=AlwaysTrust(), window_mode="within",
                   window_period=24.0)
    assert res.n_trusted == 1
    assert res.time_prockpt == pytest.approx(4.0 * 4)
    # Same prediction in instant mode: only the window-start checkpoint.
    res_i = simulate(trace_of([50.0], [2], [80.0]), p, 360.0, 200.0, cp=4.0,
                     trust=AlwaysTrust(), window_mode="instant")
    assert res_i.time_prockpt == pytest.approx(4.0)


def test_within_mode_bounds_loss_to_window_quantum():
    """A true window prediction materializing late in the window destroys
    at most W_p = window_period - C_p of work."""
    p = Platform(mu=1e12, c=10.0, d=0.0, r=0.0)
    rng = np.random.default_rng(5)
    res = simulate(trace_of([50.0], [1], [200.0]), p, 720.0, 100.0, cp=4.0,
                   trust=AlwaysTrust(), window_mode="within",
                   window_period=24.0, rng=rng)
    assert res.n_trusted_true == 1
    assert res.time_lost <= 24.0 - 4.0 + 1e-9
    # Instant mode on the same draw loses the full in-window work.
    res_i = simulate(trace_of([50.0], [1], [200.0]), p, 720.0, 100.0, cp=4.0,
                     trust=AlwaysTrust(), window_mode="instant",
                     rng=np.random.default_rng(5))
    assert res_i.time_lost > res.time_lost


def test_window_period_validation():
    p = Platform(mu=1e5, c=600.0)
    tr = trace_of([], [])
    with pytest.raises(ValueError, match="window_period"):
        simulate(tr, p, 1e4, 2000.0, cp=600.0, window_mode="within",
                 window_period=600.0)
    with pytest.raises(ValueError, match="window_mode"):
        simulate(tr, p, 1e4, 2000.0, window_mode="sometimes")
    with pytest.raises(ValueError, match="window_period"):
        simulate_batch([tr], p, 1e4, [2000.0], cp=600.0,
                       window_mode="within", window_period=10.0)
    with pytest.raises(ValueError, match="window_mode"):
        simulate_batch([tr], p, 1e4, [2000.0], window_mode="sometimes")


def _window_case(case: int):
    r = np.random.default_rng(9000 + case)
    platform = Platform(mu=float(r.uniform(2e4, 2e5)),
                        c=float(r.uniform(100, 900)),
                        d=float(r.uniform(0, 120)),
                        r=float(r.uniform(0, 900)))
    cp = float(r.uniform(0.1, 2.0)) * platform.c
    time_base = float(r.uniform(2, 6)) * platform.mu
    dist = Exponential(1.0) if case % 2 == 0 else Weibull(0.7, 1.0)
    trust = [AlwaysTrust(), ThresholdTrust(float(r.uniform(0, platform.c * 3))),
             FixedProbabilityTrust(float(r.uniform(0.2, 0.8))),
             NeverTrust()][case % 4]
    window = float(r.uniform(0.5, 6.0)) * platform.c
    # Mode flips every 4 cases while trust cycles mod 4, so every
    # (trust, mode) pair — incl. stochastic trust inside an armed window —
    # gets scalar-vs-batch parity coverage.
    wmode = ["instant", "within"][(case // 4) % 2]
    wperiod = cp + float(r.uniform(0.2, 3.0)) * platform.c
    traces = [make_event_trace(dist, platform.mu, float(r.uniform(0.3, 1.0)),
                               float(r.uniform(0.3, 1.0)), 30 * time_base,
                               np.random.default_rng(7 * case + i),
                               window=window)
              for i in range(3)]
    periods = [float(x) for x in
               np.random.default_rng(case).uniform(platform.c * 2,
                                                   platform.c * 20, 3)]
    return platform, cp, time_base, trust, wmode, wperiod, traces, periods


@pytest.mark.parametrize("case", range(16))
def test_randomized_window_equivalence(case):
    """Window-bearing banks + both action modes: batch == scalar, every
    counter, bit for bit."""
    platform, cp, tb, trust, wmode, wperiod, traces, periods = \
        _window_case(case)
    seeds = [11 + 7919 * i for i in range(len(traces))]
    batch = simulate_batch(traces, platform, tb, periods, cp=cp, trust=trust,
                           window_mode=wmode, window_period=wperiod,
                           trace_seeds=seeds)
    for ci, period in enumerate(periods):
        for ti, trace in enumerate(traces):
            want = simulate(trace, platform, tb, period, cp=cp, trust=trust,
                            window_mode=wmode, window_period=wperiod,
                            rng=np.random.default_rng(seeds[ti]))
            assert_same(batch.result(ci, ti), want, f"case {case}")


def test_simulate_lanes_mixed_window_modes():
    platform, cp, tb, _, _, wperiod, traces, periods = _window_case(1)
    trusts = [AlwaysTrust(), ThresholdTrust(500.0), AlwaysTrust()]
    modes = ["instant", "within", "within"]
    ms = simulate_lanes(
        traces, platform, tb, cp=cp,
        trace_indices=[0, 1, 2],
        periods=periods,
        trusts=trusts,
        windows=[0.0, 0.0, 0.0],
        window_modes=modes,
        window_periods=[0.0, wperiod, wperiod],
        seeds=[5, 5 + 7919, 5 + 2 * 7919])
    for j in range(3):
        want = simulate(traces[j], platform, tb, periods[j], cp=cp,
                        trust=trusts[j], window_mode=modes[j],
                        window_period=(0.0, wperiod, wperiod)[j],
                        rng=np.random.default_rng(5 + 7919 * j))
        assert ms[j] == want.makespan


def test_jax_backend_runs_window_lanes():
    """The flagship jax engine runs window candidates (within-mode and
    per-event window tensors); full bitwise parity is asserted in
    tests/test_jax_engine.py and the golden net.  Without x64 the engine
    refuses loudly instead of silently degrading the bitwise contract."""
    pytest.importorskip("jax")
    import jax as _jax
    p = Platform(mu=5e4, c=600.0)
    wtr = trace_of([5000.0], [1], [600.0])
    kw = dict(cp=600.0, trust=AlwaysTrust(), trace_seeds=[3],
              window_mode="within", window_period=1800.0)
    if not _jax.config.jax_enable_x64:
        with pytest.raises(RuntimeError, match="x64"):
            simulate_batch([wtr], p, 1e4, [2000.0], backend="jax", **kw)
    else:  # pragma: no cover - depends on session config
        got = simulate_batch([wtr], p, 1e4, [2000.0], backend="jax", **kw)
        want = simulate_batch([wtr], p, 1e4, [2000.0], **kw)
        assert got.makespan[0, 0] == want.makespan[0, 0]


# ---------------------------------------------------------------------------
# window = 0 regression: the exact-date behaviour is recovered bit-for-bit
# ---------------------------------------------------------------------------

def test_window_zero_equals_exact_date_results():
    plain = WSMALL.replace(window=0.0)
    traces = plain.make_traces()
    plat, tb, cp = plain.platform, plain.time_base, plain.cp
    exact = build_strategy("optimal_prediction", plain)
    start = build_strategy("window_start", plain)
    pro = build_strategy("window_proactive", plain)
    # At I = 0 the window strategies resolve to the exact-date refined
    # policy: same period, same threshold, no "within" machinery.
    assert start.period == exact.period
    assert start.trust == ThresholdTrust(beta_lim(plain.pp))
    assert pro.window_mode == "instant" and pro.window_period == 0.0
    means = evaluate_strategies(traces, plat, tb, cp, [exact, start, pro],
                                seed=7)
    assert means[0] == means[1] == means[2]


def test_within_machinery_inert_without_windows():
    """On a window-less trace with inexact_window=0, "within" mode never
    arms and the result equals the plain exact-date run, bit for bit."""
    platform, cp, tb, _, _, wperiod, _, periods = _window_case(2)
    tr = make_event_trace(Exponential(1.0), platform.mu, 0.7, 0.6, 20 * tb,
                          np.random.default_rng(3))
    assert tr.windows is None
    want = simulate(tr, platform, tb, periods[0], cp=cp,
                    trust=AlwaysTrust(), rng=np.random.default_rng(1))
    got = simulate(tr, platform, tb, periods[0], cp=cp, trust=AlwaysTrust(),
                   window_mode="within", window_period=wperiod,
                   rng=np.random.default_rng(1))
    assert_same(got, want)


# ---------------------------------------------------------------------------
# Analytic layer: continuity + optimality (mirrors prediction.py tests)
# ---------------------------------------------------------------------------

def test_waste_formulas_reduce_to_exact_dates_at_zero_window():
    ppl = pp()
    for t in (5000.0, 15000.0, 40000.0):
        assert waste_window_instant(t, ppl, 0.0) == waste2(t, ppl)
        assert waste_window_within(t, ppl, 0.0, 3000.0) \
            == pytest.approx(waste2(t, ppl), rel=1e-12)
    assert beta_lim_window(ppl, 0.0) == beta_lim(ppl)
    assert beta_lim_window(ppl, 0.0, 3000.0) == beta_lim(ppl)


def test_waste_continuity_at_window_thresholds():
    """Continuity in I at the W_p = I switch of the within formula, and of
    the threshold as I -> 0."""
    ppl = pp()
    tp = 3000.0
    wp = tp - ppl.cp
    for f in (lambda i: waste_window_within(15000.0, ppl, i, tp),
              lambda i: beta_lim_window(ppl, i, tp)):
        left, right = f(wp * (1 - 1e-9)), f(wp * (1 + 1e-9))
        assert left == pytest.approx(right, rel=1e-6)
    eps = 1e-6
    assert beta_lim_window(ppl, eps, tp) == pytest.approx(beta_lim(ppl),
                                                          rel=1e-6)
    assert waste_window(15000.0, ppl, eps, "within", tp) == pytest.approx(
        waste2(15000.0, ppl), rel=1e-9)


def test_t_window_period_is_argmin():
    ppl = pp()
    window = 18000.0
    tp_star = t_window_period(ppl, window)
    assert ppl.cp < tp_star < window
    w_star = waste_window_within(t_pred(ppl), ppl, window, tp_star)
    for tp in np.geomspace(ppl.cp * 1.01, window * 3, 300):
        assert waste_window_within(t_pred(ppl), ppl, window, float(tp)) \
            >= w_star - 1e-12


def test_optimal_window_plan_picks_best_mode():
    ppl = pp()
    # At I = 0 every acting plan equals exact-date WASTE2 at T_pred.
    plan0 = optimal_window_plan(ppl, 0.0)
    assert isinstance(plan0, WindowPlan)
    assert plan0.waste == pytest.approx(waste2(t_pred(ppl), ppl), rel=1e-12)
    # A huge window makes acting worthless: the ignore plan must win.
    plan_big = optimal_window_plan(ppl, 1e9)
    assert plan_big.mode == "ignore"
    assert plan_big.period == pytest.approx(max(ppl.platform.c,
                                                t_rfo(ppl.platform)))
    # At a few periods, within beats instant analytically.
    w_in = optimal_window_plan(ppl, 18000.0, mode="within").waste
    w_st = optimal_window_plan(ppl, 18000.0, mode="instant").waste
    assert w_in < w_st


def test_window_strategy_modes():
    ppl = pp()
    ig = window_strategy(ppl, 9000.0, "ignore")
    assert isinstance(ig.trust, NeverTrust) and ig.window_mode == "instant"
    st = window_strategy(ppl, 9000.0, "instant")
    assert st.inexact_window == 9000.0
    assert st.trust == ThresholdTrust(beta_lim(ppl))
    pro = window_strategy(ppl, 9000.0, "within")
    assert pro.window_mode == "within"
    assert pro.window_period == pytest.approx(t_window_period(ppl, 9000.0))
    assert pro.trust == ThresholdTrust(
        beta_lim_window(ppl, 9000.0, pro.window_period))
    # Tiny windows degrade gracefully to the instant mechanics.
    tiny = window_strategy(ppl, 1.0, "within")
    assert tiny.window_mode == "instant"
    with pytest.raises(ValueError):
        window_strategy(ppl, 9000.0, "sometimes")
    # An explicit in-window period must leave room for work — fail at
    # construction, not mid-sweep inside the engines.
    with pytest.raises(ValueError, match="window_period"):
        window_strategy(ppl, 9000.0, "within", window_period=ppl.cp)


# ---------------------------------------------------------------------------
# Runner integration + pinned window_sweep cell
# ---------------------------------------------------------------------------

def test_runner_window_strategies_engines_agree():
    traces = WSMALL.make_traces()
    plat, tb, cp = WSMALL.platform, WSMALL.time_base, WSMALL.cp
    strategies = [build_strategy("window_ignore", WSMALL),
                  build_strategy("window_start", WSMALL),
                  build_strategy("window_proactive", WSMALL)]
    auto = evaluate_strategies(traces, plat, tb, cp, strategies, seed=7,
                               engine="auto")
    scalar = evaluate_strategies(traces, plat, tb, cp, strategies, seed=7,
                                 engine="scalar")
    assert auto == scalar


def test_window_sweep_pinned_means():
    """Regression pin for one window_sweep cell (WSMALL, I=3600): guards
    window trace generation, both engines and the strategy constructions
    against silent drift."""
    traces = WSMALL.make_traces()
    strategies = [build_strategy(name, WSMALL) for name in
                  ("window_ignore", "window_start", "window_proactive")]
    means = evaluate_strategies(traces, WSMALL.platform, WSMALL.time_base,
                                WSMALL.cp, strategies, seed=7)
    want = [125891.38666757442, 110187.96486062315, 109255.70226936118]
    assert means == pytest.approx(want, rel=1e-12)
