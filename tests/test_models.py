"""Per-arch smoke tests (reduced configs) + decode/prefill consistency.

The assignment requires, per architecture, a REDUCED variant (<= 2-3 layers,
d_model <= 512, <= 4 experts) running one forward/train step on CPU with
shape + finiteness assertions.  The consistency tests additionally pin the
semantics: prefill + decode_step must reproduce the teacher-forced logits.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import REGISTRY, SHAPES, pairs, skip_reason
from repro.configs.base import InputShape
from repro.models import (decode_step, forward_train, init_cache,
                          init_params, loss_fn, make_batch, prefill)
from repro.models.model import input_specs
from repro.models.transformer import cache_axes

ARCHS = sorted(REGISTRY)
SMOKE_SHAPE = InputShape("smoke", 64, 2, "train")


@pytest.fixture(scope="module")
def params_cache():
    cache = {}

    def get(name, **over):
        key = (name, tuple(sorted(over.items())))
        if key not in cache:
            cfg = REGISTRY[name].reduced()
            if over:
                cfg = dataclasses.replace(cfg, **over)
            params, axes = init_params(cfg, jax.random.PRNGKey(0))
            cache[key] = (cfg, params, axes)
        return cache[key]

    return get


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_loss(arch, params_cache):
    """One forward + loss on the reduced config: shapes + no NaNs."""
    cfg, params, _ = params_cache(arch)
    batch = make_batch(cfg, SMOKE_SHAPE, jax.random.PRNGKey(1))
    logits, aux = forward_train(cfg, params, batch)
    assert logits.shape == (2, 64, cfg.vocab_size)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())
    loss, metrics = loss_fn(cfg, params, batch)
    assert np.isfinite(float(loss))
    if cfg.n_experts:
        assert float(metrics["moe_aux"]) > 0.0


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step(arch, params_cache):
    """One grad step: finite global grad norm for every family."""
    cfg, params, _ = params_cache(arch)
    batch = make_batch(cfg, SMOKE_SHAPE, jax.random.PRNGKey(2))
    grads = jax.grad(lambda p: loss_fn(cfg, p, batch)[0])(params)
    sq = sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
             for g in jax.tree.leaves(grads))
    assert np.isfinite(float(sq)) and float(sq) > 0.0


DECODE_ARCHS = [a for a in ARCHS if REGISTRY[a].causal]


@pytest.mark.parametrize("arch", DECODE_ARCHS)
def test_prefill_decode_consistency(arch, params_cache):
    """prefill + decode_step == teacher-forced forward (fp32, dropless)."""
    cfg, params, _ = params_cache(arch, dtype="float32", remat=False,
                                  capacity_factor=None)
    shp = InputShape("t", 32, 2, "train")
    batch = make_batch(cfg, shp, jax.random.PRNGKey(3))
    logits_full, _ = forward_train(cfg, params, batch)
    s_pre = 24
    pre = {k: (v[:, :s_pre] if v.ndim >= 2 and v.shape[1] == 32 else v)
           for k, v in batch.items()}
    lg, cache = prefill(cfg, params, pre, cache_len=40)
    np.testing.assert_allclose(np.asarray(lg),
                               np.asarray(logits_full[:, s_pre - 1]),
                               atol=5e-3)
    for t in range(s_pre, 31):
        thw = batch["positions_thw"][:, t] \
            if "positions_thw" in batch else None
        lg, cache = decode_step(cfg, params, batch["tokens"][:, t], cache,
                                positions_thw=thw)
        np.testing.assert_allclose(np.asarray(lg),
                                   np.asarray(logits_full[:, t]),
                                   atol=5e-3)


@pytest.mark.parametrize("arch", DECODE_ARCHS)
def test_init_cache_matches_prefill_structure(arch, params_cache):
    """init_cache (used by serve_step dry-runs) matches prefill's cache."""
    cfg, params, _ = params_cache(arch)
    shp = InputShape("t", 16, 2, "train")
    batch = make_batch(cfg, shp, jax.random.PRNGKey(4))
    _, cache_p = prefill(cfg, params, batch, cache_len=16)
    cache_i = init_cache(cfg, 2, 16)
    s1 = jax.tree.structure(cache_p)
    s2 = jax.tree.structure(cache_i)
    assert s1 == s2
    for a, b in zip(jax.tree.leaves(cache_p), jax.tree.leaves(cache_i)):
        assert a.shape == b.shape, (arch, a.shape, b.shape)


@pytest.mark.parametrize("arch", DECODE_ARCHS)
def test_decode_from_init_cache(arch, params_cache):
    """Decoding from a zero cache (length 0) runs and yields finite logits."""
    cfg, params, _ = params_cache(arch)
    cache = init_cache(cfg, 2, 16)
    tok = jnp.array([1, 2], jnp.int32)
    for _ in range(3):
        logits, cache = decode_step(cfg, params, tok, cache)
        assert logits.shape == (2, cfg.vocab_size)
        assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())
    assert int(cache["length"][0]) == 3


def test_cache_axes_aligned_with_cache():
    """cache_axes tree must align leaf-for-leaf with init_cache."""
    for arch in DECODE_ARCHS:
        cfg = REGISTRY[arch].reduced()
        cache = jax.eval_shape(lambda c=cfg: init_cache(c, 2, 16))
        axes = cache_axes(cfg)
        is_axes = lambda x: (isinstance(x, tuple) and len(x) > 0 and
                             all(isinstance(e, (str, type(None)))
                                 for e in x))
        flat_axes = jax.tree.flatten(axes, is_leaf=is_axes)[0]
        flat_cache = jax.tree.leaves(cache)
        assert len(flat_axes) == len(flat_cache), arch
        for a, leaf in zip(flat_axes, flat_cache):
            assert len(a) == len(leaf.shape), (arch, a, leaf.shape)


def test_pairs_grid():
    """The assigned grid: 40 combinations, 2 documented skips."""
    all_pairs = list(pairs(include_skipped=True))
    assert len(all_pairs) == 40
    skipped = [(c.name, s.name) for c, s, r in all_pairs if r]
    assert sorted(skipped) == [("hubert-xlarge", "decode_32k"),
                               ("hubert-xlarge", "long_500k")]


def test_input_specs_no_allocation():
    """input_specs returns ShapeDtypeStructs for every (arch x shape)."""
    for cfg, shape, _ in pairs():
        specs = input_specs(cfg, shape)
        for leaf in jax.tree.leaves(specs):
            assert isinstance(leaf, jax.ShapeDtypeStruct)


def test_reduced_constraints():
    """Reduced variants respect the assignment's smoke limits."""
    for arch in ARCHS:
        r = REGISTRY[arch].reduced()
        assert r.n_layers <= 3
        assert r.d_model <= 512
        assert r.n_experts <= 4
        assert r.vocab_size <= 512


def test_encoder_has_no_decode():
    cfg = REGISTRY["hubert-xlarge"].reduced()
    params, _ = init_params(cfg, jax.random.PRNGKey(0))
    with pytest.raises(ValueError):
        decode_step(cfg, params, jnp.zeros((2,), jnp.int32),
                    init_cache(cfg, 2, 8))


# Published sizes [source citations in each config file]; tolerance covers
# head/embedding accounting differences.
PUBLISHED_SIZES_B = {
    "llama3-405b": (405.0, 0.05),
    "internlm2-20b": (20.0, 0.08),
    "qwen3-moe-235b-a22b": (235.0, 0.05),
    "tinyllama-1.1b": (1.1, 0.05),
    "qwen2-vl-72b": (72.0, 0.05),
    "llama3.2-1b": (1.24, 0.05),
}
PUBLISHED_ACTIVE_B = {
    "qwen3-moe-235b-a22b": (22.0, 0.10),
    "qwen2-moe-a2.7b": (2.7, 0.10),
}


@pytest.mark.parametrize("arch", sorted(PUBLISHED_SIZES_B))
def test_param_count_matches_published(arch):
    total, tol = PUBLISHED_SIZES_B[arch]
    ours = REGISTRY[arch].param_count() / 1e9
    assert abs(ours / total - 1) < tol, f"{arch}: {ours:.2f}B vs {total}B"


@pytest.mark.parametrize("arch", sorted(PUBLISHED_ACTIVE_B))
def test_active_params_match_published(arch):
    active, tol = PUBLISHED_ACTIVE_B[arch]
    ours = REGISTRY[arch].active_param_count() / 1e9
    assert abs(ours / active - 1) < tol


def test_extra_architectures_smoke():
    """Extra (non-assigned) configs run a forward/loss step when reduced."""
    from repro.configs import EXTRAS
    assert set(EXTRAS) == {"mixtral-8x7b", "gemma2-9b"}
    for name, cfg_full in EXTRAS.items():
        cfg = cfg_full.reduced()
        params, _ = init_params(cfg, jax.random.PRNGKey(0))
        batch = make_batch(cfg, SMOKE_SHAPE, jax.random.PRNGKey(1))
        loss, _ = loss_fn(cfg, params, batch)
        assert np.isfinite(float(loss)), name
    # Published sizes: mixtral 46.7B total / 12.9B active.
    mix = EXTRAS["mixtral-8x7b"]
    assert abs(mix.param_count() / 46.7e9 - 1) < 0.08
    assert abs(mix.active_param_count() / 12.9e9 - 1) < 0.10
