"""Fleet availability subsystem: degeneracy, contention, repair slots,
availability model, specs and the ``evaluate_fleet`` path.

The load-bearing contract: a 1-job fleet with no contention and unbounded
repair runs the scalar engine's float arithmetic verbatim, so it must
reproduce the committed golden makespans (tests/golden/parity_v1.json)
**bit-for-bit** — the same file the cross-engine parity net pins.
"""

import dataclasses
import json
import math
from pathlib import Path

import numpy as np
import pytest

from repro.core.prediction import PredictedPlatform, Predictor, beta_lim
from repro.core.simulator import (NeverTrust, SimResult, ThresholdTrust,
                                  simulate)
from repro.core.waste import Platform, t_rfo, waste
from repro.experiments import ScenarioSpec, StrategySpec
from repro.fleet import (FleetJobInput, FleetJobSpec, FleetSpec, JobPlan,
                         OutageWeights, beta_avail, evaluate_fleet,
                         job_from_model, measured_unavailability, plan_fleet,
                         plan_job, simulate_fleet, staggered_period,
                         t_avail_nopred, unavailability,
                         unavailability_nopred)

GOLDEN_PATH = Path(__file__).parent / "golden" / "parity_v1.json"

# Golden cells a fleet job can express (no window_mode="within", no
# adaptive re-planning — both single-job engine features).
_FLEET_CELLS = ("baseline_rfo", "prediction_optimal",
                "prediction_exact_model", "predictor_lead_time",
                "stochastic_trust_q")


def _golden_cell(name):
    golden = json.loads(GOLDEN_PATH.read_text())
    want = golden["cells"][name]
    scenario = ScenarioSpec.from_dict(want["scenario"])
    strat = StrategySpec.from_dict(want["strategy"]).build(scenario)
    return scenario, strat, want["makespans"]


def _inputs_for(scenario, strat, i, period=None):
    return FleetJobInput(
        trace=scenario.make_trace(i),
        platform=scenario.platform,
        time_base=scenario.time_base,
        period=float(strat.period) if period is None else period,
        cp=scenario.cp,
        trust=strat.trust,
        inexact_window=strat.inexact_window,
        rng=np.random.default_rng(scenario.seed + 7919 * i))


# ---------------------------------------------------------------------------
# Degeneracy: 1 job, no contention == the scalar engine, bit for bit
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", _FLEET_CELLS)
def test_one_job_fleet_matches_golden_bit_for_bit(name):
    scenario, strat, makespans = _golden_cell(name)
    got = []
    for i in range(scenario.n_traces):
        fleet = simulate_fleet([_inputs_for(scenario, strat, i)])
        got.append(fleet.jobs[0].sim.makespan)
    assert got == makespans, \
        f"{name}: 1-job fleet diverged from the golden scalar makespans"


def test_one_job_fleet_full_simresult_equality():
    """Every SimResult field (not just the makespan) matches the scalar
    engine, and the fleet couplings report exactly zero."""
    scenario, strat, _ = _golden_cell("prediction_optimal")
    for i in range(scenario.n_traces):
        want = simulate(scenario.make_trace(i), scenario.platform,
                        scenario.time_base, float(strat.period),
                        cp=scenario.cp, trust=strat.trust,
                        inexact_window=strat.inexact_window,
                        rng=np.random.default_rng(scenario.seed + 7919 * i))
        job = simulate_fleet([_inputs_for(scenario, strat, i)]).jobs[0]
        for f in dataclasses.fields(SimResult):
            g, w = getattr(job.sim, f.name), getattr(want, f.name)
            assert g == w, f"trace {i}: {f.name}: fleet {g} != scalar {w}"
        assert job.time_contention_ckpt == 0.0
        assert job.time_contention_prockpt == 0.0
        assert job.time_repair_wait == 0.0


def test_multi_job_uncontended_matches_scalar():
    """N jobs with unlimited streams/slots never interact: each equals its
    own scalar run bit-for-bit."""
    scenario, strat, makespans = _golden_cell("prediction_optimal")
    fleet = simulate_fleet([_inputs_for(scenario, strat, i)
                            for i in range(scenario.n_traces)])
    assert [j.sim.makespan for j in fleet.jobs] == makespans
    assert fleet.makespan == max(makespans)


# ---------------------------------------------------------------------------
# Storage contention and staggering
# ---------------------------------------------------------------------------

_FAULT_FREE = ScenarioSpec(n=4, c=600.0, d=60.0, r=600.0,
                           mu_ind=4e12,  # mu ~ 1e12 s: no faults in-base
                           time_base_years_total=4 * 2.0 / 365.0,
                           n_traces=1, seed=2)


def _sync_pair(streams, stagger_offsets=(0.0, 0.0)):
    sc = _FAULT_FREE
    inputs = []
    for k, off in enumerate(stagger_offsets):
        period = 7200.0 if off <= 0.0 else staggered_period(7200.0, off)
        inp = _inputs_for(sc, _Strat(), 0, period=period)
        inp.name = f"tenant{k}"
        inputs.append(inp)
    return simulate_fleet(inputs, storage_streams=streams)


class _Strat:
    period = 7200.0
    trust = NeverTrust()
    inexact_window = 0.0


def test_synchronized_saves_stretch_each_other():
    """Two identical fault-free jobs on one stream: every save overlaps its
    twin completely, so each job pays one extra C per checkpoint."""
    solo = _sync_pair(streams=None)
    shared = _sync_pair(streams=1)
    for j in solo.jobs:
        assert j.time_contention_ckpt == 0.0
    c = _FAULT_FREE.c
    n_ckpts = round(solo.jobs[0].sim.time_ckpt / c)
    assert n_ckpts > 20
    for j in shared.jobs:
        # stretch factor 2 -> extra wall time == nominal C per save
        assert j.time_contention_ckpt == pytest.approx(n_ckpts * c, rel=1e-9)
        assert j.sim.makespan == pytest.approx(
            solo.jobs[0].sim.makespan + n_ckpts * c, rel=1e-9)


def test_staggering_removes_contention():
    """Offsetting one cadence by T/2 (period >> 2C) de-overlaps every save:
    zero contention, the unstaggered job bit-for-bit the solo run."""
    staggered = _sync_pair(streams=1, stagger_offsets=(0.0, 3600.0))
    solo = _sync_pair(streams=None)
    assert staggered.jobs[0].time_contention_ckpt == 0.0
    assert staggered.jobs[1].time_contention_ckpt == 0.0
    # The unstaggered job is untouched — bit-for-bit the solo run.
    assert staggered.jobs[0].sim.makespan == solo.jobs[0].sim.makespan
    # The staggered job front-loads one offset of work into its longer
    # first period, so it fits the fixed time_base in one fewer save.
    c = _FAULT_FREE.c
    assert staggered.jobs[1].sim.time_ckpt == \
        solo.jobs[1].sim.time_ckpt - c
    assert staggered.jobs[1].sim.makespan == solo.jobs[1].sim.makespan - c


def test_plan_fleet_staggers_offsets():
    job = FleetJobSpec(scenario=_FAULT_FREE)
    spec = FleetSpec(jobs=(job, job, job), stagger=True)
    plans = plan_fleet(spec)
    offs = [p.stagger_offset for p in plans]
    assert offs[0] == 0.0 and offs[1] > 0.0 and offs[2] > offs[1]
    assert offs[1] == pytest.approx(plans[1].period / 3.0)
    # period_arg: plain float when unstaggered, callable shim otherwise.
    assert isinstance(plans[0].period_arg, float)
    fn = plans[1].period_arg
    assert fn(0.0) == pytest.approx(plans[1].period + offs[1])
    assert fn(1.0) == plans[1].period


# ---------------------------------------------------------------------------
# Repair slots
# ---------------------------------------------------------------------------

_FAULTY = ScenarioSpec(n=64, c=300.0, d=600.0, r=1800.0, mu_ind=64 * 2e5,
                       time_base_years_total=64 * 4.0 / 365.0,
                       n_traces=3, seed=9)


# Heavy fault pressure (mu = 1e4 s against 2400 s of outage per fault)
# so three jobs' downtimes are certain to overlap on one repair slot.
_REPAIR_HEAVY = dataclasses.replace(_FAULTY, mu_ind=64 * 1e4)


def test_repair_slots_queue_and_unbounded_is_free():
    strat = StrategySpec("rfo").build(_REPAIR_HEAVY)
    inputs = lambda: [_inputs_for(_REPAIR_HEAVY, strat, i) for i in range(3)]
    free = simulate_fleet(inputs())
    assert all(j.time_repair_wait == 0.0 for j in free.jobs)
    queued = simulate_fleet(inputs(), repair_slots=1)
    waits = [j.time_repair_wait for j in queued.jobs]
    assert sum(waits) > 0.0, "overlapping outages must queue on one slot"
    # Queueing delays, never accelerates (the longer wall time can even
    # expose a job to extra trace faults).
    for jq, jf in zip(queued.jobs, free.jobs):
        assert jq.sim.makespan >= jf.sim.makespan
        assert jq.sim.n_faults >= jf.sim.n_faults


# ---------------------------------------------------------------------------
# Availability model: degeneracy, divergence, measured accounting
# ---------------------------------------------------------------------------

PLAT = Platform(mu=5e4, c=600.0, d=60.0, r=600.0)
PP = PredictedPlatform(PLAT, Predictor(0.85, 0.82), 180.0)


def test_unit_weights_degenerate_to_waste_model():
    w1 = OutageWeights()
    assert t_avail_nopred(PLAT, w1) == pytest.approx(t_rfo(PLAT))
    assert beta_avail(PP, w1) == pytest.approx(beta_lim(PP))
    t = 9000.0
    # U1 is exactly the first-order sum wff + wfault; the waste model
    # keeps the second-order cross product (1 - (1-wff)(1-wfault)).
    wff = PLAT.c / t
    wfault = (PLAT.d + PLAT.r + t / 2.0) / PLAT.mu
    assert unavailability_nopred(t, PLAT, w1) == pytest.approx(wff + wfault)
    assert waste(t, PLAT) == pytest.approx(wff + wfault - wff * wfault)


def test_weighted_optimum_scales_by_sqrt_ratio():
    w = OutageWeights(ckpt=0.25, prockpt=0.25, replay=1.0)
    assert t_avail_nopred(PLAT, w) == \
        pytest.approx(0.5 * t_rfo(PLAT), rel=1e-12)
    assert beta_avail(PP, w) == pytest.approx(0.25 * beta_lim(PP))
    # Checkpointing twice as often must not be free: U at the weighted
    # optimum beats U at the waste-optimal period under the same weights.
    t_a, t_w = t_avail_nopred(PLAT, w), t_rfo(PLAT)
    assert unavailability_nopred(t_a, PLAT, w) < \
        unavailability_nopred(t_w, PLAT, w)


def test_outage_weights_validation_and_round_trip():
    with pytest.raises(ValueError):
        OutageWeights(ckpt=0.0)
    with pytest.raises(ValueError):
        OutageWeights(replay=1.5)
    w = OutageWeights(ckpt=0.3, prockpt=0.6, replay=0.9)
    assert OutageWeights.from_dict(w.to_dict()) == w


def test_unavailability_two_branch_continuity():
    # A proactive checkpoint costly enough that beta_A lands above C, so
    # both branches are defined at the breakpoint.
    pp = PredictedPlatform(PLAT, Predictor(0.85, 0.82), 900.0)
    w = OutageWeights(ckpt=0.5, prockpt=1.0, replay=0.5)
    beta = beta_avail(pp, w)
    assert beta > PLAT.c
    lo, hi = unavailability(beta, pp, w), unavailability(beta * 1.0001, pp, w)
    assert lo == pytest.approx(hi, rel=1e-3)


def test_measured_unavailability_unit_weights_equals_waste():
    """The simulator's accounting identity: with unit weights and no fleet
    couplings, the weighted outage fraction IS SimResult.waste."""
    scenario, strat, _ = _golden_cell("prediction_optimal")
    job = simulate_fleet([_inputs_for(scenario, strat, 0)]).jobs[0]
    u = measured_unavailability(
        makespan=job.sim.makespan, time_ckpt=job.sim.time_ckpt,
        time_prockpt=job.sim.time_prockpt, time_down=job.sim.time_down,
        time_lost=job.sim.time_lost, w=OutageWeights())
    assert u == pytest.approx(job.sim.waste, abs=1e-12)


# ---------------------------------------------------------------------------
# Specs, planning, evaluate_fleet
# ---------------------------------------------------------------------------

def test_fleet_spec_round_trip():
    spec = FleetSpec(
        jobs=(job_from_model("llama3.2-1b", n_devices=16, n_traces=2,
                             slo=0.99),
              FleetJobSpec(scenario=_FAULTY, strategy=StrategySpec("rfo"),
                           name="legacy")),
        objective="availability",
        outage=OutageWeights(ckpt=0.25, prockpt=0.25, replay=1.0),
        storage_streams=1, repair_slots=2, stagger=True, name="rt")
    back = FleetSpec.from_dict(json.loads(json.dumps(spec.to_dict())))
    assert back == spec
    assert back.key() == spec.key()
    assert back.n_runs == 2          # min over job trace banks
    assert back.job_name(1) == "legacy"
    with pytest.raises(ValueError):
        FleetSpec(objective="throughput")
    with pytest.raises(ValueError):
        FleetJobSpec(scenario=_FAULTY, slo=1.5)


def test_job_from_model_sizes_from_zoo():
    small = job_from_model("llama3.2-1b", n_devices=16, n_traces=2)
    big = job_from_model("llama3-405b", n_devices=8192, n_traces=2)
    for j in (small, big):
        sc = j.scenario
        assert sc.c > 0.0 and 0.0 < sc.cp < sc.c
        assert sc.r == sc.c          # recovery defaults to re-reading C
    # Per-shard writes: the 405B job on 512x the shards is not 400x slower.
    assert big.scenario.c < 400 * small.scenario.c
    assert big.scenario.platform.mu < small.scenario.platform.mu


def test_plan_job_objectives_diverge():
    job = FleetJobSpec(scenario=_FAULTY)
    w = OutageWeights(ckpt=0.25, prockpt=0.25, replay=1.0)
    pw = plan_job(job, "waste")
    pa = plan_job(job, "availability", w)
    assert pa.period < pw.period     # cheap checkpoints -> save more often
    assert pa.expected < pw.expected if pa.use_predictions == \
        pw.use_predictions else True
    if pa.use_predictions and pw.use_predictions:
        assert pa.trust.threshold < pw.trust.threshold


def test_plan_job_rejects_single_job_engine_features():
    job = FleetJobSpec(scenario=dataclasses.replace(_FAULTY, window=9000.0),
                       strategy=StrategySpec("window_proactive"))
    with pytest.raises(ValueError, match="window_mode"):
        plan_job(job)
    job = FleetJobSpec(scenario=_FAULTY,
                       strategy=StrategySpec("adaptive", {"min_preds": 4,
                                                          "min_faults": 2}))
    with pytest.raises(ValueError, match="adaptive"):
        plan_job(job)


def test_evaluate_fleet_reports_per_tenant_slos():
    jobs = (FleetJobSpec(scenario=_FAULTY, name="a", slo=0.97),
            FleetJobSpec(scenario=dataclasses.replace(_FAULTY, seed=17),
                         name="b", slo=0.5))
    spec = FleetSpec(jobs=jobs, objective="availability",
                     outage=OutageWeights(ckpt=0.5, prockpt=0.5, replay=1.0),
                     storage_streams=1, repair_slots=1, n_traces=2,
                     name="slo-fleet")
    table = evaluate_fleet(spec)
    assert [r["job"] for r in table.rows] == ["a", "b"]
    for row in table.rows:
        assert row["fleet"] == "slo-fleet"
        assert row["objective"] == "availability"
        assert 0.0 < row["availability"] < 1.0
        assert row["availability"] == pytest.approx(
            1.0 - row["unavailability"])
        assert 0.0 <= row["slo_met"] <= 1.0
        assert row["expected_objective"] > 0.0
        assert row["n_faults"] > 0
    # The loose SLO is met at least as often as the tight one.
    assert table.rows[1]["slo_met"] >= table.rows[0]["slo_met"]
    # Coupled runs really paid coupling costs somewhere in the fleet.
    assert sum(r["contention_ckpt_s"] + r["repair_wait_s"]
               for r in table.rows) >= 0.0


def test_evaluate_fleet_availability_objective_beats_waste_plan():
    """On cheap-checkpoint weights the availability plan must measure a
    lower weighted outage than the waste plan on the same traces."""
    w = OutageWeights(ckpt=0.25, prockpt=0.25, replay=1.0)
    jobs = (FleetJobSpec(scenario=_FAULTY, name="t"),)
    by_obj = {}
    for obj in ("waste", "availability"):
        table = evaluate_fleet(FleetSpec(jobs=jobs, objective=obj, outage=w))
        by_obj[obj] = table.rows[0]
    assert by_obj["availability"]["period"] < by_obj["waste"]["period"]
    assert by_obj["availability"]["unavailability"] < \
        by_obj["waste"]["unavailability"]
