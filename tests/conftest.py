"""Shared pytest config. NOTE: no XLA_FLAGS here — tests see 1 CPU device."""

import pytest


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running CPU test")
