"""Shared pytest config. NOTE: no XLA_FLAGS here — tests see 1 CPU device."""

import pytest


def pytest_addoption(parser):
    parser.addoption(
        "--update-golden", action="store_true", default=False,
        help="regenerate the pinned golden files under tests/golden/ "
             "from the current engines instead of comparing against them")


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running CPU test")


@pytest.fixture
def update_golden(request) -> bool:
    return request.config.getoption("--update-golden")
