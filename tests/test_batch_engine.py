"""Lane-parallel batched engine: bit-for-bit equivalence with the scalar
simulator, runner dispatch, persistent result cache, batched trace banks."""

import dataclasses
import json
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core.batch import BatchResult, simulate_batch, simulate_lanes
from repro.core.simulator import (AlwaysTrust, FixedProbabilityTrust,
                                  NeverTrust, SimResult, ThresholdTrust,
                                  simulate)
from repro.core.traces import (EventTrace, Exponential, Weibull,
                               make_event_trace, make_event_trace_bank,
                               renewal_trace_bank, superposed_trace_bank)
from repro.core.waste import Platform
from repro.experiments import (DistributionSpec, EvalCache, ScenarioSpec,
                               build_strategy, evaluate_strategies,
                               run_experiment)
from repro.experiments.runner import _resolve_workers, best_period_search

SMALL = ScenarioSpec(n=32, dist=DistributionSpec("weibull", {"shape": 0.7}),
                     mu_ind=32 * 1e5, c=600.0, d=60.0, r=600.0,
                     time_base_years_total=0.1, start=0.0, n_traces=4,
                     seed=3)


def trace_of(times, kinds, horizon=1e9):
    return EventTrace(np.asarray(times, float), np.asarray(kinds, np.int8),
                      horizon)


def batch_one(trace, platform, time_base, period, *, seed=0, **kw):
    """simulate_batch on a single lane, unwrapped to a SimResult."""
    res = simulate_batch([trace], platform, time_base, [period],
                         trace_seeds=[seed], **kw)
    assert isinstance(res, BatchResult)
    return res.result(0, 0)


def assert_same(got: SimResult, want: SimResult, context=""):
    for f in dataclasses.fields(SimResult):
        g, w = getattr(got, f.name), getattr(want, f.name)
        assert g == w, f"{context}: {f.name}: batch {g} != scalar {w}"


# ---------------------------------------------------------------------------
# Mechanics: the scalar unit scenarios, replayed through the lane engine
# ---------------------------------------------------------------------------

def test_fault_free_execution_matches():
    p = Platform(mu=1e12, c=10.0, d=1.0, r=2.0)
    res = batch_one(trace_of([], []), p, 360.0, 100.0)
    assert res.makespan == pytest.approx(400.0)
    assert res.n_periodic_ckpts == 4


def test_unit_scenarios_match_scalar_exactly():
    p = Platform(mu=1e12, c=10.0, d=2.0, r=3.0)
    cases = [
        (trace_of([150.0], [0]), dict()),                    # mid-period fault
        (trace_of([95.0], [0]), dict()),                     # fault in ckpt
        (trace_of([50.0], [1]), dict(trust=AlwaysTrust())),  # trusted true
        (trace_of([50.0], [1]), dict(trust=NeverTrust())),   # untrusted true
        (trace_of([50.0], [2]), dict(trust=AlwaysTrust())),  # false pred
        (trace_of([2.0], [2]), dict(trust=AlwaysTrust())),   # unhonourable
        (trace_of([20.0], [2]), dict(trust=ThresholdTrust(30.0))),
        (trace_of([50.0], [1]), dict(trust=AlwaysTrust(),
                                     inexact_window=20.0)),
        (trace_of([50.0, 55.0, 170.0], [1, 2, 0]),
         dict(trust=AlwaysTrust(), inexact_window=30.0)),    # pred pile-up
    ]
    for i, (trace, kw) in enumerate(cases):
        want = simulate(trace, p, 360.0, 100.0, cp=4.0,
                        rng=np.random.default_rng(17), **kw)
        got = batch_one(trace, p, 360.0, 100.0, cp=4.0, seed=17, **kw)
        assert_same(got, want, f"case {i}")


def test_period_below_checkpoint_raises():
    p = Platform(mu=1e5, c=600.0)
    with pytest.raises(ValueError):
        simulate_batch([trace_of([], [])], p, 1e4, [10.0])


# ---------------------------------------------------------------------------
# Randomized equivalence suite (the 1e-9 acceptance bar, met exactly)
# ---------------------------------------------------------------------------

def _random_case(case: int):
    r = np.random.default_rng(1000 + case)
    platform = Platform(mu=float(r.uniform(2e4, 2e5)),
                        c=float(r.uniform(100, 900)),
                        d=float(r.uniform(0, 120)),
                        r=float(r.uniform(0, 900)))
    cp = float(r.uniform(0.1, 2.0)) * platform.c
    time_base = float(r.uniform(2, 6)) * platform.mu
    dist = Exponential(1.0) if case % 2 == 0 else Weibull(0.7, 1.0)
    trust = [NeverTrust(), AlwaysTrust(),
             ThresholdTrust(float(r.uniform(0, platform.c * 3))),
             FixedProbabilityTrust(float(r.uniform(0.2, 0.8)))][case % 4]
    window = [0.0, 2.0 * platform.c][case % 2]
    traces = [make_event_trace(dist, platform.mu, float(r.uniform(0, 1)),
                               float(r.uniform(0.3, 1.0)), 30 * time_base,
                               np.random.default_rng(7 * case + i))
              for i in range(3)]
    periods = [float(x) for x in
               np.random.default_rng(case).uniform(platform.c * 2,
                                                   platform.c * 20, 3)]
    return platform, cp, time_base, trust, window, traces, periods


@pytest.mark.parametrize("case", range(8))
def test_randomized_equivalence(case):
    platform, cp, tb, trust, window, traces, periods = _random_case(case)
    seeds = [11 + 7919 * i for i in range(len(traces))]
    batch = simulate_batch(traces, platform, tb, periods, cp=cp,
                           trust=trust, inexact_window=window,
                           trace_seeds=seeds)
    for ci, period in enumerate(periods):
        for ti, trace in enumerate(traces):
            want = simulate(trace, platform, tb, period, cp=cp, trust=trust,
                            inexact_window=window,
                            rng=np.random.default_rng(seeds[ti]))
            assert_same(batch.result(ci, ti), want, f"case {case}")


def test_simulate_lanes_sparse_subset():
    platform, cp, tb, trust, window, traces, periods = _random_case(2)
    lanes = [(0, 2), (1, 0), (2, 1), (2, 2)]       # (trace, period) pairs
    ms = simulate_lanes(
        traces, platform, tb, cp=cp,
        trace_indices=[t for t, _ in lanes],
        periods=[periods[c] for _, c in lanes],
        trusts=[trust] * len(lanes),
        windows=[window] * len(lanes),
        seeds=[5 + 7919 * t for t, _ in lanes])
    for j, (ti, ci) in enumerate(lanes):
        want = simulate(traces[ti], platform, tb, periods[ci], cp=cp,
                        trust=trust, inexact_window=window,
                        rng=np.random.default_rng(5 + 7919 * ti))
        assert ms[j] == want.makespan


def test_per_candidate_trust_and_window():
    platform, cp, tb, _, _, traces, periods = _random_case(4)
    trusts = [NeverTrust(), ThresholdTrust(200.0), AlwaysTrust()]
    windows = [0.0, 2 * platform.c, platform.c]
    batch = simulate_batch(traces, platform, tb, periods, cp=cp,
                           trust=trusts, inexact_window=windows,
                           trace_seeds=[3, 4, 5])
    for ci in range(3):
        for ti, trace in enumerate(traces):
            want = simulate(trace, platform, tb, periods[ci], cp=cp,
                            trust=trusts[ci], inexact_window=windows[ci],
                            rng=np.random.default_rng(3 + ti))
            assert_same(batch.result(ci, ti), want)


# ---------------------------------------------------------------------------
# Hypothesis property suite (skips when hypothesis is unavailable)
# ---------------------------------------------------------------------------

try:
    from hypothesis import given, settings, strategies as st
    _HAVE_HYPOTHESIS = True
except ImportError:        # pragma: no cover - optional test dep
    _HAVE_HYPOTHESIS = False


if _HAVE_HYPOTHESIS:
    @given(st.integers(0, 10 ** 6), st.floats(100.0, 900.0),
           st.floats(0.0, 1.0), st.floats(0.3, 1.0),
           st.sampled_from(["exp", "weibull"]),
           st.sampled_from(["never", "always", "threshold", "fixed_q"]),
           st.booleans())
    @settings(max_examples=20, deadline=None)
    def test_property_batch_equals_scalar(seed, c, recall, precision,
                                          dist_kind, trust_kind, inexact):
        r = np.random.default_rng(seed)
        platform = Platform(mu=float(r.uniform(2e4, 1e5)), c=c,
                            d=float(r.uniform(0, 100)),
                            r=float(r.uniform(0, 600)))
        cp = float(r.uniform(0.2, 1.5)) * c
        tb = float(r.uniform(2, 5)) * platform.mu
        dist = Exponential(1.0) if dist_kind == "exp" else Weibull(0.7, 1.0)
        trust = {"never": NeverTrust(), "always": AlwaysTrust(),
                 "threshold": ThresholdTrust(float(r.uniform(0, 2 * c))),
                 "fixed_q": FixedProbabilityTrust(0.5)}[trust_kind]
        window = 2.0 * c if inexact else 0.0
        traces = [make_event_trace(dist, platform.mu, recall, precision,
                                   20 * tb, np.random.default_rng(seed + i))
                  for i in range(2)]
        periods = [float(x) for x in r.uniform(c * 2, c * 15, 2)]
        batch = simulate_batch(traces, platform, tb, periods, cp=cp,
                               trust=trust, inexact_window=window,
                               trace_seeds=[seed, seed + 1])
        for ci, period in enumerate(periods):
            for ti, trace in enumerate(traces):
                want = simulate(trace, platform, tb, period, cp=cp,
                                trust=trust, inexact_window=window,
                                rng=np.random.default_rng(seed + ti))
                assert_same(batch.result(ci, ti), want)


# ---------------------------------------------------------------------------
# Runner dispatch: lane engine vs forced-scalar path, dynamic fallback
# ---------------------------------------------------------------------------

def test_runner_engines_agree_bit_for_bit():
    traces = SMALL.make_traces()
    plat, tb, cp = SMALL.platform, SMALL.time_base, SMALL.cp
    strategies = [build_strategy("rfo", SMALL),
                  build_strategy("optimal_prediction", SMALL),
                  build_strategy("inexact_prediction", SMALL)]
    auto = evaluate_strategies(traces, plat, tb, cp, strategies, seed=7,
                               engine="auto")
    scalar = evaluate_strategies(traces, plat, tb, cp, strategies, seed=7,
                                 engine="scalar")
    assert auto == scalar


def test_runner_dynamic_strategy_falls_back_to_scalar():
    sc = SMALL
    traces = sc.make_traces()
    dyn = build_strategy("dynamic_rfo", sc)          # callable period
    assert callable(dyn.period)
    got = evaluate_strategies(traces, sc.platform, sc.time_base, sc.cp,
                              [dyn, build_strategy("rfo", sc)], seed=2)
    want = evaluate_strategies(traces, sc.platform, sc.time_base, sc.cp,
                               [dyn, build_strategy("rfo", sc)], seed=2,
                               engine="scalar")
    assert got == want


def test_best_period_search_same_optimum_on_both_engines():
    traces = SMALL.make_traces()
    plat, tb, cp = SMALL.platform, SMALL.time_base, SMALL.cp
    base = build_strategy("rfo", SMALL)
    sa, ma = best_period_search(base, traces, plat, tb, cp, n_points=8,
                                engine="auto")
    ss, ms = best_period_search(base, traces, plat, tb, cp, n_points=8,
                                engine="scalar")
    assert (sa.period, ma) == (ss.period, ms)


def test_tolerance_pinned_regression_means():
    """Regression pin for evaluate_strategies means on the SMALL scenario —
    guards engine, trace generation and seeding against silent drift."""
    traces = SMALL.make_traces()
    plat, tb, cp = SMALL.platform, SMALL.time_base, SMALL.cp
    strategies = [build_strategy("rfo", SMALL),
                  build_strategy("optimal_prediction", SMALL),
                  build_strategy("young", SMALL)]
    means = evaluate_strategies(traces, plat, tb, cp, strategies, seed=7)
    want = [119433.55140339246, 103766.19817640496, 126397.87625327974]
    assert means == pytest.approx(want, rel=1e-12)


def test_unpicklable_lambda_period_runs_serially(monkeypatch):
    """Ad-hoc closure periods are legal simulator inputs; the now-default
    process pool must peel them off to a serial pass, not crash."""
    from repro.core.policies import Strategy
    monkeypatch.delenv("REPRO_EXPERIMENT_WORKERS", raising=False)
    traces = SMALL.make_traces()
    # Distinct lambda objects -> distinct cache keys -> enough pending
    # scalar work (5 x 4 traces >= _MIN_PARALLEL_SIMS) to engage the pool.
    lams = [Strategy(f"Lambda{i}", lambda t: 9000.0, NeverTrust())
            for i in range(5)]
    got = evaluate_strategies(traces, SMALL.platform, SMALL.time_base,
                              SMALL.cp, lams, seed=1, workers=4)
    want = evaluate_strategies(traces, SMALL.platform, SMALL.time_base,
                               SMALL.cp, lams, seed=1, workers=0)
    assert got == want


def test_engine_batch_is_strict():
    traces = SMALL.make_traces()
    dyn = build_strategy("dynamic_rfo", SMALL)
    with pytest.raises(ValueError, match="batch"):
        evaluate_strategies(traces, SMALL.platform, SMALL.time_base,
                            SMALL.cp, [dyn], engine="batch")
    ok = evaluate_strategies(traces, SMALL.platform, SMALL.time_base,
                             SMALL.cp, [build_strategy("rfo", SMALL)],
                             engine="batch")
    assert ok == evaluate_strategies(traces, SMALL.platform, SMALL.time_base,
                                     SMALL.cp,
                                     [build_strategy("rfo", SMALL)],
                                     engine="scalar")


def test_resolve_workers_defaults_to_cpu_count(monkeypatch):
    monkeypatch.delenv("REPRO_EXPERIMENT_WORKERS", raising=False)
    assert _resolve_workers(None) == (os.cpu_count() or 1)
    monkeypatch.setenv("REPRO_EXPERIMENT_WORKERS", "3")
    assert _resolve_workers(None) == 3
    assert _resolve_workers(1) == 1


# ---------------------------------------------------------------------------
# Persistent on-disk cache
# ---------------------------------------------------------------------------

def test_eval_cache_persists_and_resumes(tmp_path):
    traces = SMALL.make_traces()
    plat, tb, cp = SMALL.platform, SMALL.time_base, SMALL.cp
    strategies = [build_strategy("rfo", SMALL),
                  build_strategy("inexact_prediction", SMALL)]
    cold = EvalCache(persist_key="ctx", cache_dir=tmp_path)
    first = evaluate_strategies(traces, plat, tb, cp, strategies, seed=7,
                                cache=cold)
    assert cold.misses == len(strategies) * len(traces)
    cold.flush()
    assert (tmp_path / "ctx.json").exists()

    warm = EvalCache(persist_key="ctx", cache_dir=tmp_path)
    again = evaluate_strategies(traces, plat, tb, cp, strategies, seed=7,
                                cache=warm)
    assert again == first
    assert warm.misses == 0 and warm.hits == len(strategies) * len(traces)


def test_eval_cache_skips_non_serializable_candidates(tmp_path):
    traces = SMALL.make_traces()
    dyn = build_strategy("dynamic_rfo", SMALL)       # HazardPeriod period
    cache = EvalCache(persist_key="dyn", cache_dir=tmp_path)
    evaluate_strategies(traces, SMALL.platform, SMALL.time_base, SMALL.cp,
                        [dyn], seed=1, cache=cache)
    cache.flush()
    assert not (tmp_path / "dyn.json").exists()      # nothing persistable


def test_eval_cache_tolerates_corrupt_store(tmp_path):
    for i, payload in enumerate(["[]", "{\"makespans\": []}", "not json",
                                 "{\"makespans\": {\"bad key\": 1}}",
                                 "{\"makespans\": {\"[1,[],0]\": 5}}"]):
        (tmp_path / f"c{i}.json").write_text(payload)
        cache = EvalCache(persist_key=f"c{i}", cache_dir=tmp_path)
        assert len(cache) == 0


def test_run_experiment_persist_resume(tmp_path, monkeypatch):
    from repro.experiments import ExperimentSpec, StrategySpec
    monkeypatch.setenv("REPRO_CACHE_DIR", str(tmp_path))
    exp = ExperimentSpec(name="t", scenario=SMALL,
                         strategies=(StrategySpec("rfo"),
                                     StrategySpec("optimal_prediction")))
    t1 = run_experiment(exp, persist=True)
    assert list(tmp_path.glob("eval-*.json"))
    t2 = run_experiment(exp, persist=True)
    assert t1.rows == t2.rows
    # persist=False must not touch or read the store
    t3 = run_experiment(exp, persist=False)
    assert t3.rows == t1.rows


# ---------------------------------------------------------------------------
# Batched trace generation
# ---------------------------------------------------------------------------

def test_renewal_trace_bank_shapes_and_stats():
    rng = np.random.default_rng(0)
    bank = renewal_trace_bank(Exponential(10.0), 1000.0, rng, 16)
    assert len(bank) == 16
    for times in bank:
        assert np.all(np.diff(times) > 0)
        assert times.size == 0 or times[-1] < 1000.0
    mean_count = np.mean([t.size for t in bank])
    assert mean_count == pytest.approx(100.0, rel=0.3)


def test_superposed_trace_bank_matches_scalar_statistics():
    rng = np.random.default_rng(1)
    bank = superposed_trace_bank(Exponential(100.0), 10, 1000.0, rng, 12)
    assert len(bank) == 12
    for times in bank:
        assert np.all(np.diff(times) >= 0)
    # superposition of 10 procs with mean 100 ~ rate 0.1/s -> ~100 events
    assert np.mean([t.size for t in bank]) == pytest.approx(100.0, rel=0.3)


def test_make_event_trace_bank_kinds_and_merge():
    rng = np.random.default_rng(2)
    bank = make_event_trace_bank(Exponential(1.0), 50.0, 0.8, 0.7, 5000.0,
                                 rng, n_traces=8)
    assert len(bank) == 8
    for tr in bank:
        assert np.all(np.diff(tr.times) >= 0)
        assert set(np.unique(tr.kinds)) <= {0, 1, 2}
    # recall 0.8 -> most faults predicted
    kinds = np.concatenate([tr.kinds for tr in bank])
    n_faults = np.sum(kinds != 2)
    assert np.sum(kinds == 1) / max(1, n_faults) == pytest.approx(0.8,
                                                                  abs=0.1)


def test_scenario_batched_bank_equivalent_results():
    """A batched bank is a different draw but statistically interchangeable:
    evaluate a strategy on both and require agreement within a few percent."""
    spec = SMALL.replace(n_traces=16)
    per_trace = spec.make_traces()
    batched = spec.make_traces(batched=True)
    assert len(batched) == len(per_trace)
    strat = build_strategy("rfo", spec)
    plat, tb, cp = spec.platform, spec.time_base, spec.cp
    m1 = evaluate_strategies(per_trace, plat, tb, cp, [strat])[0]
    m2 = evaluate_strategies(batched, plat, tb, cp, [strat])[0]
    assert m2 == pytest.approx(m1, rel=0.05)


def test_trace_bank_batched_entries_are_distinct():
    from repro.experiments.runner import clear_trace_bank, trace_bank
    clear_trace_bank()
    a = trace_bank(SMALL, batched=False)
    b = trace_bank(SMALL, batched=True)
    assert a is trace_bank(SMALL, batched=False)
    assert b is trace_bank(SMALL, batched=True)
    assert a is not b
    clear_trace_bank()


# ---------------------------------------------------------------------------
# JAX backend (subprocess: needs x64 without disturbing this process's jax)
# ---------------------------------------------------------------------------

_JAX_CHECK = """
import numpy as np
from repro.core.batch import simulate_batch
from repro.core.simulator import ThresholdTrust, simulate
from repro.core.traces import Exponential, make_event_trace
from repro.core.waste import Platform

p = Platform(mu=5e4, c=600.0, d=60.0, r=600.0)
tb, cp = 2e5, 600.0
trust = ThresholdTrust(700.0)
traces = [make_event_trace(Exponential(1.0), p.mu, 0.6, 0.8, 30 * tb,
                           np.random.default_rng(i)) for i in range(3)]
periods = [3000.0, 9000.0]
batch = simulate_batch(traces, p, tb, periods, cp=cp, trust=trust,
                       backend="jax")
for ci, period in enumerate(periods):
    for ti, tr in enumerate(traces):
        want = simulate(tr, p, tb, period, cp=cp, trust=trust,
                        rng=np.random.default_rng(0))
        assert batch.result(ci, ti) == want, (ci, ti)
print("JAX-OK")
"""


@pytest.mark.slow
def test_jax_backend_matches_scalar_subprocess():
    jax = pytest.importorskip("jax")
    del jax
    env = dict(os.environ, JAX_ENABLE_X64="1",
               PYTHONPATH=os.pathsep.join(
                   [os.path.join(os.path.dirname(__file__), "..", "src")]
                   + sys.path))
    proc = subprocess.run([sys.executable, "-c", _JAX_CHECK], env=env,
                          capture_output=True, text=True, timeout=570)
    assert proc.returncode == 0, proc.stderr
    assert "JAX-OK" in proc.stdout


def test_jax_backend_rejects_unsupported_config():
    pytest.importorskip("jax")
    import jax as _jax
    p = Platform(mu=5e4, c=600.0)
    tr = trace_of([], [])
    if not _jax.config.jax_enable_x64:
        with pytest.raises(RuntimeError, match="x64"):
            simulate_batch([tr], p, 1e4, [2000.0], backend="jax")
    else:  # pragma: no cover - depends on session config
        with pytest.raises(ValueError, match="period"):
            simulate_batch([tr], p, 1e4, [p.c / 2], backend="jax")
