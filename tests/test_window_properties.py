"""Hypothesis property tests for the window analytics (core/windows.py).

Mirrors tests/test_prediction.py's style for the prediction-window family
(arXiv:1302.4558): the closed-form in-window period T_p* is the argmin of
the window waste on its validity branch, the window trust breakpoint is
continuous across its branches, and every window formula collapses to the
exact-date (window = 0) results of core/prediction.py.
"""

import math

import numpy as np
import pytest

pytest.importorskip("hypothesis")  # optional test dep: degrade to skip
from hypothesis import assume, given, settings, strategies as st

from repro.core.prediction import (PredictedPlatform, Predictor, beta_lim,
                                   t_pred, waste2)
from repro.core.waste import Platform
from repro.core.windows import (WindowPlan, beta_lim_window,
                                optimal_window_plan, t_window_period,
                                waste_window, waste_window_ignore,
                                waste_window_instant, waste_window_within,
                                window_strategy)

MU_IND = 125.0 * 365.0 * 86400.0


def pp(n=2**16, c=600.0, cp=600.0, d=60.0, r=600.0, recall=0.85,
       precision=0.82) -> PredictedPlatform:
    plat = Platform(mu=MU_IND / n, c=c, d=d, r=r)
    return PredictedPlatform(plat, Predictor(recall, precision), cp)


# -- T_p* = sqrt(I C_p (2-p)/p) is the argmin on its branch -------------------

@given(st.floats(0.1, 0.95), st.floats(0.1, 0.95),
       st.sampled_from([0.25, 1.0, 2.0]), st.floats(2.0, 60.0),
       st.integers(2**12, 2**19))
@settings(max_examples=60, deadline=None)
def test_t_window_period_is_argmin(r, p, cp_ratio, window_mult, n):
    """On the valid branch (C_p < T_p <= C_p + I, so in-window checkpoints
    actually fire), T_p* minimizes the within-mode waste."""
    ppl = pp(n=n, recall=r, precision=p, cp=600.0 * cp_ratio)
    window = window_mult * ppl.cp
    tp_star = t_window_period(ppl, window)
    # Skip degenerate windows where no in-window checkpoint pays off (the
    # planner falls back to the instant plan there).
    assume(tp_star > ppl.cp * 1.01 and tp_star - ppl.cp < window * 0.99)
    t = t_pred(ppl)
    w_star = waste_window_within(t, ppl, window, tp_star)
    for tp in np.linspace(ppl.cp * 1.001, ppl.cp + window, 60):
        assert waste_window_within(t, ppl, window, float(tp)) \
            >= w_star - 1e-12


@given(st.floats(0.1, 0.95), st.sampled_from([0.25, 1.0, 2.0]))
@settings(max_examples=40, deadline=None)
def test_t_window_period_closed_form(p, cp_ratio):
    """T_p*^2 = I C_p (2-p)/p — the sqrt trade-off, and scaling in I."""
    ppl = pp(precision=p, cp=600.0 * cp_ratio)
    window = 4.0 * ppl.cp
    tp = t_window_period(ppl, window)
    assert tp ** 2 == pytest.approx(window * ppl.cp * (2.0 - p) / p,
                                    rel=1e-9)
    assert t_window_period(ppl, 4.0 * window) == pytest.approx(2.0 * tp,
                                                               rel=1e-9)
    assert t_window_period(ppl, 0.0) == math.inf


# -- beta_lim_window branch continuity ----------------------------------------

@given(st.floats(0.1, 0.95), st.floats(0.1, 0.95),
       st.floats(1.2, 8.0), st.floats(100.0, 40000.0))
@settings(max_examples=60, deadline=None)
def test_beta_lim_window_continuous_in_window(r, p, tp_mult, window):
    """The breakpoint is Lipschitz in I across the min(W_p, I) kink and
    the max(0, .) clamp (derivative bounded by C_p kappa / T_p + 1)."""
    ppl = pp(recall=r, precision=p)
    tp = tp_mult * ppl.cp
    lipschitz = ppl.cp * (2.0 - p) / (2.0 * p) / tp + 1.0
    delta = 1e-3 * max(1.0, window)
    f0 = beta_lim_window(ppl, window, tp)
    f1 = beta_lim_window(ppl, window + delta, tp)
    assert abs(f1 - f0) <= lipschitz * delta + 1e-9
    assert f0 >= 0.0
    # Exactly at the kink I = W_p the two branches agree.
    wp = tp - ppl.cp
    lo = beta_lim_window(ppl, wp * (1.0 - 1e-9), tp)
    hi = beta_lim_window(ppl, wp * (1.0 + 1e-9), tp)
    assert lo == pytest.approx(hi, abs=1e-3)


@given(st.floats(0.1, 0.95), st.floats(1.2, 8.0))
@settings(max_examples=40, deadline=None)
def test_beta_lim_window_reaches_base_at_zero(p, tp_mult):
    """I -> 0 recovers the exact-date Theorem-1 breakpoint, from either
    the instant form (no T_p) or the within form (any T_p)."""
    ppl = pp(precision=p)
    base = beta_lim(ppl)
    assert beta_lim_window(ppl, 0.0, None) == base
    tp = tp_mult * ppl.cp
    assert beta_lim_window(ppl, 0.0, tp) == base
    # The I -> 0 slope is bounded by C_p kappa / T_p (< 10 on this grid).
    assert beta_lim_window(ppl, 1e-6, tp) == pytest.approx(base, abs=1e-4)


# -- window = 0 collapses to the exact-date formulas --------------------------

@given(st.floats(0.1, 0.95), st.floats(0.1, 0.95),
       st.sampled_from([0.5, 1.0, 2.0]), st.integers(2**12, 2**19))
@settings(max_examples=60, deadline=None)
def test_window_zero_collapses_to_exact_dates(r, p, cp_ratio, n):
    ppl = pp(n=n, recall=r, precision=p, cp=600.0 * cp_ratio)
    t = max(t_pred(ppl), ppl.platform.c * 1.5)
    tp = 2.0 * ppl.cp
    w2 = waste2(t, ppl)
    assert waste_window_instant(t, ppl, 0.0) == pytest.approx(w2, rel=1e-12)
    assert waste_window_within(t, ppl, 0.0, tp) == pytest.approx(w2,
                                                                 rel=1e-12)
    assert waste_window(t, ppl, 0.0, "instant") == \
        waste_window_instant(t, ppl, 0.0)
    # The ignore mode never depends on I at all.
    assert waste_window_ignore(t, ppl, 0.0) == \
        waste_window_ignore(t, ppl, 18000.0)


@given(st.floats(0.3, 0.95), st.floats(0.3, 0.95))
@settings(max_examples=30, deadline=None)
def test_window_zero_plan_is_the_exact_date_plan(r, p):
    """optimal_window_plan(I=0) degenerates to the instant plan at T_pred,
    and the built strategy carries the exact-date trust threshold."""
    ppl = pp(recall=r, precision=p)
    plan = optimal_window_plan(ppl, 0.0, mode="within")
    assert isinstance(plan, WindowPlan)
    assert plan.mode == "instant" and plan.window_period == math.inf
    assert plan.period == pytest.approx(t_pred(ppl))
    assert plan.waste == pytest.approx(waste2(plan.period, ppl), rel=1e-12)
    strat = window_strategy(ppl, 0.0, "instant")
    assert strat.period == pytest.approx(t_pred(ppl))
    assert strat.trust.threshold == pytest.approx(beta_lim(ppl))
