"""Cross-engine golden parity net (tests/golden/parity_v1.json).

A small scenario matrix — baseline / prediction / exact-model / window /
predictor-model / adaptive / stochastic-trust cells — runs through BOTH
simulation engines:

  * per cell, the scalar engine (``repro.core.simulator.simulate``) and the
    lane engine (``repro.core.batch.simulate_lanes``) must agree
    **bit-for-bit** on every per-trace makespan (the engines' equivalence
    contract, exercised across every strategy family at once);
  * the makespans (and each planner's period) must equal the committed
    golden values **exactly** — full-precision floats survive the JSON
    round-trip via repr, so any drift in trace generation, planning or
    either engine fails loudly here before it can silently skew sweeps.

Updating intentionally changed behaviour::

    python -m pytest tests/test_golden_parity.py --update-golden
    git diff tests/golden/parity_v1.json   # review, then commit

(see tests/README.md).  The jax backend is compared in a subprocess (it
needs x64 without disturbing this process's jax) on the cells it supports.
"""

import json
import math
import os
import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest

from repro.core.batch import simulate_lanes
from repro.core.simulator import simulate
from repro.experiments import ScenarioSpec, StrategySpec

GOLDEN_PATH = Path(__file__).parent / "golden" / "parity_v1.json"

# One tiny, fast base scenario (~110 periods per trace); every cell keeps
# the full paper mechanics, just less of it.
_BASE = dict(n=2 ** 16, c=600.0, d=60.0, r=600.0, n_traces=2,
             time_base_years_total=2000.0, seed=5)

# name -> (scenario, strategy): the pinned matrix.  Keep entries stable;
# *add* cells for new strategy families rather than mutating existing ones.
_CELLS: dict[str, tuple[ScenarioSpec, StrategySpec]] = {
    "baseline_rfo": (ScenarioSpec(**_BASE), StrategySpec("rfo")),
    "prediction_optimal": (ScenarioSpec(**_BASE),
                           StrategySpec("optimal_prediction")),
    "prediction_exact_model": (ScenarioSpec(**_BASE, model_order="exact"),
                               StrategySpec("prediction")),
    "window_within": (ScenarioSpec(**_BASE, window=9000.0),
                      StrategySpec("window_proactive")),
    "predictor_lead_time": (
        ScenarioSpec(**_BASE,
                     predictor={"name": "lead_time",
                                "params": {"lead_mean": 3600.0,
                                           "min_lead": 600.0}}),
        StrategySpec("optimal_prediction")),
    "adaptive_stale_prior": (
        ScenarioSpec(**_BASE),
        StrategySpec("adaptive", {"prior_recall": 0.4,
                                  "prior_precision": 0.95,
                                  "min_preds": 8, "min_faults": 4,
                                  "tol": 0.03})),
    "stochastic_trust_q": (ScenarioSpec(**_BASE),
                           StrategySpec("simple_policy", {"q": 0.5})),
    "silent_verify": (
        ScenarioSpec(**_BASE, silent_mu_ind=2.0e9, verify_cost=120.0,
                     keep_ckpts=2),
        StrategySpec("silent_verify")),
    "silent_verify_pred": (
        ScenarioSpec(**_BASE, silent_mu_ind=2.0e9, verify_cost=120.0,
                     keep_ckpts=2),
        StrategySpec("silent_verify_pred")),
}

# Every pinned cell: the flagship jax engine covers the full strategy
# matrix (windows, adaptive re-planning, stochastic trust, exact model).
_JAX_CELLS = tuple(sorted(_CELLS))


def _simulate_cell(name: str) -> dict:
    """Run one cell through both engines; assert bit-for-bit parity."""
    scenario, sspec = _CELLS[name]
    strat = sspec.build(scenario)
    traces = scenario.make_traces()
    seeds = [scenario.seed + 7919 * i for i in range(len(traces))]
    scalar = [
        simulate(tr, scenario.platform, scenario.time_base, strat.period,
                 cp=scenario.cp, trust=strat.trust,
                 inexact_window=strat.inexact_window,
                 window_mode=strat.window_mode,
                 window_period=strat.window_period,
                 adaptive=strat.adaptive,
                 n_verify=strat.n_verify,
                 verify_cost=strat.verify_cost,
                 keep_ckpts=strat.keep_ckpts,
                 rng=np.random.default_rng(seeds[i])).makespan
        for i, tr in enumerate(traces)
    ]
    lane = simulate_lanes(
        traces, scenario.platform, scenario.time_base, cp=scenario.cp,
        trace_indices=np.arange(len(traces)),
        periods=[float(strat.period)] * len(traces),
        trusts=[strat.trust] * len(traces),
        windows=[strat.inexact_window] * len(traces),
        window_modes=[strat.window_mode] * len(traces),
        window_periods=[strat.window_period] * len(traces),
        adaptives=[strat.adaptive] * len(traces),
        n_verifies=[strat.n_verify] * len(traces),
        verify_costs=[strat.verify_cost] * len(traces),
        keep_ckpts=[strat.keep_ckpts] * len(traces),
        seeds=seeds)
    assert list(lane) == scalar, \
        f"{name}: lane engine diverged from the scalar engine"
    return {
        "scenario": scenario.to_dict(),
        "strategy": sspec.to_dict(),
        "period": float(strat.period),
        "makespans": scalar,
    }


def _read_golden() -> dict:
    if not GOLDEN_PATH.exists():
        return {"version": 1, "cells": {}}
    return json.loads(GOLDEN_PATH.read_text())


@pytest.mark.parametrize("name", sorted(_CELLS))
def test_golden_parity(name, update_golden):
    got = _simulate_cell(name)
    if update_golden:
        golden = _read_golden()
        golden["cells"][name] = got
        GOLDEN_PATH.parent.mkdir(parents=True, exist_ok=True)
        GOLDEN_PATH.write_text(json.dumps(golden, indent=1, sort_keys=True)
                               + "\n")
        return
    golden = _read_golden()
    assert name in golden["cells"], \
        f"no golden entry for {name!r}: run " \
        f"`python -m pytest {Path(__file__).name} --update-golden` and " \
        f"commit tests/golden/parity_v1.json"
    want = golden["cells"][name]
    assert got["period"] == want["period"], \
        f"{name}: planned period drifted " \
        f"({got['period']!r} != {want['period']!r})"
    assert got["makespans"] == want["makespans"], \
        f"{name}: makespans drifted from the golden file " \
        f"({got['makespans']} != {want['makespans']}); if intentional, " \
        f"re-pin with --update-golden and commit the diff"


def test_golden_file_has_no_orphan_cells(update_golden):
    """Every committed golden cell still has a live definition; in update
    mode orphans are pruned instead, so a cell rename/removal heals with
    the same --update-golden run that re-pins the live cells."""
    golden = _read_golden()
    orphans = set(golden["cells"]) - set(_CELLS)
    if update_golden and orphans:
        for name in orphans:
            del golden["cells"][name]
        GOLDEN_PATH.write_text(json.dumps(golden, indent=1, sort_keys=True)
                               + "\n")
        return
    assert not orphans, f"golden cells without definitions: {sorted(orphans)}"


# ---------------------------------------------------------------------------
# JAX backend cells (subprocess: needs x64 without disturbing this process)
# ---------------------------------------------------------------------------

_JAX_GOLDEN_CHECK = """
import json, sys
import numpy as np
from repro.core.batch import simulate_batch
from repro.experiments import ScenarioSpec, StrategySpec

golden = json.loads(open(sys.argv[1]).read())
for name in sys.argv[2:]:
    want = golden["cells"][name]
    scenario = ScenarioSpec.from_dict(want["scenario"])
    strat = StrategySpec.from_dict(want["strategy"]).build(scenario)
    traces = scenario.make_traces()
    batch = simulate_batch(
        traces, scenario.platform, scenario.time_base, [float(strat.period)],
        cp=scenario.cp, trust=strat.trust,
        inexact_window=strat.inexact_window,
        window_mode=strat.window_mode,
        window_period=strat.window_period,
        adaptive=strat.adaptive,
        n_verify=strat.n_verify,
        verify_cost=strat.verify_cost,
        keep_ckpts=strat.keep_ckpts,
        trace_seeds=[scenario.seed + 7919 * i for i in range(len(traces))],
        backend="jax")
    got = [float(m) for m in batch.makespan[0]]
    assert got == want["makespans"], (name, got, want["makespans"])
print("JAX-GOLDEN-OK")
"""


@pytest.mark.slow
def test_jax_backend_matches_golden_subprocess():
    jax = pytest.importorskip("jax")
    del jax
    if not GOLDEN_PATH.exists():
        pytest.skip("golden file not generated yet")
    env = dict(os.environ, JAX_ENABLE_X64="1",
               PYTHONPATH=os.pathsep.join(
                   [os.path.join(os.path.dirname(__file__), "..", "src")]
                   + sys.path))
    proc = subprocess.run(
        [sys.executable, "-c", _JAX_GOLDEN_CHECK, str(GOLDEN_PATH)]
        + list(_JAX_CELLS),
        env=env, capture_output=True, text=True, timeout=570)
    assert proc.returncode == 0, proc.stderr
    assert "JAX-GOLDEN-OK" in proc.stdout
