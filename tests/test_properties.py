"""Hypothesis property tests for system invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # optional test dep: degrade to skip
from hypothesis import given, settings, strategies as st

from repro.core.prediction import (PredictedPlatform, Predictor,
                                   optimal_period_with_prediction,
                                   waste_with_prediction)
from repro.core.simulator import NeverTrust, simulate
from repro.core.traces import EventTrace
from repro.core.waste import Platform
from repro.kernels import ops, ref
from repro.models.layers import chunked_attention
from repro.models.moe import moe_apply, moe_init


# -- attention: chunking is work-preserving for any chunk size -----------------

@given(st.sampled_from([16, 32, 64, 128]), st.sampled_from([16, 32, 64]),
       st.booleans())
@settings(max_examples=12, deadline=None)
def test_chunked_attention_chunk_invariance(qc, kc, causal):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (2, 128, 4, 32), jnp.float32)
    k = jax.random.normal(ks[1], (2, 128, 2, 32), jnp.float32)
    v = jax.random.normal(ks[2], (2, 128, 2, 32), jnp.float32)
    a = chunked_attention(q, k, v, causal=causal, q_chunk=qc, kv_chunk=kc)
    b = ref.flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=3e-5)


# -- MoE: group-count invariance and dropless identity -------------------------

@given(st.sampled_from([1, 2, 4, 8]))
@settings(max_examples=8, deadline=None)
def test_moe_group_invariance(n_groups):
    """Dropless MoE output must not depend on the dispatch group count."""
    d, e, f, t, k = 16, 4, 32, 64, 2
    params, _ = moe_init(jax.random.PRNGKey(0), d, e, f, 0, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (t, d), jnp.float32)
    y1, _ = moe_apply(params, x, top_k=k, capacity_factor=None, n_groups=1)
    y2, _ = moe_apply(params, x, top_k=k, capacity_factor=None,
                      n_groups=n_groups)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=2e-5)


def test_moe_topk_full_equals_dense_mixture():
    """top_k = E with dropless capacity = softmax-weighted sum of all
    experts (closed-form check of the dispatch/combine path)."""
    d, e, f, t = 8, 3, 16, 32
    params, _ = moe_init(jax.random.PRNGKey(0), d, e, f, 0, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (t, d), jnp.float32)
    y, _ = moe_apply(params, x, top_k=e, capacity_factor=None, n_groups=2)
    logits = x @ params["router"]
    gates = jax.nn.softmax(logits, axis=-1)
    ref_out = jnp.zeros_like(x)
    for i in range(e):
        w = params["experts"]
        h = jax.nn.silu(x @ w["w_gate"][i]) * (x @ w["w_up"][i])
        ref_out += gates[:, i:i + 1] * (h @ w["w_down"][i])
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref_out),
                               atol=2e-4)


def test_moe_capacity_drops_pass_residual():
    """With capacity 0-ish, outputs collapse toward zero (residual passes
    outside this layer), never NaN."""
    d, e, f, t = 8, 4, 16, 64
    params, _ = moe_init(jax.random.PRNGKey(0), d, e, f, 0, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (t, d), jnp.float32)
    y, aux = moe_apply(params, x, top_k=2, capacity_factor=0.05, n_groups=1)
    assert bool(jnp.isfinite(y).all())
    assert float(jnp.abs(y).mean()) < float(jnp.abs(x).mean())


def test_moe_padded_experts_never_selected():
    d, e, f, t = 8, 3, 16, 128
    params, _ = moe_init(jax.random.PRNGKey(0), d, e, f, 0, jnp.float32,
                         pad_to=8)
    assert params["experts"]["w_gate"].shape[0] == 8
    assert params["router"].shape[-1] == 3
    x = jax.random.normal(jax.random.PRNGKey(1), (t, d), jnp.float32)
    y, _ = moe_apply(params, x, top_k=2, capacity_factor=None, n_groups=2)
    # Zeroing the dead experts must not change the output.
    import copy
    p2 = jax.tree.map(lambda a: a, params)
    for kk in ("w_gate", "w_up", "w_down"):
        p2["experts"][kk] = p2["experts"][kk].at[3:].set(0.0)
    y2, _ = moe_apply(p2, x, top_k=2, capacity_factor=None, n_groups=2)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y2), atol=1e-6)


# -- ckpt delta: quantization error bound ---------------------------------------

@given(st.integers(1, 4000), st.floats(1e-4, 10.0))
@settings(max_examples=20, deadline=None)
def test_delta_quantization_error_bound(n, scale):
    rng = np.random.default_rng(n)
    base = jnp.asarray(rng.normal(size=n), jnp.float32)
    cur = base + jnp.asarray(scale * rng.normal(size=n), jnp.float32)
    q, s = ref.quantize_delta_ref(cur, base)
    rec = ref.dequantize_delta_ref(q, s, base)
    err = np.abs(np.asarray(rec) - np.asarray(cur))
    # Error per element <= its block scale / 2.
    bound = np.repeat(np.asarray(s), 256)[:n] * 0.5 + 1e-6
    assert (err <= bound).all()


# -- analytic model: waste bounded, periods admissible --------------------------

@given(st.floats(0.01, 0.99), st.floats(0.05, 0.99),
       st.integers(2 ** 8, 2 ** 18), st.sampled_from([0.1, 1.0, 2.0]))
@settings(max_examples=50, deadline=None)
def test_optimal_period_admissible(r, p, n, cp_ratio):
    mu = 125.0 * 365.0 * 86400.0 / n
    plat = Platform(mu=mu, c=600.0, d=60.0, r=600.0)
    pp = PredictedPlatform(plat, Predictor(r, p), 600.0 * cp_ratio)
    t, w, _ = optimal_period_with_prediction(pp)
    assert t >= plat.c
    assert 0.0 <= w
    assert w == pytest.approx(waste_with_prediction(t, pp), rel=1e-6) \
        or t == plat.c


# -- simulator conservation ------------------------------------------------------

@given(st.lists(st.floats(10.0, 5000.0), min_size=0, max_size=12),
       st.integers(0, 2))
@settings(max_examples=30, deadline=None)
def test_simulator_time_conservation(times, kind):
    """makespan == base + ckpt + prockpt + destroyed + down for any trace."""
    times = sorted(times)
    kinds = [kind] * len(times)
    trace = EventTrace(np.asarray(times, float),
                       np.asarray(kinds, np.int8), horizon=1e9)
    plat = Platform(mu=1e9, c=10.0, d=3.0, r=7.0)
    res = simulate(trace, plat, time_base=500.0, period=120.0,
                   trust=NeverTrust(), rng=np.random.default_rng(0))
    lhs = res.makespan
    rhs = (res.time_base + res.time_ckpt + res.time_prockpt
           + res.time_lost + res.time_down)
    # Partial phases destroyed by faults (work in ckpt when hit) are
    # counted in time_lost; identity must hold to float tolerance.
    assert lhs == pytest.approx(rhs, rel=1e-9)
