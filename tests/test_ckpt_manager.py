"""Checkpoint manager round trips: full + delta restore, keep/gc, and the
measured-delta cost model (C_p tracks this manager's actual sparsity).

Standalone (no hypothesis dependency) so it runs everywhere the manager
does; the broader substrate suite keeps its own manager smoke tests.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import CheckpointManager, state_bytes
from repro.ckpt.manager import DELTA_RATIO_PRIOR, modeled_costs_from_bytes


def tiny_state(key=0):
    k = jax.random.PRNGKey(key)
    return {
        "params": {"w": jax.random.normal(k, (64, 32), jnp.bfloat16),
                   "b": jnp.zeros((32,), jnp.float32)},
        "opt": {"m": jax.random.normal(k, (64, 32), jnp.float32)},
        "data_step": jnp.asarray(17, jnp.int32),
    }


def assert_trees_close(a, b, atol=0.0):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_allclose(np.asarray(x, np.float32),
                                   np.asarray(y, np.float32), atol=atol)


def test_full_restore_round_trip_is_exact(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    state = tiny_state()
    info = mgr.save(7, state)
    assert info.kind == "full" and info.bytes > 0
    step, restored = mgr.restore(like=state)
    assert step == 7
    assert_trees_close(state, restored)          # bit-exact incl. bf16
    # Restored tree preserves structure and dtypes.
    assert jax.tree.structure(restored) == jax.tree.structure(state)
    for x, y in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        assert x.dtype == y.dtype


def test_delta_restore_round_trip(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    state = tiny_state()
    mgr.save(1, state)
    drift = jax.tree.map(
        lambda x: x + (0.01 if jnp.issubdtype(x.dtype, jnp.floating) else 1),
        state)
    info = mgr.save_proactive(2, drift)
    assert info.kind == "proactive"
    step, restored = mgr.restore(like=state)
    assert step == 2
    # int8 block quantization: close, not exact, on large float leaves.
    assert_trees_close(drift, restored, atol=2e-3)


def test_restore_specific_step_and_gc_drops_orphan_deltas(tmp_path):
    """keep/gc round trip: dropping an old full also drops the deltas
    based on it; every surviving checkpoint still restores."""
    mgr = CheckpointManager(str(tmp_path), keep=2)
    state = tiny_state()
    mgr.save(1, state)
    mgr.save_proactive(2, state)          # delta on full_1
    mgr.save(3, state)
    mgr.save_proactive(4, state)          # delta on full_3
    assert [s for s, _ in mgr.checkpoints()] == [1, 2, 3, 4]
    mgr.save(5, state)                    # gc: full_1 + its delta_2 go
    assert mgr.checkpoints() == [(3, "full"), (4, "delta"), (5, "full")]
    for step in (3, 4, 5):
        got, restored = mgr.restore(like=state, step=step)
        assert got == step
        assert_trees_close(state, restored, atol=2e-3)
    assert mgr.latest_step() == 5


def test_modeled_costs_track_measured_delta_ratio(tmp_path):
    """C_p reflects the sparsity this manager actually achieved, not the
    assumed prior, once a proactive delta has been measured."""
    mgr = CheckpointManager(str(tmp_path), bandwidth=1e6)
    state = {"p": jax.random.normal(jax.random.PRNGKey(0), (4096, 64),
                                    jnp.float32)}
    full = mgr.save(1, state)
    # Before any delta: the prior applies.
    assert mgr.measured_delta_ratio is None
    c0, cp0 = mgr.modeled_costs(state)
    assert cp0 == pytest.approx(DELTA_RATIO_PRIOR * c0)
    pro = mgr.save_proactive(2, jax.tree.map(lambda x: x * 1.001, state))
    ratio = mgr.measured_delta_ratio
    assert ratio == pytest.approx(pro.bytes / full.bytes)
    assert abs(ratio - DELTA_RATIO_PRIOR) > 0.005   # measured != assumed
    c1, cp1 = mgr.modeled_costs(state)
    assert c1 == c0
    assert cp1 == pytest.approx(ratio * c1)
    # An explicit ratio still overrides, and the pure form agrees.
    _, cp_expl = mgr.modeled_costs(state, delta_ratio=0.5)
    assert cp_expl == pytest.approx(0.5 * c1)
    assert modeled_costs_from_bytes(state_bytes(state), bandwidth=1e6,
                                    delta_ratio=ratio) == (c1, cp1)


def test_modeled_costs_from_bytes_shards():
    c1, cp1 = modeled_costs_from_bytes(1e9, bandwidth=2e9)
    c8, cp8 = modeled_costs_from_bytes(1e9, bandwidth=2e9, n_shards=8)
    assert c1 == pytest.approx(0.5)
    assert cp1 == pytest.approx(DELTA_RATIO_PRIOR * 0.5)
    assert c8 == pytest.approx(c1 / 8) and cp8 == pytest.approx(cp1 / 8)
