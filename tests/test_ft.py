"""Fault-tolerance runtime + scheduler + end-to-end trainer integration."""

import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import REGISTRY
from repro.configs.base import InputShape, PlatformConfig
from repro.core.prediction import (PredictedPlatform, Predictor, beta_lim,
                                   optimal_period_with_prediction)
from repro.core.traces import EventTrace, Exponential, make_event_trace
from repro.core.waste import Platform
from repro.ft import (CheckpointScheduler, FaultInjector, PredictorRuntime,
                      VirtualClock)
from repro.train import FaultTolerantTrainer

CFG = REGISTRY["llama3.2-1b"].reduced()
SHAPE = InputShape("t", 64, 4, "train")
PLAT = PlatformConfig(mu_ind=300.0, c=30.0, cp=10.0, d=5.0, r=15.0,
                      recall=0.85, precision=0.82)


def trace_of(times, kinds):
    return EventTrace(np.asarray(times, float), np.asarray(kinds, np.int8),
                      horizon=1e9)


# -- runtime pieces ---------------------------------------------------------------

def test_virtual_clock():
    c = VirtualClock()
    assert c.advance(5.0) == 5.0
    with pytest.raises(ValueError):
        c.advance(-1.0)


def test_fault_injector_window_queries():
    inj = FaultInjector(trace_of([10.0, 20.0, 30.0], [0, 1, 0]))
    assert inj.next_fault_in(0.0, 15.0) == 10.0
    assert inj.next_fault_in(10.5, 19.0) is None
    assert inj.next_fault_in(25.0, 35.0) == 30.0
    assert inj.next_fault_in(31.0, 100.0) is None


def test_injector_ignores_false_predictions():
    inj = FaultInjector(trace_of([10.0], [2]))
    assert inj.next_fault_in(0.0, 100.0) is None


def test_predictor_runtime_lead_time():
    pr = PredictorRuntime(trace_of([100.0, 200.0], [1, 2]), lead_time=30.0)
    anns = pr.announced_in(60.0, 80.0)
    assert len(anns) == 1
    assert anns[0].announce_time == 70.0
    assert anns[0].date == 100.0
    assert anns[0].is_true
    anns = pr.announced_in(160.0, 180.0)
    assert len(anns) == 1 and not anns[0].is_true


def test_predictor_runtime_skips_unpredicted():
    pr = PredictorRuntime(trace_of([100.0], [0]), lead_time=30.0)
    assert pr.announced_in(0.0, 1000.0) == []


# -- scheduler ---------------------------------------------------------------------

def test_scheduler_matches_core_analysis():
    sched = CheckpointScheduler(PLAT, n_devices=1)
    plat = Platform(mu=300.0, c=30.0, d=5.0, r=15.0)
    ppl = PredictedPlatform(plat, Predictor(0.85, 0.82), 10.0)
    t_star, w_star, use = optimal_period_with_prediction(ppl)
    assert sched.period == pytest.approx(t_star)
    assert sched.decision.use_predictions == use
    assert sched.decision.beta_lim == pytest.approx(beta_lim(ppl))
    assert sched.decision.expected_waste == pytest.approx(w_star)


def test_scheduler_mesh_scaling():
    """mu = mu_ind / n_devices (Prop. 2) drives the period down with scale."""
    big = CheckpointScheduler(
        dataclasses.replace(PLAT, mu_ind=125 * 365 * 86400.0, c=600.0,
                            cp=600.0, d=60.0, r=600.0), n_devices=512)
    small = CheckpointScheduler(
        dataclasses.replace(PLAT, mu_ind=125 * 365 * 86400.0, c=600.0,
                            cp=600.0, d=60.0, r=600.0), n_devices=64)
    assert big.mu == pytest.approx(small.mu / 8)
    assert big.period < small.period


def test_scheduler_trust_threshold():
    sched = CheckpointScheduler(PLAT, n_devices=1)
    sched.notify_save_completed(100.0)
    bl = sched.decision.beta_lim
    assert not sched.trust(100.0 + bl - 1.0)
    assert sched.trust(100.0 + bl + 1.0)


def test_scheduler_periodic_due():
    sched = CheckpointScheduler(PLAT, n_devices=1, use_predictor=False)
    sched.notify_save_completed(0.0)
    t_work = sched.period - sched.c
    assert not sched.due(t_work - 1.0)
    assert sched.due(t_work + 0.1)


def test_scheduler_requires_positive_costs():
    with pytest.raises(ValueError):
        CheckpointScheduler(dataclasses.replace(PLAT, c=0.0), n_devices=1)


def test_steps_per_checkpoint():
    sched = CheckpointScheduler(PLAT, n_devices=1)
    n = sched.steps_per_checkpoint(10.0)
    assert n == int((sched.period - sched.c) / 10.0)


# A platform roomy enough that the availability optimum is not clamped
# (period caps at ALPHA_CAP * mu otherwise).
_AVAIL_PLAT = dataclasses.replace(PLAT, mu_ind=3e5)


def test_scheduler_availability_objective_scales_period():
    """phi_c=0.25, rho=1: cheap (mostly concurrent) checkpoints halve the
    availability-optimal period vs the waste-optimal one."""
    cheap = dataclasses.replace(_AVAIL_PLAT, ckpt_outage=0.25,
                                prockpt_outage=0.25, replay_outage=1.0)
    a = CheckpointScheduler(cheap, n_devices=1, use_predictor=False,
                            objective="availability")
    w = CheckpointScheduler(cheap, n_devices=1, use_predictor=False,
                            objective="waste")
    assert a.period == pytest.approx(0.5 * w.period, rel=1e-12)
    assert a.decision.expected_waste < 1.0   # it's a U value, well-defined


def test_scheduler_availability_unit_weights_degenerate():
    """Unit outage weights: availability plans the waste-optimal period and
    the Theorem-1 threshold exactly."""
    a = CheckpointScheduler(_AVAIL_PLAT, n_devices=1,
                            objective="availability")
    w = CheckpointScheduler(_AVAIL_PLAT, n_devices=1, objective="waste")
    assert a.decision.use_predictions == w.decision.use_predictions
    if a.decision.use_predictions:
        assert a.decision.beta_lim == pytest.approx(w.decision.beta_lim)


def test_scheduler_availability_trust_threshold_is_beta_a():
    """beta_A = phi_p C_p / (rho p) < beta_lim: the scheduler acts on
    predictions closer to the last save when proactive outage is cheap."""
    cheap = dataclasses.replace(_AVAIL_PLAT, ckpt_outage=0.25,
                                prockpt_outage=0.25, replay_outage=1.0)
    a = CheckpointScheduler(cheap, n_devices=1, objective="availability")
    w = CheckpointScheduler(cheap, n_devices=1, objective="waste")
    if a.decision.use_predictions and w.decision.use_predictions:
        assert a.decision.beta_lim == pytest.approx(
            0.25 * w.decision.beta_lim)
        a.notify_save_completed(0.0)
        w.notify_save_completed(0.0)
        mid = 0.5 * (a.decision.beta_lim + w.decision.beta_lim)
        assert a.trust(mid) and not w.trust(mid)


def test_scheduler_rejects_unknown_objective():
    with pytest.raises(ValueError, match="objective"):
        CheckpointScheduler(PLAT, n_devices=1, objective="throughput")


# -- end-to-end trainer --------------------------------------------------------------

@pytest.fixture(scope="module")
def fault_trace():
    rng = np.random.default_rng(3)
    return make_event_trace(Exponential(1.0), 300.0, 0.85, 0.82,
                            horizon=1e5, rng=rng)


@pytest.mark.slow
def test_trainer_faultfree_baseline(tmp_path):
    tr = FaultTolerantTrainer(CFG, SHAPE, PLAT, workdir=str(tmp_path),
                              step_time=10.0, seed=0)
    stats = tr.run(30)
    assert stats.n_steps == 30
    assert stats.n_faults == 0
    assert stats.useful_time == pytest.approx(300.0)
    assert np.isfinite(stats.final_loss)


@pytest.mark.slow
def test_trainer_with_faults_recovers(tmp_path, fault_trace):
    tr = FaultTolerantTrainer(CFG, SHAPE, PLAT, workdir=str(tmp_path),
                              step_time=10.0, trace=fault_trace, seed=0)
    stats = tr.run(60)
    assert stats.n_faults > 0
    assert int(tr.state["data_step"]) >= 60
    # Accounting identity: total = useful + lost + ckpts + downtime (+ idle
    # stalls before proactive saves, bounded by n_proactive * period).
    attributed = (stats.useful_time + stats.lost_time + stats.ckpt_time +
                  stats.prockpt_time + stats.down_time)
    assert attributed <= stats.total_time + 1e-6
    assert np.isfinite(stats.final_loss)


@pytest.mark.slow
def test_rollback_replay_is_deterministic(tmp_path, fault_trace):
    """After rollbacks, the final state equals a fault-free run's state
    (deterministic data replay from the restored step)."""
    tr_faulty = FaultTolerantTrainer(CFG, SHAPE, PLAT,
                                     workdir=str(tmp_path / "a"),
                                     step_time=10.0, trace=fault_trace,
                                     seed=0)
    s_faulty = tr_faulty.run(40)
    tr_clean = FaultTolerantTrainer(CFG, SHAPE, PLAT,
                                    workdir=str(tmp_path / "b"),
                                    step_time=10.0, seed=0)
    s_clean = tr_clean.run(40)
    assert s_faulty.n_rollbacks > 0
    # The delta-quantized proactive restores introduce bounded drift; the
    # trajectories must agree to within that quantization error.
    a = np.asarray(jax.tree.leaves(tr_faulty.state["params"])[0],
                   np.float32)
    b = np.asarray(jax.tree.leaves(tr_clean.state["params"])[0], np.float32)
    np.testing.assert_allclose(a, b, atol=5e-2)
    assert s_faulty.final_loss == pytest.approx(s_clean.final_loss, abs=0.5)


@pytest.mark.slow
def test_predictor_reduces_measured_waste(tmp_path, fault_trace):
    """The paper's bottom line, end-to-end on real training state."""
    with_pred = FaultTolerantTrainer(CFG, SHAPE, PLAT,
                                     workdir=str(tmp_path / "p"),
                                     step_time=10.0, trace=fault_trace,
                                     seed=0).run(60)
    without = FaultTolerantTrainer(CFG, SHAPE, PLAT,
                                   workdir=str(tmp_path / "n"),
                                   step_time=10.0, trace=fault_trace,
                                   seed=0, use_predictor=False).run(60)
    assert with_pred.waste < without.waste
