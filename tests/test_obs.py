"""Observability subsystem (repro.obs): tracing, attribution, metrics.

The tentpole invariants, exercised over the golden-parity cell matrix:

  * tracing is inert — running with a sink attached yields the *identical*
    ``SimResult`` (every field, bit-for-bit) as running with none;
  * the waste-attribution buckets sum to the makespan **exactly** (scalar
    and numpy engines), and the downtime/recovery split reconciles with
    the authoritative merged ``time_down`` accrual;
  * trace event counts agree with the engine counters
    (``prockpt_end`` == ``n_proactive_ckpts``, ``rollback`` ==
    ``n_rollbacks``, ``fault`` == ``n_faults_hit``);
  * measured bucket fractions reconcile with the paper's first-order
    expectations (Eq. 7 / ``waste1``) within first-order tolerance;
  * the Perfetto export is structurally valid trace-event JSON.
"""

import json
import math

import numpy as np
import pytest

from repro.core.batch import simulate_batch
from repro.core.simulator import simulate
from repro.core.waste import waste
from repro.experiments import ScenarioSpec, StrategySpec
from repro.obs import (NullSink, RecordingSink, TraceEvent,
                       attribute_fleet_job, attribute_result,
                       events_to_trace_events, expected_fractions,
                       fleet_to_perfetto, record_run, write_trace)
from repro.obs.attribution import BUCKETS, attribute_batch
from repro.obs.metrics import MetricsRegistry, get_registry, set_registry

# Same base scenario as tests/test_golden_parity.py: ~110 periods/trace,
# full paper mechanics.
_BASE = dict(n=2 ** 16, c=600.0, d=60.0, r=600.0, n_traces=2,
             time_base_years_total=2000.0, seed=5)

_CELLS = {
    "baseline_rfo": (ScenarioSpec(**_BASE), StrategySpec("rfo")),
    "prediction_optimal": (ScenarioSpec(**_BASE),
                           StrategySpec("optimal_prediction")),
    "window_within": (ScenarioSpec(**_BASE, window=9000.0),
                      StrategySpec("window_proactive")),
    "adaptive_stale_prior": (
        ScenarioSpec(**_BASE),
        StrategySpec("adaptive", {"prior_recall": 0.4,
                                  "prior_precision": 0.95,
                                  "min_preds": 8, "min_faults": 4,
                                  "tol": 0.03})),
    "stochastic_trust_q": (ScenarioSpec(**_BASE),
                           StrategySpec("simple_policy", {"q": 0.5})),
}


def _run_cell(name, trace_index=0, sink=None):
    scenario, sspec = _CELLS[name]
    strat = sspec.build(scenario)
    traces = scenario.make_traces()
    i = trace_index
    return simulate(traces[i], scenario.platform, scenario.time_base,
                    strat.period, cp=scenario.cp, trust=strat.trust,
                    inexact_window=strat.inexact_window,
                    window_mode=strat.window_mode,
                    window_period=strat.window_period,
                    adaptive=strat.adaptive,
                    rng=np.random.default_rng(scenario.seed + 7919 * i),
                    sink=sink)


# ---------------------------------------------------------------------------
# Tracing is inert
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", sorted(_CELLS))
@pytest.mark.parametrize("trace_index", [0, 1])
def test_tracing_never_changes_results(name, trace_index):
    bare = _run_cell(name, trace_index, sink=None)
    null = _run_cell(name, trace_index, sink=NullSink())
    rec_sink = RecordingSink()
    rec = _run_cell(name, trace_index, sink=rec_sink)
    assert bare == null == rec            # every SimResult field, bitwise
    assert len(rec_sink) > 0


# Hypothesis widening of the same property (skips when unavailable; the
# parametrized cell matrix above always runs).
try:
    from hypothesis import given, settings, strategies as st
    _HAVE_HYPOTHESIS = True
except ImportError:        # pragma: no cover - optional test dep
    _HAVE_HYPOTHESIS = False

if _HAVE_HYPOTHESIS:
    @given(st.integers(0, 10 ** 6), st.sampled_from(sorted(_CELLS)))
    @settings(max_examples=15, deadline=None)
    def test_property_tracing_inert(seed, name):
        scenario, sspec = _CELLS[name]
        strat = sspec.build(scenario)
        traces = scenario.make_traces()
        kw = dict(cp=scenario.cp, trust=strat.trust,
                  inexact_window=strat.inexact_window,
                  window_mode=strat.window_mode,
                  window_period=strat.window_period,
                  adaptive=strat.adaptive)
        bare = simulate(traces[0], scenario.platform, scenario.time_base,
                        strat.period, rng=np.random.default_rng(seed), **kw)
        traced = simulate(traces[0], scenario.platform, scenario.time_base,
                          strat.period, rng=np.random.default_rng(seed),
                          sink=RecordingSink(), **kw)
        assert bare == traced


# ---------------------------------------------------------------------------
# Bucket closure + counter/trace reconciliation (scalar engine)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", sorted(_CELLS))
def test_buckets_sum_to_makespan_exactly(name):
    sink = RecordingSink()
    res = _run_cell(name, sink=sink)
    att = attribute_result(res)
    assert att.total() == res.makespan    # bit-for-bit, not isclose
    assert att.makespan == res.makespan
    assert all(getattr(att, b) >= 0.0 for b in BUCKETS)
    # The split accumulators reconcile with the authoritative merged
    # accrual up to summation order.
    assert math.isclose(att.downtime + att.recovery, res.time_down,
                        rel_tol=1e-12, abs_tol=1e-6)
    fr = att.fractions()
    assert math.isclose(sum(fr.values()), 1.0, rel_tol=1e-12)
    assert att.waste_fraction() == 1.0 - fr["work"]


@pytest.mark.parametrize("name", sorted(_CELLS))
def test_trace_counts_match_engine_counters(name):
    sink = RecordingSink()
    res = _run_cell(name, sink=sink)
    counts = sink.counts()
    assert counts.get("fault", 0) == res.n_faults_hit
    assert counts.get("rollback", 0) == res.n_rollbacks
    assert counts.get("prockpt_end", 0) == res.n_proactive_ckpts
    assert counts.get("ckpt_end", 0) == res.n_periodic_ckpts
    assert counts.get("prediction", 0) == res.n_predictions
    assert counts.get("rollback", 0) == counts.get("re_exec", 0)
    assert counts.get("replan", 0) == res.n_replans
    # Every event is a TraceEvent with a non-negative time and duration.
    for ev in sink:
        assert isinstance(ev, TraceEvent)
        assert ev.t >= 0.0 and ev.dur >= 0.0


def test_record_run_convenience():
    scenario, sspec = _CELLS["prediction_optimal"]
    strat = sspec.build(scenario)
    traces = scenario.make_traces()
    res, sink = record_run(traces[0], scenario.platform, scenario.time_base,
                           strat.period, cp=scenario.cp, trust=strat.trust,
                           rng=np.random.default_rng(scenario.seed))
    assert isinstance(sink, RecordingSink) and len(sink) > 0
    assert attribute_result(res).total() == res.makespan


# ---------------------------------------------------------------------------
# Bucket closure, elementwise (numpy lane engine) + cross-engine counters
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", sorted(_CELLS))
def test_batch_buckets_and_counters_match_scalar(name):
    scenario, sspec = _CELLS[name]
    strat = sspec.build(scenario)
    traces = scenario.make_traces()
    seeds = [scenario.seed + 7919 * i for i in range(len(traces))]
    batch = simulate_batch(traces, scenario.platform, scenario.time_base,
                           [float(strat.period)], cp=scenario.cp,
                           trust=strat.trust,
                           inexact_window=strat.inexact_window,
                           window_mode=strat.window_mode,
                           window_period=strat.window_period,
                           adaptive=strat.adaptive, trace_seeds=seeds)
    buckets = attribute_batch(batch)
    total = sum(buckets[b] for b in reversed(BUCKETS))
    tot = buckets["work"].copy()
    for b in BUCKETS[1:]:
        tot = tot + buckets[b]
    assert (tot == np.asarray(batch.makespan)).all()
    for i in range(len(traces)):
        want = _run_cell(name, i)
        got = batch.result(0, i)
        assert got.makespan == want.makespan
        assert got.n_proactive_ckpts == want.n_proactive_ckpts
        assert got.n_rollbacks == want.n_rollbacks
        assert got.time_downtime == want.time_downtime
        assert got.time_recovery == want.time_recovery
        # Scalar closure on the lane's result view agrees with the
        # vectorized closure.
        att = attribute_result(got)
        assert att.total() == got.makespan
    del total


def test_attribute_batch_requires_split_fields():
    class _Legacy:
        makespan = np.ones(2)
        time_ckpt = np.zeros(2)
        time_prockpt = np.zeros(2)
        time_lost = np.zeros(2)
        time_downtime = None
        time_recovery = None

    with pytest.raises(ValueError):
        attribute_batch(_Legacy())


# ---------------------------------------------------------------------------
# Reconciliation against the paper's analytic terms
# ---------------------------------------------------------------------------

def test_fractions_reconcile_with_first_order_waste():
    # RFO cell: Eq. 7 terms C/T, D/mu, R/mu, T/2mu.
    scenario, sspec = _CELLS["baseline_rfo"]
    strat = sspec.build(scenario)
    t = float(strat.period)
    exp = expected_fractions(t, scenario.platform)
    assert math.isclose(sum(exp.values()), 1.0, rel_tol=1e-12)
    assert exp["ckpt"] == scenario.platform.c / t
    assert exp["proactive_ckpt"] == 0.0
    # Aggregate first-order waste (Eq. 4) matches the sum of the overhead
    # fractions to first order (the cross-term is second order).
    w = waste(t, scenario.platform)
    assert math.isclose(1.0 - exp["work"], w, rel_tol=0.05)
    # Measured fractions (mean of both traces) land near the expectation:
    # first-order model, 2 finite traces — generous but directional tol.
    atts = [attribute_result(_run_cell("baseline_rfo", i)) for i in (0, 1)]
    for b in ("ckpt", "downtime", "recovery", "re_exec"):
        got = sum(a.fractions()[b] for a in atts) / len(atts)
        assert abs(got - exp[b]) < max(0.02, 1.5 * exp[b]), \
            f"{b}: measured {got:.4f} vs expected {exp[b]:.4f}"
    got_work = sum(a.fractions()["work"] for a in atts) / len(atts)
    assert abs(got_work - exp["work"]) < 0.05


def test_fractions_reconcile_with_prediction_terms():
    # Prediction cell: Eq. 15 refined-policy terms via waste1's vocabulary.
    scenario, sspec = _CELLS["prediction_optimal"]
    strat = sspec.build(scenario)
    t = float(strat.period)
    pp = scenario.pp
    exp = expected_fractions(t, scenario.platform, pp)
    assert exp["proactive_ckpt"] > 0.0
    assert math.isclose(sum(exp.values()), 1.0, rel_tol=1e-12)
    # With a predictor the expected re-execution term is strictly below
    # the unpredicted T/2mu.
    assert exp["re_exec"] < expected_fractions(t, scenario.platform)["re_exec"]
    atts = [attribute_result(_run_cell("prediction_optimal", i))
            for i in (0, 1)]
    for b in ("ckpt", "downtime", "recovery", "proactive_ckpt", "re_exec"):
        got = sum(a.fractions()[b] for a in atts) / len(atts)
        assert abs(got - exp[b]) < max(0.02, 1.5 * exp[b]), \
            f"{b}: measured {got:.4f} vs expected {exp[b]:.4f}"
    got_work = sum(a.fractions()["work"] for a in atts) / len(atts)
    assert abs(got_work - exp["work"]) < 0.05


# ---------------------------------------------------------------------------
# Fleet: sink plumbing, wait bucket, Perfetto export
# ---------------------------------------------------------------------------

def _fleet_run():
    from repro.fleet.sim import FleetJobInput, simulate_fleet

    scenario, sspec = _CELLS["prediction_optimal"]
    strat = sspec.build(scenario)
    traces = scenario.make_traces()
    sinks = [RecordingSink() for _ in traces]
    fleet = simulate_fleet(
        [FleetJobInput(trace=tr, platform=scenario.platform,
                       time_base=scenario.time_base, period=strat.period,
                       cp=scenario.cp, trust=strat.trust,
                       rng=np.random.default_rng(scenario.seed + 7919 * i),
                       name=f"job{i}", sink=sinks[i])
         for i, tr in enumerate(traces)],
        storage_streams=1, repair_slots=1)
    return fleet, sinks


def test_fleet_attribution_and_sinks():
    fleet, sinks = _fleet_run()
    assert all(len(s) > 0 for s in sinks)
    waits = 0.0
    for job, sink in zip(fleet.jobs, sinks):
        att = attribute_fleet_job(job)
        assert att.total() == job.sim.makespan
        assert att.wait == (job.time_contention_ckpt
                            + job.time_contention_prockpt
                            + job.time_repair_wait)
        waits += att.wait
        counts = sink.counts()
        # The fleet emits saves through the coordinator, not _start_ckpt:
        # starts must still pair with the machine-side end events.
        assert counts.get("ckpt_start", 0) >= counts.get("ckpt_end", 0)
        assert counts.get("prockpt_end", 0) == job.sim.n_proactive_ckpts
    assert waits > 0.0                   # 2 jobs, 1 storage stream


def test_fleet_perfetto_export(tmp_path):
    fleet, sinks = _fleet_run()
    streams = [(j.name, s.events) for j, s in zip(fleet.jobs, sinks)]
    trace = fleet_to_perfetto(streams)
    evs = trace["traceEvents"]
    assert evs, "empty Perfetto trace"
    phs = {e["ph"] for e in evs}
    assert "X" in phs and "M" in phs     # slices + track metadata
    for e in evs:
        assert "ph" in e and "pid" in e
        if e["ph"] == "X":
            assert e["dur"] >= 0.0 and "name" in e and "ts" in e
        if e["ph"] == "i":
            assert "s" in e
    names = {e["args"]["name"] for e in evs
             if e["ph"] == "M" and e["name"] in ("process_name",
                                                 "thread_name")}
    assert {j.name for j in fleet.jobs} <= names    # jobs are tracks
    out = tmp_path / "trace.json"
    write_trace(out, streams)
    loaded = json.loads(out.read_text())
    assert len(loaded["traceEvents"]) == len(evs)


def test_events_to_trace_events_pairing():
    events = [TraceEvent(0.0, "ckpt_start"),
              TraceEvent(600.0, "ckpt_end", dur=600.0),
              TraceEvent(700.0, "fault", args={"phase": 0}),
              TraceEvent(700.0, "down_start", dur=60.0),
              TraceEvent(760.0, "recover_start", dur=600.0),
              TraceEvent(1360.0, "recover_end", dur=600.0)]
    out = events_to_trace_events(events)
    slices = [e for e in out if e["ph"] == "X"]
    instants = [e for e in out if e["ph"] == "i"]
    assert {s["name"] for s in slices} == {"ckpt", "downtime", "recovery"}
    assert [i["name"] for i in instants] == ["fault"]
    ck = next(s for s in slices if s["name"] == "ckpt")
    assert ck["ts"] == 0.0 and ck["dur"] == 600.0


# ---------------------------------------------------------------------------
# Metrics registry + CLI
# ---------------------------------------------------------------------------

def test_metrics_registry_basics():
    reg = MetricsRegistry()
    reg.count("a")
    reg.count("a", 4)
    reg.gauge("g", 2.5)
    reg.add_time("t", 0.25)
    with reg.timer("t"):
        pass
    snap = reg.snapshot()
    assert snap["counters"]["a"] == 5
    assert snap["gauges"]["g"] == 2.5
    assert snap["timers"]["t"] >= 0.25
    flat = reg.flat_timings()
    assert flat["g"] == 2.5 and flat["t"] >= 0.25
    other = MetricsRegistry()
    other.count("a", 2)
    other.gauge("g2", 1.0)
    reg.merge(other)
    assert reg.counters["a"] == 7 and reg.gauges["g2"] == 1.0
    reg.clear()
    assert not reg.counters and not reg.gauges and not reg.timers


def test_set_registry_scoping():
    fresh = MetricsRegistry()
    prev = set_registry(fresh)
    try:
        get_registry().count("x")
        assert fresh.counters["x"] == 1
    finally:
        set_registry(prev)
    assert get_registry() is prev


def test_fleet_feeds_metrics_registry():
    fresh = MetricsRegistry()
    prev = set_registry(fresh)
    try:
        _fleet_run()
    finally:
        set_registry(prev)
    assert fresh.counters.get("fleet.faults", 0) > 0
    assert fresh.counters.get("fleet.repair_waits", 0) >= 0


def test_ft_runtime_feeds_metrics_registry():
    from repro.core.traces import Exponential, make_event_trace
    from repro.ft.runtime import FaultInjector, PredictorRuntime

    trace = make_event_trace(Exponential(1.0), 1000.0, 0.8, 0.8, 50_000.0,
                             np.random.default_rng(0))
    fresh = MetricsRegistry()
    prev = set_registry(fresh)
    try:
        inj = FaultInjector(trace)
        pred = PredictorRuntime(trace, lead_time=100.0)
        assert inj.next_fault_in(0.0, 50_000.0) is not None
        assert pred.announced_in(0.0, 50_000.0)
    finally:
        set_registry(prev)
    assert fresh.counters.get("ft.faults_injected", 0) > 0
    assert fresh.counters.get("ft.predictions", 0) > 0


def test_cli_metrics_view(tmp_path, capsys):
    from repro.store.cli import main as cli_main
    from repro.store.record import RunRecord
    from repro.store.store import ResultStore

    store_dir = str(tmp_path / "store")
    store = ResultStore(store_dir)
    rec = RunRecord.create(
        "benchmark", "obs_demo", {"v": 1},
        payload={"metrics": {"runner.cells": 3, "fleet.faults": 7}},
        timings={"wall_s": 1.25, "jax.compile_s": 0.5})
    store.put(rec)
    assert cli_main(["--store", store_dir, "metrics", rec.record_id]) == 0
    out = capsys.readouterr().out
    assert "runner.cells" in out and "fleet.faults" in out
    assert "wall_s" in out and "jax.compile_s" in out
    # Name-based lookup + empty-metrics record both work.
    bare = RunRecord.create("benchmark", "bare", {"v": 1})
    store.put(bare)
    assert cli_main(["--store", store_dir, "metrics", "bare"]) == 0
    assert "no metrics" in capsys.readouterr().out
