"""Result store: record round-trips, content-hash stability, schema
invalidation, deterministic diffs, suite parsing + claim evaluation, the
store-backed resumable suite runner, gc (store, spill) and the CLI."""

import json
import math
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.experiments import (DistributionSpec, ResultTable, ScenarioSpec,
                               run_suite)
from repro.experiments.runner import EvalCache, _cell_persist_key
from repro.store import (STORE_SCHEMA_VERSION, ClaimSpec, ResultStore,
                         RunRecord, SuiteItem, SuiteSpec, canonical_json,
                         content_hash, diff_records, gc_cache)
from repro.store.cli import main as cli_main
from repro.store.suite import lookup_path

# The deliberately small cell of test_experiments: a handful of events per
# trace, so suite-runner tests execute in well under a second per run.
SMALL = ScenarioSpec(n=32, dist=DistributionSpec("weibull", {"shape": 0.7}),
                     mu_ind=32 * 1e5, c=600.0, d=60.0, r=600.0,
                     time_base_years_total=0.1, start=0.0, n_traces=3,
                     seed=3)

TINY_SUITE = {
    "suite": "tiny",
    "register": [],
    "items": [{
        "spec": {"name": "tiny", "scenario": SMALL.to_dict(),
                 "strategies": [{"name": "rfo"},
                                {"name": "optimal_prediction"}]},
        "claims": [
            {"kind": "bound", "metric": "waste", "min": 0.0, "max": 1.0,
             "where": {"strategy": "RFO"}},
            {"kind": "compare", "metric": "makespan", "op": "<=",
             "rel_factor": 2.0,
             "lhs": {"strategy": "OptimalPrediction"},
             "rhs": {"strategy": "RFO"}},
        ],
    }],
}


# ---------------------------------------------------------------------------
# Records: round-trip, ids, canonical serialization
# ---------------------------------------------------------------------------

def test_record_round_trip():
    rec = RunRecord.create(
        "experiment", "demo", {"spec": {"n": 2 ** 16}, "seed": 0},
        rows=[{"strategy": "RFO", "waste": np.float64(0.25)}],
        timings={"wall_s": 1.25})
    back = RunRecord.from_dict(json.loads(rec.to_json()))
    assert back == rec
    assert back.record_id == rec.record_id
    # numpy scalars became plain floats on the way in
    assert isinstance(rec.rows[0]["waste"], float)


def test_record_id_covers_inputs_not_outputs():
    a = RunRecord.create("experiment", "demo", {"seed": 0},
                         rows=[{"waste": 0.1}])
    b = RunRecord.create("experiment", "demo", {"seed": 0},
                         rows=[{"waste": 0.9}], timings={"wall_s": 99.0})
    c = RunRecord.create("experiment", "demo", {"seed": 1},
                         rows=[{"waste": 0.1}])
    assert a.record_id == b.record_id       # outputs don't affect identity
    assert a.record_id != c.record_id       # inputs do


def test_content_hash_stable_across_processes():
    """The id must not depend on PYTHONHASHSEED / dict insertion order."""
    payload = {"b": 2, "a": [1.5, {"z": True, "y": None}], "n": 2 ** 40}
    here = content_hash(payload)
    code = ("import sys, json; sys.path.insert(0, 'src'); "
            "from repro.store import content_hash; "
            "print(content_hash(json.loads(sys.argv[1])))")
    for seed in ("0", "4242"):
        out = subprocess.run(
            [sys.executable, "-c", code, json.dumps(payload)],
            capture_output=True, text=True, check=True,
            cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
            env=dict(os.environ, PYTHONHASHSEED=seed))
        assert out.stdout.strip() == here


def test_canonical_json_is_deterministic():
    a = canonical_json({"b": np.float64(0.1), "a": (1, 2)})
    b = canonical_json({"a": [1, 2], "b": 0.1})
    assert a == b
    assert json.loads(a) == {"a": [1, 2], "b": 0.1}


def test_schema_mismatch_invalidated_never_misread(tmp_path):
    store = ResultStore(tmp_path)
    rec = RunRecord.create("experiment", "demo", {"seed": 0})
    rid = store.put(rec)
    # Rewrite the record as if a future schema produced it.
    d = json.loads(store.record_path(rid).read_text())
    d["schema"] = STORE_SCHEMA_VERSION + 1
    store.record_path(rid).write_text(json.dumps(d))
    assert store.get(rid) is None
    assert store.invalidated == 1
    with pytest.raises(ValueError, match="never misread"):
        RunRecord.from_dict(d)
    # Corrupt JSON degrades the same way.
    store.record_path(rid).write_text("{not json")
    assert store.get(rid) is None


# ---------------------------------------------------------------------------
# Store CRUD / query / baselines / gc
# ---------------------------------------------------------------------------

def _rec(name, seed, created):
    import dataclasses
    rec = RunRecord.create("experiment", name, {"seed": seed})
    return dataclasses.replace(rec, created=created)


def test_store_find_latest(tmp_path):
    store = ResultStore(tmp_path)
    for i in range(3):
        store.put(_rec("a", i, created=100.0 + i))
    store.put(_rec("b", 0, created=50.0))
    assert len(list(store)) == 4
    assert [r.identity["seed"] for r in store.find(name="a")] == [2, 1, 0]
    assert store.latest("a").identity["seed"] == 2
    assert store.find(kind="benchmark") == []
    assert store.find(since=100.5)[0].identity["seed"] in (1, 2)


def test_store_gc_keep_and_size_cap(tmp_path):
    store = ResultStore(tmp_path)
    for i in range(6):
        store.put(_rec("a", i, created=float(i)))
    dry = store.gc(keep_per_name=2, dry_run=True)
    assert len(dry) == 4 and len(list(store)) == 6      # dry run deletes nothing
    gone = store.gc(keep_per_name=2)
    assert len(gone) == 4
    kept = store.find(name="a")
    assert [r.identity["seed"] for r in kept] == [5, 4]
    # Size cap: evict LRU (oldest created) past the budget.
    victims = store.gc(keep_per_name=10, max_bytes=0)
    assert len(victims) == 2 and "size cap" in victims[0][1]
    assert list(store) == []


def test_baseline_bundle_round_trip(tmp_path):
    store = ResultStore(tmp_path)
    member = RunRecord.create("experiment", "m", {"seed": 0},
                              rows=[{"waste": 0.1}])
    store.put(member)
    suite_rec = RunRecord.create(
        "suite", "s", {"member_ids": [member.record_id]},
        payload={"items": [{"record_id": member.record_id}]})
    bundle = ResultStore.bundle(suite_rec, [member])
    path = store.set_baseline("s", bundle)
    assert store.get_baseline("s") == json.loads(canonical_json(bundle))
    loaded = ResultStore.load_bundle(path)
    assert member.record_id in loaded["records"]
    bad = dict(bundle, schema=STORE_SCHEMA_VERSION + 1)
    (tmp_path / "bad.json").write_text(json.dumps(bad))
    with pytest.raises(ValueError, match="never misread"):
        ResultStore.load_bundle(tmp_path / "bad.json")


# ---------------------------------------------------------------------------
# Deterministic diff
# ---------------------------------------------------------------------------

def test_diff_ignores_provenance_and_timing():
    a = RunRecord.create("benchmark", "b", {"q": True},
                         payload={"speedup": 10.0, "batch_s": 1.0,
                                  "scalar_s_measured": 2.0,
                                  "cell": {"value": 3.0}},
                         timings={"wall_s": 5.0})
    import dataclasses
    b = dataclasses.replace(
        a, payload={"speedup": 99.0, "batch_s": 9.0,
                    "scalar_s_measured": 7.0, "cell": {"value": 3.0}},
        timings={"wall_s": 50.0}, created=a.created + 100, git_rev="other")
    assert diff_records(a, b) == []
    # With a timing band, a 9.9x change trips it...
    banded = diff_records(a, b, timing_rel_tol=0.5)
    assert {d.path for d in banded} == {"payload.speedup", "payload.batch_s",
                                        "payload.scalar_s_measured"}
    assert all(d.kind == "timing" for d in banded)
    # ...but result cells stay exact regardless.
    c = dataclasses.replace(a, payload=dict(a.payload, cell={"value": 3.1}))
    assert [d.path for d in diff_records(a, c)] == ["payload.cell.value"]


def test_diff_values_lists_and_nan():
    a = RunRecord.create("experiment", "e", {"s": 0},
                         rows=[{"w": math.nan}, {"w": 1.0}])
    b = RunRecord.create("experiment", "e", {"s": 0},
                         rows=[{"w": math.nan}, {"w": 2.0}])
    diffs = diff_records(a, b)
    assert [d.path for d in diffs] == ["rows[1].w"]      # NaN == NaN
    short = RunRecord.create("experiment", "e", {"s": 0}, rows=[{"w": 1.0}])
    assert any(d.path == "rows.length" for d in diff_records(a, short))
    # bool vs int is a type change, not an equality
    x = RunRecord.create("experiment", "e", {"s": 0}, payload={"v": True})
    y = RunRecord.create("experiment", "e", {"s": 0}, payload={"v": 1})
    assert len(diff_records(x, y)) == 1


# ---------------------------------------------------------------------------
# Suites: parsing + claim evaluation
# ---------------------------------------------------------------------------

def test_suite_yaml_parse(tmp_path):
    text = """\
suite: demo
register: []
defaults: {n_traces: 2}
items:
  - experiment: foo
    claims:
      - {kind: pinned, metric: period, value: 1.0, tol: 0.1, where: {n: 4}}
  - experiment: baz
    n_traces: 5
"""
    path = tmp_path / "demo.yaml"
    path.write_text(text)
    suite = SuiteSpec.from_file(path)
    assert suite.name == "demo"
    assert suite.items[0].n_traces == 2          # defaults merged
    assert suite.items[0].claims[0].kind == "pinned"
    assert suite.items[1].n_traces == 5          # item wins over defaults

    bench = SuiteSpec.from_dict({"suite": "b", "items": [
        {"benchmark": "bar",
         "claims": [{"kind": "bound", "path": "a.b", "min": 0}]}]})
    assert bench.items[0].kind == "benchmark"
    assert bench.items[0].claims[0].path == "a.b"


def test_suite_item_validation():
    with pytest.raises(ValueError, match="exactly one"):
        SuiteItem()
    with pytest.raises(ValueError, match="exactly one"):
        SuiteItem(experiment="a", benchmark="b")
    with pytest.raises(ValueError, match="owns its parameters"):
        SuiteItem(benchmark="b", overrides={"n": 4})
    with pytest.raises(KeyError, match="unknown suite item fields"):
        SuiteItem.from_dict({"experiment": "a", "bogus": 1})
    with pytest.raises(ValueError, match="unknown claim kind"):
        ClaimSpec(kind="magic")
    with pytest.raises(ValueError, match="needs 'over'"):
        ClaimSpec(kind="monotonic", metric="w")


def test_claim_evaluation_kinds():
    table = ResultTable([
        {"x": 1, "strategy": "A", "w": 0.10},
        {"x": 2, "strategy": "A", "w": 0.20},
        {"x": 3, "strategy": "A", "w": 0.15},
        {"x": 1, "strategy": "B", "w": 0.30},
    ])
    payload = {"cell": {"speedup": 12.0}, "list": [{"v": 5}]}

    pinned = ClaimSpec(kind="pinned", metric="w", value=0.1, tol=0.01,
                       where={"x": 1, "strategy": "A"})
    assert pinned.evaluate(table, payload)["ok"]
    exact = ClaimSpec(kind="pinned", metric="w", value=0.100001,
                      where={"x": 1, "strategy": "A"})
    assert not exact.evaluate(table, payload)["ok"]     # no tol = exact

    bound = ClaimSpec(kind="bound", path="cell.speedup", min=10.0)
    assert bound.evaluate(table, payload)["ok"]
    assert lookup_path(payload, "list.0.v") == 5

    comp = ClaimSpec(kind="compare", metric="w", op="<",
                     lhs={"x": 1, "strategy": "A"},
                     rhs={"x": 1, "strategy": "B"})
    assert comp.evaluate(table, payload)["ok"]
    scaled = ClaimSpec(kind="compare", metric="w", op="<=", rel_factor=0.5,
                       lhs={"x": 1, "strategy": "B"},
                       rhs={"x": 1, "strategy": "B"})
    assert not scaled.evaluate(table, payload)["ok"]

    mono = ClaimSpec(kind="monotonic", metric="w", over="x", tol=0.06,
                     direction="increasing", where={"strategy": "A"})
    assert mono.evaluate(table, payload)["ok"]          # 0.2 -> 0.15 in tol
    strict = ClaimSpec(kind="monotonic", metric="w", over="x",
                       direction="increasing", where={"strategy": "A"})
    assert not strict.evaluate(table, payload)["ok"]

    missing = ClaimSpec(kind="bound", path="cell.nope", min=0.0)
    res = missing.evaluate(table, payload)
    assert not res["ok"] and "lookup error" in res["detail"]


def test_claim_round_trip():
    c = ClaimSpec.from_dict({"kind": "compare", "metric": "w", "op": "==",
                             "lhs": {"a": 1}, "rhs": {"a": 2}})
    assert ClaimSpec.from_dict(c.to_dict()) == c
    with pytest.raises(KeyError, match="unknown claim fields"):
        ClaimSpec.from_dict({"kind": "bound", "path": "x", "mim": 0})


# ---------------------------------------------------------------------------
# Suite runner: store-backed resume
# ---------------------------------------------------------------------------

def test_run_suite_resumes_from_store(tmp_path):
    store = ResultStore(tmp_path)
    suite = SuiteSpec.from_dict(TINY_SUITE)

    first = run_suite(suite, store=store)
    assert first.ok and not first.items[0].cached
    assert len(first.items[0].claims) == 2
    stored = store.get(first.items[0].record_id)
    assert stored is not None and stored.ok

    second = run_suite(suite, store=store)
    assert second.ok and second.items[0].cached
    assert second.items[0].record_id == first.items[0].record_id
    assert second.record_id == first.record_id   # suite identity too
    # the cached rows are the executed rows, verbatim
    assert second.items[0].record.rows == first.items[0].record.rows

    third = run_suite(suite, store=store, resume=False)
    assert not third.items[0].cached
    assert third.items[0].record.rows == first.items[0].record.rows


def test_run_suite_failed_run_not_stored(tmp_path):
    store = ResultStore(tmp_path)
    suite = SuiteSpec.from_dict({
        "suite": "broken", "register": [],
        "items": [{"experiment": "no_such_experiment_xyz"}]})
    result = run_suite(suite, store=store)
    assert not result.ok
    assert result.items[0].error is not None
    assert store.get(result.items[0].record_id) is None
    assert any("ERROR" in f for f in result.failures())


def test_run_suite_reevaluates_claims_on_resume(tmp_path):
    store = ResultStore(tmp_path)
    run_suite(SuiteSpec.from_dict(TINY_SUITE), store=store)
    tightened = json.loads(json.dumps(TINY_SUITE))
    tightened["items"][0]["claims"] = [
        {"kind": "bound", "metric": "waste", "max": -1.0,
         "where": {"strategy": "RFO"}}]
    result = run_suite(SuiteSpec.from_dict(tightened), store=store)
    assert result.items[0].cached          # no re-simulation...
    assert not result.ok                   # ...but the new claim gates


# ---------------------------------------------------------------------------
# EvalCache spill gc (the unbounded ~/.cache/repro fix)
# ---------------------------------------------------------------------------

def _spill(tmp_path, name, size, mtime):
    path = tmp_path / f"eval-{name}.json"
    path.write_text("x" * size)
    os.utime(path, (mtime, mtime))
    return path


def _strategy(period):
    from repro.core.policies import NeverTrust, Strategy
    return Strategy("S", period, NeverTrust())


def test_gc_cache_lru_eviction(tmp_path):
    old = _spill(tmp_path, "old", 600, 1_000.0)
    mid = _spill(tmp_path, "mid", 600, 2_000.0)
    new = _spill(tmp_path, "new", 600, 3_000.0)
    other = tmp_path / "not-a-spill.json"
    other.write_text("x" * 600)

    dry = gc_cache(tmp_path, max_bytes=1300, dry_run=True)
    assert [p for p, _ in dry] == [old] and old.exists()

    evicted = gc_cache(tmp_path, max_bytes=1300)
    assert [p for p, _ in evicted] == [old]
    assert not old.exists() and mid.exists() and new.exists()
    assert other.exists()                     # only eval-*.json is fair game
    assert gc_cache(tmp_path, max_bytes=1300) == []


def test_evalcache_flush_triggers_gc(tmp_path, monkeypatch, capsys):
    monkeypatch.setenv("REPRO_CACHE_DIR", str(tmp_path))
    monkeypatch.setenv("REPRO_CACHE_MAX_MB", str(1e-5))   # ~10 bytes
    _spill(tmp_path, "stale", 400, 1_000.0)

    monkeypatch.setenv("REPRO_CACHE_GC_DRY_RUN", "1")
    key = _cell_persist_key(SMALL, False)
    cache = EvalCache(persist_key=key, cache_dir=tmp_path)
    cache.put(_strategy(1200.0), 0, 123.0)
    cache.flush()
    assert "would evict" in capsys.readouterr().err
    assert (tmp_path / "eval-stale.json").exists()        # dry run

    monkeypatch.delenv("REPRO_CACHE_GC_DRY_RUN")
    cache.put(_strategy(1300.0), 0, 124.0)
    cache.flush()
    assert not (tmp_path / "eval-stale.json").exists()    # LRU victim

    monkeypatch.setenv("REPRO_CACHE_MAX_MB", "0")         # 0 disables
    _spill(tmp_path, "stale2", 400, 1_000.0)
    cache.put(_strategy(1400.0), 0, 125.0)
    cache.flush()
    assert (tmp_path / "eval-stale2.json").exists()


def test_evalcache_load_touches_lru_clock(tmp_path):
    key = _cell_persist_key(SMALL, False)
    cache = EvalCache(persist_key=key, cache_dir=tmp_path)
    cache.put(_strategy(1200.0), 0, 123.0)
    cache.flush()
    path = tmp_path / f"{key}.json"
    os.utime(path, (1_000.0, 1_000.0))
    EvalCache(persist_key=key, cache_dir=tmp_path)        # pure read
    assert path.stat().st_mtime > 1_000.0


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

def test_cli_list_show_diff_gc_baseline(tmp_path, capsys):
    store_dir = str(tmp_path / "store")
    a = RunRecord.create("experiment", "demo", {"seed": 0},
                         rows=[{"w": 0.1}])
    b = RunRecord.create("experiment", "demo", {"seed": 1},
                         rows=[{"w": 0.2}])
    store = ResultStore(store_dir)
    store.put(a)
    store.put(b)

    assert cli_main(["--store", store_dir, "list"]) == 0
    out = capsys.readouterr().out
    assert a.record_id in out and b.record_id in out

    assert cli_main(["--store", store_dir, "show", a.record_id]) == 0
    assert json.loads(capsys.readouterr().out)["record_id"] == a.record_id

    rc = cli_main(["--store", store_dir, "diff", a.record_id, b.record_id])
    assert rc == 1
    assert "identity.seed" in capsys.readouterr().out
    assert cli_main(["--store", store_dir, "diff", a.record_id,
                     a.record_id]) == 0
    capsys.readouterr()

    # bundle diff: clean then injected regression
    suite_rec = RunRecord.create("suite", "s",
                                 {"member_ids": [a.record_id]},
                                 payload={"items": [
                                     {"record_id": a.record_id}]})
    store.put(suite_rec)
    bundle = ResultStore.bundle(suite_rec, [a])
    good = tmp_path / "good.json"
    good.write_text(canonical_json(bundle))
    assert cli_main(["--store", store_dir, "diff", str(good)]) == 0
    bad_bundle = json.loads(canonical_json(bundle))
    bad_bundle["records"][a.record_id]["rows"][0]["w"] = 9.9
    bad = tmp_path / "bad.json"
    bad.write_text(json.dumps(bad_bundle))
    assert cli_main(["--store", store_dir, "diff", str(bad)]) == 1
    capsys.readouterr()

    assert cli_main(["--store", store_dir, "baseline", "s",
                     "--out", str(tmp_path / "base.json")]) == 0
    exported = ResultStore.load_bundle(tmp_path / "base.json")
    assert a.record_id in exported["records"]

    assert cli_main(["--store", store_dir, "gc", "--keep", "1"]) == 0
    assert len(store.find(name="demo")) == 1


def test_cli_run_gate_and_require_cached(tmp_path, capsys):
    store_dir = str(tmp_path / "store")
    suite_path = tmp_path / "tiny.json"
    suite_path.write_text(json.dumps(TINY_SUITE))
    baseline = tmp_path / "baseline.json"

    rc = cli_main(["--store", store_dir, "run", str(suite_path),
                   "--update-baseline", str(baseline)])
    assert rc == 0 and baseline.exists()
    capsys.readouterr()

    # resume: everything cached, gate clean
    rc = cli_main(["--store", store_dir, "run", str(suite_path),
                   "--require-cached", "--gate", str(baseline)])
    out = capsys.readouterr().out
    assert rc == 0
    assert "1 from store" in out and "no divergence" in out

    # injected regression: perturb the baseline, the gate must fail
    bundle = json.loads(baseline.read_text())
    for rec in bundle["records"].values():
        if rec["kind"] == "experiment":
            rec["rows"][0]["makespan"] += 1.0
    baseline.write_text(json.dumps(bundle))
    rc = cli_main(["--store", store_dir, "run", str(suite_path),
                   "--gate", str(baseline)])
    assert rc == 1
    assert "makespan" in capsys.readouterr().out


# ---------------------------------------------------------------------------
# Determinism knobs riding along
# ---------------------------------------------------------------------------

def test_result_table_to_json_sorted():
    table = ResultTable([{"b": 1, "a": 2}])
    assert table.to_json() == '[{"a": 2, "b": 1}]'
    assert table.to_json(sort_keys=False) == '[{"b": 1, "a": 2}]'


def test_with_overrides():
    from repro.experiments import ExperimentSpec, StrategySpec, SweepSpec
    exp = ExperimentSpec(
        name="t", scenario=SMALL, strategies=(StrategySpec("rfo"),),
        sweep=SweepSpec(axes={"n": [32, 64]}, labels={"n": ["s", "l"]}))
    # axis override replaces the swept values and drops the stale labels
    over = exp.with_overrides({"n": [128]})
    assert tuple(over.sweep.axes["n"]) == (128,)
    assert "n" not in over.sweep.labels
    # scenario override on a non-swept field
    assert over.with_overrides({"seed": 9}).scenario.seed == 9


def test_with_overrides_covered_field():
    from repro.experiments import ExperimentSpec, StrategySpec, SweepSpec
    exp = ExperimentSpec(
        name="t", scenario=SMALL, strategies=(StrategySpec("rfo"),),
        sweep=SweepSpec(
            axes={"recall,precision": [(0.85, 0.82), (0.7, 0.4)]}))
    # a scenario field controlled by a (zipped) sweep axis cannot be
    # overridden underneath it — the axis would discard it per cell
    with pytest.raises(ValueError, match="controlled by sweep axis"):
        exp.with_overrides({"recall": 0.9})
    # paths the axis does not cover merge fine
    assert exp.with_overrides(
        {"dist.params.shape": 0.9}).scenario.dist.params["shape"] == 0.9
