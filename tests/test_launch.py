"""Launch layer: HLO parsing, spec trees, step builders, policies, serving."""

import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import REGISTRY, SHAPES, pairs
from repro.core.policies import (best_period, daly, evaluate,
                                 inexact_prediction, optimal_prediction,
                                 rfo, simple_policy, young)
from repro.core.prediction import PredictedPlatform, Predictor
from repro.core.traces import Exponential, make_event_trace
from repro.core.waste import Platform
from repro.launch import hlo
from repro.launch.steps import abstract_cache, abstract_state
from repro.models.transformer import cache_axes
from repro.parallel.sharding import DECODE_RULES, spec_tree

# ---------------------------------------------------------------------------
# HLO collective parsing
# ---------------------------------------------------------------------------

SAMPLE_HLO = """
  %ag = bf16[16,512,1024]{2,1,0} all-gather(%x), replica_groups={...}
  %ar = f32[256,4096]{1,0} all-reduce(%y), to_apply=%add
  %rs = f32[64]{0} reduce-scatter(%z), dimensions={0}
  %a2a = bf16[8,128]{1,0} all-to-all(%w), dimensions={0}
  %cp = f32[256,1,32,512]{3,2,1,0} collective-permute(%v)
  %tuple_ar = (f32[16,16]{1,0}, bf16[8,8]{1,0}) all-reduce(%a, %b)
  %dot = f32[128,128]{1,0} dot(%p, %q)
"""


def test_collective_bytes_parsing():
    stats = hlo.collective_bytes(SAMPLE_HLO)
    expect = {
        "all-gather": 16 * 512 * 1024 * 2,
        "all-reduce": 256 * 4096 * 4 + (16 * 16 * 4 + 8 * 8 * 2),
        "reduce-scatter": 64 * 4,
        "all-to-all": 8 * 128 * 2,
        "collective-permute": 256 * 32 * 512 * 4,
    }
    assert stats.by_kind == expect
    assert stats.n_ops == 6
    assert stats.total == sum(expect.values())


def test_collective_bytes_ignores_compute_ops():
    assert hlo.collective_bytes("%d = f32[4,4] dot(%a, %b)").total == 0


def test_shape_bytes_unknown_dtype():
    assert hlo._shape_bytes("weird[100]") == 0
    assert hlo._shape_bytes("bf16[2,3]") == 12


def test_roofline_terms_math():
    t = hlo.RooflineTerms(
        arch="a", shape="s", mesh="m", n_devices=256,
        hlo_flops=197e12, hlo_bytes=819e9, coll_bytes=100e9,
        t_compute=1.0, t_memory=1.0, t_collective=2.0,
        model_flops=197e12 * 128, bytes_per_device=8e9)
    assert t.dominant == "collective"
    assert t.useful_flops_ratio == pytest.approx(0.5)


# ---------------------------------------------------------------------------
# Abstract state / spec trees for every assigned arch
# ---------------------------------------------------------------------------

class FakeMesh:
    def __init__(self, **shape):
        self.shape = shape


@pytest.mark.parametrize("arch", sorted(REGISTRY))
def test_abstract_state_and_specs(arch):
    """Full-size abstract params + axes align, and spec trees build."""
    cfg = REGISTRY[arch]
    params_abs, axes, _ = abstract_state(cfg)
    flat_p = jax.tree.leaves(params_abs)
    assert all(isinstance(l, jax.ShapeDtypeStruct) for l in flat_p)
    mesh = FakeMesh(data=16, model=16)
    specs = spec_tree(axes, params_abs, mesh)
    flat_s = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    assert len(flat_s) == len(flat_p)
    # Parameter bytes per device <= global/16 (something must shard).
    total = sum(np.prod(l.shape) * l.dtype.itemsize for l in flat_p)
    assert total > 0


@pytest.mark.parametrize("arch,shape_name", [
    ("llama3-405b", "decode_32k"),
    ("recurrentgemma-2b", "long_500k"),
    ("xlstm-125m", "decode_32k"),
])
def test_abstract_cache_specs(arch, shape_name):
    cfg = REGISTRY[arch].for_shape(SHAPES[shape_name])
    shape = SHAPES[shape_name]
    cache = abstract_cache(cfg, shape.global_batch, shape.seq_len)
    axes = cache_axes(cfg)
    mesh = FakeMesh(data=16, model=16)
    specs = spec_tree(axes, cache, mesh, DECODE_RULES)
    # KV caches must shard their time axis over "model" when divisible.
    flat = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    assert len(flat) == len(jax.tree.leaves(cache))


def test_dryrun_artifacts_complete():
    """The committed dry-run results must cover the full assigned grid."""
    if not os.path.exists("dryrun_results.json"):
        pytest.skip("dryrun_results.json not present")
    rows = json.load(open("dryrun_results.json"))
    base = {(r["arch"], r["shape"], r["mesh"]) for r in rows
            if r["status"] == "ok" and "tag" not in r}
    runnable = [(c.name, s.name) for c, s, _ in pairs()]
    assert len(runnable) == 38
    for mesh in ("16x16", "2x16x16"):
        missing = [(a, s) for a, s in runnable if (a, s, mesh) not in base]
        assert not missing, f"dry-run missing on {mesh}: {missing}"
    errors = [r for r in rows if r["status"] == "error"]
    assert not errors


# ---------------------------------------------------------------------------
# Policies (paper §5.1 heuristics)
# ---------------------------------------------------------------------------

MU_IND = 125.0 * 365.0 * 86400.0


def small_setup():
    n = 2 ** 16
    plat = Platform(mu=MU_IND / n, c=600.0, d=60.0, r=600.0)
    pp = PredictedPlatform(plat, Predictor(0.85, 0.82), 600.0)
    rng = np.random.default_rng(0)
    traces = [make_event_trace(Exponential(1.0), plat.mu, 0.85, 0.82,
                               2e8, np.random.default_rng(i))
              for i in range(3)]
    return plat, pp, traces


def test_strategy_periods_ordering():
    plat, pp, _ = small_setup()
    assert young(plat).period < daly(plat).period
    assert rfo(plat).period < young(plat).period
    s = optimal_prediction(pp)
    assert s.trust.threshold == pytest.approx(600.0 / 0.82)
    assert inexact_prediction(pp).inexact_window == pytest.approx(1200.0)


def test_simple_policy_picks_extreme_q():
    _, pp, _ = small_setup()
    s = simple_policy(pp)
    assert s.name in ("Simple(q=0)", "Simple(q=1)")


@pytest.mark.slow
def test_best_period_improves_or_matches():
    plat, pp, traces = small_setup()
    base = rfo(plat)
    m_base = evaluate(base, traces, plat, 5e6, pp.cp)
    refined, m_best = best_period(base, traces, plat, 5e6, pp.cp,
                                  n_points=8, span=4.0)
    assert m_best <= m_base + 1e-6
    assert refined.name == "BestPeriod(RFO)"
