"""Exact-Exponential analysis (repro.core.exact, arXiv:1207.6936).

Covers the renewal formulas (no-prediction and threshold-policy branches),
the numeric optimizers, the exact trust threshold, the first-order limits
C/mu -> 0, and cross-validation of the exact expected-makespan formulas
against both the scalar and the lane simulation engines.
"""

import math

import numpy as np
import pytest

from repro.core import exact
from repro.core.exact import (ExactPlan, beta_lim_exact,
                              exact_cycle_prediction,
                              expected_cycle_nopred,
                              expected_makespan_exact_nopred,
                              expected_makespan_exact_prediction,
                              minimize_scalar, optimal_period_exact,
                              optimal_period_exact_nopred, repair_time_exact,
                              t_exact_nopred, waste_exact_nopred,
                              waste_exact_prediction)
from repro.core.prediction import (PredictedPlatform, Predictor, beta_lim,
                                   optimal_period_with_prediction, t_pred,
                                   waste1, waste2)
from repro.core.waste import (Platform, expected_makespan_exponential,
                              t_exact_exponential, t_rfo)

MU_IND = 125.0 * 365.0 * 86400.0


def pp(n=2**16, c=600.0, cp=600.0, d=60.0, r=600.0, recall=0.85,
       precision=0.82) -> PredictedPlatform:
    plat = Platform(mu=MU_IND / n, c=c, d=d, r=r)
    return PredictedPlatform(plat, Predictor(recall, precision), cp)


# -- repair + no-prediction branch -------------------------------------------

def test_repair_time_first_order():
    """Exact repair -> D + R as (D+R)/mu -> 0."""
    plat = Platform(mu=1e7, c=600.0, d=60.0, r=600.0)
    assert repair_time_exact(plat) == pytest.approx(660.0, rel=1e-4)
    # Exact value: mu (e^{(D+R)/mu} - 1) > D + R always.
    harsh = Platform(mu=2000.0, c=600.0, d=60.0, r=600.0)
    assert repair_time_exact(harsh) > 660.0


def test_nopred_formula_matches_bougeret_variant():
    """The simulator-faithful formula agrees with the Bougeret et al. form
    of waste.py to O(((D+R)/mu)^2) — they differ only in whether downtime
    is fault-prone."""
    plat = Platform(mu=MU_IND / 2**16, c=600.0, d=60.0, r=600.0)
    t = t_exact_exponential(plat)
    mine = expected_makespan_exact_nopred(t, 1e6, plat)
    theirs = expected_makespan_exponential(t, 1e6, plat)
    assert mine == pytest.approx(theirs, rel=5e-4)


def test_t_exact_nopred_is_argmin():
    """The Lambert-W period minimizes the exact no-prediction waste (the
    repair prefactor is T-free, so it shares waste.py's closed form)."""
    for n in (2**10, 2**16, 2**19):
        plat = Platform(mu=MU_IND / n, c=600.0, d=60.0, r=600.0)
        t0 = t_exact_nopred(plat)
        assert t0 == t_exact_exponential(plat)
        w0 = waste_exact_nopred(t0, plat)
        for t in np.geomspace(plat.c * 1.001, 30 * t0, 200):
            assert waste_exact_nopred(float(t), plat) >= w0 - 1e-12


def test_nopred_period_rejects_degenerate():
    plat = Platform(mu=1e5, c=600.0)
    with pytest.raises(ValueError):
        waste_exact_nopred(plat.c, plat)
    with pytest.raises(ValueError):
        exact_cycle_prediction(plat.c, pp(), beta_lim(pp()))


# -- prediction branch --------------------------------------------------------

def test_never_act_reduces_to_nopred():
    """beta = +inf (or an empty acting region) collapses the prediction
    cycle to the no-prediction renewal pair."""
    ppl = pp()
    t = 2.5 * ppl.platform.c + 4000.0
    ey, ez = exact_cycle_prediction(t, ppl, math.inf)
    lam = 1.0 / ppl.platform.mu
    assert ey == pytest.approx(
        expected_cycle_nopred(t, ppl.platform) * math.exp(-lam * t), rel=1e-12)
    assert ez == pytest.approx(
        (t - ppl.platform.c) * math.exp(-lam * t), rel=1e-12)
    assert waste_exact_prediction(t, ppl, math.inf) == pytest.approx(
        waste_exact_nopred(t, ppl.platform), rel=1e-12)


def test_zero_recall_reduces_to_nopred():
    """With no true predictions and no false-prediction rate the acting
    region is irrelevant: the threshold policy is the plain periodic one."""
    ppl = pp(recall=1e-15)
    t = 9000.0
    assert waste_exact_prediction(t, ppl, beta_lim(ppl)) == pytest.approx(
        waste_exact_nopred(t, ppl.platform), rel=1e-6)
    plan = optimal_period_exact(pp(recall=0.0))
    assert not plan.use_predictions
    assert plan.period == pytest.approx(t_exact_nopred(ppl.platform))


def test_acting_helps_at_paper_scale():
    """At the paper's synthetic scale the exact acting branch beats the
    exact no-prediction branch, like the first-order analysis (§5)."""
    for n in (2**16, 2**19):
        ppl = pp(n=n)
        plan = optimal_period_exact(ppl)
        assert plan.use_predictions
        assert plan.waste < optimal_period_exact_nopred(ppl.platform).waste


def test_optimal_period_exact_beats_grid():
    """(T*, beta*) from the optimizer beats a dense (T, beta) grid."""
    ppl = pp()
    plan = optimal_period_exact(ppl)
    assert isinstance(plan, ExactPlan)
    for t in np.geomspace(ppl.platform.c * 1.01, 40 * plan.period, 120):
        for beta in (ppl.cp, beta_lim(ppl), 2 * beta_lim(ppl), math.inf):
            assert 1.0 - _ratio(float(t), ppl, beta) >= plan.waste - 1e-9


def _ratio(t, ppl, beta):
    ey, ez = exact_cycle_prediction(t, ppl, beta)
    return ez / ey


def test_beta_lim_exact_is_argmin_and_limits():
    """beta* minimizes the exact waste at T, and -> C_p/p as C/mu -> 0."""
    ppl = pp()
    t = t_pred(ppl)
    b_star = beta_lim_exact(ppl, t)
    w_star = waste_exact_prediction(t, ppl, b_star)
    for b in np.linspace(ppl.cp, t, 80):
        assert waste_exact_prediction(t, ppl, float(b)) >= w_star - 1e-12
    rels = []
    for n in (2**19, 2**16, 2**12):
        ppl = pp(n=n)
        rels.append(abs(beta_lim_exact(ppl, t_pred(ppl)) / beta_lim(ppl) - 1))
    assert rels[0] > rels[1] > rels[2]
    assert rels[-1] < 0.01


@pytest.mark.parametrize("metric", ["waste1", "waste2", "t_pred"])
def test_first_order_limit(metric):
    """Exact formulas converge to the first-order model as C/mu -> 0."""
    rels = []
    for n in (2**19, 2**16, 2**12, 2**8):
        ppl = pp(n=n)
        plat = ppl.platform
        if metric == "waste1":
            t = t_rfo(plat)
            rels.append(abs(waste_exact_nopred(t, plat) / waste1(t, ppl) - 1))
        elif metric == "waste2":
            t = t_pred(ppl)
            rels.append(abs(waste_exact_prediction(t, ppl) / waste2(t, ppl)
                            - 1))
        else:
            rels.append(abs(optimal_period_exact(ppl).period / t_pred(ppl)
                            - 1))
    assert all(a >= b for a, b in zip(rels, rels[1:])), rels
    assert rels[-1] < 0.02, rels


def test_exact_waste_above_first_order_never_below_ff():
    """Exact waste stays in (0, 1) and above the fault-free floor C/T on
    the whole admissible range."""
    ppl = pp(n=2**19, c=1800.0, cp=1800.0)
    for t in np.geomspace(ppl.platform.c * 1.01, 30 * ppl.platform.mu, 60):
        w = waste_exact_prediction(float(t), ppl)
        assert ppl.platform.c / t < w < 1.0


# -- numeric optimizer --------------------------------------------------------

def test_minimize_scalar_quadratic():
    x = minimize_scalar(lambda v: (v - 3.25) ** 2, 0.1, 100.0)
    assert x == pytest.approx(3.25, abs=1e-6)
    # Degenerate bracket returns the lower bound.
    assert minimize_scalar(lambda v: v, 5.0, 5.0) == 5.0


def test_minimize_scalar_piecewise_kink():
    """Golden section after a grid scan handles a kinked unimodal f."""
    f = lambda v: abs(v - 7.0) + 0.01 * v
    assert minimize_scalar(f, 0.5, 400.0) == pytest.approx(7.0, abs=1e-4)


# -- engine cross-validation --------------------------------------------------

def test_exact_makespan_matches_both_engines():
    """The exact expected-makespan formulas predict the simulated mean of
    the scalar AND the lane engine within a few percent (both engines
    bit-for-bit equal, so one tolerance covers both)."""
    from repro.core.policies import Strategy
    from repro.core.simulator import NeverTrust, ThresholdTrust
    from repro.experiments import ScenarioSpec, evaluate_strategies

    sc = ScenarioSpec(n_traces=4)
    traces = sc.make_traces()
    plan = optimal_period_exact(sc.pp)
    strategies = [
        Strategy("exact_pred", plan.period, ThresholdTrust(plan.threshold)),
        Strategy("exact_nopred", t_exact_nopred(sc.platform), NeverTrust()),
    ]
    kw = dict(seed=sc.seed, workers=0)
    lane = evaluate_strategies(traces, sc.platform, sc.time_base, sc.cp,
                               strategies, engine="batch", **kw)
    scalar = evaluate_strategies(traces, sc.platform, sc.time_base, sc.cp,
                                 strategies, engine="scalar", **kw)
    assert lane == scalar  # bit-for-bit engine parity
    em_pred = expected_makespan_exact_prediction(
        plan.period, sc.time_base, sc.pp, plan.threshold)
    em_np = expected_makespan_exact_nopred(
        t_exact_nopred(sc.platform), sc.time_base, sc.platform)
    assert em_pred == pytest.approx(lane[0], rel=0.05)
    assert em_np == pytest.approx(lane[1], rel=0.05)


# -- registry / axis integration ---------------------------------------------

def test_model_order_axis_and_strategies():
    from repro.experiments import ScenarioSpec, build_strategy

    sc = ScenarioSpec()
    sce = sc.replace(model_order="exact")
    assert build_strategy("nopred", sc).period == \
        pytest.approx(t_rfo(sc.platform))
    assert build_strategy("nopred", sce).period == \
        pytest.approx(t_exact_nopred(sc.platform))
    t_first, _, _ = optimal_period_with_prediction(sc.pp)
    assert build_strategy("prediction", sc).period == pytest.approx(t_first)
    plan = optimal_period_exact(sc.pp)
    s_exact = build_strategy("prediction", sce)
    assert s_exact.period == pytest.approx(plan.period)
    assert s_exact.trust.threshold == pytest.approx(plan.threshold)
    # Explicit param overrides the scenario axis.
    assert build_strategy("prediction", sc, model_order="exact").period == \
        pytest.approx(plan.period)
    with pytest.raises(ValueError):
        build_strategy("prediction", sc, model_order="bogus")
    with pytest.raises(ValueError):
        ScenarioSpec(model_order="nope")


def test_adaptive_model_order_in_candidate_key():
    """The adaptive planner's model order is part of the result-cache
    candidate key — first and exact adaptive candidates must never alias."""
    from repro.experiments import ScenarioSpec, build_strategy
    from repro.experiments.runner import _candidate_key, _persistable_key

    sc = ScenarioSpec()
    a_first = build_strategy("adaptive", sc)
    a_exact = build_strategy("adaptive", sc.replace(model_order="exact"))
    k1, k2 = _candidate_key(a_first), _candidate_key(a_exact)
    assert k1 != k2
    # key() = (..., halflife, model_order, estimate_mu) since the PR-7
    # online-mu element was appended.
    assert a_first.adaptive.key()[-2] == "first"
    assert a_exact.adaptive.key()[-2] == "exact"
    # Both candidate keys stay persistable (JSON value semantics).
    assert _persistable_key(k1) is not None
    assert _persistable_key(k2) is not None
    assert _persistable_key(k1) != _persistable_key(k2)
