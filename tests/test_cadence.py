"""Post-proactive cadence correction (ROADMAP item 6).

The engines keep the original periodic cadence after a proactive
checkpoint (``simulator._complete_phase``: "Period continues") while
Eq. 15's WASTE2 implicitly restarts the period, so the restart model
overestimates the measured waste at large r/p.  These tests pin the
corrected ``cadence="continue"`` analytic mode against the lane engine
and guard the degenerate regimes.  No hypothesis dependency: this file
must run in tier-1 even without the optional property-test stack.
"""

import numpy as np
import pytest
from numpy.random import default_rng

from repro.core.batch import simulate_lanes
from repro.core.prediction import (PredictedPlatform, Predictor, beta_lim,
                                   cadence_correction,
                                   optimal_period_with_prediction, t_pred,
                                   waste2)
from repro.core.simulator import ThresholdTrust
from repro.core.traces import Exponential, make_event_trace
from repro.core.waste import Platform

MU_IND = 125.0 * 365.0 * 86400.0


def pp(n=2**16, c=600.0, cp=600.0, d=60.0, r=600.0, recall=0.85,
       precision=0.82) -> PredictedPlatform:
    plat = Platform(mu=MU_IND / n, c=c, d=d, r=r)
    return PredictedPlatform(plat, Predictor(recall, precision), cp)


def test_cadence_correction_sign_and_zeros():
    """Continued cadence reduces waste (Delta <= 0); degenerate regimes
    (no acted predictions, recall 0 or 1) have no correction."""
    ppl = pp(recall=0.9, precision=0.9)
    beta = beta_lim(ppl)
    t = t_pred(ppl)
    assert cadence_correction(t, ppl) < 0.0
    assert cadence_correction(beta, ppl) == 0.0          # T <= beta_lim
    assert cadence_correction(beta / 2.0, ppl) == 0.0
    assert cadence_correction(t, pp(recall=0.0, precision=0.9)) == 0.0
    assert cadence_correction(t, pp(recall=1.0, precision=0.9)) == 0.0
    with pytest.raises(ValueError):
        waste2(t, ppl, cadence="sometimes")
    with pytest.raises(ValueError):
        t_pred(ppl, cadence="sometimes")


def test_cadence_restart_unchanged():
    """cadence='restart' is the default and is bit-for-bit the historical
    model: the keyword must not perturb existing analytic results."""
    ppl = pp()
    t = t_pred(ppl)
    assert t_pred(ppl, cadence="restart") == t
    assert waste2(t, ppl, cadence="restart") == waste2(t, ppl)
    assert optimal_period_with_prediction(ppl, cadence="restart") \
        == optimal_period_with_prediction(ppl)


def test_cadence_continue_never_above_restart():
    """The corrected objective sits at or below the restart model for all
    periods past the breakpoint, and coincides below it."""
    ppl = pp(recall=0.9, precision=0.9)
    beta = beta_lim(ppl)
    for t in np.geomspace(ppl.platform.c, 10.0 * ppl.platform.mu, 64):
        t = float(max(t, ppl.platform.c))
        wc = waste2(t, ppl, cadence="continue")
        wr = waste2(t, ppl)
        if t <= beta:
            assert wc == wr
        else:
            assert wc <= wr


def test_cadence_continue_optimum_well_behaved():
    """The numeric continue-cadence optimizer stays in the legal domain
    and its optimum scores at least as well as the restart period under
    the corrected objective."""
    for r, p in [(0.9, 0.9), (0.85, 0.82), (0.95, 0.7)]:
        ppl = pp(recall=r, precision=p, cp=300.0)
        tr = t_pred(ppl)
        tc = t_pred(ppl, cadence="continue")
        lo = max(ppl.platform.c, beta_lim(ppl))
        assert tc >= lo
        assert np.isfinite(tc)
        assert waste2(tc, ppl, cadence="continue") \
            <= waste2(tr, ppl, cadence="continue") + 1e-12


def test_cadence_continue_pins_model_vs_engine_gap():
    """Regression: the continued-cadence model must track the engines far
    better than the restart model at large r/p — the ROADMAP item 6 gap.

    The engines keep the periodic cadence after proactive checkpoints, so
    the measured waste sits *below* WASTE2(restart); cadence='continue'
    closes most of that gap.  Pinned: the corrected model's gap is under
    half the restart model's, and under 0.01 absolute, on two predictor
    cells."""
    plat = Platform(mu=20000.0, c=600.0, r=900.0, d=60.0)
    tb = 2.0e6
    n = 48
    for r, p in [(0.9, 0.9), (0.95, 0.7)]:
        ppl = PredictedPlatform(plat, Predictor(r, p), cp=300.0)
        t = t_pred(ppl)
        traces = [make_event_trace(Exponential(1.0), plat.mu, r, p, 60e6,
                                   default_rng(5000 + i)) for i in range(n)]
        ms = simulate_lanes(traces, plat, tb, cp=ppl.cp,
                            trace_indices=np.arange(n),
                            periods=[t] * n,
                            trusts=[ThresholdTrust(beta_lim(ppl))] * n,
                            windows=[0.0] * n,
                            seeds=np.arange(n))
        mean = float(np.mean(ms))
        w_engine = (mean - tb) / mean
        gap_restart = abs(w_engine - waste2(t, ppl))
        gap_continue = abs(w_engine - waste2(t, ppl, cadence="continue"))
        assert waste2(t, ppl, cadence="continue") < waste2(t, ppl)
        assert gap_continue < 0.5 * gap_restart, \
            f"r={r} p={p}: {gap_continue:.5f} !< 0.5*{gap_restart:.5f}"
        assert gap_continue < 0.01
