"""Serving engine: batched generate, determinism, family coverage."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import REGISTRY
from repro.configs.base import InputShape
from repro.models.model import init_params, make_batch
from repro.serve import ServingEngine

FAMS = ["llama3.2-1b", "recurrentgemma-2b", "xlstm-125m", "qwen2-moe-a2.7b"]


@pytest.fixture(scope="module")
def engines():
    out = {}
    for arch in FAMS:
        cfg = REGISTRY[arch].reduced()
        params, _ = init_params(cfg, jax.random.PRNGKey(0))
        out[arch] = (cfg, ServingEngine(cfg, params, cache_len=48))
    return out


@pytest.mark.parametrize("arch", FAMS)
def test_generate_shapes_and_determinism(arch, engines):
    cfg, engine = engines[arch]
    batch = make_batch(cfg, InputShape("s", 24, 3, "prefill"),
                       jax.random.PRNGKey(1))
    r1 = engine.generate(batch, 8)
    r2 = engine.generate(batch, 8)
    assert r1.tokens.shape == (3, 8)
    assert np.array_equal(np.asarray(r1.tokens), np.asarray(r2.tokens))
    assert bool(jnp.isfinite(r1.logprobs).all())
    assert int(r1.tokens.max()) < cfg.vocab_size


def test_sampling_differs_from_greedy(engines):
    cfg, engine = engines["llama3.2-1b"]
    batch = make_batch(cfg, InputShape("s", 24, 3, "prefill"),
                       jax.random.PRNGKey(2))
    greedy = engine.generate(batch, 12)
    hot = engine.generate(batch, 12, temperature=1.5, seed=9)
    assert not np.array_equal(np.asarray(greedy.tokens),
                              np.asarray(hot.tokens))


def test_sampled_logprobs_are_of_sampled_tokens(engines):
    cfg, engine = engines["llama3.2-1b"]
    batch = make_batch(cfg, InputShape("s", 16, 2, "prefill"),
                       jax.random.PRNGKey(3))
    res = engine.generate(batch, 4, temperature=0.9, seed=1)
    assert float(res.logprobs.max()) <= 0.0


def test_encoder_rejected():
    cfg = REGISTRY["hubert-xlarge"].reduced()
    params, _ = init_params(cfg, jax.random.PRNGKey(0))
    with pytest.raises(ValueError):
        ServingEngine(cfg, params)
