"""Silent-error + verification family (arXiv:1310.8486; ISSUE 10).

Covers the analytic layer (``repro.core.silent``: combined waste, joint
(T*, k*) plans, domain guards), the engine mechanics (latent corruption,
retained-checkpoint ring, deep rollbacks, the verify accrual), the
scalar-vs-lane bit-for-bit contract on silent traces, the obs ``verify``
bucket closure, the ScenarioSpec axis round-trip, and the two-level
engine's cross-validation against the scalar oracle on shared
trace-machinery streams.  The hypothesis property degrades to a skip
when the optional dependency is missing; everything else runs in tier-1.
"""

import math

import numpy as np
import pytest
from numpy.random import default_rng

from repro.core.batch import simulate_lanes
from repro.core.multilevel import (TwoLevelPlatform, simulate_two_level,
                                   two_level_stream)
from repro.core.prediction import PredictedPlatform, Predictor, beta_lim
from repro.core.silent import (DEFAULT_KEEP_CKPTS, SilentPlan,
                               optimal_silent_plan, optimal_silent_pred_plan,
                               silent_strategy, t_silent, t_silent_pred,
                               waste_silent, waste_silent_pred)
from repro.core.simulator import (AlwaysTrust, NeverTrust, ThresholdTrust,
                                  simulate)
from repro.core.traces import (SILENT, EventTrace, Exponential,
                               make_event_trace)
from repro.core.waste import Platform, t_rfo, waste
from repro.obs import attribute_result

PLAT = Platform(mu=50_000.0, c=600.0, r=900.0, d=60.0)
SMU = 20_000.0
V = 100.0


def silent_traces(n, horizon=8e6, silent_mu=SMU, recall=0.85,
                  precision=0.82, base_seed=100):
    return [make_event_trace(Exponential(1.0), PLAT.mu, recall, precision,
                             horizon, default_rng(base_seed + i),
                             silent_mu=silent_mu)
            for i in range(n)]


# ---------------------------------------------------------------------------
# Analytic layer: collapse, argmin, domain guards (satellite 3)
# ---------------------------------------------------------------------------

def test_waste_silent_collapses_to_failstop():
    """silent rate 0 + k = 0 is bit-for-bit Eq. 11/12."""
    for t in (2000.0, 7000.0, 20_000.0):
        assert waste_silent(t, 0, PLAT, None) == waste(t, PLAT)
        assert waste_silent(t, 0, PLAT, math.inf) == waste(t, PLAT)


def test_waste_silent_domain_guards():
    with pytest.raises(ValueError):
        waste_silent(PLAT.c / 2.0, 1, PLAT, SMU, V)      # T < C
    with pytest.raises(ValueError):
        waste_silent(5000.0, 0, PLAT, SMU, V)            # k=0, rate > 0
    with pytest.raises(ValueError):
        waste_silent(5000.0, 8, PLAT, SMU, 700.0)        # k*V >= T
    with pytest.raises(ValueError):
        waste_silent(5000.0, 1, PLAT, -10.0, V)          # bad rate
    with pytest.raises(ValueError):
        waste_silent(5000.0, 1, PLAT, SMU, -1.0)         # bad cost
    with pytest.raises(ValueError):
        waste_silent(5000.0, 1, PLAT, SMU, math.inf)     # bad cost
    with pytest.raises(ValueError):
        waste_silent(5000.0, -1, PLAT, SMU, V)           # bad k


def test_t_silent_is_argmin():
    for k in (1, 2, 4, 8):
        t_star = t_silent(k, PLAT, SMU, V)
        w_star = waste_silent(t_star, k, PLAT, SMU, V)
        for f in (0.8, 0.9, 1.1, 1.25):
            assert waste_silent(t_star * f, k, PLAT, SMU, V) >= w_star - 1e-12


def test_optimal_silent_plan_structure():
    plan = optimal_silent_plan(PLAT, SMU, V)
    assert isinstance(plan, SilentPlan)
    assert plan.n_verify >= 1
    assert plan.keep_ckpts == DEFAULT_KEEP_CKPTS
    assert plan.period >= PLAT.c
    assert plan.n_verify * V < plan.period
    # Neighbour k values cannot beat the scan winner.
    for k in (plan.n_verify - 1, plan.n_verify + 1):
        if k < 1:
            continue
        t = t_silent(k, PLAT, SMU, V)
        if k * V < t:
            assert waste_silent(t, k, PLAT, SMU, V) >= plan.waste - 1e-12


def test_optimal_silent_plan_rate0_is_rfo():
    plan = optimal_silent_plan(PLAT, None, V)
    assert plan.n_verify == 0
    assert plan.keep_ckpts == 1
    assert plan.period == max(PLAT.c, t_rfo(PLAT))
    assert plan.waste == waste(plan.period, PLAT)


def test_optimal_silent_plan_infeasible_cost_raises():
    """A verify cost that swallows every candidate period must raise, not
    return a NaN plan (the PR-3 beta_lim < C guard, mirrored)."""
    tiny = Platform(mu=300.0, c=100.0, r=50.0, d=5.0)
    with pytest.raises(ValueError, match="feasible"):
        optimal_silent_plan(tiny, 50.0, 5_000.0)
    with pytest.raises(ValueError):
        optimal_silent_plan(PLAT, SMU, V, k_max=0)
    with pytest.raises(ValueError):
        optimal_silent_plan(PLAT, SMU, V, keep_ckpts=0)


def test_silent_pred_guards_and_bounds():
    pp = PredictedPlatform(PLAT, Predictor(0.85, 0.82), cp=300.0)
    with pytest.raises(ValueError):
        optimal_silent_pred_plan(pp, None, V)            # rate-0: wrong API
    with pytest.raises(ValueError):
        t_silent_pred(0, pp, SMU, V)                     # k < 1
    with pytest.raises(ValueError):
        waste_silent_pred(5000.0, 8, pp, SMU, 700.0)     # k*V >= T
    # beta_lim < C degenerate cp: lower bound must clamp at C, not below.
    pp_deg = PredictedPlatform(PLAT, Predictor(0.85, 0.82), cp=60.0)
    assert beta_lim(pp_deg) < PLAT.c
    for k in (1, 2, 4):
        assert t_silent_pred(k, pp_deg, SMU, V) >= PLAT.c
    plan = optimal_silent_pred_plan(pp, SMU, V)
    assert plan.use_predictions
    assert plan.n_verify >= 1
    assert plan.period >= max(PLAT.c, beta_lim(pp))


def test_waste_monotone_in_verify_cost_analytic():
    """Exact analytic monotonicity: both the fixed-(T, k) waste and the
    optimized plan waste are non-increasing as verify_cost -> 0."""
    costs = [400.0, 200.0, 100.0, 25.0, 5.0, 0.0]
    t, k = 8000.0, 3
    fixed = [waste_silent(t, k, PLAT, SMU, v) for v in costs]
    assert all(a >= b for a, b in zip(fixed, fixed[1:]))
    plans = [optimal_silent_plan(PLAT, SMU, v).waste for v in costs]
    assert all(a >= b - 1e-15 for a, b in zip(plans, plans[1:]))


def test_silent_strategy_modes():
    pp = PredictedPlatform(PLAT, Predictor(0.85, 0.82), cp=300.0)
    ign = silent_strategy(PLAT, SMU, V, mode="ignore")
    assert ign.n_verify == 0 and isinstance(ign.trust, NeverTrust)
    ver = silent_strategy(PLAT, SMU, V, mode="verify")
    assert ver.n_verify >= 1 and ver.keep_ckpts == DEFAULT_KEEP_CKPTS
    vp = silent_strategy(PLAT, SMU, V, mode="verify_pred", pp=pp)
    assert vp.n_verify >= 1 and isinstance(vp.trust, ThresholdTrust)
    # rate 0 verify_pred falls back to the prediction-only optimum.
    vp0 = silent_strategy(PLAT, None, V, mode="verify_pred", pp=pp)
    assert vp0.n_verify == 0 and vp0.name == "SilentVerifyPred"
    with pytest.raises(ValueError):
        silent_strategy(PLAT, SMU, V, mode="sometimes")
    with pytest.raises(ValueError):
        silent_strategy(PLAT, SMU, V, mode="verify_pred")  # pp missing


# ---------------------------------------------------------------------------
# Engine mechanics: scalar oracle
# ---------------------------------------------------------------------------

def test_scalar_verification_detects_and_accounts():
    """A verified run on a silent trace detects corruptions, accrues the
    verify bucket, and closes the attribution sum exactly."""
    tr = silent_traces(1)[0]
    res = simulate(tr, PLAT, 2.4e6, 8000.0, trust=NeverTrust(),
                   n_verify=3, verify_cost=V, keep_ckpts=2,
                   rng=default_rng(0))
    assert res.n_verifications > 0
    assert res.time_verify > 0.0
    assert res.n_silent > 0
    att = attribute_result(res)
    assert att.verify == res.time_verify
    assert att.total() == res.makespan          # bit-for-bit closure
    exp_ms = (res.time_base + res.time_ckpt + res.time_prockpt
              + res.time_lost + res.time_down + res.time_verify)
    assert res.makespan == pytest.approx(exp_ms, rel=1e-12)


def test_keep_ring_depth_protects_against_dirty_saves():
    """A silent strike just before a checkpoint write makes that snapshot
    dirty; keep_ckpts=1 then evicts the only clean one (restart from 0 on
    detection) while keep_ckpts=2 rolls back one period — a deep rollback
    with strictly smaller makespan."""
    t, k = 4000.0, 1
    # One silent corruption striking inside the *second* checkpoint write
    # (the guarding verification has already passed, so the snapshot is
    # written dirty); no fail-stop faults.  Period = work (T - C) +
    # verify + ckpt, so ckpt 2 spans
    # [2(T-C) + 2V + C, 2(T-C) + 2V + 2C].
    strike = 2.0 * (t - PLAT.c) + 2.0 * V + PLAT.c + 300.0
    tr = EventTrace(np.array([strike]), np.array([SILENT], np.int8), 1e9)
    kw = dict(trust=NeverTrust(), n_verify=k, verify_cost=V)
    r1 = simulate(tr, PLAT, 20_000.0, t, keep_ckpts=1, rng=default_rng(0),
                  **kw)
    r2 = simulate(tr, PLAT, 20_000.0, t, keep_ckpts=2, rng=default_rng(0),
                  **kw)
    assert r1.n_silent == r2.n_silent == 1
    # Both detect past a dirty snapshot; but keep=1 has evicted its only
    # clean one (restart from 0) while keep=2 rolls back one period.
    assert r1.n_deep_rollbacks >= 1 and r2.n_deep_rollbacks >= 1
    assert r2.makespan < r1.makespan
    assert r1.time_lost > r2.time_lost


def test_silent_free_trace_unchanged_by_ring_depth():
    """keep_ckpts is inert without corruption: silent-free runs are
    bit-for-bit identical for any ring depth (the rate-0 collapse)."""
    tr = make_event_trace(Exponential(1.0), PLAT.mu, 0.85, 0.82, 8e6,
                          default_rng(7))
    base = simulate(tr, PLAT, 2.4e6, 8000.0, trust=NeverTrust(),
                    rng=default_rng(0))
    for keep in (2, 5):
        again = simulate(tr, PLAT, 2.4e6, 8000.0, trust=NeverTrust(),
                         keep_ckpts=keep, rng=default_rng(0))
        assert again.makespan == base.makespan
        assert again.n_deep_rollbacks == 0


# ---------------------------------------------------------------------------
# Scalar-vs-lane bit-for-bit parity on silent lanes (every run)
# ---------------------------------------------------------------------------

def test_lane_engine_matches_scalar_on_silent_lanes():
    traces = silent_traces(4)
    pp = PredictedPlatform(PLAT, Predictor(0.85, 0.82), cp=300.0)
    configs = [
        dict(period=8000.0, trust=NeverTrust(), nv=0, vc=0.0, keep=1),
        dict(period=8000.0, trust=NeverTrust(), nv=2, vc=100.0, keep=2),
        dict(period=6500.0, trust=AlwaysTrust(), nv=1, vc=50.0, keep=3),
        dict(period=9000.0, trust=ThresholdTrust(beta_lim(pp)), nv=4,
             vc=25.0, keep=2),
    ]
    items = [(ci, ti) for ci in range(len(configs))
             for ti in range(len(traces))]
    lane = simulate_lanes(
        traces, PLAT, 2.4e6, cp=300.0,
        trace_indices=np.array([ti for _, ti in items]),
        periods=[configs[ci]["period"] for ci, _ in items],
        trusts=[configs[ci]["trust"] for ci, _ in items],
        windows=[0.0] * len(items),
        n_verifies=[configs[ci]["nv"] for ci, _ in items],
        verify_costs=[configs[ci]["vc"] for ci, _ in items],
        keep_ckpts=[configs[ci]["keep"] for ci, _ in items],
        seeds=[7919 * ti for _, ti in items])
    for j, (ci, ti) in enumerate(items):
        cfg = configs[ci]
        want = simulate(traces[ti], PLAT, 2.4e6, cfg["period"], cp=300.0,
                        trust=cfg["trust"], n_verify=cfg["nv"],
                        verify_cost=cfg["vc"], keep_ckpts=cfg["keep"],
                        rng=default_rng(7919 * ti)).makespan
        assert lane[j] == want, f"lane {j} (config {ci}, trace {ti})"


# ---------------------------------------------------------------------------
# Simulated monotonicity (hypothesis; optional dep -> skip)
# ---------------------------------------------------------------------------

def test_simulated_waste_monotone_as_verify_cost_shrinks():
    """Mean simulated makespan over a trace bank is monotone non-increasing
    as verify_cost -> 0 (fixed T, k, seeds; per-trace makespans are NOT
    monotone — cheaper verifications shift every later phase boundary — so
    the property averages over traces with a small tolerance)."""
    hyp = pytest.importorskip("hypothesis")
    given, settings, st = hyp.given, hyp.settings, hyp.strategies

    traces = silent_traces(16, base_seed=300)
    n = len(traces)

    def mean_makespan(vc: float) -> float:
        ms = simulate_lanes(
            traces, PLAT, 2.4e6, cp=PLAT.c,
            trace_indices=np.arange(n),
            periods=[8000.0] * n, trusts=[NeverTrust()] * n,
            windows=[0.0] * n, n_verifies=[2] * n,
            verify_costs=[vc] * n, keep_ckpts=[2] * n,
            seeds=np.arange(n))
        return float(np.mean(ms))

    @given(st.floats(0.0, 300.0), st.floats(0.0, 300.0))
    @settings(max_examples=10, deadline=None, derandomize=True)
    def prop(v1, v2):
        lo, hi = sorted((v1, v2))
        assert mean_makespan(lo) <= mean_makespan(hi) * (1.0 + 1e-3)

    prop()


# ---------------------------------------------------------------------------
# ScenarioSpec axis round-trip + trace plumbing
# ---------------------------------------------------------------------------

def test_scenario_silent_axis_roundtrip_and_traces():
    from repro.experiments import ScenarioSpec

    spec = ScenarioSpec(n=2 ** 16, c=600.0, d=60.0, r=600.0, n_traces=2,
                        time_base_years_total=2000.0, seed=5,
                        silent_mu_ind=2.0e9, verify_cost=120.0,
                        n_verify=2, keep_ckpts=2)
    back = ScenarioSpec.from_dict(spec.to_dict())
    assert back == spec
    assert spec.silent_mu == pytest.approx(2.0e9 / 2 ** 16)
    traces = spec.make_traces()
    assert any((tr.kinds == SILENT).any() for tr in traces)
    # The silent-free spec from the same seed carries no SILENT events.
    off = ScenarioSpec(n=2 ** 16, c=600.0, d=60.0, r=600.0, n_traces=2,
                       time_base_years_total=2000.0, seed=5)
    assert off.silent_mu is None
    assert not any((tr.kinds == SILENT).any() for tr in off.make_traces())
    with pytest.raises(ValueError):
        ScenarioSpec(n=2 ** 16, silent_mu_ind=-1.0)
    with pytest.raises(ValueError):
        ScenarioSpec(n=2 ** 16, verify_cost=-1.0)
    with pytest.raises(ValueError):
        ScenarioSpec(n=2 ** 16, n_verify=-1)
    with pytest.raises(ValueError):
        ScenarioSpec(n=2 ** 16, keep_ckpts=0)


def test_registry_silent_strategies_run_end_to_end():
    from repro.experiments import ScenarioSpec, StrategySpec
    from repro.experiments.runner import EvalCache, evaluate_strategies

    spec = ScenarioSpec(n=2 ** 16, c=600.0, d=60.0, r=600.0, n_traces=2,
                        time_base_years_total=2000.0, seed=5,
                        silent_mu_ind=2.0e9, verify_cost=120.0,
                        keep_ckpts=2)
    strategies = [StrategySpec(s).build(spec)
                  for s in ("silent_ignore", "silent_verify",
                            "silent_verify_pred")]
    traces = spec.make_traces()
    res = {}
    for engine in ("scalar", "batch"):
        res[engine] = evaluate_strategies(
            traces, spec.platform, spec.time_base, spec.cp, strategies,
            seed=spec.seed, cache=EvalCache(), engine=engine)
    assert res["scalar"] == res["batch"]
    ignore, verify, _ = res["scalar"]
    assert verify < ignore  # verification pays for itself at this rate


# ---------------------------------------------------------------------------
# Two-level engine cross-validation (satellite 2)
# ---------------------------------------------------------------------------

def test_two_level_degenerate_limit_matches_scalar_bitforbit():
    """k=1 with c1=c2, r1=r2 collapses the hierarchy: soft and hard faults
    both roll back to the last (only-level) checkpoint, so the two-level
    engine must reproduce the scalar oracle bit-for-bit on shared streams
    — including faults landing inside the downtime + recovery window
    (the boundary this PR fixed) and inside checkpoint writes."""
    tb = 200_000.0
    t1 = 1500.0
    for phi in (0.0, 0.5, 1.0):
        p2 = TwoLevelPlatform(mu=3000.0, phi=phi, c1=100.0, c2=100.0,
                              r1=120.0, r2=120.0, d=15.0)
        p1 = Platform(mu=3000.0, c=100.0, d=15.0, r=120.0)
        for seed in range(8):
            ft, soft = two_level_stream(p2, 40.0 * tb, default_rng(seed))
            got = simulate_two_level(ft, soft, p2, tb, t1, 1)
            tr = EventTrace(ft, np.zeros(len(ft), np.int8), 40.0 * tb)
            want = simulate(tr, p1, tb, t1, trust=NeverTrust())
            assert got.makespan == want.makespan, (phi, seed)
            assert got.n_soft + got.n_hard == want.n_faults_hit, (phi, seed)


def test_two_level_stream_matches_hand_rolled_law():
    """The make_event_trace-routed stream has the advertised law: total
    rate ~ 1/mu, soft fraction ~ phi."""
    p = TwoLevelPlatform(mu=1000.0, phi=0.7, c1=10.0, c2=100.0,
                         r1=10.0, r2=100.0, d=5.0)
    ft, soft = two_level_stream(p, 4e6, default_rng(0))
    assert np.all(np.diff(ft) > 0)
    assert len(ft) == pytest.approx(4e6 / p.mu, rel=0.1)
    assert float(np.mean(soft)) == pytest.approx(p.phi, abs=0.05)
    # Degenerate fractions: one stream only.
    ft0, soft0 = two_level_stream(
        TwoLevelPlatform(mu=1000.0, phi=0.0, c1=10.0, c2=100.0,
                         r1=10.0, r2=100.0, d=5.0), 2e6, default_rng(1))
    assert not soft0.any() and len(ft0) > 0
    ft1, soft1 = two_level_stream(
        TwoLevelPlatform(mu=1000.0, phi=1.0, c1=10.0, c2=100.0,
                         r1=10.0, r2=100.0, d=5.0), 2e6, default_rng(2))
    assert soft1.all() and len(ft1) > 0
