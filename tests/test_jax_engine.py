"""Flagship jax engine suite: feature parity, scale paths, kernel impls.

Every test here asserts the engines' **bit-for-bit equivalence contract**:
the jax lane engine replays the exact float64 operation sequence of the
NumPy lane engine (itself pinned to the scalar reference), so results are
compared with ``==`` — never ``allclose`` — across the full candidate
matrix (all four trust families x instant/within window modes x per-event
windows x adaptive re-planning incl. online-mu and the exact model) and
across every execution plan (chunked, sharded, Pallas-interpreted).

The contract needs float64, so the whole module skips unless x64 is on —
run it as ``JAX_ENABLE_X64=1 python -m pytest tests/test_jax_engine.py``
(the CI jax-engine job does exactly that).  The always-on subprocess
checks live in tests/test_batch_engine.py and tests/test_golden_parity.py.
"""

import dataclasses

import numpy as np
import pytest

jax = pytest.importorskip("jax")
if not jax.config.jax_enable_x64:
    pytestmark = pytest.mark.skip(
        reason="jax x64 disabled; run with JAX_ENABLE_X64=1")

from repro.core.batch import BatchResult, simulate_batch, simulate_lanes
from repro.core.policies import Strategy
from repro.core.simulator import (AlwaysTrust, FixedProbabilityTrust,
                                  NeverTrust, ThresholdTrust)
from repro.core.traces import (FALSE_PRED, FAULT_PRED, FAULT_UNPRED,
                               EventTrace, Exponential, make_event_trace)
from repro.core.waste import Platform
from repro.experiments.runner import (_cell_persist_key, evaluate_strategies)
from repro.experiments.spec import ScenarioSpec
from repro.predictors import AdaptiveConfig

PLAT = Platform(mu=2500.0, c=60.0, d=10.0, r=30.0)
TIME_BASE = 120000.0
PERIODS = [1200.0, 2500.0]
SEEDS = [5, 6, 7]


def _traces(seeds=(20, 21, 22), horizon=400000.0):
    return [make_event_trace(Exponential(2500.0), 2500.0, 0.7, 0.6, horizon,
                             np.random.default_rng(s)) for s in seeds]


def _run(traces, backend, **kw):
    kw.setdefault("cp", 30.0)
    kw.setdefault("trace_seeds", SEEDS[:len(traces)])
    return simulate_batch(traces, PLAT, TIME_BASE, PERIODS,
                          backend=backend, **kw)


def _assert_bitwise(a: BatchResult, b: BatchResult, tag: str) -> None:
    for f in dataclasses.fields(a):
        va, vb = getattr(a, f.name), getattr(b, f.name)
        if not isinstance(va, np.ndarray):
            continue
        assert (va == vb).all(), \
            f"{tag}: field {f.name} diverged (bitwise contract broken)"


# ---------------------------------------------------------------------------
# Feature parity: the full candidate matrix, jax vs numpy, bit-for-bit
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("trust", [
    NeverTrust(), AlwaysTrust(), ThresholdTrust(100.0),
    FixedProbabilityTrust(0.6),
], ids=["never", "always", "threshold", "fixed_q"])
@pytest.mark.parametrize("wmode", ["instant", "within"])
def test_trust_matrix_matches_numpy(trust, wmode):
    traces = _traces()
    kw = dict(trust=trust, inexact_window=300.0, window_mode=wmode)
    if wmode == "within":
        kw["window_period"] = 100.0
    _assert_bitwise(_run(traces, "numpy", **kw), _run(traces, "jax", **kw),
                    f"{type(trust).__name__}/{wmode}")


def test_per_event_windows_match_numpy():
    """Traces carrying per-event window lengths (mixed with -1 fallback
    sentinels and zero-width exact dates) drive the same window arming."""
    def win_trace(seed):
        r = np.random.default_rng(seed)
        n = 120
        times = np.sort(r.uniform(0, 300000.0, n))
        kinds = r.choice([FAULT_UNPRED, FAULT_PRED, FALSE_PRED], n,
                         p=[0.3, 0.4, 0.3]).astype(np.int8)
        wins = r.choice([-1.0, 0.0, 250.0, 600.0], n).astype(np.float64)
        return EventTrace(times, kinds, 400000.0, wins)

    traces = [win_trace(s) for s in (10, 11, 12)]
    for kw in (dict(trust=AlwaysTrust(), inexact_window=300.0),
               dict(trust=ThresholdTrust(100.0), inexact_window=300.0,
                    window_mode="within", window_period=100.0)):
        _assert_bitwise(_run(traces, "numpy", **kw),
                        _run(traces, "jax", **kw), "per-event windows")


@pytest.mark.parametrize("ad", [
    AdaptiveConfig(prior_recall=0.5, prior_precision=0.5, min_preds=8,
                   min_faults=4, tol=0.02),
    AdaptiveConfig(prior_recall=0.5, prior_precision=0.5, min_preds=8,
                   min_faults=4, tol=0.02, halflife=64.0),
    AdaptiveConfig(prior_recall=0.5, prior_precision=0.5, min_preds=8,
                   min_faults=4, tol=0.02, estimate_mu=True),
    AdaptiveConfig(prior_recall=0.5, prior_precision=0.5, min_preds=8,
                   min_faults=4, tol=0.02, model_order="exact"),
], ids=["plain", "halflife", "estimate_mu", "exact_model"])
def test_adaptive_matches_numpy(ad):
    traces = _traces()
    kw = dict(trust=ThresholdTrust(100.0), inexact_window=300.0, adaptive=ad)
    np_res = _run(traces, "numpy", **kw)
    jx_res = _run(traces, "jax", **kw)
    _assert_bitwise(np_res, jx_res, f"adaptive/{ad.key()}")
    assert (np_res.n_replans > 0).any(), "scenario never replanned: inert test"


def test_adaptive_mu_within_window_combo():
    """The heaviest candidate: online mu + EW decay + within-windows —
    every estimator counter and the window machinery active at once."""
    ad = AdaptiveConfig(prior_recall=0.5, prior_precision=0.5, min_preds=8,
                        min_faults=4, tol=0.02, halflife=64.0,
                        estimate_mu=True)
    traces = _traces()
    kw = dict(trust=ThresholdTrust(100.0), inexact_window=300.0,
              window_mode="within", window_period=100.0, adaptive=ad)
    np_res = _run(traces, "numpy", **kw)
    jx_res = _run(traces, "jax", **kw)
    _assert_bitwise(np_res, jx_res, "adaptive mu+hl+within")
    assert np_res.est_mu is not None and (np_res.est_mu > 0).any()


# ---------------------------------------------------------------------------
# Execution plans: chunking, sharding, Pallas — same bits, different plan
# ---------------------------------------------------------------------------

def test_chunked_matches_unchunked(monkeypatch):
    traces = _traces()
    kw = dict(trust=ThresholdTrust(100.0), inexact_window=300.0)
    ref = _run(traces, "jax", **kw)
    for chunk in ("1", "4", "5"):
        monkeypatch.setenv("REPRO_JAX_CHUNK", chunk)
        _assert_bitwise(ref, _run(traces, "jax", **kw), f"chunk={chunk}")


def test_forced_shard_matches(monkeypatch):
    traces = _traces()
    kw = dict(trust=ThresholdTrust(100.0), inexact_window=300.0)
    ref = _run(traces, "jax", **kw)
    monkeypatch.setenv("REPRO_JAX_SHARD", "1")
    _assert_bitwise(ref, _run(traces, "jax", **kw), "shard=1")
    monkeypatch.setenv("REPRO_JAX_CHUNK", "4")
    _assert_bitwise(ref, _run(traces, "jax", **kw), "shard=1 chunk=4")


def test_adaptive_chunked_matches(monkeypatch):
    """Adaptive grids replan through a host callback per chunk; chunking
    must not change where replans land."""
    ad = AdaptiveConfig(prior_recall=0.5, prior_precision=0.5, min_preds=8,
                        min_faults=4, tol=0.02)
    traces = _traces()
    kw = dict(trust=ThresholdTrust(100.0), inexact_window=300.0, adaptive=ad)
    ref = _run(traces, "jax", **kw)
    monkeypatch.setenv("REPRO_JAX_CHUNK", "4")
    _assert_bitwise(ref, _run(traces, "jax", **kw), "adaptive chunk=4")


def test_pallas_interpret_matches(monkeypatch):
    """The Pallas event-step kernel (interpreter mode on CPU) is drop-in
    for the jnp reference inside the engine loop."""
    traces = _traces()
    kw = dict(trust=ThresholdTrust(100.0), inexact_window=300.0)
    ref = _run(traces, "jax", **kw)
    monkeypatch.setenv("REPRO_JAX_PALLAS", "interpret")
    _assert_bitwise(ref, _run(traces, "jax", **kw), "pallas interpret")


def test_event_step_pallas_interpret_matches_ref():
    """Direct kernel check: the Pallas event-step (interpreter mode) is
    bitwise identical to the jnp reference on arbitrary stacked state,
    including a lane count that is not a multiple of the block size."""
    import jax.numpy as jnp
    from repro.kernels.event_step import N_F, N_I, event_step

    r = np.random.default_rng(0)
    n = 300
    fs = jnp.asarray(r.uniform(0.0, 5000.0, (N_F, n)))
    is_ = jnp.asarray(
        np.stack([r.integers(0, 5, n), r.integers(0, 2, n),
                  r.integers(0, 40, n), r.integers(0, 40, n)]
                 ).astype(np.int32))
    assert is_.shape == (N_I, n)    # phase/finished/periodic/proactive
    kw = dict(c=60.0, cp=30.0, d=10.0, r=30.0, time_base=120000.0)
    f_ref, i_ref = event_step(fs, is_, impl="ref", **kw)
    f_pl, i_pl = event_step(fs, is_, impl="pallas_interpret", **kw)
    assert (np.asarray(f_ref) == np.asarray(f_pl)).all()
    assert (np.asarray(i_ref) == np.asarray(i_pl)).all()
    with pytest.raises(ValueError, match="impl"):
        event_step(fs, is_, impl="cuda", **kw)


def test_pallas_env_rejects_garbage(monkeypatch):
    monkeypatch.setenv("REPRO_JAX_PALLAS", "gpu?!")
    with pytest.raises(ValueError, match="REPRO_JAX_PALLAS"):
        _run(_traces(), "jax", trust=NeverTrust())


def test_deferred_overflow_raises():
    """More in-flight deferred fault dates than the engine's fixed slot
    capacity must fail loudly (numpy handles the same trace fine)."""
    n = 12  # > _DEF_SLOTS overlapping armed windows
    times = 1000.0 + 10.0 * np.arange(n)
    trace = EventTrace(times, np.full(n, FAULT_PRED, dtype=np.int8), 1e7,
                       np.full(n, 1e6))
    kw = dict(cp=30.0, trust=AlwaysTrust(), trace_seeds=[3])
    simulate_batch([trace], PLAT, TIME_BASE, [1200.0], **kw)  # numpy: fine
    with pytest.raises(RuntimeError, match="deferred-fault capacity"):
        simulate_batch([trace], PLAT, TIME_BASE, [1200.0], backend="jax",
                       **kw)


def test_simulate_lanes_backend_jax():
    traces = _traces()
    args = dict(cp=30.0, trace_indices=[0, 1, 2, 0],
                periods=[1200.0, 1500.0, 2500.0, 1200.0],
                trusts=[NeverTrust(), AlwaysTrust(), ThresholdTrust(100.0),
                        FixedProbabilityTrust(0.6)],
                windows=[0.0, 300.0, 300.0, 300.0],
                window_modes=["instant", "instant", "within", "instant"],
                window_periods=[0.0, 0.0, 100.0, 0.0],
                seeds=[5, 6, 7, 8])
    ms_np = simulate_lanes(traces, PLAT, TIME_BASE, **args)
    ms_jx = simulate_lanes(traces, PLAT, TIME_BASE, backend="jax", **args)
    assert list(ms_np) == list(ms_jx)


# ---------------------------------------------------------------------------
# Runner integration: engine="jax" dispatch + cache identity
# ---------------------------------------------------------------------------

def test_runner_engine_jax_matches_auto():
    traces = _traces(seeds=(1, 2))
    strats = [
        Strategy(name="thr", period=1500.0, trust=ThresholdTrust(100.0),
                 inexact_window=300.0),
        Strategy(name="q", period=2000.0, trust=FixedProbabilityTrust(0.5),
                 inexact_window=300.0, window_mode="within",
                 window_period=100.0),
    ]
    auto = evaluate_strategies(traces, PLAT, TIME_BASE, 30.0, strats,
                               engine="auto")
    jx = evaluate_strategies(traces, PLAT, TIME_BASE, 30.0, strats,
                             engine="jax")
    assert auto == jx


def test_runner_engine_jax_is_strict():
    traces = _traces(seeds=(1,))
    dyn = [Strategy(name="d", period=lambda rp: 1500.0,
                    trust=NeverTrust())]
    with pytest.raises(ValueError, match="engine='jax'"):
        evaluate_strategies(traces, PLAT, TIME_BASE, 30.0, dyn, engine="jax")


def test_cache_key_fingerprints_jax_engine():
    """jax results live under their own persist key (device identity);
    the numpy-family engines keep sharing one store."""
    cell = ScenarioSpec()
    k_auto = _cell_persist_key(cell, False, "auto")
    assert _cell_persist_key(cell, False, "batch") == k_auto
    assert _cell_persist_key(cell, False, "scalar") == k_auto
    assert _cell_persist_key(cell, False, "jax") != k_auto
