"""Paper Tables 3-5: job execution times under the five heuristics.

Grid: {Exponential, Weibull k=0.7, Weibull k=0.5} x {2^16, 2^19 processors}
x {good, fair} predictors, C_p = C.  Reports execution time in days and the
gain of OptimalPrediction / InexactPrediction over RFO, next to the paper's
numbers.  ``--quick`` trims the trace count (the paper averages 100 runs;
the trend, not the third digit, is the reproduction target).
"""

from __future__ import annotations

from repro.core.traces import Exponential, Weibull

from .common import PREDICTORS, Scenario, gain, run_scenario

# Paper values (days): {(dist, n_exp, predictor): {strategy: days}}
PAPER = {
    ("exp", 16, "good"): {"RFO": 65.2, "OptimalPrediction": 60.0,
                          "InexactPrediction": 60.6},
    ("exp", 19, "good"): {"RFO": 11.7, "OptimalPrediction": 9.5,
                          "InexactPrediction": 10.2},
    ("exp", 16, "fair"): {"RFO": 65.2, "OptimalPrediction": 61.7},
    ("exp", 19, "fair"): {"RFO": 11.7, "OptimalPrediction": 10.7},
    ("w07", 16, "good"): {"RFO": 80.3, "OptimalPrediction": 65.9,
                          "InexactPrediction": 68.0},
    ("w07", 19, "good"): {"RFO": 25.5, "OptimalPrediction": 15.9},
    ("w07", 16, "fair"): {"RFO": 80.3, "OptimalPrediction": 69.7},
    ("w07", 19, "fair"): {"RFO": 25.5, "OptimalPrediction": 20.2},
    ("w05", 16, "good"): {"RFO": 120.2, "OptimalPrediction": 75.9},
    ("w05", 19, "good"): {"RFO": 114.8, "OptimalPrediction": 39.5},
    ("w05", 16, "fair"): {"RFO": 120.2, "OptimalPrediction": 83.0},
    ("w05", 19, "fair"): {"RFO": 114.8, "OptimalPrediction": 60.8},
}

DISTS = {
    "exp": lambda: Exponential(1.0),
    "w07": lambda: Weibull(0.7, 1.0),
    "w05": lambda: Weibull(0.5, 1.0),
}


def run(quick: bool = True) -> list[dict]:
    n_runs = 5 if quick else 40
    n_exps = [16, 19]
    rows = []
    for dist_name, dist_fn in DISTS.items():
        for pred_name, pred in PREDICTORS.items():
            for n_exp in n_exps:
                sc = Scenario(n=2 ** n_exp, dist=dist_fn(), predictor=pred)
                res = run_scenario(sc, n_runs=n_runs)
                row = {
                    "dist": dist_name, "N": f"2^{n_exp}",
                    "predictor": pred_name,
                    **{k: round(v, 1) for k, v in res.items()},
                    "gain_opt_pct": round(gain(res, "OptimalPrediction"), 1),
                    "gain_inexact_pct": round(
                        gain(res, "InexactPrediction"), 1),
                }
                paper = PAPER.get((dist_name, n_exp, pred_name), {})
                row["paper_rfo"] = paper.get("RFO")
                row["paper_opt"] = paper.get("OptimalPrediction")
                rows.append(row)
                print(f"{dist_name} N=2^{n_exp} {pred_name}: "
                      f"RFO={res['RFO']:.1f}d (paper {paper.get('RFO')}), "
                      f"Opt={res['OptimalPrediction']:.1f}d "
                      f"(paper {paper.get('OptimalPrediction')}), "
                      f"gain={row['gain_opt_pct']}%", flush=True)
    # Qualitative claims (Tables 3-5): prediction helps, gains grow with N
    # and with distance from Exponential.
    by = {(r["dist"], r["N"], r["predictor"]): r for r in rows}
    for d in DISTS:
        for p in PREDICTORS:
            assert by[(d, "2^19", p)]["gain_opt_pct"] > 0
            assert by[(d, "2^19", p)]["gain_opt_pct"] \
                >= by[(d, "2^16", p)]["gain_opt_pct"] - 3.0
    assert by[("w05", "2^19", "good")]["gain_opt_pct"] \
        > by[("exp", "2^19", "good")]["gain_opt_pct"]
    print("exec_times: paper trend claims verified")
    return rows


if __name__ == "__main__":
    run(quick=False)
