"""Paper Tables 3-5: job execution times under the five heuristics.

Grid: {Exponential, Weibull k=0.7, Weibull k=0.5} x {2^16, 2^19 processors}
x {good, fair} predictors, C_p = C — one :class:`ExperimentSpec` with a
cartesian sweep, evaluated by the batched runner (one trace bank per cell,
shared across the five strategies).  Reports execution time in days and the
gain of OptimalPrediction / InexactPrediction over RFO, next to the paper's
numbers.  Quick mode trims the trace count (the paper averages 100 runs;
the trend, not the third digit, is the reproduction target).
"""

from __future__ import annotations

from repro.experiments import (DistributionSpec, ExperimentSpec, ScenarioSpec,
                               SweepSpec, register_experiment, run_experiment)

from .common import STANDARD_STRATEGIES, gain, predictor_axis

# Paper values (days): {(dist, n_exp, predictor): {strategy: days}}
PAPER = {
    ("exp", 16, "good"): {"RFO": 65.2, "OptimalPrediction": 60.0,
                          "InexactPrediction": 60.6},
    ("exp", 19, "good"): {"RFO": 11.7, "OptimalPrediction": 9.5,
                          "InexactPrediction": 10.2},
    ("exp", 16, "fair"): {"RFO": 65.2, "OptimalPrediction": 61.7},
    ("exp", 19, "fair"): {"RFO": 11.7, "OptimalPrediction": 10.7},
    ("w07", 16, "good"): {"RFO": 80.3, "OptimalPrediction": 65.9,
                          "InexactPrediction": 68.0},
    ("w07", 19, "good"): {"RFO": 25.5, "OptimalPrediction": 15.9},
    ("w07", 16, "fair"): {"RFO": 80.3, "OptimalPrediction": 69.7},
    ("w07", 19, "fair"): {"RFO": 25.5, "OptimalPrediction": 20.2},
    ("w05", 16, "good"): {"RFO": 120.2, "OptimalPrediction": 75.9},
    ("w05", 19, "good"): {"RFO": 114.8, "OptimalPrediction": 39.5},
    ("w05", 16, "fair"): {"RFO": 120.2, "OptimalPrediction": 83.0},
    ("w05", 19, "fair"): {"RFO": 114.8, "OptimalPrediction": 60.8},
}

DISTS = {
    "exp": DistributionSpec("exponential"),
    "w07": DistributionSpec("weibull", {"shape": 0.7}),
    "w05": DistributionSpec("weibull", {"shape": 0.5}),
}
N_EXPS = [16, 19]


@register_experiment("exec_times", "Tables 3-5: execution times of the five "
                                   "heuristics over dist x predictor x N")
def experiment(quick: bool = True) -> ExperimentSpec:
    preds, pred_names = predictor_axis()
    return ExperimentSpec(
        name="exec_times",
        description="Execution time (days) of the paper's five heuristics",
        scenario=ScenarioSpec(n_traces=5 if quick else 40),
        sweep=SweepSpec(
            axes={"dist": list(DISTS.values()),
                  "recall,precision": preds,
                  "n": [2 ** k for k in N_EXPS]},
            labels={"dist": list(DISTS), "recall,precision": pred_names},
            names={"recall,precision": "predictor"}),
        strategies=STANDARD_STRATEGIES,
        metrics=("makespan_days",),
    )


def run(quick: bool = True) -> list[dict]:
    _, pred_names = predictor_axis()
    table = run_experiment(experiment(quick))
    rows = []
    for dist_name in DISTS:
        for pred_name in pred_names:
            for n_exp in N_EXPS:
                res = table.strategy_dict(
                    "makespan_days", dist=dist_name, predictor=pred_name,
                    n=2 ** n_exp)
                row = {
                    "dist": dist_name, "N": f"2^{n_exp}",
                    "predictor": pred_name,
                    **{k: round(v, 1) for k, v in res.items()},
                    "gain_opt_pct": round(gain(res, "OptimalPrediction"), 1),
                    "gain_inexact_pct": round(
                        gain(res, "InexactPrediction"), 1),
                }
                paper = PAPER.get((dist_name, n_exp, pred_name), {})
                row["paper_rfo"] = paper.get("RFO")
                row["paper_opt"] = paper.get("OptimalPrediction")
                rows.append(row)
                print(f"{dist_name} N=2^{n_exp} {pred_name}: "
                      f"RFO={res['RFO']:.1f}d (paper {paper.get('RFO')}), "
                      f"Opt={res['OptimalPrediction']:.1f}d "
                      f"(paper {paper.get('OptimalPrediction')}), "
                      f"gain={row['gain_opt_pct']}%", flush=True)
    # Qualitative claims (Tables 3-5): prediction helps, gains grow with N
    # and with distance from Exponential.
    by = {(r["dist"], r["N"], r["predictor"]): r for r in rows}
    for d in DISTS:
        for p in pred_names:
            assert by[(d, "2^19", p)]["gain_opt_pct"] > 0
            assert by[(d, "2^19", p)]["gain_opt_pct"] \
                >= by[(d, "2^16", p)]["gain_opt_pct"] - 3.0
    assert by[("w05", "2^19", "good")]["gain_opt_pct"] \
        > by[("exp", "2^19", "good")]["gain_opt_pct"]
    print("exec_times: paper trend claims verified")
    return rows


if __name__ == "__main__":
    import os
    import sys
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))
    from benchmarks.run import record_benchmark
    record_benchmark("exec_times", {"rows": run(quick=False)}, quick=False)
