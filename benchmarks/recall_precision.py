"""Paper §5.4 / Figures 6-9: recall matters more than precision.

Weibull k=0.7 faults, N in {2^16, 2^19}, C_p = C.  Sweep precision at fixed
recall (Figs 6-7) and recall at fixed precision (Figs 8-9) — each direction
is one :class:`ExperimentSpec` with a single predictor axis — and assert the
paper's headline: the waste is far more sensitive to recall than precision.
"""

from __future__ import annotations

from repro.experiments import (DistributionSpec, ExperimentSpec, ScenarioSpec,
                               StrategySpec, SweepSpec, register_experiment,
                               run_experiment)


@register_experiment("recall_precision", "Figures 6-9: OptimalPrediction "
                                         "waste vs predictor recall/precision")
def experiment(quick: bool = True, n: int = 2 ** 16, fixed: float = 0.8,
               axis: str = "precision") -> ExperimentSpec:
    """Sweep one predictor axis (``precision`` or ``recall``) with the other
    held at ``fixed``."""
    if axis not in ("precision", "recall"):
        raise ValueError(f"axis must be 'precision' or 'recall', got {axis!r}")
    other = "recall" if axis == "precision" else "precision"
    sweep_vals = [0.3, 0.5, 0.7, 0.9] if quick else \
        [0.3, 0.4, 0.5, 0.6, 0.7, 0.8, 0.9, 0.99]
    return ExperimentSpec(
        name=f"recall_precision[{axis}@{other}={fixed:g}]",
        description="Waste sensitivity to one predictor axis",
        scenario=ScenarioSpec(
            n=n, dist=DistributionSpec("weibull", {"shape": 0.7}),
            n_traces=4 if quick else 20,
            **{other: fixed}),
        sweep=SweepSpec(axes={axis: sweep_vals}),
        strategies=(StrategySpec("optimal_prediction"),),
        metrics=("waste",),
    )


def run(quick: bool = True) -> list[dict]:
    ns = [2 ** 16] if quick else [2 ** 16, 2 ** 19]
    rows = []
    for n in ns:
        for fixed in (0.4, 0.8):
            tables = {
                axis: run_experiment(experiment(quick, n=n, fixed=fixed,
                                                axis=axis))
                for axis in ("precision", "recall")
            }
            sweep = [r["precision"] for r in tables["precision"]]
            w_p = tables["precision"].column("waste")   # recall fixed
            w_r = tables["recall"].column("waste")      # precision fixed
            spread_p = max(w_p) - min(w_p)
            spread_r = max(w_r) - min(w_r)
            rows.append({"N": n, "fixed": fixed,
                         "sweep": sweep,
                         "waste_vs_precision": [round(w, 4) for w in w_p],
                         "waste_vs_recall": [round(w, 4) for w in w_r],
                         "spread_precision": round(spread_p, 4),
                         "spread_recall": round(spread_r, 4)})
            print(f"N={n} fixed={fixed}: spread over precision "
                  f"{spread_p:.4f} vs over recall {spread_r:.4f}", flush=True)
            # §5.4 headline: recall dominates precision.
            assert spread_r > spread_p
            # Higher recall must (weakly) reduce waste.
            assert w_r[-1] <= w_r[0] + 0.01
    print("recall_precision: recall >> precision sensitivity verified")
    return rows


if __name__ == "__main__":
    run(quick=False)
