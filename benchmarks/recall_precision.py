"""Paper §5.4 / Figures 6-9: recall matters more than precision.

Weibull k=0.7 faults, N in {2^16, 2^19}, C_p = C.  Sweep precision at fixed
recall (Figs 6-7) and recall at fixed precision (Figs 8-9); assert the
paper's headline: the waste is far more sensitive to recall than precision.
"""

from __future__ import annotations

import numpy as np

from repro.core.policies import evaluate, optimal_prediction
from repro.core.prediction import Predictor
from repro.core.traces import Weibull

from .common import Scenario


def waste_at(n: int, recall: float, precision: float, n_runs: int) -> float:
    sc = Scenario(n=n, dist=Weibull(0.7, 1.0),
                  predictor=Predictor(recall, precision))
    traces = sc.traces(n_runs)
    strat = optimal_prediction(sc.pp)
    m = evaluate(strat, traces, sc.platform, sc.time_base, sc.pp.cp)
    return 1.0 - sc.time_base / m


def run(quick: bool = True) -> list[dict]:
    n_runs = 4 if quick else 20
    sweep = [0.3, 0.5, 0.7, 0.9] if quick else \
        [0.3, 0.4, 0.5, 0.6, 0.7, 0.8, 0.9, 0.99]
    ns = [2 ** 16] if quick else [2 ** 16, 2 ** 19]
    rows = []
    for n in ns:
        for fixed in (0.4, 0.8):
            w_p = [waste_at(n, fixed, p, n_runs) for p in sweep]  # r fixed
            w_r = [waste_at(n, r, fixed, n_runs) for r in sweep]  # p fixed
            spread_p = max(w_p) - min(w_p)
            spread_r = max(w_r) - min(w_r)
            rows.append({"N": n, "fixed": fixed,
                         "sweep": sweep,
                         "waste_vs_precision": [round(w, 4) for w in w_p],
                         "waste_vs_recall": [round(w, 4) for w in w_r],
                         "spread_precision": round(spread_p, 4),
                         "spread_recall": round(spread_r, 4)})
            print(f"N={n} fixed={fixed}: spread over precision "
                  f"{spread_p:.4f} vs over recall {spread_r:.4f}", flush=True)
            # §5.4 headline: recall dominates precision.
            assert spread_r > spread_p
            # Higher recall must (weakly) reduce waste.
            assert w_r[-1] <= w_r[0] + 0.01
    print("recall_precision: recall >> precision sensitivity verified")
    return rows


if __name__ == "__main__":
    run(quick=False)
