"""Benchmark entry point: one benchmark per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--full] [--only NAME]

Default is quick mode (few traces per cell — the paper's qualitative claims
are still asserted); ``--full`` approaches the paper's 100-run averaging.
The dry-run/roofline benchmarks need 512 placeholder devices and therefore
run as separate processes (repro.launch.dryrun / benchmarks.roofline); this
driver reports their saved results if present.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time


def report_dryrun(path: str = "dryrun_results.json") -> None:
    if not os.path.exists(path):
        print(f"[dryrun] {path} missing — run "
              f"`python -m repro.launch.dryrun --mesh both`")
        return
    rows = json.load(open(path))
    ok = sum(r["status"] == "ok" for r in rows)
    skip = sum(r["status"] == "skipped" for r in rows)
    err = sum(r["status"] == "error" for r in rows)
    fits = sum(1 for r in rows if r.get("fits_hbm"))
    print(f"[dryrun] {ok} ok / {skip} skipped / {err} errors; "
          f"{fits}/{ok} fit 16 GB HBM as-configured")


def report_roofline(path: str = "roofline_results.json") -> None:
    if not os.path.exists(path):
        print(f"[roofline] {path} missing — run "
              f"`python -m benchmarks.roofline`")
        return
    rows = [r for r in json.load(open(path)) if "t_compute_s" in r]
    print(f"[roofline] {len(rows)} pairs analysed")
    by_dom: dict[str, int] = {}
    for r in rows:
        by_dom[r["dominant"]] = by_dom.get(r["dominant"], 0) + 1
    print(f"[roofline] dominant terms: {by_dom}")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--full", action="store_true",
                    help="paper-scale trace counts (slow)")
    ap.add_argument("--only", default=None,
                    help="run a single benchmark by name")
    args = ap.parse_args()
    quick = not args.full

    from . import (beyond, exec_times, log_traces, multilevel,
                   recall_precision, table2, waste_vs_n)
    benches = {
        "table2": table2.run,
        "exec_times": exec_times.run,
        "waste_vs_n": waste_vs_n.run,
        "log_traces": log_traces.run,
        "recall_precision": recall_precision.run,
        "beyond": beyond.run,
        "multilevel": multilevel.run,
    }
    if args.only:
        benches = {args.only: benches[args.only]}

    results = {}
    for name, fn in benches.items():
        print(f"\n######## {name} ########", flush=True)
        t0 = time.time()
        try:
            results[name] = fn(quick=quick)
            print(f"[{name}] done in {time.time() - t0:.1f}s", flush=True)
        except AssertionError as e:
            print(f"[{name}] CLAIM FAILED: {e}", flush=True)
            raise
    json.dump(results, open("bench_results.json", "w"), indent=1,
              default=str)

    print("\n######## dry-run / roofline artifacts ########")
    report_dryrun()
    report_roofline()
    print("\nall benchmarks done -> bench_results.json")


if __name__ == "__main__":
    main()
