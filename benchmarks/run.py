"""Registry-driven benchmark entry point.

    PYTHONPATH=src python -m benchmarks.run --list
    PYTHONPATH=src python -m benchmarks.run --experiment exec_times \\
        --set n=[65536] --set "recall,precision=[(0.9,0.8)]" --traces 8
    PYTHONPATH=src python -m benchmarks.run [--full] [--only NAME]

Three modes:

  * ``--list``          enumerate the registered experiments (every
                        benchmark registers its :class:`ExperimentSpec`
                        builder on import) and the paper-claim benchmark
                        suites;
  * ``--experiment``    build one registered spec, apply ``--set`` overrides
                        (a sweep-axis name replaces that axis's values, any
                        other dotted path updates the base scenario), run it
                        through the batched runner and print/save the tidy
                        result table;
  * default             run the paper-claim benchmark suites (each asserts
                        its table/figure claims).  Quick mode uses few
                        traces per cell; ``--full`` approaches the paper's
                        100-run averaging.

The dry-run/roofline benchmarks need 512 placeholder devices and therefore
run as separate processes (repro.launch.dryrun / benchmarks.roofline); this
driver reports their saved results if present.
"""

from __future__ import annotations

import argparse
import ast
import json
import os
import sys
import time


def record_benchmark(name: str, payload: object, quick: bool) -> str | None:
    """Write a benchmark suite's result through the result store.

    The identity matches the suite runner's benchmark-item identity, so a
    CLI run pre-populates the store that ``repro-store run`` resumes from.
    Best effort: a store failure reports and returns None, never breaks
    the benchmark itself.
    """
    try:
        from repro.experiments.runner import (_EVAL_CACHE_VERSION,
                                              _engine_fingerprint,
                                              _resolve_engine)
        from repro.store import ResultStore, RunRecord
        identity = {
            "eval_version": _EVAL_CACHE_VERSION,
            "engine_fingerprint": _engine_fingerprint(_resolve_engine(None)),
            "benchmark": name, "quick": quick,
        }
        rec = RunRecord.create("benchmark", name, identity,
                               payload=payload or {})
        return ResultStore().put(rec)
    except Exception as e:  # noqa: BLE001 - recording must never break a run
        print(f"[store] skipped recording {name}: {e}", file=sys.stderr)
        return None


def report_dryrun(path: str = "dryrun_results.json") -> None:
    if not os.path.exists(path):
        print(f"[dryrun] {path} missing — run "
              f"`python -m repro.launch.dryrun --mesh both`")
        return
    rows = json.load(open(path))
    ok = sum(r["status"] == "ok" for r in rows)
    skip = sum(r["status"] == "skipped" for r in rows)
    err = sum(r["status"] == "error" for r in rows)
    fits = sum(1 for r in rows if r.get("fits_hbm"))
    print(f"[dryrun] {ok} ok / {skip} skipped / {err} errors; "
          f"{fits}/{ok} fit 16 GB HBM as-configured")


def report_roofline(path: str = "roofline_results.json") -> None:
    if not os.path.exists(path):
        print(f"[roofline] {path} missing — run "
              f"`python -m benchmarks.roofline`")
        return
    rows = [r for r in json.load(open(path)) if "t_compute_s" in r]
    print(f"[roofline] {len(rows)} pairs analysed")
    by_dom: dict[str, int] = {}
    for r in rows:
        by_dom[r["dominant"]] = by_dom.get(r["dominant"], 0) + 1
    print(f"[roofline] dominant terms: {by_dom}")


def _import_benchmarks():
    """Import every benchmark module so experiments register themselves."""
    from . import (beyond, engine_perf, exact_sweep, exec_times, fleet_sweep,
                   log_traces, multilevel, obs_metrics, predictor_sweep,
                   recall_precision, roofline, silent_sweep, table2,
                   waste_vs_n, window_sweep)
    del roofline  # registers the spec-driven accelerator sweep only
    return {
        "engine_perf": engine_perf.bench,
        "table2": table2.run,
        "exec_times": exec_times.run,
        "waste_vs_n": waste_vs_n.run,
        "log_traces": log_traces.run,
        "recall_precision": recall_precision.run,
        "beyond": beyond.run,
        "multilevel": multilevel.run,
        "window_sweep": window_sweep.run,
        "predictor_sweep": predictor_sweep.run,
        "exact_sweep": exact_sweep.run,
        "silent_sweep": silent_sweep.run,
        "fleet_sweep": fleet_sweep.run,
        "obs_metrics": obs_metrics.run,
    }


def _parse_set(items: list[str]) -> dict[str, object]:
    out: dict[str, object] = {}
    for item in items:
        key, sep, raw = item.partition("=")
        if not sep:
            raise SystemExit(f"--set expects key=value, got {item!r}")
        try:
            out[key] = ast.literal_eval(raw)
        except (ValueError, SyntaxError):
            out[key] = raw  # bare strings, e.g. --set dist.name=weibull
    return out


def run_one_experiment(name: str, overrides: dict[str, object],
                       quick: bool, n_traces: int | None, seed: int | None,
                       workers: int | None, out_path: str | None,
                       persist: bool = True, engine: str | None = None,
                       batched_traces: bool | None = None) -> None:
    from repro.experiments import build_experiment, run_experiment
    exp = build_experiment(name, quick=quick)
    try:
        exp = exp.with_overrides(overrides)
    except ValueError as e:
        raise SystemExit(f"error: {e}") from None
    if exp.scenario.extras.get("external_runner"):
        # Spec-driven accelerator sweep (e.g. roofline): runs as a
        # subprocess under the dry-run device flag the spec demands.
        import subprocess
        from benchmarks.roofline import spec_args
        args_tail, env_extra = spec_args(exp)
        cmd = [sys.executable, "-m", exp.scenario.extras["external_runner"]]
        cmd += args_tail
        print(f"# {exp.name}: {exp.description}")
        print("exec:", " ".join(cmd), flush=True)
        rc = subprocess.call(cmd, env=dict(os.environ, **env_extra))
        if rc != 0:
            raise SystemExit(rc)
        return
    if not exp.strategies:
        raise SystemExit(
            f"experiment {name!r} uses a custom engine; run it with "
            f"`python -m benchmarks.run --only {name}` instead")
    print(f"# {exp.name}: {exp.description}", flush=True)
    table = run_experiment(exp, n_traces=n_traces, seed=seed,
                           workers=workers, verbose=True, persist=persist,
                           engine=engine, batched_traces=batched_traces)
    print()
    print(table.format())

    # Record the run through the result store (same identity as a suite
    # item, so suite runs resume from CLI runs and vice versa).
    try:
        from repro.experiments.runner import (_EVAL_CACHE_VERSION,
                                              _engine_fingerprint,
                                              _resolve_engine)
        from repro.store import ResultStore, RunRecord
        identity = {
            "eval_version": _EVAL_CACHE_VERSION,
            "engine_fingerprint": _engine_fingerprint(
                _resolve_engine(engine)),
            "spec": exp.to_dict(), "n_traces": n_traces, "seed": seed,
            "batched_traces": bool(batched_traces),
        }
        rec = RunRecord.create("experiment", name, identity,
                               rows=table.rows)
        rid = ResultStore().put(rec)
        print(f"store  -> {rid}")
    except Exception as e:  # noqa: BLE001
        print(f"[store] skipped recording {name}: {e}", file=sys.stderr)

    if out_path:
        with open(out_path, "w") as fh:
            fh.write(table.to_json(indent=1))
        print(f"\nresults -> {out_path}")


def main() -> None:
    ap = argparse.ArgumentParser(
        description=__doc__, formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--full", action="store_true",
                    help="paper-scale trace counts (slow)")
    ap.add_argument("--only", default=None,
                    help="run a single benchmark suite by name")
    ap.add_argument("--list", action="store_true",
                    help="list registered experiments and benchmark suites")
    ap.add_argument("--experiment", default=None, metavar="NAME",
                    help="run one registered experiment through the runner")
    ap.add_argument("--set", action="append", default=[], metavar="KEY=VALUE",
                    help="override a sweep axis or scenario field "
                         "(dotted paths OK; repeatable)")
    ap.add_argument("--traces", type=int, default=None,
                    help="override the number of traces per cell")
    ap.add_argument("--seed", type=int, default=None,
                    help="override the evaluation seed")
    ap.add_argument("--workers", type=int, default=None,
                    help="process-parallel workers for scalar-fallback "
                         "candidates (default: $REPRO_EXPERIMENT_WORKERS "
                         "or the CPU count)")
    ap.add_argument("--no-cache", action="store_true",
                    help="bypass the persistent on-disk result cache "
                         "(~/.cache/repro or $REPRO_CACHE_DIR)")
    ap.add_argument("--engine", default=None,
                    choices=("auto", "batch", "scalar", "jax"),
                    help="simulation engine for --experiment runs "
                         "(default auto: lane-parallel batched where "
                         "possible, scalar fallback otherwise; jax needs "
                         "JAX_ENABLE_X64=1)")
    ap.add_argument("--batched-traces", action="store_true",
                    help="sample each cell's trace bank in shared RNG "
                         "waves (a different but statistically identical "
                         "bank; separate trace/result caches)")
    ap.add_argument("--out", default=None,
                    help="write the result table JSON here "
                         "(default experiment_<name>.json)")
    args = ap.parse_args()
    quick = not args.full

    benches = _import_benchmarks()

    if args.list:
        from repro.experiments import (list_distributions, list_experiments,
                                       list_strategies)
        print("registered experiments (run with --experiment NAME):")
        for name, desc in list_experiments().items():
            print(f"  {name:20s} {desc}")
        print("\nbenchmark suites with paper-claim asserts "
              "(run with --only NAME):")
        for name in benches:
            print(f"  {name}")
        print(f"\nregistered strategies:    {', '.join(list_strategies())}")
        print(f"registered distributions: {', '.join(list_distributions())}")
        return

    if args.experiment:
        out = args.out or f"experiment_{args.experiment}.json"
        try:
            run_one_experiment(args.experiment, _parse_set(args.set), quick,
                               args.traces, args.seed, args.workers, out,
                               persist=not args.no_cache, engine=args.engine,
                               batched_traces=args.batched_traces or None)
        except KeyError as e:  # unknown experiment / field: message, not trace
            raise SystemExit(f"error: {e.args[0]}") from None
        return

    if args.only:
        benches = {args.only: benches[args.only]}

    results = {}
    for name, fn in benches.items():
        print(f"\n######## {name} ########", flush=True)
        t0 = time.time()
        try:
            results[name] = fn(quick=quick)
            print(f"[{name}] done in {time.time() - t0:.1f}s", flush=True)
            rid = record_benchmark(name, results[name], quick)
            if rid:
                print(f"[{name}] store -> {rid}", flush=True)
        except AssertionError as e:
            print(f"[{name}] CLAIM FAILED: {e}", flush=True)
            raise
    json.dump(results, open("bench_results.json", "w"), indent=1,
              default=str)

    print("\n######## dry-run / roofline artifacts ########")
    report_dryrun()
    report_roofline()
    print("\nall benchmarks done -> bench_results.json")


if __name__ == "__main__" and __package__ in (None, ""):
    # Executed as a script (`python benchmarks/run.py`): put the repo root
    # and src/ on sys.path, then re-enter through the package so the
    # benchmark modules' relative imports resolve.
    _root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    for _p in (_root, os.path.join(_root, "src")):
        if _p not in sys.path:
            sys.path.insert(0, _p)
    from benchmarks.run import main as _main
    _main()
elif __name__ == "__main__":
    main()
