"""Prediction-window sweep (arXiv:1302.4558): waste vs window length I.

Sweeps the window length from 0 (exact dates) to about two checkpointing
periods, crossed with the literature predictors, and compares the window
action policies:

  * RFO               — predictor ignored entirely (baseline);
  * OptimalPrediction — the exact-date refined policy (window still
                        materializes the fault somewhere in [t, t+I]);
  * WindowStart       — one proactive checkpoint at the window start;
  * WindowProactive   — periodic proactive checkpoints inside the window
                        (period T_p* = sqrt(2 I C_p kappa)).

Claims asserted in quick mode:
  * at I = 0 WindowStart reproduces the exact-date refined policy
    bit-for-bit (same candidate: period T_pred, threshold beta_lim);
  * widening the window hurts WindowStart (the in-window loss r I/2);
  * at the widest window WindowProactive beats WindowStart (bounding the
    work at risk pays for the in-window checkpoints).

    PYTHONPATH=src python -m benchmarks.run --experiment window_sweep
    PYTHONPATH=src python -m benchmarks.run --only window_sweep
"""

from __future__ import annotations

from repro.experiments import (ExperimentSpec, ScenarioSpec, StrategySpec,
                               SweepSpec, register_experiment, run_experiment)

WINDOWS = [0.0, 600.0, 3000.0, 9000.0, 18000.0]


@register_experiment("window_sweep",
                     "waste vs prediction-window length I x predictor "
                     "(arXiv:1302.4558 axes)")
def build(quick: bool = True) -> ExperimentSpec:
    return ExperimentSpec(
        name="window_sweep",
        scenario=ScenarioSpec(n_traces=4 if quick else 50),
        strategies=(
            StrategySpec("rfo"),
            StrategySpec("optimal_prediction"),
            StrategySpec("window_start"),
            StrategySpec("window_proactive"),
        ),
        sweep=SweepSpec(
            axes={"recall,precision": [(0.85, 0.82), (0.70, 0.40)],
                  "window": WINDOWS},
            labels={"recall,precision": ["good", "fair"]},
            names={"recall,precision": "predictor"},
        ),
        description="waste vs prediction-window length I (0 = exact dates)",
    )


def run(quick: bool = True) -> dict:
    exp = build(quick=quick)
    table = run_experiment(exp, verbose=True)
    print(table.format())

    out: dict = {"rows": table.rows}
    for predictor in ("good", "fair"):
        # Claim 1: I = 0 recovers the exact-date refined policy.  Both
        # strategies resolve to (T_pred, ThresholdTrust(beta_lim)), so the
        # runner's cache dedup already guarantees identical makespans; the
        # assert locks the strategy construction.
        m_exact = table.value("makespan", predictor=predictor, window=0.0,
                              strategy="OptimalPrediction")
        m_start0 = table.value("makespan", predictor=predictor, window=0.0,
                               strategy="WindowStart")
        assert m_start0 == m_exact, \
            f"{predictor}: WindowStart(I=0) != OptimalPrediction " \
            f"({m_start0} vs {m_exact})"

        # Claim 2: a wider window costs WindowStart makespan.
        m_wide = table.value("makespan", predictor=predictor,
                             window=WINDOWS[-1], strategy="WindowStart")
        assert m_wide > m_start0, \
            f"{predictor}: widest window should hurt WindowStart " \
            f"({m_wide} <= {m_start0})"

        # Claim 3: at the widest window, in-window proactive checkpointing
        # beats the single window-start checkpoint.
        m_pro = table.value("makespan", predictor=predictor,
                            window=WINDOWS[-1], strategy="WindowProactive")
        assert m_pro < m_wide, \
            f"{predictor}: WindowProactive should beat WindowStart at " \
            f"I={WINDOWS[-1]} ({m_pro} >= {m_wide})"
        out[f"{predictor}_exact_days"] = m_exact / 86400.0
        out[f"{predictor}_wide_start_days"] = m_wide / 86400.0
        out[f"{predictor}_wide_proactive_days"] = m_pro / 86400.0
    print("[window_sweep] claims OK: I=0 reproduces exact dates; "
          "windows hurt; in-window checkpointing recovers part of it")
    return out


if __name__ == "__main__":
    import os
    import sys
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))
    from benchmarks.run import record_benchmark
    record_benchmark("window_sweep", run(quick=False), quick=False)
