"""Fleet availability sweep: heterogeneous tenants, shared limits.

A pinned two-tenant fleet from the model zoo — a 1B model on 256 devices
next to a 405B model on 8192 (per-job C/C_p from the checkpoint manager's
bytes/bandwidth model, mu from the shared per-chip MTBF) — planned under
the paper's waste objective and under the availability objective of
``repro.fleet.availability`` with mostly-concurrent checkpoints
(phi_c = phi_p = 0.25, rho = 1), then simulated by the fleet engine.

Claims asserted (quick and full mode):

  * **objective divergence** (acceptance criterion): on every tenant the
    availability-optimal period is sqrt(phi_c/rho) = 0.5x the
    waste-optimal one — the two objectives provably plan differently on
    the same hardware;
  * **the divergence pays**: the availability plan *measures* a lower
    weighted-outage fraction than the waste plan on both tenants (same
    trace banks, paired comparison);
  * **model-vs-simulator** (acceptance criterion): on the pinned
    fault-rich 405B tenant the analytic unavailability tracks the fleet
    simulator within a few percent (the 1B tenant sees ~3 faults per run
    — quoted, not asserted: Monte-Carlo noise dominates);
  * **staggering works**: on a twin-tenant contended cell (one storage
    stream), bandwidth-aware staggering cuts checkpoint contention by an
    order of magnitude.

    PYTHONPATH=src python -m benchmarks.run --only fleet_sweep
    PYTHONPATH=src python -m benchmarks.fleet_sweep
"""

from __future__ import annotations

from repro.fleet import (FleetSpec, OutageWeights, evaluate_fleet,
                         job_from_model)

# Mostly-concurrent checkpoints, full-outage replay: the regime where the
# availability objective diverges hardest from waste (sqrt(0.25) = 0.5).
WEIGHTS = OutageWeights(ckpt=0.25, prockpt=0.25, replay=1.0)

# Shared per-chip MTBF: 10 years (mu = mu_ind / n_devices, Prop. 2).
MU_IND = 3650.0 * 86400.0

# Simulator-vs-model tolerance on the pinned fault-rich tenant.
TRACK_TOL = 0.10


def _jobs(n_traces: int):
    return (job_from_model("llama3.2-1b", n_devices=256, n_traces=n_traces,
                           seed=0, mu_ind=MU_IND, time_base_days=20.0),
            job_from_model("llama3-405b", n_devices=8192, n_traces=n_traces,
                           seed=1, mu_ind=MU_IND, time_base_days=20.0))


def _twins(n_traces: int):
    return tuple(job_from_model("llama3-405b", n_devices=8192,
                                n_traces=n_traces, seed=s, mu_ind=MU_IND,
                                time_base_days=20.0, name=f"tenant{s}")
                 for s in (1, 2))


def run(quick: bool = True) -> dict:
    n_traces = 5 if quick else 25
    jobs = _jobs(n_traces)
    out: dict = {}

    # -- objective divergence on the heterogeneous fleet -------------------
    tables = {}
    for obj in ("waste", "availability"):
        tables[obj] = evaluate_fleet(FleetSpec(
            jobs=jobs, objective=obj, outage=WEIGHTS,
            name=f"hetero-{obj}"))
        print(tables[obj].format())
    rows = {obj: {r["job"]: r for r in t.rows} for obj, t in tables.items()}
    out["rows"] = {obj: t.rows for obj, t in tables.items()}

    for job in ("llama3.2-1b", "llama3-405b"):
        t_w = rows["waste"][job]["period"]
        t_a = rows["availability"][job]["period"]
        ratio = t_a / t_w
        # sqrt(phi_c/rho) = 0.5 up to the O(beta^2/mu) prediction-term
        # correction both optima carry (well under 0.1% here).
        assert abs(ratio - 0.5) < 5e-4, \
            f"{job}: availability period should be sqrt(phi_c/rho) = 0.5x " \
            f"the waste period, got {ratio:.6f} ({t_a:.1f} vs {t_w:.1f})"
        u_w = rows["waste"][job]["unavailability"]
        u_a = rows["availability"][job]["unavailability"]
        assert u_a < u_w, \
            f"{job}: the availability plan must measure a lower weighted " \
            f"outage ({u_a:.6f} vs {u_w:.6f})"
        print(f"[fleet_sweep] {job}: T {t_w:.0f}s -> {t_a:.0f}s, "
              f"measured U {u_w:.6f} -> {u_a:.6f}")

    # -- analytic model vs fleet simulator (pinned fault-rich tenant) ------
    big = rows["availability"]["llama3-405b"]
    rel = big["expected_objective"] / big["unavailability"] - 1.0
    assert abs(rel) < TRACK_TOL, \
        f"analytic availability model off by {100 * rel:.1f}% vs the " \
        f"fleet simulator on the 405B tenant (tol {100 * TRACK_TOL:.0f}%)"
    small = rows["availability"]["llama3.2-1b"]
    out["model_vs_sim"] = {
        "llama3-405b": 1.0 + rel,
        "llama3.2-1b_unasserted":
            small["expected_objective"] / small["unavailability"],
    }
    print(f"[fleet_sweep] 405B model/sim = {1 + rel:.3f} "
          f"(1B quoted: {out['model_vs_sim']['llama3.2-1b_unasserted']:.3f})")

    # -- staggering under storage contention (twin tenants, one stream) ----
    twins = _twins(n_traces)
    cont = {}
    for stagger in (False, True):
        t = evaluate_fleet(FleetSpec(
            jobs=twins, objective="availability", outage=WEIGHTS,
            storage_streams=1, stagger=stagger,
            name=f"twins-stagger={stagger}"))
        cont[stagger] = sum(r["contention_ckpt_s"] + r["contention_prockpt_s"]
                            for r in t.rows)
    assert cont[True] < 0.1 * cont[False], \
        f"staggering should cut twin-tenant contention by >10x " \
        f"({cont[True]:.2f}s vs {cont[False]:.2f}s)"
    out["contention_s"] = {"synchronized": cont[False],
                           "staggered": cont[True]}
    print(f"[fleet_sweep] twin-tenant contention: {cont[False]:.2f}s "
          f"synchronized -> {cont[True]:.2f}s staggered")

    print("[fleet_sweep] claims OK: periods diverge by sqrt(phi_c/rho), the "
          "availability plan measures a lower weighted outage on every "
          "tenant, the analytic model tracks the simulator, and "
          "staggering removes contention")
    return out


if __name__ == "__main__":
    import os
    import sys
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))
    from benchmarks.run import record_benchmark
    _quick = "--full" not in sys.argv
    record_benchmark("fleet_sweep", run(quick=_quick), quick=_quick)
