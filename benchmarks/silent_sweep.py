"""Silent-error + verification sweep (arXiv:1310.8486 axis).

Sweeps the per-processor silent-corruption MTBF ``silent_mu_ind`` (off /
mild / harsh) against three plans on the same trace banks:

  * RFO             — the fail-stop baseline, blind to silent errors
                      (corruption is only caught by the end-of-job
                      acceptance check — the worst case);
  * SilentVerify    — the jointly optimal (T*, k*) verification plan of
                      ``core/silent.py``;
  * SilentVerifyPred — the composite plan: verifications + Theorem-1
                      threshold trust on the fault predictor.

Claims asserted in quick mode:

  * **acceptance criterion**: whenever the silent MTBF is finite, the
    verified plans beat the blind baseline in simulated makespan, on
    every silent cell;
  * **rate-0 collapse**: with the silent stream off, SilentVerify plans
    k = 0 / keep = 1 and reproduces the RFO baseline **bit-for-bit**
    (same periods, same per-trace makespans — the golden-cell
    degeneracy);
  * the combined analytic waste ``waste_silent`` tracks the simulated
    waste of its own plan on the silent cells (model cross-validation;
    the bit-for-bit engine parity net is tests/test_golden_parity.py);
  * blind waste grows as the silent MTBF shrinks (the axis direction).

    PYTHONPATH=src python -m benchmarks.run --experiment silent_sweep
    PYTHONPATH=src python -m benchmarks.run --only silent_sweep
"""

from __future__ import annotations

from repro.core.silent import optimal_silent_plan
from repro.experiments import (ExperimentSpec, ScenarioSpec, StrategySpec,
                               SweepSpec, register_experiment, run_experiment)

# Per-processor silent MTBF axis: off reproduces the legacy fail-stop
# traces bit-for-bit; the harsh value matches the pinned golden cells.
SILENT_AXIS = [None, 8.0e9, 2.0e9]
SILENT_LABELS = ["off", "mild", "harsh"]
VERIFY_COST = 120.0


@register_experiment("silent_sweep",
                     "simulated makespan/waste, blind RFO vs verified "
                     "(T*, k*) plans on the silent-error MTBF axis")
def build(quick: bool = True) -> ExperimentSpec:
    return ExperimentSpec(
        name="silent_sweep",
        scenario=ScenarioSpec(verify_cost=VERIFY_COST,
                              n_traces=4 if quick else 25),
        strategies=(StrategySpec("rfo"),
                    StrategySpec("silent_verify"),
                    StrategySpec("silent_verify_pred")),
        sweep=SweepSpec(axes={"silent_mu_ind": SILENT_AXIS},
                        labels={"silent_mu_ind": SILENT_LABELS},
                        names={"silent_mu_ind": "silent"}),
        description="blind vs verified checkpointing under silent errors",
    )


def run(quick: bool = True) -> dict:
    exp = build(quick=quick)
    table = run_experiment(exp, verbose=True)
    print(table.format())
    out: dict = {"rows": table.rows}

    # Claim 1 (acceptance criterion): finite silent MTBF -> both verified
    # plans beat the blind baseline outright (paired: shared trace banks).
    wins = {}
    for cell in ("mild", "harsh"):
        m_blind = table.value("makespan", silent=cell, strategy="RFO")
        for strat in ("SilentVerify", "SilentVerifyPred"):
            m = table.value("makespan", silent=cell, strategy=strat)
            assert m < m_blind, \
                f"{cell}: {strat} should beat blind RFO " \
                f"({m:.4g} >= {m_blind:.4g})"
            wins[f"{cell}.{strat}"] = m_blind / m
    out["speedup_vs_blind"] = wins

    # Claim 2: rate-0 collapse is bit-for-bit (period and makespan).
    assert table.value("period", silent="off", strategy="SilentVerify") \
        == table.value("period", silent="off", strategy="RFO")
    assert table.value("makespan", silent="off", strategy="SilentVerify") \
        == table.value("makespan", silent="off", strategy="RFO"), \
        "rate-0 SilentVerify must reproduce the RFO baseline bit-for-bit"

    # Claim 3: the combined first-order waste model tracks its own plan's
    # simulated waste on the silent cells.
    sc = exp.scenario
    model_vs_sim = {}
    for cell, mu_ind in zip(SILENT_LABELS[1:], SILENT_AXIS[1:]):
        plan = optimal_silent_plan(sc.platform, mu_ind / sc.n, VERIFY_COST)
        w_sim = table.value("waste", silent=cell, strategy="SilentVerify")
        ratio = plan.waste / w_sim
        assert 0.85 < ratio < 1.15, \
            f"{cell}: analytic waste {plan.waste:.4f} is off the simulated " \
            f"{w_sim:.4f} by more than 15%"
        model_vs_sim[cell] = ratio
    out["model_vs_sim"] = model_vs_sim

    # Claim 4: the blind baseline degrades monotonically along the axis.
    w_off = table.value("waste", silent="off", strategy="RFO")
    w_mild = table.value("waste", silent="mild", strategy="RFO")
    w_harsh = table.value("waste", silent="harsh", strategy="RFO")
    assert w_off < w_mild < w_harsh, \
        f"blind waste should grow with the silent rate " \
        f"({w_off:.4f}, {w_mild:.4f}, {w_harsh:.4f})"
    out["blind_waste"] = {"off": w_off, "mild": w_mild, "harsh": w_harsh}

    print("[silent_sweep] claims OK: verified plans win under finite "
          "silent MTBF, rate-0 collapses to RFO bit-for-bit, and the "
          "combined waste model tracks the simulation")
    return out


if __name__ == "__main__":
    import os
    import sys
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))
    from benchmarks.run import record_benchmark
    record_benchmark("silent_sweep", run(quick=False), quick=False)
