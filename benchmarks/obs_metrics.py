"""Observability benchmark: waste-attribution buckets + trace counters.

Runs one fixed prediction cell through the scalar engine with a
``RecordingSink``, attributes every simulated second to a paper term
(``repro.obs.attribution``), and replays two of the jobs as a contended
fleet to exercise the ``wait`` bucket and the Perfetto exporter.  The
payload's bucket values and event counters are deterministic — the CI
suite pins them exactly (``suites/quick.yaml``) and the baseline gate
diffs them bit-for-bit; ``wall_s`` rides in the banded timing cells.
"""

from __future__ import annotations

import time


def run(quick: bool = True) -> dict:
    import numpy as np

    from repro.core.simulator import simulate
    from repro.experiments import ScenarioSpec, StrategySpec
    from repro.fleet.sim import FleetJobInput, simulate_fleet
    from repro.obs import (RecordingSink, attribute_fleet_job,
                           attribute_result, fleet_to_perfetto)

    t0 = time.perf_counter()
    scenario = ScenarioSpec(n=2 ** 16, c=600.0, d=60.0, r=600.0, n_traces=2,
                            time_base_years_total=2000.0, seed=5)
    strat = StrategySpec("optimal_prediction").build(scenario)
    traces = scenario.make_traces()
    seeds = [scenario.seed + 7919 * i for i in range(len(traces))]

    # -- single run: tracing on, buckets must close exactly -----------------
    sink = RecordingSink()
    res = simulate(traces[0], scenario.platform, scenario.time_base,
                   strat.period, cp=scenario.cp, trust=strat.trust,
                   rng=np.random.default_rng(seeds[0]), sink=sink)
    att = attribute_result(res)
    assert att.total() == res.makespan, "bucket closure broke"
    counts = sink.counts()
    single = {name: v for name, v in att.buckets().items()}
    single.update(
        makespan=res.makespan,
        n_proactive_ckpts=res.n_proactive_ckpts,
        n_rollbacks=res.n_rollbacks,
        n_events=len(sink),
        n_fault_events=counts.get("fault", 0),
        n_trust_events=counts.get("trust", 0),
        sum_exact=int(att.total() == res.makespan),
    )

    # -- contended fleet: wait bucket + Perfetto timeline -------------------
    sinks = [RecordingSink() for _ in traces]
    fleet = simulate_fleet(
        [FleetJobInput(trace=tr, platform=scenario.platform,
                       time_base=scenario.time_base, period=strat.period,
                       cp=scenario.cp, trust=strat.trust,
                       rng=np.random.default_rng(seeds[i]),
                       name=f"job{i}", sink=sinks[i])
         for i, tr in enumerate(traces)],
        storage_streams=1, repair_slots=1)
    fatts = [attribute_fleet_job(j) for j in fleet.jobs]
    assert all(a.total() == j.sim.makespan
               for a, j in zip(fatts, fleet.jobs)), "fleet closure broke"
    trace_json = fleet_to_perfetto(
        [(j.name, s.events) for j, s in zip(fleet.jobs, sinks)])
    fleet_out = {
        "n_jobs": len(fleet.jobs),
        "wait_total": sum(a.wait for a in fatts),
        "makespan": fleet.makespan,
        "n_trace_events": len(trace_json["traceEvents"]),
        "sum_exact": int(all(a.total() == j.sim.makespan
                             for a, j in zip(fatts, fleet.jobs))),
    }

    print(f"obs_metrics: buckets closed on 1 run + {len(fleet.jobs)} fleet "
          f"jobs; {len(sink)} events traced")
    return {"single": single, "fleet": fleet_out,
            "wall_s": time.perf_counter() - t0}


if __name__ == "__main__":
    import os
    import sys
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))
    from benchmarks.run import record_benchmark
    record_benchmark("obs_metrics", run(quick=False), quick=False)
