"""Paper Table 2: first-order periods vs the exact Exponential optimum.

Pure analysis (no simulation): for N = 2^10..2^19 print Young / Daly / RFO
periods, their relative deviation from the Lambert-W optimum, and assert the
paper's qualitative claims (Young/Daly overestimate, RFO underestimates,
|error| grows with N).  Declared as an analytic :class:`ExperimentSpec`
(``n_traces=0``: the runner reports each strategy's period, no simulation).
"""

from __future__ import annotations

from repro.experiments import (DistributionSpec, ExperimentSpec, ScenarioSpec,
                               StrategySpec, SweepSpec, register_experiment,
                               run_experiment)

from .common import MU_IND_SYNTH

# Paper Table 2 reference values (seconds).
PAPER = {
    10: (68567, 68573, 67961, 68240),
    11: (48660, 48668, 48052, 48320),
    12: (34584, 34595, 33972, 34189),
    13: (24630, 24646, 24014, 24231),
    14: (17592, 17615, 16968, 17194),
    15: (12615, 12648, 11982, 12218),
    16: (9096, 9142, 8449, 8701),
    17: (6608, 6673, 5941, 6214),
    18: (4848, 4940, 4154, 4458),
    19: (3604, 3733, 2869, 3218),
}


@register_experiment("table2", "Table 2: first-order periods vs the exact "
                               "Exponential optimum (analytic)")
def experiment(quick: bool = True) -> ExperimentSpec:
    return ExperimentSpec(
        name="table2",
        description="Young/Daly/RFO periods vs Lambert-W optimum, N=2^10..2^19",
        scenario=ScenarioSpec(dist=DistributionSpec("exponential"),
                              mu_ind=MU_IND_SYNTH, c=600.0, d=60.0, r=600.0,
                              n_traces=0),
        sweep=SweepSpec(axes={"n": [2 ** k for k in PAPER]}),
        strategies=(StrategySpec("young"), StrategySpec("daly"),
                    StrategySpec("rfo"), StrategySpec("exact_exponential")),
        metrics=(),
    )


def run(quick: bool = False) -> list[dict]:
    table = run_experiment(experiment(quick))
    rows = []
    print("\n== Table 2: periods (s) and deviation from exact optimum ==")
    print(f"{'N':>6s} {'mu':>9s} | {'Young':>8s} {'Daly':>8s} {'RFO':>8s} "
          f"{'Opt':>8s} | {'eY%':>6s} {'eD%':>6s} {'eR%':>6s} | paper(Y/D/R/O)")
    prev_err = 0.0
    for k, ref in PAPER.items():
        n = 2 ** k
        periods = table.strategy_dict("period", n=n)
        ty, td, tr = periods["Young"], periods["Daly"], periods["RFO"]
        topt = periods["ExactExponential"]
        ey, ed, er = [100 * (t / topt - 1) for t in (ty, td, tr)]
        rows.append({"N": n, "young": ty, "daly": td, "rfo": tr,
                     "opt": topt, "err_young_pct": ey, "err_daly_pct": ed,
                     "err_rfo_pct": er, "paper": ref})
        print(f"2^{k:<4d} {MU_IND_SYNTH / n:9.0f} | {ty:8.0f} {td:8.0f} "
              f"{tr:8.0f} {topt:8.0f} | {ey:6.2f} {ed:6.2f} {er:6.2f} | {ref}")
        # Paper claims: Young/Daly over, RFO under, errors grow with N.
        assert ey > 0 and ed > 0 and er < 0
        assert abs(ey) >= prev_err - 1e-9
        prev_err = abs(ey)
        # Values match the paper to 0.2%.
        for ours, theirs in zip((ty, td, tr), ref[:3]):
            assert abs(ours / theirs - 1) < 2e-3, (ours, theirs)
    print("table2: all paper claims verified")
    return rows


if __name__ == "__main__":
    run()
