"""Paper Table 2: first-order periods vs the exact Exponential optimum.

Pure analysis (no simulation): for N = 2^10..2^19 print Young / Daly / RFO
periods, their relative deviation from the Lambert-W optimum, and assert the
paper's qualitative claims (Young/Daly overestimate, RFO underestimates,
|error| grows with N).
"""

from __future__ import annotations

from repro.core.waste import (Platform, t_daly, t_exact_exponential, t_rfo,
                              t_young)

from .common import MU_IND_SYNTH

# Paper Table 2 reference values (seconds).
PAPER = {
    10: (68567, 68573, 67961, 68240),
    11: (48660, 48668, 48052, 48320),
    12: (34584, 34595, 33972, 34189),
    13: (24630, 24646, 24014, 24231),
    14: (17592, 17615, 16968, 17194),
    15: (12615, 12648, 11982, 12218),
    16: (9096, 9142, 8449, 8701),
    17: (6608, 6673, 5941, 6214),
    18: (4848, 4940, 4154, 4458),
    19: (3604, 3733, 2869, 3218),
}


def run(quick: bool = False) -> list[dict]:
    rows = []
    print("\n== Table 2: periods (s) and deviation from exact optimum ==")
    print(f"{'N':>6s} {'mu':>9s} | {'Young':>8s} {'Daly':>8s} {'RFO':>8s} "
          f"{'Opt':>8s} | {'eY%':>6s} {'eD%':>6s} {'eR%':>6s} | paper(Y/D/R/O)")
    prev_err = 0.0
    for k, ref in PAPER.items():
        n = 2 ** k
        p = Platform(mu=MU_IND_SYNTH / n, c=600.0, d=60.0, r=600.0)
        ty, td, tr = t_young(p), t_daly(p), t_rfo(p)
        topt = t_exact_exponential(p)
        ey, ed, er = [100 * (t / topt - 1) for t in (ty, td, tr)]
        rows.append({"N": n, "young": ty, "daly": td, "rfo": tr,
                     "opt": topt, "err_young_pct": ey, "err_daly_pct": ed,
                     "err_rfo_pct": er, "paper": ref})
        print(f"2^{k:<4d} {p.mu:9.0f} | {ty:8.0f} {td:8.0f} {tr:8.0f} "
              f"{topt:8.0f} | {ey:6.2f} {ed:6.2f} {er:6.2f} | {ref}")
        # Paper claims: Young/Daly over, RFO under, errors grow with N.
        assert ey > 0 and ed > 0 and er < 0
        assert abs(ey) >= prev_err - 1e-9
        prev_err = abs(ey)
        # Values match the paper to 0.2%.
        for ours, theirs in zip((ty, td, tr), ref[:3]):
            assert abs(ours / theirs - 1) < 2e-3, (ours, theirs)
    print("table2: all paper claims verified")
    return rows


if __name__ == "__main__":
    run()
