"""Before/after comparison of the §Perf optimizations across the full grid.

Reads the baseline artifacts (dryrun_results.json / roofline_results.json,
paper-faithful defaults) and the optimized ones (dryrun_optimized.json /
roofline_optimized.json, post-hillclimb defaults) and prints the deltas.

    PYTHONPATH=src python -m benchmarks.compare
"""

from __future__ import annotations

import json
import os


def load(path, tagged=None):
    if not os.path.exists(path):
        return {}
    rows = json.load(open(path))
    out = {}
    for r in rows:
        if r.get("status", "ok") != "ok" and "t_compute_s" not in r:
            continue
        if tagged is None and "tag" in r:
            continue
        if tagged is not None and r.get("tag") != tagged:
            continue
        out[(r["arch"], r["shape"], r.get("mesh", "16x16"))] = r
    return out


def main() -> None:
    dry_base = load("dryrun_results.json")
    dry_opt = load("dryrun_optimized.json", tagged="opt")
    roof_base = load("roofline_results.json")
    roof_opt = load("roofline_optimized.json", tagged="opt")

    print("== Memory per device (dry-run, 16x16): baseline -> optimized ==")
    print(f"{'pair':40s} {'base GB':>8s} {'opt GB':>8s} {'delta':>7s}")
    improved = regressed = 0
    for key in sorted(dry_base):
        if key not in dry_opt or key[2] != "16x16":
            continue
        b = dry_base[key]["bytes_per_device"] / 1e9
        o = dry_opt[key]["bytes_per_device"] / 1e9
        d = 100 * (o / b - 1)
        improved += d < -1
        regressed += d > 1
        print(f"{key[0] + ' x ' + key[1]:40s} {b:8.2f} {o:8.2f} {d:+6.1f}%")
    print(f"-> {improved} improved, {regressed} regressed (>1%)\n")

    print("== Roofline bound (max term, s): baseline -> optimized ==")
    print(f"{'pair':40s} {'base':>8s} {'opt':>8s} {'delta':>8s} "
          f"{'useful b->o':>12s}")
    for key in sorted(roof_base):
        k2 = (key[0], key[1], key[2])
        if k2 not in roof_opt:
            continue
        b = roof_base[key]
        o = roof_opt[k2]
        bb = max(b["t_compute_s"], b["t_memory_s"], b["t_collective_s"])
        oo = max(o["t_compute_s"], o["t_memory_s"], o["t_collective_s"])
        print(f"{key[0] + ' x ' + key[1]:40s} {bb:8.3f} {oo:8.3f} "
              f"{100 * (oo / bb - 1):+7.1f}% "
              f"{b['useful_flops_ratio']:.3f}->{o['useful_flops_ratio']:.3f}")


if __name__ == "__main__":
    main()
