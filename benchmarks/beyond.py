"""Beyond the paper: hazard-aware dynamic checkpoint periods.

The paper's first-order analysis (and Young/Daly before it) assumes a
constant fault rate 1/mu.  Real platforms — and the paper's own Weibull
k<1 simulations — have a *decreasing aggregate hazard*: all N processors
power on together, so the platform fault rate starts far above 1/mu and
decays with calendar time ("infant mortality", the reason Weibull k=0.5
destroys fixed-period policies at 2^19 processors).

Extension: make the period track the instantaneous hazard.  For Weibull
inter-arrivals with shape k and per-processor scale lambda, the aggregate
hazard at platform age t (all processors fresh at t=0, few failures per
processor over the horizon) is

    h(t) ~ N * (k / lambda) * (t / lambda)^(k-1)

and the locally-optimal RFO period is T(t) = sqrt(2 C / h(t)) — Eq. 13
with mu replaced by 1/h(t).  With a predictor, the same substitution
extends OptimalPrediction: T(t) = sqrt(2 C / ((1-r) h(t))) with the
Theorem-1 trust rule unchanged (beta_lim does not depend on mu).

This module measures static RFO / OptimalPrediction vs their dynamic
counterparts on the paper's Weibull settings.  The simulator accepts a
callable period (evaluated at each period start).
"""

from __future__ import annotations

import math

import numpy as np

from repro.core.prediction import beta_lim, optimal_period_with_prediction
from repro.core.simulator import NeverTrust, ThresholdTrust, simulate
from repro.core.traces import Weibull
from repro.core.waste import t_rfo

from .common import PREDICTORS, SECONDS_PER_DAY, Scenario


def aggregate_hazard(n: int, shape: float, mu_ind: float, t: float) -> float:
    """h(t) for N superposed fresh Weibull(shape) processors."""
    lam = mu_ind / math.gamma(1.0 + 1.0 / shape)
    t = max(t, 1.0)
    return n * (shape / lam) * (t / lam) ** (shape - 1.0)


def dynamic_period(sc: Scenario, shape: float, recall: float = 0.0,
                   floor_mult: float = 1.0):
    """T(t) = sqrt(2 C / ((1-r) h(t_cal))) with t_cal = job start + t."""
    c = sc.c

    def period(t: float) -> float:
        h = aggregate_hazard(sc.n, shape, sc.mu_ind, sc.start + t)
        mu_eff = 1.0 / max(h, 1e-12)
        t_opt = math.sqrt(2.0 * mu_eff * c / max(1.0 - recall, 1e-6))
        return max(floor_mult * c, t_opt)

    return period


def run_cell(sc: Scenario, shape: float, n_runs: int) -> dict:
    traces = sc.traces(n_runs)
    plat = sc.platform
    pp = sc.pp
    t_static = t_rfo(plat)
    t_pred, _, use = optimal_period_with_prediction(pp)
    bl = beta_lim(pp)
    strategies = {
        "RFO": (t_static, NeverTrust()),
        "DynamicRFO": (dynamic_period(sc, shape), NeverTrust()),
        "OptimalPrediction": (t_pred, ThresholdTrust(bl) if use
                              else NeverTrust()),
        "DynamicPrediction": (
            dynamic_period(sc, shape, recall=pp.predictor.recall),
            ThresholdTrust(bl)),
    }
    out = {}
    for name, (period, trust) in strategies.items():
        tot = 0.0
        for i, tr in enumerate(traces):
            res = simulate(tr, plat, sc.time_base, period, cp=pp.cp,
                           trust=trust, rng=np.random.default_rng(i))
            tot += res.makespan
        out[name] = tot / len(traces) / SECONDS_PER_DAY
    return out


def run(quick: bool = True) -> list[dict]:
    n_runs = 5 if quick else 30
    rows = []
    for shape in (0.5, 0.7):
        for n_exp in (16, 19):
            sc = Scenario(n=2 ** n_exp, dist=Weibull(shape, 1.0),
                          predictor=PREDICTORS["good"])
            res = run_cell(sc, shape, n_runs)
            gain_rfo = 100 * (1 - res["DynamicRFO"] / res["RFO"])
            gain_pred = 100 * (1 - res["DynamicPrediction"]
                               / res["OptimalPrediction"])
            row = {"shape": shape, "N": f"2^{n_exp}",
                   **{k: round(v, 1) for k, v in res.items()},
                   "dyn_vs_rfo_pct": round(gain_rfo, 1),
                   "dyn_vs_pred_pct": round(gain_pred, 1)}
            rows.append(row)
            print(f"k={shape} N=2^{n_exp}: RFO={res['RFO']:.1f}d "
                  f"DynRFO={res['DynamicRFO']:.1f}d ({gain_rfo:+.1f}%)  "
                  f"Opt={res['OptimalPrediction']:.1f}d "
                  f"DynOpt={res['DynamicPrediction']:.1f}d "
                  f"({gain_pred:+.1f}%)", flush=True)
    # The dynamic period must help where the hazard decays hardest.
    by = {(r["shape"], r["N"]): r for r in rows}
    assert by[(0.5, "2^19")]["dyn_vs_rfo_pct"] > 0
    print("beyond: hazard-aware dynamic period verified")
    return rows


if __name__ == "__main__":
    run(quick=False)
