"""Beyond the paper: hazard-aware dynamic checkpoint periods.

The paper's first-order analysis (and Young/Daly before it) assumes a
constant fault rate 1/mu.  Real platforms — and the paper's own Weibull
k<1 simulations — have a *decreasing aggregate hazard*: all N processors
power on together, so the platform fault rate starts far above 1/mu and
decays with calendar time ("infant mortality", the reason Weibull k=0.5
destroys fixed-period policies at 2^19 processors).

Extension: make the period track the instantaneous hazard.  For Weibull
inter-arrivals with shape k and per-processor scale lambda, the aggregate
hazard at platform age t (all processors fresh at t=0, few failures per
processor over the horizon) is

    h(t) ~ N * (k / lambda) * (t / lambda)^(k-1)

and the locally-optimal RFO period is T(t) = sqrt(2 C / h(t)) — Eq. 13
with mu replaced by 1/h(t).  With a predictor, the same substitution
extends OptimalPrediction: T(t) = sqrt(2 C / ((1-r) h(t))) with the
Theorem-1 trust rule unchanged (beta_lim does not depend on mu).

The dynamic strategies are registered (``dynamic_rfo`` /
``dynamic_prediction``, implemented by
:class:`repro.experiments.registry.HazardPeriod`); they read the Weibull
shape from the scenario's fault distribution, so a single
:class:`ExperimentSpec` sweeping ``dist.params.shape`` compares static and
hazard-tracking periods cell by cell.
"""

from __future__ import annotations

from repro.experiments import (DistributionSpec, ExperimentSpec, ScenarioSpec,
                               StrategySpec, SweepSpec, register_experiment,
                               run_experiment)


@register_experiment("beyond", "Beyond the paper: hazard-aware dynamic "
                               "periods vs static RFO/OptimalPrediction")
def experiment(quick: bool = True) -> ExperimentSpec:
    return ExperimentSpec(
        name="beyond",
        description="Static vs hazard-tracking periods on Weibull faults",
        scenario=ScenarioSpec(dist=DistributionSpec("weibull", {"shape": 0.7}),
                              n_traces=5 if quick else 30),
        sweep=SweepSpec(
            axes={"dist.params.shape": [0.5, 0.7],
                  "n": [2 ** 16, 2 ** 19]},
            names={"dist.params.shape": "shape"}),
        strategies=(StrategySpec("rfo"),
                    StrategySpec("dynamic_rfo"),
                    StrategySpec("optimal_prediction"),
                    StrategySpec("dynamic_prediction")),
        metrics=("makespan_days",),
    )


def run(quick: bool = True) -> list[dict]:
    exp = experiment(quick)
    shapes = list(exp.sweep.axes["dist.params.shape"])
    n_exps = [int(n).bit_length() - 1 for n in exp.sweep.axes["n"]]
    table = run_experiment(exp)
    rows = []
    for shape in shapes:
        for n_exp in n_exps:
            res = table.strategy_dict("makespan_days", shape=shape,
                                      n=2 ** n_exp)
            gain_rfo = 100 * (1 - res["DynamicRFO"] / res["RFO"])
            gain_pred = 100 * (1 - res["DynamicPrediction"]
                               / res["OptimalPrediction"])
            row = {"shape": shape, "N": f"2^{n_exp}",
                   **{k: round(v, 1) for k, v in res.items()},
                   "dyn_vs_rfo_pct": round(gain_rfo, 1),
                   "dyn_vs_pred_pct": round(gain_pred, 1)}
            rows.append(row)
            print(f"k={shape} N=2^{n_exp}: RFO={res['RFO']:.1f}d "
                  f"DynRFO={res['DynamicRFO']:.1f}d ({gain_rfo:+.1f}%)  "
                  f"Opt={res['OptimalPrediction']:.1f}d "
                  f"DynOpt={res['DynamicPrediction']:.1f}d "
                  f"({gain_pred:+.1f}%)", flush=True)
    # The dynamic period must help where the hazard decays hardest.
    by = {(r["shape"], r["N"]): r for r in rows}
    assert by[(0.5, "2^19")]["dyn_vs_rfo_pct"] > 0
    print("beyond: hazard-aware dynamic period verified")
    return rows


if __name__ == "__main__":
    run(quick=False)
