"""Predictor-family sweep: generative predictors x drift, static vs adaptive.

The predictor subsystem (``repro.predictors``) makes "which predictor?" a
scenario axis: the sweep crosses the registered generative models —

  * ``oracle``      — the paper's stamped (r, p) predictor;
  * ``lead_time``   — sampled per-event prediction windows (lead times);
  * ``bursty``      — correlated false alarms at the nominal rate;
  * ``drifting``    — precision degrades over the run (slow / fast);

— with the strategies RFO (predictor ignored), OptimalPrediction (the
static paper-optimal plan at the *nominal* (r, p)) and Adaptive (online
(r-hat, p-hat) estimation with re-planning, ``repro.predictors.estimator``).

Claims asserted in quick mode:

  * on the oracle cell the static paper plan beats RFO, and Adaptive
    (correct prior) stays within a few percent of it — estimation noise
    does not wreck a well-planned run;
  * **convergence** (the acceptance criterion): started from a stale
    prior (r=0.3, p=0.99), the adaptive strategy's re-planned operating
    point converges to the analytic ``optimal_period_with_prediction``
    plan at the *true* (r, p) — every lane re-plans, the final periods
    bracket T*, the final trust thresholds sit at beta_lim = C_p/p, and
    the trust decision matches the analytic WASTE2-branch choice;
  * the adaptive run beats the same stale plan left static.

    PYTHONPATH=src python -m benchmarks.run --experiment predictor_sweep
    PYTHONPATH=src python -m benchmarks.run --only predictor_sweep
"""

from __future__ import annotations

import numpy as np

from repro.core.batch import simulate_batch
from repro.core.prediction import beta_lim, optimal_period_with_prediction
from repro.experiments import (ExperimentSpec, PredictorSpec, ScenarioSpec,
                               StrategySpec, SweepSpec, build_strategy,
                               evaluate_strategies, register_experiment,
                               run_experiment, trace_bank)

PREDICTOR_LABELS = ["oracle", "lead_time", "bursty", "drift_slow",
                    "drift_fast"]


def predictor_axis(sc: ScenarioSpec) -> list[PredictorSpec]:
    """The swept predictor families; the drifting ramps are placed inside
    the job window (the job starts ``sc.start`` seconds into the trace)
    so quality actually degrades *during* the run."""
    drift = {"drift_start": sc.start, "drift_span": 2.0 * sc.time_base}
    return [
        PredictorSpec("oracle"),
        PredictorSpec("lead_time", {"lead_mean": 3600.0, "min_lead": 600.0}),
        PredictorSpec("bursty", {"burst_size": 4.0, "burst_gap": 900.0}),
        PredictorSpec("drifting", {"precision_end": 0.6, **drift}),
        PredictorSpec("drifting", {"precision_end": 0.25, "recall_end": 0.6,
                                   **drift}),
    ]

# Stale prior for the convergence cell: the adaptive strategy must discover
# the true predictor quality and re-plan its way to the analytic optimum.
STALE_PRIOR = {"prior_recall": 0.3, "prior_precision": 0.99, "tol": 0.02}


@register_experiment("predictor_sweep",
                     "waste vs generative predictor family x drift "
                     "(oracle / lead_time / bursty / drifting), static vs "
                     "adaptive re-planning")
def build(quick: bool = True) -> ExperimentSpec:
    scenario = ScenarioSpec(n_traces=4 if quick else 25)
    return ExperimentSpec(
        name="predictor_sweep",
        scenario=scenario,
        strategies=(
            StrategySpec("rfo"),
            StrategySpec("optimal_prediction"),
            StrategySpec("adaptive"),
        ),
        sweep=SweepSpec(
            axes={"predictor": [p.to_dict()
                                for p in predictor_axis(scenario)]},
            labels={"predictor": PREDICTOR_LABELS},
        ),
        description="generative predictor families x static vs adaptive "
                    "planning",
    )


def _convergence_cell(quick: bool) -> dict:
    """The acceptance assert: stale-prior adaptive converges to the
    analytic plan at the true (r, p) on the oracle scenario."""
    sc = ScenarioSpec(n_traces=6 if quick else 20,
                      time_base_years_total=40000.0)
    traces = trace_bank(sc)
    plat, tb, cp = sc.platform, sc.time_base, sc.cp

    ad = build_strategy("adaptive", sc, **STALE_PRIOR)
    batch = simulate_batch(
        traces, plat, tb, [ad.period], cp=cp, trust=ad.trust,
        adaptive=ad.adaptive,
        trace_seeds=[sc.seed + 7919 * i for i in range(len(traces))])

    t_true, _, use_true = optimal_period_with_prediction(sc.pp)
    thr_true = beta_lim(sc.pp)
    periods = batch.final_period[0]
    thresholds = batch.final_threshold[0]
    replans = batch.n_replans[0]
    r_hat, p_hat = batch.est_recall[0], batch.est_precision[0]

    rel_t = np.abs(periods - t_true) / t_true
    rel_thr = np.abs(thresholds - thr_true) / thr_true
    assert use_true, "paper scenario: predictions are analytically worth it"
    assert (replans >= 1).all(), \
        f"every lane must re-plan away from the stale prior, got {replans}"
    assert np.isfinite(thresholds).all(), \
        "adaptive trust decision must converge to 'act' (finite beta_lim)"
    assert float(rel_thr.max()) < 0.15, \
        f"final thresholds should sit at beta_lim={thr_true:.0f}, " \
        f"rel err {rel_thr}"
    assert float(rel_t.mean()) < 0.20 and float(rel_t.max()) < 0.35, \
        f"final periods should converge to T*={t_true:.0f}, rel err {rel_t}"
    assert abs(float(r_hat.mean()) - sc.recall) < 0.1
    assert abs(float(p_hat.mean()) - sc.precision) < 0.1

    # The re-planned run must beat the same stale plan left static.
    stale = build_strategy("fixed_period", sc, period=ad.period,
                           trust_threshold=ad.trust.threshold)
    m_stale, m_ad = evaluate_strategies(traces, plat, tb, cp, [stale, ad],
                                        seed=sc.seed)
    assert m_ad < m_stale, \
        f"adaptive ({m_ad}) should beat the stale static plan ({m_stale})"
    return {
        "t_star": t_true, "beta_lim": thr_true,
        "final_periods": [round(float(t), 1) for t in periods],
        "final_thresholds": [round(float(t), 1) for t in thresholds],
        "est_recall": [round(float(v), 3) for v in r_hat],
        "est_precision": [round(float(v), 3) for v in p_hat],
        "n_replans": [int(n) for n in replans],
        "stale_static_days": m_stale / 86400.0,
        "adaptive_days": m_ad / 86400.0,
    }


def run(quick: bool = True) -> dict:
    exp = build(quick=quick)
    table = run_experiment(exp, verbose=True)
    print(table.format())
    out: dict = {"rows": table.rows}

    # Claim 1: on the oracle cell the static paper plan beats RFO and the
    # adaptive strategy (correct prior) stays within a few percent of it.
    m_rfo = table.value("makespan", predictor="oracle", strategy="RFO")
    m_opt = table.value("makespan", predictor="oracle",
                        strategy="OptimalPrediction")
    m_ad = table.value("makespan", predictor="oracle", strategy="Adaptive")
    assert m_opt < m_rfo, f"oracle: static plan should beat RFO " \
                          f"({m_opt} >= {m_rfo})"
    assert m_ad < m_opt * 1.03, \
        f"oracle: adaptive should track the static optimum within 3% " \
        f"({m_ad} vs {m_opt})"
    out["oracle_days"] = {"rfo": m_rfo / 86400.0, "optimal": m_opt / 86400.0,
                          "adaptive": m_ad / 86400.0}

    # Claim 1b: predictor pathologies cost the static plan makespan.  The
    # fault streams are identical across cells (the predictor draws after
    # the fault draws), so these are paired comparisons.
    m_lead = table.value("makespan", predictor="lead_time",
                         strategy="OptimalPrediction")
    m_drift = table.value("makespan", predictor="drift_fast",
                          strategy="OptimalPrediction")
    assert m_lead > m_opt, \
        f"lead-time windows should cost the exact-date plan " \
        f"({m_lead} <= {m_opt})"
    assert m_drift > m_opt, \
        f"fast quality drift should cost the static plan " \
        f"({m_drift} <= {m_opt})"

    # Claim 2 (acceptance criterion): stale-prior adaptive converges to
    # the analytic optimal_period_with_prediction plan.
    out["convergence"] = _convergence_cell(quick)
    print(f"[predictor_sweep] convergence: T*="
          f"{out['convergence']['t_star']:.0f} <- final periods "
          f"{out['convergence']['final_periods']}; beta_lim="
          f"{out['convergence']['beta_lim']:.0f} <- "
          f"{out['convergence']['final_thresholds']}")
    print("[predictor_sweep] claims OK: static beats RFO; adaptive tracks "
          "the optimum and converges from a stale prior to the analytic "
          "plan")
    return out


if __name__ == "__main__":
    import os
    import sys
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))
    from benchmarks.run import record_benchmark
    record_benchmark("predictor_sweep", run(quick=False), quick=False)
