"""Exact vs first-order analysis sweep (arXiv:1207.6936 axes).

Sweeps ``ScenarioSpec.model_order`` ("first" = the paper's Eq. 12/15
first-order waste model, "exact" = the exact-Exponential renewal analysis
of ``repro.core.exact``) crossed with a (mu, C, r, p) grid — platform
scale n (mu = mu_ind/n), checkpoint cost C and the literature predictors —
with the order-aware strategies:

  * NoPred      — the no-prediction baseline (RFO vs the Lambert-W exact
                  optimum);
  * Prediction  — the threshold policy (§4.3 first-order (T*, C_p/p) vs
                  the exact joint (T*, beta*)).

Claims asserted in quick mode:

  * **acceptance criterion**: on every grid cell, the simulated waste
    under the exact-model plan is <= the simulated waste under the
    first-order plan (within a small tolerance absorbing Monte-Carlo
    noise), for both the baseline and the prediction policy — planning on
    the exact analysis never hurts;
  * on the harshest cell (n = 2^19, C = 1800 s, the "fair" predictor,
    C/mu ~ 0.24) the exact plan wins *outright* by several points of
    waste — the regime where the first-order model visibly breaks;
  * **convergence**: as C/mu -> 0 the exact formulas converge to the
    first-order ones (waste curves, optimal periods and the trust
    threshold beta* -> C_p/p), monotonically along the scale ladder;
  * the exact expected-makespan formula predicts the simulated makespan
    of its own plan within a few percent (model cross-validation; the
    bit-for-bit engine parity net is tests/test_golden_parity.py).

    PYTHONPATH=src python -m benchmarks.run --experiment exact_vs_first_order
    PYTHONPATH=src python -m benchmarks.run --only exact_sweep
"""

from __future__ import annotations

import math

from repro.core.exact import (beta_lim_exact, expected_makespan_exact_nopred,
                              expected_makespan_exact_prediction,
                              optimal_period_exact, t_exact_nopred,
                              waste_exact_nopred, waste_exact_prediction)
from repro.core.prediction import (beta_lim, optimal_period_with_prediction,
                                   t_pred, waste1, waste2)
from repro.core.waste import t_rfo
from repro.experiments import (ExperimentSpec, ScenarioSpec, StrategySpec,
                               SweepSpec, register_experiment, run_experiment)

# (n, C) scale grid: C/mu from ~0.01 (the paper's synthetic default) up to
# ~0.24 (where first-order planning visibly breaks).
SCALES = [(2 ** 16, 600.0), (2 ** 19, 600.0), (2 ** 19, 1800.0)]
SCALE_LABELS = ["2^16/C600", "2^19/C600", "2^19/C1800"]

# Simulated-waste tolerance for the <= acceptance assert: the two plans
# coincide as C/mu -> 0, so near the paper's default scale the comparison
# is a coin-flip inside Monte-Carlo noise; the tolerance absorbs that
# without masking a real regression (the harsh-cell margins are 10x it).
WASTE_TOL = 0.008


@register_experiment("exact_vs_first_order",
                     "simulated waste, first-order vs exact-Exponential "
                     "planning (model_order axis) x (mu, C, r, p) grid")
def build(quick: bool = True) -> ExperimentSpec:
    return ExperimentSpec(
        name="exact_vs_first_order",
        scenario=ScenarioSpec(n_traces=4 if quick else 25),
        strategies=(StrategySpec("nopred"), StrategySpec("prediction")),
        sweep=SweepSpec(
            axes={"n,c": SCALES,
                  "recall,precision": [(0.85, 0.82), (0.70, 0.40)],
                  "model_order": ["first", "exact"]},
            labels={"n,c": SCALE_LABELS},
            names={"n,c": "scale", "recall,precision": "predictor"},
        ),
        description="exact vs first-order planning on a (mu, C, r, p) grid",
    )


def _assert_first_order_limit() -> dict:
    """Exact -> first-order as C/mu -> 0 (pure analysis, no simulation)."""
    from repro.core.prediction import PredictedPlatform, Predictor
    from repro.core.waste import Platform
    from repro.experiments import MU_IND_SYNTH

    gaps = []
    for n in (2 ** 19, 2 ** 16, 2 ** 12, 2 ** 8):
        plat = Platform(mu=MU_IND_SYNTH / n, c=600.0, d=60.0, r=600.0)
        pp = PredictedPlatform(plat, Predictor(0.85, 0.82), 600.0)
        t2 = t_pred(pp)
        t1 = t_rfo(plat)
        plan = optimal_period_exact(pp)
        gaps.append({
            "c_over_mu": plat.c / plat.mu,
            "waste1": abs(waste_exact_nopred(t1, plat) / waste1(t1, pp) - 1),
            "waste2": abs(waste_exact_prediction(t2, pp) / waste2(t2, pp) - 1),
            "t_nopred": abs(t_exact_nopred(plat) / t1 - 1),
            "t_pred": abs(plan.period / t2 - 1),
            "beta": abs(beta_lim_exact(pp, t2) / beta_lim(pp) - 1),
        })
    for metric in ("waste1", "waste2", "t_nopred", "t_pred", "beta"):
        seq = [g[metric] for g in gaps]
        assert all(a >= b for a, b in zip(seq, seq[1:])), \
            f"{metric}: exact->first-order gap must shrink with C/mu, {seq}"
        assert seq[-1] < 0.02, \
            f"{metric}: gap {seq[-1]} at C/mu={gaps[-1]['c_over_mu']:.1e} " \
            f"should be <2%"
    return {"ladder": gaps}


def run(quick: bool = True) -> dict:
    exp = build(quick=quick)
    table = run_experiment(exp, verbose=True)
    print(table.format())
    out: dict = {"rows": table.rows}

    # Claim 1 (acceptance criterion): per cell and strategy, the exact plan
    # simulates no worse than the first-order plan (shared trace banks:
    # model_order does not enter trace generation, so this is paired).
    deltas = []
    for scale in SCALE_LABELS:
        for pred in ("0.85/0.82", "0.7/0.4"):
            for strat in ("NoPred", "Prediction"):
                w_first = table.value("waste", scale=scale, predictor=pred,
                                      model_order="first", strategy=strat)
                w_exact = table.value("waste", scale=scale, predictor=pred,
                                      model_order="exact", strategy=strat)
                deltas.append(w_exact - w_first)
                assert w_exact <= w_first + WASTE_TOL, \
                    f"{scale} {pred} {strat}: exact plan simulated worse " \
                    f"({w_exact:.4f} > {w_first:.4f} + {WASTE_TOL})"
    assert sum(deltas) < 0.0, \
        f"exact planning should win on aggregate, deltas {deltas}"
    out["waste_deltas"] = deltas

    # Claim 2: on the harshest cell the exact plan wins outright.
    w_first = table.value("waste", scale="2^19/C1800", predictor="0.7/0.4",
                          model_order="first", strategy="Prediction")
    w_exact = table.value("waste", scale="2^19/C1800", predictor="0.7/0.4",
                          model_order="exact", strategy="Prediction")
    assert w_exact < w_first - 0.02, \
        f"harsh cell: exact plan should beat first-order by >2 points of " \
        f"waste ({w_exact:.4f} vs {w_first:.4f})"
    out["harsh_cell"] = {"first": w_first, "exact": w_exact}

    # Claim 3: the exact makespan formulas predict their own plans'
    # simulated makespans within a few percent (paper-default cell).
    sc = ScenarioSpec(n_traces=4 if quick else 25)
    plan = optimal_period_exact(sc.pp)
    m_pred = table.value("makespan", scale="2^16/C600",
                         predictor="0.85/0.82", model_order="exact",
                         strategy="Prediction")
    m_np = table.value("makespan", scale="2^16/C600", predictor="0.85/0.82",
                       model_order="exact", strategy="NoPred")
    em_pred = expected_makespan_exact_prediction(
        plan.period, sc.time_base, sc.pp, plan.threshold)
    em_np = expected_makespan_exact_nopred(
        t_exact_nopred(sc.platform), sc.time_base, sc.platform)
    for name, model, sim in (("prediction", em_pred, m_pred),
                             ("nopred", em_np, m_np)):
        assert abs(model / sim - 1.0) < 0.05, \
            f"exact {name} makespan formula off by " \
            f"{100 * (model / sim - 1):.1f}% vs simulation"
    out["model_vs_sim"] = {"prediction": em_pred / m_pred,
                           "nopred": em_np / m_np}

    # Claim 4 (acceptance criterion): exact -> first-order as C/mu -> 0.
    out["first_order_limit"] = _assert_first_order_limit()

    print("[exact_sweep] claims OK: exact plans simulate no worse anywhere, "
          "win outright at C/mu~0.24, formulas track the engines, and "
          "converge to the first-order model as C/mu -> 0")
    return out


if __name__ == "__main__":
    import os
    import sys
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))
    from benchmarks.run import record_benchmark
    record_benchmark("exact_sweep", run(quick=False), quick=False)
