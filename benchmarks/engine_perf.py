"""Engine benchmark: scalar event loop vs the lane-parallel batched engine.

Times the two simulation engines on the standard bank sizes (the paper's
synthetic scenario, 200 traces x 24 candidate periods by default) plus the
per-trace vs bank-level trace generation paths, verifies the engines agree
bit-for-bit on the measured subset, and writes ``BENCH_simulator.json`` —
the perf trajectory of the repo's hottest path.

    PYTHONPATH=src python benchmarks/engine_perf.py            # full grid
    PYTHONPATH=src python benchmarks/engine_perf.py --quick    # CI smoke

The scalar loop is timed on ``--scalar-periods`` period columns of the grid
and extrapolated linearly to the full grid (each column costs the same: one
``simulate()`` call per trace); the batched engine runs the whole grid for
real.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

import numpy as np


def _engine_cell(traces, platform, time_base, cp, trust, periods, seeds,
                 scalar_periods: int, **sim_kwargs) -> dict:
    """Time one batch-vs-scalar cell: the batched engine on the full
    (periods x traces) grid, the scalar loop on ``scalar_periods`` columns
    (extrapolated linearly), and their max |makespan| disagreement."""
    from repro.core.batch import simulate_batch
    from repro.core.simulator import simulate

    t0 = time.perf_counter()
    batch = simulate_batch(traces, platform, time_base, periods, cp=cp,
                           trust=trust, trace_seeds=seeds, **sim_kwargs)
    t_batch = time.perf_counter() - t0

    t0 = time.perf_counter()
    max_diff = 0.0
    for ci in range(scalar_periods):
        for ti, tr in enumerate(traces):
            res = simulate(tr, platform, time_base, float(periods[ci]),
                           cp=cp, trust=trust,
                           rng=np.random.default_rng(int(seeds[ti])),
                           **sim_kwargs)
            max_diff = max(max_diff,
                           abs(res.makespan - batch.makespan[ci, ti]))
    t_scalar = time.perf_counter() - t0
    n_periods = len(periods)
    t_scalar_full = t_scalar * n_periods / scalar_periods
    return {
        "grid": f"{n_periods} periods x {len(traces)} traces",
        "batch_s": round(t_batch, 3),
        "scalar_s_measured": round(t_scalar, 3),
        "scalar_s_est_full_grid": round(t_scalar_full, 3),
        "speedup": round(t_scalar_full / max(t_batch, 1e-9), 1),
        "max_abs_makespan_diff": max_diff,
    }


def _jax_cell(traces, platform, time_base, cp, trust, periods, seeds,
              big_lanes: int, **sim_kwargs) -> dict | None:
    """Flagship jax engine: the numpy candidate grid re-run on
    ``backend="jax"`` (must agree **bit-for-bit**, compared with ``==``)
    plus a large replicated lane sweep on a light scenario, timed in
    lanes/sec through the chunked execution path."""
    try:
        import jax
    except ImportError:
        return None
    # The engines' bitwise contract needs float64 lane state; the update
    # must land before the first jax operation of the process.
    jax.config.update("jax_enable_x64", True)
    os.environ.setdefault("REPRO_JAX_CHUNK", str(2 ** 16))
    from repro.core.batch import simulate_batch, simulate_lanes
    from repro.core.simulator import ThresholdTrust
    from repro.core.traces import Exponential, make_event_trace
    from repro.core.waste import Platform

    t0 = time.perf_counter()
    ref = simulate_batch(traces, platform, time_base, periods, cp=cp,
                         trust=trust, trace_seeds=seeds, **sim_kwargs)
    t_numpy = time.perf_counter() - t0
    t0 = time.perf_counter()
    jbatch = simulate_batch(traces, platform, time_base, periods, cp=cp,
                            trust=trust, trace_seeds=seeds, backend="jax",
                            **sim_kwargs)
    t_jax = time.perf_counter() - t0
    bitwise = bool((jbatch.makespan == ref.makespan).all())

    # Large-lane sweep: a light scenario (short job, ~120 events/trace) so
    # the cell measures lane throughput, not one giant paper run.
    lp = Platform(mu=2500.0, c=60.0, d=10.0, r=30.0)
    bank = [make_event_trace(Exponential(1.0), lp.mu, 0.7, 0.6, 200000.0,
                             np.random.default_rng(s)) for s in range(64)]
    idx = np.arange(big_lanes) % len(bank)
    t0 = time.perf_counter()
    simulate_lanes(bank, lp, 50000.0, cp=30.0, trace_indices=idx,
                   periods=np.full(big_lanes, 1200.0),
                   trusts=[ThresholdTrust(100.0)] * big_lanes,
                   windows=np.full(big_lanes, 300.0),
                   seeds=np.arange(big_lanes) + 7, backend="jax")
    t_big = time.perf_counter() - t0
    return {
        "grid": f"{len(periods)} periods x {len(traces)} traces",
        "batch_jax_s": round(t_jax, 3),
        "batch_numpy_s": round(t_numpy, 3),
        "bitwise_equal": bitwise,
        "device": f"{jax.devices()[0].platform}"
                  f"-{jax.devices()[0].device_kind}",
        "big_lanes": int(big_lanes),
        "big_lanes_s": round(t_big, 3),
        "lanes_per_s": round(big_lanes / max(t_big, 1e-9), 1),
        "chunk": int(os.environ["REPRO_JAX_CHUNK"]),
    }


def _fleet_cell(traces, platform, time_base, cp, trust, period,
                seeds, n_jobs: int) -> dict:
    """Time the fleet engine's degeneracy path (1-job fleets vs the scalar
    loop, must agree bit-for-bit) and one contended N-job fleet."""
    from repro.core.simulator import simulate
    from repro.fleet.sim import FleetJobInput, simulate_fleet

    n = min(n_jobs, len(traces))

    def inp(i):
        return FleetJobInput(trace=traces[i], platform=platform,
                             time_base=time_base, period=period, cp=cp,
                             trust=trust,
                             rng=np.random.default_rng(int(seeds[i])))

    t0 = time.perf_counter()
    scalar = [simulate(traces[i], platform, time_base, period, cp=cp,
                       trust=trust,
                       rng=np.random.default_rng(int(seeds[i]))).makespan
              for i in range(n)]
    t_scalar = time.perf_counter() - t0

    t0 = time.perf_counter()
    solo = [simulate_fleet([inp(i)]).jobs[0].sim.makespan for i in range(n)]
    t_solo = time.perf_counter() - t0

    t0 = time.perf_counter()
    coupled = simulate_fleet([inp(i) for i in range(n)], storage_streams=1)
    t_coupled = time.perf_counter() - t0

    return {
        "n_jobs": n,
        "scalar_s": round(t_scalar, 3),
        "fleet_1job_s": round(t_solo, 3),
        "coordination_overhead": round(t_solo / max(t_scalar, 1e-9), 2),
        "fleet_coupled_s": round(t_coupled, 3),
        "contention_s": round(sum(j.time_contention_ckpt
                                  + j.time_contention_prockpt
                                  for j in coupled.jobs), 2),
        "max_abs_makespan_diff": max(abs(a - b)
                                     for a, b in zip(solo, scalar)),
    }


def run(n_traces: int, n_periods: int, scalar_periods: int,
        batched_traces: bool, big_lanes: int,
        with_jax: bool = True) -> dict:
    from repro.core.prediction import beta_lim
    from repro.core.simulator import ThresholdTrust
    from repro.experiments.spec import ScenarioSpec

    spec = ScenarioSpec(n_traces=n_traces)
    out: dict = {"config": {"scenario": spec.to_dict(),
                            "n_traces": n_traces, "n_periods": n_periods,
                            "scalar_periods_measured": scalar_periods}}

    # -- trace-bank generation: per-trace streams vs shared waves ----------
    t0 = time.perf_counter()
    traces = spec.make_traces()
    t_gen = time.perf_counter() - t0
    t0 = time.perf_counter()
    spec.make_traces(batched=True)
    t_gen_batched = time.perf_counter() - t0
    out["bank_gen"] = {
        "n_traces": n_traces,
        "per_trace_s": round(t_gen, 4),
        "batched_s": round(t_gen_batched, 4),
        "speedup": round(t_gen / max(t_gen_batched, 1e-9), 2),
        "events_per_trace": float(np.mean([t.times.size for t in traces])),
    }
    if batched_traces:
        traces = spec.make_traces(batched=True)

    # Bank-level sampling shines when per-trace Python overhead dominates
    # (many small traces); at paper-scale superposition each trace already
    # saturates the vectorized wave path.  Record the small-bank regime too.
    from repro.experiments.spec import DistributionSpec
    small = ScenarioSpec(n=32, dist=DistributionSpec("weibull",
                                                     {"shape": 0.7}),
                         mu_ind=32 * 1e5, time_base_years_total=0.1,
                         start=0.0, n_traces=8 * n_traces, seed=3)
    t0 = time.perf_counter()
    small.make_traces()
    t_small = time.perf_counter() - t0
    t0 = time.perf_counter()
    small.make_traces(batched=True)
    t_small_b = time.perf_counter() - t0
    out["bank_gen_small_traces"] = {
        "n_traces": small.n_traces,
        "per_trace_s": round(t_small, 4),
        "batched_s": round(t_small_b, 4),
        "speedup": round(t_small / max(t_small_b, 1e-9), 2),
    }

    # -- the engines over the (period x trace) candidate grid --------------
    platform, time_base, cp = spec.platform, spec.time_base, spec.cp
    trust = ThresholdTrust(beta_lim(spec.pp))
    periods = np.geomspace(platform.c * 2.0, platform.mu * 0.5, n_periods)
    seeds = 7919 * np.arange(n_traces)

    out["engine"] = dict(
        _engine_cell(traces, platform, time_base, cp, trust, periods, seeds,
                     scalar_periods),
        lanes=n_periods * n_traces)

    # -- flagship jax engine (PR 7): same grid bit-for-bit + lane scale ----
    jcell = _jax_cell(traces, platform, time_base, cp, trust, periods,
                      seeds, big_lanes) if with_jax else None
    if jcell is not None:
        out["engine_jax"] = jcell

    # -- fleet coordinator (PR 6): degeneracy overhead + contended run -----
    # 1-job fleets must reproduce the scalar loop bit-for-bit; the cell
    # records what the cooperative-coroutine coordinator costs on top.
    out["fleet"] = _fleet_cell(traces, platform, time_base, cp, trust,
                               float(periods[n_periods // 2]), seeds,
                               n_jobs=8)

    # -- window-strategy lanes (arXiv:1302.4558 "within" mode) -------------
    # Same grid on a window-bearing bank with in-window proactive
    # checkpointing: the heaviest per-lane state the engine carries.
    from repro.core.windows import beta_lim_window, t_window_period
    wspec = spec.replace(window=9000.0)
    wtraces = wspec.make_traces(batched=batched_traces)
    tp = t_window_period(wspec.pp, wspec.window)
    wtrust = ThresholdTrust(beta_lim_window(wspec.pp, wspec.window, tp))

    out["engine_window"] = dict(
        _engine_cell(wtraces, platform, time_base, cp, wtrust, periods,
                     seeds, scalar_periods, window_mode="within",
                     window_period=tp),
        window=wspec.window, window_period=round(tp, 1))
    return out


def check_contracts(result: dict) -> None:
    """The engine-equivalence claims (shared by ``main`` and the suite
    registry's ``bench``): numpy batch vs scalar within 1e-9, jax bitwise
    vs numpy when present, 1-job fleets bit-for-bit vs the scalar loop."""
    if result["engine"]["max_abs_makespan_diff"] > 1e-9:
        raise AssertionError("engines disagree beyond the 1e-9 contract")
    if result["engine_window"]["max_abs_makespan_diff"] > 1e-9:
        raise AssertionError("window-mode engines disagree beyond the "
                             "1e-9 contract")
    if result["fleet"]["max_abs_makespan_diff"] != 0.0:
        raise AssertionError("1-job fleet broke the bit-for-bit degeneracy "
                             "contract vs the scalar loop")
    if "engine_jax" in result and not result["engine_jax"]["bitwise_equal"]:
        raise AssertionError("jax engine broke the bit-for-bit equivalence "
                             "contract vs the numpy lanes")


def bench(quick: bool = True) -> dict:
    """Suite-registry entry point (``benchmarks.run`` / suite files).

    Skips the jax cell so the payload's *structure* is identical on
    jax-less and jax-bearing environments (the committed suite baseline is
    diffed on both); the jax engine keeps its dedicated CI job via
    ``python benchmarks/engine_perf.py --quick``.
    """
    n_traces = 24 if quick else 200
    n_periods = 6 if quick else 24
    result = run(n_traces, n_periods,
                 scalar_periods=min(1 if quick else 3, n_periods),
                 batched_traces=False,
                 big_lanes=2 ** 14 if quick else 2 ** 20, with_jax=False)
    check_contracts(result)
    return result


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--traces", type=int, default=None,
                    help="bank size (default 200; 24 with --quick)")
    ap.add_argument("--periods", type=int, default=None,
                    help="candidate periods (default 24; 6 with --quick)")
    ap.add_argument("--scalar-periods", type=int, default=None,
                    help="period columns to time the scalar loop on "
                         "(default 3; 1 with --quick)")
    ap.add_argument("--quick", action="store_true",
                    help="small sizes for CI smoke runs")
    ap.add_argument("--batched-traces", action="store_true",
                    help="benchmark the engines on a bank sampled in "
                         "shared RNG waves")
    ap.add_argument("--big-lanes", type=int, default=None,
                    help="jax large-lane sweep size (default 2^20; "
                         "2^14 with --quick)")
    ap.add_argument("--out", default="BENCH_simulator.json")
    args = ap.parse_args()

    n_traces = args.traces or (24 if args.quick else 200)
    n_periods = args.periods or (6 if args.quick else 24)
    scalar_periods = args.scalar_periods or (1 if args.quick else 3)
    scalar_periods = min(scalar_periods, n_periods)
    big_lanes = args.big_lanes or (2 ** 14 if args.quick else 2 ** 20)

    result = run(n_traces, n_periods, scalar_periods, args.batched_traces,
                 big_lanes)
    gen, eng = result["bank_gen"], result["engine"]
    weng = result["engine_window"]
    small = result["bank_gen_small_traces"]
    print(f"bank gen ({n_traces} traces): per-trace {gen['per_trace_s']}s, "
          f"batched {gen['batched_s']}s ({gen['speedup']}x)")
    print(f"bank gen ({small['n_traces']} small traces): per-trace "
          f"{small['per_trace_s']}s, batched {small['batched_s']}s "
          f"({small['speedup']}x)")
    print(f"engine ({eng['grid']}): batch {eng['batch_s']}s, scalar "
          f"~{eng['scalar_s_est_full_grid']}s -> {eng['speedup']}x "
          f"(max |diff| = {eng['max_abs_makespan_diff']})")
    print(f"engine window I={weng['window']:g} Tp={weng['window_period']}: "
          f"batch {weng['batch_s']}s, scalar "
          f"~{weng['scalar_s_est_full_grid']}s -> {weng['speedup']}x "
          f"(max |diff| = {weng['max_abs_makespan_diff']})")
    fl = result["fleet"]
    print(f"fleet ({fl['n_jobs']} jobs): scalar {fl['scalar_s']}s, 1-job "
          f"fleets {fl['fleet_1job_s']}s "
          f"({fl['coordination_overhead']}x overhead), coupled "
          f"{fl['fleet_coupled_s']}s with {fl['contention_s']}s contention "
          f"(max |diff| = {fl['max_abs_makespan_diff']})")
    if "engine_jax" in result:
        jx = result["engine_jax"]
        print(f"engine jax [{jx['device']}] ({jx['grid']}): "
              f"{jx['batch_jax_s']}s vs numpy {jx['batch_numpy_s']}s, "
              f"bitwise_equal={jx['bitwise_equal']}; "
              f"{jx['big_lanes']} lanes in {jx['big_lanes_s']}s "
              f"({jx['lanes_per_s']:,} lanes/s, chunk {jx['chunk']})")
    check_contracts(result)

    # The store record is the source of truth; BENCH_simulator.json is its
    # derived export (payload + record id for traceability).
    export = dict(result)
    try:
        from benchmarks.run import record_benchmark
        rid = record_benchmark("engine_perf", result, quick=args.quick)
        if rid:
            export["record_id"] = rid
            print(f"store  -> {rid}")
    except ImportError:
        pass
    with open(args.out, "w") as fh:
        json.dump(export, fh, indent=1, sort_keys=True)
    print(f"results -> {args.out}")


if __name__ == "__main__":
    _root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    for _p in (_root, os.path.join(_root, "src")):
        if _p not in sys.path:
            sys.path.insert(0, _p)
    main()
