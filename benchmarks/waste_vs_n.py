"""Paper Figures 3, 4 (and 10, 11): waste vs platform size.

For N = 2^14..2^19, both predictors, C_p in {C, 0.1C, 2C}, Weibull k=0.7
faults (the paper's richest setting): measured waste of RFO and
OptimalPrediction, their BestPeriod counterparts, and the false-prediction
distribution variant (same-as-faults vs uniform, Appendix B).
"""

from __future__ import annotations

from repro.core.policies import best_period, optimal_prediction, rfo
from repro.core.traces import UniformDist, Weibull
from repro.core.waste import waste as analytic_waste

from .common import (PREDICTORS, CP_SCENARIOS, Scenario, evaluate,
                     run_scenario)


def measured_waste(sc: Scenario, n_runs: int, with_best: bool) -> dict:
    traces = sc.traces(n_runs)
    out = {}
    for strat in (rfo(sc.platform), optimal_prediction(sc.pp)):
        m = evaluate(strat, traces, sc.platform, sc.time_base, sc.pp.cp)
        out[strat.name] = 1.0 - sc.time_base / m
        if with_best:
            refined, mb = best_period(strat, traces, sc.platform,
                                      sc.time_base, sc.pp.cp, n_points=12)
            out[refined.name] = 1.0 - sc.time_base / mb
    return out


def run(quick: bool = True) -> list[dict]:
    n_runs = 4 if quick else 30
    n_exps = [14, 16, 18] if quick else [14, 15, 16, 17, 18, 19]
    with_best = not quick
    rows = []
    for pred_name, pred in PREDICTORS.items():
        for cp_name, cp_ratio in CP_SCENARIOS.items():
            if quick and cp_name == "expensive" and pred_name == "good":
                pass  # keep: the paper's notable corner case
            for n_exp in n_exps:
                sc = Scenario(n=2 ** n_exp, dist=Weibull(0.7, 1.0),
                              predictor=pred, cp_ratio=cp_ratio)
                res = measured_waste(sc, n_runs, with_best)
                row = {"predictor": pred_name, "cp": cp_name,
                       "N": f"2^{n_exp}",
                       **{k: round(v, 4) for k, v in res.items()}}
                rows.append(row)
                print(f"{pred_name} cp={cp_name} N=2^{n_exp}: "
                      f"RFO={res['RFO']:.3f} "
                      f"Opt={res['OptimalPrediction']:.3f}", flush=True)
    # Figure-level claims: waste grows with N; prediction helps except the
    # bad-predictor + expensive-proactive + largest-platform corner.
    by = {(r["predictor"], r["cp"], r["N"]): r for r in rows}
    big, small = f"2^{n_exps[-1]}", f"2^{n_exps[0]}"
    for p in PREDICTORS:
        for cpn in CP_SCENARIOS:
            assert by[(p, cpn, big)]["RFO"] > by[(p, cpn, small)]["RFO"]
    for p in PREDICTORS:
        r = by[(p, "cheap", big)]
        assert r["OptimalPrediction"] < r["RFO"]
    print("waste_vs_n: figure-level claims verified")

    # Appendix B: uniform false-prediction dates barely change the picture.
    sc_same = Scenario(n=2 ** 16, dist=Weibull(0.7, 1.0),
                       predictor=PREDICTORS["good"])
    sc_unif = Scenario(n=2 ** 16, dist=Weibull(0.7, 1.0),
                       predictor=PREDICTORS["good"],
                       false_pred_dist=UniformDist(1.0))
    w_same = measured_waste(sc_same, n_runs, False)["OptimalPrediction"]
    w_unif = measured_waste(sc_unif, n_runs, False)["OptimalPrediction"]
    print(f"false-pred dist: same={w_same:.4f} uniform={w_unif:.4f} "
          f"(Appendix B: similar)")
    assert abs(w_same - w_unif) < 0.05
    rows.append({"predictor": "good", "cp": "equal", "N": "2^16",
                 "false_pred": "uniform",
                 "OptimalPrediction": round(w_unif, 4)})
    return rows


if __name__ == "__main__":
    run(quick=False)
