"""Paper Figures 3, 4 (and 10, 11): waste vs platform size.

For N = 2^14..2^19, both predictors, C_p in {C, 0.1C, 2C}, Weibull k=0.7
faults (the paper's richest setting): measured waste of RFO and
OptimalPrediction, plus their BestPeriod counterparts in full mode, and the
false-prediction distribution variant (same-as-faults vs uniform,
Appendix B).  One cartesian :class:`ExperimentSpec`; the BestPeriod search
runs over the same per-cell trace bank and result cache as the plain
strategies.
"""

from __future__ import annotations

from repro.experiments import (DistributionSpec, ExperimentSpec, ScenarioSpec,
                               StrategySpec, SweepSpec, register_experiment,
                               run_experiment)

from .common import CP_SCENARIOS, predictor_axis


def _strategies(with_best: bool) -> tuple[StrategySpec, ...]:
    strategies = (StrategySpec("rfo"), StrategySpec("optimal_prediction"))
    if with_best:
        strategies += (
            StrategySpec("best_period", {"base": "rfo", "n_points": 12}),
            StrategySpec("best_period", {"base": "optimal_prediction",
                                         "n_points": 12}),
        )
    return strategies


@register_experiment("waste_vs_n", "Figures 3-4/10-11: waste vs platform "
                                   "size over predictor x C_p x N")
def experiment(quick: bool = True) -> ExperimentSpec:
    preds, pred_names = predictor_axis()
    n_exps = [14, 16, 18] if quick else [14, 15, 16, 17, 18, 19]
    return ExperimentSpec(
        name="waste_vs_n",
        description="Waste of RFO / OptimalPrediction (+ BestPeriod) vs N",
        scenario=ScenarioSpec(dist=DistributionSpec("weibull", {"shape": 0.7}),
                              n_traces=4 if quick else 30),
        sweep=SweepSpec(
            axes={"recall,precision": preds,
                  "cp_ratio": list(CP_SCENARIOS.values()),
                  "n": [2 ** k for k in n_exps]},
            labels={"recall,precision": pred_names,
                    "cp_ratio": list(CP_SCENARIOS)},
            names={"recall,precision": "predictor", "cp_ratio": "cp"}),
        strategies=_strategies(with_best=not quick),
        metrics=("waste",),
    )


@register_experiment("false_pred_dist", "Appendix B: false-prediction dates "
                                        "same-as-faults vs uniform")
def false_pred_experiment(quick: bool = True) -> ExperimentSpec:
    return ExperimentSpec(
        name="false_pred_dist",
        description="OptimalPrediction waste under two false-prediction laws",
        scenario=ScenarioSpec(n=2 ** 16,
                              dist=DistributionSpec("weibull", {"shape": 0.7}),
                              n_traces=4 if quick else 30),
        sweep=SweepSpec(
            axes={"false_pred_dist": [None, DistributionSpec("uniform")]},
            labels={"false_pred_dist": ["same", "uniform"]},
            names={"false_pred_dist": "false_pred"}),
        strategies=(StrategySpec("optimal_prediction"),),
        metrics=("waste",),
    )


def run(quick: bool = True) -> list[dict]:
    _, pred_names = predictor_axis()
    exp = experiment(quick)
    n_exps = sorted({int(v) for v in exp.sweep.axes["n"]})
    table = run_experiment(exp)
    rows = []
    for pred_name in pred_names:
        for cp_name in CP_SCENARIOS:
            for n in n_exps:
                res = table.strategy_dict("waste", predictor=pred_name,
                                          cp=cp_name, n=n)
                row = {"predictor": pred_name, "cp": cp_name,
                       "N": f"2^{n.bit_length() - 1}",
                       **{k: round(v, 4) for k, v in res.items()}}
                rows.append(row)
                print(f"{pred_name} cp={cp_name} N=2^{n.bit_length() - 1}: "
                      f"RFO={res['RFO']:.3f} "
                      f"Opt={res['OptimalPrediction']:.3f}", flush=True)
    # Figure-level claims: waste grows with N; prediction helps except the
    # bad-predictor + expensive-proactive + largest-platform corner.
    by = {(r["predictor"], r["cp"], r["N"]): r for r in rows}
    big = f"2^{n_exps[-1].bit_length() - 1}"
    small = f"2^{n_exps[0].bit_length() - 1}"
    for p in pred_names:
        for cpn in CP_SCENARIOS:
            assert by[(p, cpn, big)]["RFO"] > by[(p, cpn, small)]["RFO"]
    for p in pred_names:
        r = by[(p, "cheap", big)]
        assert r["OptimalPrediction"] < r["RFO"]
    print("waste_vs_n: figure-level claims verified")

    # Appendix B: uniform false-prediction dates barely change the picture.
    fp_table = run_experiment(false_pred_experiment(quick))
    w_same = fp_table.value("waste", false_pred="same")
    w_unif = fp_table.value("waste", false_pred="uniform")
    print(f"false-pred dist: same={w_same:.4f} uniform={w_unif:.4f} "
          f"(Appendix B: similar)")
    assert abs(w_same - w_unif) < 0.05
    rows.append({"predictor": "good", "cp": "equal", "N": "2^16",
                 "false_pred": "uniform",
                 "OptimalPrediction": round(w_unif, 4)})
    return rows


if __name__ == "__main__":
    run(quick=False)
