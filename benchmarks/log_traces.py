"""Paper §5.3 / Tables 6-7: log-based failure traces (LANL-18/19-like).

The Failure Trace Archive files are offline-unavailable; per DESIGN.md §7 we
reproduce the *mechanism*: an empirical discrete distribution over
availability intervals (synthesized once to match the published LANL
per-processor MTBF and interval counts; the registered ``lanl`` distribution
is deterministic in its seed), resampled per 4-processor node and
superposed.  Parameters follow the paper: C = R = 60 s, D = 6 s, false
predictions uniform, TIME_base = 250 years / N.  The two logs sweep as one
compound axis (``dist,mu_ind``): each log pairs its interval set with its
published per-processor MTBF.
"""

from __future__ import annotations

from repro.experiments import (DistributionSpec, ExperimentSpec, ScenarioSpec,
                               SweepSpec, register_experiment, run_experiment)

from .common import STANDARD_STRATEGIES, gain, predictor_axis

LOGS = {
    "LANL18": dict(n_intervals=3010, mu_ind_days=691.0),
    "LANL19": dict(n_intervals=2343, mu_ind_days=679.0),
}

# Paper Tables 6-7 (days): {(log, n_exp, pred): (RFO, Opt, Inexact)}
PAPER = {
    ("LANL18", 14, "good"): (26.8, 24.4, 24.7),
    ("LANL18", 17, "good"): (4.88, 3.89, 4.20),
    ("LANL18", 14, "fair"): (26.8, 25.2, 25.5),
    ("LANL18", 17, "fair"): (4.88, 4.44, 4.73),
    ("LANL19", 14, "good"): (26.8, 24.4, 24.6),
    ("LANL19", 17, "good"): (4.86, 3.85, 4.14),
    ("LANL19", 14, "fair"): (26.8, 25.2, 25.4),
    ("LANL19", 17, "fair"): (4.86, 4.42, 4.71),
}


def _log_axis() -> list[tuple[DistributionSpec, float]]:
    """(empirical log distribution, per-processor MTBF in s) per LANL log."""
    return [(DistributionSpec("lanl", dict(seed=42, **kw)),
             kw["mu_ind_days"] * 86400.0)
            for kw in LOGS.values()]


@register_experiment("log_traces", "Tables 6-7: LANL-like log-based failure "
                                   "traces, 4-processor nodes")
def experiment(quick: bool = True) -> ExperimentSpec:
    preds, pred_names = predictor_axis()
    n_exps = [14] if quick else [10, 12, 14, 16, 17]
    return ExperimentSpec(
        name="log_traces",
        description="Execution time on empirical (LANL-like) interval logs",
        scenario=ScenarioSpec(c=60.0, r=60.0, d=6.0,
                              time_base_years_total=250.0,
                              false_pred_dist=DistributionSpec("uniform"),
                              procs_per_stream=4,
                              n_traces=4 if quick else 20),
        sweep=SweepSpec(
            axes={"dist,mu_ind": _log_axis(),
                  "recall,precision": preds,
                  "n": [2 ** k for k in n_exps]},
            labels={"dist,mu_ind": list(LOGS),
                    "recall,precision": pred_names},
            names={"dist,mu_ind": "log", "recall,precision": "predictor"}),
        strategies=STANDARD_STRATEGIES,
        metrics=("makespan_days",),
    )


def run(quick: bool = True) -> list[dict]:
    _, pred_names = predictor_axis()
    exp = experiment(quick)
    n_exps = [int(n).bit_length() - 1 for n in exp.sweep.axes["n"]]
    table = run_experiment(exp)
    rows = []
    for log_name in LOGS:
        for pred_name in pred_names:
            for n_exp in n_exps:
                res = table.strategy_dict("makespan_days", log=log_name,
                                          predictor=pred_name, n=2 ** n_exp)
                row = {"log": log_name, "predictor": pred_name,
                       "N": f"2^{n_exp}",
                       **{k: round(v, 2) for k, v in res.items()},
                       "gain_opt_pct": round(
                           gain(res, "OptimalPrediction"), 1)}
                paper = PAPER.get((log_name, n_exp, pred_name))
                row["paper_rfo_opt"] = paper[:2] if paper else None
                rows.append(row)
                print(f"{log_name} {pred_name} N=2^{n_exp}: "
                      f"RFO={res['RFO']:.2f}d "
                      f"Opt={res['OptimalPrediction']:.2f}d "
                      f"gain={row['gain_opt_pct']}% "
                      f"(paper {paper[:2] if paper else 'n/a'})",
                      flush=True)
                assert res["OptimalPrediction"] <= res["RFO"] * 1.02
    print("log_traces: prediction beneficial on log-based traces")
    return rows


if __name__ == "__main__":
    import os
    import sys
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))
    from benchmarks.run import record_benchmark
    record_benchmark("log_traces", {"rows": run(quick=False)}, quick=False)
