"""Paper §5.3 / Tables 6-7: log-based failure traces (LANL-18/19-like).

The Failure Trace Archive files are offline-unavailable; per DESIGN.md §7 we
reproduce the *mechanism*: an empirical discrete distribution over
availability intervals (synthesized once to match the published LANL
per-processor MTBF and interval counts), resampled per 4-processor node and
superposed.  Parameters follow the paper: C = R = 60 s, D = 6 s, false
predictions uniform, TIME_base = 250 years / N.
"""

from __future__ import annotations

import numpy as np

from repro.core.traces import UniformDist, lanl_like_log

from .common import PREDICTORS, Scenario, gain, run_scenario

LOGS = {
    "LANL18": dict(n_intervals=3010, mu_ind_days=691.0),
    "LANL19": dict(n_intervals=2343, mu_ind_days=679.0),
}

# Paper Tables 6-7 (days): {(log, n_exp, pred): (RFO, Opt, Inexact)}
PAPER = {
    ("LANL18", 14, "good"): (26.8, 24.4, 24.7),
    ("LANL18", 17, "good"): (4.88, 3.89, 4.20),
    ("LANL18", 14, "fair"): (26.8, 25.2, 25.5),
    ("LANL18", 17, "fair"): (4.88, 4.44, 4.73),
    ("LANL19", 14, "good"): (26.8, 24.4, 24.6),
    ("LANL19", 17, "good"): (4.86, 3.85, 4.14),
    ("LANL19", 14, "fair"): (26.8, 25.2, 25.4),
    ("LANL19", 17, "fair"): (4.86, 4.42, 4.71),
}


def run(quick: bool = True) -> list[dict]:
    n_runs = 4 if quick else 20
    n_exps = [14] if quick else [10, 12, 14, 16, 17]
    rows = []
    for log_name, log_kw in LOGS.items():
        emp = lanl_like_log(np.random.default_rng(42), **log_kw)
        for pred_name, pred in PREDICTORS.items():
            for n_exp in n_exps:
                sc = Scenario(
                    n=2 ** n_exp, dist=emp, predictor=pred,
                    c=60.0, r=60.0, d=6.0,
                    mu_ind=log_kw["mu_ind_days"] * 86400.0,
                    time_base_years_total=250.0,
                    false_pred_dist=UniformDist(1.0),
                    procs_per_stream=4)  # 4-processor nodes (paper §5.1)
                res = run_scenario(sc, n_runs=n_runs)
                row = {"log": log_name, "predictor": pred_name,
                       "N": f"2^{n_exp}",
                       **{k: round(v, 2) for k, v in res.items()},
                       "gain_opt_pct": round(
                           gain(res, "OptimalPrediction"), 1)}
                paper = PAPER.get((log_name, n_exp, pred_name))
                row["paper_rfo_opt"] = paper[:2] if paper else None
                rows.append(row)
                print(f"{log_name} {pred_name} N=2^{n_exp}: "
                      f"RFO={res['RFO']:.2f}d "
                      f"Opt={res['OptimalPrediction']:.2f}d "
                      f"gain={row['gain_opt_pct']}% "
                      f"(paper {paper[:2] if paper else 'n/a'})",
                      flush=True)
                assert res["OptimalPrediction"] <= res["RFO"] * 1.02
    print("log_traces: prediction beneficial on log-based traces")
    return rows


if __name__ == "__main__":
    run(quick=False)
