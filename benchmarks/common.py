"""Shared benchmark utilities: paper parameter sets + experiment drivers."""

from __future__ import annotations

import dataclasses
import math
import sys
import time

import numpy as np

from repro.core.policies import (Strategy, best_period, daly, evaluate,
                                 inexact_prediction, optimal_prediction, rfo,
                                 young)
from repro.core.prediction import PredictedPlatform, Predictor
from repro.core.traces import (Distribution, Exponential, UniformDist,
                               Weibull, lanl_like_log, make_event_trace)
from repro.core.waste import Platform

MU_IND_SYNTH = 125.0 * 365.0 * 86400.0     # paper §5.1, 125 years
PREDICTORS = {
    "good": Predictor(recall=0.85, precision=0.82),   # Yu et al. [7]
    "fair": Predictor(recall=0.70, precision=0.40),   # Zheng et al. [8]
}
CP_SCENARIOS = {"equal": 1.0, "cheap": 0.1, "expensive": 2.0}

SECONDS_PER_DAY = 86400.0


@dataclasses.dataclass
class Scenario:
    """One experiment cell: platform x predictor x distribution."""

    n: int
    dist: Distribution
    predictor: Predictor
    cp_ratio: float = 1.0
    c: float = 600.0
    r: float = 600.0
    d: float = 60.0
    mu_ind: float = MU_IND_SYNTH
    time_base_years_total: float = 10_000.0   # paper: 10000 years / N
    false_pred_dist: Distribution | None = None
    # Paper §5.1: faults are the superposition of per-processor renewal
    # streams (this, not the marginal law, is what makes Weibull k<1 hurt:
    # fresh processors burn in together), and the job starts one year into
    # the trace to avoid the synchronized-start artifact.
    per_processor: bool = True
    procs_per_stream: int = 1      # log-based traces: 4-processor nodes
    start: float = 365.0 * SECONDS_PER_DAY

    @property
    def mu(self) -> float:
        return self.mu_ind / self.n

    @property
    def platform(self) -> Platform:
        return Platform(mu=self.mu, c=self.c, d=self.d, r=self.r)

    @property
    def pp(self) -> PredictedPlatform:
        return PredictedPlatform(self.platform, self.predictor,
                                 cp=self.cp_ratio * self.c)

    @property
    def time_base(self) -> float:
        return self.time_base_years_total * 365.0 * SECONDS_PER_DAY / self.n

    def traces(self, n_runs: int, seed: int = 0):
        from repro.core.traces import EventTrace
        out = []
        n_streams = max(1, self.n // self.procs_per_stream) \
            if self.per_processor else None
        for i in range(n_runs):
            rng = np.random.default_rng(seed + 1009 * i)
            horizon = self.start \
                + max(60.0 * self.time_base, 50.0 * self.mu)
            tr = make_event_trace(
                self.dist, self.mu, self.predictor.recall,
                self.predictor.precision, horizon, rng,
                false_pred_dist=self.false_pred_dist,
                n_processors=n_streams)
            # Shift so the job starts `start` seconds into the trace.
            sel = tr.times >= self.start
            out.append(EventTrace(tr.times[sel] - self.start,
                                  tr.kinds[sel], horizon - self.start))
        return out


def standard_strategies(sc: Scenario) -> list[Strategy]:
    return [
        young(sc.platform),
        daly(sc.platform),
        rfo(sc.platform),
        optimal_prediction(sc.pp),
        inexact_prediction(sc.pp),   # 2C uncertainty window (paper §5.1)
    ]


def run_scenario(sc: Scenario, n_runs: int = 10, seed: int = 0,
                 with_best_period: bool = False) -> dict[str, float]:
    """Average makespans (in days) of the standard strategies."""
    traces = sc.traces(n_runs, seed)
    out: dict[str, float] = {}
    for strat in standard_strategies(sc):
        m = evaluate(strat, traces, sc.platform, sc.time_base,
                     sc.pp.cp, seed=seed)
        out[strat.name] = m / SECONDS_PER_DAY
        if with_best_period and strat.name in ("RFO", "OptimalPrediction"):
            refined, mbest = best_period(strat, traces, sc.platform,
                                         sc.time_base, sc.pp.cp, seed=seed)
            out[refined.name] = mbest / SECONDS_PER_DAY
    return out


def gain(row: dict[str, float], name: str, base: str = "RFO") -> float:
    """Percent improvement of strategy ``name`` over ``base``."""
    return 100.0 * (1.0 - row[name] / row[base])


def print_table(title: str, rows: list[dict], cols: list[str]) -> None:
    print(f"\n== {title} ==")
    header = " | ".join(f"{c:>22s}" for c in cols)
    print(header)
    print("-" * len(header))
    for row in rows:
        print(" | ".join(f"{row.get(c, ''):>22}" if isinstance(row.get(c), str)
                         else f"{row.get(c, float('nan')):>22.2f}"
                         for c in cols))
    sys.stdout.flush()
