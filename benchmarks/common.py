"""Shared benchmark utilities on top of :mod:`repro.experiments`.

Benchmarks declare their scenarios/sweeps as :class:`ExperimentSpec`s (each
module registers its experiment with ``@register_experiment``, so
``python -m benchmarks.run --list`` enumerates them) and evaluate through
the batched runner.  This module keeps only the paper parameter sets and
small table/printing helpers shared across scripts.
"""

from __future__ import annotations

import sys

from repro.experiments import (MU_IND_SYNTH, SECONDS_PER_DAY, PREDICTORS,
                               StrategySpec)

__all__ = [
    "MU_IND_SYNTH",
    "SECONDS_PER_DAY",
    "PREDICTORS",
    "CP_SCENARIOS",
    "STANDARD_STRATEGIES",
    "predictor_axis",
    "gain",
    "print_table",
]

# Proactive checkpoint cost scenarios C_p = ratio * C (paper §5.2 / Fig. 10-11).
CP_SCENARIOS = {"equal": 1.0, "cheap": 0.1, "expensive": 2.0}

# The five heuristics compared throughout §5 (paper Tables 3-7).
STANDARD_STRATEGIES = (
    StrategySpec("young"),
    StrategySpec("daly"),
    StrategySpec("rfo"),
    StrategySpec("optimal_prediction"),
    StrategySpec("inexact_prediction"),   # 2C uncertainty window (paper §5.1)
)


def predictor_axis(names: tuple[str, ...] = ("good", "fair")):
    """(axis values, labels) for a ``"recall,precision"`` sweep axis."""
    return [PREDICTORS[n] for n in names], list(names)


def gain(row: dict[str, float], name: str, base: str = "RFO") -> float:
    """Percent improvement of strategy ``name`` over ``base``."""
    return 100.0 * (1.0 - row[name] / row[base])


def print_table(title: str, rows: list[dict], cols: list[str]) -> None:
    print(f"\n== {title} ==")
    header = " | ".join(f"{c:>22s}" for c in cols)
    print(header)
    print("-" * len(header))
    for row in rows:
        print(" | ".join(f"{row.get(c, ''):>22}" if isinstance(row.get(c), str)
                         else f"{row.get(c, float('nan')):>22.2f}"
                         for c in cols))
    sys.stdout.flush()
