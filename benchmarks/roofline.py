"""§Roofline: per-(arch x shape) three-term roofline from the compiled dry-run.

Methodology (see EXPERIMENTS.md §Roofline):

  * XLA's ``cost_analysis()`` counts loop *bodies once*, so a scanned
    126-layer model reports ~1 layer of FLOPs.  We therefore lower ANALYSIS
    variants with 1 and 2 repeats of the block unit (inner chunk loops
    widened to one trip: attn_q_chunk = seq, mlstm_chunk = seq, microbatch
    scan removed — the total tokens per step are unchanged, so the true
    per-step compute is identical) and extrapolate linearly:

        F_total = F(1) + (n_rep - 1 + n_tail/unit) * (F(2) - F(1))

    The same correction applies to bytes-accessed and collective bytes.
    Residual undercount: the sLSTM time-step scan (xlstm archs) — its
    recurrent cell is O(4 d hd) per token (< 2% of block FLOPs), noted
    rather than corrected.
  * The peak per-device memory (does-it-fit) comes from the REAL config's
    dry-run (dryrun_results.json), not the analysis variant.
  * Hardware: TPU v5e (197 TFLOP/s bf16, 819 GB/s HBM, 50 GB/s ICI).

Must run under the dry-run device flag; use:
    PYTHONPATH=src python -m benchmarks.roofline --pairs all
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys

try:  # registry available when src/ is importable (the usual CLI setup)
    from repro.experiments import (ExperimentSpec, ScenarioSpec,
                                   build_experiment, register_experiment)
except ImportError:  # pragma: no cover - bare script usage
    register_experiment = None
    build_experiment = None


if register_experiment is not None:
    @register_experiment("roofline",
                         "per-(arch x shape) three-term roofline from the "
                         "compiled dry-run (subprocess: needs 512 XLA "
                         "host devices)")
    def build_spec(quick: bool = True) -> ExperimentSpec:
        """The accelerator sweep as spec data: the (arch x shape) grid,
        artifact paths and the XLA device requirement live in
        ``scenario.extras``, so the registry CLI can enumerate, override
        (``--set extras.pairs=...``) and dispatch it like any other
        experiment — it just runs in a subprocess with the dry-run device
        flag instead of through the trace runner."""
        return ExperimentSpec(
            name="roofline",
            scenario=ScenarioSpec(n_traces=0, extras={
                "external_runner": "benchmarks.roofline",
                "pairs": "tinyllama-1.1b:train_4k" if quick else "all",
                "rules": None,
                "tag": None,
                "dryrun_json": "dryrun_results.json",
                "out": "roofline_results.json",
                "xla_devices": 512,
            }),
            strategies=(),
            metrics=(),
            description="compiled-HLO roofline sweep (FLOPs / HBM / ICI "
                        "terms per arch x shape)",
        )


def spec_args(exp) -> tuple[list[str], dict[str, str]]:
    """Derive the subprocess argv tail + env for a spec-driven run.

    Shared by the registry CLI (``benchmarks.run --experiment roofline``)
    and ``--from-spec``; unit-testable without jax or the device flag.
    """
    extras = dict(exp.scenario.extras)
    args = ["--pairs", str(extras.get("pairs", "all")),
            "--dryrun-json", str(extras.get("dryrun_json",
                                            "dryrun_results.json")),
            "--out", str(extras.get("out", "roofline_results.json"))]
    if extras.get("rules"):
        args += ["--rules", str(extras["rules"])]
    if extras.get("tag"):
        args += ["--tag", str(extras["tag"])]
    for key, value in dict(extras.get("overrides", {})).items():
        args += ["--set", f"{key}={value}"]
    n_dev = int(extras.get("xla_devices", 512))
    env = {"XLA_FLAGS": f"--xla_force_host_platform_device_count={n_dev}"}
    return args, env


def _require_devices() -> None:
    # Honour a device-count flag already set by the caller (the registry
    # CLI's subprocess env, or --from-spec) — only default to 512 when
    # none is present, and import jax immediately to lock the flag.
    if "--xla_force_host_platform_device_count" not in os.environ.get(
            "XLA_FLAGS", ""):
        os.environ["XLA_FLAGS"] = \
            "--xla_force_host_platform_device_count=512"
        import jax  # noqa: F401  (locks the flag; must be first init)


def apply_overrides(cfg, overrides: dict):
    import dataclasses as _dc
    return _dc.replace(cfg, **overrides) if overrides else cfg


def analysis_cfg(cfg, shape, n_units: int):
    """Analysis variant: n_units repeats, unrolled loops, real chunking.

    Chunk sizes stay at production values (they define the actual work for
    chunkwise mLSTM and the block schedule for attention); unrolling makes
    every trip visible to cost_analysis.  Attention q-chunks are widened to
    2048 to bound HLO size (same total FLOPs — attention chunking is
    work-preserving, unlike mLSTM chunking)."""
    return dataclasses.replace(
        cfg, n_layers=n_units * len(cfg.block_unit), microbatches=1,
        attn_q_chunk=2048, attn_kv_chunk=4096,
        scan_layers=False, unroll_inner=True)


def measure(cfg, shape, mesh, rules=None) -> dict:
    from repro.launch import hlo
    from repro.launch.steps import lower_step
    pair = lower_step(cfg, shape, mesh, compile_now=True, rules=rules)
    cost = pair.compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    stats = hlo.collective_bytes(pair.compiled.as_text())
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "coll": float(stats.total),
        "n_coll": stats.n_ops,
    }


def corrected_pair(arch: str, shape_name: str, mesh, mesh_name: str,
                   fit_row: dict | None, overrides: dict | None = None,
                   rules=None) -> dict:
    from repro.configs import SHAPES, get
    from repro.launch import hlo
    from repro.launch.dryrun import model_flops

    cfg = apply_overrides(get(arch).for_shape(SHAPES[shape_name]),
                          overrides or {})
    shape = SHAPES[shape_name]
    unit = len(cfg.block_unit)
    n_rep = cfg.n_layers // unit
    n_tail = cfg.n_layers - n_rep * unit

    f1 = measure(analysis_cfg(cfg, shape, 1), shape, mesh, rules)
    if n_rep + n_tail / unit > 1:
        f2 = measure(analysis_cfg(cfg, shape, 2), shape, mesh, rules)
        mult = (n_rep - 1) + n_tail / unit
        tot = {k: f1[k] + mult * (f2[k] - f1[k])
               for k in ("flops", "bytes", "coll")}
        tot["n_coll"] = f1["n_coll"] + int(mult * (f2["n_coll"]
                                                   - f1["n_coll"]))
    else:
        tot = f1
    hw = hlo.V5E
    mf = model_flops(cfg, shape)
    n_dev = mesh.devices.size
    row = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "hlo_flops_per_dev": tot["flops"],
        "hlo_bytes_per_dev": tot["bytes"],
        "coll_bytes_per_dev": tot["coll"],
        "n_collectives": tot["n_coll"],
        "t_compute_s": tot["flops"] / hw.flops_bf16,
        "t_memory_s": tot["bytes"] / hw.hbm_bw,
        "t_collective_s": tot["coll"] / hw.ici_bw,
        "model_flops": mf,
        "useful_flops_ratio": mf / max(tot["flops"] * n_dev, 1.0),
        "bytes_per_device": (fit_row or {}).get("bytes_per_device"),
        "fits_hbm": (fit_row or {}).get("fits_hbm"),
    }
    terms = {"compute": row["t_compute_s"], "memory": row["t_memory_s"],
             "collective": row["t_collective_s"]}
    row["dominant"] = max(terms, key=terms.get)
    row["roofline_bound_s"] = max(terms.values())
    row["roofline_fraction"] = row["t_compute_s"] / max(
        row["roofline_bound_s"], 1e-12)
    return row


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--pairs", default=None,
                    help='"all" or comma list arch:shape (default: the '
                         'spec value with --from-spec, else "all")')
    ap.add_argument("--dryrun-json", default=None)
    ap.add_argument("--out", default=None)
    ap.add_argument("--append", action="store_true")
    ap.add_argument("--set", action="append", default=[],
                    help="cfg override key=value (python literal)")
    ap.add_argument("--rules", default=None,
                    choices=[None, "default", "seq_parallel", "decode"])
    ap.add_argument("--tag", default=None,
                    help="variant tag recorded with each row")
    ap.add_argument("--from-spec", default=None, metavar="NAME",
                    help="take pairs/artifact paths/overrides/device "
                         "count from the registered experiment spec "
                         "(e.g. 'roofline') as *defaults* — explicit "
                         "flags still win, --set entries append")
    ap.add_argument("--quick", action="store_true",
                    help="with --from-spec: the spec's quick-mode grid")
    args = ap.parse_args()

    # Spec extras are fallbacks for flags the user did not pass.
    extras: dict = {}
    if args.from_spec:
        if build_experiment is None:
            raise SystemExit("--from-spec needs the registry importable: "
                             "run with PYTHONPATH=src")
        exp = build_experiment(args.from_spec, quick=args.quick)
        extras = dict(exp.scenario.extras)
        args.set = [f"{k}={v}"
                    for k, v in dict(extras.get("overrides", {})).items()] \
            + args.set
        _, spec_env = spec_args(exp)
        os.environ.setdefault("XLA_FLAGS", spec_env["XLA_FLAGS"])
    if args.pairs is None:
        args.pairs = str(extras.get("pairs", "all"))
    if args.dryrun_json is None:
        args.dryrun_json = str(extras.get("dryrun_json",
                                          "dryrun_results.json"))
    if args.out is None:
        args.out = str(extras.get("out", "roofline_results.json"))
    if args.rules is None and extras.get("rules"):
        args.rules = str(extras["rules"])
    if args.tag is None and extras.get("tag"):
        args.tag = str(extras["tag"])

    _require_devices()
    import jax  # noqa: F401  (device flag locked above)
    from repro.configs import REGISTRY, SHAPES, get, skip_reason
    from repro.launch.mesh import make_production_mesh

    import ast
    overrides = {}
    for kv in args.set:
        k, v = kv.split("=", 1)
        try:
            overrides[k] = ast.literal_eval(v)
        except (ValueError, SyntaxError):
            overrides[k] = v
    rules = None
    if args.rules == "seq_parallel":
        from repro.parallel.sharding import SEQ_PARALLEL_RULES
        rules = SEQ_PARALLEL_RULES

    fits = {}
    if os.path.exists(args.dryrun_json):
        for r in json.load(open(args.dryrun_json)):
            if r.get("status") == "ok":
                fits[(r["arch"], r["shape"], r["mesh"])] = r

    mesh = make_production_mesh()
    mesh_name = "16x16"

    if args.pairs == "all":
        todo = [(c.name, s.name) for c in REGISTRY.values()
                for s in SHAPES.values() if not skip_reason(c, s)]
    else:
        todo = [tuple(p.split(":")) for p in args.pairs.split(",")]

    results = []
    if args.append and os.path.exists(args.out):
        results = json.load(open(args.out))
    done = {(r["arch"], r["shape"], r.get("tag")) for r in results}

    for arch, shape_name in todo:
        if (arch, shape_name, args.tag) in done:
            continue
        print(f"[roofline] {arch} x {shape_name} "
              f"{'(' + args.tag + ')' if args.tag else ''}...", flush=True)
        try:
            row = corrected_pair(arch, shape_name, mesh, mesh_name,
                                 fits.get((arch, shape_name, mesh_name)),
                                 overrides=overrides, rules=rules)
            if args.tag:
                row["tag"] = args.tag
                row["overrides"] = {k: str(v) for k, v in overrides.items()}
            print(f"  t_comp={row['t_compute_s']:.4f}s "
                  f"t_mem={row['t_memory_s']:.4f}s "
                  f"t_coll={row['t_collective_s']:.4f}s "
                  f"dominant={row['dominant']} "
                  f"useful={row['useful_flops_ratio']:.2f}", flush=True)
        except Exception as e:  # noqa: BLE001
            row = {"arch": arch, "shape": shape_name, "status": "error",
                   "error": f"{type(e).__name__}: {e}"}
            print(f"  ERROR {e}", flush=True)
        results.append(row)
        json.dump(results, open(args.out, "w"), indent=1)
    print(f"roofline -> {args.out}")


if __name__ == "__main__":
    main()
