"""Beyond the paper: two-level (hierarchical) checkpointing.

The paper's conclusion names hierarchical protocols as future work; this
benchmark quantifies the win with core/multilevel.py on TPU-flavoured
parameters: level-1 = in-HBM buddy copy (C1 ~ seconds), level-2 = durable
object-store write (C2 ~ minutes), soft-fault fraction phi = share of
failures survivable without losing device memory (preemptions, software
crashes — production incident reports put this at 60-85%).
"""

from __future__ import annotations

import numpy as np

from repro.core.multilevel import (TwoLevelPlatform, optimal_two_level,
                                   simulate_two_level)
from repro.core.simulator import NeverTrust, simulate
from repro.core.traces import EventTrace
from repro.core.waste import Platform, t_rfo, waste

MU_IND = 125.0 * 365.0 * 86400.0


def run(quick: bool = True) -> list[dict]:
    n_runs = 6 if quick else 30
    rows = []
    print("| N | phi | single waste | two-level waste | k* | T1* | "
          "sim 2-level |")
    for n_exp in (16, 18, 19):
        n = 2 ** n_exp
        mu = MU_IND / n
        for phi in (0.6, 0.8):
            p1 = Platform(mu=mu, c=600.0, d=60.0, r=600.0)
            p2 = TwoLevelPlatform(mu=mu, phi=phi, c1=30.0, c2=600.0,
                                  r1=30.0, r2=600.0, d=60.0)
            w1 = waste(t_rfo(p1), p1)
            t1, k, w2 = optimal_two_level(p2)
            # Simulation check.
            sims = []
            time_base = 10_000 * 365 * 86400 / n
            for seed in range(n_runs):
                r = np.random.default_rng(seed)
                need = int(5 * time_base / mu) + 50
                faults = np.cumsum(r.exponential(mu, size=need))
                soft = r.random(len(faults)) < phi
                sims.append(simulate_two_level(
                    faults, soft, p2, time_base, t1, k).waste)
            row = {"N": f"2^{n_exp}", "phi": phi,
                   "waste_single": round(w1, 4),
                   "waste_two_level": round(w2, 4),
                   "k_star": k, "t1_star": round(t1, 0),
                   "waste_sim": round(float(np.mean(sims)), 4),
                   "gain_pct": round(100 * (1 - w2 / w1), 1)}
            rows.append(row)
            print(f"| 2^{n_exp} | {phi} | {w1:.4f} | {w2:.4f} | {k} | "
                  f"{t1:.0f} | {np.mean(sims):.4f} |", flush=True)
            assert w2 < w1  # hierarchy must help with soft faults
    print("multilevel: two-level checkpointing verified")
    return rows


if __name__ == "__main__":
    run(quick=False)
