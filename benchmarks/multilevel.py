"""Beyond the paper: two-level (hierarchical) checkpointing.

The paper's conclusion names hierarchical protocols as future work; this
benchmark quantifies the win with core/multilevel.py on TPU-flavoured
parameters: level-1 = in-HBM buddy copy (C1 ~ seconds), level-2 = durable
object-store write (C2 ~ minutes), soft-fault fraction phi = share of
failures survivable without losing device memory (preemptions, software
crashes — production incident reports put this at 60-85%).

The grid is declared as an :class:`ExperimentSpec` (``extras.phi`` carries
the workload-specific knob); the two-level engine is its own simulator, so
the spec drives scenario construction/sweeping while evaluation stays with
``simulate_two_level``.
"""

from __future__ import annotations

import numpy as np

from repro.core.multilevel import TwoLevelPlatform, optimal_two_level, \
    simulate_two_level, two_level_stream
from repro.core.waste import t_rfo, waste
from repro.experiments import (DistributionSpec, ExperimentSpec, ScenarioSpec,
                               SweepSpec, register_experiment)


@register_experiment("multilevel", "Beyond the paper: two-level checkpointing "
                                   "(custom engine; run via --only multilevel)")
def experiment(quick: bool = True) -> ExperimentSpec:
    return ExperimentSpec(
        name="multilevel",
        description="Single-level RFO vs optimal two-level checkpointing",
        scenario=ScenarioSpec(dist=DistributionSpec("exponential"),
                              c=600.0, d=60.0, r=600.0,
                              extras={"phi": 0.6, "c1": 30.0, "r1": 30.0},
                              n_traces=6 if quick else 30),
        sweep=SweepSpec(axes={"n": [2 ** 16, 2 ** 18, 2 ** 19],
                              "extras.phi": [0.6, 0.8]},
                        names={"extras.phi": "phi"}),
        strategies=(),  # evaluated by the two-level engine below
        metrics=(),
    )


def run(quick: bool = True) -> list[dict]:
    exp = experiment(quick)
    rows = []
    print("| N | phi | single waste | two-level waste | k* | T1* | "
          "sim 2-level |")
    for cols, cell in exp.cells():
        phi = cell.extras["phi"]
        p1 = cell.platform
        p2 = TwoLevelPlatform(mu=cell.mu, phi=phi,
                              c1=cell.extras["c1"], c2=cell.c,
                              r1=cell.extras["r1"], r2=cell.r, d=cell.d)
        w1 = waste(t_rfo(p1), p1)
        t1, k, w2 = optimal_two_level(p2)
        # Simulation check: the stream rides the shared trace machinery
        # (hard = fail-stop stream, soft = silent stream; for Exponential
        # the superposition is rate 1/mu with soft probability phi).
        sims = []
        for seed in range(cell.n_traces):
            faults, soft = two_level_stream(
                p2, 5.0 * cell.time_base, np.random.default_rng(seed))
            sims.append(simulate_two_level(
                faults, soft, p2, cell.time_base, t1, k).waste)
        n_exp = cell.n.bit_length() - 1
        row = {"N": f"2^{n_exp}", "phi": phi,
               "waste_single": round(w1, 4),
               "waste_two_level": round(w2, 4),
               "k_star": k, "t1_star": round(t1, 0),
               "waste_sim": round(float(np.mean(sims)), 4),
               "gain_pct": round(100 * (1 - w2 / w1), 1)}
        rows.append(row)
        print(f"| 2^{n_exp} | {phi} | {w1:.4f} | {w2:.4f} | {k} | "
              f"{t1:.0f} | {np.mean(sims):.4f} |", flush=True)
        assert w2 < w1  # hierarchy must help with soft faults
    print("multilevel: two-level checkpointing verified")
    return rows


if __name__ == "__main__":
    run(quick=False)
