"""Serving example: batched prefill + decode across architecture families.

Serves three reduced architectures — dense GQA (llama3.2-1b), hybrid
RG-LRU (recurrentgemma-2b) and SSM (xlstm-125m) — through the same
ServingEngine API, demonstrating that KV caches, ring buffers and
recurrent states all hide behind one decode interface.  Greedy decoding is
checked to be deterministic.

Run:  PYTHONPATH=src python examples/serving.py
"""

import time

import jax
import numpy as np

from repro.configs import get
from repro.configs.base import InputShape
from repro.models.model import init_params, make_batch
from repro.serve import ServingEngine

ARCHS = ["llama3.2-1b", "recurrentgemma-2b", "xlstm-125m"]


def main() -> None:
    batch, prompt_len, n_new = 4, 48, 24
    for arch in ARCHS:
        cfg = get(arch).reduced()
        params, _ = init_params(cfg, jax.random.PRNGKey(0))
        engine = ServingEngine(cfg, params, cache_len=prompt_len + n_new)
        req = make_batch(cfg, InputShape("s", prompt_len, batch, "prefill"),
                         jax.random.PRNGKey(1))

        t0 = time.perf_counter()
        res = engine.generate(req, n_new)          # greedy
        jax.block_until_ready(res.tokens)
        dt = time.perf_counter() - t0

        res2 = engine.generate(req, n_new)         # determinism check
        assert np.array_equal(np.asarray(res.tokens),
                              np.asarray(res2.tokens))
        sampled = engine.generate(req, n_new, temperature=0.8, seed=3)

        print(f"{arch:22s} [{cfg.family:6s}] "
              f"{batch * n_new / dt:6.1f} tok/s  "
              f"greedy[0,:8]={res.tokens[0, :8].tolist()}  "
              f"mean_lp={float(res.logprobs.mean()):.2f}  "
              f"sampled_differs={not np.array_equal(np.asarray(res.tokens), np.asarray(sampled.tokens))}")
    print("serving: all families decode through one engine API")


if __name__ == "__main__":
    main()
