"""Waste-attribution telemetry end-to-end: trace a contended fleet run
and export a Perfetto-loadable timeline.

Three jobs with predicted faults share one storage stream and one repair
slot.  Each job carries a :class:`repro.obs.RecordingSink`, so every
checkpoint, proactive checkpoint, fault, rollback, re-execution span,
downtime/recovery window, prediction arrival and trust decision lands in
a structured event stream.  The script then:

  1. prints the per-job waste attribution — every simulated second
     bucketed into {work, ckpt, proactive_ckpt, re_exec, downtime,
     recovery, wait}, summing to the makespan *bit-for-bit*;
  2. writes ``trace_timeline.json``, a Chrome ``trace_event`` file: load
     it at https://ui.perfetto.dev (or chrome://tracing).  Jobs are
     tracks; checkpoints/downtime/recovery are slices; faults,
     rollbacks, predictions and trust decisions are instants.  One trace
     microsecond equals one simulated second.

Run:  PYTHONPATH=src python examples/trace_timeline.py [OUT.json]
"""

import sys

import numpy as np

from repro.experiments import ScenarioSpec, StrategySpec
from repro.fleet.sim import FleetJobInput, simulate_fleet
from repro.obs import RecordingSink, attribute_fleet_job, write_trace

N_JOBS = 3


def main(out_path: str = "trace_timeline.json") -> None:
    scenario = ScenarioSpec(n=2 ** 16, c=600.0, d=60.0, r=600.0,
                            n_traces=N_JOBS,
                            time_base_years_total=2000.0, seed=5)
    strat = StrategySpec("optimal_prediction").build(scenario)
    traces = scenario.make_traces()

    sinks = [RecordingSink() for _ in traces]
    fleet = simulate_fleet(
        [FleetJobInput(trace=tr, platform=scenario.platform,
                       time_base=scenario.time_base, period=strat.period,
                       cp=scenario.cp, trust=strat.trust,
                       rng=np.random.default_rng(scenario.seed + 7919 * i),
                       name=f"job{i}", sink=sinks[i])
         for i, tr in enumerate(traces)],
        storage_streams=1, repair_slots=1)

    print(f"fleet of {N_JOBS} jobs, 1 storage stream, 1 repair slot "
          f"(T={strat.period:.0f}s)")
    print(f"{'job':>6} {'makespan':>12}  work%  ckpt% prock%  reex%  "
          f"down%   rec%  wait%   events")
    for job, sink in zip(fleet.jobs, sinks):
        att = attribute_fleet_job(job)
        assert att.total() == job.sim.makespan  # exact bucket closure
        f = att.fractions()
        print(f"{job.name:>6} {job.sim.makespan:>12.1f} "
              + " ".join(f"{100 * f[b]:>6.2f}"
                         for b in ("work", "ckpt", "proactive_ckpt",
                                   "re_exec", "downtime", "recovery",
                                   "wait"))
              + f" {len(sink):>8}")

    write_trace(out_path,
                [(j.name, s.events) for j, s in zip(fleet.jobs, sinks)],
                title="fleet")
    print(f"\nwrote {out_path} — open it at https://ui.perfetto.dev")


if __name__ == "__main__":
    main(*sys.argv[1:2])
