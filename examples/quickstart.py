"""Quickstart: the paper's planner in five minutes.

1. Plan the optimal checkpoint period for a 512-chip pod, with and without
   a fault predictor (the paper's core contribution, §3-§4), by declaring
   the deployment as a serializable ScenarioSpec and looking the strategies
   up in the registry.
2. Measure the plan: one small ExperimentSpec through the batched runner.
3. Train a reduced llama3.2-1b for 60 steps with that schedule, injecting
   faults from a synthetic Weibull trace, and compare the measured waste
   against the analytic prediction.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import tempfile

import numpy as np

from repro.configs import get
from repro.configs.base import InputShape, PlatformConfig
from repro.core.exact import optimal_period_exact
from repro.core.prediction import beta_lim, optimal_period_with_prediction
from repro.core.traces import Weibull, make_event_trace
from repro.core.waste import t_rfo, waste
from repro.experiments import (DistributionSpec, ExperimentSpec, ScenarioSpec,
                               StrategySpec, build_strategy, run_experiment)
from repro.train import FaultTolerantTrainer


def main() -> None:
    # ---- 1. Analytic planning (paper §3/§4) -------------------------------
    print("=" * 64)
    print("1. Checkpoint planning for a 512-chip v5e deployment")
    print("=" * 64)
    # The whole deployment is one declarative, JSON-serializable spec.
    sc = ScenarioSpec(n=512, dist=DistributionSpec("weibull", {"shape": 0.7}),
                      recall=0.85, precision=0.82,   # Yu et al. predictor
                      c=600.0, d=60.0, r=600.0, n_traces=5)
    plat = sc.platform
    print(f"platform MTBF mu = {plat.mu / 3600:.1f} h  (mu_ind / {sc.n})")
    for name in ("young", "daly", "rfo"):
        strat = build_strategy(name, sc)
        print(f"{strat.name:5s} period : {strat.period:8.0f} s")
    print(f"RFO waste     : {waste(t_rfo(plat), plat):.4f}")

    pp = sc.pp
    t_star, w_star, use = optimal_period_with_prediction(pp)
    print(f"With the predictor: T* = {t_star:8.0f} s, waste {w_star:.4f}, "
          f"trust predictions past beta_lim = {beta_lim(pp):.0f} s")
    print(f"-> predicted waste reduction: "
          f"{100 * (1 - w_star / waste(t_rfo(plat), plat)):.1f}%")

    # The first-order model drops O((T/mu)^2) terms; the exact-Exponential
    # renewal analysis (repro.core.exact, sweepable via
    # ScenarioSpec.model_order="exact") re-plans both knobs.
    plan = optimal_period_exact(pp)
    print(f"Exact-Exponential plan: T* = {plan.period:8.0f} s, "
          f"beta* = {plan.threshold:.0f} s, exact waste {plan.waste:.4f} "
          f"(first-order T* was {t_star:.0f} s)")

    # ---- 2. Measure the plan with the batched runner ----------------------
    print()
    print("=" * 64)
    print("2. Simulated check (ExperimentSpec -> batched runner)")
    print("=" * 64)
    exp = ExperimentSpec(
        name="quickstart",
        scenario=sc,
        strategies=(StrategySpec("rfo"), StrategySpec("optimal_prediction"),
                    StrategySpec("best_period", {"base": "rfo",
                                                 "n_points": 8})),
        metrics=("makespan_days", "waste"),
    )
    print(f"spec round-trips through JSON: "
          f"{ExperimentSpec.from_json(exp.to_json()) == exp}")
    table = run_experiment(exp)
    print(table.format(["strategy", "period", "makespan_days", "waste"]))

    # ---- 3. End-to-end fault-tolerant training ------------------------------
    print()
    print("=" * 64)
    print("3. Fault-tolerant training (reduced llama3.2-1b, virtual clock)")
    print("=" * 64)
    cfg = get("llama3.2-1b").reduced()
    shape = InputShape("quickstart", 64, 4, "train")
    # Dense-fault platform so something actually happens in 60 steps.
    demo = PlatformConfig(mu_ind=300.0, c=30.0, cp=10.0, d=5.0, r=15.0,
                          recall=0.85, precision=0.82)
    trace = make_event_trace(Weibull(0.7, 1.0), 300.0, 0.85, 0.82,
                             horizon=1e5, rng=np.random.default_rng(1))
    with tempfile.TemporaryDirectory() as d:
        tr = FaultTolerantTrainer(cfg, shape, demo, workdir=d,
                                  step_time=10.0, trace=trace, seed=0)
        print(f"scheduler: T* = {tr.scheduler.period:.0f} s, "
              f"beta_lim = {tr.scheduler.decision.beta_lim:.1f} s, "
              f"analytic waste = "
              f"{tr.scheduler.decision.expected_waste:.3f}")
        stats = tr.run(60)
    print(f"steps secured      : {stats.n_steps}")
    print(f"faults / rollbacks : {stats.n_faults} / {stats.n_rollbacks}")
    print(f"periodic ckpts     : {stats.n_periodic}")
    print(f"proactive ckpts    : {stats.n_proactive} "
          f"({stats.n_trusted_true} before real faults)")
    print(f"final loss         : {stats.final_loss:.3f}")
    print(f"measured waste     : {stats.waste:.3f}")


if __name__ == "__main__":
    main()
