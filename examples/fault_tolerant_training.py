"""End-to-end driver: train a ~100M-param model for a few hundred steps
under injected faults with the paper's optimal checkpoint schedule.

Phase 1 (flagship): a ~100M-parameter xLSTM variant (d_model widened to
896, 10 layers, full 50k vocab) trains for --steps steps with Weibull
faults injected on a virtual clock.  Every rollback
restores real parameters/optimizer state from disk; proactive checkpoints
are delta-quantized (the C_p < C path).  Loss must decrease and the
measured waste is compared with the scheduler's analytic prediction.

Phase 2 (policy comparison): the same trace replayed against three
policies — Young (no predictor), RFO (no predictor), OptimalPrediction —
on the fast reduced config, reproducing the paper's ordering end-to-end.

Run:  PYTHONPATH=src python examples/fault_tolerant_training.py \
          [--steps 200] [--phase 1|2|all]
"""

import argparse
import dataclasses
import tempfile

import numpy as np

from repro.configs import get
from repro.configs.base import InputShape, PlatformConfig
from repro.core.traces import Weibull, make_event_trace
from repro.core.waste import Platform, t_young
from repro.train import FaultTolerantTrainer


def flagship_cfg():
    """~100M-parameter xLSTM (widened to d_model=896, 10 layers)."""
    cfg = get("xlstm-125m")
    cfg = dataclasses.replace(cfg, n_layers=10, d_model=896, head_dim=224,
                              name="xlstm-100m-demo", remat=False)
    return cfg


def phase1(steps: int) -> None:
    cfg = flagship_cfg()
    shape = InputShape("e2e", 128, 1, "train")
    print(f"== Phase 1: {cfg.name} (~{cfg.param_count()/1e6:.0f}M params), "
          f"{steps} steps, {shape.global_batch}x{shape.seq_len} tokens/step")
    plat = PlatformConfig(mu_ind=900.0, c=60.0, cp=20.0, d=10.0, r=30.0,
                          recall=0.85, precision=0.82)
    trace = make_event_trace(Weibull(0.7, 1.0), 900.0, 0.85, 0.82,
                             horizon=1e6, rng=np.random.default_rng(7))
    with tempfile.TemporaryDirectory() as d:
        tr = FaultTolerantTrainer(cfg, shape, plat, workdir=d,
                                  step_time=20.0, trace=trace, seed=0)
        print(f"   schedule: T*={tr.scheduler.period:.0f}s "
              f"beta_lim={tr.scheduler.decision.beta_lim:.1f}s "
              f"analytic waste={tr.scheduler.decision.expected_waste:.3f}")
        first_loss = None

        orig = tr._do_step

        def logged(stats):
            nonlocal first_loss
            m = orig(stats)
            step = int(tr.state["data_step"])
            if first_loss is None:
                first_loss = float(m["loss"])
            if step % 25 == 0:
                print(f"   step {step:4d} loss {float(m['loss']):.3f} "
                      f"(faults so far: {stats.n_faults})", flush=True)
            return m

        tr._do_step = logged
        stats = tr.run(steps)
    print(f"   secured {stats.n_steps} steps | faults {stats.n_faults} | "
          f"periodic {stats.n_periodic} | proactive {stats.n_proactive} "
          f"({stats.n_trusted_true} true)")
    print(f"   loss {first_loss:.3f} -> {stats.final_loss:.3f} | "
          f"measured waste {stats.waste:.3f}")
    assert stats.final_loss < first_loss, "loss must decrease"


def phase2(steps: int) -> None:
    print(f"\n== Phase 2: policy comparison (reduced config, {steps} steps)")
    cfg = get("llama3.2-1b").reduced()
    shape = InputShape("cmp", 64, 4, "train")
    plat = PlatformConfig(mu_ind=500.0, c=60.0, cp=20.0, d=10.0, r=30.0,
                          recall=0.85, precision=0.82)
    trace = make_event_trace(Weibull(0.7, 1.0), 500.0, 0.85, 0.82,
                             horizon=3e5, rng=np.random.default_rng(7))

    results = {}
    with tempfile.TemporaryDirectory() as d:
        young_T = t_young(Platform(mu=500.0, c=60.0, d=10.0, r=30.0))
        for name, use_pred, override in (
                ("Young", False, young_T),
                ("RFO", False, None),
                ("OptimalPrediction", True, None)):
            tr = FaultTolerantTrainer(cfg, shape, plat,
                                      workdir=f"{d}/{name}",
                                      step_time=20.0, trace=trace, seed=0,
                                      use_predictor=use_pred)
            if override is not None:
                tr.scheduler.decision = dataclasses.replace(
                    tr.scheduler.decision, period=override,
                    use_predictions=False)
            stats = tr.run(steps)
            results[name] = stats
            print(f"   {name:20s} waste={stats.waste:.3f} "
                  f"makespan={stats.total_time:7.0f}s "
                  f"faults={stats.n_faults} proactive={stats.n_proactive} "
                  f"loss={stats.final_loss:.3f}")
    gain = 100 * (1 - results["OptimalPrediction"].total_time
                  / results["RFO"].total_time)
    print(f"   OptimalPrediction vs RFO: {gain:.1f}% shorter makespan")
    assert results["OptimalPrediction"].waste <= results["RFO"].waste + 0.02


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--phase", default="all", choices=["1", "2", "all"])
    args = ap.parse_args()
    if args.phase in ("1", "all"):
        phase1(args.steps)
    if args.phase in ("2", "all"):
        phase2(min(args.steps, 120))


if __name__ == "__main__":
    main()
