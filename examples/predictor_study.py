"""Predictor design study: what should a fault-predictor team optimize?

Reproduces the paper's §5.4 conclusion ("better safe than sorry": recall
beats precision) and extends it with the analytic model: iso-waste curves
over the (recall, precision) plane for a 2^16-processor platform, plus the
break-even precision below which predictions should be ignored entirely.

The (recall, precision) plane is generated with the experiment API's
SweepSpec — the same declarative axes the simulation benchmarks use — and
each cell's predicted platform comes from its ScenarioSpec.

Run:  PYTHONPATH=src python examples/predictor_study.py
"""

import numpy as np

from repro.core.prediction import optimal_period_with_prediction
from repro.core.waste import t_rfo, waste
from repro.experiments import ScenarioSpec, SweepSpec


def main() -> None:
    base = ScenarioSpec(n=2 ** 16, c=600.0, d=60.0, r=600.0)
    plat = base.platform
    w_nopred = waste(t_rfo(plat), plat)
    print(f"platform: N=2^16, mu={plat.mu:.0f}s; "
          f"RFO waste without predictor = {w_nopred:.4f}\n")

    grid = [0.1, 0.3, 0.5, 0.7, 0.9, 0.99]
    sweep = SweepSpec(axes={"recall": grid, "precision": grid})
    cells = {(c["recall"], c["precision"]): sc for c, sc in sweep.cells(base)}
    print("analytic waste of OptimalPrediction (rows: recall; "
          "cols: precision)")
    print("        " + "".join(f"p={p:<7.2f}" for p in grid))
    for r in grid:
        row = []
        for p in grid:
            _, w, used = optimal_period_with_prediction(cells[(r, p)].pp)
            row.append(f"{w:.4f}{'*' if not used else ' '}  ")
        print(f"r={r:<5.2f} " + "".join(row))
    print("(* = predictor analytically not worth using)\n")

    # Sensitivity: d(waste)/d(recall) vs d(waste)/d(precision) at the
    # literature predictor point (paper §5.4).
    r0, p0, eps = 0.7, 0.7, 0.05

    def w_at(r, p):
        sc = base.replace(recall=r, precision=p)
        return optimal_period_with_prediction(sc.pp)[1]

    dr = (w_at(r0 + eps, p0) - w_at(r0 - eps, p0)) / (2 * eps)
    dp = (w_at(r0, p0 + eps) - w_at(r0, p0 - eps)) / (2 * eps)
    print(f"at (r={r0}, p={p0}): dWaste/dRecall = {dr:+.4f}, "
          f"dWaste/dPrecision = {dp:+.4f}")
    print(f"-> recall is {abs(dr / dp):.1f}x more valuable than precision "
          f"(paper §5.4: invest in recall)")
    assert abs(dr) > abs(dp)

    # Break-even: smallest precision at which predictions still help,
    # as a function of C_p/C.
    print("\nbreak-even precision (predictions worth using) vs C_p/C:")
    for cp_ratio in (0.1, 0.5, 1.0, 2.0):
        lo = None
        for p in np.linspace(0.01, 0.99, 99):
            sc = base.replace(recall=0.85, precision=float(p),
                              cp_ratio=cp_ratio)
            if optimal_period_with_prediction(sc.pp)[2]:
                lo = p
                break
        print(f"  C_p = {cp_ratio:>4.1f} C : p_breakeven ~ "
              f"{lo if lo is not None else '>0.99'}"
              f"{'' if lo else ' (never worth it)'}")


if __name__ == "__main__":
    main()
