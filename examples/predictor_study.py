"""Predictor design study on the generative predictor subsystem.

Part 1 (analytic, paper §5.4): what should a fault-predictor team
optimize?  Iso-waste over the (recall, precision) plane says recall —
reproduced with the experiment API's SweepSpec.

Part 2 (generative, repro.predictors): what happens when the predictor is
not the idealized stamp?  A ``drifting`` predictor degrades from the
"good" literature predictor (r=0.85, p=0.82) to a poor one *during* the
run; the static paper-optimal plan keeps trusting with the stale
beta_lim while the ``adaptive`` strategy tracks (r-hat, p-hat) online
(``repro.predictors.estimator``) and re-plans period + trust threshold as
the estimates drift.

Run:  PYTHONPATH=src python examples/predictor_study.py
"""

import numpy as np

from repro.core.batch import simulate_batch
from repro.core.prediction import optimal_period_with_prediction
from repro.core.waste import t_rfo, waste
from repro.experiments import (PredictorSpec, ScenarioSpec, SweepSpec,
                               build_strategy, evaluate_strategies,
                               trace_bank)


def analytic_plane() -> None:
    base = ScenarioSpec(n=2 ** 16, c=600.0, d=60.0, r=600.0)
    plat = base.platform
    w_nopred = waste(t_rfo(plat), plat)
    print(f"platform: N=2^16, mu={plat.mu:.0f}s; "
          f"RFO waste without predictor = {w_nopred:.4f}\n")

    grid = [0.1, 0.3, 0.5, 0.7, 0.9, 0.99]
    sweep = SweepSpec(axes={"recall": grid, "precision": grid})
    cells = {(c["recall"], c["precision"]): sc for c, sc in sweep.cells(base)}
    print("analytic waste of OptimalPrediction (rows: recall; "
          "cols: precision)")
    print("        " + "".join(f"p={p:<7.2f}" for p in grid))
    for r in grid:
        row = []
        for p in grid:
            _, w, used = optimal_period_with_prediction(cells[(r, p)].pp)
            row.append(f"{w:.4f}{'*' if not used else ' '}  ")
        print(f"r={r:<5.2f} " + "".join(row))
    print("(* = predictor analytically not worth using)")

    # Sensitivity at the literature predictor point (paper §5.4).
    r0, p0, eps = 0.7, 0.7, 0.05

    def w_at(r, p):
        return optimal_period_with_prediction(
            base.replace(recall=r, precision=p).pp)[1]

    dr = (w_at(r0 + eps, p0) - w_at(r0 - eps, p0)) / (2 * eps)
    dp = (w_at(r0, p0 + eps) - w_at(r0, p0 - eps)) / (2 * eps)
    print(f"\nat (r={r0}, p={p0}): dWaste/dRecall = {dr:+.4f}, "
          f"dWaste/dPrecision = {dp:+.4f} -> invest in recall "
          f"({abs(dr / dp):.1f}x more valuable)\n")
    assert abs(dr) > abs(dp)


def adaptive_demo() -> None:
    # The drift ramp is placed inside the job window: quality starts
    # degrading when the job starts and bottoms out two time_bases later.
    base = ScenarioSpec(n_traces=5, time_base_years_total=40000.0)
    sc = base.replace(predictor=PredictorSpec("drifting", {
        "precision_end": 0.25, "recall_end": 0.5,
        "drift_start": base.start, "drift_span": 2.0 * base.time_base}))
    traces = trace_bank(sc)
    plat, tb, cp = sc.platform, sc.time_base, sc.cp

    static = build_strategy("optimal_prediction", sc)
    adaptive = build_strategy("adaptive", sc, tol=0.03)
    rfo = build_strategy("rfo", sc)
    print("drifting predictor: (r, p) = (0.85, 0.82) -> (0.50, 0.25) "
          "during the run")
    m_rfo, m_static, m_ad = evaluate_strategies(
        traces, plat, tb, cp, [rfo, static, adaptive], seed=sc.seed)
    print(f"  RFO (ignore predictor):      {m_rfo / 86400:8.2f} days")
    print(f"  OptimalPrediction (static):  {m_static / 86400:8.2f} days")
    print(f"  Adaptive (online re-plan):   {m_ad / 86400:8.2f} days")

    # Inside the adaptive runs: what did the estimator see and do?
    batch = simulate_batch(
        traces, plat, tb, [adaptive.period], cp=cp, trust=adaptive.trust,
        adaptive=adaptive.adaptive,
        trace_seeds=[sc.seed + 7919 * i for i in range(len(traces))])
    print("\nper-trace adaptive diagnostics (start plan: "
          f"T={adaptive.period:.0f}s, beta_lim={adaptive.trust.threshold:.0f}s):")
    for ti in range(len(traces)):
        res = batch.result(0, ti)
        print(f"  trace {ti}: {res.n_replans:2d} replans -> "
              f"T={res.final_period:8.0f}s "
              f"thr={res.final_threshold:7.1f}s  "
              f"r-hat={res.est_recall:.3f} p-hat={res.est_precision:.3f}")
    assert all(batch.n_replans[0] >= 1), "drift must trigger re-planning"
    # The estimator should have noticed the degradation (estimates are
    # run-averages, so they sit between the start and end quality).
    assert float(batch.est_precision[0].mean()) < 0.75
    print("\nthe adaptive strategy noticed the degradation (p-hat well "
          "below the nominal 0.82) and re-planned; the static plan kept "
          "trusting a predictor that no longer deserved it")


def main() -> None:
    analytic_plane()
    adaptive_demo()


if __name__ == "__main__":
    main()
