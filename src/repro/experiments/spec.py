"""Declarative experiment specifications (serializable, registry-backed).

The paper's results are all *sweeps* — over platform size, recall/precision,
proactive-checkpoint cost, candidate periods.  This module turns one sweep
cell and one sweep into data:

  * :class:`DistributionSpec` — a trace distribution by registry name + params;
  * :class:`ScenarioSpec`     — platform + predictor + trace distribution +
                                time_base + seed (one simulation cell);
  * :class:`StrategySpec`     — a strategy by registry name + params;
  * :class:`SweepSpec`        — named axes over any scenario field, cartesian
                                or zipped;
  * :class:`ExperimentSpec`   — scenario x strategies x metrics.

Every spec round-trips through ``to_dict`` / ``from_dict`` (plain JSON types
only), so experiments can be defined in JSON or on the CLI as well as in
code.  Building runtime objects (``Distribution``, ``Strategy``, traces)
goes through :mod:`repro.experiments.registry`.
"""

from __future__ import annotations

import dataclasses
import itertools
import json
from typing import Any, Iterator, Mapping

import numpy as np

from repro.core.prediction import PredictedPlatform, Predictor
from repro.core.traces import (Distribution, EventTrace, make_event_trace,
                               make_event_trace_bank)
from repro.core.waste import Platform

__all__ = [
    "SECONDS_PER_DAY",
    "MU_IND_SYNTH",
    "DistributionSpec",
    "PredictorSpec",
    "ScenarioSpec",
    "StrategySpec",
    "SweepSpec",
    "ExperimentSpec",
]

SECONDS_PER_DAY = 86400.0
MU_IND_SYNTH = 125.0 * 365.0 * 86400.0  # paper §5.1: 125-year individual MTBF


def _normalize(value: Any) -> Any:
    """Canonicalize lists to tuples (deep) so specs compare equal across a
    JSON round-trip (lists) and literal construction (tuples)."""
    if isinstance(value, (list, tuple)):
        return tuple(_normalize(v) for v in value)
    if isinstance(value, Mapping):
        return {str(k): _normalize(v) for k, v in value.items()}
    return value


def _jsonable(value: Any) -> Any:
    """Convert a spec field value to plain JSON types."""
    if dataclasses.is_dataclass(value) and hasattr(value, "to_dict"):
        return value.to_dict()
    if isinstance(value, (np.floating, np.integer)):
        return value.item()
    if isinstance(value, (list, tuple)):
        return [_jsonable(v) for v in value]
    if isinstance(value, Mapping):
        return {str(k): _jsonable(v) for k, v in value.items()}
    return value


@dataclasses.dataclass(frozen=True)
class DistributionSpec:
    """A trace distribution referenced by registry name, e.g.
    ``DistributionSpec("weibull", {"shape": 0.7})``."""

    name: str
    params: dict = dataclasses.field(default_factory=dict)

    def __post_init__(self) -> None:
        object.__setattr__(self, "params", _normalize(self.params))

    def build(self) -> Distribution:
        from .registry import build_distribution
        return build_distribution(self.name, **self.params)

    def to_dict(self) -> dict:
        return {"name": self.name, "params": _jsonable(self.params)}

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "DistributionSpec":
        return cls(name=d["name"], params=dict(d.get("params", {})))


def _coerce_dist(value: Any) -> DistributionSpec | None:
    if value is None or isinstance(value, DistributionSpec):
        return value
    if isinstance(value, Mapping):
        return DistributionSpec.from_dict(value)
    raise TypeError(f"cannot coerce {value!r} into a DistributionSpec")


@dataclasses.dataclass(frozen=True)
class PredictorSpec:
    """A generative predictor model by registry name, e.g.
    ``PredictorSpec("drifting", {"precision_end": 0.3})``.

    The model is built at the scenario's nominal (recall, precision) —
    params carry only the model-specific knobs — so sweeping the nominal
    axis and the model family compose.  ``None`` on the scenario means the
    ``oracle`` stamping (bit-for-bit the legacy traces).
    """

    name: str
    params: dict = dataclasses.field(default_factory=dict)

    def __post_init__(self) -> None:
        object.__setattr__(self, "params", _normalize(self.params))

    def build(self, recall: float, precision: float):
        from repro.predictors import build_predictor
        return build_predictor(self.name, recall, precision, **self.params)

    def to_dict(self) -> dict:
        return {"name": self.name, "params": _jsonable(self.params)}

    @classmethod
    def from_dict(cls, d: Mapping[str, Any] | str) -> "PredictorSpec":
        if isinstance(d, str):
            return cls(name=d)
        return cls(name=d["name"], params=dict(d.get("params", {})))


def _coerce_pred(value: Any) -> PredictorSpec | None:
    if value is None or isinstance(value, PredictorSpec):
        return value
    if isinstance(value, (Mapping, str)):
        return PredictorSpec.from_dict(value)
    raise TypeError(f"cannot coerce {value!r} into a PredictorSpec")


@dataclasses.dataclass(frozen=True)
class ScenarioSpec:
    """One simulation cell (paper §5.1 defaults).

    Mirrors the paper's synthetic setting: N processors of individual MTBF
    ``mu_ind`` (platform MTBF mu = mu_ind / N), checkpoints C/R/D, a fault
    predictor (recall, precision) with proactive cost C_p = cp_ratio * C,
    faults drawn from ``dist`` (superposed per-processor streams when
    ``per_processor``), and a job of ``time_base_years_total / N`` years
    starting ``start`` seconds into the trace.

    ``window`` is the prediction-window length I (arXiv:1302.4558): with
    I > 0 every prediction event in the scenario's traces announces the
    interval [t, t+I] and the true fault materializes uniformly inside it.
    ``window=0`` (default) keeps exact-date predictions, bit-for-bit.

    ``predictor`` selects the generative predictor model
    (:mod:`repro.predictors`) that turns the fault stream into the
    prediction stream; ``None`` (default) is the ``oracle`` stamping at
    the nominal (recall, precision), bit-for-bit the legacy traces.
    Model-emitted per-event windows (e.g. ``lead_time``) take precedence
    over the constant ``window`` stamping.

    ``model_order`` selects the *analysis order* scenario-aware strategies
    plan with: ``"first"`` (default) is the paper's first-order waste model
    (Eqs. 12/15), ``"exact"`` the exact-Exponential renewal analysis of
    :mod:`repro.core.exact` (arXiv:1207.6936).  The order-aware registered
    strategies (``nopred``, ``prediction``, ``adaptive``) consult it, so a
    sweep axis ``{"model_order": ["first", "exact"]}`` compares the two
    analyses cell by cell on identical trace banks.
    """

    n: int = 2 ** 16
    dist: DistributionSpec = dataclasses.field(
        default_factory=lambda: DistributionSpec("exponential"))
    recall: float = 0.85
    precision: float = 0.82
    window: float = 0.0
    predictor: PredictorSpec | None = None
    model_order: str = "first"
    # Silent-error / verification axis (arXiv:1310.8486; core/silent.py):
    # ``silent_mu_ind`` is the per-processor silent-corruption MTBF (None =
    # no silent stream, bit-for-bit the legacy traces); the remaining three
    # are the scenario's default verification knobs, consulted by the
    # silent strategies the way ``window`` is by the window family.
    silent_mu_ind: float | None = None
    verify_cost: float = 0.0
    n_verify: int = 0
    keep_ckpts: int = 1
    cp_ratio: float = 1.0
    c: float = 600.0
    r: float = 600.0
    d: float = 60.0
    mu_ind: float = MU_IND_SYNTH
    time_base_years_total: float = 10_000.0
    false_pred_dist: DistributionSpec | None = None
    per_processor: bool = True
    procs_per_stream: int = 1
    start: float = 365.0 * SECONDS_PER_DAY
    n_traces: int = 10
    seed: int = 0
    extras: dict = dataclasses.field(default_factory=dict)

    def __post_init__(self) -> None:
        object.__setattr__(self, "dist", _coerce_dist(self.dist))
        object.__setattr__(self, "false_pred_dist",
                           _coerce_dist(self.false_pred_dist))
        object.__setattr__(self, "predictor", _coerce_pred(self.predictor))
        object.__setattr__(self, "extras", _normalize(self.extras))
        if self.model_order not in ("first", "exact"):
            raise ValueError(f"model_order must be 'first' or 'exact', "
                             f"got {self.model_order!r}")
        if self.silent_mu_ind is not None and not self.silent_mu_ind > 0:
            raise ValueError(f"silent_mu_ind must be positive or None, "
                             f"got {self.silent_mu_ind}")
        if not self.verify_cost >= 0.0:
            raise ValueError(f"verify_cost must be >= 0, "
                             f"got {self.verify_cost}")
        if self.n_verify < 0:
            raise ValueError(f"n_verify must be >= 0, got {self.n_verify}")
        if self.keep_ckpts < 1:
            raise ValueError(f"keep_ckpts must be >= 1, "
                             f"got {self.keep_ckpts}")

    # -- derived quantities --------------------------------------------------

    @property
    def mu(self) -> float:
        return self.mu_ind / self.n

    @property
    def silent_mu(self) -> float | None:
        """Platform-level silent-corruption MTBF (None = stream off)."""
        if self.silent_mu_ind is None:
            return None
        return self.silent_mu_ind / self.n

    @property
    def platform(self) -> Platform:
        return Platform(mu=self.mu, c=self.c, d=self.d, r=self.r)

    @property
    def nominal_predictor(self) -> Predictor:
        """The (recall, precision) pair as the analytic-model Predictor."""
        return Predictor(recall=self.recall, precision=self.precision)

    @property
    def pp(self) -> PredictedPlatform:
        return PredictedPlatform(self.platform, self.nominal_predictor,
                                 cp=self.cp_ratio * self.c)

    @property
    def cp(self) -> float:
        return self.cp_ratio * self.c

    @property
    def time_base(self) -> float:
        return self.time_base_years_total * 365.0 * SECONDS_PER_DAY / self.n

    @property
    def horizon(self) -> float:
        return self.start + max(60.0 * self.time_base, 50.0 * self.mu)

    # -- trace generation ----------------------------------------------------

    def _stream_args(self) -> tuple[int | None, Distribution | None]:
        n_streams = (max(1, self.n // self.procs_per_stream)
                     if self.per_processor else None)
        fdist = (self.false_pred_dist.build()
                 if self.false_pred_dist is not None else None)
        return n_streams, fdist

    def _predictor_model(self):
        """The built generative predictor model, or None (oracle path)."""
        if self.predictor is None:
            return None
        return self.predictor.build(self.recall, self.precision)

    def _shift(self, tr: EventTrace) -> EventTrace:
        # Shift so the job starts ``start`` seconds into the trace (avoids
        # the synchronized-processor-start artifact, paper §5.1).
        sel = tr.times >= self.start
        return EventTrace(tr.times[sel] - self.start, tr.kinds[sel],
                          self.horizon - self.start,
                          windows=None if tr.windows is None
                          else tr.windows[sel])

    def make_trace(self, index: int, seed: int | None = None) -> EventTrace:
        """Trace ``index`` of this scenario's bank (seeded, reproducible)."""
        seed = self.seed if seed is None else seed
        rng = np.random.default_rng(seed + 1009 * index)
        n_streams, fdist = self._stream_args()
        tr = make_event_trace(
            self.dist.build(), self.mu, self.recall, self.precision,
            self.horizon, rng, false_pred_dist=fdist, n_processors=n_streams,
            window=self.window, predictor_model=self._predictor_model(),
            silent_mu=self.silent_mu)
        return self._shift(tr)

    def make_traces(self, n_traces: int | None = None,
                    seed: int | None = None, *,
                    batched: bool = False) -> list[EventTrace]:
        """The scenario's trace bank.

        ``batched=True`` samples the whole bank in shared RNG waves
        (:func:`repro.core.traces.make_event_trace_bank`) — statistically
        identical; ~4x faster when the bank is many small traces (the
        per-trace Python overhead dominates) and a wash at paper-scale
        superposition where each trace already saturates the vectorized
        wave path (see ``BENCH_simulator.json``).  Drawn from one
        ``default_rng([seed, n])`` stream rather than the per-trace
        ``default_rng(seed + 1009*i)`` streams, so the two modes produce
        different (equally valid) banks.
        """
        n = self.n_traces if n_traces is None else n_traces
        if not batched:
            return [self.make_trace(i, seed=seed) for i in range(n)]
        seed = self.seed if seed is None else seed
        rng = np.random.default_rng([seed, n])
        n_streams, fdist = self._stream_args()
        bank = make_event_trace_bank(
            self.dist.build(), self.mu, self.recall, self.precision,
            self.horizon, rng, false_pred_dist=fdist,
            n_processors=n_streams, n_traces=n, window=self.window,
            predictor_model=self._predictor_model(),
            silent_mu=self.silent_mu)
        return [self._shift(tr) for tr in bank]

    # -- field update (dotted paths; how sweeps and the CLI set fields) ------

    def replace(self, **updates: Any) -> "ScenarioSpec":
        """``dataclasses.replace`` accepting dotted paths as keys.

        ``spec.replace(**{"n": 512, "dist.params.shape": 0.5})`` returns a
        new spec with the nested distribution parameter updated.
        """
        spec = self
        for path, value in updates.items():
            spec = spec._replace_path(path, value)
        return spec

    def _replace_path(self, path: str, value: Any) -> "ScenarioSpec":
        head, _, rest = path.partition(".")
        if not hasattr(self, head):
            raise KeyError(f"ScenarioSpec has no field {head!r}")
        if not rest:
            return dataclasses.replace(self, **{head: value})
        current = getattr(self, head)
        if head == "predictor" and current is None:
            # Descending into an unset predictor starts from the oracle.
            current = PredictorSpec("oracle")
        if isinstance(current, (DistributionSpec, PredictorSpec)):
            sub_head, _, sub_rest = rest.partition(".")
            if sub_head == "name" and not sub_rest:
                new = dataclasses.replace(current, name=value)
            elif sub_head == "params":
                params = dict(current.params)
                if sub_rest:
                    params[sub_rest] = value
                else:
                    params = dict(value)
                new = dataclasses.replace(current, params=params)
            else:
                raise KeyError(f"unknown {head} field {rest!r}")
            return dataclasses.replace(self, **{head: new})
        if isinstance(current, Mapping):
            sub = dict(current)
            sub[rest] = value
            return dataclasses.replace(self, **{head: sub})
        raise KeyError(f"cannot descend into scalar field {head!r}")

    # -- serialization -------------------------------------------------------

    def to_dict(self) -> dict:
        out = {}
        for f in dataclasses.fields(self):
            out[f.name] = _jsonable(getattr(self, f.name))
        return out

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "ScenarioSpec":
        known = {f.name for f in dataclasses.fields(cls)}
        unknown = set(d) - known
        if unknown:
            raise KeyError(f"unknown ScenarioSpec fields: {sorted(unknown)}")
        kw = dict(d)
        if "dist" in kw:
            kw["dist"] = _coerce_dist(kw["dist"])
        if kw.get("false_pred_dist") is not None:
            kw["false_pred_dist"] = _coerce_dist(kw["false_pred_dist"])
        if kw.get("predictor") is not None:
            kw["predictor"] = _coerce_pred(kw["predictor"])
        return cls(**kw)

    def key(self) -> str:
        """Canonical JSON string (cache key for the runner's trace bank)."""
        return json.dumps(self.to_dict(), sort_keys=True)


@dataclasses.dataclass(frozen=True)
class StrategySpec:
    """A checkpointing strategy by registry name + params.

    ``label`` overrides the display name in result tables.  Examples::

        StrategySpec("rfo")
        StrategySpec("inexact_prediction", {"window": 1200.0})
        StrategySpec("best_period", {"base": "rfo", "n_points": 12})
    """

    name: str
    params: dict = dataclasses.field(default_factory=dict)
    label: str | None = None

    def build(self, scenario: ScenarioSpec):
        from .registry import build_strategy
        return build_strategy(self.name, scenario, **self.params)

    @property
    def display(self) -> str:
        return self.label if self.label is not None else self.name

    def to_dict(self) -> dict:
        out: dict[str, Any] = {"name": self.name,
                               "params": _jsonable(self.params)}
        if self.label is not None:
            out["label"] = self.label
        return out

    @classmethod
    def from_dict(cls, d: Mapping[str, Any] | str) -> "StrategySpec":
        if isinstance(d, str):
            return cls(name=d)
        return cls(name=d["name"], params=dict(d.get("params", {})),
                   label=d.get("label"))


@dataclasses.dataclass(frozen=True)
class SweepSpec:
    """Named axes over scenario fields, cartesian (default) or zipped.

    Axis keys are dotted field paths into :class:`ScenarioSpec`
    (``"n"``, ``"dist.params.shape"``, ``"extras.phi"``); a comma-separated
    key sweeps several fields together (``"recall,precision"`` with value
    pairs).  ``labels`` optionally maps an axis key to display values used
    in result-table columns (e.g. predictor names instead of number pairs);
    ``names`` renames an axis's result-table column (e.g.
    ``{"recall,precision": "predictor"}``).
    """

    axes: dict = dataclasses.field(default_factory=dict)
    mode: str = "cartesian"
    labels: dict = dataclasses.field(default_factory=dict)
    names: dict = dataclasses.field(default_factory=dict)

    def __post_init__(self) -> None:
        # Normalize AND coerce axis values (dist / predictor dicts become
        # specs), so directly-constructed sweeps compare equal to
        # ``from_dict`` round-trips.
        axes: dict[str, tuple] = {}
        for key, values in self.axes.items():
            fields = key.split(",")
            if len(fields) == 1:
                vals = tuple(self._coerce_axis_value(key, _normalize(v))
                             for v in values)
            else:
                vals = tuple(tuple(self._coerce_axis_value(f, _normalize(c))
                                   for f, c in zip(fields, v))
                             for v in values)
            axes[key] = vals
        object.__setattr__(self, "axes", axes)
        object.__setattr__(self, "labels",
                           {k: _normalize(v) for k, v in self.labels.items()})
        if self.mode not in ("cartesian", "zip"):
            raise ValueError(f"unknown sweep mode {self.mode!r}")
        if self.mode == "zip" and self.axes:
            lengths = {len(v) for v in self.axes.values()}
            if len(lengths) > 1:
                raise ValueError(f"zip sweep axes differ in length: {lengths}")
        for key, names in self.labels.items():
            if key not in self.axes:
                raise ValueError(f"labels for unknown axis {key!r}")
            if len(names) != len(self.axes[key]):
                raise ValueError(f"labels/values length mismatch on {key!r}")
        for key in self.names:
            if key not in self.axes:
                raise ValueError(f"column name for unknown axis {key!r}")

    def _axis_column(self, key: str, idx: int, value: Any) -> Any:
        if key in self.labels:
            return self.labels[key][idx]
        if isinstance(value, (DistributionSpec, PredictorSpec)):
            return value.name
        if isinstance(value, Mapping):
            return json.dumps(_jsonable(value), sort_keys=True)
        if isinstance(value, (list, tuple)):
            return "/".join(str(v) for v in value)
        return value

    def _apply(self, spec: ScenarioSpec, key: str, value: Any) -> ScenarioSpec:
        fields = key.split(",")
        if len(fields) == 1:
            return spec.replace(**{key: value})
        if len(value) != len(fields):
            raise ValueError(f"axis {key!r} expects {len(fields)}-tuples, "
                             f"got {value!r}")
        return spec.replace(**dict(zip(fields, value)))

    def cells(self, base: ScenarioSpec) -> Iterator[tuple[dict, ScenarioSpec]]:
        """Yield ``(axis_columns, scenario)`` per sweep cell."""
        if not self.axes:
            yield {}, base
            return
        keys = list(self.axes)
        if self.mode == "zip":
            n = len(self.axes[keys[0]])
            index_sets: Iterator[tuple[int, ...]] = (
                (i,) * len(keys) for i in range(n))
        else:
            # First axis is major, last axis fastest (matches nested loops).
            index_sets = itertools.product(
                *(range(len(self.axes[k])) for k in keys))
        for indices in index_sets:
            cols: dict[str, Any] = {}
            spec = base
            for key, i in zip(keys, indices):
                value = self.axes[key][i]
                cols[self.names.get(key, key)] = \
                    self._axis_column(key, i, value)
                spec = self._apply(spec, key, value)
            yield cols, spec

    def to_dict(self) -> dict:
        return {"axes": {k: _jsonable(v) for k, v in self.axes.items()},
                "mode": self.mode,
                "labels": _jsonable(self.labels),
                "names": dict(self.names)}

    @staticmethod
    def _coerce_axis_value(field: str, value: Any) -> Any:
        if field in ("dist", "false_pred_dist") and value is not None:
            return _coerce_dist(value)
        if field == "predictor" and value is not None:
            return _coerce_pred(value)
        return value

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "SweepSpec":
        axes: dict[str, list] = {}
        for key, values in d.get("axes", {}).items():
            fields = key.split(",")
            if len(fields) == 1:
                values = [cls._coerce_axis_value(key, v) for v in values]
            else:
                values = [tuple(cls._coerce_axis_value(f, comp)
                                for f, comp in zip(fields, v))
                          for v in values]
            axes[key] = list(values)
        return cls(axes=axes, mode=d.get("mode", "cartesian"),
                   labels={k: list(v)
                           for k, v in d.get("labels", {}).items()},
                   names=dict(d.get("names", {})))


@dataclasses.dataclass(frozen=True)
class ExperimentSpec:
    """Scenario x strategies x metrics (optionally swept over axes)."""

    name: str
    scenario: ScenarioSpec = dataclasses.field(default_factory=ScenarioSpec)
    strategies: tuple = ()
    sweep: SweepSpec | None = None
    metrics: tuple = ("makespan", "makespan_days", "waste")
    description: str = ""

    def __post_init__(self) -> None:
        object.__setattr__(
            self, "strategies",
            tuple(StrategySpec.from_dict(s) if not isinstance(s, StrategySpec)
                  else s for s in self.strategies))
        object.__setattr__(self, "metrics", tuple(self.metrics))
        if self.sweep is not None and not isinstance(self.sweep, SweepSpec):
            object.__setattr__(self, "sweep", SweepSpec.from_dict(self.sweep))

    def cells(self) -> Iterator[tuple[dict, ScenarioSpec]]:
        sweep = self.sweep or SweepSpec()
        yield from sweep.cells(self.scenario)

    def with_overrides(self, overrides: Mapping[str, Any]) -> "ExperimentSpec":
        """Apply ``--set``-style overrides: a sweep-axis name replaces that
        axis's values (dropping its display labels), any other dotted path
        updates the base scenario.  Overriding a scenario field that a sweep
        axis controls raises ``ValueError`` — the axis would silently
        discard the override per cell.  (The CLI and suite files share this
        semantics; see ``benchmarks/run.py --set``.)
        """
        sweep = self.sweep
        scenario = self.scenario

        def _covering_axis(field: str) -> str | None:
            # An axis discards a base-scenario override when one of its
            # swept paths equals the override path or is a prefix of it
            # (the axis replaces the whole subtree per cell).  An axis on a
            # *deeper* path (axis "dist.params.shape" vs override
            # "dist.name") merges instead, so the override survives.
            for axis_key in (sweep.axes if sweep else ()):
                for axis_field in axis_key.split(","):
                    if field == axis_field \
                            or field.startswith(axis_field + "."):
                        return axis_key
            return None

        for key, value in overrides.items():
            if sweep is not None and key in sweep.axes:
                values = list(value) if isinstance(value, (list, tuple)) \
                    else [value]
                axes = dict(sweep.axes)
                axes[key] = values
                labels = {k: v for k, v in sweep.labels.items() if k != key}
                sweep = dataclasses.replace(sweep, axes=axes, labels=labels)
            else:
                covering = next((a for f in key.split(",")
                                 for a in [_covering_axis(f)] if a), None)
                if covering:
                    raise ValueError(
                        f"field {key!r} is controlled by sweep axis "
                        f"{covering!r}; override the axis instead, e.g. "
                        f"--set '{covering}=[...]'")
                scenario = scenario.replace(**{key: value})
        return dataclasses.replace(self, sweep=sweep, scenario=scenario)

    def to_dict(self) -> dict:
        return {
            "name": self.name,
            "scenario": self.scenario.to_dict(),
            "strategies": [s.to_dict() for s in self.strategies],
            "sweep": self.sweep.to_dict() if self.sweep else None,
            "metrics": list(self.metrics),
            "description": self.description,
        }

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "ExperimentSpec":
        return cls(
            name=d["name"],
            scenario=ScenarioSpec.from_dict(d.get("scenario", {})),
            strategies=tuple(StrategySpec.from_dict(s)
                             for s in d.get("strategies", ())),
            sweep=(SweepSpec.from_dict(d["sweep"])
                   if d.get("sweep") else None),
            metrics=tuple(d.get("metrics",
                                ("makespan", "makespan_days", "waste"))),
            description=d.get("description", ""),
        )

    def to_json(self, **kw: Any) -> str:
        return json.dumps(self.to_dict(), **kw)

    @classmethod
    def from_json(cls, s: str) -> "ExperimentSpec":
        return cls.from_dict(json.loads(s))
