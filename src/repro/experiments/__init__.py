"""Unified experiment API: declarative scenarios, strategy registry, and a
batched trace-evaluation runner.

Define an experiment as data, run it, read the results table::

    from repro.experiments import (DistributionSpec, ExperimentSpec,
                                   ScenarioSpec, StrategySpec, SweepSpec,
                                   run_experiment)

    exp = ExperimentSpec(
        name="demo",
        scenario=ScenarioSpec(n=2 ** 16,
                              dist=DistributionSpec("weibull", {"shape": 0.7}),
                              n_traces=5),
        sweep=SweepSpec(axes={"n": [2 ** 16, 2 ** 19]}),
        strategies=[StrategySpec("rfo"), StrategySpec("optimal_prediction"),
                    StrategySpec("best_period", {"base": "rfo"})],
    )
    table = run_experiment(exp)
    print(table.format(["n", "strategy", "period", "makespan_days", "waste"]))

Every spec round-trips through ``to_dict``/``from_dict`` (JSON), strategies
and trace distributions are looked up by registered name, and the runner
shares one trace bank + result cache per scenario across all strategies and
period searches.
"""

from .registry import (PREDICTORS, build_distribution, build_experiment,
                       build_strategy, list_distributions, list_experiments,
                       list_strategies, register_distribution,
                       register_experiment, register_strategy)
from .runner import (BestPeriodSearch, EvalCache, ResultTable,
                     SuiteItemResult, SuiteRunResult, best_period_search,
                     clear_trace_bank, default_cache_dir,
                     evaluate_strategies, evaluate_mean, run_experiment,
                     run_suite, trace_bank)
from .spec import (MU_IND_SYNTH, SECONDS_PER_DAY, DistributionSpec,
                   ExperimentSpec, PredictorSpec, ScenarioSpec, StrategySpec,
                   SweepSpec)

__all__ = [
    "MU_IND_SYNTH",
    "SECONDS_PER_DAY",
    "PREDICTORS",
    "DistributionSpec",
    "PredictorSpec",
    "ScenarioSpec",
    "StrategySpec",
    "SweepSpec",
    "ExperimentSpec",
    "BestPeriodSearch",
    "EvalCache",
    "ResultTable",
    "register_strategy",
    "register_distribution",
    "register_experiment",
    "build_strategy",
    "build_distribution",
    "build_experiment",
    "list_strategies",
    "list_distributions",
    "list_experiments",
    "default_cache_dir",
    "trace_bank",
    "clear_trace_bank",
    "evaluate_strategies",
    "evaluate_mean",
    "best_period_search",
    "run_experiment",
    "run_suite",
    "SuiteItemResult",
    "SuiteRunResult",
]
