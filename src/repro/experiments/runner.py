"""Batched trace-evaluation runner.

Replaces the O(strategies x periods x traces) serial ``simulate()`` loops
that used to live in ``policies.evaluate`` / ``policies.best_period`` and in
every benchmark script:

  * one shared **trace bank** per scenario (content-addressed by the
    scenario spec, memoized across strategies, sweeps and BestPeriod
    searches);
  * all (strategy x period x trace) candidates evaluated against the bank
    with **result caching** — identical (period, trust, window) candidates
    are simulated once no matter how many strategies or search grids ask;
  * every candidate with a constant period and a standard trust policy is
    flattened into the **lane-parallel batched engine**
    (:func:`repro.core.batch.simulate_lanes`) and simulated in one
    vectorized lockstep pass; the scalar engine survives as the reference
    oracle and as the fallback for dynamic (callable-period) or custom
    trust candidates, optionally chunked process-parallel;
  * a tidy :class:`ResultTable` (one row per sweep-cell x strategy) with
    derived metric columns.

Determinism contract: each (strategy, trace ``i``) pair is simulated with
``np.random.default_rng(seed + 7919 * i)`` and makespans are averaged in
trace order — **bit-for-bit** identical to the legacy
``policies.evaluate`` loop, regardless of engine choice, caching, batching
or worker count.

:class:`EvalCache` can additionally spill to a persistent on-disk store
(``~/.cache/repro/`` or ``$REPRO_CACHE_DIR``) keyed by a content hash of
the evaluation context, so interrupted ``--full`` sweeps resume instead of
recomputing; see :func:`run_experiment` (``persist=``) and the benchmark
CLI's ``--no-cache`` flag.
"""

from __future__ import annotations

import collections
import dataclasses
import hashlib
import json
import math
import os
import pickle
import sys
import tempfile
import time
from concurrent.futures import ProcessPoolExecutor
from pathlib import Path
from typing import Any, Iterable, Iterator, Mapping, Sequence

import numpy as np

from repro.core.batch import simulate_lanes, supported_trust
from repro.core.policies import Strategy
from repro.core.simulator import (AlwaysTrust, FixedProbabilityTrust,
                                  NeverTrust, ThresholdTrust, TrustPolicy,
                                  simulate)
from repro.core.traces import EventTrace
from repro.core.waste import Platform

from .spec import SECONDS_PER_DAY, ExperimentSpec, ScenarioSpec

__all__ = [
    "BestPeriodSearch",
    "EvalCache",
    "ResultTable",
    "default_cache_dir",
    "trace_bank",
    "clear_trace_bank",
    "evaluate_strategies",
    "evaluate_mean",
    "best_period_search",
    "run_experiment",
    "run_suite",
    "SuiteItemResult",
    "SuiteRunResult",
]

# Environment knobs.
_WORKERS_ENV = "REPRO_EXPERIMENT_WORKERS"   # scalar-fallback process pool
_ENGINE_ENV = "REPRO_ENGINE"          # auto (default) | batch | scalar | jax
_PERSIST_ENV = "REPRO_PERSIST_CACHE"        # 1 = spill EvalCache to disk
_CACHE_DIR_ENV = "REPRO_CACHE_DIR"          # default ~/.cache/repro
_BATCHED_TRACES_ENV = "REPRO_BATCHED_TRACES"  # 1 = bank-level trace sampling
_CACHE_MAX_MB_ENV = "REPRO_CACHE_MAX_MB"    # spill size cap (0 = unbounded)
_CACHE_GC_DRY_ENV = "REPRO_CACHE_GC_DRY_RUN"  # 1 = report, don't evict

# The persistent spill is a *derived* cache (every entry regenerates from
# its spec; run-level results live durably in repro.store), so it gets a
# default size cap with LRU eviction instead of growing without bound.
_DEFAULT_CACHE_MAX_MB = 512.0

# Below this many pending scalar simulations a process pool is not worth
# its startup cost; the fallback runs serial regardless of worker count.
_MIN_PARALLEL_SIMS = 16

# Persistent-cache schema/semantics version.  The on-disk store is keyed by
# the *spec* content hash only — it cannot see code changes.  Bump this
# whenever simulator mechanics, trace generation or runner seeding change
# the makespans a spec produces, or stale pre-change results will be served.
# v2: candidate keys grew the window_mode/window_period axis (PR 3).
# v3: candidate keys grew the adaptive-replanning axis and scenarios the
#     predictor field (PR 4); v2 stores hash differently and are ignored,
#     and a v2-format candidate key inside a store file fails decoding and
#     degrades the whole store to empty (invalidated, never misread).
# v4: AdaptiveConfig.key() grew the model_order element and scenarios the
#     model_order field (PR 5); v3 stores hash differently and are ignored
#     — invalidated, never misread — and a v3 adaptive key inside a store
#     would decode into a 5-tuple that can never equal a v4 6-tuple.
# v5: AdaptiveConfig.key() grew the halflife element (windowed/EW online
#     estimator, PR 6); same invalidation story as v4 (6-tuple vs 7-tuple).
# v6: the persist key grew the engine-identity tag (PR 7) — the numpy-family
#     engines (auto/batch/scalar, bit-for-bit identical by contract) share
#     the empty legacy tag, the jax engine is fingerprinted by jax version +
#     backend platform + device kind (accelerator backends may relax the
#     bitwise contract to float32 tolerances, so their results must never
#     alias a CPU store).  v5 stores hash differently and are ignored —
#     invalidated, never misread.
# v7: candidate keys grew the silent-error verification axis
#     (n_verify/verify_cost/keep_ckpts) and scenarios the silent_mu_ind
#     field (PR 10); v6 stores hash differently and are ignored —
#     invalidated, never misread — and a v6-format 6-element candidate key
#     inside a store file fails the 9-element decode and degrades the
#     whole store to empty.
_EVAL_CACHE_VERSION = 7


def _env_flag(name: str) -> bool:
    return os.environ.get(name, "").strip().lower() in ("1", "true", "yes",
                                                        "on")


@dataclasses.dataclass(frozen=True)
class BestPeriodSearch:
    """A strategy whose period is brute-forced over the runner's trace bank.

    Produced by the registered ``best_period`` strategy factory; the runner
    resolves it into a concrete :class:`Strategy` via
    :func:`best_period_search`.
    """

    base: Strategy
    n_points: int = 24
    span: float = 8.0

    @property
    def name(self) -> str:
        return f"BestPeriod({self.base.name})"


# ---------------------------------------------------------------------------
# Result cache (per evaluation context: bank x platform x time_base x cp x seed)
# ---------------------------------------------------------------------------

class _IdKey:
    """Hashable identity wrapper for cache keys built from objects without
    value semantics.  Holding the object itself (not its ``id()``) keeps it
    alive for the cache's lifetime, so the key can never alias a freed
    object's recycled id."""

    __slots__ = ("obj",)

    def __init__(self, obj: Any) -> None:
        self.obj = obj

    def __hash__(self) -> int:
        return object.__hash__(self.obj) if isinstance(
            self.obj, collections.abc.Hashable) else id(self.obj)

    def __eq__(self, other: Any) -> bool:
        return isinstance(other, _IdKey) and self.obj is other.obj


def _trust_key(trust: TrustPolicy) -> tuple:
    if isinstance(trust, NeverTrust):
        return ("never",)
    if isinstance(trust, AlwaysTrust):
        return ("always",)
    if isinstance(trust, FixedProbabilityTrust):
        return ("fixed_q", trust.q)
    if isinstance(trust, ThresholdTrust):
        return ("threshold", trust.threshold)
    return ("opaque", _IdKey(trust))


def _adaptive_key(adaptive) -> tuple | None:
    """Value tuple of an AdaptiveConfig candidate axis (None = static)."""
    if adaptive is None:
        return None
    if hasattr(adaptive, "key"):
        return tuple(adaptive.key())
    return _IdKey(adaptive)  # opaque custom object: identity semantics


def _candidate_key(strategy: Strategy) -> tuple:
    period = strategy.period
    if callable(period) and not isinstance(period, collections.abc.Hashable):
        period = _IdKey(period)
    return (period, _trust_key(strategy.trust), strategy.inexact_window,
            strategy.window_mode, strategy.window_period,
            _adaptive_key(strategy.adaptive), strategy.n_verify,
            strategy.verify_cost, strategy.keep_ckpts)


def _persistable_key(key: tuple) -> str | None:
    """Canonical JSON form of a candidate key, or None if the candidate has
    no value semantics (callable period, opaque trust policy)."""
    (period, trust, window, wmode, wperiod, adaptive,
     n_verify, verify_cost, keep_ckpts) = key
    if not isinstance(period, (int, float)):
        return None
    if any(isinstance(part, _IdKey) for part in trust) \
            or isinstance(adaptive, _IdKey):
        return None
    return json.dumps([period, list(trust), window, wmode, wperiod,
                       None if adaptive is None else list(adaptive),
                       n_verify, verify_cost, keep_ckpts])


def default_cache_dir() -> Path:
    """On-disk result cache root: ``$REPRO_CACHE_DIR`` or ``~/.cache/repro``."""
    env = os.environ.get(_CACHE_DIR_ENV, "").strip()
    if env:
        return Path(env)
    return Path.home() / ".cache" / "repro"


class EvalCache:
    """Maps (candidate key, trace index) -> makespan.

    Shared across the strategies / period grids of one evaluation context so
    duplicated candidates (e.g. the analytic period appearing both in a
    BestPeriod grid and as a plain strategy) are simulated exactly once.

    With ``persist_key`` the cache is additionally backed by a JSON file
    ``<cache_dir>/<persist_key>.json``: prior results load on construction
    (so an interrupted sweep resumes where it stopped) and new results of
    serializable candidates are written back by :meth:`flush`.  The caller
    owns the key — it must content-hash everything the makespans depend on
    (scenario spec incl. the trace bank seeds, cp, evaluation seed).  The
    key cannot capture *code*: after changing simulator/trace semantics,
    bump ``_EVAL_CACHE_VERSION`` (or clear the cache dir / pass
    ``--no-cache``) or stale results will be served.
    """

    def __init__(self, persist_key: str | None = None,
                 cache_dir: str | Path | None = None) -> None:
        self._makespans: dict[tuple, float] = {}
        self.hits = 0
        self.misses = 0
        self._path: Path | None = None
        self._new: dict[str, dict[int, float]] = {}
        if persist_key is not None:
            self._path = Path(cache_dir or default_cache_dir()) \
                / f"{persist_key}.json"
            store = self._read_store()
            for ckey_str, per_trace in store.items():
                key = self._decode_key(ckey_str)
                for ti, m in per_trace.items():
                    self._makespans[(key, int(ti))] = float(m)
            if store:
                # mtime is the spill's LRU clock (see gc in flush): a pure
                # read marks the file recently-used too.
                try:
                    os.utime(self._path)
                except OSError:
                    pass

    @staticmethod
    def _decode_key(ckey_str: str) -> tuple:
        (period, trust, window, wmode, wperiod, adaptive,
         n_verify, verify_cost, keep_ckpts) = json.loads(ckey_str)
        return (period, tuple(trust), window, wmode, wperiod,
                None if adaptive is None else tuple(adaptive),
                n_verify, verify_cost, keep_ckpts)

    def _read_store(self) -> dict:
        """The on-disk makespan map; any unreadable or wrong-shape file
        (older tool versions, manual edits) degrades to an empty store."""
        try:
            with open(self._path) as fh:
                store = json.load(fh).get("makespans", {})
            if not isinstance(store, dict):
                return {}
            for ckey_str, per_trace in store.items():
                self._decode_key(ckey_str)
                dict(per_trace).items()
            return store
        except (FileNotFoundError, OSError, ValueError, TypeError,
                AttributeError, KeyError):
            return {}

    def get(self, strategy: Strategy, trace_idx: int) -> float | None:
        got = self._makespans.get((_candidate_key(strategy), trace_idx))
        if got is not None:
            self.hits += 1
        return got

    def put(self, strategy: Strategy, trace_idx: int, makespan: float) -> None:
        self.misses += 1
        key = _candidate_key(strategy)
        self._makespans[(key, trace_idx)] = makespan
        if self._path is not None:
            ckey_str = _persistable_key(key)
            if ckey_str is not None:
                self._new.setdefault(ckey_str, {})[trace_idx] = makespan

    def flush(self) -> None:
        """Merge new results into the on-disk store (atomic rename).

        Concurrent flushes of the same cell from separate processes are a
        read-merge-replace race: the last writer may drop the other's new
        entries.  Values are deterministic per key, so this only costs
        recomputation, never wrong results.
        """
        if self._path is None or not self._new:
            return
        store = self._read_store()
        for ckey_str, per_trace in self._new.items():
            dst = store.setdefault(ckey_str, {})
            for ti, m in per_trace.items():
                dst[str(ti)] = m
        self._path.parent.mkdir(parents=True, exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=self._path.parent,
                                   prefix=self._path.name, suffix=".tmp")
        try:
            with os.fdopen(fd, "w") as fh:
                json.dump({"makespans": store}, fh)
            os.replace(tmp, self._path)
        except OSError:
            try:
                os.unlink(tmp)
            except OSError:
                pass
            raise
        self._new.clear()
        self._maybe_gc()

    def _maybe_gc(self) -> None:
        """Keep the spill directory under ``$REPRO_CACHE_MAX_MB`` (default
        512; ``0`` disables) by LRU-evicting other cells' spill files —
        the fix for the previously unbounded ``~/.cache/repro`` growth.
        ``REPRO_CACHE_GC_DRY_RUN=1`` reports would-be evictions loudly on
        stderr without deleting anything."""
        raw = os.environ.get(_CACHE_MAX_MB_ENV, "").strip()
        try:
            max_mb = float(raw) if raw else _DEFAULT_CACHE_MAX_MB
        except ValueError:
            max_mb = _DEFAULT_CACHE_MAX_MB
        if max_mb <= 0:
            return
        from repro.store.store import gc_cache  # late: avoid import cycle
        dry = _env_flag(_CACHE_GC_DRY_ENV)
        evicted = gc_cache(self._path.parent,
                           max_bytes=int(max_mb * 1024 * 1024), dry_run=dry)
        for path, size in evicted:
            verb = "would evict" if dry else "evicted"
            print(f"[repro cache gc] {verb} {path} ({size} bytes; "
                  f"cap {max_mb:g} MB, set {_CACHE_MAX_MB_ENV}=0 to disable)",
                  file=sys.stderr, flush=True)

    def __len__(self) -> int:
        return len(self._makespans)


# ---------------------------------------------------------------------------
# Shared trace bank
# ---------------------------------------------------------------------------

_BANK_CACHE: "collections.OrderedDict[str, list[EventTrace]]" = \
    collections.OrderedDict()
_BANK_CACHE_MAX = 8


def trace_bank(scenario: ScenarioSpec,
               batched: bool | None = None) -> list[EventTrace]:
    """The scenario's shared trace bank (content-addressed, memoized).

    Two scenario specs with equal fields share one generated bank; the sizes
    and seeds are part of the spec, so overriding either yields a new bank.

    ``batched=True`` (or ``REPRO_BATCHED_TRACES=1``) samples the bank in
    shared RNG waves (:meth:`ScenarioSpec.make_traces` with
    ``batched=True``) — statistically identical; fastest for banks of many
    small traces (see ``BENCH_simulator.json``).  A different stream than
    per-trace seeding, hence a separate cache entry (and separate
    persistent-cache results).
    """
    if batched is None:
        batched = _env_flag(_BATCHED_TRACES_ENV)
    key = ("batched|" if batched else "") + scenario.key()
    if key in _BANK_CACHE:
        _BANK_CACHE.move_to_end(key)
        return _BANK_CACHE[key]
    bank = scenario.make_traces(batched=batched)
    _BANK_CACHE[key] = bank
    while len(_BANK_CACHE) > _BANK_CACHE_MAX:
        _BANK_CACHE.popitem(last=False)
    return bank


def clear_trace_bank() -> None:
    _BANK_CACHE.clear()


# ---------------------------------------------------------------------------
# Batched evaluation
# ---------------------------------------------------------------------------

def _simulate_pair(trace: EventTrace, platform: Platform, time_base: float,
                   cp: float, strategy: Strategy, seed: int,
                   trace_idx: int) -> float:
    rng = np.random.default_rng(seed + 7919 * trace_idx)
    res = simulate(trace, platform, time_base, strategy.period, cp=cp,
                   trust=strategy.trust,
                   inexact_window=strategy.inexact_window,
                   window_mode=strategy.window_mode,
                   window_period=strategy.window_period,
                   adaptive=strategy.adaptive,
                   n_verify=strategy.n_verify,
                   verify_cost=strategy.verify_cost,
                   keep_ckpts=strategy.keep_ckpts, rng=rng)
    return res.makespan


def _eval_chunk(trace: EventTrace, platform: Platform, time_base: float,
                cp: float, seed: int, trace_idx: int,
                items: list[tuple[int, Strategy]]) -> list[tuple[int, float]]:
    """Worker task: one trace x several candidate strategies."""
    return [(slot, _simulate_pair(trace, platform, time_base, cp, strat,
                                  seed, trace_idx))
            for slot, strat in items]


def _resolve_workers(workers: int | None) -> int:
    """Worker count for the scalar-fallback pool: explicit argument, then
    ``$REPRO_EXPERIMENT_WORKERS``, then the machine's CPU count."""
    if workers is None:
        env = os.environ.get(_WORKERS_ENV, "").strip()
        workers = int(env) if env else (os.cpu_count() or 1)
    return max(0, workers)


def _resolve_engine(engine: str | None) -> str:
    engine = engine or os.environ.get(_ENGINE_ENV, "").strip() or "auto"
    if engine not in ("auto", "batch", "scalar", "jax"):
        raise ValueError(f"unknown engine {engine!r} "
                         f"(expected auto, batch, scalar or jax)")
    return engine


def _batchable(strategy: Strategy) -> bool:
    """True if the lane engine can run this candidate (constant period and
    a standard trust policy)."""
    return isinstance(strategy.period, (int, float, np.integer)) \
        and supported_trust(strategy.trust)


def _picklable(strategy: Strategy) -> bool:
    try:
        pickle.dumps(strategy)
        return True
    except Exception:
        return False


def evaluate_strategies(
    traces: Sequence[EventTrace],
    platform: Platform,
    time_base: float,
    cp: float,
    strategies: Sequence[Strategy],
    *,
    seed: int = 0,
    cache: EvalCache | None = None,
    workers: int | None = None,
    engine: str | None = None,
) -> list[float]:
    """Average makespan of each strategy over the shared trace set.

    The batched replacement for per-strategy ``policies.evaluate`` loops:
    all (strategy x trace) candidates are gathered, deduplicated through
    ``cache``, executed, and averaged in trace order.  Candidates with
    constant periods and standard trust policies run as one lane-parallel
    pass of the vectorized engine (:func:`repro.core.batch.simulate_lanes`);
    the rest (dynamic periods, custom trust policies) fall back to
    per-trace scalar simulation, process-parallel when ``workers`` > 1
    (default ``$REPRO_EXPERIMENT_WORKERS``, else the CPU count) and the
    pending work is large enough.  ``engine="scalar"`` (or
    ``REPRO_ENGINE=scalar``) forces the scalar path everywhere;
    ``engine="batch"`` and ``engine="jax"`` are strict — they raise if any
    candidate needs the fallback (``"jax"`` runs the lane pass on the jax
    engine, bit-for-bit the numpy lanes on CPU x64).  Results are
    bit-for-bit independent of the execution plan.
    """
    cache = cache if cache is not None else EvalCache()
    engine = _resolve_engine(engine)
    n = len(traces)
    makespans = np.empty((len(strategies), max(1, n)), dtype=np.float64)

    # Gather the missing (strategy, trace) pairs, dedup via the cache key.
    pending: dict[tuple, list[int]] = {}          # (si, ti) slots per key
    lane_items: list[tuple[int, int]] = []        # (si, ti) for the lane engine
    by_trace: dict[int, list[tuple[int, Strategy]]] = {}
    seen_keys: dict[tuple, tuple[int, int]] = {}  # key -> first slot
    for si, strat in enumerate(strategies):
        lanes_ok = engine != "scalar" and _batchable(strat)
        if engine in ("batch", "jax") and not lanes_ok:
            raise ValueError(
                f"engine={engine!r} cannot run strategy {strat.name!r} "
                f"(dynamic period or unsupported trust policy); use "
                f"engine='auto' to allow the scalar fallback")
        for ti in range(n):
            got = cache.get(strat, ti)
            if got is not None:
                makespans[si, ti] = got
                continue
            key = (_candidate_key(strat), ti)
            if key in seen_keys:
                pending.setdefault(key, []).append(si)
                continue
            seen_keys[key] = (si, ti)
            if lanes_ok:
                lane_items.append((si, ti))
            else:
                by_trace.setdefault(ti, []).append((si, strat))

    # One lockstep pass over every batchable (candidate, trace) lane.
    if lane_items:
        tr_idx = np.fromiter((ti for _, ti in lane_items), np.int64,
                             len(lane_items))
        lane_ms = simulate_lanes(
            traces, platform, time_base, cp=cp,
            trace_indices=tr_idx,
            periods=[float(strategies[si].period) for si, _ in lane_items],
            trusts=[strategies[si].trust for si, _ in lane_items],
            windows=[strategies[si].inexact_window for si, _ in lane_items],
            window_modes=[strategies[si].window_mode
                          for si, _ in lane_items],
            window_periods=[strategies[si].window_period
                            for si, _ in lane_items],
            adaptives=[strategies[si].adaptive for si, _ in lane_items],
            n_verifies=[strategies[si].n_verify for si, _ in lane_items],
            verify_costs=[strategies[si].verify_cost
                          for si, _ in lane_items],
            keep_ckpts=[strategies[si].keep_ckpts for si, _ in lane_items],
            seeds=seed + 7919 * tr_idx,
            backend="jax" if engine == "jax" else "numpy")
        for (si, ti), m in zip(lane_items, lane_ms):
            makespans[si, ti] = m
            cache.put(strategies[si], ti, float(m))

    # Scalar fallback for dynamic-period / custom-trust candidates.  The
    # process pool needs picklable strategies; ad-hoc closures (lambda
    # periods, local trust classes) are legal inputs, so unpicklable
    # candidates peel off into a serial-only pass instead of crashing.
    workers = _resolve_workers(workers)
    serial_only: dict[int, list[tuple[int, Strategy]]] = {}
    if workers > 1:
        picklable: dict[int, bool] = {}
        for ti, items in list(by_trace.items()):
            for slot, strat in items:
                if slot not in picklable:
                    picklable[slot] = _picklable(strat)
            stuck = [it for it in items if not picklable[it[0]]]
            if stuck:
                serial_only[ti] = stuck
                kept = [it for it in items if picklable[it[0]]]
                if kept:
                    by_trace[ti] = kept
                else:
                    del by_trace[ti]
    n_scalar = sum(len(items) for items in by_trace.values())
    if workers > 1 and n_scalar >= _MIN_PARALLEL_SIMS:
        with ProcessPoolExecutor(max_workers=workers) as pool:
            futures = {
                ti: pool.submit(_eval_chunk, traces[ti], platform, time_base,
                                cp, seed, ti, items)
                for ti, items in by_trace.items()
            }
            for ti, fut in futures.items():
                for slot, m in fut.result():
                    makespans[slot, ti] = m
                    cache.put(strategies[slot], ti, m)
    else:
        for ti, items in by_trace.items():
            serial_only.setdefault(ti, []).extend(items)
    for ti, items in serial_only.items():
        for slot, m in _eval_chunk(traces[ti], platform, time_base, cp,
                                   seed, ti, items):
            makespans[slot, ti] = m
            cache.put(strategies[slot], ti, m)

    # Fill the duplicated candidates from the now-populated cache.
    for (ckey, ti), slots in pending.items():
        first_si, _ = seen_keys[(ckey, ti)]
        for si in slots:
            makespans[si, ti] = makespans[first_si, ti]

    # Average in trace order with sequential accumulation: bit-for-bit the
    # legacy ``total += makespan; total / max(1, n)`` reduction.
    out = []
    for si in range(len(strategies)):
        total = 0.0
        for ti in range(n):
            total += makespans[si, ti]
        out.append(float(total / max(1, n)))
    return out


def evaluate_mean(
    strategy: Strategy,
    traces: Sequence[EventTrace],
    platform: Platform,
    time_base: float,
    cp: float,
    *,
    seed: int = 0,
    cache: EvalCache | None = None,
    workers: int | None = None,
    engine: str | None = None,
) -> float:
    """Single-strategy convenience wrapper over :func:`evaluate_strategies`."""
    return evaluate_strategies(traces, platform, time_base, cp, [strategy],
                               seed=seed, cache=cache, workers=workers,
                               engine=engine)[0]


# ---------------------------------------------------------------------------
# BestPeriod as a thin search over the runner
# ---------------------------------------------------------------------------

def best_period_grid(t0: float, platform: Platform, n_points: int,
                     span: float) -> np.ndarray:
    """Deduplicated candidate grid around the analytic period ``t0``.

    Log-spaced in [t0/span, t0*span] (clamped above C) with ``t0`` included
    — BestPeriod must never lose to the analytic period — and made unique so
    no candidate is ever evaluated twice.
    """
    lo = max(platform.c * 1.001, t0 / span)
    hi = max(lo * 1.01, t0 * span)
    return np.unique(np.append(np.geomspace(lo, hi, n_points), t0))


def best_period_search(
    search: BestPeriodSearch | Strategy,
    traces: Sequence[EventTrace],
    platform: Platform,
    time_base: float,
    cp: float,
    *,
    n_points: int = 24,
    span: float = 8.0,
    seed: int = 0,
    cache: EvalCache | None = None,
    workers: int | None = None,
    engine: str | None = None,
) -> tuple[Strategy, float]:
    """Brute-force the best period for a strategy (paper's BestPeriod).

    A thin argmin over :func:`evaluate_strategies`: the whole candidate grid
    is flattened into lanes of the batched engine in one call, with the
    cache deduplicating any candidate already simulated (e.g. the base
    strategy's own period, or overlapping grids of other searches).
    """
    if isinstance(search, BestPeriodSearch):
        base, n_points, span = search.base, search.n_points, search.span
    else:
        base = search
    cache = cache if cache is not None else EvalCache()
    grid = best_period_grid(base.period, platform, n_points, span)
    candidates = [base.with_period(float(t)) for t in grid]
    means = evaluate_strategies(traces, platform, time_base, cp, candidates,
                                seed=seed, cache=cache, workers=workers,
                                engine=engine)
    best_i = int(np.argmin(means))
    best_t, best_m = float(grid[best_i]), float(means[best_i])
    refined = dataclasses.replace(base, name=f"BestPeriod({base.name})",
                                  period=best_t)
    return refined, best_m


# ---------------------------------------------------------------------------
# Tidy result table
# ---------------------------------------------------------------------------

class ResultTable:
    """A tidy list of result rows (one per sweep-cell x strategy)."""

    def __init__(self, rows: Iterable[Mapping[str, Any]] = ()) -> None:
        self.rows: list[dict[str, Any]] = [dict(r) for r in rows]

    # -- container protocol --------------------------------------------------

    def __len__(self) -> int:
        return len(self.rows)

    def __iter__(self) -> Iterator[dict[str, Any]]:
        return iter(self.rows)

    def __repr__(self) -> str:
        return f"ResultTable({len(self.rows)} rows x {len(self.columns)} cols)"

    @property
    def columns(self) -> list[str]:
        cols: dict[str, None] = {}
        for row in self.rows:
            for c in row:
                cols.setdefault(c)
        return list(cols)

    # -- relational helpers --------------------------------------------------

    def where(self, **eq: Any) -> "ResultTable":
        return ResultTable(r for r in self.rows
                           if all(r.get(k) == v for k, v in eq.items()))

    def column(self, name: str) -> list[Any]:
        return [r.get(name) for r in self.rows]

    def value(self, name: str, **eq: Any) -> Any:
        hits = self.where(**eq).rows
        if len(hits) != 1:
            raise KeyError(f"expected exactly one row for {eq}, "
                           f"got {len(hits)}")
        return hits[0][name]

    def strategy_dict(self, metric: str = "makespan_days",
                      **eq: Any) -> dict[str, float]:
        """{strategy name: metric} for the rows matching ``eq``."""
        return {r["strategy"]: r[metric] for r in self.where(**eq).rows}

    def mean(self, name: str, **eq: Any) -> float:
        vals = [v for v in self.where(**eq).column(name) if v is not None]
        return float(np.mean(vals)) if vals else math.nan

    # -- output --------------------------------------------------------------

    def to_json(self, **kw: Any) -> str:
        """Deterministic by default: keys sorted so exported tables diff
        cleanly (pass ``sort_keys=False`` for insertion order)."""
        kw.setdefault("sort_keys", True)
        return json.dumps(self.rows, default=str, **kw)

    def format(self, columns: Sequence[str] | None = None,
               float_fmt: str = "{:.2f}") -> str:
        cols = list(columns) if columns else self.columns
        widths = {c: max(len(str(c)), 8) for c in cols}
        def fmt(v: Any) -> str:
            if isinstance(v, float):
                return float_fmt.format(v)
            return "" if v is None else str(v)
        for row in self.rows:
            for c in cols:
                widths[c] = max(widths[c], len(fmt(row.get(c))))
        head = " | ".join(f"{c:>{widths[c]}s}" for c in cols)
        lines = [head, "-" * len(head)]
        for row in self.rows:
            lines.append(" | ".join(f"{fmt(row.get(c)):>{widths[c]}s}"
                                    for c in cols))
        return "\n".join(lines)


# ---------------------------------------------------------------------------
# Experiment execution
# ---------------------------------------------------------------------------

def _metric_value(metric: str, makespan: float | None,
                  scenario: ScenarioSpec) -> Any:
    if makespan is None:
        return None
    if metric == "makespan":
        return makespan
    if metric == "makespan_days":
        return makespan / SECONDS_PER_DAY
    if metric == "waste":
        return 1.0 - scenario.time_base / makespan if makespan > 0 else 0.0
    raise KeyError(f"unknown metric {metric!r}")


def _engine_fingerprint(engine: str) -> str:
    """Cache-identity tag of the resolved engine.

    The numpy-family engines (auto / batch / scalar) are bit-for-bit
    identical by contract, so they share the empty legacy tag and keep
    hitting each other's stores.  The jax engine matches them bitwise on
    CPU x64, but an accelerator backend may relax the contract (float64
    emulation, float32 kernels), so its results are keyed by jax version +
    backend platform + device kind — a TPU store can never be misread as
    a CPU (or numpy) one.
    """
    if engine != "jax":
        return ""
    import jax
    dev = jax.devices()[0]
    return f"jax-{jax.__version__}-{dev.platform}-{dev.device_kind}|"


def _cell_persist_key(cell: ScenarioSpec, batched_bank: bool,
                      engine: str = "auto") -> str:
    """Content hash of one evaluation context: the scenario spec (which
    covers the trace bank seeds/sizes, platform, cp and the evaluation
    seed) plus the bank sampling mode (batched banks are different draws
    than per-trace banks) and the engine identity tag (see
    :func:`_engine_fingerprint`)."""
    tag = ("batched|" if batched_bank else "") + _engine_fingerprint(engine)
    digest = hashlib.sha256(
        (f"eval-v{_EVAL_CACHE_VERSION}|" + tag + cell.key()).encode()
    ).hexdigest()
    return f"eval-{digest[:32]}"


def run_experiment(
    exp: ExperimentSpec,
    *,
    n_traces: int | None = None,
    seed: int | None = None,
    workers: int | None = None,
    verbose: bool = False,
    persist: bool | None = None,
    engine: str | None = None,
    batched_traces: bool | None = None,
) -> ResultTable:
    """Run an :class:`ExperimentSpec`; returns the tidy result table.

    Per sweep cell: one shared trace bank, one :class:`EvalCache`; all plain
    strategies are evaluated as a single batch, then BestPeriod searches run
    against the same bank and cache (so grids share every previously
    simulated candidate).  ``n_traces`` / ``seed`` override the scenario
    spec; ``n_traces=0`` skips simulation entirely (analytic experiments
    still report each strategy's period).

    ``persist=True`` (or ``REPRO_PERSIST_CACHE=1``) backs each cell's cache
    with the on-disk store under :func:`default_cache_dir`, keyed by a
    content hash of the cell spec — interrupted sweeps resume for free and
    repeated runs of the same cell simulate nothing.  ``engine`` /
    ``batched_traces`` select the simulation engine and the bank sampling
    path (see :func:`evaluate_strategies` / :func:`trace_bank`).
    """
    from repro.obs.metrics import get_registry

    if persist is None:
        persist = _env_flag(_PERSIST_ENV)
    if batched_traces is None:
        batched_traces = _env_flag(_BATCHED_TRACES_ENV)
    engine = _resolve_engine(engine)
    reg = get_registry()
    rows: list[dict[str, Any]] = []
    for axis_cols, cell in exp.cells():
        overrides: dict[str, Any] = {}
        if n_traces is not None:
            overrides["n_traces"] = n_traces
        if seed is not None:
            overrides["seed"] = seed
        if overrides:
            cell = cell.replace(**overrides)
        built = [(sspec, sspec.build(cell)) for sspec in exp.strategies]
        platform, time_base, cp = cell.platform, cell.time_base, cell.cp

        traces: list[EventTrace] = []
        if cell.n_traces > 0 and built:
            traces = trace_bank(cell, batched=batched_traces)
        cache = EvalCache(persist_key=_cell_persist_key(
            cell, batched_traces, engine) if persist else None)

        # Batch all plain strategies first, then resolve the searches
        # against the warm cache.
        plain = [(i, s) for i, (_, s) in enumerate(built)
                 if isinstance(s, Strategy)]
        means: dict[int, float | None] = {i: None for i in range(len(built))}
        resolved: dict[int, Strategy | BestPeriodSearch] = {
            i: s for i, (_, s) in enumerate(built)}
        if traces and plain:
            with reg.timer("runner.eval_s"):
                batched = evaluate_strategies(
                    traces, platform, time_base, cp, [s for _, s in plain],
                    seed=cell.seed, cache=cache, workers=workers,
                    engine=engine)
            for (i, _), m in zip(plain, batched):
                means[i] = m
        for i, (_, s) in enumerate(built):
            if isinstance(s, BestPeriodSearch):
                if not traces:
                    # Nothing to search against: report the base strategy's
                    # analytic period under the search's own name so the row
                    # stays distinct from the plain base strategy.
                    resolved[i] = dataclasses.replace(s.base, name=s.name)
                    continue
                with reg.timer("runner.eval_s"):
                    refined, m = best_period_search(
                        s, traces, platform, time_base, cp, seed=cell.seed,
                        cache=cache, workers=workers, engine=engine)
                resolved[i], means[i] = refined, m
        cache.flush()
        reg.count("runner.cache_hits", cache.hits)
        reg.count("runner.cache_misses", cache.misses)
        reg.count("runner.cells")

        for i, (sspec, _) in enumerate(built):
            strat = resolved[i]
            name = sspec.label if sspec.label is not None else (
                strat.name if isinstance(strat, Strategy) else sspec.name)
            period = strat.period if isinstance(strat, Strategy) else None
            row: dict[str, Any] = dict(axis_cols)
            row["strategy"] = name
            row["period"] = (float(period) if isinstance(period, (int, float))
                             else "dynamic")
            for metric in exp.metrics:
                row[metric] = _metric_value(metric, means[i], cell)
            rows.append(row)
        if verbose:
            cellname = ", ".join(f"{k}={v}" for k, v in axis_cols.items())
            print(f"[{exp.name}] {cellname or 'base'}: "
                  f"{len(traces)} traces, cache {cache.misses} sims "
                  f"/ {cache.hits} hits", flush=True)
    return ResultTable(rows)


# ---------------------------------------------------------------------------
# Suite execution (store-backed, resumable)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class SuiteItemResult:
    """Outcome of one suite item: the stored record (or the error that
    prevented one), whether the store satisfied it without executing, and
    the evaluated claim results."""

    name: str
    kind: str
    record_id: str
    record: Any = None            # RunRecord | None (None on error)
    cached: bool = False
    claims: list = dataclasses.field(default_factory=list)
    error: str | None = None
    wall_s: float = 0.0

    @property
    def ok(self) -> bool:
        return self.error is None \
            and all(c.get("ok", False) for c in self.claims)


@dataclasses.dataclass
class SuiteRunResult:
    """Outcome of :func:`run_suite`: the per-item results plus the
    aggregate suite record written to the store."""

    suite: Any                    # SuiteSpec
    record: Any                   # suite-kind RunRecord
    items: list = dataclasses.field(default_factory=list)

    @property
    def ok(self) -> bool:
        return all(it.ok for it in self.items)

    @property
    def record_id(self) -> str:
        return self.record.record_id

    @property
    def n_cached(self) -> int:
        return sum(it.cached for it in self.items)

    def failures(self) -> list[str]:
        out = []
        for it in self.items:
            if it.error is not None:
                out.append(f"{it.name}: ERROR {it.error}")
            for c in it.claims:
                if not c.get("ok", False):
                    out.append(f"{it.name}: CLAIM FAILED {c['claim']} "
                               f"({c.get('detail', '')})")
        return out

    def summary(self) -> str:
        lines = [f"suite {self.suite.name}: {len(self.items)} items, "
                 f"{self.n_cached} from store, "
                 f"{'OK' if self.ok else 'FAILED'} "
                 f"[{self.record_id}]"]
        for it in self.items:
            n_claims = len(it.claims)
            n_ok = sum(c.get("ok", False) for c in it.claims)
            tag = "store" if it.cached else f"{it.wall_s:.1f}s"
            state = "error" if it.error else \
                ("ok" if it.ok else f"{n_claims - n_ok} claim(s) failed")
            lines.append(f"  {it.kind:10s} {it.name:24s} {tag:>7s}  "
                         f"claims {n_ok}/{n_claims}  {state}")
        lines += [f"  ! {f}" for f in self.failures()]
        return "\n".join(lines)


def _suite_item_identity(item: Any, engine: str) -> tuple[dict, Any]:
    """(identity dict, built ExperimentSpec | None) of one suite item.

    The identity covers everything the results depend on — the full
    canonical spec (experiment items) or the benchmark name + quick flag,
    the execution context, the runner semantics version and the engine
    identity fingerprint (v6 EvalCache precedent: numpy-family engines
    share the empty tag) — and nothing they don't, so re-running the same
    inputs finds the prior record.
    """
    base = {"eval_version": _EVAL_CACHE_VERSION,
            "engine_fingerprint": _engine_fingerprint(engine)}
    if item.kind == "benchmark":
        return dict(base, benchmark=item.benchmark, quick=item.quick), None
    from .registry import build_experiment
    if item.spec is not None:
        exp = ExperimentSpec.from_dict(item.spec)
    else:
        exp = build_experiment(item.experiment, quick=item.quick,
                               **item.args)
    if item.overrides:
        exp = exp.with_overrides(item.overrides)
    identity = dict(base, spec=exp.to_dict(), n_traces=item.n_traces,
                    seed=item.seed, batched_traces=item.batched_traces)
    return identity, exp


def _metrics_outputs(reg: Any) -> tuple[dict, dict]:
    """Split a registry snapshot into (payload counters, timing extras).

    Deterministic counters go into the record payload (exact-diffed);
    anything resume- or environment-dependent — the cache hit/miss split,
    chunk counts, and all timers/gauges — rides in ``timings``, which
    diffs exclude as provenance.
    """
    cnt = dict(reg.counters)
    extras = dict(reg.flat_timings())
    hits = cnt.pop("runner.cache_hits", 0)
    misses = cnt.pop("runner.cache_misses", 0)
    if hits or misses:
        cnt["runner.cache_lookups"] = hits + misses
        extras["runner.cache_hits"] = hits
        extras["runner.cache_misses"] = misses
    chunks = cnt.pop("jax.chunks", 0)    # REPRO_JAX_CHUNK-dependent
    if chunks:
        extras["jax.chunks"] = chunks
    return cnt, extras


def _run_suite_item(item: Any, store: Any, *, resume: bool,
                    engine: str | None, workers: int | None,
                    verbose: bool) -> SuiteItemResult:
    from repro.store import RunRecord, evaluate_claims

    eng = _resolve_engine(item.engine or engine)
    try:
        identity, exp = _suite_item_identity(item, eng)
    except (KeyError, ValueError, TypeError) as e:
        # Unknown experiment / malformed spec or overrides: no identity,
        # so nothing to probe or store — report the item as failed.
        return SuiteItemResult(name=item.name, kind=item.kind, record_id="",
                               error=f"{type(e).__name__}: {e}")
    rid = RunRecord.id_for(item.kind, item.name, identity)
    res = SuiteItemResult(name=item.name, kind=item.kind, record_id=rid)

    rec = store.get(rid) if resume else None
    if rec is not None:
        res.record, res.cached = rec, True
    else:
        from repro.obs.metrics import MetricsRegistry, set_registry

        reg = MetricsRegistry()
        prev_reg = set_registry(reg)
        t0 = time.time()
        try:
            if item.kind == "benchmark":
                import benchmarks.run as bench_mod
                benches = bench_mod._import_benchmarks()
                if item.benchmark not in benches:
                    raise KeyError(
                        f"unknown benchmark {item.benchmark!r} "
                        f"(have {sorted(benches)})")
                old = os.environ.get(_ENGINE_ENV)
                if item.engine:
                    os.environ[_ENGINE_ENV] = item.engine
                try:
                    payload = benches[item.benchmark](quick=item.quick)
                finally:
                    if item.engine:
                        if old is None:
                            os.environ.pop(_ENGINE_ENV, None)
                        else:
                            os.environ[_ENGINE_ENV] = old
                counters, extras = _metrics_outputs(reg)
                if isinstance(payload, dict) or not payload:
                    payload = dict(payload or {})
                else:    # row-list benchmarks (log_traces / exec_times)
                    payload = {"rows": payload}
                if counters:
                    payload["metrics"] = counters
                rec = RunRecord.create(item.kind, item.name, identity,
                                       payload=payload,
                                       timings={"wall_s": time.time() - t0,
                                                **extras})
            else:
                table = run_experiment(
                    exp, n_traces=item.n_traces, seed=item.seed,
                    workers=workers, verbose=verbose, engine=eng,
                    batched_traces=item.batched_traces or None)
                counters, extras = _metrics_outputs(reg)
                rec = RunRecord.create(item.kind, item.name, identity,
                                       rows=table.rows,
                                       payload={"metrics": counters}
                                       if counters else {},
                                       timings={"wall_s": time.time() - t0,
                                                **extras})
        except (AssertionError, KeyError, ValueError, TypeError) as e:
            # A failed run is reported, never stored: the identity must
            # only ever resolve to a completed result.
            res.error = f"{type(e).__name__}: {e}"
            res.wall_s = time.time() - t0
            return res
        finally:
            set_registry(prev_reg)
        res.record, res.wall_s = rec, time.time() - t0

    # Claims are (re-)evaluated on every run, including store-resumed ones,
    # so tightening a suite file re-gates cached results without simulating.
    table = ResultTable(res.record.rows) if res.record.rows else None
    res.claims = evaluate_claims(item, table, res.record.payload)
    res.record = res.record.with_claims(res.claims)
    store.put(res.record)
    return res


def run_suite(
    suite: Any,
    *,
    store: Any = None,
    resume: bool = True,
    engine: str | None = None,
    workers: int | None = None,
    verbose: bool = False,
) -> SuiteRunResult:
    """Run a scenario suite through the result store (resumably).

    ``suite`` is a :class:`repro.store.SuiteSpec` or a path to a suite
    file.  Per item the store is probed with the item's identity hash
    first — a hit (``resume=True``, the default) skips execution entirely
    and only re-evaluates the item's claims, so a second invocation of an
    unchanged suite simulates nothing.  Results land in ``store``
    (default :func:`repro.store.default_store_dir`) as immutable
    :class:`~repro.store.RunRecord`\\ s plus one aggregate suite record
    whose identity covers every member id.
    """
    from repro.store import ResultStore, RunRecord, SuiteSpec

    if not isinstance(suite, SuiteSpec):
        suite = SuiteSpec.from_file(suite)
    store = store if store is not None else ResultStore()
    suite.ensure_registered()

    items: list[SuiteItemResult] = []
    for item in suite.items:
        if verbose:
            print(f"[suite {suite.name}] {item.kind} {item.name} ...",
                  flush=True)
        res = _run_suite_item(item, store, resume=resume, engine=engine,
                              workers=workers, verbose=verbose)
        if verbose:
            src = "store" if res.cached else f"ran in {res.wall_s:.1f}s"
            print(f"[suite {suite.name}] {item.name}: {src}, "
                  f"{'ok' if res.ok else 'FAILED'}", flush=True)
        items.append(res)

    identity = {"suite": suite.name,
                "member_ids": [it.record_id for it in items],
                "eval_version": _EVAL_CACHE_VERSION}
    suite_rec = RunRecord.create(
        "suite", suite.name, identity,
        payload={"items": [{
            "name": it.name, "kind": it.kind, "record_id": it.record_id,
            "cached": it.cached, "ok": it.ok, "error": it.error,
            "claims": it.claims,
        } for it in items]},
        timings={"wall_s": sum(it.wall_s for it in items)})
    store.put(suite_rec)
    return SuiteRunResult(suite=suite, record=suite_rec, items=items)
