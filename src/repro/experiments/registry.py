"""Registries: strategies, trace distributions, named experiments.

Strategies and distributions become *discoverable, spec-constructible*
objects: a :class:`~repro.experiments.spec.StrategySpec` or
:class:`~repro.experiments.spec.DistributionSpec` names a registered factory
and supplies its parameters, so experiments serialize to JSON and the CLI
(``python -m benchmarks.run --list``) can enumerate everything.

  * ``@register_distribution(name)`` — factory ``(**params) -> Distribution``;
  * ``@register_strategy(name)``     — factory ``(scenario, **params)`` that
    returns either a :class:`repro.core.policies.Strategy` or a
    :class:`~repro.experiments.runner.BestPeriodSearch`;
  * ``@register_experiment(name)``   — builder ``(quick=True) -> ExperimentSpec``
    (benchmarks register themselves on import).

Strategy factories receive the full :class:`ScenarioSpec`, so scenario-aware
strategies (hazard-tracking dynamic periods, prediction-based policies) can
derive their parameters from the cell they run in.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable

import numpy as np

from repro.core import policies
from repro.core.simulator import NeverTrust, ThresholdTrust
from repro.core.traces import (Distribution, Empirical, Exponential,
                               LogNormalDist, UniformDist, Weibull,
                               lanl_like_log)
from repro.core.exact import optimal_period_exact, t_exact_nopred
from repro.core.prediction import beta_lim
from repro.core.waste import t_exact_exponential

from .spec import ExperimentSpec, ScenarioSpec

__all__ = [
    "register_strategy",
    "register_distribution",
    "register_experiment",
    "build_strategy",
    "build_distribution",
    "build_experiment",
    "list_strategies",
    "list_distributions",
    "list_experiments",
    "PREDICTORS",
    "HazardPeriod",
    "aggregate_hazard",
]

_STRATEGIES: dict[str, Callable[..., Any]] = {}
_DISTRIBUTIONS: dict[str, Callable[..., Distribution]] = {}
_EXPERIMENTS: dict[str, tuple[Callable[..., ExperimentSpec], str]] = {}

# Literature predictors used throughout the paper's simulations (§5.1).
PREDICTORS = {
    "good": (0.85, 0.82),   # Yu et al. [7]
    "fair": (0.70, 0.40),   # Zheng et al. [8]
}


def register_strategy(name: str):
    """Register ``factory(scenario: ScenarioSpec, **params)`` under ``name``."""
    def wrap(factory: Callable[..., Any]) -> Callable[..., Any]:
        if name in _STRATEGIES:
            raise ValueError(f"strategy {name!r} already registered")
        _STRATEGIES[name] = factory
        return factory
    return wrap


def register_distribution(name: str):
    """Register ``factory(**params) -> Distribution`` under ``name``."""
    def wrap(factory: Callable[..., Distribution]) -> Callable[..., Distribution]:
        if name in _DISTRIBUTIONS:
            raise ValueError(f"distribution {name!r} already registered")
        _DISTRIBUTIONS[name] = factory
        return factory
    return wrap


def register_experiment(name: str, description: str = ""):
    """Register ``builder(quick=True) -> ExperimentSpec`` under ``name``."""
    def wrap(builder: Callable[..., ExperimentSpec]) -> Callable[..., ExperimentSpec]:
        if name in _EXPERIMENTS:
            raise ValueError(f"experiment {name!r} already registered")
        _EXPERIMENTS[name] = (builder, description or (builder.__doc__ or "")
                              .strip().split("\n")[0])
        return builder
    return wrap


def build_strategy(name: str, scenario: ScenarioSpec, **params: Any):
    if name not in _STRATEGIES:
        raise KeyError(f"unknown strategy {name!r}; "
                       f"registered: {sorted(_STRATEGIES)}")
    return _STRATEGIES[name](scenario, **params)


def build_distribution(name: str, **params: Any) -> Distribution:
    if name not in _DISTRIBUTIONS:
        raise KeyError(f"unknown distribution {name!r}; "
                       f"registered: {sorted(_DISTRIBUTIONS)}")
    return _DISTRIBUTIONS[name](**params)


def build_experiment(name: str, **kw: Any) -> ExperimentSpec:
    if name not in _EXPERIMENTS:
        raise KeyError(f"unknown experiment {name!r}; "
                       f"registered: {sorted(_EXPERIMENTS)}")
    return _EXPERIMENTS[name][0](**kw)


def list_strategies() -> list[str]:
    return sorted(_STRATEGIES)


def list_distributions() -> list[str]:
    return sorted(_DISTRIBUTIONS)


def list_experiments() -> dict[str, str]:
    return {name: desc for name, (_, desc) in sorted(_EXPERIMENTS.items())}


# ---------------------------------------------------------------------------
# Built-in distributions (core/traces.py families)
# ---------------------------------------------------------------------------

@register_distribution("exponential")
def _exponential(mean: float = 1.0) -> Exponential:
    return Exponential(mean)


@register_distribution("weibull")
def _weibull(shape: float = 0.7, mean: float = 1.0) -> Weibull:
    return Weibull(shape, mean)


@register_distribution("uniform")
def _uniform(mean: float = 1.0) -> UniformDist:
    return UniformDist(mean)


@register_distribution("lognormal")
def _lognormal(sigma: float = 1.0, mean: float = 1.0) -> LogNormalDist:
    return LogNormalDist(sigma, mean)


@register_distribution("empirical")
def _empirical(samples: tuple | list = ()) -> Empirical:
    return Empirical(tuple(float(s) for s in samples))


@register_distribution("lanl")
def _lanl(n_intervals: int = 3010, mu_ind_days: float = 691.0,
          shape: float = 0.6, seed: int = 42) -> Empirical:
    """LANL-like empirical availability-interval log (paper §5.3 mechanism)."""
    return lanl_like_log(np.random.default_rng(seed),
                         n_intervals=n_intervals, mu_ind_days=mu_ind_days,
                         shape=shape)


# ---------------------------------------------------------------------------
# Built-in strategies (paper §5.1 heuristics + beyond-paper extensions)
# ---------------------------------------------------------------------------

@register_strategy("young")
def _young(scenario: ScenarioSpec) -> policies.Strategy:
    return policies.young(scenario.platform)


@register_strategy("daly")
def _daly(scenario: ScenarioSpec) -> policies.Strategy:
    return policies.daly(scenario.platform)


@register_strategy("rfo")
def _rfo(scenario: ScenarioSpec) -> policies.Strategy:
    return policies.rfo(scenario.platform)


@register_strategy("exact_exponential")
def _exact_exponential(scenario: ScenarioSpec) -> policies.Strategy:
    """Lambert-W optimal period for Exponential faults (paper §3 end)."""
    return policies.Strategy("ExactExponential",
                             t_exact_exponential(scenario.platform),
                             NeverTrust())


@register_strategy("optimal_prediction")
def _optimal_prediction(scenario: ScenarioSpec) -> policies.Strategy:
    return policies.optimal_prediction(scenario.pp)


# -- exact-Exponential strategies (arXiv:1207.6936; core/exact.py) ----------

@register_strategy("exact_nopred")
def _exact_nopred(scenario: ScenarioSpec) -> policies.Strategy:
    """The exact no-prediction optimum (Lambert-W period, never trust) —
    the renewal-analysis counterpart of ``rfo``."""
    return policies.Strategy("ExactNoPred", t_exact_nopred(scenario.platform),
                             NeverTrust())


@register_strategy("exact_prediction")
def _exact_prediction(scenario: ScenarioSpec,
                      refine_threshold: bool = True) -> policies.Strategy:
    """The exact threshold-policy optimum: (T*, beta*) jointly minimizing
    the exact renewal waste — the counterpart of ``optimal_prediction``."""
    plan = optimal_period_exact(scenario.pp,
                                refine_threshold=refine_threshold)
    trust = (ThresholdTrust(plan.threshold) if plan.use_predictions
             else NeverTrust())
    return policies.Strategy("ExactPrediction", plan.period, trust)


# -- model-order-aware planners (follow ScenarioSpec.model_order) -----------

def _scenario_order(scenario: ScenarioSpec, model_order: str | None) -> str:
    order = scenario.model_order if model_order is None else model_order
    if order not in ("first", "exact"):
        raise ValueError(f"model_order must be 'first' or 'exact', "
                         f"got {order!r}")
    return order


@register_strategy("nopred")
def _nopred(scenario: ScenarioSpec,
            model_order: str | None = None) -> policies.Strategy:
    """The no-prediction baseline planned at the scenario's model order:
    RFO (first order) or the Lambert-W exact optimum."""
    if _scenario_order(scenario, model_order) == "exact":
        period = t_exact_nopred(scenario.platform)
    else:
        period = policies.rfo(scenario.platform).period
    return policies.Strategy("NoPred", period, NeverTrust())


@register_strategy("prediction")
def _prediction(scenario: ScenarioSpec,
                model_order: str | None = None) -> policies.Strategy:
    """The prediction-aware threshold policy planned at the scenario's
    model order (§4.3 first-order vs the exact renewal optimum)."""
    if _scenario_order(scenario, model_order) == "exact":
        inner = _exact_prediction(scenario)
    else:
        inner = policies.optimal_prediction(scenario.pp)
    return dataclasses.replace(inner, name="Prediction")


@register_strategy("inexact_prediction")
def _inexact_prediction(scenario: ScenarioSpec,
                        window: float | None = None) -> policies.Strategy:
    return policies.inexact_prediction(scenario.pp, window=window)


@register_strategy("simple_policy")
def _simple_policy(scenario: ScenarioSpec,
                   q: float | None = None) -> policies.Strategy:
    return policies.simple_policy(scenario.pp, q=q)


# -- prediction-window strategies (arXiv:1302.4558; core/windows.py) --------

def _scenario_window(scenario: ScenarioSpec, window: float | None) -> float:
    return scenario.window if window is None else float(window)


@register_strategy("window_ignore")
def _window_ignore(scenario: ScenarioSpec,
                   window: float | None = None) -> policies.Strategy:
    """Ignore window predictions entirely (the RFO baseline on the window
    scenario; faults still materialize inside their windows)."""
    from repro.core.windows import window_strategy
    return window_strategy(scenario.pp, _scenario_window(scenario, window),
                           mode="ignore")


@register_strategy("window_start")
def _window_start(scenario: ScenarioSpec,
                  window: float | None = None) -> policies.Strategy:
    """One proactive checkpoint completing at the window start (the
    'instant' reduction of a window prediction)."""
    from repro.core.windows import window_strategy
    return window_strategy(scenario.pp, _scenario_window(scenario, window),
                           mode="instant")


@register_strategy("window_proactive")
def _window_proactive(scenario: ScenarioSpec, window: float | None = None,
                      window_period: float | None = None) -> policies.Strategy:
    """Periodic proactive checkpointing inside the window (period T_p*, or
    an explicit ``window_period``), with the window trust breakpoint."""
    from repro.core.windows import window_strategy
    return window_strategy(scenario.pp, _scenario_window(scenario, window),
                           mode="within", window_period=window_period)


# -- silent-error strategies (arXiv:1310.8486; core/silent.py) --------------

def _scenario_verify(scenario: ScenarioSpec, verify_cost: float | None,
                     keep_ckpts: int | None) -> tuple[float, int]:
    from repro.core.silent import DEFAULT_KEEP_CKPTS
    vc = scenario.verify_cost if verify_cost is None else float(verify_cost)
    if keep_ckpts is None:
        # The scenario default of 1 is the fail-stop value; verifying
        # strategies need depth >= 2 to survive a corrupted save.
        kc = max(scenario.keep_ckpts, DEFAULT_KEEP_CKPTS)
    else:
        kc = int(keep_ckpts)
    return vc, kc


@register_strategy("silent_ignore")
def _silent_ignore(scenario: ScenarioSpec) -> policies.Strategy:
    """The fail-stop RFO baseline running blind on the silent stream (no
    verifications; corruption is only caught by the acceptance check)."""
    from repro.core.silent import silent_strategy
    return silent_strategy(scenario.platform, scenario.silent_mu,
                           mode="ignore")


@register_strategy("silent_verify")
def _silent_verify(scenario: ScenarioSpec, verify_cost: float | None = None,
                   keep_ckpts: int | None = None,
                   k_max: int = 16) -> policies.Strategy:
    """The jointly optimal (T*, k*) verification plan, never trusting
    predictions (core/silent.py)."""
    from repro.core.silent import silent_strategy
    vc, kc = _scenario_verify(scenario, verify_cost, keep_ckpts)
    return silent_strategy(scenario.platform, scenario.silent_mu, vc,
                           mode="verify", k_max=k_max, keep_ckpts=kc)


@register_strategy("silent_verify_pred")
def _silent_verify_pred(scenario: ScenarioSpec,
                        verify_cost: float | None = None,
                        keep_ckpts: int | None = None,
                        k_max: int = 16) -> policies.Strategy:
    """The combined silent + prediction plan (Theorem-1 threshold trust
    on top of the (T*, k*) verification cadence)."""
    from repro.core.silent import silent_strategy
    vc, kc = _scenario_verify(scenario, verify_cost, keep_ckpts)
    return silent_strategy(scenario.platform, scenario.silent_mu, vc,
                           mode="verify_pred", pp=scenario.pp, k_max=k_max,
                           keep_ckpts=kc)


@register_strategy("adaptive")
def _adaptive(scenario: ScenarioSpec, prior_recall: float | None = None,
              prior_precision: float | None = None, min_preds: int = 32,
              min_faults: int = 16, tol: float = 0.05,
              model_order: str | None = None,
              halflife: float | None = None) -> policies.Strategy:
    """Online (r-hat, p-hat) estimation with adaptive re-planning.

    Starts on the model-optimal plan for the *prior* (r, p) — the
    scenario's nominal predictor by default, or an explicitly stale
    ``prior_recall`` / ``prior_precision`` — then re-plans T* and the
    trust threshold from the gated running estimates as they drift
    (``repro.predictors.estimator``).  Both the initial plan and every
    re-plan solve the scenario's ``model_order`` analysis.  ``halflife``
    (observations) switches the estimator to its windowed (EW) variant so
    the plan tracks a drifting predictor instead of the all-time average.
    """
    from repro.predictors.estimator import AdaptiveConfig
    r0 = scenario.recall if prior_recall is None else float(prior_recall)
    p0 = scenario.precision if prior_precision is None \
        else float(prior_precision)
    cfg = AdaptiveConfig(prior_recall=r0, prior_precision=p0,
                         min_preds=min_preds, min_faults=min_faults, tol=tol,
                         model_order=_scenario_order(scenario, model_order),
                         halflife=halflife)
    t0, thr0 = cfg.plan(scenario.platform, scenario.cp, r0, p0)
    return policies.Strategy("Adaptive", float(t0), ThresholdTrust(thr0),
                             adaptive=cfg)


@register_strategy("fixed_period")
def _fixed_period(scenario: ScenarioSpec, period: float = 0.0,
                  trust_threshold: float | None = None) -> policies.Strategy:
    """An explicit period (seconds); optional Theorem-1 threshold trust."""
    if period <= 0.0:
        raise ValueError("fixed_period requires period > 0")
    trust = (ThresholdTrust(trust_threshold)
             if trust_threshold is not None else NeverTrust())
    return policies.Strategy(f"Fixed(T={period:g})", period, trust)


@register_strategy("best_period")
def _best_period(scenario: ScenarioSpec, base: str = "rfo",
                 base_params: dict | None = None, n_points: int = 24,
                 span: float = 8.0):
    """BestPeriod search (paper §5.1) wrapped around any registered strategy."""
    from .runner import BestPeriodSearch
    inner = build_strategy(base, scenario, **(base_params or {}))
    if isinstance(inner, BestPeriodSearch):
        raise ValueError("cannot nest best_period searches")
    return BestPeriodSearch(base=inner, n_points=n_points, span=span)


# -- hazard-aware dynamic periods (beyond the paper; see benchmarks/beyond.py)

def aggregate_hazard(n: int, shape: float, mu_ind: float, t: float) -> float:
    """h(t) for N superposed fresh Weibull(shape) processors."""
    lam = mu_ind / math.gamma(1.0 + 1.0 / shape)
    t = max(t, 1.0)
    return n * (shape / lam) * (t / lam) ** (shape - 1.0)


@dataclasses.dataclass(frozen=True)
class HazardPeriod:
    """Callable period T(t) = sqrt(2 C / ((1-r) h(start + t))).

    Picklable (unlike a closure), so dynamic strategies survive the runner's
    process-parallel path and result caching.
    """

    n: int
    shape: float
    mu_ind: float
    start: float
    c: float
    recall: float = 0.0
    floor_mult: float = 1.0

    def __call__(self, t: float) -> float:
        h = aggregate_hazard(self.n, self.shape, self.mu_ind, self.start + t)
        mu_eff = 1.0 / max(h, 1e-12)
        t_opt = math.sqrt(2.0 * mu_eff * self.c
                          / max(1.0 - self.recall, 1e-6))
        return max(self.floor_mult * self.c, t_opt)


def _scenario_shape(scenario: ScenarioSpec, shape: float | None) -> float:
    if shape is not None:
        return shape
    if "shape" in scenario.dist.params:
        return float(scenario.dist.params["shape"])
    raise ValueError("dynamic strategies need a Weibull shape: pass "
                     "params={'shape': k} or use a weibull fault distribution")


@register_strategy("dynamic_rfo")
def _dynamic_rfo(scenario: ScenarioSpec, shape: float | None = None,
                 floor_mult: float = 1.0) -> policies.Strategy:
    """RFO with the period tracking the decaying aggregate Weibull hazard."""
    period = HazardPeriod(scenario.n, _scenario_shape(scenario, shape),
                          scenario.mu_ind, scenario.start, scenario.c,
                          floor_mult=floor_mult)
    return policies.Strategy("DynamicRFO", period, NeverTrust())


@register_strategy("dynamic_prediction")
def _dynamic_prediction(scenario: ScenarioSpec, shape: float | None = None,
                        floor_mult: float = 1.0) -> policies.Strategy:
    """OptimalPrediction with a hazard-tracking period (beta_lim unchanged)."""
    period = HazardPeriod(scenario.n, _scenario_shape(scenario, shape),
                          scenario.mu_ind, scenario.start, scenario.c,
                          recall=scenario.recall, floor_mult=floor_mult)
    return policies.Strategy("DynamicPrediction", period,
                             ThresholdTrust(beta_lim(scenario.pp)))
