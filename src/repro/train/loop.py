"""Fault-tolerant training loop: the paper's policy wired to real state.

The trainer executes *actual* jitted train steps (model fwd/bwd + AdamW) and
overlays the paper's fault/checkpoint schedule on a virtual clock:

  * every step costs ``step_time`` virtual seconds (measured on first call
    when ``step_time`` is None);
  * periodic checkpoints of cost C follow the scheduler's period T*
    (RFO or OptimalPrediction);
  * trusted predictions trigger proactive checkpoints (cost C_p, delta-
    encoded) timed to complete exactly at the predicted date (§4.1);
  * injected faults roll the *real* training state back to the last durable
    checkpoint: parameters and optimizer state are restored from disk, the
    deterministic data pipeline replays from the restored step, and the
    clock pays D + R.

Decisions happen at step boundaries (steps are atomic in a real framework —
the one deviation from the paper's continuous-work model; it quantizes
T_lost by at most one step).  The stats mirror
:class:`repro.core.simulator.SimResult`, so the measured waste of a run can
be compared directly against the analytic model — that comparison is an
integration test and an example.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from ..ckpt.manager import CheckpointManager
from ..configs.base import InputShape, ModelConfig, PlatformConfig
from ..core.traces import EventTrace
from ..data.pipeline import DataConfig, SyntheticLM
from ..ft.runtime import (FaultInjector, Prediction, PredictorRuntime,
                          VirtualClock)
from ..ft.scheduler import CheckpointScheduler
from ..models.model import init_params, loss_fn
from ..optim.adamw import AdamWConfig, adamw_init, adamw_update

__all__ = ["TrainerStats", "FaultTolerantTrainer"]


@dataclasses.dataclass
class TrainerStats:
    """Measured waste breakdown (same axes as the paper's simulator)."""

    total_time: float = 0.0
    useful_time: float = 0.0     # first-execution step time
    lost_time: float = 0.0       # re-executed (destroyed) step time
    ckpt_time: float = 0.0
    prockpt_time: float = 0.0
    down_time: float = 0.0
    n_steps: int = 0
    n_faults: int = 0
    n_rollbacks: int = 0
    n_periodic: int = 0
    n_proactive: int = 0
    n_trusted_true: int = 0
    final_loss: float = float("nan")

    @property
    def waste(self) -> float:
        return 1.0 - self.useful_time / self.total_time \
            if self.total_time > 0 else 0.0


class FaultTolerantTrainer:
    """End-to-end trainer with faults, predictions and optimal checkpoints."""

    def __init__(self, cfg: ModelConfig, shape: InputShape,
                 platform: PlatformConfig, *, workdir: str,
                 n_devices: int = 1, step_time: float | None = None,
                 trace: EventTrace | None = None, lead_time: float = 0.0,
                 use_predictor: bool = True, seed: int = 0,
                 opt: AdamWConfig | None = None,
                 data_cfg: DataConfig | None = None) -> None:
        self.cfg = cfg
        self.shape = shape
        self.platform = platform
        self.opt_cfg = opt or AdamWConfig(moment_dtype=cfg.opt_dtype)
        self.data = SyntheticLM(cfg, shape, data_cfg or DataConfig(seed=seed))
        self.manager = CheckpointManager(workdir,
                                         bandwidth=platform.ckpt_bandwidth)

        params, self.axes = init_params(cfg, jax.random.PRNGKey(seed))
        self.state: dict[str, Any] = {
            "params": params,
            "opt": adamw_init(params, self.opt_cfg),
            "data_step": jnp.zeros((), jnp.int32),
        }

        c, cp = platform.c, platform.cp
        if c <= 0:  # derive from state bytes / bandwidth (TPU_V5E preset)
            c, cp = self.manager.modeled_costs(self.state,
                                               n_shards=n_devices)
        self.scheduler = CheckpointScheduler(
            platform, n_devices, c=c, cp=cp, use_predictor=use_predictor)

        self.clock = VirtualClock()
        self.injector = FaultInjector(trace) if trace is not None else None
        self._trace = trace
        self._lead_time = lead_time
        self._use_predictor = use_predictor
        self.predictor = None  # built in run() once step_time is known
        self._step_time = step_time

        def train_step(params, opt_state, batch):
            (_, metrics), grads = jax.value_and_grad(
                lambda p: loss_fn(cfg, p, batch), has_aux=True)(params)
            new_params, new_opt, opt_metrics = adamw_update(
                params, grads, opt_state, self.opt_cfg)
            return new_params, new_opt, {**metrics, **opt_metrics}

        self._train_step = jax.jit(train_step)

    # -- helpers ---------------------------------------------------------------

    def _measure_step_time(self) -> float:
        batch = self.data.batch_at(0)
        p, o, _ = self._train_step(self.state["params"], self.state["opt"],
                                   batch)  # compile
        jax.block_until_ready(p)
        t0 = time.perf_counter()
        p, o, _ = self._train_step(self.state["params"], self.state["opt"],
                                   batch)
        jax.block_until_ready(p)
        return time.perf_counter() - t0

    def _do_step(self, stats: TrainerStats) -> dict:
        step = int(self.state["data_step"])
        batch = self.data.batch_at(step)
        params, opt, metrics = self._train_step(
            self.state["params"], self.state["opt"], batch)
        self.state = {"params": params, "opt": opt,
                      "data_step": jnp.asarray(step + 1, jnp.int32)}
        return metrics

    def _save(self, stats: TrainerStats, *, proactive: bool,
              complete_at: float | None = None) -> None:
        cost = self.scheduler.cp if proactive else self.scheduler.c
        if complete_at is not None:
            # Stall work so the save completes exactly at the predicted date.
            idle = complete_at - cost - self.clock.now
            if idle > 0:
                self.clock.advance(idle)
        step = int(self.state["data_step"])
        if proactive:
            self.manager.save_proactive(step, self.state)
            stats.prockpt_time += cost
            stats.n_proactive += 1
        else:
            self.manager.save(step, self.state)
            stats.ckpt_time += cost
            stats.n_periodic += 1
        self.clock.advance(cost)
        self.scheduler.notify_save_completed(self.clock.now)
        self._work_since_save = 0.0

    def _rollback(self, stats: TrainerStats, fault_time: float) -> None:
        stats.n_faults += 1
        stats.n_rollbacks += 1
        # Destroyed work: completed-but-unsaved steps plus the partial step
        # that was in flight when the fault struck.
        partial = max(0.0, fault_time - self.clock.now)
        stats.lost_time += self._work_since_save + partial
        stats.useful_time -= self._work_since_save
        self._work_since_save = 0.0
        if fault_time > self.clock.now:
            self.clock.advance(fault_time - self.clock.now)
        self.clock.advance(self.platform.d + self.platform.r)
        stats.down_time += self.platform.d + self.platform.r
        try:
            _, self.state = self.manager.restore(like=self.state)
        except FileNotFoundError:
            # No checkpoint yet: restart from scratch (step 0 state is
            # reproducible from the seed).
            params, _ = init_params(self.cfg, jax.random.PRNGKey(0))
            self.state = {"params": params,
                          "opt": adamw_init(params, self.opt_cfg),
                          "data_step": jnp.zeros((), jnp.int32)}
        self.scheduler.notify_save_completed(self.clock.now)

    # -- the loop ---------------------------------------------------------------

    def run(self, n_steps: int) -> TrainerStats:
        """Train until ``n_steps`` *useful* steps are secured."""
        stats = TrainerStats()
        if self._step_time is None:
            self._step_time = self._measure_step_time()
        dt = self._step_time
        if self.predictor is None and self._trace is not None \
                and self._use_predictor:
            # Steps are atomic: a prediction announced mid-step can only be
            # acted on once the step completes, so the minimum usable lead
            # time is C_p + one step (predictions with shorter leads count
            # as unpredicted faults, exactly the paper's §2.2 rule).
            lead = max(self._lead_time, self.scheduler.cp + dt)
            self.predictor = PredictorRuntime(self._trace, lead)
        self._work_since_save = 0.0
        metrics: dict = {}

        while int(self.state["data_step"]) < n_steps:
            t0 = self.clock.now
            t1 = t0 + dt

            # 1. Does a fault strike during this step?
            fault = (self.injector.next_fault_in(t0, t1)
                     if self.injector else None)
            if fault is not None:
                self._rollback(stats, fault)
                continue

            # 2. Predictions announced during this step.  Steps are atomic,
            #    so the reaction happens right after the step; the lead-time
            #    floor above guarantees date - C_p >= t1.
            planned: Prediction | None = None
            if self.predictor is not None:
                for pred in self.predictor.announced_in(t0, t1):
                    if pred.date - self.scheduler.cp < t1:
                        continue  # too late to honour: ignore by necessity
                    if self.scheduler.trust(pred.date):
                        planned = pred
                        break  # one proactive save covers this window

            # 3. Execute the real step.
            metrics = self._do_step(stats)
            self.clock.advance(dt)
            stats.useful_time += dt
            self._work_since_save += dt
            stats.n_steps += 1

            # 4. Take the planned proactive checkpoint, completing exactly
            #    at the predicted date (§4.1).
            if planned is not None:
                self._save(stats, proactive=True, complete_at=planned.date)
                if planned.is_true:
                    stats.n_trusted_true += 1

            # 5. Periodic checkpoint when due.
            if self.scheduler.due(self.clock.now):
                self._save(stats, proactive=False)

        # Final checkpoint (the paper checkpoints at the end of execution).
        self._save(stats, proactive=False)
        stats.total_time = self.clock.now
        if "loss" in metrics:
            stats.final_loss = float(metrics["loss"])
        return stats
