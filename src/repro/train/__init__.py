"""Fault-tolerant training loop."""

from .loop import FaultTolerantTrainer, TrainerStats

__all__ = ["FaultTolerantTrainer", "TrainerStats"]
