"""Structured run tracing: the ``TraceSink`` protocol and its sinks.

The scalar engine (:func:`repro.core.simulator.simulate`) and the fleet
engine accept a ``sink`` argument.  The default ``sink=None`` is the
zero-overhead-off switch: every hook site is a single ``is not None``
test, tracing never touches the RNG stream or any float the simulation
computes, so enabling it cannot change results (pinned by
``tests/test_obs.py``).

Event vocabulary (the ``kind`` strings the engines emit):

======================  =====================================================
kind                    meaning / args
======================  =====================================================
``ckpt_start``          periodic checkpoint begins
``ckpt_end``            ... completes (``dur`` = C)
``prockpt_start``       proactive checkpoint begins (on a trusted prediction
                        or the in-window cadence)
``prockpt_end``         ... completes (``dur`` = C_p)
``fault``               a fault strikes (``phase`` = machine phase code)
``rollback``            the fault discarded progress (``lost``, ``saved``)
``re_exec``             re-execution debt created (``dur`` = lost work)
``down_start``          downtime begins (``dur`` = D)
``recover_start``       recovery begins (``dur`` = R)
``recover_end``         recovery completes, schedule restarts (``dur`` = R)
``prediction``          a prediction is announced (``true``, ``window``)
``trust``               the trust decision (``trusted``, ``acted``, and
                        ``ignored`` = ignored by necessity)
``replan``              adaptive re-plan fired (``period``, ``threshold``)
======================  =====================================================

The numpy and jax lane engines are bit-for-bit equivalent to the scalar
engine, so a lane's event stream is *reconstructed* post hoc by replaying
the scalar engine on that lane's inputs (:func:`record_run`) — the
ISSUE-sanctioned alternative to host callbacks, and exact by the parity
contract the golden tests pin.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Iterator

__all__ = ["TraceEvent", "TraceSink", "NullSink", "RecordingSink",
           "record_run"]


@dataclasses.dataclass(frozen=True)
class TraceEvent:
    """One structured record emitted by an engine hook point."""

    t: float                  # simulated time of the event
    kind: str                 # vocabulary above
    dur: float = 0.0          # span length for phase-shaped events
    args: dict = dataclasses.field(default_factory=dict)


class TraceSink:
    """Protocol: engines call ``emit`` at every hook point."""

    def emit(self, t: float, kind: str, dur: float = 0.0,
             **args: Any) -> None:
        raise NotImplementedError


class NullSink(TraceSink):
    """Drops every event (for callers that want a sink object anyway;
    the engines' ``sink=None`` default skips the call entirely)."""

    def emit(self, t: float, kind: str, dur: float = 0.0,
             **args: Any) -> None:
        pass


class RecordingSink(TraceSink):
    """Appends every event to an in-memory list."""

    def __init__(self) -> None:
        self.events: list[TraceEvent] = []

    def emit(self, t: float, kind: str, dur: float = 0.0,
             **args: Any) -> None:
        self.events.append(TraceEvent(t, kind, dur, args))

    def __len__(self) -> int:
        return len(self.events)

    def __iter__(self) -> Iterator[TraceEvent]:
        return iter(self.events)

    def by_kind(self, kind: str) -> list[TraceEvent]:
        return [e for e in self.events if e.kind == kind]

    def counts(self) -> dict[str, int]:
        out: dict[str, int] = {}
        for e in self.events:
            out[e.kind] = out.get(e.kind, 0) + 1
        return out


def record_run(trace, platform, time_base, period,
               **kwargs) -> tuple[Any, RecordingSink]:
    """Run the scalar engine with a fresh :class:`RecordingSink`.

    Returns ``(SimResult, sink)``.  Because the lane engines are
    bit-for-bit the scalar engine, this is also the post-hoc trace
    reconstruction for any numpy/jax lane: call it with that lane's
    inputs and the recorded stream is exactly what a host callback
    inside the lane engine would have seen.
    """
    from repro.core.simulator import simulate

    sink = RecordingSink()
    res = simulate(trace, platform, time_base, period, sink=sink, **kwargs)
    return res, sink
