"""Observability layer: tracing, waste attribution, metrics, timelines.

The paper's first-order analysis (arXiv:1302.3752) is a *decomposition* —
waste is a sum of named terms (periodic checkpoints, proactive
checkpoints on predictions, re-execution after unpredicted faults,
downtime + recovery).  This package attributes every simulated second to
one of those terms and exposes the decision points as structured events:

  * :mod:`repro.obs.trace` — the zero-overhead-when-off ``TraceSink``
    protocol the scalar engine (and the fleet engine) emit structured
    events into; ``RecordingSink`` captures them, ``NullSink`` drops
    them.  The numpy/jax lane engines are bit-for-bit equivalent to the
    scalar engine, so a lane's trace is *reconstructed* by replaying the
    scalar engine (:func:`repro.obs.trace.record_run`).
  * :mod:`repro.obs.attribution` — ``WasteAttribution`` buckets
    {work, ckpt, proactive_ckpt, verify, re_exec, downtime, recovery,
    wait}
    with ``sum(buckets) == makespan`` enforced bit-for-bit, plus the
    analytic first-order expectations to reconcile against.
  * :mod:`repro.obs.metrics` — a process-local ``MetricsRegistry``
    (counters / gauges / timers) threaded through the experiment
    runner, the jax engine's chunk driver, and the fleet simulator.
  * :mod:`repro.obs.perfetto` — Chrome/Perfetto ``trace_event`` JSON
    timelines of a run or a fleet (jobs as tracks, checkpoints as
    slices, faults as instants).
"""

from .attribution import (BUCKETS, WasteAttribution, attribute_batch,
                          attribute_fleet_job, attribute_result,
                          expected_fractions)
from .metrics import MetricsRegistry, get_registry, set_registry
from .perfetto import events_to_trace_events, fleet_to_perfetto, write_trace
from .trace import NullSink, RecordingSink, TraceEvent, TraceSink, record_run

__all__ = [
    "BUCKETS",
    "WasteAttribution",
    "attribute_result",
    "attribute_batch",
    "attribute_fleet_job",
    "expected_fractions",
    "MetricsRegistry",
    "get_registry",
    "set_registry",
    "TraceEvent",
    "TraceSink",
    "NullSink",
    "RecordingSink",
    "record_run",
    "events_to_trace_events",
    "fleet_to_perfetto",
    "write_trace",
]
