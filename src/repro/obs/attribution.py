"""Waste attribution: bucket every simulated second into a paper term.

The engines' accrual-exact accounting (see ``_Machine.fault``) already
decomposes the makespan as ``base + ckpt + prockpt + lost + down``; this
module re-expresses that decomposition in the paper's vocabulary —

    {work, ckpt, proactive_ckpt, verify, re_exec, downtime, recovery, wait}

— with the invariant ``sum(buckets) == makespan`` **bit-for-bit**.  The
``work`` bucket is the closure term (makespan minus the overhead
buckets, subtracted in a fixed order); ``total()`` re-adds the same
terms in the exact reverse order, and the constructor repairs the
residual ulp when the float round-trip lands one off, so the invariant
holds exactly, not approximately.

``downtime``/``recovery`` come from the engines' independent split
accumulators (``SimResult.time_downtime`` / ``time_recovery``); the
merged ``time_down`` stays the authoritative golden-parity accrual and
is *not* used in bucket math.  ``verify`` is the silent-error
verification accrual (``SimResult.time_verify``; 0 unless the run used
``n_verify >= 1``, and read with a 0 default so pre-silent result
objects still attribute).  ``wait`` is the fleet-level coupling cost
(storage contention stretch + repair-queue waiting); it is 0 for
single-job runs.

:func:`expected_fractions` gives the paper's first-order expectation of
each bucket as a fraction of the makespan — ``C/T`` checkpointing,
``D/mu`` downtime, ``R/mu`` recovery, ``T/2mu`` re-execution (Eq. 7),
and with a predictor the refined-policy terms of Eq. 15 /
``unavailability_pred`` — so a measured attribution reconciles
term-by-term against ``waste1``/``waste2`` instead of only in aggregate.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any

__all__ = ["BUCKETS", "WasteAttribution", "attribute_result",
           "attribute_fleet_job", "attribute_batch", "expected_fractions"]

BUCKETS = ("work", "ckpt", "proactive_ckpt", "verify", "re_exec",
           "downtime", "recovery", "wait")

# The overhead buckets in the fixed fold order total()/closure use.
_OVERHEADS = BUCKETS[1:]


@dataclasses.dataclass(frozen=True)
class WasteAttribution:
    """Per-run (or per-job) bucketed decomposition of the makespan."""

    makespan: float
    work: float
    ckpt: float
    proactive_ckpt: float
    verify: float
    re_exec: float
    downtime: float
    recovery: float
    wait: float = 0.0

    def total(self) -> float:
        """Left-fold sum of the buckets — equals ``makespan`` exactly."""
        tot = self.work
        for name in _OVERHEADS:
            tot += getattr(self, name)
        return tot

    def buckets(self) -> dict[str, float]:
        return {name: getattr(self, name) for name in BUCKETS}

    def fractions(self) -> dict[str, float]:
        """Bucket shares of the makespan (0 if the run is empty)."""
        if self.makespan <= 0.0:
            return {name: 0.0 for name in BUCKETS}
        return {name: getattr(self, name) / self.makespan
                for name in BUCKETS}

    def waste_fraction(self) -> float:
        """Share of the makespan not spent on useful work."""
        if self.makespan <= 0.0:
            return 0.0
        return 1.0 - self.work / self.makespan


def _close(makespan: float, ckpt: float, proactive_ckpt: float,
           verify: float, re_exec: float, downtime: float,
           recovery: float, wait: float) -> WasteAttribution:
    """Build the attribution with ``work`` as the exact closure term.

    ``work`` subtracts the overheads in reverse fold order so
    ``total()`` is the float round-trip of the same chain; the repair
    loop absorbs the rare half-ulp tie where the round-trip lands one
    ulp off, making ``total() == makespan`` a hard invariant.
    """
    work = makespan
    for v in (wait, recovery, downtime, re_exec, verify, proactive_ckpt,
              ckpt):
        work -= v
    for _ in range(8):
        att = WasteAttribution(makespan=makespan, work=work, ckpt=ckpt,
                               proactive_ckpt=proactive_ckpt,
                               verify=verify,
                               re_exec=re_exec, downtime=downtime,
                               recovery=recovery, wait=wait)
        err = makespan - att.total()
        if err == 0.0:
            return att
        work += err
    raise ArithmeticError(           # pragma: no cover - repair converges
        f"bucket closure did not converge (residual {err!r})")


def attribute_result(res: Any, *, wait: float = 0.0) -> WasteAttribution:
    """Attribution of a :class:`repro.core.simulator.SimResult` (or any
    object with the same time fields, e.g. ``BatchResult.result()``)."""
    return _close(res.makespan, res.time_ckpt, res.time_prockpt,
                  getattr(res, "time_verify", 0.0), res.time_lost,
                  res.time_downtime, res.time_recovery, wait)


def attribute_fleet_job(job: Any) -> WasteAttribution:
    """Attribution of a :class:`repro.fleet.sim.FleetJobResult`.

    The ``wait`` bucket collects the fleet couplings: storage-contention
    stretch on periodic and proactive saves plus repair-queue waiting.
    """
    wait = job.time_contention_ckpt
    wait += job.time_contention_prockpt
    wait += job.time_repair_wait
    return attribute_result(job.sim, wait=wait)


def attribute_batch(batch: Any) -> dict[str, Any]:
    """Vectorized attribution of a numpy/jax ``BatchResult``.

    Returns ``{bucket: ndarray}`` (the grid shape of the batch) built
    with the same closure + repair construction, so
    ``sum(buckets) == makespan`` holds elementwise bit-for-bit.
    """
    import numpy as np

    if batch.time_downtime is None or batch.time_recovery is None:
        raise ValueError("batch result lacks the downtime/recovery split "
                         "(engine predates the observability fields)")
    makespan = np.asarray(batch.makespan, dtype=np.float64)
    time_verify = getattr(batch, "time_verify", None)
    if time_verify is None:
        time_verify = np.zeros_like(makespan)
    over = [np.broadcast_to(np.asarray(a, dtype=np.float64),
                            makespan.shape)
            for a in (batch.time_ckpt, batch.time_prockpt, time_verify,
                      batch.time_lost, batch.time_downtime,
                      batch.time_recovery)]
    ckpt, proactive, verify, re_exec, downtime, recovery = over
    wait = np.zeros_like(makespan)
    work = makespan.copy()
    for v in (wait, recovery, downtime, re_exec, verify, proactive, ckpt):
        work -= v
    for _ in range(8):
        tot = work.copy()
        for v in (ckpt, proactive, verify, re_exec, downtime, recovery,
                  wait):
            tot += v
        err = makespan - tot
        if not err.any():
            break
        work += err
    else:                            # pragma: no cover - repair converges
        raise ArithmeticError("bucket closure did not converge")
    return {"work": work, "ckpt": ckpt, "proactive_ckpt": proactive,
            "verify": verify, "re_exec": re_exec, "downtime": downtime,
            "recovery": recovery, "wait": wait}


def expected_fractions(t: float, platform: Any, pp: Any = None, *,
                       n_verify: int = 0,
                       verify_cost: float = 0.0) -> dict[str, float]:
    """First-order expected bucket fractions of the makespan.

    Without a predictor (``pp=None``) these are the terms of Eq. 4/7:
    ``ckpt = C/T``, ``downtime = D/mu``, ``recovery = R/mu``,
    ``re_exec = T/2mu``.  With a :class:`PredictedPlatform` acting past
    ``beta_lim`` they are the refined-policy terms of Eq. 15 (the unit
    weight case of ``fleet.availability.unavailability_pred``):
    re-execution drops to ``(1-r)T/2mu + r beta^2/2Tmu`` and proactive
    checkpoints cost ``(r/p) C_p max(0, 1 - beta/T)/mu``.  With
    ``n_verify = k >= 1`` verifications of cost ``verify_cost = V`` per
    period (arXiv:1310.8486; see :mod:`repro.core.silent`) the
    fault-free verification term is ``kV/T``.  ``work`` is the
    complement; ``wait`` is 0 (single-job analysis).
    """
    mu = platform.mu
    out = {"ckpt": platform.c / t, "downtime": platform.d / mu,
           "recovery": platform.r / mu, "wait": 0.0,
           "verify": n_verify * verify_cost / t}
    if pp is None:
        out["proactive_ckpt"] = 0.0
        out["re_exec"] = t / (2.0 * mu)
    else:
        from repro.core.prediction import beta_lim

        rec = pp.predictor.recall
        prec = pp.predictor.precision
        beta = beta_lim(pp)
        act = max(0.0, 1.0 - beta / t)
        out["proactive_ckpt"] = (rec / prec) * pp.cp * act / mu
        out["re_exec"] = ((1.0 - rec) * t / 2.0
                          + rec * beta * beta / (2.0 * t)) / mu
    out["work"] = 1.0 - math.fsum(out[n] for n in _OVERHEADS)
    return out
