"""Process-local metrics registry: counters, gauges, timers.

A :class:`MetricsRegistry` is a plain dict-of-dicts with no locking or
export machinery — the runner, the jax chunk driver, and the fleet
simulator increment into whichever registry is *installed*
(:func:`get_registry`), and suite runs snapshot it into ``RunRecord``
outputs.  Deterministic counters (replans, deferred-fault overflows,
total cache lookups) are safe to diff exactly; wall-clock timers and
rates (``*_s``, ``lanes_per_s``) carry the store's timing-key naming so
diffs band them instead of comparing bitwise.

Metric names used by the instrumented call sites:

======================================  ==================================
``runner.cache_hits`` / ``_misses``     eval-cache outcomes (counter)
``runner.eval_s``                       strategy-evaluation wall time
``jax.chunks``                          lane chunks driven (counter)
``jax.compile_s``                       first-chunk (compile+run) seconds
``jax.run_s``                           steady-state chunk seconds
``jax.lanes_per_s``                     lanes/second of the last call
``engine.deferred_overflows``           deferred-fault capacity trips
``fleet.faults`` / ``fleet.repair_waits``  fleet coupling events
``ft.predictions`` / ``ft.faults_injected``  ft-runtime activity
======================================  ==================================
"""

from __future__ import annotations

import time
from contextlib import contextmanager

__all__ = ["MetricsRegistry", "get_registry", "set_registry"]


class MetricsRegistry:
    """Counters / gauges / timers with a mergeable snapshot."""

    def __init__(self) -> None:
        self.counters: dict[str, int] = {}
        self.gauges: dict[str, float] = {}
        self.timers: dict[str, float] = {}

    def count(self, name: str, inc: int = 1) -> None:
        self.counters[name] = self.counters.get(name, 0) + inc

    def gauge(self, name: str, value: float) -> None:
        self.gauges[name] = float(value)

    def add_time(self, name: str, seconds: float) -> None:
        self.timers[name] = self.timers.get(name, 0.0) + float(seconds)

    @contextmanager
    def timer(self, name: str):
        t0 = time.perf_counter()
        try:
            yield
        finally:
            self.add_time(name, time.perf_counter() - t0)

    def snapshot(self) -> dict[str, dict]:
        return {"counters": dict(self.counters),
                "gauges": dict(self.gauges),
                "timers": dict(self.timers)}

    def merge(self, other: "MetricsRegistry") -> None:
        for k, v in other.counters.items():
            self.count(k, v)
        self.gauges.update(other.gauges)
        for k, v in other.timers.items():
            self.add_time(k, v)

    def clear(self) -> None:
        self.counters.clear()
        self.gauges.clear()
        self.timers.clear()

    def flat_timings(self) -> dict[str, float]:
        """Timers + gauges flattened for ``RunRecord.timings`` (every key
        already carries a timing-shaped name, so diffs band them)."""
        out = dict(self.timers)
        out.update(self.gauges)
        return out


_registry = MetricsRegistry()


def get_registry() -> MetricsRegistry:
    """The installed process-local registry (instrumented sites use it)."""
    return _registry


def set_registry(registry: MetricsRegistry) -> MetricsRegistry:
    """Install ``registry`` (e.g. a fresh one per suite item) and return
    the previously installed one."""
    global _registry
    prev = _registry
    _registry = registry
    return prev
