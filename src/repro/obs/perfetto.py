"""Chrome/Perfetto ``trace_event`` JSON timelines of simulated runs.

Converts a :class:`repro.obs.trace.RecordingSink` event stream (or one
stream per fleet job) into the Trace Event Format that
https://ui.perfetto.dev and ``chrome://tracing`` load directly: phases
as complete slices (``ph: "X"``), faults / predictions / decisions as
instants (``ph: "i"``), one process per run and one thread track per
lane or fleet job.

Time base: **1 trace microsecond == 1 simulated second** (``ts`` values
are simulated seconds written verbatim), so durations in the UI read
directly as simulated seconds.
"""

from __future__ import annotations

import json
from typing import Any, Iterable, Sequence

__all__ = ["events_to_trace_events", "fleet_to_perfetto", "write_trace"]

# Slice-shaped kinds: (start-kind, end-kind, slice name).  The end event
# carries the nominal duration, but pairing start -> end keeps stretched
# or interrupted phases honest in the timeline.
_SLICES = (
    ("ckpt_start", "ckpt_end", "ckpt"),
    ("prockpt_start", "prockpt_end", "proactive_ckpt"),
    ("verify_start", "verify_end", "verify"),
    ("down_start", "recover_start", "downtime"),
    ("recover_start", "recover_end", "recovery"),
)
_INSTANTS = {"fault", "rollback", "re_exec", "prediction", "trust",
             "replan", "silent_detect"}


def _num(v: Any) -> Any:
    return float(v) if isinstance(v, (int, float)) else v


def events_to_trace_events(events: Iterable, *, pid: int = 1,
                           tid: int = 1) -> list[dict]:
    """Lower one event stream to a list of ``traceEvents`` dicts."""
    evs = list(events)
    out: list[dict] = []
    for start_kind, end_kind, name in _SLICES:
        open_t: float | None = None
        for e in evs:
            if e.kind == start_kind:
                open_t = e.t
            elif e.kind == end_kind and open_t is not None:
                out.append({"name": name, "ph": "X", "pid": pid,
                            "tid": tid, "ts": open_t,
                            "dur": e.t - open_t, "cat": "phase"})
                open_t = None
        # A phase interrupted by the end of the run (or a fault with no
        # recorded closer) still gets its nominal duration.
        if open_t is not None:
            nominal = next((e.dur for e in evs
                            if e.kind == end_kind and e.dur > 0.0), 0.0)
            out.append({"name": name, "ph": "X", "pid": pid, "tid": tid,
                        "ts": open_t, "dur": nominal, "cat": "phase"})
    for e in evs:
        if e.kind in _INSTANTS:
            out.append({"name": e.kind, "ph": "i", "pid": pid, "tid": tid,
                        "ts": e.t, "s": "t", "cat": "event",
                        "args": {k: _num(v) for k, v in e.args.items()}})
    out.sort(key=lambda d: (d["ts"], d["ph"] != "X"))
    return out


def _meta(pid: int, tid: int | None, name: str) -> dict:
    ev = {"name": "process_name" if tid is None else "thread_name",
          "ph": "M", "pid": pid, "args": {"name": name}}
    if tid is not None:
        ev["tid"] = tid
    return ev


def fleet_to_perfetto(job_streams: Sequence[tuple[str, Iterable]],
                      *, title: str = "fleet") -> dict:
    """Timeline of a fleet run: one thread track per ``(name, events)``.

    Returns the Trace Event Format top-level object (``traceEvents`` +
    metadata); dump it with :func:`write_trace` and load it in
    https://ui.perfetto.dev.
    """
    trace_events: list[dict] = [_meta(1, None, title)]
    for tid, (name, events) in enumerate(job_streams, start=1):
        trace_events.append(_meta(1, tid, name or f"job{tid}"))
        trace_events.extend(events_to_trace_events(events, pid=1, tid=tid))
    return {
        "traceEvents": trace_events,
        "displayTimeUnit": "ms",
        "otherData": {"time_base": "1 trace us == 1 simulated second"},
    }


def write_trace(path: str, trace: dict | Sequence[tuple[str, Iterable]],
                **kwargs) -> str:
    """Write a Perfetto-loadable JSON file; accepts either a prebuilt
    trace object or the ``fleet_to_perfetto`` job-stream argument."""
    if not isinstance(trace, dict):
        trace = fleet_to_perfetto(trace, **kwargs)
    with open(path, "w", encoding="utf-8") as fh:
        json.dump(trace, fh, indent=1)
    return path
