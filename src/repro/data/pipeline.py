"""Synthetic deterministic LM data pipeline.

Production shape without production data: an infinite, seedable, *stateless-
resumable* token stream.  ``batch_at(step)`` is a pure function of
(seed, step), so resuming from a checkpoint only needs the step counter — the
cursor IS the state, which is exactly what the checkpoint manager saves.

The synthetic distribution is not uniform noise: tokens follow a power-law
(Zipf-like) unigram distribution with injected bigram structure so that the
model has learnable signal and the loss visibly decreases during the
end-to-end examples.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import InputShape, ModelConfig
from ..models.model import make_batch

__all__ = ["DataConfig", "SyntheticLM", "DataState"]


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seed: int = 0
    zipf_a: float = 1.2          # unigram power-law exponent
    bigram_shift: int = 17       # next-token bias: x_{t+1} ~ x_t + shift
    bigram_prob: float = 0.65    # probability of following the bigram rule


@dataclasses.dataclass
class DataState:
    """Pipeline cursor (what the checkpoint saves)."""

    step: int = 0


class SyntheticLM:
    """Deterministic synthetic LM stream for (cfg, shape)."""

    def __init__(self, cfg: ModelConfig, shape: InputShape,
                 data_cfg: DataConfig = DataConfig()) -> None:
        self.cfg = cfg
        self.shape = shape
        self.data_cfg = data_cfg
        # Unigram distribution (host-side, computed once).
        v = cfg.vocab_size
        ranks = np.arange(1, v + 1, dtype=np.float64)
        probs = ranks ** (-data_cfg.zipf_a)
        self._probs = jnp.asarray(probs / probs.sum(), jnp.float32)

    def batch_at(self, step: int) -> dict:
        """Batch for a given step — pure function of (seed, step)."""
        cfg, shape = self.cfg, self.shape
        key = jax.random.fold_in(jax.random.PRNGKey(self.data_cfg.seed), step)
        if not cfg.embed_inputs or cfg.mrope_sections is not None:
            # Audio/VLM: reuse the stub batch builder (embeddings + masks),
            # deterministic in (seed, step) via the folded key.
            return make_batch(cfg, shape, key)
        k1, k2 = jax.random.split(key)
        b, s = shape.global_batch, shape.seq_len
        fresh = jax.random.categorical(
            k1, jnp.log(self._probs)[None, None, :], shape=(b, s))
        follow = jax.random.bernoulli(k2, self.data_cfg.bigram_prob, (b, s))
        shift = self.data_cfg.bigram_shift

        # First-order Markov chain: x_t = x_{t-1} + shift with prob
        # bigram_prob, else a fresh Zipf draw — a genuinely learnable
        # next-token signal (scan over time).
        def step(prev, xs):
            f, fr = xs
            tok = jnp.where(f, (prev + shift) % cfg.vocab_size, fr)
            return tok, tok

        _, toks = jax.lax.scan(
            step, fresh[:, 0],
            (follow[:, 1:].T, fresh[:, 1:].T))
        tokens = jnp.concatenate([fresh[:, :1], toks.T], axis=1)
        return {"tokens": tokens.astype(jnp.int32)}

    def __iter__(self):
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1
