"""Data pipelines (synthetic deterministic LM stream)."""

from .pipeline import DataConfig, DataState, SyntheticLM

__all__ = ["DataConfig", "DataState", "SyntheticLM"]
