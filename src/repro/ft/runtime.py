"""Fault-tolerance runtime: virtual clock, fault injector, predictor runtime.

These three pieces replay a :class:`repro.core.traces.EventTrace` against a
*real* training loop (repro.train.loop):

  * :class:`VirtualClock` — the loop's notion of wall-clock.  Training steps,
    checkpoint writes, downtimes and recoveries advance it; fault/prediction
    events are timestamped against it.  Using a virtual clock makes fault-
    dense end-to-end tests run in seconds while keeping every duration
    (C, C_p, D, R, T) in real units.
  * :class:`FaultInjector` — replays the fault events of a trace: queries of
    the form "does a fault strike in [t0, t1)?" drive rollbacks.
  * :class:`PredictorRuntime` — surfaces predictions (true and false)
    ``lead_time`` seconds before their predicted date, mirroring §2.2: only
    predictions with lead time >= C_p are actionable, the trust decision is
    the scheduler's.
"""

from __future__ import annotations

import bisect
import dataclasses

import numpy as np

from ..core.traces import FALSE_PRED, FAULT_PRED, FAULT_UNPRED, EventTrace
from ..obs.metrics import get_registry

__all__ = ["VirtualClock", "FaultInjector", "PredictorRuntime", "Prediction"]


class VirtualClock:
    """Monotone virtual time in seconds."""

    def __init__(self, start: float = 0.0) -> None:
        self.now = float(start)

    def advance(self, dt: float) -> float:
        if dt < 0:
            raise ValueError(f"cannot advance clock by {dt}")
        self.now += dt
        return self.now


@dataclasses.dataclass(frozen=True)
class Prediction:
    """A prediction announced at ``announce_time`` for date ``date``."""

    announce_time: float
    date: float
    is_true: bool  # hidden from the consumer; used for accounting only


class FaultInjector:
    """Replays actual faults (predicted or not) from a trace."""

    def __init__(self, trace: EventTrace) -> None:
        sel = trace.kinds != FALSE_PRED
        self.fault_times = np.asarray(trace.times[sel], np.float64)

    def next_fault_in(self, t0: float, t1: float) -> float | None:
        """Earliest fault time in [t0, t1), or None."""
        i = bisect.bisect_left(self.fault_times, t0)
        if i < len(self.fault_times) and self.fault_times[i] < t1:
            get_registry().count("ft.faults_injected")
            return float(self.fault_times[i])
        return None


class PredictorRuntime:
    """Surfaces predictions with a fixed lead time (paper §2.2).

    Predictions whose lead time is < C_p are unusable; the paper folds them
    into the unpredicted-fault rate.  Here the consumer simply cannot act on
    them (the proactive checkpoint would not fit), producing exactly the
    same behaviour.
    """

    def __init__(self, trace: EventTrace, lead_time: float) -> None:
        sel = trace.kinds != FAULT_UNPRED
        self.pred_dates = np.asarray(trace.times[sel], np.float64)
        self.pred_true = np.asarray(trace.kinds[sel] == FAULT_PRED)
        self.lead_time = float(lead_time)

    def announced_in(self, t0: float, t1: float) -> list[Prediction]:
        """Predictions whose announce time falls in [t0, t1)."""
        a0, a1 = t0 + self.lead_time, t1 + self.lead_time
        i = bisect.bisect_left(self.pred_dates, a0)
        j = bisect.bisect_left(self.pred_dates, a1)
        if j > i:
            get_registry().count("ft.predictions", j - i)
        return [
            Prediction(float(d) - self.lead_time, float(d), bool(tr))
            for d, tr in zip(self.pred_dates[i:j], self.pred_true[i:j])
        ]
