"""Checkpoint scheduler: the paper's optimal policy as a runtime component.

Maps the analytical results of :mod:`repro.core` onto a live training loop:

  * the platform MTBF is derived from the production mesh size
    (mu = mu_ind / n_devices, paper Prop. 2);
  * C and C_p come from the checkpoint manager's cost model (per-shard
    bytes / bandwidth) or from measured save times;
  * the period T* is :func:`optimal_period_with_prediction` (Eq. 16/17) when
    a predictor is configured, :func:`t_rfo` (Eq. 13) otherwise;
  * predictions are trusted iff their date falls >= beta_lim = C_p / p after
    the last state save (Theorem 1).

The scheduler is deliberately stateless w.r.t. the training state — it just
answers "checkpoint now?", "trust this prediction?" from clock readings, so
the trainer, the serving engine, or an external orchestrator can all drive it.
"""

from __future__ import annotations

import dataclasses
import math

from ..configs.base import PlatformConfig
from ..core.prediction import (PredictedPlatform, Predictor, beta_lim,
                               optimal_period_with_prediction)
from ..core.waste import Platform, t_rfo, waste

__all__ = ["ScheduleDecision", "CheckpointScheduler"]


@dataclasses.dataclass(frozen=True)
class ScheduleDecision:
    period: float          # chosen checkpointing period T*
    use_predictions: bool  # whether the WASTE2 branch won
    beta_lim: float        # trust threshold (C_p/p; availability: beta_A)
    expected_waste: float  # analytic objective value at T* (waste or U)


class CheckpointScheduler:
    """Plans checkpoint cadence and trust decisions for a live job.

    ``objective`` selects the analytic model the plan minimizes:
    ``"waste"`` (default) is the paper's makespan overhead,
    ``"availability"`` the weighted outage fraction of
    :mod:`repro.fleet.availability`, using the platform's
    ``ckpt_outage`` / ``prockpt_outage`` / ``replay_outage`` fractions
    (unit weights plan identically to ``"waste"``).
    """

    def __init__(self, platform: PlatformConfig, n_devices: int, *,
                 c: float | None = None, cp: float | None = None,
                 use_predictor: bool = True,
                 objective: str = "waste") -> None:
        if objective not in ("waste", "availability"):
            raise ValueError(f"objective must be 'waste' or 'availability', "
                             f"got {objective!r}")
        self.cfg = platform
        self.n_devices = n_devices
        self.objective = objective
        self.c = float(c if c is not None else platform.c)
        self.cp = float(cp if cp is not None else platform.cp)
        if self.c <= 0 or self.cp <= 0:
            raise ValueError(
                "checkpoint costs must be positive; pass measured/modeled "
                f"costs (got C={self.c}, C_p={self.cp})")
        self.mu = platform.mu_ind / n_devices
        self.plat = Platform(mu=self.mu, c=self.c, d=platform.d, r=platform.r)
        self.use_predictor = use_predictor and platform.recall > 0
        if objective == "availability":
            from ..fleet.availability import (OutageWeights, beta_avail,
                                              optimal_period_availability,
                                              t_avail_nopred,
                                              unavailability_nopred)
            w = OutageWeights(ckpt=platform.ckpt_outage,
                              prockpt=platform.prockpt_outage,
                              replay=platform.replay_outage)
            if self.use_predictor:
                pred = Predictor(recall=platform.recall,
                                 precision=platform.precision)
                self.pp = PredictedPlatform(self.plat, pred, cp=self.cp)
                t, u, use = optimal_period_availability(self.pp, w)
                self.decision = ScheduleDecision(
                    t, use, beta_avail(self.pp, w), u)
            else:
                t = t_avail_nopred(self.plat, w)
                self.decision = ScheduleDecision(
                    t, False, math.inf, unavailability_nopred(t, self.plat, w))
        elif self.use_predictor:
            pred = Predictor(recall=platform.recall,
                             precision=platform.precision)
            self.pp = PredictedPlatform(self.plat, pred, cp=self.cp)
            t, w, use = optimal_period_with_prediction(self.pp)
            self.decision = ScheduleDecision(t, use, beta_lim(self.pp), w)
        else:
            t = t_rfo(self.plat)
            self.decision = ScheduleDecision(t, False, math.inf,
                                             waste(t, self.plat))
        self._last_save_end = 0.0

    # -- runtime queries -------------------------------------------------------

    @property
    def period(self) -> float:
        return self.decision.period

    def notify_save_completed(self, now: float) -> None:
        """Any completed state save (periodic, proactive, or recovery)."""
        self._last_save_end = now

    def next_checkpoint_start(self) -> float:
        """Wall-clock time at which the next periodic checkpoint should
        start: work for T - C after the last save."""
        return self._last_save_end + self.decision.period - self.c

    def due(self, now: float) -> bool:
        return now >= self.next_checkpoint_start()

    def trust(self, prediction_date: float) -> bool:
        """Theorem 1: act iff the predicted date is >= beta_lim after the
        last save (and predictions are worth using at all)."""
        if not self.use_predictor or not self.decision.use_predictions:
            return False
        offset = prediction_date - self._last_save_end
        return offset >= self.decision.beta_lim

    def steps_per_checkpoint(self, step_time: float) -> int:
        """Translate the period into a steps-per-checkpoint cadence."""
        return max(1, int((self.decision.period - self.c) / step_time))
