"""Online estimation of platform and predictor parameters.

The paper assumes mu, r, p are known.  In production none of them are: the
platform MTBF drifts (hardware ages, fleets change), and a predictor's
recall/precision must be measured against observed faults.  This module
keeps running estimates from the event stream and re-plans the schedule
when they move — the missing piece that makes the paper's policy
deployable (and the mechanism behind the hazard-aware dynamic periods of
benchmarks/beyond.py, measured instead of assumed).

Estimators:
  * MTBF — exponentially-weighted mean of fault inter-arrival times
    (window ~ the last `halflife` faults), so burn-in decay shows up as a
    falling mu-hat instead of poisoning the estimate forever;
  * recall — EW fraction of faults that had been predicted;
  * precision — EW fraction of predictions that materialized (a prediction
    "materializes" if a fault strikes within `match_window` of its date).

`replan` hysteresis: the scheduler is rebuilt only when the optimal period
under the new estimates moves by more than `replan_threshold` (re-planning
every event would thrash the checkpoint cadence for no waste benefit —
the waste curve is flat near its minimum, WASTE''(T*) ~ 1/mu T^3).
"""

from __future__ import annotations

import dataclasses
import math

from ..configs.base import PlatformConfig
from .scheduler import CheckpointScheduler

__all__ = ["OnlineEstimator", "AdaptiveScheduler"]


class _EWMean:
    """Exponentially-weighted mean with a half-life in observations."""

    def __init__(self, halflife: float, init: float | None = None) -> None:
        self.alpha = 1.0 - 0.5 ** (1.0 / halflife)
        self.value = init
        self.n = 0

    def update(self, x: float) -> float:
        self.n += 1
        if self.value is None:
            self.value = x
        else:
            self.value += self.alpha * (x - self.value)
        return self.value


@dataclasses.dataclass
class EstimatorState:
    mu: float | None
    recall: float | None
    precision: float | None
    n_faults: int
    n_predictions: int


class OnlineEstimator:
    """Running (mu, recall, precision) estimates from observed events."""

    def __init__(self, *, halflife: float = 20.0,
                 match_window: float = 60.0,
                 prior: PlatformConfig | None = None) -> None:
        self.match_window = match_window
        self._mu = _EWMean(halflife, prior.mu_ind if prior else None)
        self._recall = _EWMean(halflife,
                               prior.recall if prior else None)
        self._precision = _EWMean(halflife,
                                  prior.precision if prior else None)
        self._last_fault: float | None = None
        self._open_predictions: list[float] = []  # predicted dates
        self.n_faults = 0
        self.n_predictions = 0

    # -- event feed -----------------------------------------------------------

    def observe_prediction(self, date: float) -> None:
        """A prediction announced for ``date`` (dates must be fed in order)."""
        self.n_predictions += 1
        self._open_predictions.append(date)

    def observe_fault(self, t: float, was_predicted: bool | None = None
                      ) -> None:
        """An actual fault at time ``t``."""
        self.n_faults += 1
        if self._last_fault is not None:
            self._mu.update(t - self._last_fault)
        self._last_fault = t

        # Match against open predictions for the precision estimate.
        matched = False
        still_open = []
        for d in self._open_predictions:
            if abs(d - t) <= self.match_window and not matched:
                matched = True
                self._precision.update(1.0)
            elif d < t - self.match_window:
                self._precision.update(0.0)  # expired false prediction
            else:
                still_open.append(d)
        self._open_predictions = still_open
        hit = matched if was_predicted is None else was_predicted
        self._recall.update(1.0 if hit else 0.0)

    def expire_predictions(self, now: float) -> None:
        """Flush predictions whose window passed without a fault."""
        still = []
        for d in self._open_predictions:
            if d < now - self.match_window:
                self._precision.update(0.0)
            else:
                still.append(d)
        self._open_predictions = still

    # -- state ------------------------------------------------------------------

    @property
    def state(self) -> EstimatorState:
        return EstimatorState(self._mu.value, self._recall.value,
                              self._precision.value,
                              self.n_faults, self.n_predictions)


class AdaptiveScheduler:
    """CheckpointScheduler that re-plans from online estimates."""

    def __init__(self, prior: PlatformConfig, n_devices: int, *,
                 c: float, cp: float, halflife: float = 20.0,
                 replan_threshold: float = 0.15) -> None:
        self.prior = prior
        self.n_devices = n_devices
        self.c, self.cp = c, cp
        self.threshold = replan_threshold
        self.estimator = OnlineEstimator(halflife=halflife, prior=prior)
        self.scheduler = CheckpointScheduler(prior, n_devices, c=c, cp=cp)
        self.n_replans = 0

    def _current_config(self) -> PlatformConfig:
        st = self.estimator.state
        mu_platform = st.mu if st.mu is not None \
            else self.prior.mu_ind / self.n_devices
        return dataclasses.replace(
            self.prior,
            # Estimated mu is already platform-level; scheduler divides by
            # n_devices, so scale back up.
            mu_ind=mu_platform * self.n_devices,
            recall=st.recall if st.recall is not None else self.prior.recall,
            precision=(st.precision if st.precision is not None
                       else self.prior.precision),
        )

    def maybe_replan(self) -> bool:
        """Rebuild the schedule if the optimal period moved enough."""
        cfg = self._current_config()
        if cfg.recall <= 0 or not (0 < cfg.precision <= 1):
            return False
        new = CheckpointScheduler(cfg, self.n_devices, c=self.c, cp=self.cp)
        old_t = self.scheduler.period
        if abs(new.period - old_t) / old_t > self.threshold:
            new._last_save_end = self.scheduler._last_save_end
            self.scheduler = new
            self.n_replans += 1
            return True
        return False
