"""Fault-tolerance runtime: clock, injector, predictor, scheduler."""

from .estimator import AdaptiveScheduler, OnlineEstimator
from .runtime import FaultInjector, Prediction, PredictorRuntime, VirtualClock
from .scheduler import CheckpointScheduler, ScheduleDecision

__all__ = ["FaultInjector", "Prediction", "PredictorRuntime", "VirtualClock",
           "CheckpointScheduler", "ScheduleDecision", "OnlineEstimator",
           "AdaptiveScheduler"]
