"""Analytic availability model: the availability-optimal checkpoint interval.

Production serving fleets optimize *availability* — the fraction of wall
time the service answers — rather than the paper's *waste* (the fraction of
makespan that is not useful work).  The two objectives price the same three
ingredients differently (arXiv:2410.18124):

  * a periodic checkpoint of length C need not take the service down for
    all of C: with concurrent / fuzzy snapshotting only a stop-the-world
    fraction ``phi_c = OutageWeights.ckpt`` of it is an outage;
  * likewise a proactive checkpoint C_p is an outage for
    ``phi_p = OutageWeights.prockpt`` of its duration;
  * re-executed (replayed) work after a rollback is an outage for a
    fraction ``rho = OutageWeights.replay`` — a training job replays at
    full outage (rho = 1), a serving replica that still answers stale
    reads while catching up replays cheaper (rho < 1).

First-order unavailability per unit time, mirroring the structure of
:func:`repro.core.waste.waste` (and dropping the second-order
``wff * wfault`` cross products the waste model keeps):

  U1(T) = phi_c C / T + (D + R + rho T / 2) / mu                (no predictor)

which is minimized at

  T_A* = sqrt(2 (mu - (D + R)) phi_c C / rho)                   (Eq. RFO-A)

— the waste-optimal T_RFO scaled by sqrt(phi_c / rho).  **The two optima
provably differ whenever phi_c != rho**: a service whose checkpoints are
mostly concurrent (phi_c < 1) but whose replay is a full outage (rho = 1)
should checkpoint *more often* than the waste-optimal cadence, by the
factor sqrt(phi_c / rho).

With the paper's predictor (recall r, precision p, proactive cost C_p) the
prediction term extends U the same way Eq. 15's WASTE2 extends Eq. 12: act
on predictions whose offset in the period exceeds the availability trust
breakpoint

  beta_A = phi_p C_p / (rho p)                                  (Thm. 1-A)

(act iff the expected replay outage saved, rho * offset * p, exceeds the
proactive outage phi_p C_p).  Acted predictions arrive at rate r/(p mu)
and remove their fault's replay; predictions below the breakpoint keep it:

  U2(T) = phi_c C / T
        + (D + R + rho (1-r) T / 2 + phi_p (r/p) C_p (1 - beta_A/T)
           + rho r beta_A^2 / (2 T)) / mu

With unit weights (phi_c = phi_p = rho = 1) beta_A reduces to the paper's
beta_lim = C_p/p and U2 to WASTE2 minus its O(C/mu) cross terms, so the
availability-optimal plan degenerates to the waste-optimal one — the
regression tests pin both the degeneracy and the divergence.
"""

from __future__ import annotations

import dataclasses
import math

from repro.core.prediction import PredictedPlatform
from repro.core.waste import ALPHA_CAP, Platform, clamp_period

__all__ = [
    "OutageWeights",
    "beta_avail",
    "unavailability_nopred",
    "unavailability_pred",
    "unavailability",
    "t_avail_nopred",
    "t_avail_pred",
    "optimal_period_availability",
    "measured_unavailability",
]


@dataclasses.dataclass(frozen=True)
class OutageWeights:
    """Outage fractions pricing each waste ingredient as service downtime.

    All three weights live in (0, 1]; unit weights make availability
    1 - waste at first order (the degenerate check the tests pin).
    """

    ckpt: float = 1.0      # phi_c: stop-the-world fraction of a periodic C
    prockpt: float = 1.0   # phi_p: ... of a proactive C_p
    replay: float = 1.0    # rho:   outage fraction of re-executed work

    def __post_init__(self) -> None:
        for name in ("ckpt", "prockpt", "replay"):
            v = getattr(self, name)
            if not (0.0 < v <= 1.0):
                raise ValueError(f"OutageWeights.{name} must be in (0, 1], "
                                 f"got {v}")

    def to_dict(self) -> dict:
        return {"ckpt": self.ckpt, "prockpt": self.prockpt,
                "replay": self.replay}

    @classmethod
    def from_dict(cls, d) -> "OutageWeights":
        return cls(**dict(d))


def beta_avail(pp: PredictedPlatform, w: OutageWeights) -> float:
    """Availability trust breakpoint beta_A = phi_p C_p / (rho p).

    Act on a prediction iff its offset in the period >= beta_A: the
    expected replay outage saved (rho * offset * p) then exceeds the
    proactive outage spent (phi_p C_p).  Unit weights give the paper's
    beta_lim = C_p / p.
    """
    return w.prockpt * pp.cp / (w.replay * pp.predictor.precision)


# ---------------------------------------------------------------------------
# Unavailability at period T
# ---------------------------------------------------------------------------

def unavailability_nopred(t: float, plat: Platform,
                          w: OutageWeights) -> float:
    """U1(T): first-order unavailability without acting on predictions."""
    if t < plat.c:
        raise ValueError(f"T={t} < C={plat.c}")
    return w.ckpt * plat.c / t \
        + (plat.d + plat.r + w.replay * t / 2.0) / plat.mu


def unavailability_pred(t: float, pp: PredictedPlatform,
                        w: OutageWeights) -> float:
    """U2(T): unavailability of the refined policy acting past beta_A."""
    plat, pred = pp.platform, pp.predictor
    if t < plat.c:
        raise ValueError(f"T={t} < C={plat.c}")
    r, p = pred.recall, pred.precision
    beta = beta_avail(pp, w)
    act = max(0.0, 1.0 - beta / t)   # fraction of predictions past beta_A
    return w.ckpt * plat.c / t + (
        plat.d + plat.r
        + w.replay * (1.0 - r) * t / 2.0
        + w.prockpt * (r / p) * pp.cp * act
        + w.replay * r * beta * beta / (2.0 * t)
    ) / plat.mu


def unavailability(t: float, pp: PredictedPlatform, w: OutageWeights) -> float:
    """Two-branch unavailability (the availability analogue of Eq. 15)."""
    if t <= beta_avail(pp, w):
        return unavailability_nopred(t, pp.platform, w)
    return unavailability_pred(t, pp, w)


# ---------------------------------------------------------------------------
# Availability-optimal periods
# ---------------------------------------------------------------------------

def t_avail_nopred(plat: Platform, w: OutageWeights) -> float:
    """Minimizer of U1: T_A* = sqrt(2 (mu - (D+R)) phi_c C / rho).

    The waste-optimal T_RFO scaled by sqrt(phi_c / rho); clamped to the
    feasible [C, alpha mu] range like :func:`repro.core.waste.clamp_period`.
    """
    slack = max(plat.mu - (plat.d + plat.r), plat.c)
    t = math.sqrt(2.0 * slack * w.ckpt * plat.c / w.replay)
    return clamp_period(t, plat)


def t_avail_pred(pp: PredictedPlatform, w: OutageWeights) -> float:
    """Minimizer of U2 on [max(C, beta_A), +inf).

    dU2/dT = 0 gives T = sqrt(v / x) with
      v = phi_c C + r (rho beta_A^2/2 - phi_p C_p beta_A / p) / mu
      x = rho (1 - r) / (2 mu)
    (v's correction term collapses to -phi_p^2 C_p^2 r / (2 rho p^2 mu)).
    """
    plat, pred = pp.platform, pp.predictor
    r, p = pred.recall, pred.precision
    beta = beta_avail(pp, w)
    lo = max(plat.c, beta)
    v = w.ckpt * plat.c + r * (w.replay * beta * beta / 2.0
                               - w.prockpt * pp.cp * beta / p) / plat.mu
    x = w.replay * (1.0 - r) / (2.0 * plat.mu)
    if x <= 0.0 or v <= 0.0:
        # r == 1 (no unpredicted replay) or degenerate v: periodic
        # checkpoints are pure overhead — fall back to the rigor cap.
        return max(lo, ALPHA_CAP * plat.mu)
    return min(max(lo, math.sqrt(v / x)), ALPHA_CAP * plat.mu)


def optimal_period_availability(
        pp: PredictedPlatform, w: OutageWeights) -> tuple[float, float, bool]:
    """(T_A*, U(T_A*), use_predictions) — availability analogue of
    :func:`repro.core.prediction.optimal_period_with_prediction`."""
    tp = t_avail_pred(pp, w)
    u2 = unavailability_pred(tp, pp, w)
    if beta_avail(pp, w) < pp.platform.c:
        return tp, u2, True
    tn = t_avail_nopred(pp.platform, w)
    u1 = unavailability_nopred(tn, pp.platform, w)
    if u1 <= u2:
        return tn, u1, False
    return tp, u2, True


# ---------------------------------------------------------------------------
# Measured availability (simulator-side accounting)
# ---------------------------------------------------------------------------

def measured_unavailability(*, makespan: float, time_ckpt: float,
                            time_prockpt: float, time_down: float,
                            time_lost: float, w: OutageWeights,
                            time_contention_ckpt: float = 0.0,
                            time_contention_prockpt: float = 0.0,
                            time_repair_wait: float = 0.0) -> float:
    """Weighted outage fraction of a simulated run.

    The simulator's makespan decomposes exactly as base + ckpt + prockpt +
    lost + down (accrual-exact accounting, see ``_Machine.fault``); the
    fleet engine adds contention stretch and repair-queue waiting on top.
    With unit weights and no contention this equals ``SimResult.waste``.
    """
    if makespan <= 0.0:
        return 0.0
    outage = (w.ckpt * (time_ckpt + time_contention_ckpt)
              + w.prockpt * (time_prockpt + time_contention_prockpt)
              + (time_down + time_repair_wait)
              + w.replay * time_lost)
    return outage / makespan
