"""Fleet planning: per-job (period, trust) under a shared objective.

Three planning layers compose here:

  * **objective**: a job without an explicit strategy gets the analytic
    optimum for the fleet's objective — the paper's waste-optimal plan
    (:func:`repro.core.prediction.optimal_period_with_prediction`, with
    Theorem 1's beta_lim trust threshold) or the availability-optimal plan
    (:func:`repro.fleet.availability.optimal_period_availability`, with the
    beta_A threshold), honouring each job's own (mu, C, C_p, r, p);
  * **shared predictor, per-job trust**: every job consumes the same
    (r, p)-characterized prediction stream, but each trusts it past its
    *own* threshold — a cheap-C_p job acts on predictions a costly-C_p job
    ignores;
  * **bandwidth-aware staggering**: jobs' first periods are offset by
    ``rank/n * T_job`` so their periodic save cadences start spread out
    instead of synchronized, reducing storage contention (the offset is a
    one-time callable-period shim; steady-state periods are unchanged).
"""

from __future__ import annotations

import dataclasses

from repro.core.prediction import (beta_lim, optimal_period_with_prediction,
                                   waste_with_prediction)
from repro.core.simulator import NeverTrust, ThresholdTrust, TrustPolicy
from repro.core.waste import waste
from repro.fleet.availability import (OutageWeights, beta_avail,
                                      optimal_period_availability,
                                      unavailability, unavailability_nopred)
from repro.fleet.spec import FleetJobSpec, FleetSpec

__all__ = ["JobPlan", "plan_job", "plan_fleet", "staggered_period",
           "expected_objective"]


@dataclasses.dataclass(frozen=True)
class JobPlan:
    """A planned job, ready for :class:`repro.fleet.sim.FleetJobInput`."""

    period: float                # steady-state period T
    trust: TrustPolicy
    use_predictions: bool
    expected: float              # analytic objective value at T
    inexact_window: float = 0.0
    stagger_offset: float = 0.0  # added to the first period only

    @property
    def period_arg(self) -> object:
        """What the simulator gets: a float, or the staggered callable."""
        if self.stagger_offset <= 0.0:
            return self.period
        return staggered_period(self.period, self.stagger_offset)


def staggered_period(period: float, offset: float):
    """A callable period whose first evaluation (t == 0) is offset.

    ``_Machine`` evaluates the period function at every period start; only
    the initial one happens at t == 0, so the job's first checkpoint lands
    ``offset`` seconds later and the steady-state cadence is untouched.
    """
    def fn(t: float) -> float:
        return period + offset if t <= 0.0 else period
    return fn


def plan_job(job: FleetJobSpec, objective: str = "waste",
             outage: OutageWeights | None = None) -> JobPlan:
    """The analytic plan for one job under the fleet objective."""
    scenario = job.scenario
    if job.strategy is not None:
        strat = job.strategy.build(scenario)
        if strat.window_mode != "instant":
            raise ValueError(
                f"fleet jobs do not support window_mode="
                f"{strat.window_mode!r} (single-job engine feature)")
        if strat.adaptive is not None:
            raise ValueError("fleet jobs do not support adaptive "
                             "re-planning (single-job engine feature)")
        if callable(strat.period):
            raise ValueError("fleet jobs need a constant planned period")
        use = not isinstance(strat.trust, NeverTrust)
        t = float(strat.period)
        w = (waste_with_prediction(t, scenario.pp) if use
             else waste(t, scenario.platform))
        return JobPlan(period=t, trust=strat.trust, use_predictions=use,
                       expected=w, inexact_window=strat.inexact_window)

    if objective == "availability":
        w = outage or OutageWeights()
        t, u, use = optimal_period_availability(scenario.pp, w)
        trust: TrustPolicy = (ThresholdTrust(beta_avail(scenario.pp, w))
                              if use else NeverTrust())
        return JobPlan(period=t, trust=trust, use_predictions=use,
                       expected=u, inexact_window=scenario.window)

    t, w_star, use = optimal_period_with_prediction(scenario.pp)
    trust = ThresholdTrust(beta_lim(scenario.pp)) if use else NeverTrust()
    return JobPlan(period=t, trust=trust, use_predictions=use,
                   expected=w_star, inexact_window=scenario.window)


def plan_fleet(spec: FleetSpec) -> list[JobPlan]:
    """Plan every job; apply first-period staggering when enabled."""
    plans = [plan_job(j, spec.objective, spec.outage) for j in spec.jobs]
    if spec.stagger and len(plans) > 1:
        n = len(plans)
        plans = [dataclasses.replace(p, stagger_offset=(i / n) * p.period)
                 for i, p in enumerate(plans)]
    return plans


def expected_objective(job: FleetJobSpec, plan: JobPlan, objective: str,
                       outage: OutageWeights) -> float:
    """The analytic objective value of a plan (for simulator comparison)."""
    if objective == "availability":
        if plan.use_predictions:
            return unavailability(plan.period, job.scenario.pp, outage)
        return unavailability_nopred(plan.period, job.scenario.platform,
                                     outage)
    if plan.use_predictions:
        return waste_with_prediction(plan.period, job.scenario.pp)
    return waste(plan.period, job.scenario.platform)
