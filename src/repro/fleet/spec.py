"""Declarative fleet specifications: heterogeneous jobs under shared limits.

A :class:`FleetSpec` is N jobs — each a full single-job
:class:`~repro.experiments.spec.ScenarioSpec` (so every knob of the paper
model is available per job) plus an optional explicit
:class:`~repro.experiments.spec.StrategySpec` — sharing:

  * one **objective** for the jobs planned implicitly: ``"waste"`` (the
    paper's makespan overhead) or ``"availability"`` (the weighted outage
    fraction of :mod:`repro.fleet.availability` under ``outage`` weights);
  * **checkpoint-storage bandwidth**: ``storage_streams`` concurrent
    full-rate savers (None = uncontended);
  * **spare repair capacity**: ``repair_slots`` concurrent repairs
    (None = unbounded);
  * optionally **staggered** first checkpoints to desynchronize the
    periodic save cadences.

:func:`job_from_model` sizes a job from the ``repro.configs`` model zoo:
C comes from the architecture's analytic parameter count through the
checkpoint manager's bytes/bandwidth cost model
(:func:`repro.ckpt.manager.modeled_costs_from_bytes`), C_p from the
measured-or-prior proactive delta ratio, and mu from the per-chip MTBF and
the mesh size (mu = mu_ind / n_devices, paper Prop. 2).

Specs round-trip through ``to_dict`` / ``from_dict`` like every spec in
:mod:`repro.experiments.spec`.
"""

from __future__ import annotations

import dataclasses
import json
from typing import Any, Mapping

from repro.experiments.spec import (SECONDS_PER_DAY, ScenarioSpec,
                                    StrategySpec, _jsonable)
from repro.fleet.availability import OutageWeights

__all__ = [
    "STATE_BYTES_PER_PARAM",
    "FleetJobSpec",
    "FleetSpec",
    "job_from_model",
]

# Mixed-precision training state: bf16 params + fp32 Adam m and v moments.
STATE_BYTES_PER_PARAM = 10.0

_OBJECTIVES = ("waste", "availability")


@dataclasses.dataclass(frozen=True)
class FleetJobSpec:
    """One tenant: a single-job scenario + how it plans + its SLO.

    ``strategy`` None means the fleet plans the job from the shared
    objective (:func:`repro.fleet.plan.plan_job`); an explicit
    :class:`StrategySpec` reuses any registered single-job strategy.
    ``slo`` is the tenant's availability target in (0, 1): the per-tenant
    metric reports the fraction of runs meeting it.
    """

    scenario: ScenarioSpec
    strategy: StrategySpec | None = None
    name: str = ""
    slo: float | None = None

    def __post_init__(self) -> None:
        if not isinstance(self.scenario, ScenarioSpec):
            object.__setattr__(self, "scenario",
                               ScenarioSpec.from_dict(self.scenario))
        if self.strategy is not None \
                and not isinstance(self.strategy, StrategySpec):
            object.__setattr__(self, "strategy",
                               StrategySpec.from_dict(self.strategy))
        if self.slo is not None and not (0.0 < self.slo < 1.0):
            raise ValueError(f"slo must be in (0, 1), got {self.slo}")

    def to_dict(self) -> dict:
        return {"scenario": self.scenario.to_dict(),
                "strategy": (self.strategy.to_dict()
                             if self.strategy is not None else None),
                "name": self.name,
                "slo": self.slo}

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "FleetJobSpec":
        return cls(scenario=ScenarioSpec.from_dict(d["scenario"]),
                   strategy=(StrategySpec.from_dict(d["strategy"])
                             if d.get("strategy") else None),
                   name=d.get("name", ""),
                   slo=d.get("slo"))


@dataclasses.dataclass(frozen=True)
class FleetSpec:
    """N jobs + the shared objective, storage and repair limits."""

    jobs: tuple = ()
    objective: str = "waste"
    outage: OutageWeights = dataclasses.field(default_factory=OutageWeights)
    storage_streams: int | None = None
    repair_slots: int | None = None
    stagger: bool = False
    n_traces: int | None = None   # None: min over the jobs' scenarios
    name: str = "fleet"

    def __post_init__(self) -> None:
        jobs = tuple(j if isinstance(j, FleetJobSpec)
                     else FleetJobSpec.from_dict(j) for j in self.jobs)
        object.__setattr__(self, "jobs", jobs)
        if not isinstance(self.outage, OutageWeights):
            object.__setattr__(self, "outage",
                               OutageWeights.from_dict(self.outage))
        if self.objective not in _OBJECTIVES:
            raise ValueError(f"objective must be one of {_OBJECTIVES}, "
                             f"got {self.objective!r}")

    @property
    def n_runs(self) -> int:
        """Fleet replications: bounded by every job's trace bank."""
        if not self.jobs:
            return 0
        n = min(j.scenario.n_traces for j in self.jobs)
        return n if self.n_traces is None else min(n, self.n_traces)

    def job_name(self, idx: int) -> str:
        return self.jobs[idx].name or f"job{idx}"

    def to_dict(self) -> dict:
        return {"jobs": [j.to_dict() for j in self.jobs],
                "objective": self.objective,
                "outage": self.outage.to_dict(),
                "storage_streams": self.storage_streams,
                "repair_slots": self.repair_slots,
                "stagger": self.stagger,
                "n_traces": self.n_traces,
                "name": self.name}

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "FleetSpec":
        return cls(jobs=tuple(FleetJobSpec.from_dict(j)
                              for j in d.get("jobs", ())),
                   objective=d.get("objective", "waste"),
                   outage=OutageWeights.from_dict(d.get("outage", {})),
                   storage_streams=d.get("storage_streams"),
                   repair_slots=d.get("repair_slots"),
                   stagger=d.get("stagger", False),
                   n_traces=d.get("n_traces"),
                   name=d.get("name", "fleet"))

    def key(self) -> str:
        """Canonical JSON string (cache / golden-pin key)."""
        return json.dumps(_jsonable(self.to_dict()), sort_keys=True)


def job_from_model(arch: str, *, n_devices: int,
                   mu_ind: float | None = None,
                   d: float = 60.0, r: float | None = None,
                   ckpt_bandwidth: float = 2e9,
                   delta_ratio: float | None = None,
                   recall: float = 0.85, precision: float = 0.82,
                   time_base_days: float = 30.0,
                   n_traces: int = 5, seed: int = 0,
                   start_days: float = 365.0,
                   name: str | None = None,
                   slo: float | None = None,
                   strategy: StrategySpec | None = None) -> FleetJobSpec:
    """Size a fleet job from the ``repro.configs`` model zoo.

    The checkpoint cost C is the architecture's analytic state size
    (``param_count() * STATE_BYTES_PER_PARAM`` bytes: bf16 params + fp32
    Adam moments) through the per-shard bytes/bandwidth model of
    :func:`repro.ckpt.manager.modeled_costs_from_bytes`; C_p applies
    ``delta_ratio`` (default: the manager's measured-delta prior).
    Recovery R defaults to C (read back the same bytes).
    """
    from repro.ckpt.manager import (DELTA_RATIO_PRIOR,
                                    modeled_costs_from_bytes)
    from repro.configs import get as get_model
    from repro.experiments.spec import MU_IND_SYNTH

    cfg = get_model(arch)
    nbytes = cfg.param_count() * STATE_BYTES_PER_PARAM
    ratio = DELTA_RATIO_PRIOR if delta_ratio is None else delta_ratio
    c, cp = modeled_costs_from_bytes(nbytes, bandwidth=ckpt_bandwidth,
                                     n_shards=n_devices, delta_ratio=ratio)
    scenario = ScenarioSpec(
        n=n_devices,
        recall=recall, precision=precision,
        c=c, cp_ratio=cp / c,
        d=d, r=(c if r is None else r),
        mu_ind=MU_IND_SYNTH if mu_ind is None else mu_ind,
        # ScenarioSpec divides the total by n: undo it for a fixed per-job
        # duration regardless of mesh size.
        time_base_years_total=time_base_days / 365.0 * n_devices,
        start=start_days * SECONDS_PER_DAY,
        n_traces=n_traces, seed=seed)
    return FleetJobSpec(scenario=scenario, strategy=strategy,
                        name=name if name is not None else arch, slo=slo)
