"""Fleet evaluation: simulate a :class:`FleetSpec`, report per-tenant SLOs.

``fleet_sweep`` path through :mod:`repro.experiments`: plan every job
(:func:`repro.fleet.plan.plan_fleet`), replicate the fleet over ``n_runs``
independent trace draws (per-job trace ``i`` and simulation RNG seeded by
the *same* ``seed + 1009*i`` / ``seed + 7919*i`` recipe as the single-job
runner — the bit-for-bit degeneracy contract), and reduce to one
:class:`~repro.experiments.runner.ResultTable` row per job with:

  * ``waste`` / ``unavailability`` — measured, averaged over runs
    (unavailability weighs checkpoint / proactive / replay time by the
    fleet's :class:`~repro.fleet.availability.OutageWeights` and adds
    contention stretch + repair-queue waiting in full);
  * ``expected_unavailability`` (or expected waste) — the analytic model
    at the planned period, for model-vs-simulator tracking;
  * ``slo_met`` — fraction of runs with availability >= the tenant's SLO;
  * contention / repair-wait seconds, fault / prediction counters.
"""

from __future__ import annotations

import numpy as np

from repro.experiments.runner import ResultTable
from repro.experiments.spec import SECONDS_PER_DAY
from repro.fleet.availability import measured_unavailability
from repro.fleet.plan import JobPlan, expected_objective, plan_fleet
from repro.fleet.sim import FleetJobInput, FleetJobResult, simulate_fleet
from repro.fleet.spec import FleetSpec

__all__ = ["evaluate_fleet", "fleet_run_results"]


def fleet_run_results(spec: FleetSpec,
                      plans: list[JobPlan] | None = None,
                      ) -> list[list[FleetJobResult]]:
    """Raw per-run, per-job results (run-major: ``out[run][job]``)."""
    plans = plan_fleet(spec) if plans is None else plans
    out: list[list[FleetJobResult]] = []
    for i in range(spec.n_runs):
        inputs = []
        for job, plan in zip(spec.jobs, plans):
            sc = job.scenario
            inputs.append(FleetJobInput(
                trace=sc.make_trace(i),
                platform=sc.platform,
                time_base=sc.time_base,
                period=plan.period_arg,
                cp=sc.cp,
                trust=plan.trust,
                inexact_window=plan.inexact_window,
                rng=np.random.default_rng(sc.seed + 7919 * i),
                name=job.name))
        fleet = simulate_fleet(inputs,
                               storage_streams=spec.storage_streams,
                               repair_slots=spec.repair_slots)
        out.append(fleet.jobs)
    return out


def evaluate_fleet(spec: FleetSpec) -> ResultTable:
    """Simulate the fleet; one :class:`ResultTable` row per job."""
    plans = plan_fleet(spec)
    runs = fleet_run_results(spec, plans)
    rows = []
    for j, (job, plan) in enumerate(zip(spec.jobs, plans)):
        per_run = [run[j] for run in runs]
        unavail = [
            measured_unavailability(
                makespan=r.sim.makespan,
                time_ckpt=r.sim.time_ckpt,
                time_prockpt=r.sim.time_prockpt,
                time_down=r.sim.time_down,
                time_lost=r.sim.time_lost,
                w=spec.outage,
                time_contention_ckpt=r.time_contention_ckpt,
                time_contention_prockpt=r.time_contention_prockpt,
                time_repair_wait=r.time_repair_wait)
            for r in per_run
        ]
        availability = [1.0 - u for u in unavail]
        slo_met = (None if job.slo is None else
                   float(np.mean([a >= job.slo for a in availability])))
        rows.append({
            "fleet": spec.name,
            "job": spec.job_name(j),
            "objective": spec.objective,
            "period": plan.period,
            "use_predictions": plan.use_predictions,
            "stagger_offset": plan.stagger_offset,
            "makespan_days": float(np.mean(
                [r.sim.makespan for r in per_run])) / SECONDS_PER_DAY,
            "waste": float(np.mean([r.sim.waste for r in per_run])),
            "unavailability": float(np.mean(unavail)),
            "availability": float(np.mean(availability)),
            "expected_objective": expected_objective(
                job, plan, spec.objective, spec.outage),
            "slo": job.slo,
            "slo_met": slo_met,
            "contention_ckpt_s": float(np.mean(
                [r.time_contention_ckpt for r in per_run])),
            "contention_prockpt_s": float(np.mean(
                [r.time_contention_prockpt for r in per_run])),
            "repair_wait_s": float(np.mean(
                [r.time_repair_wait for r in per_run])),
            "n_faults": float(np.mean([r.sim.n_faults for r in per_run])),
            "n_trusted": float(np.mean([r.sim.n_trusted for r in per_run])),
        })
    return ResultTable(rows)
