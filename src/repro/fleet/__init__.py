"""Fleet availability subsystem: N heterogeneous jobs, shared limits.

Layers (each importable on its own):

  * :mod:`repro.fleet.availability` — the analytic availability model
    (availability-optimal interval, beta_A trust breakpoint, measured
    weighted-outage accounting);
  * :mod:`repro.fleet.sim` — the fleet discrete-event engine (storage
    contention + repair slots over the exact single-job mechanics);
  * :mod:`repro.fleet.spec` — declarative fleet specs + the model-zoo
    job sizing helper;
  * :mod:`repro.fleet.plan` — per-job planning under a shared objective,
    with bandwidth-aware staggering;
  * :mod:`repro.fleet.experiment` — ``evaluate_fleet`` producing per-tenant
    SLO result tables.
"""

from repro.fleet.availability import (OutageWeights, beta_avail,
                                      measured_unavailability,
                                      optimal_period_availability,
                                      t_avail_nopred, t_avail_pred,
                                      unavailability, unavailability_nopred,
                                      unavailability_pred)
from repro.fleet.experiment import evaluate_fleet, fleet_run_results
from repro.fleet.plan import (JobPlan, plan_fleet, plan_job,
                              staggered_period)
from repro.fleet.sim import (FleetJobInput, FleetJobResult, FleetSimResult,
                             simulate_fleet)
from repro.fleet.spec import (STATE_BYTES_PER_PARAM, FleetJobSpec, FleetSpec,
                              job_from_model)

__all__ = [
    "OutageWeights", "beta_avail", "measured_unavailability",
    "optimal_period_availability", "t_avail_nopred", "t_avail_pred",
    "unavailability", "unavailability_nopred", "unavailability_pred",
    "evaluate_fleet", "fleet_run_results",
    "JobPlan", "plan_fleet", "plan_job", "staggered_period",
    "FleetJobInput", "FleetJobResult", "FleetSimResult", "simulate_fleet",
    "FleetJobSpec", "FleetSpec", "job_from_model",
    "STATE_BYTES_PER_PARAM",
]
