"""Fleet discrete-event simulator: N jobs, shared storage + repair capacity.

Runs N heterogeneous single-job simulations — each the exact mechanics of
:func:`repro.core.simulator.simulate` — under one global clock with two
cross-job couplings:

  * **checkpoint-storage contention**: the storage fabric sustains
    ``storage_streams`` concurrent full-rate writers; when k jobs save
    (periodic or proactive) at once, each proceeds at rate
    ``min(1, storage_streams / k)`` — concurrent saves stretch each
    other's C.  A proactive checkpoint that gets stretched slips past its
    predicted date, so contention eats prediction lead time (the effect
    bandwidth-aware staggering mitigates).
  * **shared repair capacity**: at most ``repair_slots`` jobs can be in
    downtime + recovery at once; further faulted jobs queue FIFO, and the
    queueing time counts as (unweighted) outage.

Architecture: each job runs as a *coroutine* that executes the scalar
engine's event loop verbatim, yielding to the coordinator at every point
where cross-job state can matter — save starts, phase completions, fault
arrivals, and trust decisions.  The coordinator resumes whichever job has
the earliest next interaction time, so the couplings are causally ordered
across jobs.  Between yields a job performs *exactly* the scalar engine's
float arithmetic; with 1 job (or ``storage_streams=None`` and
``repair_slots=None``) no coordinator intervention ever fires and the
per-trace makespans are **bit-for-bit** those of ``simulate`` — the golden
degeneracy contract ``tests/test_fleet.py`` pins against
``tests/golden/parity_v1.json``.

Unsupported in the fleet engine (raise): ``window_mode="within"`` and
adaptive re-planning — both remain single-job engine features.
"""

from __future__ import annotations

import dataclasses
import heapq
import math
from collections import deque
from typing import Sequence

import numpy as np

from repro.core.simulator import (_CKPT, _DOWN, _EV_FAULT, _EV_PREDICTION,
                                  _FAULT_DEFERRED, _FAULT_FROM_TRACE,
                                  _PROCKPT, _RECOVER, _WORK, NeverTrust,
                                  SimResult, TrustPolicy)
from repro.core.simulator import _Machine
from repro.core.traces import FAULT_PRED, FAULT_UNPRED, EventTrace
from repro.core.waste import Platform
from repro.obs.metrics import get_registry

__all__ = ["FleetJobInput", "FleetJobResult", "FleetSimResult",
           "simulate_fleet"]

# Coroutine yield kinds: ("at", t) = resume when the global frontier
# reaches wall time t; ("end", target) = resume at min(phase_end, target),
# reading phase_end *live* (the coordinator may move it while suspended).
_AT, _END = 0, 1


@dataclasses.dataclass
class FleetJobInput:
    """One job's single-run inputs (the ``simulate()`` argument set)."""

    trace: EventTrace
    platform: Platform
    time_base: float
    period: float | object            # float or callable t -> T (stagger)
    cp: float
    trust: TrustPolicy
    inexact_window: float = 0.0
    rng: np.random.Generator | None = None
    name: str = ""
    sink: object | None = None        # repro.obs TraceSink (None = off)


@dataclasses.dataclass
class FleetJobResult:
    """Per-job :class:`SimResult` plus the fleet-level couplings' costs."""

    name: str
    sim: SimResult
    time_contention_ckpt: float = 0.0     # stretch added to periodic saves
    time_contention_prockpt: float = 0.0  # ... to proactive saves
    time_repair_wait: float = 0.0         # queueing for a repair slot


@dataclasses.dataclass
class FleetSimResult:
    jobs: list[FleetJobResult]

    @property
    def makespan(self) -> float:
        return max(j.sim.makespan for j in self.jobs)


class _OpenSave:
    """Coordinator-side state of one in-flight (possibly stretched) save."""

    __slots__ = ("kind", "nominal", "done", "last", "start", "stretched")

    def __init__(self, kind: int, nominal: float, start: float) -> None:
        self.kind = kind          # _CKPT or _PROCKPT
        self.nominal = nominal    # unstretched duration (C or C_p)
        self.done = 0.0           # nominal progress so far
        self.last = start         # wall time of the last progress update
        self.start = start        # wall time the save started
        self.stretched = False    # ever ran below full rate


class _JobRun:
    """One job: the scalar event loop as a coordinator-driven coroutine."""

    def __init__(self, idx: int, inp: FleetJobInput,
                 coord: "_Coordinator") -> None:
        self.idx = idx
        self.coord = coord
        self.name = inp.name or f"job{idx}"
        self.res = SimResult(makespan=0.0, time_base=inp.time_base)
        self.sink = inp.sink
        self.m = _Machine(inp.platform, inp.cp, inp.period, inp.time_base,
                          self.res, sink=inp.sink)
        self.cp = inp.cp
        self.period_arg = inp.period
        self.trust = inp.trust or NeverTrust()
        self.window = inp.inexact_window
        self.rng = inp.rng or np.random.default_rng(0)
        # Event queue: identical layout + ordering to simulate()'s heap.
        trace = inp.trace
        wins = trace.windows
        self.queue: list[tuple[float, int, int, int, float]] = []
        seq = 0
        for i, (t, k) in enumerate(zip(trace.times, trace.kinds)):
            w = -1.0 if wins is None else float(wins[i])
            if k == FAULT_UNPRED:
                self.queue.append((float(t), seq, _EV_FAULT,
                                   _FAULT_FROM_TRACE, 0.0))
            else:
                self.queue.append((float(t), seq, _EV_PREDICTION, int(k), w))
            seq += 1
        heapq.heapify(self.queue)
        self.seq = seq
        # Fleet couplings' state.
        self.save: _OpenSave | None = None
        self.has_slot = False
        self.waiting = False
        self.wait_since = 0.0
        self.time_contention_ckpt = 0.0
        self.time_contention_prockpt = 0.0
        self.time_repair_wait = 0.0
        self.pending: tuple[int, float] | None = None  # last yield
        self.gen = self._run()

    # -- scheduling ----------------------------------------------------------

    def wake(self) -> float:
        """Wall time of this job's next interaction (phase_end read live)."""
        kind, t = self.pending
        if kind == _AT:
            return t
        return min(self.m.phase_end, t)

    # -- the engine, cooperative ---------------------------------------------

    def _advance(self, target: float):
        """``_Machine.advance_to`` with a coordinator yield at every phase
        boundary; between yields the float ops are the scalar engine's."""
        m = self.m
        while m.now < target and not m.finished:
            if m.phase == _WORK:
                if m.w_rem <= 0.0:
                    yield (_AT, m.now)
                    self.coord.start_save(self, _CKPT, m.p.c, m.now + m.p.c)
                    continue
                dt = min(m.w_rem, target - m.now)
                m.now += dt
                m.done += dt
                m.w_rem -= dt
                if m.w_rem <= 0.0:
                    yield (_AT, m.now)
                    self.coord.start_save(self, _CKPT, m.p.c, m.now + m.p.c)
            elif m.phase_end <= target:
                yield (_END, target)
                e = m.phase_end      # may have moved while suspended
                if e <= target:
                    m.now = e
                    ph = m.phase
                    m._complete_phase()
                    self.coord.on_phase_complete(self, ph, e)
                # else: re-evaluate (stretch pushed the end past target)
            elif math.isinf(m.phase_end):
                # Waiting for a repair slot: suspend so the grant (which
                # sets a finite phase_end) can land *before* the local
                # clock advances past it.
                yield (_END, target)
                if m.phase_end <= target:
                    continue         # granted; complete on the next pass
                m.now = target       # frontier reached target, still queued
            else:
                m.now = target

    def _run(self):
        """The ``simulate()`` event loop, yielding at cross-job points."""
        m, res, queue = self.m, self.res, self.queue
        while queue and not m.finished:
            t, _, ev, payload, w = heapq.heappop(queue)
            if ev == _EV_FAULT:
                if payload == _FAULT_FROM_TRACE:
                    res.n_faults += 1
                yield from self._advance(t)
                if m.finished:
                    break
                yield (_AT, t)
                self.coord.on_fault(self, t)
                continue

            res.n_predictions += 1
            is_true = payload == FAULT_PRED
            w_i = self.window if w < 0.0 else w
            fault_date = t
            if is_true:
                res.n_faults += 1
                if w_i > 0.0:
                    fault_date = t + float(self.rng.uniform(0.0, w_i))
            if self.sink is not None:
                self.sink.emit(t, "prediction", true=is_true, window=w_i)

            ckpt_start = t - self.cp
            if ckpt_start >= m.now:
                yield from self._advance(ckpt_start)
                if m.finished:
                    break
                yield (_AT, ckpt_start)
                if m.phase == _WORK:
                    offset = t - m.period_start
                    trusted = self.trust.trust(offset, self.rng)
                    acted = trusted and self.coord.try_proactive(self, t)
                    if acted:
                        res.n_trusted += 1
                        if is_true:
                            res.n_trusted_true += 1
                    if self.sink is not None:
                        self.sink.emit(t, "trust", trusted=trusted,
                                       acted=acted, offset=offset)
                else:
                    res.n_ignored_by_necessity += 1
                    if self.sink is not None:
                        self.sink.emit(t, "trust", trusted=False,
                                       acted=False, ignored=True)
            else:
                res.n_ignored_by_necessity += 1
                if self.sink is not None:
                    self.sink.emit(t, "trust", trusted=False, acted=False,
                                   ignored=True)

            if is_true:
                heapq.heappush(queue, (fault_date, self.seq, _EV_FAULT,
                                       _FAULT_DEFERRED, 0.0))
                self.seq += 1

        yield from self._advance(math.inf)
        res.makespan = m.now
        if isinstance(self.period_arg, (int, float)):
            res.final_period = float(self.period_arg)


class _Coordinator:
    """Global clock: storage contention + repair slots across jobs."""

    def __init__(self, storage_streams: int | None,
                 repair_slots: int | None) -> None:
        if storage_streams is not None and storage_streams < 1:
            raise ValueError(f"storage_streams must be >= 1, "
                             f"got {storage_streams}")
        if repair_slots is not None and repair_slots < 1:
            raise ValueError(f"repair_slots must be >= 1, got {repair_slots}")
        self.streams = storage_streams
        self.repair_slots = repair_slots
        self.slots_free = repair_slots
        self.repair_q: deque[_JobRun] = deque()
        self.saving: list[_JobRun] = []
        self.cur_stretch = 1.0

    # -- storage contention --------------------------------------------------

    def _stretch(self, k: int) -> float:
        if self.streams is None or k <= self.streams:
            return 1.0
        return k / self.streams

    def _progress(self, t: float) -> None:
        """Advance every open save's nominal progress to wall time t."""
        for j in self.saving:
            sv = j.save
            if t > sv.last:
                sv.done += (t - sv.last) / self.cur_stretch
                sv.last = t

    def _set_stretch(self, t: float) -> None:
        """Recompute the shared rate and every open save's end time."""
        new = self._stretch(len(self.saving))
        if new == 1.0 and self.cur_stretch == 1.0:
            # Below capacity before and after: phase_end values already
            # advance at full rate — leave the scalar-exact floats alone
            # (this is the whole of the 1-job bit-for-bit degeneracy).
            return
        self.cur_stretch = new
        for j in self.saving:
            sv = j.save
            sv.stretched = True
            j.m.phase_end = t + (sv.nominal - sv.done) * new

    def start_save(self, job: _JobRun, kind: int, nominal: float,
                   scalar_end: float) -> None:
        """Register a starting save; ``scalar_end`` is the uncontended
        completion time computed with the scalar engine's float ops."""
        m = job.m
        m.phase = kind
        m.phase_end = scalar_end
        if job.sink is not None:     # the fleet bypasses _start_ckpt
            job.sink.emit(m.now, "ckpt_start" if kind == _CKPT
                          else "prockpt_start")
        job.save = _OpenSave(kind, nominal, m.now)
        self.saving.append(job)
        self._progress(m.now)
        self._set_stretch(m.now)

    def try_proactive(self, job: _JobRun, pred_date: float) -> bool:
        """``_Machine.try_proactive`` + contention registration: the
        uncontended save completes exactly at the predicted date."""
        m = job.m
        if m.finished or m.phase != _WORK:
            return False
        self.start_save(job, _PROCKPT, job.cp, pred_date)
        return True

    def _close_save(self, job: _JobRun, t: float) -> None:
        self._progress(t)
        sv = job.save
        job.save = None
        self.saving.remove(job)
        if sv.stretched:
            extra = max(0.0, (t - sv.start) - sv.nominal)
            if sv.kind == _CKPT:
                job.time_contention_ckpt += extra
            else:
                job.time_contention_prockpt += extra
        self._set_stretch(t)

    # -- phase completions / faults ------------------------------------------

    def on_phase_complete(self, job: _JobRun, phase: int, t: float) -> None:
        if phase in (_CKPT, _PROCKPT):
            self._close_save(job, t)
        elif phase == _RECOVER:
            self._release_slot(job, t)

    def on_fault(self, job: _JobRun, t: float) -> None:
        m = job.m
        if job.save is not None:
            # Abort the in-flight save.  For a stretched save, restore the
            # *nominal* remaining time into phase_end so _Machine.fault's
            # elapsed arithmetic charges nominal seconds; the stretch extra
            # already elapsed is contention time.  Unstretched saves keep
            # their scalar-exact phase_end untouched.
            self._progress(t)
            sv = job.save
            if sv.stretched:
                extra = max(0.0, (t - sv.start) - sv.done)
                if sv.kind == _CKPT:
                    job.time_contention_ckpt += extra
                else:
                    job.time_contention_prockpt += extra
                m.phase_end = t + (sv.nominal - sv.done)
            job.save = None
            self.saving.remove(job)
            self._set_stretch(t)
        was_waiting = job.waiting
        m.fault(t)
        get_registry().count("fleet.faults")
        if self.repair_slots is None:
            return
        if job.has_slot:
            return                       # restarts D holding its slot
        if was_waiting:
            m.phase_end = math.inf       # still queued; keep waiting
            return
        if self.slots_free > 0:
            self.slots_free -= 1
            job.has_slot = True
        else:
            job.waiting = True
            job.wait_since = t
            self.repair_q.append(job)
            m.phase_end = math.inf
            get_registry().count("fleet.repair_waits")

    def _release_slot(self, job: _JobRun, t: float) -> None:
        if self.repair_slots is None or not job.has_slot:
            return
        job.has_slot = False
        if self.repair_q:
            nxt = self.repair_q.popleft()
            nxt.waiting = False
            nxt.has_slot = True
            nxt.time_repair_wait += t - nxt.wait_since
            nxt.m.phase_end = t + nxt.m.p.d
        else:
            self.slots_free += 1


def simulate_fleet(
    inputs: Sequence[FleetJobInput],
    *,
    storage_streams: int | None = None,
    repair_slots: int | None = None,
) -> FleetSimResult:
    """Run all jobs to completion under the shared couplings.

    ``storage_streams=None`` / ``repair_slots=None`` disable the
    respective coupling entirely (every job runs at full rate / repairs
    immediately), which together with a single job reproduces
    :func:`repro.core.simulator.simulate` bit-for-bit.
    """
    if not inputs:
        return FleetSimResult(jobs=[])
    coord = _Coordinator(storage_streams, repair_slots)
    jobs = [_JobRun(i, inp, coord) for i, inp in enumerate(inputs)]
    live: list[_JobRun] = []
    for job in jobs:
        try:
            job.pending = next(job.gen)
            live.append(job)
        except StopIteration:
            pass
    while live:
        nxt = min(live, key=lambda j: (j.wake(), j.idx))
        if math.isinf(nxt.wake()):
            raise RuntimeError(
                "fleet deadlock: every live job waits forever "
                "(repair queue with no slot holder?)")
        try:
            nxt.pending = next(nxt.gen)
        except StopIteration:
            live.remove(nxt)
    return FleetSimResult(jobs=[
        FleetJobResult(name=j.name, sim=j.res,
                       time_contention_ckpt=j.time_contention_ckpt,
                       time_contention_prockpt=j.time_contention_prockpt,
                       time_repair_wait=j.time_repair_wait)
        for j in jobs
    ])
