"""Batched serving engine: prefill + decode with KV / recurrent caches.

The engine serves a batch of requests in lockstep (static-batch serving):
``prefill`` encodes the prompts and materializes the decode cache, then
``generate`` runs jitted single-token steps with greedy or temperature
sampling.  ``serve_step`` — one new token against a seq_len-deep cache — is
exactly what the decode input-shapes of the assignment lower in the dry-run.

Proactive checkpointing applies to serving too: the engine exposes its cache
as state so the fault-tolerance layer can snapshot in-flight batches; for the
paper's experiments the checkpointed unit is the training state, so serving
checkpoints are left to the caller.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..models.model import decode_step, init_cache, prefill

__all__ = ["GenerateResult", "ServingEngine"]


@dataclasses.dataclass
class GenerateResult:
    tokens: jax.Array        # (B, n_new)
    logprobs: jax.Array      # (B, n_new)
    steps: int


class ServingEngine:
    def __init__(self, cfg: ModelConfig, params: Any, *,
                 cache_len: int = 4096) -> None:
        if not cfg.causal:
            raise ValueError(f"{cfg.name} is encoder-only; nothing to serve")
        self.cfg = cfg
        self.params = params
        self.cache_len = cache_len

        def _prefill(params, batch):
            return prefill(cfg, params, batch, cache_len=cache_len)

        def _step(params, token, cache, key, temperature):
            logits, cache = decode_step(cfg, params, token, cache)
            logits = logits.astype(jnp.float32)
            greedy = jnp.argmax(logits, axis=-1)
            sampled = jax.random.categorical(key, logits / temperature)
            tok = jnp.where(temperature > 0, sampled, greedy).astype(jnp.int32)
            lp = jax.nn.log_softmax(logits)
            lp = jnp.take_along_axis(lp, tok[:, None], axis=-1)[:, 0]
            return tok, lp, cache

        self._prefill = jax.jit(_prefill)
        self._step = jax.jit(_step)

    def prefill(self, batch: dict) -> tuple[jax.Array, dict]:
        """Encode prompts. Returns (last-position logits, cache)."""
        return self._prefill(self.params, batch)

    def generate(self, batch: dict, n_new: int, *, temperature: float = 0.0,
                 seed: int = 0) -> GenerateResult:
        logits, cache = self.prefill(batch)
        key = jax.random.PRNGKey(seed)
        tok = jnp.argmax(logits.astype(jnp.float32), axis=-1).astype(jnp.int32)
        toks, lps = [], []
        for i in range(n_new):
            key, sub = jax.random.split(key)
            tok, lp, cache = self._step(self.params, tok, cache, sub,
                                        jnp.asarray(temperature, jnp.float32))
            toks.append(tok)
            lps.append(lp)
        return GenerateResult(jnp.stack(toks, axis=1),
                              jnp.stack(lps, axis=1), n_new)

    def fresh_cache(self, batch_size: int) -> dict:
        return init_cache(self.cfg, batch_size, self.cache_len)
