"""Batched serving engine (prefill + decode)."""

from .engine import GenerateResult, ServingEngine

__all__ = ["GenerateResult", "ServingEngine"]
