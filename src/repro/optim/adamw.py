"""AdamW optimizer + LR schedules + global-norm clipping (pure JAX).

No optax dependency.  The optimizer state is a pytree aligned with the
parameters: {"m": ..., "v": ..., "step": scalar}.  Moment dtype is
configurable (fp32 default; bf16 for memory-tight configs like llama3-405b
on 512 v5e chips — see the per-arch ``opt_dtype``).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "adamw_init", "adamw_update", "global_norm",
           "clip_by_global_norm", "cosine_schedule", "linear_schedule",
           "constant_schedule"]


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float | Callable[[jax.Array], jax.Array] = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    moment_dtype: str = "float32"

    def lr_at(self, step: jax.Array) -> jax.Array:
        if callable(self.lr):
            return self.lr(step)
        return jnp.asarray(self.lr, jnp.float32)


def cosine_schedule(peak: float, warmup: int, total: int,
                    floor_frac: float = 0.1) -> Callable:
    """Linear warmup then cosine decay to ``floor_frac * peak``."""

    def lr(step: jax.Array) -> jax.Array:
        step = step.astype(jnp.float32)
        warm = peak * step / jnp.maximum(1.0, warmup)
        prog = jnp.clip((step - warmup) / jnp.maximum(1.0, total - warmup),
                        0.0, 1.0)
        cos = floor_frac * peak + (1.0 - floor_frac) * peak \
            * 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
        return jnp.where(step < warmup, warm, cos)

    return lr


def linear_schedule(peak: float, warmup: int, total: int) -> Callable:
    def lr(step: jax.Array) -> jax.Array:
        step = step.astype(jnp.float32)
        warm = peak * step / jnp.maximum(1.0, warmup)
        decay = peak * jnp.clip((total - step) / jnp.maximum(1.0, total - warmup),
                                0.0, 1.0)
        return jnp.where(step < warmup, warm, decay)

    return lr


def constant_schedule(value: float) -> Callable:
    return lambda step: jnp.asarray(value, jnp.float32)


def global_norm(tree: Any) -> jax.Array:
    leaves = [jnp.sum(jnp.square(l.astype(jnp.float32)))
              for l in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(tree: Any, max_norm: float) -> tuple[Any, jax.Array]:
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale
                                   ).astype(g.dtype), tree), norm


def adamw_init(params: Any, cfg: AdamWConfig) -> dict:
    dt = jnp.bfloat16 if cfg.moment_dtype == "bfloat16" else jnp.float32
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def adamw_update(params: Any, grads: Any, state: dict, cfg: AdamWConfig,
                 ) -> tuple[Any, dict, dict]:
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    step = state["step"] + 1
    lr = cfg.lr_at(step)
    b1, b2 = cfg.b1, cfg.b2
    # Bias correction folded into the step size.
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        m32 = m.astype(jnp.float32) * b1 + (1.0 - b1) * gf
        # v >= 0 invariant enforced: a delta-quantized checkpoint restore
        # (proactive C_p path) can carry tiny negative noise into v, and
        # sqrt of that would poison the run with NaNs.
        v32 = jnp.maximum(
            v.astype(jnp.float32), 0.0) * b2 + (1.0 - b2) * jnp.square(gf)
        mhat = m32 / c1
        vhat = jnp.maximum(v32 / c2, 0.0)
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) \
            + cfg.weight_decay * p.astype(jnp.float32)
        newp = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return newp, m32.astype(m.dtype), v32.astype(v.dtype)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    new_state = {"m": new_m, "v": new_v, "step": step}
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
