"""Optimizers and schedules (pure JAX, no optax)."""

from .adamw import (AdamWConfig, adamw_init, adamw_update,
                    clip_by_global_norm, constant_schedule, cosine_schedule,
                    global_norm, linear_schedule)

__all__ = ["AdamWConfig", "adamw_init", "adamw_update", "global_norm",
           "clip_by_global_norm", "cosine_schedule", "linear_schedule",
           "constant_schedule"]
