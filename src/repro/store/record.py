"""Immutable, content-addressed run records (the result store's unit).

A :class:`RunRecord` captures one execution of an experiment, a benchmark
suite, or a whole scenario suite:

  * an **identity** — everything the results *depend on*: the canonical
    :class:`~repro.experiments.spec.ExperimentSpec` dict (which covers the
    trace-bank seeds/sizes, platform, predictor, cp), the execution context
    (n_traces / seed / engine / overrides), the runner's semantics version
    (``_EVAL_CACHE_VERSION`` — the same version that guards the persistent
    :class:`~repro.experiments.runner.EvalCache`), and the engine-identity
    fingerprint introduced with the v6 cache keys;
  * the **results** — the tidy result-table rows or the benchmark payload;
  * **provenance** — creation time, repo git rev, wall-clock timings,
    interpreter/library versions, and evaluated claim outcomes.

The record id is a content hash of the identity alone, so re-running the
same inputs finds the prior record (store-backed memoization / ``--resume``)
and a changed input can never alias a stale result.  Outputs are *not* part
of the id: two runs of one identity are interchangeable by the determinism
contract of the runner.

Serialization is deterministic — :func:`canonical_json` sorts keys and uses
the shortest round-trip float repr — so git diffs of exported records and
``repro-store diff`` output are meaningful.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import platform as _platform
import subprocess
import sys
import time
from typing import Any, Mapping

import numpy as np

__all__ = [
    "STORE_SCHEMA_VERSION",
    "canonical_json",
    "content_hash",
    "RunRecord",
]

# Store schema/semantics version.  Bump whenever the record layout or the
# meaning of an identity changes; records of another version are
# *invalidated, never misread* (``ResultStore.get`` refuses to decode them),
# matching the EvalCache v2-v6 precedent.
STORE_SCHEMA_VERSION = 1


def _plain(value: Any) -> Any:
    """Deep-convert to plain JSON types (numpy scalars, tuples, dataclasses
    with to_dict); unknown objects degrade to ``str``."""
    if isinstance(value, (np.floating,)):
        return float(value)
    if isinstance(value, (np.integer,)):
        return int(value)
    if isinstance(value, np.bool_):
        return bool(value)
    if isinstance(value, np.ndarray):
        return [_plain(v) for v in value.tolist()]
    if dataclasses.is_dataclass(value) and hasattr(value, "to_dict"):
        return _plain(value.to_dict())
    if isinstance(value, Mapping):
        return {str(k): _plain(v) for k, v in value.items()}
    if isinstance(value, (list, tuple)):
        return [_plain(v) for v in value]
    if value is None or isinstance(value, (bool, int, float, str)):
        return value
    return str(value)


def canonical_json(obj: Any, indent: int | None = 1) -> str:
    """Deterministic JSON: sorted keys, plain types, shortest round-trip
    float repr (CPython's ``repr`` — stable across processes and platforms).
    ``indent=None`` gives the compact single-line form used for hashing."""
    separators = (",", ":") if indent is None else (",", ": ")
    return json.dumps(_plain(obj), sort_keys=True, indent=indent,
                      separators=separators)


def content_hash(obj: Any) -> str:
    """sha256 hex digest of the canonical compact JSON form."""
    return hashlib.sha256(canonical_json(obj, indent=None).encode()).hexdigest()


def _git_rev() -> str:
    """Best-effort repo revision for provenance (never raises)."""
    try:
        out = subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            capture_output=True, text=True, timeout=5)
        if out.returncode == 0:
            rev = out.stdout.strip()
            dirty = subprocess.run(["git", "status", "--porcelain"],
                                   capture_output=True, text=True, timeout=5)
            if dirty.returncode == 0 and dirty.stdout.strip():
                rev += "-dirty"
            return rev
    except (OSError, subprocess.SubprocessError):
        pass
    return "unknown"


@dataclasses.dataclass(frozen=True)
class RunRecord:
    """One immutable run record (see module docstring).

    ``kind`` is ``"experiment"`` (a registered/inline
    :class:`ExperimentSpec` run through the batched runner — results in
    ``rows``), ``"benchmark"`` (a paper-claim benchmark script — results in
    ``payload``), or ``"suite"`` (an aggregate referencing member record
    ids in ``payload["items"]``).
    """

    kind: str
    name: str
    identity: dict
    rows: tuple = ()
    payload: dict = dataclasses.field(default_factory=dict)
    claims: tuple = ()
    timings: dict = dataclasses.field(default_factory=dict)
    created: float = 0.0
    git_rev: str = "unknown"
    provenance: dict = dataclasses.field(default_factory=dict)
    schema: int = STORE_SCHEMA_VERSION

    @property
    def record_id(self) -> str:
        """Content hash of the identity (inputs only — see module doc)."""
        return self.id_for(self.kind, self.name, self.identity,
                           schema=self.schema)

    @staticmethod
    def id_for(kind: str, name: str, identity: Mapping[str, Any], *,
               schema: int = STORE_SCHEMA_VERSION) -> str:
        """The record id a (kind, name, identity) run would get — what the
        suite runner probes the store with before executing anything."""
        return "r" + content_hash({
            "schema": schema, "kind": kind, "name": name,
            "identity": _plain(dict(identity))})[:20]

    @classmethod
    def create(cls, kind: str, name: str, identity: Mapping[str, Any], *,
               rows: Any = (), payload: Mapping[str, Any] | None = None,
               claims: Any = (), timings: Mapping[str, Any] | None = None,
               ) -> "RunRecord":
        """Build a record stamped with fresh provenance."""
        return cls(
            kind=kind, name=name, identity=_plain(dict(identity)),
            rows=tuple(_plain(list(rows))), payload=_plain(payload or {}),
            claims=tuple(_plain(list(claims))),
            timings=_plain(timings or {}), created=time.time(),
            git_rev=_git_rev(),
            provenance={
                "python": sys.version.split()[0],
                "numpy": np.__version__,
                "machine": _platform.machine(),
            })

    def with_claims(self, claims: Any) -> "RunRecord":
        return dataclasses.replace(self, claims=tuple(_plain(list(claims))))

    @property
    def ok(self) -> bool:
        """True when every evaluated claim passed (vacuously true)."""
        return all(c.get("ok", False) for c in self.claims)

    def to_dict(self) -> dict:
        return {
            "record_id": self.record_id,
            "schema": self.schema,
            "kind": self.kind,
            "name": self.name,
            "identity": self.identity,
            "rows": list(self.rows),
            "payload": self.payload,
            "claims": list(self.claims),
            "timings": self.timings,
            "created": self.created,
            "git_rev": self.git_rev,
            "provenance": self.provenance,
        }

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "RunRecord":
        if d.get("schema") != STORE_SCHEMA_VERSION:
            raise ValueError(
                f"record schema {d.get('schema')!r} != "
                f"{STORE_SCHEMA_VERSION} (invalidated, never misread)")
        return cls(
            kind=d["kind"], name=d["name"], identity=dict(d["identity"]),
            rows=tuple(d.get("rows", ())), payload=dict(d.get("payload", {})),
            claims=tuple(d.get("claims", ())),
            timings=dict(d.get("timings", {})),
            created=float(d.get("created", 0.0)),
            git_rev=str(d.get("git_rev", "unknown")),
            provenance=dict(d.get("provenance", {})),
            schema=int(d["schema"]))

    def to_json(self, indent: int | None = 1) -> str:
        return canonical_json(self.to_dict(), indent=indent)
