"""``repro-store`` — query, diff, gc and gate the result store.

    repro-store list [--kind K] [--name N]
    repro-store show RECORD_ID
    repro-store metrics RECORD_ID_OR_NAME
    repro-store diff A B [--timing-rel-tol 0.5]
    repro-store diff BASELINE.json            # bundle vs the store
    repro-store gc [--keep 5] [--max-mb 64] [--dry-run]
    repro-store gc --cache --max-mb 512       # EvalCache spill LRU eviction
    repro-store baseline NAME --out suites/baselines/NAME.json
    repro-store run suites/quick.yaml [--gate suites/baselines/quick.json]
                                      [--update-baseline PATH]
                                      [--no-resume] [--require-cached]

``run`` executes a suite file through :func:`repro.experiments.run_suite`
(store-backed, resumable) and exits nonzero on any failed claim;
``--gate`` additionally diffs the run's records against a committed
baseline bundle (exact on result cells, timing cells banded by
``--timing-rel-tol``) and fails on divergence — the CI regression gate.
``--update-baseline`` writes the bundle the gate compares against.
"""

from __future__ import annotations

import argparse
import sys
import time
from pathlib import Path
from typing import Any

from .record import RunRecord, canonical_json
from .store import ResultStore, default_store_dir, diff_records, gc_cache

_EXIT_OK = 0
_EXIT_REGRESSION = 1
_EXIT_USAGE = 2


def _store(args: argparse.Namespace) -> ResultStore:
    return ResultStore(args.store)


def _cmd_list(args: argparse.Namespace) -> int:
    store = _store(args)
    recs = store.find(kind=args.kind, name=args.name)
    if not recs:
        print(f"no records in {store.root}")
        return _EXIT_OK
    print(f"{'record_id':22s} {'kind':10s} {'name':24s} "
          f"{'created':19s} {'ok':3s} git_rev")
    for rec in recs:
        ts = time.strftime("%Y-%m-%d %H:%M:%S",
                           time.localtime(rec.created)) \
            if rec.created else "-"
        ok = ("yes" if rec.ok else "NO") if rec.claims else "-"
        print(f"{rec.record_id:22s} {rec.kind:10s} {rec.name:24s} "
              f"{ts:19s} {ok:3s} {rec.git_rev}")
    if store.invalidated:
        print(f"({store.invalidated} record(s) of another schema version "
              f"ignored)", file=sys.stderr)
    return _EXIT_OK


def _resolve_record(store: ResultStore, ref: str) -> RunRecord | None:
    """A record by id, by file path, or the newest by name."""
    if Path(ref).is_file():
        import json
        with open(ref) as fh:
            return RunRecord.from_dict(json.load(fh))
    rec = store.get(ref)
    if rec is not None:
        return rec
    return store.latest(ref)


def _cmd_show(args: argparse.Namespace) -> int:
    rec = _resolve_record(_store(args), args.record)
    if rec is None:
        print(f"error: no record {args.record!r}", file=sys.stderr)
        return _EXIT_USAGE
    print(rec.to_json())
    return _EXIT_OK


def _diff_pair(a: Any, b: Any, label: str,
               timing_rel_tol: float | None) -> int:
    diffs = diff_records(a, b, timing_rel_tol=timing_rel_tol)
    for d in diffs:
        print(f"{label}: {d}")
    return len(diffs)


def _cmd_diff(args: argparse.Namespace) -> int:
    store = _store(args)
    if args.b is None:
        # One argument: a baseline bundle, compared member-by-member
        # against the store (by record id — an identity change shows up as
        # a missing record, which is itself a divergence).
        try:
            bundle = ResultStore.load_bundle(args.a)
        except (OSError, ValueError) as e:
            print(f"error: {e}", file=sys.stderr)
            return _EXIT_USAGE
        n = 0
        for rid, rec_dict in sorted(bundle["records"].items()):
            mine = store.get(rid)
            label = f"{rec_dict.get('kind')}/{rec_dict.get('name')}"
            if mine is None:
                print(f"{label}: record {rid} missing from store "
                      f"(identity changed or never run)")
                n += 1
                continue
            n += _diff_pair(rec_dict, mine, label, args.timing_rel_tol)
        if n == 0:
            print(f"baseline {args.a}: no divergence "
                  f"({len(bundle['records'])} records)")
        return _EXIT_REGRESSION if n else _EXIT_OK
    a = _resolve_record(store, args.a)
    b = _resolve_record(store, args.b)
    if a is None or b is None:
        missing = args.a if a is None else args.b
        print(f"error: no record {missing!r}", file=sys.stderr)
        return _EXIT_USAGE
    n = _diff_pair(a, b, f"{a.name}", args.timing_rel_tol)
    if n == 0:
        print("no divergence")
    return _EXIT_REGRESSION if n else _EXIT_OK


def _cmd_metrics(args: argparse.Namespace) -> int:
    """Tabulate a record's observability metrics: the deterministic
    counters of ``payload["metrics"]`` plus the timing-banded timers and
    gauges persisted under ``timings``."""
    rec = _resolve_record(_store(args), args.record)
    if rec is None:
        print(f"error: no record {args.record!r}", file=sys.stderr)
        return _EXIT_USAGE
    sections = (
        ("counters", rec.payload.get("metrics", {}) or {}),
        ("timings", rec.timings or {}),
    )
    print(f"{rec.kind}/{rec.name} ({rec.record_id})")
    empty = True
    for title, values in sections:
        if not values:
            continue
        empty = False
        print(f"  {title}:")
        for key in sorted(values):
            v = values[key]
            shown = f"{v:.6g}" if isinstance(v, float) else v
            print(f"    {key:32s} {shown}")
    if empty:
        print("  (no metrics recorded)")
    return _EXIT_OK


def _cmd_gc(args: argparse.Namespace) -> int:
    if args.cache:
        if args.max_mb is None:
            print("error: gc --cache needs --max-mb", file=sys.stderr)
            return _EXIT_USAGE
        evicted = gc_cache(args.cache_dir,
                           max_bytes=int(args.max_mb * 1024 * 1024),
                           dry_run=args.dry_run)
        verb = "would evict" if args.dry_run else "evicted"
        for path, size in evicted:
            print(f"{verb} {path} ({size} bytes)")
        print(f"{verb} {len(evicted)} spill file(s), "
              f"{sum(s for _, s in evicted)} bytes")
        return _EXIT_OK
    store = _store(args)
    max_bytes = None if args.max_mb is None \
        else int(args.max_mb * 1024 * 1024)
    victims = store.gc(keep_per_name=args.keep, max_bytes=max_bytes,
                       dry_run=args.dry_run)
    verb = "would delete" if args.dry_run else "deleted"
    for rid, reason in victims:
        print(f"{verb} {rid}: {reason}")
    print(f"{verb} {len(victims)} record(s) from {store.root}")
    return _EXIT_OK


def _suite_bundle(store: ResultStore, suite_rec: RunRecord) -> dict:
    members = []
    for item in suite_rec.payload.get("items", ()):
        rec = store.get(item["record_id"])
        if rec is not None:
            members.append(rec)
    return ResultStore.bundle(suite_rec, members)


def _cmd_baseline(args: argparse.Namespace) -> int:
    store = _store(args)
    suite_rec = store.latest(args.name, kind="suite") \
        if args.record is None else store.get(args.record)
    if suite_rec is None or suite_rec.kind != "suite":
        print(f"error: no suite record for {args.name!r} "
              f"(run the suite first)", file=sys.stderr)
        return _EXIT_USAGE
    bundle = _suite_bundle(store, suite_rec)
    if args.out:
        out = Path(args.out)
        out.parent.mkdir(parents=True, exist_ok=True)
        out.write_text(canonical_json(bundle) + "\n")
        print(f"baseline {args.name} ({len(bundle['records'])} records) "
              f"-> {out}")
    else:
        path = store.set_baseline(args.name, bundle)
        print(f"baseline {args.name} ({len(bundle['records'])} records) "
              f"-> {path}")
    return _EXIT_OK


def _gate(store: ResultStore, result: Any, baseline_path: str,
          timing_rel_tol: float | None) -> int:
    """Diff a suite run against a committed baseline bundle, by item name
    (so an identity change diffs loudly instead of just going missing)."""
    bundle = ResultStore.load_bundle(baseline_path)
    base_by_name = {(r.get("kind"), r.get("name")): r
                    for r in bundle["records"].values()}
    cur_by_name = {(it.kind, it.name): it.record for it in result.items
                   if it.record is not None}
    n = 0
    for key in sorted(set(base_by_name) | set(cur_by_name),
                      key=lambda kv: (str(kv[0]), str(kv[1]))):
        label = f"{key[0]}/{key[1]}"
        if key not in cur_by_name:
            print(f"gate: {label} in baseline but not in this run")
            n += 1
        elif key not in base_by_name:
            print(f"gate: {label} ran but has no baseline record "
                  f"(update the baseline)")
            n += 1
        else:
            n += _diff_pair(base_by_name[key], cur_by_name[key], label,
                            timing_rel_tol)
    if n == 0:
        print(f"gate: no divergence vs {baseline_path} "
              f"({len(base_by_name)} records)")
    return n


def _cmd_run(args: argparse.Namespace) -> int:
    from repro.experiments.runner import run_suite

    store = _store(args)
    result = run_suite(args.suite, store=store, resume=not args.no_resume,
                       engine=args.engine, workers=args.workers,
                       verbose=args.verbose)
    print(result.summary())
    rc = _EXIT_OK
    if not result.ok:
        rc = _EXIT_REGRESSION
    if args.require_cached:
        missed = [it.name for it in result.items if not it.cached]
        if missed:
            print(f"require-cached: {len(missed)} item(s) executed instead "
                  f"of resuming from the store: {missed}")
            rc = _EXIT_REGRESSION
    if args.gate:
        if _gate(store, result, args.gate, args.timing_rel_tol):
            rc = _EXIT_REGRESSION
    if args.update_baseline:
        bundle = _suite_bundle(store, result.record)
        out = Path(args.update_baseline)
        out.parent.mkdir(parents=True, exist_ok=True)
        out.write_text(canonical_json(bundle) + "\n")
        print(f"baseline ({len(bundle['records'])} records) -> {out}")
    return rc


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="repro-store", description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--store", default=None, metavar="DIR",
                    help=f"store root (default $REPRO_STORE_DIR or "
                         f"{default_store_dir()})")
    sub = ap.add_subparsers(dest="cmd", required=True)

    p = sub.add_parser("list", help="list records, newest first")
    p.add_argument("--kind", default=None,
                   choices=("experiment", "benchmark", "suite"))
    p.add_argument("--name", default=None)
    p.set_defaults(fn=_cmd_list)

    p = sub.add_parser("show", help="print one record as canonical JSON")
    p.add_argument("record", help="record id, file path, or name (newest)")
    p.set_defaults(fn=_cmd_show)

    p = sub.add_parser(
        "diff", help="diff two records, or a baseline bundle vs the store")
    p.add_argument("a", help="record id/path/name, or a baseline bundle")
    p.add_argument("b", nargs="?", default=None,
                   help="second record (omit when A is a bundle)")
    p.add_argument("--timing-rel-tol", type=float, default=None,
                   metavar="FRAC",
                   help="compare timing cells within this relative band "
                        "(default: ignore them)")
    p.set_defaults(fn=_cmd_diff)

    p = sub.add_parser(
        "metrics", help="show a record's counters / timers / gauges")
    p.add_argument("record", help="record id, file path, or name (newest)")
    p.set_defaults(fn=_cmd_metrics)

    p = sub.add_parser("gc", help="prune store records / the EvalCache spill")
    p.add_argument("--keep", type=int, default=5, metavar="N",
                   help="newest records kept per (kind, name) (default 5)")
    p.add_argument("--max-mb", type=float, default=None,
                   help="size cap; LRU-evict past it")
    p.add_argument("--dry-run", action="store_true",
                   help="report would-be deletions without deleting")
    p.add_argument("--cache", action="store_true",
                   help="gc the EvalCache spill (eval-*.json) instead of "
                        "store records")
    p.add_argument("--cache-dir", default=None, metavar="DIR",
                   help="spill dir for --cache (default $REPRO_CACHE_DIR "
                        "or ~/.cache/repro)")
    p.set_defaults(fn=_cmd_gc)

    p = sub.add_parser(
        "baseline", help="export a suite run as a baseline bundle")
    p.add_argument("name", help="suite name (newest suite record)")
    p.add_argument("--record", default=None,
                   help="a specific suite record id instead of the newest")
    p.add_argument("--out", default=None, metavar="PATH",
                   help="write the bundle here (for committing); default: "
                        "the store's baselines/ dir")
    p.set_defaults(fn=_cmd_baseline)

    p = sub.add_parser("run", help="run a suite file (store-backed)")
    p.add_argument("suite", help="suite file (.yaml/.yml/.json)")
    p.add_argument("--no-resume", action="store_true",
                   help="execute every item even when the store has it")
    p.add_argument("--require-cached", action="store_true",
                   help="fail unless every item resumed from the store")
    p.add_argument("--gate", default=None, metavar="BASELINE",
                   help="fail on divergence vs this baseline bundle")
    p.add_argument("--update-baseline", default=None, metavar="PATH",
                   help="write the run's baseline bundle here")
    p.add_argument("--timing-rel-tol", type=float, default=None,
                   metavar="FRAC", help="timing band for --gate")
    p.add_argument("--engine", default=None,
                   choices=("auto", "batch", "scalar", "jax"))
    p.add_argument("--workers", type=int, default=None)
    p.add_argument("-v", "--verbose", action="store_true")
    p.set_defaults(fn=_cmd_run)

    args = ap.parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    sys.exit(main())
