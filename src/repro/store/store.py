"""Queryable on-disk result store (the durable tier under the EvalCache).

Layout (``$REPRO_STORE_DIR`` or ``<cache_dir>/store``)::

    <root>/records/<record_id>.json     immutable run records
    <root>/baselines/<name>.json        named baseline bundles

Records are written atomically and read back through a schema-version
check: a record of any other :data:`~repro.store.record.STORE_SCHEMA_VERSION`
is *invalidated, never misread* (``get`` returns ``None``), matching the
persistent EvalCache v2-v6 precedent.  The store is the durable result
tier — the per-cell ``~/.cache/repro`` EvalCache spill is a derived cache
that :func:`gc_cache` may evict at any time (results regenerate; run-level
records do not).

:func:`diff_records` compares two records **deterministically**: result
content (identity, rows, payload) is compared exactly, while provenance
(timestamps, git revs, wall-clock timings, device names — see
:data:`PROVENANCE_KEYS` / :func:`is_timing_key`) is excluded, or banded
with a relative tolerance when ``timing_rel_tol`` is given.  That is what
makes ``repro-store diff`` empty on an unchanged tree even though every
run re-measures its timings.
"""

from __future__ import annotations

import dataclasses
import json
import math
import os
import tempfile
from pathlib import Path
from typing import Any, Iterable, Iterator

from repro.experiments.runner import default_cache_dir

from .record import STORE_SCHEMA_VERSION, RunRecord, canonical_json

__all__ = [
    "ResultStore",
    "default_store_dir",
    "Diff",
    "diff_records",
    "gc_cache",
    "PROVENANCE_KEYS",
    "is_timing_key",
]

_STORE_DIR_ENV = "REPRO_STORE_DIR"

# Leaf keys that are provenance, not results: excluded from diffs anywhere
# they appear.  ``claims`` are derived from rows/payload (and gated
# separately by the suite runner); ``device`` names the accelerator a
# benchmark happened to run on.
PROVENANCE_KEYS = frozenset({
    "record_id", "created", "git_rev", "timings", "provenance", "claims",
    "device", "host",
})

# Timing-valued leaf keys: wall-clock measurements that legitimately differ
# run to run.  Ignored by default; compared within a relative band when a
# tolerance is given (the "tolerance bands for timing cells" of the CI
# gate).  ``_s`` is the repo-wide convention for seconds cells in benchmark
# payloads, both as a suffix (``batch_s``) and infixed in derived cells
# (``scalar_s_measured``, ``scalar_s_est_full_grid``).
_TIMING_NAMES = frozenset({
    "speedup", "lanes_per_s", "coordination_overhead", "wall_s",
})


def is_timing_key(key: str) -> bool:
    return key in _TIMING_NAMES or key.endswith("_s") \
        or key.endswith("_seconds") or "_s_" in key


def default_store_dir() -> Path:
    """``$REPRO_STORE_DIR``, else ``<eval-cache-dir>/store``."""
    env = os.environ.get(_STORE_DIR_ENV, "").strip()
    if env:
        return Path(env)
    return default_cache_dir() / "store"


def _atomic_write(path: Path, text: str) -> None:
    path.parent.mkdir(parents=True, exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=path.parent, prefix=path.name,
                               suffix=".tmp")
    try:
        with os.fdopen(fd, "w") as fh:
            fh.write(text)
        os.replace(tmp, path)
    except OSError:
        try:
            os.unlink(tmp)
        except OSError:
            pass
        raise


class ResultStore:
    """The on-disk record store (see module docstring)."""

    def __init__(self, root: str | Path | None = None) -> None:
        self.root = Path(root) if root is not None else default_store_dir()
        self.invalidated = 0   # wrong-schema / unreadable records seen

    # -- paths ---------------------------------------------------------------

    @property
    def records_dir(self) -> Path:
        return self.root / "records"

    @property
    def baselines_dir(self) -> Path:
        return self.root / "baselines"

    def record_path(self, record_id: str) -> Path:
        return self.records_dir / f"{record_id}.json"

    # -- record CRUD ---------------------------------------------------------

    def put(self, record: RunRecord) -> str:
        """Write (or overwrite — same identity, interchangeable results) the
        record; returns its id."""
        rid = record.record_id
        _atomic_write(self.record_path(rid), record.to_json() + "\n")
        return rid

    def get(self, record_id: str) -> RunRecord | None:
        """The record, or ``None`` when absent *or* written by another
        schema version / unreadable (invalidated, never misread)."""
        return self._load(self.record_path(record_id))

    def _load(self, path: Path) -> RunRecord | None:
        try:
            with open(path) as fh:
                d = json.load(fh)
            return RunRecord.from_dict(d)
        except FileNotFoundError:
            return None
        except (OSError, ValueError, TypeError, KeyError):
            self.invalidated += 1
            return None

    def delete(self, record_id: str) -> bool:
        try:
            os.unlink(self.record_path(record_id))
            return True
        except OSError:
            return False

    # -- query API -----------------------------------------------------------

    def __iter__(self) -> Iterator[RunRecord]:
        if not self.records_dir.is_dir():
            return
        for path in sorted(self.records_dir.glob("*.json")):
            rec = self._load(path)
            if rec is not None:
                yield rec

    def find(self, kind: str | None = None, name: str | None = None,
             since: float | None = None) -> list[RunRecord]:
        """Records filtered by kind/name/creation time, newest first."""
        out = [r for r in self
               if (kind is None or r.kind == kind)
               and (name is None or r.name == name)
               and (since is None or r.created >= since)]
        out.sort(key=lambda r: (-r.created, r.record_id))
        return out

    def latest(self, name: str, kind: str | None = None) -> RunRecord | None:
        got = self.find(kind=kind, name=name)
        return got[0] if got else None

    # -- baselines -----------------------------------------------------------

    @staticmethod
    def bundle(suite_record: RunRecord,
               members: Iterable[RunRecord]) -> dict:
        """A self-contained baseline bundle: the suite record plus every
        member record, keyed by id (the committed-to-git form)."""
        return {
            "format": "repro-store-baseline",
            "schema": STORE_SCHEMA_VERSION,
            "suite": suite_record.to_dict(),
            "records": {r.record_id: r.to_dict() for r in members},
        }

    @staticmethod
    def load_bundle(path: str | Path) -> dict:
        with open(path) as fh:
            d = json.load(fh)
        if d.get("format") != "repro-store-baseline" \
                or d.get("schema") != STORE_SCHEMA_VERSION:
            raise ValueError(
                f"{path}: not a schema-v{STORE_SCHEMA_VERSION} baseline "
                f"bundle (invalidated, never misread)")
        return d

    def set_baseline(self, name: str, bundle: dict) -> Path:
        path = self.baselines_dir / f"{name}.json"
        _atomic_write(path, canonical_json(bundle) + "\n")
        return path

    def get_baseline(self, name: str) -> dict | None:
        path = self.baselines_dir / f"{name}.json"
        try:
            return self.load_bundle(path)
        except (OSError, ValueError):
            return None

    # -- gc ------------------------------------------------------------------

    def gc(self, keep_per_name: int = 5, max_bytes: int | None = None,
           dry_run: bool = False) -> list[tuple[str, str]]:
        """Prune store records: keep the newest ``keep_per_name`` per
        (kind, name), then evict LRU (by creation time) past ``max_bytes``.
        Baselines are never touched.  Returns ``(record_id, reason)`` of
        every (would-be) deletion; ``dry_run`` reports without deleting."""
        by_name: dict[tuple[str, str], list[RunRecord]] = {}
        for rec in self:
            by_name.setdefault((rec.kind, rec.name), []).append(rec)
        victims: list[tuple[str, str]] = []
        survivors: list[RunRecord] = []
        for recs in by_name.values():
            recs.sort(key=lambda r: (-r.created, r.record_id))
            for rec in recs[keep_per_name:]:
                victims.append((rec.record_id,
                                f"superseded (keep={keep_per_name})"))
            survivors.extend(recs[:keep_per_name])
        if max_bytes is not None:
            sized = [(r, self.record_path(r.record_id).stat().st_size)
                     for r in survivors
                     if self.record_path(r.record_id).exists()]
            total = sum(s for _, s in sized)
            sized.sort(key=lambda rs: (rs[0].created, rs[0].record_id))
            for rec, size in sized:
                if total <= max_bytes:
                    break
                victims.append((rec.record_id,
                                f"size cap ({size} bytes over budget)"))
                total -= size
        if not dry_run:
            for rid, _ in victims:
                self.delete(rid)
        return victims


# ---------------------------------------------------------------------------
# Deterministic record diff
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Diff:
    """One divergence between two records."""

    path: str
    a: Any
    b: Any
    kind: str = "value"   # value | missing_a | missing_b | timing

    def __str__(self) -> str:
        if self.kind == "missing_a":
            return f"{self.path}: only in B ({self.b!r})"
        if self.kind == "missing_b":
            return f"{self.path}: only in A ({self.a!r})"
        tag = " [timing]" if self.kind == "timing" else ""
        return f"{self.path}: {self.a!r} != {self.b!r}{tag}"


def _leaf_equal(a: Any, b: Any) -> bool:
    if isinstance(a, float) and isinstance(b, float) \
            and math.isnan(a) and math.isnan(b):
        return True
    if isinstance(a, bool) != isinstance(b, bool):
        return False
    return a == b


def _walk(a: Any, b: Any, path: str, out: list[Diff],
          timing_rel_tol: float | None, in_timing: bool) -> None:
    if isinstance(a, dict) and isinstance(b, dict):
        for key in sorted(set(a) | set(b)):
            sub = f"{path}.{key}" if path else str(key)
            if key in PROVENANCE_KEYS:
                continue
            timing = in_timing or is_timing_key(str(key))
            if key not in a:
                out.append(Diff(sub, None, b[key], "missing_a"))
            elif key not in b:
                out.append(Diff(sub, a[key], None, "missing_b"))
            else:
                _walk(a[key], b[key], sub, out, timing_rel_tol, timing)
        return
    if isinstance(a, list) and isinstance(b, list):
        if len(a) != len(b):
            out.append(Diff(f"{path}.length", len(a), len(b)))
            return
        for i, (av, bv) in enumerate(zip(a, b)):
            _walk(av, bv, f"{path}[{i}]", out, timing_rel_tol, in_timing)
        return
    if in_timing:
        # Timing cells: ignored entirely without a tolerance, banded with one.
        if timing_rel_tol is None:
            return
        if isinstance(a, (int, float)) and isinstance(b, (int, float)) \
                and not isinstance(a, bool) and not isinstance(b, bool):
            ref = max(abs(float(a)), abs(float(b)), 1e-12)
            if abs(float(a) - float(b)) / ref > timing_rel_tol:
                out.append(Diff(path, a, b, "timing"))
            return
    if not _leaf_equal(a, b):
        out.append(Diff(path, a, b))


def diff_records(a: RunRecord | dict, b: RunRecord | dict, *,
                 timing_rel_tol: float | None = None) -> list[Diff]:
    """Result-content differences between two records (see module doc).

    Exact on result cells (identity, rows, payload values — the bitwise
    tier), excluding provenance keys; timing cells are skipped, or compared
    within ``timing_rel_tol`` relative when given.
    """
    da = a.to_dict() if isinstance(a, RunRecord) else dict(a)
    db = b.to_dict() if isinstance(b, RunRecord) else dict(b)
    out: list[Diff] = []
    _walk(da, db, "", out, timing_rel_tol, False)
    return out


# ---------------------------------------------------------------------------
# EvalCache spill gc (the unbounded ~/.cache/repro growth fix)
# ---------------------------------------------------------------------------

def gc_cache(cache_dir: str | Path | None = None, *,
             max_bytes: int, dry_run: bool = False,
             now: float | None = None) -> list[tuple[Path, int]]:
    """LRU-evict persistent EvalCache spill files past ``max_bytes``.

    The spill (``<cache_dir>/eval-*.json``) is a derived cache — every entry
    regenerates from its spec — so eviction is always safe, it only costs
    recomputation.  Files are evicted oldest-``mtime`` first (the EvalCache
    touches its file on load, so mtime is an LRU clock) until the total is
    under the cap.  Returns ``(path, size)`` of every (would-be) eviction;
    ``dry_run`` reports without deleting.  The result store itself (the
    durable tier, a subdirectory by default) is never touched.
    """
    root = Path(cache_dir) if cache_dir is not None else default_cache_dir()
    if not root.is_dir():
        return []
    files = []
    for path in root.glob("eval-*.json"):
        try:
            st = path.stat()
        except OSError:
            continue
        files.append((st.st_mtime, st.st_size, path))
    total = sum(size for _, size, _ in files)
    if total <= max_bytes:
        return []
    files.sort()   # oldest first
    evicted: list[tuple[Path, int]] = []
    for _, size, path in files:
        if total <= max_bytes:
            break
        evicted.append((path, size))
        total -= size
        if not dry_run:
            try:
                os.unlink(path)
            except OSError:
                pass
    del now  # reserved for age-based policies
    return evicted
