"""Declarative scenario suites: experiment collections + expected-claim asserts.

A **suite file** (YAML or JSON) collects runnable items with the claims the
repo's benchmark scripts used to hard-code, lifting them into data::

    suite: quick
    description: pinned CI suite
    register: [benchmarks.run]        # modules whose import registers items
    defaults: {quick: true}
    items:
      - experiment: window_sweep      # a registered ExperimentSpec builder
        n_traces: 4
        claims:
          - {kind: compare, metric: makespan, op: "==",
             lhs: {strategy: WindowStart, window: 0.0, predictor: good},
             rhs: {strategy: OptimalPrediction, window: 0.0, predictor: good}}
          - {kind: monotonic, metric: makespan, over: window,
             where: {strategy: WindowStart, predictor: good},
             direction: increasing}
      - benchmark: fleet_sweep        # a paper-claim benchmark function
        claims:
          - {kind: bound, path: model_vs_sim.llama3-405b, min: 0.9, max: 1.1}

Item forms:

  * ``experiment:`` — a registered experiment name (``build_experiment``)
    or ``spec:`` an inline :class:`ExperimentSpec` dict; optional
    ``args`` (builder kwargs), ``overrides`` (``--set`` semantics via
    :meth:`ExperimentSpec.with_overrides`), ``n_traces`` / ``seed`` /
    ``engine`` execution context.  Claims address the tidy result table by
    ``metric`` + ``where`` (axis-column equality).
  * ``benchmark:`` — a benchmark-suite function from the
    :mod:`benchmarks.run` registry (its internal paper-claim asserts run
    too).  Claims address the returned payload by dotted ``path``.

Claim kinds:

  * ``pinned``     — a value equals ``value`` within ``tol`` (absolute)
    and/or ``rel_tol`` (relative); both omitted = exact;
  * ``bound``      — a value within ``[min, max]``;
  * ``compare``    — ``lhs <op> rhs`` for two looked-up values, with an
    optional ``rel_factor`` scaling the rhs (e.g. "within 3%": op ``<=``,
    rel_factor 1.03);
  * ``monotonic``  — a metric is monotone along a sweep column (sorted by
    that column's numeric value), ``direction`` increasing/decreasing,
    optional ``tol`` slack.

Claims are evaluated on every suite run — including store-resumed ones, so
tightening a claim re-gates cached results without re-simulating.
"""

from __future__ import annotations

import dataclasses
import json
import operator
from pathlib import Path
from typing import Any, Mapping, Sequence

__all__ = [
    "ClaimSpec",
    "SuiteItem",
    "SuiteSpec",
    "evaluate_claims",
    "lookup_path",
]

_OPS = {"<": operator.lt, "<=": operator.le, ">": operator.gt,
        ">=": operator.ge, "==": operator.eq}


def lookup_path(payload: Mapping[str, Any], path: str) -> Any:
    """Dotted-path lookup into a nested payload dict (list indices OK):
    ``lookup_path(p, "engine.speedup")``, ``lookup_path(p, "rows.0.waste")``.
    """
    cur: Any = payload
    for part in path.split("."):
        if isinstance(cur, Mapping):
            if part not in cur:
                raise KeyError(f"payload path {path!r}: no key {part!r} "
                               f"(have {sorted(cur)[:12]})")
            cur = cur[part]
        elif isinstance(cur, Sequence) and not isinstance(cur, str):
            cur = cur[int(part)]
        else:
            raise KeyError(f"payload path {path!r}: cannot descend into "
                           f"{type(cur).__name__} at {part!r}")
    return cur


@dataclasses.dataclass(frozen=True)
class ClaimSpec:
    """One expected-claim assert (see module docstring)."""

    kind: str
    metric: str | None = None          # table claims
    where: dict = dataclasses.field(default_factory=dict)
    path: str | None = None            # payload claims
    value: Any = None                  # pinned
    tol: float | None = None
    rel_tol: float | None = None
    min: float | None = None           # bound
    max: float | None = None
    lhs: dict | None = None            # compare
    rhs: dict | None = None
    op: str = "<"
    rel_factor: float = 1.0
    over: str | None = None            # monotonic
    direction: str = "increasing"
    label: str | None = None

    def __post_init__(self) -> None:
        if self.kind not in ("pinned", "bound", "compare", "monotonic"):
            raise ValueError(f"unknown claim kind {self.kind!r}")
        if self.kind == "compare" and self.op not in _OPS:
            raise ValueError(f"unknown compare op {self.op!r}")
        if self.kind == "monotonic":
            if self.direction not in ("increasing", "decreasing"):
                raise ValueError(
                    f"monotonic direction must be increasing/decreasing, "
                    f"got {self.direction!r}")
            if not self.over:
                raise ValueError("monotonic claim needs 'over' (the column)")

    # -- construction --------------------------------------------------------

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "ClaimSpec":
        known = {f.name for f in dataclasses.fields(cls)}
        unknown = set(d) - known
        if unknown:
            raise KeyError(f"unknown claim fields: {sorted(unknown)}")
        return cls(**{k: (dict(v) if isinstance(v, Mapping) else v)
                      for k, v in d.items()})

    def to_dict(self) -> dict:
        out: dict[str, Any] = {"kind": self.kind}
        for f in dataclasses.fields(self):
            if f.name == "kind":
                continue
            v = getattr(self, f.name)
            default = (f.default_factory()
                       if f.default is dataclasses.MISSING else f.default)
            if v != default:
                out[f.name] = v
        return out

    @property
    def display(self) -> str:
        if self.label:
            return self.label
        if self.kind == "pinned":
            tgt = self.path or f"{self.metric} @ {self.where}"
            return f"pinned {tgt} == {self.value}"
        if self.kind == "bound":
            tgt = self.path or f"{self.metric} @ {self.where}"
            return f"bound {self.min} <= {tgt} <= {self.max}"
        if self.kind == "compare":
            fac = f" * {self.rel_factor}" if self.rel_factor != 1.0 else ""
            return f"{self.metric or self.path} {self.lhs} {self.op} " \
                   f"{self.rhs}{fac}"
        return f"{self.metric} {self.direction} over {self.over} " \
               f"@ {self.where}"

    # -- evaluation ----------------------------------------------------------

    def _value(self, table, payload: Mapping[str, Any],
               where: Mapping[str, Any] | None = None) -> Any:
        if self.path is not None:
            return lookup_path(payload, self.path)
        if table is None:
            raise KeyError("table claim on a payload-only record "
                           "(set 'path' instead of 'metric'/'where')")
        return table.value(self.metric, **(self.where if where is None
                                           else dict(where)))

    def evaluate(self, table, payload: Mapping[str, Any]) -> dict:
        """-> ``{"claim", "ok", "detail"}`` (never raises on a failed
        comparison — only on a malformed claim/lookup)."""
        try:
            ok, detail = self._check(table, payload)
        except (KeyError, IndexError, TypeError, ValueError) as e:
            ok, detail = False, f"lookup error: {e}"
        return {"claim": self.display, "kind": self.kind, "ok": bool(ok),
                "detail": detail}

    def _check(self, table, payload) -> tuple[bool, str]:
        if self.kind == "pinned":
            got = float(self._value(table, payload))
            want = float(self.value)
            err = abs(got - want)
            lim = max(self.tol or 0.0,
                      (self.rel_tol or 0.0) * abs(want))
            ok = err <= lim if (self.tol is not None
                                or self.rel_tol is not None) \
                else got == want
            return ok, f"got {got!r}, pinned {want!r} (|err| {err:.3g})"
        if self.kind == "bound":
            got = float(self._value(table, payload))
            ok = (self.min is None or got >= self.min) \
                and (self.max is None or got <= self.max)
            return ok, f"got {got!r} in [{self.min}, {self.max}]"
        if self.kind == "compare":
            a = float(self._value(table, payload, where=self.lhs))
            b = float(self._value(table, payload, where=self.rhs)) \
                * self.rel_factor
            return _OPS[self.op](a, b), f"{a!r} {self.op} {b!r}"
        # monotonic
        sub = table.where(**self.where)
        pairs = sorted(((row[self.over], row[self.metric])
                        for row in sub.rows), key=lambda kv: float(kv[0]))
        if len(pairs) < 2:
            return False, f"monotonic needs >= 2 rows, got {len(pairs)}"
        vals = [float(v) for _, v in pairs]
        tol = self.tol or 0.0
        if self.direction == "increasing":
            ok = all(b >= a - tol for a, b in zip(vals, vals[1:]))
        else:
            ok = all(b <= a + tol for a, b in zip(vals, vals[1:]))
        return ok, f"{self.direction} over {self.over}: " \
                   f"{[round(v, 6) for v in vals]}"


@dataclasses.dataclass(frozen=True)
class SuiteItem:
    """One runnable suite entry (experiment or benchmark; see module doc)."""

    experiment: str | None = None
    benchmark: str | None = None
    spec: dict | None = None
    args: dict = dataclasses.field(default_factory=dict)
    overrides: dict = dataclasses.field(default_factory=dict)
    quick: bool = True
    n_traces: int | None = None
    seed: int | None = None
    engine: str | None = None
    batched_traces: bool = False
    claims: tuple = ()
    label: str | None = None

    def __post_init__(self) -> None:
        targets = [t for t in (self.experiment, self.benchmark, self.spec)
                   if t is not None]
        if len(targets) != 1:
            raise ValueError("suite item needs exactly one of "
                             "experiment / benchmark / spec")
        if self.benchmark is not None and (self.overrides or self.args
                                           or self.n_traces is not None
                                           or self.seed is not None):
            raise ValueError(
                f"benchmark item {self.benchmark!r} only takes "
                f"quick/engine/claims (its script owns its parameters)")
        object.__setattr__(
            self, "claims",
            tuple(c if isinstance(c, ClaimSpec) else ClaimSpec.from_dict(c)
                  for c in self.claims))

    @property
    def name(self) -> str:
        if self.label:
            return self.label
        if self.experiment:
            return self.experiment
        if self.benchmark:
            return self.benchmark
        return self.spec.get("name", "inline")

    @property
    def kind(self) -> str:
        return "benchmark" if self.benchmark else "experiment"

    @classmethod
    def from_dict(cls, d: Mapping[str, Any],
                  defaults: Mapping[str, Any] | None = None) -> "SuiteItem":
        merged: dict[str, Any] = dict(defaults or {})
        merged.update(d)
        known = {f.name for f in dataclasses.fields(cls)}
        unknown = set(merged) - known
        if unknown:
            raise KeyError(f"unknown suite item fields: {sorted(unknown)}")
        if "claims" in merged:
            merged["claims"] = tuple(merged["claims"])
        return cls(**merged)


@dataclasses.dataclass(frozen=True)
class SuiteSpec:
    """A parsed suite file."""

    name: str
    items: tuple = ()
    description: str = ""
    register: tuple = ("benchmarks.run",)
    defaults: dict = dataclasses.field(default_factory=dict)

    def __post_init__(self) -> None:
        object.__setattr__(
            self, "items",
            tuple(i if isinstance(i, SuiteItem)
                  else SuiteItem.from_dict(i, self.defaults)
                  for i in self.items))
        object.__setattr__(self, "register", tuple(self.register))

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "SuiteSpec":
        known = {"suite", "name", "items", "experiments", "description",
                 "register", "defaults"}
        unknown = set(d) - known
        if unknown:
            raise KeyError(f"unknown suite fields: {sorted(unknown)}")
        name = d.get("suite") or d.get("name")
        if not name:
            raise KeyError("suite file needs a 'suite' (or 'name') field")
        items = d.get("items", d.get("experiments", ()))
        return cls(name=str(name), items=tuple(items),
                   description=str(d.get("description", "")),
                   register=tuple(d.get("register", ("benchmarks.run",))),
                   defaults=dict(d.get("defaults", {})))

    @classmethod
    def from_file(cls, path: str | Path) -> "SuiteSpec":
        path = Path(path)
        text = path.read_text()
        if path.suffix in (".yaml", ".yml"):
            try:
                import yaml
            except ImportError as e:   # pragma: no cover - yaml is baked in
                raise RuntimeError(
                    f"{path}: YAML suite files need PyYAML; rewrite the "
                    f"suite as .json or install pyyaml") from e
            data = yaml.safe_load(text)
        else:
            data = json.loads(text)
        if not isinstance(data, Mapping):
            raise ValueError(f"{path}: suite file must be a mapping")
        return cls.from_dict(data)

    def ensure_registered(self) -> None:
        """Import the modules that register the suite's experiments and
        benchmarks, calling their registration hook (``_import_benchmarks``
        or ``register_all``) when they have one — ``benchmarks.run``
        registers lazily, not at import time.  Best effort per module; a
        missing registration surfaces loudly at item lookup."""
        import importlib
        for name in self.register:
            try:
                mod = importlib.import_module(name)
            except ImportError:
                continue
            for hook_name in ("_import_benchmarks", "register_all"):
                hook = getattr(mod, hook_name, None)
                if callable(hook):
                    hook()
                    break


def evaluate_claims(item: SuiteItem, table, payload) -> list[dict]:
    """Evaluate every claim of one item -> list of result dicts."""
    return [c.evaluate(table, payload) for c in item.claims]
