"""``python -m repro.store`` -> the ``repro-store`` CLI."""

import sys

from .cli import main

sys.exit(main())
