"""Queryable result store + declarative scenario suites.

The ops layer over :mod:`repro.experiments`: every run lands as an
immutable, content-addressed :class:`RunRecord` in the on-disk
:class:`ResultStore` (provenance, engine fingerprints, per-cell results),
suite files collect :class:`~repro.experiments.spec.ExperimentSpec`s with
expected-claim asserts (:class:`SuiteSpec` / :class:`ClaimSpec`), and the
``repro-store`` CLI (``python -m repro.store``) lists / shows / diffs /
garbage-collects records and gates suite runs against committed baselines.

The suite *runner* lives with the experiment runner:
:func:`repro.experiments.runner.run_suite`.
"""

from .record import (STORE_SCHEMA_VERSION, RunRecord, canonical_json,
                     content_hash)
from .store import (Diff, ResultStore, default_store_dir, diff_records,
                    gc_cache, is_timing_key)
from .suite import ClaimSpec, SuiteItem, SuiteSpec, evaluate_claims

__all__ = [
    "STORE_SCHEMA_VERSION",
    "RunRecord",
    "canonical_json",
    "content_hash",
    "Diff",
    "ResultStore",
    "default_store_dir",
    "diff_records",
    "gc_cache",
    "is_timing_key",
    "ClaimSpec",
    "SuiteItem",
    "SuiteSpec",
    "evaluate_claims",
]
