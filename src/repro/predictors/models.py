"""The generative predictor family.

  * ``oracle(r, p)``      — the legacy stamping, bit-for-bit: each fault is
                            predicted with probability r, false alarms come
                            from one renewal stream of mean p·mu/(r(1-p));
  * ``lead_time(r, p)``   — predictions arrive a *sampled* lead before the
                            event: every announcement carries a per-event
                            prediction window I ~ ``lead_dist`` (the fault
                            materializes in [t, t+I], arXiv:1302.4558's
                            C_p-lead assumption), generalizing the
                            scenario-constant ``window=I`` stamping;
                            announcements whose lead falls below
                            ``min_lead`` are useless (no time to fit C_p)
                            and are reclassified as unpredicted faults —
                            the recall adjustment of paper §2.2;
  * ``drifting(r, p)``    — predictor quality drifts linearly over the run
                            from the nominal (r, p) to
                            (``recall_end``, ``precision_end``): per-fault
                            prediction probability r(t), false alarms from
                            a thinned non-homogeneous Poisson stream of
                            rate r(t)(1-p(t))/(p(t)·mu);
  * ``bursty(r, p)``      — correlated false alarms: false predictions
                            arrive in bursts (Poisson burst starts,
                            geometric burst sizes of mean ``burst_size``,
                            ``burst_gap``-spaced members) with the *same
                            long-run false rate* as the oracle, so nominal
                            precision is preserved while alarms cluster.

All models draw exclusively from the trace RNG they are handed, so trace
banks remain reproducible per (seed, scenario).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Mapping

import numpy as np

from repro.core.traces import (FAULT_PRED, FAULT_UNPRED, Distribution,
                               Exponential, renewal_trace,
                               renewal_trace_bank)

from .base import PredictionStream, PredictorModel, register_predictor

__all__ = [
    "OraclePredictor",
    "LeadTimePredictor",
    "DriftingPredictor",
    "BurstyPredictor",
]


def _false_mean(recall: float, precision: float, mu: float) -> float:
    """Mean time between false predictions: p·mu / (r·(1-p)) (paper §2.3)."""
    return precision * mu / (recall * (1.0 - precision))


@dataclasses.dataclass(frozen=True)
class OraclePredictor(PredictorModel):
    """The paper's stamped predictor, extracted from ``make_event_trace``.

    Reproduces the legacy trace generation **bit-for-bit** for any fixed
    (r, p): the same RNG draws in the same order (per-fault flags, then the
    false-alarm renewal stream), pinned by a regression test.
    """

    recall: float
    precision: float

    def _false_stream(self, mu: float, horizon: float,
                      rng: np.random.Generator,
                      false_dist: Distribution) -> np.ndarray:
        if self.recall > 0.0 and self.precision < 1.0:
            mean_false = _false_mean(self.recall, self.precision, mu)
            return renewal_trace(false_dist.rescaled(mean_false), horizon,
                                 rng)
        return np.empty(0, dtype=np.float64)

    def predict(self, faults: np.ndarray, *, mu: float, horizon: float,
                rng: np.random.Generator,
                false_dist: Distribution) -> PredictionStream:
        predicted = rng.random(faults.size) < self.recall
        kinds = np.where(predicted, FAULT_PRED, FAULT_UNPRED).astype(np.int8)
        false_preds = self._false_stream(mu, horizon, rng, false_dist)
        return PredictionStream(kinds, false_preds)

    def predict_bank(self, fault_bank, *, mu: float, horizon: float,
                     rng: np.random.Generator,
                     false_dist: Distribution) -> list[PredictionStream]:
        # The vectorized bank draw order of the legacy
        # make_event_trace_bank: one flags wave for every fault of the
        # bank, then one shared false-alarm bank.
        sizes = np.array([f.size for f in fault_bank])
        flags = rng.random(int(sizes.sum())) < self.recall
        kind_bank = [np.where(part, FAULT_PRED, FAULT_UNPRED).astype(np.int8)
                     for part in np.split(flags, np.cumsum(sizes)[:-1])]
        n_traces = len(fault_bank)
        if self.recall > 0.0 and self.precision < 1.0:
            mean_false = _false_mean(self.recall, self.precision, mu)
            false_bank = renewal_trace_bank(false_dist.rescaled(mean_false),
                                            horizon, rng, n_traces)
        else:
            false_bank = [np.empty(0, dtype=np.float64)] * n_traces
        return [PredictionStream(k, fp)
                for k, fp in zip(kind_bank, false_bank)]


@register_predictor("oracle")
def _oracle(recall: float, precision: float) -> OraclePredictor:
    return OraclePredictor(recall, precision)


def _build_lead_dist(spec: Any, mean: float) -> Distribution:
    """Build a lead-length distribution from a (name, params) mapping,
    rescaled to ``mean``.  Resolved through the experiment registry lazily
    so the predictor package stays import-cycle-free."""
    from repro.experiments.spec import DistributionSpec
    if spec is None:
        spec = {"name": "exponential"}
    if not isinstance(spec, DistributionSpec):
        spec = DistributionSpec.from_dict(dict(spec))
    return spec.build().rescaled(mean)


@dataclasses.dataclass(frozen=True)
class LeadTimePredictor(PredictorModel):
    """Predictions arrive a sampled lead before the event.

    Each announcement (true or false) carries a per-event prediction
    window I drawn from ``lead_dist`` (rescaled to ``lead_mean``): the
    predictor fires I seconds of notice ahead of the (eventual) fault, so
    the announcement promises the interval [t, t+I] and the simulator
    materializes the true fault inside it — the window machinery's
    C_p-lead assumption, with *heterogeneous* windows the constant
    ``ScenarioSpec.window`` stamping cannot express.

    True predictions whose sampled lead is below ``min_lead`` (typically
    C_p) give the platform no time to act; per paper §2.2 they are
    reclassified as unpredicted faults, so the *effective* recall is
    r·P(I >= min_lead) < r — which an online estimator can discover and
    an adaptive strategy re-plan on.
    """

    recall: float
    precision: float
    lead_mean: float = 3600.0
    lead_dist: Any = None        # (name, params) mapping; default exponential
    min_lead: float = 0.0

    def predict(self, faults: np.ndarray, *, mu: float, horizon: float,
                rng: np.random.Generator,
                false_dist: Distribution) -> PredictionStream:
        oracle = OraclePredictor(self.recall, self.precision)
        base = oracle.predict(faults, mu=mu, horizon=horizon, rng=rng,
                              false_dist=false_dist)
        dist = _build_lead_dist(self.lead_dist, self.lead_mean)
        kinds = base.kinds.copy()
        true_windows = np.zeros(faults.size, dtype=np.float64)
        pred_idx = np.flatnonzero(kinds == FAULT_PRED)
        if pred_idx.size:
            leads = dist.sample(rng, pred_idx.size)
            usable = leads >= self.min_lead
            true_windows[pred_idx[usable]] = leads[usable]
            # Lead too short to fit C_p: the paper's recall adjustment.
            kinds[pred_idx[~usable]] = FAULT_UNPRED
        false_windows = np.empty(0, dtype=np.float64)
        if base.false_times.size:
            false_windows = dist.sample(rng, base.false_times.size)
        return PredictionStream(kinds, base.false_times,
                                true_windows=true_windows,
                                false_windows=false_windows)


@register_predictor("lead_time")
def _lead_time(recall: float, precision: float, lead_mean: float = 3600.0,
               lead_dist: Mapping | None = None,
               min_lead: float = 0.0) -> LeadTimePredictor:
    return LeadTimePredictor(recall, precision, lead_mean=lead_mean,
                             lead_dist=None if lead_dist is None
                             else dict(lead_dist), min_lead=min_lead)


@dataclasses.dataclass(frozen=True)
class DriftingPredictor(PredictorModel):
    """Predictor quality drifts linearly over the run.

    Recall moves from the nominal r to ``recall_end`` (precision
    likewise) along the drift ramp: flat at the nominal value until
    ``drift_start`` (trace time, seconds), then linear over ``drift_span``
    seconds (default: the rest of the trace horizon), then flat at the end
    value.  Each fault at date t is predicted with probability r(t), and
    false alarms follow a non-homogeneous Poisson process of rate
    lambda(t) = r(t)·(1-p(t)) / (p(t)·mu) — the instantaneous analogue of
    the oracle's false-alarm rate — realized by thinning a homogeneous
    candidate stream at the peak rate.  (The ``false_pred_dist`` family is
    ignored: a drifting rate needs the memoryless construction.)

    Scenario traces start ``ScenarioSpec.start`` seconds into the trace,
    so a drift meant to unfold *during* the job should set
    ``drift_start`` near the scenario's start and ``drift_span`` to a few
    ``time_base``.
    """

    recall: float
    precision: float
    recall_end: float | None = None
    precision_end: float | None = None
    drift_start: float = 0.0
    drift_span: float | None = None

    def _frac(self, t: np.ndarray, horizon: float) -> np.ndarray:
        span = self.drift_span if self.drift_span is not None \
            else max(horizon - self.drift_start, 1e-9)
        return np.clip((t - self.drift_start) / span, 0.0, 1.0)

    def _r_at(self, t: np.ndarray, horizon: float) -> np.ndarray:
        r1 = self.recall if self.recall_end is None else self.recall_end
        return self.recall + (r1 - self.recall) * self._frac(t, horizon)

    def _p_at(self, t: np.ndarray, horizon: float) -> np.ndarray:
        p1 = self.precision if self.precision_end is None \
            else self.precision_end
        return self.precision + (p1 - self.precision) * self._frac(t, horizon)

    def _false_rate(self, t: np.ndarray, horizon: float,
                    mu: float) -> np.ndarray:
        r = np.clip(self._r_at(t, horizon), 0.0, 1.0)
        p = np.clip(self._p_at(t, horizon), 1e-3, 1.0)
        return r * (1.0 - p) / (p * mu)

    def predict(self, faults: np.ndarray, *, mu: float, horizon: float,
                rng: np.random.Generator,
                false_dist: Distribution) -> PredictionStream:
        r_t = np.clip(self._r_at(faults, horizon), 0.0, 1.0)
        predicted = rng.random(faults.size) < r_t
        kinds = np.where(predicted, FAULT_PRED, FAULT_UNPRED).astype(np.int8)

        # Thinning bound on the false-alarm rate.  r(1-p)/p can peak
        # *inside* the ramp (not at its endpoints), so sample the ramp
        # densely in ramp-fraction space — where the rate is smooth with
        # mild curvature — and pad the grid maximum; acceptance
        # probabilities then never exceed 1.
        span = self.drift_span if self.drift_span is not None \
            else max(horizon - self.drift_start, 1e-9)
        ramp = self.drift_start + span * np.linspace(0.0, 1.0, 1025)
        grid = np.concatenate([np.linspace(0.0, horizon, 17), ramp])
        lam_max = 1.05 * float(self._false_rate(grid, horizon, mu).max())
        if lam_max <= 0.0:
            return PredictionStream(kinds, np.empty(0, dtype=np.float64))
        cand = np.cumsum(rng.exponential(
            1.0 / lam_max, max(16, int(horizon * lam_max * 1.5) + 8)))
        while cand.size and cand[-1] < horizon:
            cand = np.concatenate([
                cand, cand[-1] + np.cumsum(rng.exponential(
                    1.0 / lam_max, max(16, cand.size // 2)))])
        cand = cand[cand < horizon]
        keep = rng.random(cand.size) < (
            self._false_rate(cand, horizon, mu) / lam_max)
        return PredictionStream(kinds, cand[keep])


@register_predictor("drifting")
def _drifting(recall: float, precision: float,
              recall_end: float | None = None,
              precision_end: float | None = None,
              drift_start: float = 0.0,
              drift_span: float | None = None) -> DriftingPredictor:
    return DriftingPredictor(recall, precision, recall_end=recall_end,
                             precision_end=precision_end,
                             drift_start=drift_start, drift_span=drift_span)


@dataclasses.dataclass(frozen=True)
class BurstyPredictor(PredictorModel):
    """Correlated false alarms: one root cause fires a burst of them.

    Burst starts follow a Poisson process of rate lambda_f / burst_size
    (lambda_f = the oracle's false-alarm rate), each burst holds a
    Geometric(1/burst_size) number of alarms (mean ``burst_size``) spaced
    by Exponential(``burst_gap``) gaps — so the long-run false-alarm rate,
    and hence the nominal precision, matches the oracle while the alarms
    cluster.  Clustered false alarms stress trust policies: a burst landing
    late in a period triggers several proactive checkpoints back to back.
    """

    recall: float
    precision: float
    burst_size: float = 4.0
    burst_gap: float = 900.0

    def predict(self, faults: np.ndarray, *, mu: float, horizon: float,
                rng: np.random.Generator,
                false_dist: Distribution) -> PredictionStream:
        predicted = rng.random(faults.size) < self.recall
        kinds = np.where(predicted, FAULT_PRED, FAULT_UNPRED).astype(np.int8)
        if not (self.recall > 0.0 and self.precision < 1.0):
            return PredictionStream(kinds, np.empty(0, dtype=np.float64))
        if self.burst_size < 1.0:
            raise ValueError(f"burst_size must be >= 1, got {self.burst_size}")
        mean_false = _false_mean(self.recall, self.precision, mu)
        starts = renewal_trace(Exponential(mean_false * self.burst_size),
                               horizon, rng)
        if starts.size == 0:
            return PredictionStream(kinds, np.empty(0, dtype=np.float64))
        counts = rng.geometric(1.0 / self.burst_size, starts.size)
        extra = counts - 1
        times = starts
        n_extra = int(extra.sum())
        if n_extra:
            # Offsets within each burst: cumulative gaps restarted per
            # burst (segmented cumsum over the flat gap array).
            gaps = rng.exponential(self.burst_gap, n_extra)
            owner = np.repeat(np.arange(starts.size), extra)
            csum = np.cumsum(gaps)
            first = np.concatenate([[0], np.cumsum(extra)[:-1]])
            before = np.concatenate([[0.0], csum])[first]  # gaps before burst
            offsets = csum - before[owner]
            times = np.concatenate([starts, starts[owner] + offsets])
        times = np.sort(times[times < horizon])
        return PredictionStream(kinds, times)


@register_predictor("bursty")
def _bursty(recall: float, precision: float, burst_size: float = 4.0,
            burst_gap: float = 900.0) -> BurstyPredictor:
    return BurstyPredictor(recall, precision, burst_size=burst_size,
                           burst_gap=burst_gap)
