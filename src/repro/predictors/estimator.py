"""Online (r, p) estimation and adaptive re-planning.

The paper's optimal policy needs the predictor's recall r and precision p
to pick the period T* and the trust breakpoint beta_lim = C_p/p — but as
Aupy et al. stress (arXiv:1207.6936 §5), r and p are not oracles: they
must be *estimated online* from the prediction stream.  This module holds
the two pieces:

  * :class:`OnlineRPEstimator` — running (r-hat, p-hat) from the observed
    stream of confirmed / false predictions and predicted / unpredicted
    faults, with a **confidence gate**: the estimates are not trusted until
    enough predictions *and* faults have been observed (a handful of
    events says nothing about a ratio).
  * :class:`AdaptiveConfig` — the declarative knob set for the ``adaptive``
    strategy: both simulation engines keep exactly this estimator per
    lane (scalar locals in ``simulate``, SoA arrays in the lane engine)
    and re-plan (T*, trust threshold) through :meth:`AdaptiveConfig.plan`
    whenever the gated estimates drift more than ``tol`` from the values
    last planned on — the hysteresis that keeps the checkpoint cadence
    from thrashing (the waste curve is flat near its minimum).

Estimator semantics in the engines: a prediction's outcome is observed at
announcement (the simulator knows whether it will materialize; a real
system learns it when the prediction window closes — a lead of at most one
window that the gate's minimum counts make irrelevant), and every
unpredicted fault is observed when it strikes.  Counts are plain integers,
so the two engines produce **bit-for-bit identical** estimates, replan
points and plans.

The replan math itself is :func:`maybe_replan` — a pure function shared by
both engines (the lane engine pre-filters lanes vectorized with the same
integer/float operations, then confirms per lane through this function).
"""

from __future__ import annotations

import dataclasses
import math

from repro.core.prediction import (PredictedPlatform, Predictor, beta_lim,
                                   optimal_period_with_prediction)
from repro.core.waste import Platform

__all__ = [
    "P_HAT_MIN",
    "AdaptiveConfig",
    "OnlineRPEstimator",
    "estimate_recall",
    "estimate_precision",
    "maybe_replan",
]

# Precision estimate floor: p-hat = 0 (no prediction ever confirmed) would
# put beta_lim at infinity and break the Predictor domain; a tiny positive
# floor keeps the plan finite ("never worth trusting") instead.
P_HAT_MIN = 1e-3


def decay_factor(halflife: float | None) -> float:
    """Per-observation decay of the windowed (EW) estimator counters.

    ``halflife`` is measured in observations: after that many further
    events an old observation's weight has halved.  ``None`` (the legacy
    cumulative estimator) decays nothing.
    """
    return 1.0 if halflife is None else 0.5 ** (1.0 / halflife)


def estimate_recall(n_true_pred: float, n_unpred_faults: float) -> float:
    """r-hat = predicted faults / all faults (every true prediction is one
    predicted fault)."""
    return n_true_pred / (n_true_pred + n_unpred_faults)


def estimate_precision(n_true_pred: float, n_false_pred: float) -> float:
    """p-hat = confirmed predictions / all predictions, floored at
    :data:`P_HAT_MIN`."""
    p = n_true_pred / (n_true_pred + n_false_pred)
    return p if p >= P_HAT_MIN else P_HAT_MIN


@dataclasses.dataclass(frozen=True)
class AdaptiveConfig:
    """Knobs of the adaptive re-planning strategy (engine-agnostic).

    ``prior_recall`` / ``prior_precision`` are the (possibly stale) values
    the initial plan was computed from — they seed the hysteresis baseline,
    so the first replan fires as soon as the gated estimates leave the
    ``tol``-box around the prior.  ``min_preds`` / ``min_faults`` is the
    confidence gate; ``tol`` the re-plan hysteresis (absolute, on both
    estimates).  ``model_order`` selects the analysis each re-plan solves:
    the paper's first-order model (default) or the exact-Exponential
    renewal analysis of :mod:`repro.core.exact`.

    ``estimate_mu`` additionally estimates the platform MTBF online (the
    EW mean of observed fault inter-arrival gaps, mirroring
    ``ft/estimator.py``) and re-plans on the estimated mu instead of the
    assumed ``platform.mu`` — the same hysteresis applies, *relative* for
    mu (``|mu_hat - planned_mu| > tol * planned_mu``) because mu is not a
    ratio in [0, 1].
    """

    prior_recall: float
    prior_precision: float
    min_preds: int = 32
    min_faults: int = 16
    tol: float = 0.05
    model_order: str = "first"
    halflife: float | None = None
    estimate_mu: bool = False

    def __post_init__(self) -> None:
        if self.min_preds < 1 or self.min_faults < 1:
            raise ValueError("confidence gate needs min_preds/min_faults >= 1")
        if self.tol <= 0.0:
            raise ValueError(f"tol must be positive, got {self.tol}")
        if self.model_order not in ("first", "exact"):
            raise ValueError(f"model_order must be 'first' or 'exact', "
                             f"got {self.model_order!r}")
        if self.halflife is not None:
            if self.halflife <= 0.0:
                raise ValueError(f"halflife must be positive, "
                                 f"got {self.halflife}")
            # The decayed counters converge to sum(decay^k) = 1/(1 - decay)
            # ~= 1.44 * halflife: a gate above that ceiling never opens.
            ceiling = 1.0 / (1.0 - decay_factor(self.halflife))
            if min(self.min_preds, self.min_faults) > ceiling:
                raise ValueError(
                    f"halflife {self.halflife} caps the effective counts at "
                    f"~{ceiling:.1f}; the gate (min_preds={self.min_preds}, "
                    f"min_faults={self.min_faults}) would never open")

    def plan(self, platform: Platform, cp: float, recall: float,
             precision: float, mu: float | None = None) -> tuple[float, float]:
        """(period, trust threshold) of the model-optimal plan at (r, p).

        The threshold is the trust breakpoint when the acting branch wins
        (beta_lim = C_p/p at first order, its numeric analogue for the
        exact model) and +inf when the predictor is analytically not worth
        using (never trust).  ``mu`` (if given) overrides the platform MTBF
        with the online estimate.
        """
        if mu is not None:
            platform = dataclasses.replace(platform, mu=float(mu))
        pp = PredictedPlatform(platform, Predictor(recall, precision), cp)
        if self.model_order == "exact":
            from repro.core.exact import optimal_period_exact
            ep = optimal_period_exact(pp)
            t, thr = ep.period, (ep.threshold if ep.use_predictions
                                 else math.inf)
        else:
            t, _, use = optimal_period_with_prediction(pp)
            thr = beta_lim(pp) if use else math.inf
        # Degenerate-estimate guard: a plan with T <= C makes no forward
        # progress (W = T - C <= 0); floor the period so one checkpoint
        # plus a proactive-checkpoint's worth of work always fits.
        return max(float(t), platform.c + cp), thr

    def key(self) -> tuple:
        """Value-semantics tuple for result-cache candidate keys."""
        return (self.prior_recall, self.prior_precision, self.min_preds,
                self.min_faults, self.tol, self.halflife, self.model_order,
                self.estimate_mu)

    @property
    def decay(self) -> float:
        """Per-observation counter decay factor (1.0 = cumulative)."""
        return decay_factor(self.halflife)


def maybe_replan(cfg: AdaptiveConfig, platform: Platform, cp: float,
                 n_true_pred: float, n_false_pred: float,
                 n_unpred_faults: float,
                 planned_recall: float, planned_precision: float,
                 mu_hat: float | None = None,
                 planned_mu: float | None = None,
                 ) -> tuple[float, float, float, float] | None:
    """One estimator observation step, shared by both engines.

    Called after a counter update; returns ``None`` (keep the current
    plan: gate not passed, or estimates still inside the hysteresis box)
    or ``(r_hat, p_hat, period, threshold)`` for a re-plan.

    ``mu_hat`` / ``planned_mu`` (``estimate_mu`` configs only) widen the
    hysteresis box with a relative-mu axis: a large enough MTBF drift
    triggers a re-plan even when (r-hat, p-hat) sit still, and every
    re-plan is solved at the estimated mu.
    """
    if n_true_pred + n_false_pred < cfg.min_preds:
        return None
    if n_true_pred + n_unpred_faults < cfg.min_faults:
        return None
    r_hat = estimate_recall(n_true_pred, n_unpred_faults)
    p_hat = estimate_precision(n_true_pred, n_false_pred)
    mu_moved = (mu_hat is not None and planned_mu is not None
                and abs(mu_hat - planned_mu) > cfg.tol * planned_mu)
    if abs(r_hat - planned_recall) <= cfg.tol \
            and abs(p_hat - planned_precision) <= cfg.tol \
            and not mu_moved:
        return None
    period, threshold = cfg.plan(platform, cp, r_hat, p_hat, mu=mu_hat)
    return r_hat, p_hat, period, threshold


class OnlineRPEstimator:
    """Standalone running (r-hat, p-hat) estimator over an event feed.

    The user-facing counterpart of the per-lane counters the engines
    carry: feed it prediction outcomes and fault observations in event
    order, read the gated estimates back.  Used by the runtime layer and
    the examples; the engines inline the same integer counters for
    bit-for-bit scalar/batch parity.

    ``halflife`` turns the cumulative counters into exponentially-weighted
    ones (decayed by :func:`decay_factor` before every observation), so the
    estimates track a *drifting* predictor instead of converging to the
    stale all-time average — at the cost of capping the effective counts at
    ~1.44 * halflife (size the gate below that).
    """

    def __init__(self, *, min_preds: int = 32, min_faults: int = 16,
                 halflife: float | None = None) -> None:
        self.min_preds = min_preds
        self.min_faults = min_faults
        self.halflife = halflife
        self._decay = decay_factor(halflife)
        self.n_true_pred: float = 0
        self.n_false_pred: float = 0
        self.n_unpred_faults: float = 0

    def _age(self) -> None:
        if self._decay != 1.0:
            self.n_true_pred *= self._decay
            self.n_false_pred *= self._decay
            self.n_unpred_faults *= self._decay

    def observe_prediction(self, confirmed: bool) -> None:
        """A prediction whose outcome is known (materialized or not)."""
        self._age()
        if confirmed:
            self.n_true_pred += 1
        else:
            self.n_false_pred += 1

    def observe_fault(self, predicted: bool) -> None:
        """An actual fault; ``predicted`` = a prediction announced it.

        Predicted faults are already counted by their confirmed
        prediction, so only unpredicted ones advance a counter here."""
        if not predicted:
            self._age()
            self.n_unpred_faults += 1

    @property
    def n_predictions(self) -> float:
        return self.n_true_pred + self.n_false_pred

    @property
    def n_faults(self) -> float:
        return self.n_true_pred + self.n_unpred_faults

    @property
    def ready(self) -> bool:
        """The confidence gate: enough predictions *and* faults seen."""
        return self.n_predictions >= self.min_preds \
            and self.n_faults >= self.min_faults

    @property
    def recall(self) -> float | None:
        if self.n_faults == 0:
            return None
        return estimate_recall(self.n_true_pred, self.n_unpred_faults)

    @property
    def precision(self) -> float | None:
        if self.n_predictions == 0:
            return None
        return estimate_precision(self.n_true_pred, self.n_false_pred)
