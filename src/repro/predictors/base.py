"""Predictor subsystem: generative fault-prediction models.

The paper characterizes a fault predictor by two numbers — recall r and
precision p — and the original trace generator *stamped* those numbers onto
ground-truth fault traces (each fault predicted with probability r, false
alarms from one renewal stream).  That makes the predictor itself
invisible: every predictor with the same (r, p) produces statistically
identical traces, so "which predictor?" cannot be a scenario axis.

This package turns the predictor into a first-class generative model: a
:class:`PredictorModel` *consumes* a fault trace and *emits* the prediction
stream — which faults are announced, when the false alarms fire, and what
per-event prediction window (lead) each announcement carries.  The legacy
stamping survives bit-for-bit as the ``oracle`` model
(:class:`repro.predictors.models.OraclePredictor`), and richer models
(lead-time windows, drifting quality, bursty false alarms) slot into the
same :func:`repro.core.traces.make_event_trace` pipeline.

Models are registered by name (``@register_predictor``) so a
:class:`repro.experiments.spec.PredictorSpec` can construct them from JSON,
making the predictor family a sweepable scenario axis.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

import numpy as np

from repro.core.traces import Distribution

__all__ = [
    "PredictionStream",
    "PredictorModel",
    "register_predictor",
    "build_predictor",
    "list_predictors",
]


@dataclasses.dataclass(frozen=True)
class PredictionStream:
    """What a predictor emits for one fault trace.

    ``kinds`` labels every ground-truth fault (``FAULT_PRED`` /
    ``FAULT_UNPRED``), ``false_times`` are the announcement dates of
    predictions that never materialize.  ``true_windows`` (aligned with the
    faults; 0 for unpredicted ones) and ``false_windows`` (aligned with
    ``false_times``) optionally carry per-event prediction-window lengths
    (arXiv:1302.4558): an announcement at date t with window I promises the
    fault inside [t, t+I].  ``None`` means "no model-level windows" — the
    scenario's constant ``window`` stamping (if any) then applies.
    """

    kinds: np.ndarray                       # int8 per fault
    false_times: np.ndarray                 # float64, ascending
    true_windows: np.ndarray | None = None  # float64 per fault
    false_windows: np.ndarray | None = None  # float64 per false prediction


class PredictorModel:
    """Base class: generate the prediction stream for a fault trace.

    ``predict`` consumes the ground-truth fault times of one trace and the
    shared trace RNG; it must draw all its randomness from ``rng`` so trace
    generation stays reproducible per seed.  ``false_dist`` is the
    *family* used for false-alarm inter-arrival times (the scenario's
    ``false_pred_dist`` or, by default, its fault distribution), to be
    rescaled by the model to whatever mean its (r, p) semantics imply.
    """

    def predict(self, faults: np.ndarray, *, mu: float, horizon: float,
                rng: np.random.Generator,
                false_dist: Distribution) -> PredictionStream:
        raise NotImplementedError

    def predict_bank(self, fault_bank: Sequence[np.ndarray], *, mu: float,
                     horizon: float, rng: np.random.Generator,
                     false_dist: Distribution) -> list[PredictionStream]:
        """Prediction streams for a whole trace bank from one generator.

        The default draws per trace sequentially from the shared stream
        (statistically identical to per-trace generation; bank draws are
        documented as reproducible per (seed, n_traces), not per index).
        The oracle overrides this with the vectorized bank draw order so
        legacy batched banks stay bit-for-bit.
        """
        return [self.predict(f, mu=mu, horizon=horizon, rng=rng,
                             false_dist=false_dist) for f in fault_bank]


# ---------------------------------------------------------------------------
# Registry (mirrors the strategy / distribution registries)
# ---------------------------------------------------------------------------

_MODELS: dict[str, Callable[..., PredictorModel]] = {}


def register_predictor(name: str):
    """Register ``factory(recall, precision, **params) -> PredictorModel``."""
    def wrap(factory: Callable[..., PredictorModel]) -> Callable[..., PredictorModel]:
        if name in _MODELS:
            raise ValueError(f"predictor {name!r} already registered")
        _MODELS[name] = factory
        return factory
    return wrap


def build_predictor(name: str, recall: float, precision: float,
                    **params) -> PredictorModel:
    """Build a registered predictor at the scenario's nominal (r, p)."""
    if name not in _MODELS:
        raise KeyError(f"unknown predictor {name!r}; "
                       f"registered: {sorted(_MODELS)}")
    return _MODELS[name](recall, precision, **params)


def list_predictors() -> list[str]:
    return sorted(_MODELS)
