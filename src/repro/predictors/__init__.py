"""Generative fault-prediction models, online (r, p) estimation, adaptive
re-planning.

Instead of stamping a fixed (recall, precision) onto ground-truth fault
traces, a :class:`PredictorModel` consumes the fault stream and *emits*
the prediction stream — so "which predictor?" becomes a scenario axis::

    from repro.experiments import PredictorSpec, ScenarioSpec

    sc = ScenarioSpec(predictor=PredictorSpec("drifting",
                                              {"precision_end": 0.3}))
    traces = sc.make_traces()          # predictions degrade over the run

Registered models: ``oracle`` (the legacy stamping, bit-for-bit),
``lead_time`` (sampled per-event prediction windows / lead times),
``drifting`` (recall/precision drift linearly over the run), ``bursty``
(correlated false alarms).  On the consumption side,
:class:`OnlineRPEstimator` tracks (r-hat, p-hat) from observed outcomes
behind a confidence gate, and :class:`AdaptiveConfig` drives the
``adaptive`` strategy that re-plans (T*, beta_lim) inside both simulation
engines as the estimates drift.
"""

from .base import (PredictionStream, PredictorModel, build_predictor,
                   list_predictors, register_predictor)
from .estimator import (AdaptiveConfig, OnlineRPEstimator, estimate_precision,
                        estimate_recall, maybe_replan)
from .models import (BurstyPredictor, DriftingPredictor, LeadTimePredictor,
                     OraclePredictor)

__all__ = [
    "PredictionStream",
    "PredictorModel",
    "register_predictor",
    "build_predictor",
    "list_predictors",
    "OraclePredictor",
    "LeadTimePredictor",
    "DriftingPredictor",
    "BurstyPredictor",
    "AdaptiveConfig",
    "OnlineRPEstimator",
    "estimate_recall",
    "estimate_precision",
    "maybe_replan",
]
