import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) pair.

Proves the distribution config is coherent without hardware: 512 placeholder
CPU devices host the production meshes (16x16 single-pod, 2x16x16 multi-pod);
every pair's step function must ``.lower().compile()`` under its sharding
spec.  The compiled artifacts yield ``memory_analysis()`` (does it fit 16 GB
HBM?) and ``cost_analysis()`` + collective parsing (the §Roofline terms).

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all \
        --mesh both --out dryrun_results.json

NOTE: the XLA_FLAGS line above MUST run before any other import — jax locks
the device count on first initialization.
"""

import argparse
import json
import time
import traceback

import jax

from repro.configs import REGISTRY, SHAPES, get, skip_reason
from repro.launch import hlo
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import lower_step


def model_flops(cfg, shape) -> float:
    """Useful FLOPs per step: 6*N*D train, 2*N*D prefill, 2*N*B decode."""
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        return 6.0 * n_active * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n_active * shape.global_batch * shape.seq_len
    return 2.0 * n_active * shape.global_batch  # decode: one token per seq


def run_pair(cfg, shape, mesh, mesh_name: str, rules=None) -> dict:
    t0 = time.time()
    pair = lower_step(cfg, shape, mesh, compile_now=True, rules=rules)
    compiled = pair.compiled
    terms = hlo.roofline_terms(
        compiled, arch=cfg.name, shape=shape.name, mesh_name=mesh_name,
        n_devices=mesh.devices.size, model_flops=model_flops(cfg, shape))
    mem = compiled.memory_analysis()
    return {
        "arch": cfg.name, "shape": shape.name, "mesh": mesh_name,
        "status": "ok",
        "compile_s": round(time.time() - t0, 1),
        "bytes_per_device": terms.bytes_per_device,
        "fits_hbm": terms.bytes_per_device <= hlo.V5E.hbm_bytes,
        "hlo_flops_per_dev": terms.hlo_flops,
        "hlo_bytes_per_dev": terms.hlo_bytes,
        "coll_bytes_per_dev": terms.coll_bytes,
        "n_collectives": terms.n_collectives,
        "t_compute_s": terms.t_compute,
        "t_memory_s": terms.t_memory,
        "t_collective_s": terms.t_collective,
        "dominant": terms.dominant,
        "model_flops": terms.model_flops,
        "useful_flops_ratio": terms.useful_flops_ratio,
        "memory_analysis": {
            "temp": getattr(mem, "temp_size_in_bytes", None),
            "args": getattr(mem, "argument_size_in_bytes", None),
            "output": getattr(mem, "output_size_in_bytes", None),
            "alias": getattr(mem, "alias_size_in_bytes", None),
        },
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default="dryrun_results.json")
    ap.add_argument("--append", action="store_true",
                    help="merge results into --out instead of overwriting")
    ap.add_argument("--set", action="append", default=[],
                    help="cfg override key=value (python literal)")
    ap.add_argument("--rules", default=None,
                    choices=[None, "seq_parallel"])
    ap.add_argument("--tag", default=None)
    args = ap.parse_args()

    import ast
    import dataclasses as _dc
    overrides = {}
    for kv in args.set:
        k, v = kv.split("=", 1)
        try:
            overrides[k] = ast.literal_eval(v)
        except (ValueError, SyntaxError):
            overrides[k] = v
    rules = None
    if args.rules == "seq_parallel":
        from repro.parallel.sharding import SEQ_PARALLEL_RULES
        rules = SEQ_PARALLEL_RULES

    assert jax.device_count() == 512, (
        f"expected 512 placeholder devices, got {jax.device_count()}")

    archs = list(REGISTRY) if args.arch == "all" else args.arch.split(",")
    shapes = list(SHAPES) if args.shape == "all" else args.shape.split(",")
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    results = []
    if args.append and os.path.exists(args.out):
        results = json.load(open(args.out))
    done = {(r["arch"], r["shape"], r["mesh"], r.get("tag"))
            for r in results}

    for multi in meshes:
        mesh = make_production_mesh(multi_pod=multi)
        mesh_name = "2x16x16" if multi else "16x16"
        for arch in archs:
            cfg = get(arch)
            for shape_name in shapes:
                shape = SHAPES[shape_name]
                if (arch, shape_name, mesh_name, args.tag) in done:
                    continue
                reason = skip_reason(cfg, shape)
                if reason:
                    results.append({"arch": arch, "shape": shape_name,
                                    "mesh": mesh_name, "status": "skipped",
                                    "reason": reason})
                    print(f"[skip] {arch} x {shape_name}: {reason}",
                          flush=True)
                    continue
                print(f"[lower] {arch} x {shape_name} on {mesh_name} "
                      f"{'(' + args.tag + ')' if args.tag else ''}...",
                      flush=True)
                try:
                    cfg_run = (cfg if not overrides
                               else _dc.replace(cfg, **overrides))
                    row = run_pair(cfg_run, shape, mesh, mesh_name,
                                   rules=rules)
                    if args.tag:
                        row["tag"] = args.tag
                    print(f"  ok: {row['compile_s']}s compile, "
                          f"{row['bytes_per_device']/1e9:.2f} GB/dev, "
                          f"dominant={row['dominant']}", flush=True)
                except Exception as e:  # noqa: BLE001 - report and continue
                    row = {"arch": arch, "shape": shape_name,
                           "mesh": mesh_name, "status": "error",
                           "error": f"{type(e).__name__}: {e}",
                           "traceback": traceback.format_exc()[-2000:]}
                    print(f"  ERROR: {type(e).__name__}: {str(e)[:200]}",
                          flush=True)
                results.append(row)
                json.dump(results, open(args.out, "w"), indent=1)

    ok = sum(r["status"] == "ok" for r in results)
    skip = sum(r["status"] == "skipped" for r in results)
    err = sum(r["status"] == "error" for r in results)
    print(f"\ndry-run complete: {ok} ok, {skip} skipped, {err} errors "
          f"-> {args.out}")
    if err:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
