"""HLO post-processing: collective-traffic and roofline-term extraction.

``compiled.cost_analysis()`` reports FLOPs and bytes accessed but not
collective traffic, so we parse the optimized HLO text: every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute
op's *result* shape (per-device bytes after SPMD partitioning) is summed.
This is the per-device traffic estimate feeding the collective roofline term.

Hardware constants are TPU v5e (the assignment's target): 197 TFLOP/s bf16,
819 GB/s HBM, ~50 GB/s per ICI link; the "pod" axis of the multi-pod mesh
rides DCN at ~6.25 GB/s effective per host.
"""

from __future__ import annotations

import dataclasses
import re

__all__ = ["V5E", "CollectiveStats", "collective_bytes", "RooflineTerms",
           "roofline_terms", "parse_memory_analysis"]

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# e.g.  %all-gather.3 = bf16[16,4096,1024]{2,1,0} all-gather(...)
_OP_RE = re.compile(
    r"=\s*(?:\(([^)]*)\)|(\w+\[[\d,]*\])(?:\{[^}]*\})?)\s+"
    r"(" + "|".join(_COLLECTIVES) + r")[\s(.]")
_SHAPE_RE = re.compile(r"(\w+?)\[([\d,]*)\]")


@dataclasses.dataclass(frozen=True)
class Hardware:
    name: str
    flops_bf16: float        # per chip
    hbm_bw: float            # bytes/s per chip
    ici_bw: float            # bytes/s per link
    dcn_bw: float            # bytes/s per host (pod-axis traffic)
    hbm_bytes: float         # capacity per chip


V5E = Hardware(name="tpu_v5e", flops_bf16=197e12, hbm_bw=819e9,
               ici_bw=50e9, dcn_bw=6.25e9, hbm_bytes=16e9)


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class CollectiveStats:
    """Per-device bytes by collective kind (from the partitioned HLO)."""

    by_kind: dict
    n_ops: int

    @property
    def total(self) -> int:
        return sum(self.by_kind.values())


def collective_bytes(hlo_text: str) -> CollectiveStats:
    by_kind: dict[str, int] = {k: 0 for k in _COLLECTIVES}
    n = 0
    for m in _OP_RE.finditer(hlo_text):
        tuple_shapes, single, kind = m.group(1), m.group(2), m.group(3)
        shape_str = tuple_shapes if tuple_shapes else single
        by_kind[kind] += _shape_bytes(shape_str)
        n += 1
    return CollectiveStats({k: v for k, v in by_kind.items() if v}, n)


@dataclasses.dataclass
class RooflineTerms:
    """Three-term roofline for one compiled (arch x shape x mesh)."""

    arch: str
    shape: str
    mesh: str
    n_devices: int
    hlo_flops: float           # whole-program FLOPs (per device, XLA view)
    hlo_bytes: float           # bytes accessed (per device)
    coll_bytes: float          # collective bytes (per device)
    t_compute: float
    t_memory: float
    t_collective: float
    model_flops: float         # 6*N*D useful flops (global)
    bytes_per_device: float    # peak memory from memory_analysis
    n_collectives: int = 0

    @property
    def dominant(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / (global HLO FLOPs): remat/dispatch overhead probe."""
        total = self.hlo_flops * self.n_devices
        return self.model_flops / total if total else 0.0

    def as_row(self) -> dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "t_compute_s": self.t_compute, "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "dominant": self.dominant,
            "useful_flops_ratio": self.useful_flops_ratio,
            "bytes_per_device": self.bytes_per_device,
            "n_collectives": self.n_collectives,
        }


def parse_memory_analysis(mem) -> float:
    """Extract peak per-device bytes from compiled.memory_analysis()."""
    if mem is None:
        return 0.0
    for attr in ("temp_size_in_bytes",):
        if hasattr(mem, attr):
            temp = getattr(mem, attr)
            args = getattr(mem, "argument_size_in_bytes", 0)
            out = getattr(mem, "output_size_in_bytes", 0)
            alias = getattr(mem, "alias_size_in_bytes", 0)
            return float(temp + args + out - alias)
    return 0.0


def roofline_terms(compiled, *, arch: str, shape: str, mesh_name: str,
                   n_devices: int, model_flops: float,
                   hw: Hardware = V5E) -> RooflineTerms:
    """Derive the three roofline terms from a compiled executable.

    XLA's cost_analysis flops on the SPMD-partitioned module are per-device.
    """
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    flops = float(cost.get("flops", 0.0))
    bytes_accessed = float(cost.get("bytes accessed", 0.0))
    stats = collective_bytes(compiled.as_text())
    mem = parse_memory_analysis(compiled.memory_analysis())
    return RooflineTerms(
        arch=arch, shape=shape, mesh=mesh_name, n_devices=n_devices,
        hlo_flops=flops, hlo_bytes=bytes_accessed,
        coll_bytes=float(stats.total),
        t_compute=flops / hw.flops_bf16,
        t_memory=bytes_accessed / hw.hbm_bw,
        t_collective=stats.total / hw.ici_bw,
        model_flops=model_flops,
        bytes_per_device=mem,
        n_collectives=stats.n_ops,
    )
