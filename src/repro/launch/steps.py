"""Sharded step builders: train_step / prefill_step / serve_step + specs.

This module is the bridge between the model code and the distribution
layer: it builds the jitted step functions with explicit in/out shardings
derived from the logical-axes trees, and the matching ShapeDtypeStruct
input stand-ins — everything the multi-pod dry-run needs to
``.lower().compile()`` without allocating a byte of model state.

``abstract_state`` uses eval_shape with a side channel for the axes tree
(axes are plain-python tuples built during tracing, so they cannot travel
through eval_shape's return value).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs.base import InputShape, ModelConfig
from ..models import transformer
from ..models.model import _batch_shapes, cache_len_for, loss_fn
from ..optim.adamw import AdamWConfig, adamw_init, adamw_update
from ..parallel import sharding as shd

__all__ = ["abstract_state", "abstract_cache", "make_train_step",
           "make_prefill_step", "make_serve_step", "state_specs",
           "batch_specs", "cache_specs", "lower_step"]


# ---------------------------------------------------------------------------
# Abstract state / cache (ShapeDtypeStructs + aligned axes, no allocation)
# ---------------------------------------------------------------------------

def abstract_state(cfg: ModelConfig, opt_cfg: AdamWConfig | None = None
                   ) -> tuple[Any, Any, Any]:
    """(params_abs, params_axes, opt_abs) as ShapeDtypeStructs."""
    box: list[Any] = []

    def build(key):
        params, axes = transformer.init_params(cfg, key)
        box.append(axes)
        return params

    params_abs = jax.eval_shape(build, jax.random.PRNGKey(0))
    axes = box[0]
    opt_abs = None
    if opt_cfg is not None:
        opt_abs = jax.eval_shape(
            functools.partial(adamw_init, cfg=opt_cfg), params_abs)
    return params_abs, axes, opt_abs


def abstract_cache(cfg: ModelConfig, batch: int, cache_len: int) -> Any:
    return jax.eval_shape(
        lambda: transformer.init_cache(cfg, batch, cache_len))


# ---------------------------------------------------------------------------
# Sharding specs
# ---------------------------------------------------------------------------

def state_specs(cfg: ModelConfig, mesh: Mesh, params_abs: Any, axes: Any,
                opt_abs: Any = None, rules: shd.AxisRules | None = None):
    """NamedSharding trees for (params, opt_state)."""
    rules = rules or shd.DEFAULT_RULES
    pspecs = shd.spec_tree(axes, params_abs, mesh, rules)
    named = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs,
                         is_leaf=lambda x: isinstance(x, P))
    if opt_abs is None:
        return named, None
    ospecs = {
        "m": named, "v": named,
        "step": NamedSharding(mesh, P()),
    }
    return named, ospecs


def batch_specs(cfg: ModelConfig, shape: InputShape, mesh: Mesh,
                rules: shd.AxisRules | None = None) -> dict:
    """ShapeDtypeStructs (with shardings) for the train/prefill batch."""
    rules = rules or shd.DEFAULT_RULES
    axes_by_rank = {
        2: ("batch", "seq"),
        3: ("batch", "seq", "embed"),
    }
    out = {}
    for name, (shp, dt) in _batch_shapes(cfg, shape).items():
        if name == "positions_thw":
            axes = ("batch", "seq", None)
        elif name == "vision_embeds":
            axes = ("batch", None, "embed")
        else:
            axes = axes_by_rank[len(shp)]
        # Activations never shard "embed" on inputs (weights own that axis).
        axes = tuple(None if a == "embed" else a for a in axes)
        spec = shd.logical_to_spec(axes, shp, mesh, rules)
        out[name] = jax.ShapeDtypeStruct(shp, dt,
                                         sharding=NamedSharding(mesh, spec))
    return out


def cache_specs(cfg: ModelConfig, shape: InputShape, mesh: Mesh,
                rules: shd.AxisRules | None = None):
    """(cache_abs_with_shardings, cache_sharding_tree) for decode shapes."""
    rules = rules or shd.DECODE_RULES
    cache_abs = abstract_cache(cfg, shape.global_batch,
                               cache_len_for(cfg, shape))
    axes = transformer.cache_axes(cfg)
    specs = shd.spec_tree(axes, cache_abs, mesh, rules)
    named = jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                         is_leaf=lambda x: isinstance(x, P))
    cache_in = jax.tree.map(
        lambda a, s: jax.ShapeDtypeStruct(a.shape, a.dtype, sharding=s),
        cache_abs, named)
    return cache_in, named


# ---------------------------------------------------------------------------
# Step functions
# ---------------------------------------------------------------------------

def make_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig,
                    grad_shardings: Any = None) -> Callable:
    """Microbatched train step: grad-accumulate over cfg.microbatches.

    ``grad_shardings`` (the params' NamedSharding tree) pins the scan-carried
    gradient accumulator: without it GSPMD replicates the carry, and a 405B
    model materializes full-size fp32 grads on every device.
    """
    m = max(1, cfg.microbatches)
    acc_dt = jnp.bfloat16 if cfg.grad_accum_dtype == "bfloat16" \
        else jnp.float32

    def pin(tree):
        if grad_shardings is None:
            return tree
        return jax.tree.map(jax.lax.with_sharding_constraint, tree,
                            grad_shardings)

    def train_step(params, opt_state, batch):
        if m == 1:
            (_, metrics), grads = jax.value_and_grad(
                lambda p: loss_fn(cfg, p, batch), has_aux=True)(params)
        else:
            mb = jax.tree.map(
                lambda x: x.reshape((m, x.shape[0] // m) + x.shape[1:]),
                batch)

            def one(acc, mbatch):
                (_, metrics), grads = jax.value_and_grad(
                    lambda p: loss_fn(cfg, p, mbatch), has_aux=True)(params)
                acc = pin(jax.tree.map(
                    lambda a, g: a + g.astype(acc_dt), acc, grads))
                return acc, metrics

            zeros = pin(jax.tree.map(
                lambda p: jnp.zeros(p.shape, acc_dt), params))
            grads, metrics_all = jax.lax.scan(one, zeros, mb)
            grads = jax.tree.map(lambda g: g / m, grads)
            metrics = jax.tree.map(lambda x: x.mean(), metrics_all)
        new_params, new_opt, opt_metrics = adamw_update(
            params, grads, opt_state, opt_cfg)
        return new_params, new_opt, {**metrics, **opt_metrics}

    return train_step


def make_prefill_step(cfg: ModelConfig, shape: InputShape) -> Callable:
    """Prefill (decoder archs) or full encode (encoder-only archs)."""
    if cfg.causal:
        def prefill_step(params, batch):
            return transformer.prefill(cfg, params, batch,
                                       cache_len=shape.seq_len)
    else:
        def prefill_step(params, batch):
            logits, _ = transformer.forward_train(cfg, params, batch)
            return logits
    return prefill_step


def make_serve_step(cfg: ModelConfig) -> Callable:
    def serve_step(params, token, cache):
        return transformer.decode_step(cfg, params, token, cache)
    return serve_step


# ---------------------------------------------------------------------------
# Lowering helper (dry-run entry)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class LoweredPair:
    arch: str
    shape: str
    kind: str
    lowered: Any
    compiled: Any = None


def lower_step(cfg: ModelConfig, shape: InputShape, mesh: Mesh, *,
               opt_cfg: AdamWConfig | None = None,
               rules: shd.AxisRules | None = None,
               compile_now: bool = True) -> LoweredPair:
    """Lower (and optionally compile) the right step for (cfg, shape)."""
    cfg = cfg.for_shape(shape)
    if shape.kind == "train":
        opt_cfg = opt_cfg or AdamWConfig(moment_dtype=cfg.opt_dtype)
        rules = rules or shd.DEFAULT_RULES
        params_abs, axes, opt_abs = abstract_state(cfg, opt_cfg)
        pshard, oshard = state_specs(cfg, mesh, params_abs, axes, opt_abs,
                                     rules)
        batch = batch_specs(cfg, shape, mesh, rules)
        params_in = jax.tree.map(
            lambda a, s: jax.ShapeDtypeStruct(a.shape, a.dtype, sharding=s),
            params_abs, pshard)
        opt_in = jax.tree.map(
            lambda a, s: jax.ShapeDtypeStruct(a.shape, a.dtype, sharding=s),
            opt_abs, oshard)
        step = make_train_step(cfg, opt_cfg, grad_shardings=pshard)
        with shd.use_rules(rules, mesh), mesh:
            jitted = jax.jit(step,
                             in_shardings=(pshard, oshard, None),
                             out_shardings=(pshard, oshard, None))
            # NOTE: on real TPUs pass donate_argnums=(0, 1) so the updated
            # state aliases the old one; the CPU dry-run backend implements
            # donation as copies, which would distort memory_analysis.
            lowered = jitted.lower(params_in, opt_in, batch)
    elif shape.kind == "prefill":
        rules = rules or shd.DEFAULT_RULES
        params_abs, axes, _ = abstract_state(cfg)
        pshard, _ = state_specs(cfg, mesh, params_abs, axes, None, rules)
        batch = batch_specs(cfg, shape, mesh, rules)
        params_in = jax.tree.map(
            lambda a, s: jax.ShapeDtypeStruct(a.shape, a.dtype, sharding=s),
            params_abs, pshard)
        step = make_prefill_step(cfg, shape)
        with shd.use_rules(rules, mesh), mesh:
            jitted = jax.jit(step, in_shardings=(pshard, None))
            lowered = jitted.lower(params_in, batch)
    elif shape.kind == "decode":
        rules = rules or shd.DECODE_RULES
        params_abs, axes, _ = abstract_state(cfg)
        pshard, _ = state_specs(cfg, mesh, params_abs, axes, None, rules)
        params_in = jax.tree.map(
            lambda a, s: jax.ShapeDtypeStruct(a.shape, a.dtype, sharding=s),
            params_abs, pshard)
        cache_in, cache_shard = cache_specs(cfg, shape, mesh, rules)
        token = jax.ShapeDtypeStruct(
            (shape.global_batch,), jnp.int32,
            sharding=NamedSharding(mesh, shd.logical_to_spec(
                ("batch",), (shape.global_batch,), mesh, rules)))
        step = make_serve_step(cfg)
        with shd.use_rules(rules, mesh), mesh:
            jitted = jax.jit(step,
                             in_shardings=(pshard, token.sharding,
                                           cache_shard),
                             out_shardings=(None, cache_shard))
            # NOTE: donate the cache (argnums=2) on real TPUs.
            lowered = jitted.lower(params_in, token, cache_in)
    else:
        raise ValueError(shape.kind)

    pair = LoweredPair(cfg.name, shape.name, shape.kind, lowered)
    if compile_now:
        pair.compiled = lowered.compile()
    return pair
