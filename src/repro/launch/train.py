"""Training launcher CLI.

Runs the fault-tolerant trainer on a reduced (CPU) or full (TPU) config:

    PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b \
        --reduced --steps 200 --seq 128 --batch 8 --faults --workdir /tmp/ck

On the CPU container only reduced configs execute numerically; the full
configs are exercised by the dry-run (repro.launch.dryrun).
"""

from __future__ import annotations

import argparse
import dataclasses
import json

import numpy as np

from repro.configs import REGISTRY, get
from repro.configs.base import InputShape, PlatformConfig
from repro.configs.paper import SYNTHETIC
from repro.core.traces import Exponential, Weibull, make_event_trace
from repro.train import FaultTolerantTrainer


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="tinyllama-1.1b",
                    choices=sorted(REGISTRY))
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--workdir", default="/tmp/repro_ckpt")
    ap.add_argument("--faults", action="store_true",
                    help="inject faults from a synthetic trace")
    ap.add_argument("--fault-dist", default="exponential",
                    choices=["exponential", "weibull"])
    ap.add_argument("--mtbf", type=float, default=600.0,
                    help="platform MTBF in virtual seconds")
    ap.add_argument("--step-time", type=float, default=10.0,
                    help="virtual seconds per training step")
    ap.add_argument("--no-predictor", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    shape = InputShape("cli", args.seq, args.batch, "train")
    plat = PlatformConfig(
        mu_ind=args.mtbf, c=3.0 * args.step_time, cp=args.step_time,
        d=args.step_time / 2, r=args.step_time,
        recall=SYNTHETIC.recall, precision=SYNTHETIC.precision)

    trace = None
    if args.faults:
        dist = Exponential(1.0) if args.fault_dist == "exponential" \
            else Weibull(0.7, 1.0)
        trace = make_event_trace(
            dist, args.mtbf, plat.recall, plat.precision,
            horizon=max(1e6, args.steps * args.step_time * 20),
            rng=np.random.default_rng(args.seed))

    trainer = FaultTolerantTrainer(
        cfg, shape, plat, workdir=args.workdir, step_time=args.step_time,
        trace=trace, use_predictor=not args.no_predictor, seed=args.seed)
    print(f"arch={cfg.name} period T*={trainer.scheduler.period:.1f}s "
          f"use_pred={trainer.scheduler.decision.use_predictions} "
          f"beta_lim={trainer.scheduler.decision.beta_lim:.1f}s")
    stats = trainer.run(args.steps)
    print(json.dumps(dataclasses.asdict(stats), indent=1))
    print(f"waste={stats.waste:.4f} "
          f"(analytic {trainer.scheduler.decision.expected_waste:.4f})")


if __name__ == "__main__":
    main()
