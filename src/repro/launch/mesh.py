"""Production mesh construction.

Target: TPU v5e pods of 16 x 16 = 256 chips; multi-pod adds a leading "pod"
axis over DCN (2 x 16 x 16 = 512 chips).  Functions, not module-level
constants, so importing this module never touches jax device state (the
dry-run must set XLA_FLAGS before any device query).
"""

from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_cpu_mesh"]


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    """16x16 single-pod mesh, or 2x16x16 multi-pod (pod axis = DCN)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_cpu_mesh() -> jax.sharding.Mesh:
    """1x1 mesh over the single CPU device (smoke tests)."""
    return jax.make_mesh((1, 1), ("data", "model"))
