"""Serving launcher CLI: batched prefill + decode on a reduced config.

    PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b \
        --batch 4 --prompt-len 64 --new-tokens 32
"""

from __future__ import annotations

import argparse
import time

import jax

from repro.configs import REGISTRY, get
from repro.configs.base import InputShape
from repro.models.model import init_params, make_batch
from repro.serve import ServingEngine


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="tinyllama-1.1b",
                    choices=sorted(REGISTRY))
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get(args.arch).reduced()
    if not cfg.causal:
        raise SystemExit(f"{cfg.name} is encoder-only; nothing to decode")
    params, _ = init_params(cfg, jax.random.PRNGKey(args.seed))
    engine = ServingEngine(cfg, params,
                           cache_len=args.prompt_len + args.new_tokens)
    shape = InputShape("serve", args.prompt_len, args.batch, "prefill")
    batch = make_batch(cfg, shape, jax.random.PRNGKey(args.seed + 1))

    t0 = time.perf_counter()
    result = engine.generate(batch, args.new_tokens,
                             temperature=args.temperature, seed=args.seed)
    jax.block_until_ready(result.tokens)
    dt = time.perf_counter() - t0
    print(f"arch={cfg.name} batch={args.batch} prompt={args.prompt_len} "
          f"new={args.new_tokens}")
    print(f"tokens[0] = {result.tokens[0].tolist()}")
    print(f"mean logprob = {float(result.logprobs.mean()):.3f}")
    print(f"wall {dt:.2f}s -> "
          f"{args.batch * args.new_tokens / dt:.1f} tok/s (reduced CPU)")


if __name__ == "__main__":
    main()
