"""Launch layer: meshes, sharded steps, dry-run, train/serve CLIs."""
