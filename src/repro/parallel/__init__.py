"""Distribution layer: logical-axis sharding rules over ("pod","data","model")."""

from .sharding import (AxisRules, DEFAULT_RULES, logical_to_spec, spec_tree,
                       shard_batch_spec, constrain)

__all__ = ["AxisRules", "DEFAULT_RULES", "logical_to_spec", "spec_tree",
           "shard_batch_spec", "constrain"]
