"""Logical-axis sharding: map model-level axis names to mesh axes.

Every parameter (and the main activations) carries a tuple of *logical* axis
names (e.g. ``("vocab", "embed")``).  :class:`AxisRules` maps those names to
mesh axes with divisibility fallbacks: an axis whose size does not divide the
assigned mesh-axis extent is replicated instead (this is what makes the same
model code lower on a 1-device CPU, a 16x16 pod, and a 2x16x16 multi-pod
mesh without per-arch special cases — e.g. qwen2-moe's 60 experts do not
divide 16, so its experts replicate and its per-expert FFN dim shards).

Default placement (Megatron/FSDP hybrid, TPU-native):
  * "model"-assigned: attention heads, FFN hidden, vocab, experts, LRU width.
  * "data"-assigned (FSDP-style weight sharding): the d_model ("embed") dim.
  * batch: ("pod", "data") — pods are pure data parallelism over DCN.
  * everything else replicated.
"""

from __future__ import annotations

import contextlib
import dataclasses
from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["AxisRules", "DEFAULT_RULES", "DECODE_RULES", "SEQ_PARALLEL_RULES",
           "logical_to_spec", "spec_tree", "shard_batch_spec", "constrain",
           "use_rules"]


@dataclasses.dataclass(frozen=True)
class AxisRules:
    """Mapping logical axis name -> preferred mesh axis (or None)."""

    rules: tuple[tuple[str, str | None], ...]

    def mesh_axis(self, logical: str | None) -> str | None:
        if logical is None:
            return None
        for name, target in self.rules:
            if name == logical:
                return target
        return None

    def replace(self, **kw: str | None) -> "AxisRules":
        rules = tuple((k, kw.get(k, v)) for k, v in self.rules)
        extra = tuple((k, v) for k, v in kw.items()
                      if k not in dict(self.rules))
        return AxisRules(rules + extra)


DEFAULT_RULES = AxisRules((
    ("batch", "data"),        # batch additionally shards over "pod" (below)
    ("embed", "data"),        # FSDP-style: d_model dim of weights over data
    ("heads", "model"),
    ("kv_heads", "model"),
    ("mlp", "model"),
    ("vocab", "model"),
    ("experts", "model"),     # expert parallelism
    ("capacity", "data"),     # MoE dispatch-buffer token slots
    ("lru", "model"),
    ("seq", None),
    ("head_dim", None),
    ("layers", None),
    ("conv", None),
))

# Decode-mode rules: the KV-cache time axis shards over "model" — a 32k-deep
# cache for a 100+-layer model does not fit per-device otherwise.  GSPMD
# turns the softmax reductions over the sharded axis into all-reduces.
DECODE_RULES = DEFAULT_RULES.replace(seq="model")

# Train-mode sequence-parallel rules (hillclimb knob): activations shard
# their seq axis over "model" between blocks, Megatron-SP style.
SEQ_PARALLEL_RULES = DEFAULT_RULES.replace(seq="model")

_ACTIVE_RULES: AxisRules = DEFAULT_RULES
_ACTIVE_MESH: Mesh | None = None


@contextlib.contextmanager
def use_rules(rules: "AxisRules", mesh: Mesh | None = None):
    """Scoped override of the rules (and mesh) used by :func:`constrain`.

    The mesh must be passed explicitly: inside a jit trace the legacy
    ``with mesh:`` context does NOT surface through
    ``jax.sharding.get_abstract_mesh()`` (it returns an empty AbstractMesh),
    so activation constraints would silently no-op without it.
    """
    global _ACTIVE_RULES, _ACTIVE_MESH
    old = (_ACTIVE_RULES, _ACTIVE_MESH)
    _ACTIVE_RULES = rules
    _ACTIVE_MESH = mesh
    try:
        yield rules
    finally:
        _ACTIVE_RULES, _ACTIVE_MESH = old


def _divisible(size: int, mesh: Mesh, axis: str | None) -> bool:
    if axis is None:
        return False
    if axis not in mesh.shape:
        return False
    return size % mesh.shape[axis] == 0


def logical_to_spec(axes: tuple[str | None, ...], shape: tuple[int, ...],
                    mesh: Mesh, rules: AxisRules = DEFAULT_RULES) -> P:
    """PartitionSpec for one array given its logical axes and shape."""
    if len(axes) != len(shape):
        raise ValueError(f"axes {axes} do not match shape {shape}")
    used: set[str] = set()
    out: list[Any] = []
    for name, size in zip(axes, shape):
        target = rules.mesh_axis(name)
        if name == "batch":
            # Batch shards over ("pod","data") jointly when divisible.
            cand = [a for a in ("pod", "data") if a in mesh.shape]
            extent = 1
            for a in cand:
                extent *= mesh.shape[a]
            if cand and size % extent == 0 and not (set(cand) & used):
                out.append(tuple(cand) if len(cand) > 1 else cand[0])
                used.update(cand)
                continue
            target = "data"
        if target in used or not _divisible(size, mesh, target):
            out.append(None)
        else:
            out.append(target)
            used.add(target)  # a mesh axis may appear only once per spec
    # Trim trailing Nones for tidiness.
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def spec_tree(axes_tree: Any, params_tree: Any, mesh: Mesh,
              rules: AxisRules = DEFAULT_RULES) -> Any:
    """Map a pytree of logical-axes tuples + matching params to PartitionSpecs."""
    return jax.tree.map(
        lambda axes, p: logical_to_spec(tuple(axes), p.shape, mesh, rules),
        axes_tree, params_tree,
        is_leaf=lambda x: isinstance(x, tuple) and len(x) > 0 and all(
            isinstance(e, (str, type(None))) for e in x),
    )


def shard_batch_spec(mesh: Mesh, batch: int) -> P:
    """PartitionSpec for a (batch, ...) input array."""
    return logical_to_spec(("batch",), (batch,), mesh)


def constrain(x: jax.Array, axes: tuple[str | None, ...],
              rules: AxisRules | None = None) -> jax.Array:
    """Best-effort with_sharding_constraint using logical axes.

    No-op when tracing outside any mesh (CPU smoke tests); inside a jit whose
    arguments carry NamedShardings, GSPMD propagates from the in_shardings and
    this constraint pins the key activations (batch/heads/mlp dims).
    """
    if rules is None:
        rules = _ACTIVE_RULES
    mesh = _ACTIVE_MESH
    if mesh is None:
        try:
            am = jax.sharding.get_abstract_mesh()
            if am is None or am.empty or not am.shape:
                return x
            mesh = am
        except Exception:
            return x
    spec = logical_to_spec(axes, x.shape, mesh, rules)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, spec) if isinstance(mesh, Mesh) else spec)
