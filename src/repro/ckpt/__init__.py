"""Checkpointing: double-buffered full + delta-quantized proactive saves."""

from .manager import CheckpointManager, SaveInfo, state_bytes

__all__ = ["CheckpointManager", "SaveInfo", "state_bytes"]
