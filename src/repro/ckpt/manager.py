"""Checkpoint manager: periodic (full) + proactive (delta) checkpoints.

This is the framework realization of the paper's two checkpoint costs:

  * C   — a *full* checkpoint: every leaf of the TrainState (params,
          optimizer moments, data cursor, RNG) serialized to stable storage,
          double-buffered (the previous checkpoint is only dropped once the
          new one is durable — a fault mid-checkpoint must not destroy the
          last good state, which is exactly the paper's model where a fault
          during a checkpoint rolls back to the previous one).
  * C_p — a *proactive* checkpoint taken on a fault prediction: a blockwise
          int8-quantized delta against the last full checkpoint
          (Check-N-Run-style incremental+quantized checkpointing).  Payload
          is ~4x smaller than a bf16 full state, realizing the paper's
          C_p < C scenario [§2.2, citing Zheng et al.'s localized cheap
          proactive checkpoints].  Restoring replays base + delta.

Cost model: with per-chip checkpoint bandwidth ``bw`` and per-chip shard
bytes ``s``, C = s / bw (each chip writes its own shard concurrently — the
coordinated-checkpointing cost is per-shard, not global).  ``measure=True``
instead times the actual host serialization, for CPU-scale examples.

The quantize/dequantize hot loop is the Pallas ``ckpt_delta`` kernel
(``repro.kernels.ckpt_delta``); the manager falls back to the pure-jnp
reference on CPU.
"""

from __future__ import annotations

import dataclasses
import json
import os
import re
import shutil
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels import ckpt_delta as _delta

__all__ = ["DELTA_RATIO_PRIOR", "SaveInfo", "CheckpointManager",
           "state_bytes", "modeled_costs_from_bytes"]

# Prior payload ratio of proactive (int8 delta + per-block scales) vs full
# (bf16/fp32) checkpoints, used until a manager has measured its own saves.
DELTA_RATIO_PRIOR = 0.27


def modeled_costs_from_bytes(nbytes: float, *, bandwidth: float,
                             n_shards: int = 1,
                             delta_ratio: float = DELTA_RATIO_PRIOR,
                             ) -> tuple[float, float]:
    """(C, C_p) in seconds from a state size in bytes (no state needed).

    The pure form of :meth:`CheckpointManager.modeled_costs`, for planners
    that know the state size analytically (e.g. fleet jobs sized from
    ``ModelConfig.param_count``) without instantiating any state.
    """
    b = nbytes / max(1, n_shards)
    return b / bandwidth, delta_ratio * b / bandwidth


def state_bytes(state: Any) -> int:
    return sum(l.size * l.dtype.itemsize for l in jax.tree.leaves(state))


def _leaf_names(tree: Any) -> list[str]:
    paths = jax.tree_util.tree_flatten_with_path(tree)[0]
    return [jax.tree_util.keystr(p) for p, _ in paths]


def _encode(leaf: np.ndarray) -> np.ndarray:
    """npz-safe encoding: bfloat16 (unknown to numpy) stored as uint16 bits."""
    if leaf.dtype == jnp.bfloat16:
        return leaf.view(np.uint16)
    return leaf


def _decode(arr: np.ndarray, target_dtype) -> jax.Array:
    if target_dtype == jnp.bfloat16 and arr.dtype == np.uint16:
        return jnp.asarray(arr.view(jnp.bfloat16))
    return jnp.asarray(arr).astype(target_dtype)


@dataclasses.dataclass(frozen=True)
class SaveInfo:
    step: int
    kind: str          # "full" | "proactive"
    bytes: int         # serialized payload size
    seconds: float     # measured wall-clock (host) save time
    path: str

    def modeled_cost(self, bandwidth: float, n_shards: int = 1) -> float:
        """Modeled checkpoint duration: per-shard bytes / bandwidth."""
        return self.bytes / max(1, n_shards) / bandwidth


class CheckpointManager:
    """Double-buffered full checkpoints + delta-encoded proactive ones."""

    def __init__(self, directory: str, *, keep: int = 2,
                 bandwidth: float = 2e9, block: int = 256) -> None:
        self.dir = directory
        self.keep = keep
        self.bandwidth = bandwidth
        self.block = block
        os.makedirs(directory, exist_ok=True)
        self._last_full_state: Any = None   # host copy backing deltas
        self._last_full_step: int = -1
        self._last_full_bytes: int = -1     # measured full payload size
        self._delta_ratios: list[float] = []  # measured delta/full ratios

    # -- paths ---------------------------------------------------------------

    def _full_path(self, step: int) -> str:
        return os.path.join(self.dir, f"full_{step:08d}.npz")

    def _delta_path(self, step: int) -> str:
        return os.path.join(self.dir, f"delta_{step:08d}.npz")

    def checkpoints(self) -> list[tuple[int, str]]:
        """Sorted [(step, kind)] of all durable checkpoints."""
        out = []
        for f in os.listdir(self.dir):
            m = re.match(r"(full|delta)_(\d+)\.npz$", f)
            if m:
                out.append((int(m.group(2)), m.group(1)))
        return sorted(out)

    def latest_step(self) -> int | None:
        cks = self.checkpoints()
        return cks[-1][0] if cks else None

    # -- full checkpoints ------------------------------------------------------

    def save(self, step: int, state: Any) -> SaveInfo:
        """Full checkpoint (paper cost C).  Atomic: tmp + rename."""
        t0 = time.perf_counter()
        host = jax.tree.map(np.asarray, jax.device_get(state))
        leaves = jax.tree.leaves(host)
        names = _leaf_names(host)
        payload = {f"leaf_{i}": _encode(l) for i, l in enumerate(leaves)}
        payload["__names__"] = np.asarray(json.dumps(names))
        path = self._full_path(step)
        tmp = path + ".tmp"
        with open(tmp, "wb") as f:
            np.savez(f, **payload)
        os.replace(tmp, path)  # durable before the old one is dropped
        secs = time.perf_counter() - t0
        self._last_full_state = host
        self._last_full_step = step
        self._gc()
        nbytes = os.path.getsize(path)
        self._last_full_bytes = nbytes
        return SaveInfo(step, "full", nbytes, secs, path)

    # -- proactive (delta) checkpoints ----------------------------------------

    def save_proactive(self, step: int, state: Any) -> SaveInfo:
        """Proactive checkpoint (paper cost C_p): int8 delta vs last full.

        Falls back to a full save if no full checkpoint exists yet.
        """
        if self._last_full_state is None:
            return self.save(step, state)
        t0 = time.perf_counter()
        host = jax.tree.map(np.asarray, jax.device_get(state))
        base_leaves = jax.tree.leaves(self._last_full_state)
        leaves = jax.tree.leaves(host)
        payload: dict[str, np.ndarray] = {}
        for i, (cur, base) in enumerate(zip(leaves, base_leaves)):
            if np.issubdtype(cur.dtype, np.floating) and cur.size >= self.block:
                q, scales = _delta.quantize_delta(
                    jnp.asarray(cur), jnp.asarray(base), block=self.block)
                payload[f"q_{i}"] = np.asarray(q)
                payload[f"s_{i}"] = np.asarray(scales)
            else:  # small / integer leaves stored raw
                payload[f"raw_{i}"] = _encode(cur)
        payload["__base__"] = np.asarray(self._last_full_step)
        path = self._delta_path(step)
        tmp = path + ".tmp"
        with open(tmp, "wb") as f:
            np.savez(f, **payload)
        os.replace(tmp, path)
        secs = time.perf_counter() - t0
        nbytes = os.path.getsize(path)
        if self._last_full_bytes > 0:
            self._delta_ratios.append(nbytes / self._last_full_bytes)
        return SaveInfo(step, "proactive", nbytes, secs, path)

    # -- restore ----------------------------------------------------------------

    def restore(self, like: Any, step: int | None = None) -> tuple[int, Any]:
        """Restore the latest (or a given) checkpoint into the structure of
        ``like`` (an abstract or concrete TrainState template)."""
        cks = self.checkpoints()
        if not cks:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        if step is None:
            step, kind = cks[-1]
        else:
            kind = dict(cks)[step]
        if kind == "full":
            return step, self._restore_full(like, step)
        return step, self._restore_delta(like, step)

    def _restore_full(self, like: Any, step: int) -> Any:
        with np.load(self._full_path(step), allow_pickle=False) as z:
            leaves = [z[f"leaf_{i}"]
                      for i in range(len(jax.tree.leaves(like)))]
        treedef = jax.tree.structure(like)
        flat_like = jax.tree.leaves(like)
        out = [_decode(l, t.dtype) for l, t in zip(leaves, flat_like)]
        return jax.tree.unflatten(treedef, out)

    def _restore_delta(self, like: Any, step: int) -> Any:
        with np.load(self._delta_path(step), allow_pickle=False) as z:
            base_step = int(z["__base__"])
            base = self._restore_full(like, base_step)
            flat_base, treedef = jax.tree.flatten(base)
            out = []
            for i, b in enumerate(flat_base):
                if f"q_{i}" in z:
                    cur = _delta.dequantize_delta(
                        jnp.asarray(z[f"q_{i}"]), jnp.asarray(z[f"s_{i}"]),
                        b, block=self.block)
                    out.append(cur.astype(b.dtype))
                else:
                    out.append(_decode(z[f"raw_{i}"], b.dtype))
        return jax.tree.unflatten(treedef, out)

    # -- cost model ---------------------------------------------------------------

    @property
    def measured_delta_ratio(self) -> float | None:
        """Mean measured proactive/full payload ratio, or None if this
        manager has not yet written a delta against a measured full."""
        if not self._delta_ratios:
            return None
        return sum(self._delta_ratios) / len(self._delta_ratios)

    def modeled_costs(self, state: Any, n_shards: int = 1,
                      delta_ratio: float | None = None) -> tuple[float, float]:
        """(C, C_p) in seconds from bytes/bandwidth.

        ``delta_ratio`` is the payload ratio of proactive vs full
        checkpoints (int8 + per-block scales over bf16/fp32 state).  When
        None (default) the ratio *measured from this manager's own saves*
        is used, so C_p tracks the actual ``ckpt_delta`` sparsity; before
        any delta has been written the ``DELTA_RATIO_PRIOR`` applies.
        """
        if delta_ratio is None:
            measured = self.measured_delta_ratio
            delta_ratio = DELTA_RATIO_PRIOR if measured is None else measured
        return modeled_costs_from_bytes(
            state_bytes(state), bandwidth=self.bandwidth, n_shards=n_shards,
            delta_ratio=delta_ratio)

    # -- gc -------------------------------------------------------------------

    def _gc(self) -> None:
        """Keep the last ``keep`` full checkpoints (+ deltas on them)."""
        fulls = [s for s, k in self.checkpoints() if k == "full"]
        for s in fulls[:-self.keep]:
            os.remove(self._full_path(s))
            for ds, dk in self.checkpoints():
                if dk == "delta":
                    with np.load(self._delta_path(ds)) as z:
                        if int(z["__base__"]) == s:
                            os.remove(self._delta_path(ds))
