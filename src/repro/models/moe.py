"""Mixture-of-Experts layer: top-k router + sort-based capacity dispatch.

TPU-native adaptation (DESIGN.md §3): instead of emulating a GPU grouped-GEMM,
tokens are dispatched to a dense per-expert buffer (experts sharded over the
"model" mesh axis => expert parallelism; GSPMD inserts the all-to-all-like
collectives for the scatter/gather).  The dispatch is sort-based (GShard-style
capacity, Switch-style dropping) so expert FLOPs stay ~top_k/E of the dense
equivalent rather than computing every expert on every token:

  1. router logits -> top_k (expert, weight) per token;
  2. flatten the (token, slot) assignments, order them by expert via the
     counts/offsets of a bincount (no full argsort needed: we scatter with
     per-expert positions computed from a cumulative count);
  3. gather tokens into an (E, capacity, d) buffer, run the expert SwiGLU as
     a single batched einsum, and scatter-add weighted results back.

Tokens beyond an expert's capacity are dropped (their residual passes
through), matching the classic capacity-factor trade-off.  Shared experts
(Qwen2-MoE) run as a plain dense SwiGLU on every token.
"""

from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from ..parallel.sharding import constrain
from .layers import Axes, Params, dense_init, merge, swiglu, swiglu_init

__all__ = ["moe_init", "moe_apply", "router_aux_loss"]


def moe_init(key: jax.Array, d: int, n_experts: int, expert_ff: int,
             n_shared: int, dtype: Any, pad_to: int = 0) -> tuple[Params, Axes]:
    """``pad_to`` > n_experts appends dead experts so the expert dim is
    mesh-divisible (e.g. 60 -> 64 on a 16-wide model axis); the router stays
    n_experts wide and the dispatch masks the padding out."""
    n_phys = max(n_experts, pad_to)
    k_r, k_e, k_s = jax.random.split(key, 3)
    ke = jax.random.split(k_e, 3)
    scale = 1.0 / math.sqrt(d)
    experts_p = {
        "w_gate": jax.random.normal(ke[0], (n_phys, d, expert_ff),
                                    jnp.float32).astype(dtype) * scale,
        "w_up": jax.random.normal(ke[1], (n_phys, d, expert_ff),
                                  jnp.float32).astype(dtype) * scale,
        "w_down": jax.random.normal(ke[2], (n_phys, expert_ff, d),
                                    jnp.float32).astype(dtype)
        * (1.0 / math.sqrt(expert_ff)),
    }
    experts_a = {
        "w_gate": ("experts", "embed", "mlp"),
        "w_up": ("experts", "embed", "mlp"),
        "w_down": ("experts", "mlp", "embed"),
    }
    pairs = {
        "router": dense_init(k_r, d, n_experts, ("embed", "experts"),
                             jnp.float32),
        "experts": (experts_p, experts_a),
    }
    if n_shared:
        pairs["shared"] = swiglu_init(k_s, d, n_shared * expert_ff, dtype)
    return merge(pairs)


def router_aux_loss(gates: jax.Array, top_idx: jax.Array,
                    n_experts: int) -> jax.Array:
    """Switch-style load-balance loss: E * sum_e f_e * P_e.

    gates: (T, E) softmax probabilities; top_idx: (T, k) selected experts.
    """
    pe = gates.mean(axis=0)                                   # (E,)
    fe = jnp.zeros((n_experts,), jnp.float32).at[top_idx.reshape(-1)].add(1.0)
    fe = fe / jnp.maximum(1.0, top_idx.size)
    return n_experts * jnp.sum(fe * pe)


def moe_apply(params: Params, x: jax.Array, *, top_k: int,
              capacity_factor: float | None = 1.25,
              n_groups: int = 32) -> tuple[jax.Array, jax.Array]:
    """Apply the MoE layer. x (..., d) -> (same shape, aux_loss scalar).

    GShard-style *grouped* dispatch: tokens are split into ``n_groups``
    groups aligned with the data-parallel sharding.  Routing, position
    computation (log-depth prefix scan) and the scatter into the per-group
    expert buffer are all group-local (zero communication); the single
    resharding of the (G, E, C, d) buffer from group-sharded to
    (group, expert)-sharded IS the MoE all-to-all, after which the expert
    einsums run expert- and group-parallel.  The combine path is the exact
    mirror (a gather per group + a k-way weighted sum — no scatter).

    ``capacity_factor=None`` selects the *dropless* per-group capacity
    (every assignment fits) — used for decode, where the token count is
    small and dropping would be visible in generations.
    """
    orig_shape = x.shape
    d = orig_shape[-1]
    xt = x.reshape(-1, d)                                     # (T, d)
    t = xt.shape[0]
    n_experts = params["router"].shape[-1]      # routable experts
    n_phys = params["experts"]["w_gate"].shape[0]  # incl. dead padding

    g = math.gcd(t, max(1, n_groups))
    tl = t // g                                               # tokens/group
    xg = constrain(xt.reshape(g, tl, d), ("batch", None, None))

    logits = (xg.astype(jnp.float32) @ params["router"])      # (G, Tl, E)
    gates = jax.nn.softmax(logits, axis=-1)
    top_w, top_idx = jax.lax.top_k(gates, top_k)              # (G, Tl, k)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)
    aux = router_aux_loss(gates.reshape(t, n_experts),
                          top_idx.reshape(t, top_k), n_experts)

    # ---- group-local capacity dispatch -----------------------------------
    ts_l = tl * top_k
    flat_e = top_idx.reshape(g, ts_l)                         # (G, TSl)
    flat_w = top_w.reshape(g, ts_l).astype(x.dtype)
    if capacity_factor is None:
        capacity = ts_l  # dropless
    else:
        capacity = max(
            1, int(math.ceil(ts_l / n_experts * capacity_factor)))

    # Position of each assignment inside its (group, expert) bucket: a
    # log-depth prefix sum over the group-local one-hot.  (jnp.cumsum
    # lowers to a quadratic reduce-window on some backends; the
    # associative_scan form is O(TSl * E * log TSl) and scan-free on TPU.)
    onehot = jax.nn.one_hot(flat_e, n_phys, dtype=jnp.int32)  # (G, TSl, E)
    pos_in_e = jax.lax.associative_scan(jnp.add, onehot, axis=1) - 1
    pos = jnp.take_along_axis(pos_in_e, flat_e[..., None], axis=2)[..., 0]
    keep = pos < capacity
    safe_pos = jnp.where(keep, pos, capacity)                 # overflow slot

    # Replicate each token for its k assignments (pure reshape, no gather).
    upd = jnp.broadcast_to(xg[:, :, None, :], (g, tl, top_k, d)) \
        .reshape(g, ts_l, d)
    upd = jnp.where(keep[..., None], upd, 0)

    # Group-local scatter into (G, E, C+1, d); slot `capacity` = drops.
    def scatter_group(buf_g, e_g, p_g, u_g):
        return buf_g.at[e_g, p_g].add(u_g)

    buf = jnp.zeros((g, n_phys, capacity + 1, d), x.dtype)
    buf = jax.vmap(scatter_group)(buf, flat_e, safe_pos, upd)

    # The one resharding = the MoE all-to-all: group axis stays on "data",
    # expert axis picks up "model" (requires E % model == 0 — see
    # ``pad_experts_to`` for non-divisible expert counts like 60).
    buf = constrain(buf, ("batch", "experts", None, None))

    # Expert SwiGLU, expert- and group-parallel: (G,E,C,d) x (E,d,f).
    e = params["experts"]
    gate = jax.nn.silu(jnp.einsum("gecd,edf->gecf", buf, e["w_gate"]))
    up = jnp.einsum("gecd,edf->gecf", buf, e["w_up"])
    out_buf = jnp.einsum("gecf,efd->gecd", gate * up, e["w_down"])
    out_buf = constrain(out_buf, ("batch", "experts", None, None))

    # Combine: gather each assignment's row, weight, and sum over k.
    def gather_group(ob_g, e_g, p_g):
        return ob_g[e_g, p_g]

    contrib = jax.vmap(gather_group)(out_buf, flat_e, safe_pos)
    contrib = jnp.where(keep[..., None], contrib, 0) * flat_w[..., None]
    yt = contrib.reshape(g, tl, top_k, d).sum(axis=2)         # (G, Tl, d)
    yt = constrain(yt, ("batch", None, None)).reshape(t, d)

    if "shared" in params:
        yt = yt + swiglu(params["shared"], xt)
    return yt.reshape(orig_shape), aux
