"""xLSTM blocks: mLSTM (matrix memory) and sLSTM (scalar memory) [arXiv:2405.04517].

mLSTM — per head, a matrix memory C (hd x hd) with exponential gating:

    C_t = f_t C_{t-1} + i_t v_t k_t^T      n_t = f_t n_{t-1} + i_t k_t
    h_t = (C_t q_t) / max(|n_t . q_t|, 1)

Training uses the *chunkwise-parallel* stabilized form (state carried between
chunks of length L; within a chunk a decay matrix D plays the role of the
attention matrix).  This keeps memory O(L^2) per chunk instead of O(S^2) —
the TPU-friendly formulation (MXU-sized chunk matmuls) — and is exactly what
makes prefill_32k lowerable.  The log-space stabilizer m follows the paper's
Appendix: the carried state is (C~, n~, m) with true C = C~ * exp(m).

sLSTM — scalar memory with recurrent gate connections (block-diagonal per
head), which forces a sequential ``lax.scan`` over time:

    i/f/z/o from W x_t + R h_{t-1};  c_t = f c_{t-1} + i z;  n_t = f n + i
    h_t = o * c_t / n_t               (log-space stabilized as above)

Decode carries O(1) state for both kinds => long_500k runs natively.
"""

from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from .layers import Axes, Params, dense_init, merge, rms_norm

__all__ = [
    "mlstm_block_init", "mlstm_block_apply", "mlstm_init_state",
    "mlstm_decode_step",
    "slstm_block_init", "slstm_block_apply", "slstm_init_state",
    "slstm_decode_step",
]


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def mlstm_block_init(key: jax.Array, d: int, n_heads: int,
                     dtype: Any) -> tuple[Params, Axes]:
    """mLSTM block: up-proj (2x) -> [mlstm | silu gate] -> down-proj."""
    up = 2 * d
    hd = up // n_heads
    ks = jax.random.split(key, 8)
    params, axes = merge({
        "w_up": dense_init(ks[0], d, up, ("embed", "mlp"), dtype),
        "w_gate": dense_init(ks[1], d, up, ("embed", "mlp"), dtype),
        "w_q": dense_init(ks[2], up, up, ("mlp", "heads_mlp"), dtype),
        "w_k": dense_init(ks[3], up, up, ("mlp", "heads_mlp"), dtype),
        "w_v": dense_init(ks[4], up, up, ("mlp", "heads_mlp"), dtype),
        "w_down": dense_init(ks[5], up, d, ("mlp", "embed"), dtype),
        "w_if": dense_init(ks[6], up, 2 * n_heads, ("mlp", None),
                           jnp.float32),
    })
    # Gate biases: forget-gate bias init positive (remember by default).
    params["b_if"] = jnp.concatenate(
        [jnp.zeros((n_heads,)), jnp.linspace(3.0, 6.0, n_heads)]).astype(
            jnp.float32)
    axes["b_if"] = (None,)
    params["ln_inner"] = jnp.ones((up,), dtype)
    axes["ln_inner"] = ("mlp",)
    return params, axes


def _mlstm_chunk(q, k, v, log_i, log_f, state):
    """One chunk of the stabilized chunkwise mLSTM.

    q,k,v: (B,H,L,hd) fp32 (q pre-scaled); log_i/log_f: (B,H,L);
    state: (C~ (B,H,hd,hd), n~ (B,H,hd), m (B,H)).
    Returns h (B,H,L,hd) and the new state.
    """
    c_p, n_p, m_p = state
    fcum = jnp.cumsum(log_f, axis=-1)                      # F_j, (B,H,L)
    # Intra-chunk log decay matrix: F_j - F_t + log i_t for t <= j.
    ld = fcum[..., :, None] - fcum[..., None, :] + log_i[..., None, :]
    l = q.shape[-2]
    mask = jnp.tril(jnp.ones((l, l), bool))
    ld = jnp.where(mask, ld, -jnp.inf)
    m_intra = ld.max(axis=-1)                              # (B,H,L)
    m_inter = fcum + m_p[..., None]                        # (B,H,L)
    m = jnp.maximum(m_intra, m_inter)
    m = jnp.maximum(m, -1e30)                              # guard all--inf rows
    d_mat = jnp.exp(ld - m[..., None])                     # (B,H,L,L)
    inter_scale = jnp.exp(m_inter - m)                     # (B,H,L)

    s = jnp.einsum("bhld,bhtd->bhlt", q, k) * d_mat
    num = jnp.einsum("bhlt,bhtd->bhld", s, v) \
        + inter_scale[..., None] * jnp.einsum("bhld,bhde->bhle", q, c_p)
    den = s.sum(axis=-1) + inter_scale * jnp.einsum("bhld,bhd->bhl", q, n_p)
    h = num / jnp.maximum(jnp.abs(den), jnp.exp(-m))[..., None]

    # State update to the end of the chunk (position L).
    f_tot = fcum[..., -1]                                  # (B,H)
    m_new = jnp.maximum(f_tot + m_p, (f_tot[..., None] - fcum + log_i
                                      ).max(axis=-1))
    carry = jnp.exp(f_tot + m_p - m_new)
    w = jnp.exp(f_tot[..., None] - fcum + log_i - m_new[..., None])
    c_new = carry[..., None, None] * c_p \
        + jnp.einsum("bht,bhtd,bhte->bhde", w, k, v)
    n_new = carry[..., None] * n_p + jnp.einsum("bht,bhtd->bhd", w, k)
    return h, (c_new, n_new, m_new)


def _mlstm_qkvif(params: Params, xin: jax.Array, n_heads: int):
    """Project the up-projected input to per-head q,k,v and gate logits."""
    b, s, up = xin.shape
    hd = up // n_heads
    xf = xin.astype(jnp.float32)

    def heads(w):
        return (xin @ w).reshape(b, s, n_heads, hd).transpose(0, 2, 1, 3) \
            .astype(jnp.float32)

    q = heads(params["w_q"]) / math.sqrt(hd)
    k = heads(params["w_k"]) / math.sqrt(hd)
    v = heads(params["w_v"])
    gates = xf @ params["w_if"] + params["b_if"]           # (B,S,2H)
    log_i = gates[..., :n_heads].transpose(0, 2, 1)        # (B,H,S)
    log_f = jax.nn.log_sigmoid(gates[..., n_heads:]).transpose(0, 2, 1)
    return q, k, v, log_i, log_f


def mlstm_init_state(batch: int, n_heads: int, hd: int) -> tuple:
    return (jnp.zeros((batch, n_heads, hd, hd), jnp.float32),
            jnp.zeros((batch, n_heads, hd), jnp.float32),
            jnp.full((batch, n_heads), -1e30, jnp.float32))


def mlstm_block_apply(params: Params, x: jax.Array,
                      state: tuple | None = None, *,
                      n_heads: int, chunk: int = 256,
                      unroll: bool = False) -> tuple[jax.Array, tuple]:
    """Full-sequence mLSTM block. x (B,S,d) -> (B,S,d), new state."""
    dtype = x.dtype
    b, s, d = x.shape
    xin = x @ params["w_up"]
    gate = jax.nn.silu(x @ params["w_gate"])
    q, k, v, log_i, log_f = _mlstm_qkvif(params, xin, n_heads)
    up = xin.shape[-1]
    hd = up // n_heads

    if state is None:
        state = mlstm_init_state(b, n_heads, hd)
    c = min(chunk, s)
    while s % c:
        c -= 1
    nchunks = s // c

    def chunk_of(a, i):  # (B,H,S,...) -> (B,H,c,...)
        return a.reshape(a.shape[:2] + (nchunks, c) + a.shape[3:])[:, :, i]

    def step(carry, i):
        h, new = _mlstm_chunk(chunk_of(q, i), chunk_of(k, i), chunk_of(v, i),
                              chunk_of(log_i, i), chunk_of(log_f, i), carry)
        return new, h

    if unroll:  # roofline analysis: make every chunk visible to XLA's
        hs_list = []
        for i in range(nchunks):
            state, h_i = step(state, i)
            hs_list.append(h_i)
        hs = jnp.stack(hs_list)
    else:
        state, hs = jax.lax.scan(step, state, jnp.arange(nchunks))
    # hs: (nchunks, B, H, c, hd) -> (B, S, up)
    h = hs.transpose(1, 0, 3, 2, 4).reshape(b, s, up)
    h = rms_norm(h.astype(dtype), params["ln_inner"])
    out = (h * gate) @ params["w_down"]
    return out, state


def mlstm_decode_step(params: Params, x: jax.Array, state: tuple, *,
                      n_heads: int) -> tuple[jax.Array, tuple]:
    """One-token mLSTM step. x (B,1,d)."""
    dtype = x.dtype
    b = x.shape[0]
    xin = x @ params["w_up"]
    gate = jax.nn.silu(x @ params["w_gate"])
    q, k, v, log_i, log_f = _mlstm_qkvif(params, xin, n_heads)
    q, k, v = q[:, :, 0], k[:, :, 0], v[:, :, 0]           # (B,H,hd)
    log_i, log_f = log_i[:, :, 0], log_f[:, :, 0]          # (B,H)

    c_p, n_p, m_p = state
    m_new = jnp.maximum(log_f + m_p, log_i)
    f_t = jnp.exp(log_f + m_p - m_new)
    i_t = jnp.exp(log_i - m_new)
    c = f_t[..., None, None] * c_p \
        + i_t[..., None, None] * k[..., :, None] * v[..., None, :]
    n = f_t[..., None] * n_p + i_t[..., None] * k
    num = jnp.einsum("bhd,bhde->bhe", q, c)
    den = jnp.einsum("bhd,bhd->bh", q, n)
    h = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_new))[..., None]
    up = params["w_up"].shape[-1]
    h = h.reshape(b, 1, up).astype(dtype)
    h = rms_norm(h, params["ln_inner"])
    out = (h * gate) @ params["w_down"]
    return out, (c, n, m_new)


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def slstm_block_init(key: jax.Array, d: int, n_heads: int,
                     dtype: Any) -> tuple[Params, Axes]:
    hd = d // n_heads
    ks = jax.random.split(key, 4)
    # Input projections for the 4 gates (i, f, z, o) together.
    params, axes = merge({
        "w_in": dense_init(ks[0], d, 4 * d, ("embed", "mlp"), dtype),
        # GLU feed-forward after the recurrence (proj factor 4/3).
        "w_ff_gate": dense_init(ks[1], d, (4 * d) // 3, ("embed", "mlp"),
                                dtype),
        "w_ff_down": dense_init(ks[2], (4 * d) // 3, d, ("mlp", "embed"),
                                dtype),
    })
    # Block-diagonal recurrent weights: (4, H, hd, hd).
    r = jax.random.normal(ks[3], (4, n_heads, hd, hd), jnp.float32) \
        * (1.0 / math.sqrt(hd))
    params["r"] = r.astype(jnp.float32)
    axes["r"] = (None, "heads", None, None)
    b = jnp.zeros((4, d), jnp.float32)
    # forget bias positive.
    b = b.at[1].set(2.0)
    params["b"] = b
    axes["b"] = (None, "embed")
    params["ln_inner"] = jnp.ones((d,), dtype)
    axes["ln_inner"] = ("embed",)
    return params, axes


def slstm_init_state(batch: int, d: int) -> tuple:
    z = jnp.zeros((batch, d), jnp.float32)
    return (z, z, jnp.full((batch, d), -1e30, jnp.float32), z)  # c,n,m,h


def _slstm_cell(params: Params, wx: jax.Array, state: tuple, n_heads: int):
    """One sLSTM time step.  wx (B,4,d) = W x_t (pre-computed), fp32."""
    c, n, m, h = state
    b, d = h.shape
    hd = d // n_heads
    hh = h.reshape(b, n_heads, hd)
    rec = jnp.einsum("bhk,ghkl->bghl", hh, params["r"]).reshape(b, 4, d)
    pre = wx + rec + params["b"]                           # (B,4,d)
    log_i = pre[:, 0]
    log_f = jax.nn.log_sigmoid(pre[:, 1])
    z = jnp.tanh(pre[:, 2])
    o = jax.nn.sigmoid(pre[:, 3])
    m_new = jnp.maximum(log_f + m, log_i)
    f_t = jnp.exp(log_f + m - m_new)
    i_t = jnp.exp(log_i - m_new)
    c_new = f_t * c + i_t * z
    n_new = jnp.maximum(f_t * n + i_t, jnp.exp(-m_new))
    h_new = o * c_new / n_new
    return (c_new, n_new, m_new, h_new), h_new


def slstm_block_apply(params: Params, x: jax.Array,
                      state: tuple | None = None, *,
                      n_heads: int) -> tuple[jax.Array, tuple]:
    """Full-sequence sLSTM (sequential scan over time). x (B,S,d)."""
    dtype = x.dtype
    b, s, d = x.shape
    if state is None:
        state = slstm_init_state(b, d)
    wx = (x @ params["w_in"]).reshape(b, s, 4, d).astype(jnp.float32)

    def step(carry, wxt):
        return _slstm_cell(params, wxt, carry, n_heads)

    state, hs = jax.lax.scan(step, state, wx.transpose(1, 0, 2, 3))
    h = hs.transpose(1, 0, 2).astype(dtype)                # (B,S,d)
    h = rms_norm(h, params["ln_inner"])
    ff = jax.nn.silu(h @ params["w_ff_gate"]) @ params["w_ff_down"]
    return ff, state


def slstm_decode_step(params: Params, x: jax.Array,
                      state: tuple) -> tuple[jax.Array, tuple]:
    """One-token sLSTM step. x (B,1,d)."""
    dtype = x.dtype
    b, _, d = x.shape
    wx = (x @ params["w_in"]).reshape(b, 4, d).astype(jnp.float32)
    n_heads = params["r"].shape[1]
    state, h = _slstm_cell(params, wx, state, n_heads)
    h = rms_norm(h[:, None, :].astype(dtype), params["ln_inner"])
    ff = jax.nn.silu(h @ params["w_ff_gate"]) @ params["w_ff_down"]
    return ff, state
