"""Model primitives: RMSNorm, RoPE / M-RoPE, GQA attention, SwiGLU.

All parameter-init helpers return aligned ``(params, logical_axes)`` pytrees;
the distribution layer (repro.parallel) turns logical axes into
PartitionSpecs.  Attention is implemented as a memory-bounded chunked
online-softmax (flash-style) in pure jnp — this is the reference/compile
path; the Pallas TPU kernels in repro.kernels implement the same contract
for the hot paths and are validated against these functions.
"""

from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

Params = dict
Axes = dict

# ---------------------------------------------------------------------------
# Init helpers: (params, axes) aligned trees
# ---------------------------------------------------------------------------


def dense_init(key: jax.Array, in_dim: int, out_dim: int,
               axes: tuple[str | None, str | None], dtype: Any,
               scale: float | None = None) -> tuple[jax.Array, tuple]:
    scale = 1.0 / math.sqrt(in_dim) if scale is None else scale
    w = jax.random.normal(key, (in_dim, out_dim), jnp.float32) * scale
    return w.astype(dtype), axes


def norm_init(dim: int, dtype: Any) -> tuple[jax.Array, tuple]:
    return jnp.ones((dim,), dtype), ("embed",)


def merge(pairs: dict[str, tuple[Any, Any]]) -> tuple[Params, Axes]:
    """Merge {name: (params, axes)} into aligned (params, axes) dicts."""
    return ({k: v[0] for k, v in pairs.items()},
            {k: v[1] for k, v in pairs.items()})


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------

def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * scale.astype(jnp.float32)).astype(dtype)


# ---------------------------------------------------------------------------
# RoPE and M-RoPE
# ---------------------------------------------------------------------------

def rope_angles(positions: jax.Array, head_dim: int,
                theta: float) -> tuple[jax.Array, jax.Array]:
    """cos/sin tables. positions (..., S) -> (..., S, head_dim//2), fp32."""
    half = head_dim // 2
    inv_freq = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * inv_freq
    return jnp.cos(ang), jnp.sin(ang)


def mrope_angles(positions_thw: jax.Array, sections: tuple[int, int, int],
                 head_dim: int, theta: float) -> tuple[jax.Array, jax.Array]:
    """M-RoPE (Qwen2-VL): positions_thw (B, S, 3) -> (B, S, head_dim//2) tables.

    The head_dim//2 rotary frequencies are split into (t, h, w) sections; each
    frequency rotates by the corresponding positional component.  Text tokens
    carry identical (t, h, w) = (pos, pos, pos), reducing to plain RoPE.
    """
    half = head_dim // 2
    st, sh, sw = sections
    if st + sh + sw != half:
        raise ValueError(f"M-RoPE sections {sections} must sum to {half}")
    comp = jnp.concatenate([
        jnp.zeros((st,), jnp.int32),
        jnp.ones((sh,), jnp.int32),
        jnp.full((sw,), 2, jnp.int32),
    ])  # (half,) -> which positional component drives each frequency
    inv_freq = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    pos = jnp.take_along_axis(
        positions_thw.astype(jnp.float32),
        jnp.broadcast_to(comp[None, None, :],
                         positions_thw.shape[:2] + (half,)),
        axis=-1)  # (B, S, half)
    ang = pos * inv_freq
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x (B, S, H, hd); cos/sin (S, hd//2) or (B, S, hd//2)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    if cos.ndim == 2:      # (S, half)
        cos_b = cos[None, :, None, :]
        sin_b = sin[None, :, None, :]
    else:                  # (B, S, half)
        cos_b = cos[:, :, None, :]
        sin_b = sin[:, :, None, :]
    xf = x.dtype
    x1, x2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([x1 * cos_b - x2 * sin_b,
                           x2 * cos_b + x1 * sin_b], axis=-1)
    return out.astype(xf)


# ---------------------------------------------------------------------------
# Attention (reference path): chunked online-softmax, GQA, causal / window /
# bidirectional.
# ---------------------------------------------------------------------------

NEG_INF = -1e30


def _pick_chunk(s: int, target: int) -> int:
    """Largest divisor of s that is <= target (power-of-two seqs make this easy)."""
    c = min(s, target)
    while s % c:
        c -= 1
    return c


def chunked_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                      causal: bool, window: int = 0,
                      q_offset: int = 0,
                      q_chunk: int = 512, kv_chunk: int = 512,
                      unroll: bool = False) -> jax.Array:
    """Flash-style attention. q (B,Sq,H,hd); k,v (B,Skv,KV,hd) -> (B,Sq,H,hd).

    Memory is O(q_chunk * kv_chunk) per program instead of O(Sq * Skv).
    ``window`` > 0 restricts attention to the last ``window`` keys (inclusive
    of self); requires ``causal``.  ``q_offset`` is the absolute position of
    q[0] (used for prefill continuation and window masks).
    """
    b, sq, h, hd = q.shape
    skv, kv = k.shape[1], k.shape[2]
    g = h // kv
    qc = _pick_chunk(sq, q_chunk)
    kc = _pick_chunk(skv, kv_chunk)
    nq, nk = sq // qc, skv // kc
    scale = 1.0 / math.sqrt(hd)

    qr = q.reshape(b, nq, qc, kv, g, hd).astype(jnp.float32) * scale
    kr = k.reshape(b, nk, kc, kv, hd).astype(jnp.float32)
    vr = v.reshape(b, nk, kc, kv, hd).astype(jnp.float32)

    q_pos = q_offset + jnp.arange(sq).reshape(nq, qc)
    k_pos = jnp.arange(skv).reshape(nk, kc)

    def one_q_block(qi, qblk):
        # qblk: (b, qc, kv, g, hd)
        qp = q_pos[qi]  # (qc,)

        def kv_step(carry, inputs):
            m, l, acc = carry
            kblk, vblk, kp = inputs  # (b,kc,kv,hd), (b,kc,kv,hd), (kc,)
            s = jnp.einsum("bqkgd,bckd->bkgqc", qblk, kblk)  # (b,kv,g,qc,kc)
            mask = jnp.ones((qc, kc), bool)
            if causal:
                mask &= qp[:, None] >= kp[None, :]
            if window:
                mask &= qp[:, None] - kp[None, :] < window
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            pv = jnp.einsum("bkgqc,bckd->bkgqd", p, vblk)
            acc_new = acc * corr[..., None] + pv
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, kv, g, qc), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, kv, g, qc), jnp.float32)
        a0 = jnp.zeros((b, kv, g, qc, hd), jnp.float32)
        kvs = (kr.transpose(1, 0, 2, 3, 4), vr.transpose(1, 0, 2, 3, 4),
               k_pos)
        if unroll:  # roofline analysis: loop bodies visible to cost_analysis
            carry = (m0, l0, a0)
            for j in range(nk):
                carry, _ = kv_step(carry,
                                   jax.tree.map(lambda a: a[j], kvs))
            m, l, acc = carry
        else:
            (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0), kvs)
        out = acc / jnp.maximum(l, 1e-30)[..., None]    # (b,kv,g,qc,hd)
        return out.transpose(0, 3, 1, 2, 4)             # (b,qc,kv,g,hd)

    qrt = qr.transpose(1, 0, 2, 3, 4, 5)
    if unroll:
        outs = [one_q_block(i, qrt[i]) for i in range(nq)]
        out = jnp.stack(outs)
    else:
        out = jax.lax.map(lambda args: one_q_block(*args),
                          (jnp.arange(nq), qrt))
    # out: (nq, b, qc, kv, g, hd) -> (b, sq, h, hd)
    out = out.transpose(1, 0, 2, 3, 4, 5).reshape(b, sq, kv * g, hd)
    return out.astype(q.dtype)


def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     length: jax.Array, *, window: int = 0,
                     ring_pos: jax.Array | None = None) -> jax.Array:
    """Single-token attention against a KV cache.

    q (B, 1, H, hd); caches (B, S, KV, hd); ``length`` = number of valid
    entries (absolute tokens seen).  With ``window`` > 0 the cache is a ring
    buffer of size S == window and ``ring_pos`` gives the next write slot;
    validity is min(length, window) entries ending at ring_pos-1.
    """
    b, _, h, hd = q.shape
    s, kv = k_cache.shape[1], k_cache.shape[2]
    g = h // kv
    scale = 1.0 / math.sqrt(hd)
    qr = q.reshape(b, kv, g, hd).astype(jnp.float32) * scale
    kf = k_cache.astype(jnp.float32)
    vf = v_cache.astype(jnp.float32)
    scores = jnp.einsum("bkgd,bskd->bkgs", qr, kf)  # (b,kv,g,s)
    idx = jnp.arange(s)[None, :]                    # (1, s)
    if window:
        valid = idx < jnp.minimum(length, window)[:, None]
    else:
        valid = idx < length[:, None]
    scores = jnp.where(valid[:, None, None, :], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", p, vf)
    return out.reshape(b, 1, h, hd).astype(q.dtype)


# ---------------------------------------------------------------------------
# SwiGLU MLP
# ---------------------------------------------------------------------------

def swiglu_init(key: jax.Array, d: int, f: int, dtype: Any) -> tuple[Params, Axes]:
    k1, k2, k3 = jax.random.split(key, 3)
    return merge({
        "w_gate": dense_init(k1, d, f, ("embed", "mlp"), dtype),
        "w_up": dense_init(k2, d, f, ("embed", "mlp"), dtype),
        "w_down": dense_init(k3, f, d, ("mlp", "embed"), dtype),
    })


def swiglu(params: Params, x: jax.Array) -> jax.Array:
    from ..parallel.sharding import constrain
    gate = jax.nn.silu(x @ params["w_gate"])
    # Interior activations must carry the model axis on the HIDDEN dim
    # (never on seq): otherwise the w_down/w_up weight-gradient partial
    # products materialize at full (d, f) size per device.
    h = gate * (x @ params["w_up"])
    axes = ("batch",) + (None,) * (h.ndim - 2) + ("mlp",)
    return constrain(h, axes) @ params["w_down"]
