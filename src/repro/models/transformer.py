"""Block assembly: heterogeneous layer stacks with scan-over-repeats.

A :class:`repro.configs.ModelConfig` describes the per-layer block pattern
(``cfg.blocks``): global attention ("attn"), sliding-window attention
("local"), RG-LRU ("rec"), and xLSTM ("mlstm"/"slstm") blocks, each an
optional FFN (SwiGLU or MoE).  Layers are grouped into ``n_repeats`` copies
of the unit pattern and executed with ``jax.lax.scan`` over the repeats
(stacked parameters, leading "layers" axis) so the lowered HLO stays compact
for 100+-layer configs; remainder layers run unrolled.

Three execution modes share the same parameters:
  * ``forward_train``: full-sequence teacher-forced pass -> logits (+ MoE aux);
  * ``prefill``: full-sequence pass that also materializes the decode cache;
  * ``decode_step``: one token against the cache (attention KV / ring buffers,
    recurrent states), O(1) or O(window) per token.

Caches per kind:
  attn   {"k","v"}: (B, S_max, KV, hd) append buffer (valid prefix = length)
  local  {"k","v"}: (B, window, KV, hd) ring buffer (write slot = length mod w)
  rec    {"h": (B,w), "conv": (B,cw-1,w)}
  mlstm  (C~, n~, m) per head
  slstm  (c, n, m, h)
"""

from __future__ import annotations

import functools
import math
from typing import Any

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..parallel.sharding import constrain
from .layers import (Axes, Params, apply_rope, chunked_attention,
                     decode_attention, dense_init, merge, mrope_angles,
                     norm_init, rms_norm, rope_angles, swiglu, swiglu_init)
from .moe import moe_apply, moe_init
from .rglru import (rglru_block_apply, rglru_block_init, rglru_decode_step,
                    rglru_init_state)
from .xlstm import (mlstm_block_apply, mlstm_block_init, mlstm_decode_step,
                    mlstm_init_state, slstm_block_apply, slstm_block_init,
                    slstm_decode_step, slstm_init_state)

__all__ = ["init_params", "forward_train", "prefill", "decode_step",
           "init_cache", "param_dtype"]


def param_dtype(cfg: ModelConfig):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


# ---------------------------------------------------------------------------
# Per-block init
# ---------------------------------------------------------------------------

def _attn_init(cfg: ModelConfig, key: jax.Array) -> tuple[Params, Axes]:
    d, hd = cfg.d_model, cfg.head_dim
    ks = jax.random.split(key, 4)
    dt = param_dtype(cfg)
    return merge({
        "w_q": dense_init(ks[0], d, cfg.n_heads * hd, ("embed", "heads"), dt),
        "w_k": dense_init(ks[1], d, cfg.n_kv_heads * hd, ("embed", "kv_heads"), dt),
        "w_v": dense_init(ks[2], d, cfg.n_kv_heads * hd, ("embed", "kv_heads"), dt),
        "w_o": dense_init(ks[3], cfg.n_heads * hd, d, ("heads", "embed"), dt),
    })


def _ffn_init(cfg: ModelConfig, key: jax.Array) -> tuple[Params, Axes] | None:
    dt = param_dtype(cfg)
    if cfg.n_experts:
        return moe_init(key, cfg.d_model, cfg.n_experts,
                        cfg.expert_d_ff or cfg.d_ff, cfg.n_shared_experts,
                        dt, pad_to=cfg.pad_experts_to)
    if cfg.d_ff:
        return swiglu_init(key, cfg.d_model, cfg.d_ff, dt)
    return None


def _block_init(cfg: ModelConfig, kind: str, key: jax.Array
                ) -> tuple[Params, Axes]:
    dt = param_dtype(cfg)
    k_t, k_f = jax.random.split(key)
    pairs: dict[str, tuple[Any, Any]] = {
        "norm_t": norm_init(cfg.d_model, dt),
    }
    if kind in ("attn", "local"):
        pairs["attn"] = _attn_init(cfg, k_t)
    elif kind == "rec":
        pairs["rec"] = rglru_block_init(k_t, cfg.d_model, cfg.lru_width,
                                        cfg.conv1d_width, dt)
    elif kind == "mlstm":
        pairs["mlstm"] = mlstm_block_init(k_t, cfg.d_model, cfg.n_heads, dt)
    elif kind == "slstm":
        pairs["slstm"] = slstm_block_init(k_t, cfg.d_model, cfg.n_heads, dt)
    else:
        raise ValueError(f"unknown block kind {kind!r}")
    ffn = _ffn_init(cfg, k_f)
    if ffn is not None and kind not in ("mlstm", "slstm"):
        pairs["norm_f"] = norm_init(cfg.d_model, dt)
        pairs["ffn"] = ffn
    return merge(pairs)


def init_params(cfg: ModelConfig, key: jax.Array) -> tuple[Params, Axes]:
    """Initialize the full parameter tree (+ aligned logical-axes tree)."""
    dt = param_dtype(cfg)
    unit = cfg.block_unit
    n_rep = cfg.n_layers // len(unit)
    n_tail = cfg.n_layers - n_rep * len(unit)
    k_emb, k_head, k_layers, k_tail = jax.random.split(key, 4)

    pairs: dict[str, tuple[Any, Any]] = {}
    if cfg.embed_inputs:
        emb = jax.random.normal(k_emb, (cfg.vocab_size, cfg.d_model),
                                jnp.float32) * (1.0 / math.sqrt(cfg.d_model))
        pairs["embed"] = (emb.astype(dt), ("vocab", "embed"))
    pairs["norm_out"] = norm_init(cfg.d_model, dt)
    if not cfg.tie_embeddings:
        pairs["head"] = dense_init(k_head, cfg.d_model, cfg.vocab_size,
                                   ("embed", "vocab"), dt)

    # Stacked repeats: vmap the per-unit init over n_rep keys.
    def unit_init(k):
        ks = jax.random.split(k, len(unit))
        ps, axs = [], []
        for kind, kk in zip(unit, ks):
            p, a = _block_init(cfg, kind, kk)
            ps.append(p)
            axs.append(a)
        return tuple(ps), tuple(axs)

    rep_keys = jax.random.split(k_layers, max(n_rep, 1))
    stacked = jax.vmap(lambda k: unit_init(k)[0])(rep_keys)
    _, unit_axes = unit_init(rep_keys[0])
    stacked_axes = jax.tree.map(
        lambda a: ("layers",) + tuple(a), unit_axes,
        is_leaf=lambda x: isinstance(x, tuple) and len(x) > 0 and all(
            isinstance(e, (str, type(None))) for e in x))
    pairs["layers"] = (stacked, stacked_axes)

    if n_tail:
        tail_kinds = cfg.blocks[n_rep * len(unit):]
        tks = jax.random.split(k_tail, n_tail)
        tail = [_block_init(cfg, kind, k) for kind, k in zip(tail_kinds, tks)]
        pairs["tail"] = (tuple(t[0] for t in tail), tuple(t[1] for t in tail))
    return merge(pairs)


# ---------------------------------------------------------------------------
# Position tables
# ---------------------------------------------------------------------------

def _rope_tables(cfg: ModelConfig, batch: dict, positions: jax.Array):
    """cos/sin for the attention layers ((S,half) or (B,S,half) for M-RoPE)."""
    if cfg.mrope_sections is not None:
        thw = batch.get("positions_thw")
        if thw is None:  # text-only: (t,h,w) all equal the text position
            thw = jnp.broadcast_to(
                positions[..., None],
                positions.shape + (3,)).astype(jnp.int32)
            if thw.ndim == 2:
                thw = thw[None]
        return mrope_angles(thw, cfg.mrope_sections, cfg.head_dim,
                            cfg.rope_theta)
    return rope_angles(positions, cfg.head_dim, cfg.rope_theta)


# ---------------------------------------------------------------------------
# Per-block apply (three modes)
# ---------------------------------------------------------------------------

def _attn_apply(cfg: ModelConfig, kind: str, p: Params, x: jax.Array,
                cos, sin, *, q_offset: int = 0) -> jax.Array:
    b, s, d = x.shape
    hd = cfg.head_dim
    q = (x @ p["w_q"]).reshape(b, s, cfg.n_heads, hd)
    k = (x @ p["w_k"]).reshape(b, s, cfg.n_kv_heads, hd)
    v = (x @ p["w_v"]).reshape(b, s, cfg.n_kv_heads, hd)
    # Interior constraint: heads own the model axis (never seq here — a
    # seq-sharded interior forces full-size attention-weight grad partials).
    q = constrain(q, ("batch", None, "heads", None))
    k = apply_rope(k, cos, sin)
    q = apply_rope(q, cos, sin)
    if cfg.attn_layout == "repeat_kv":
        # Expand k/v to H heads so attention compute shards over the full
        # head dim even when KV heads < the model-axis extent.
        g = cfg.n_heads // cfg.n_kv_heads
        k = jnp.repeat(k, g, axis=2)
        v = jnp.repeat(v, g, axis=2)
        k = constrain(k, ("batch", "seq", "heads", None))
        v = constrain(v, ("batch", "seq", "heads", None))
    window = cfg.attn_window if kind == "local" else 0
    if cfg.attn_impl != "ref":
        from ..kernels import ops as _kops
        out = _kops.flash_attention(q, k, v, causal=cfg.causal,
                                    window=window, q_offset=q_offset,
                                    impl=cfg.attn_impl)
    else:
        out = chunked_attention(q, k, v, causal=cfg.causal, window=window,
                                q_offset=q_offset, q_chunk=cfg.attn_q_chunk,
                                kv_chunk=cfg.attn_kv_chunk,
                                unroll=cfg.unroll_inner)
    out = out.reshape(b, s, cfg.n_heads * hd)
    out = constrain(out, ("batch", None, "heads"))
    return out @ p["w_o"]


def _block_apply_full(cfg: ModelConfig, kind: str, p: Params, x: jax.Array,
                      cos, sin) -> tuple[jax.Array, jax.Array]:
    """Training-mode apply: returns (x_out, moe_aux)."""
    aux = jnp.zeros((), jnp.float32)
    h = rms_norm(x, p["norm_t"], cfg.norm_eps)
    if kind in ("attn", "local"):
        x = x + _attn_apply(cfg, kind, p["attn"], h, cos, sin)
    elif kind == "rec":
        out, _ = rglru_block_apply(p["rec"], h)
        x = x + out
    elif kind == "mlstm":
        out, _ = mlstm_block_apply(p["mlstm"], h, n_heads=cfg.n_heads,
                                   chunk=cfg.mlstm_chunk,
                                   unroll=cfg.unroll_inner)
        return x + out, aux
    elif kind == "slstm":
        out, _ = slstm_block_apply(p["slstm"], h, n_heads=cfg.n_heads)
        return x + out, aux
    if "ffn" in p:
        h = rms_norm(x, p["norm_f"], cfg.norm_eps)
        if cfg.n_experts:
            out, aux = moe_apply(p["ffn"], h, top_k=cfg.top_k,
                                 capacity_factor=cfg.capacity_factor)
        else:
            out = swiglu(p["ffn"], h)
        x = x + out
    x = constrain(x, ("batch", "seq", "embed"))
    return x, aux


def _block_prefill(cfg: ModelConfig, kind: str, p: Params, x: jax.Array,
                   cos, sin, cache_len: int) -> tuple[jax.Array, Any]:
    """Prefill-mode apply: returns (x_out, cache_entry)."""
    b, s, d = x.shape
    hd = cfg.head_dim
    h = rms_norm(x, p["norm_t"], cfg.norm_eps)
    if kind in ("attn", "local"):
        a = p["attn"]
        q = (h @ a["w_q"]).reshape(b, s, cfg.n_heads, hd)
        k = (h @ a["w_k"]).reshape(b, s, cfg.n_kv_heads, hd)
        v = (h @ a["w_v"]).reshape(b, s, cfg.n_kv_heads, hd)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
        window = cfg.attn_window if kind == "local" else 0
        if cfg.attn_layout == "repeat_kv":
            g = cfg.n_heads // cfg.n_kv_heads
            kx = constrain(jnp.repeat(k, g, axis=2),
                           ("batch", "seq", "heads", None))
            vx = constrain(jnp.repeat(v, g, axis=2),
                           ("batch", "seq", "heads", None))
        else:
            kx, vx = k, v
        out = chunked_attention(q, kx, vx, causal=cfg.causal, window=window,
                                q_chunk=cfg.attn_q_chunk,
                                kv_chunk=cfg.attn_kv_chunk,
                                unroll=cfg.unroll_inner)
        out = out.reshape(b, s, cfg.n_heads * hd) @ a["w_o"]
        x = x + out
        if kind == "local":
            w = cfg.attn_window
            # Ring buffer holding the last `w` keys; slot for pos t = t mod w.
            kw, vw = k[:, -w:], v[:, -w:]
            pad = w - kw.shape[1]
            if pad > 0:
                kw = jnp.pad(kw, ((0, 0), (0, pad), (0, 0), (0, 0)))
                vw = jnp.pad(vw, ((0, 0), (0, pad), (0, 0), (0, 0)))
            else:
                # Roll so that cache[t mod w] holds key at absolute pos t.
                shift = s % w
                kw = jnp.roll(kw, shift, axis=1)
                vw = jnp.roll(vw, shift, axis=1)
            entry = {"k": kw, "v": vw}
        else:
            pad = cache_len - s
            entry = {
                "k": jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0))),
                "v": jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0))),
            }
    elif kind == "rec":
        out, st = rglru_block_apply(p["rec"], h)
        x = x + out
        entry = st
    elif kind == "mlstm":
        out, st = mlstm_block_apply(p["mlstm"], h, n_heads=cfg.n_heads,
                                    chunk=cfg.mlstm_chunk,
                                    unroll=cfg.unroll_inner)
        return x + out, st
    elif kind == "slstm":
        out, st = slstm_block_apply(p["slstm"], h, n_heads=cfg.n_heads)
        return x + out, st
    if "ffn" in p:
        hf = rms_norm(x, p["norm_f"], cfg.norm_eps)
        if cfg.n_experts:
            out, _ = moe_apply(p["ffn"], hf, top_k=cfg.top_k,
                               capacity_factor=cfg.capacity_factor)
        else:
            out = swiglu(p["ffn"], hf)
        x = x + out
    return x, entry


def _block_decode(cfg: ModelConfig, kind: str, p: Params, x: jax.Array,
                  cache: Any, length: jax.Array, cos, sin
                  ) -> tuple[jax.Array, Any]:
    """Decode-mode apply: x (B,1,d); returns (x_out, new_cache_entry)."""
    b = x.shape[0]
    hd = cfg.head_dim
    h = rms_norm(x, p["norm_t"], cfg.norm_eps)
    if kind in ("attn", "local"):
        a = p["attn"]
        q = (h @ a["w_q"]).reshape(b, 1, cfg.n_heads, hd)
        k = (h @ a["w_k"]).reshape(b, 1, cfg.n_kv_heads, hd)
        v = (h @ a["w_v"]).reshape(b, 1, cfg.n_kv_heads, hd)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
        if kind == "local":
            w = cfg.attn_window
            slot = (length % w)[:, None]  # (B,1)
            kc = _scatter_time(cache["k"], k, slot)
            vc = _scatter_time(cache["v"], v, slot)
            win = w
        else:
            slot = length[:, None]
            kc = _scatter_time(cache["k"], k, slot)
            vc = _scatter_time(cache["v"], v, slot)
            win = 0
        if cfg.attn_impl != "ref":
            from ..kernels import ops as _kops
            out = _kops.decode_attention(q, kc, vc, length + 1, window=win,
                                         impl=cfg.attn_impl)
        else:
            out = decode_attention(q, kc, vc, length + 1, window=win)
        out = out.reshape(b, 1, cfg.n_heads * hd) @ a["w_o"]
        x = x + out
        entry = {"k": kc, "v": vc}
    elif kind == "rec":
        out, entry = rglru_decode_step(p["rec"], h, cache)
        x = x + out
    elif kind == "mlstm":
        out, entry = mlstm_decode_step(p["mlstm"], h, cache,
                                       n_heads=cfg.n_heads)
        return x + out, entry
    elif kind == "slstm":
        out, entry = slstm_decode_step(p["slstm"], h, cache)
        return x + out, entry
    if "ffn" in p:
        hf = rms_norm(x, p["norm_f"], cfg.norm_eps)
        if cfg.n_experts:
            out, _ = moe_apply(p["ffn"], hf, top_k=cfg.top_k,
                               capacity_factor=None)  # dropless for decode
        else:
            out = swiglu(p["ffn"], hf)
        x = x + out
    return x, entry


def _scatter_time(cache: jax.Array, new: jax.Array,
                  slot: jax.Array) -> jax.Array:
    """Write new (B,1,KV,hd) into cache (B,S,KV,hd) at per-batch slot (B,1)."""
    b, s = cache.shape[:2]
    oh = jax.nn.one_hot(slot[:, 0], s, dtype=cache.dtype)  # (B,S)
    return cache * (1.0 - oh[:, :, None, None]) + oh[:, :, None, None] * new


# ---------------------------------------------------------------------------
# Embedding / head
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1))
def _embed_lookup(shape, dtype_name, table: jax.Array,
                  tokens: jax.Array) -> jax.Array:
    return jnp.take(table, tokens, axis=0)


def _embed_lookup_fwd(shape, dtype_name, table, tokens):
    return _embed_lookup(shape, dtype_name, table, tokens), tokens


def _embed_lookup_bwd(shape, dtype_name, tokens, g):
    # Sharded embedding gradient: the default scatter-add gradient
    # materializes a replicated full-size fp32 (V, d) buffer per
    # microbatch; pinning the scatter operand to the embedding-table
    # sharding keeps it (vocab -> model, embed -> data) partitioned.
    # Accumulate in the incoming gradient dtype (bf16 under mixed
    # precision): an fp32 upcast here costs a 4.3 GB/device transient at
    # 405B scale for <1 useful bit (each vocab row sums only a handful of
    # token gradients per microbatch).
    zeros = constrain(jnp.zeros(shape, g.dtype), ("vocab", "embed"))
    flat_tok = tokens.reshape(-1)
    flat_g = g.reshape(-1, shape[1])
    dtable = zeros.at[flat_tok].add(flat_g)
    dtable = constrain(dtable, ("vocab", "embed"))
    return dtable.astype(dtype_name), None


_embed_lookup.defvjp(_embed_lookup_fwd, _embed_lookup_bwd)


def _embed(cfg: ModelConfig, params: Params, batch: dict) -> jax.Array:
    if not cfg.embed_inputs:
        return batch["frames"]
    table = params["embed"]
    x = _embed_lookup(table.shape, str(table.dtype), table,
                      batch["tokens"])
    if "vision_embeds" in batch:
        # Replace token embeddings at vision positions by patch embeddings
        # (frontend stub output), in order.
        mask = batch["vision_mask"]                    # (B,S) bool
        idx = jnp.clip(jnp.cumsum(mask, axis=1) - 1, 0,
                       batch["vision_embeds"].shape[1] - 1)
        gathered = jnp.take_along_axis(
            batch["vision_embeds"], idx[..., None], axis=1)
        x = jnp.where(mask[..., None], gathered.astype(x.dtype), x)
    return x


def _head(cfg: ModelConfig, params: Params, x: jax.Array) -> jax.Array:
    x = rms_norm(x, params["norm_out"], cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = x @ params["embed"].T
    else:
        logits = x @ params["head"]
    # Vocab MUST own the model axis here even under seq-parallel rules
    # (seq is listed first and would steal it): an unsharded-vocab dlogits
    # makes the head-weight gradient materialize as a full-size fp32
    # partial product on every device (8.4 GB for llama3-405b).
    return constrain(logits, ("batch", None, "vocab"))


# ---------------------------------------------------------------------------
# Full passes
# ---------------------------------------------------------------------------

def _scan_over_repeats(cfg: ModelConfig, params: Params, x: jax.Array,
                       body_one):
    """Run the stacked repeats with lax.scan, then the unrolled tail.

    ``body_one(kind, layer_params, x, extra) -> (x, per_layer_out)``;
    returns (x, list of per-layer outs for the tail, stacked outs for scan).
    """
    unit = cfg.block_unit

    def step(x, unit_params):
        outs = []
        for kind, p in zip(unit, unit_params):
            x, o = body_one(kind, p, x)
            outs.append(o)
        return x, tuple(outs)

    if cfg.remat:
        policy = (jax.checkpoint_policies.nothing_saveable
                  if cfg.remat_policy == "nothing" else None)
        step_fn = jax.checkpoint(step, policy=policy)
    else:
        step_fn = step
    if cfg.scan_layers:
        x, stacked_outs = jax.lax.scan(step_fn, x, params["layers"])
    else:  # unrolled (roofline analysis variants)
        n_rep = cfg.n_layers // len(unit)
        outs = []
        for i in range(n_rep):
            sl = jax.tree.map(lambda p: p[i], params["layers"])
            x, o = step_fn(x, sl)
            outs.append(o)
        stacked_outs = jax.tree.map(lambda *xs: jnp.stack(xs), *outs) \
            if outs else ()
    tail_outs = []
    for kind, p in zip(cfg.blocks[len(cfg.blocks) - len(params.get("tail", ())):],
                       params.get("tail", ())):
        x, o = body_one(kind, p, x)
        tail_outs.append(o)
    return x, stacked_outs, tuple(tail_outs)


def forward_train(cfg: ModelConfig, params: Params, batch: dict
                  ) -> tuple[jax.Array, jax.Array]:
    """Teacher-forced pass. Returns (logits (B,S,V), moe_aux_loss scalar)."""
    x = _embed(cfg, params, batch)
    x = constrain(x, ("batch", "seq", "embed"))
    s = x.shape[1]
    positions = jnp.arange(s)
    cos, sin = _rope_tables(cfg, batch, positions)

    def body_one(kind, p, x):
        x, aux = _block_apply_full(cfg, kind, p, x, cos, sin)
        return x, aux

    x, aux_s, aux_t = _scan_over_repeats(cfg, params, x, body_one)
    aux = sum(a.sum() for a in aux_s) + sum(aux_t, jnp.zeros((), jnp.float32))
    return _head(cfg, params, x), aux


def prefill(cfg: ModelConfig, params: Params, batch: dict, *,
            cache_len: int) -> tuple[jax.Array, dict]:
    """Full-sequence pass materializing the decode cache.

    Returns (logits for the last position (B,V), cache).
    """
    x = _embed(cfg, params, batch)
    b, s = x.shape[:2]
    positions = jnp.arange(s)
    cos, sin = _rope_tables(cfg, batch, positions)

    def body_one(kind, p, x):
        return _block_prefill(cfg, kind, p, x, cos, sin, cache_len)

    x, stacked, tail = _scan_over_repeats(cfg, params, x, body_one)
    logits = _head(cfg, params, x[:, -1:])[:, 0]
    cache = {
        "layers": stacked,
        "tail": tail,
        "length": jnp.full((b,), s, jnp.int32),
    }
    return logits, cache


def init_cache(cfg: ModelConfig, batch_size: int, cache_len: int) -> dict:
    """Zero-initialized decode cache (for serve_step dry-runs and tests)."""
    dt = param_dtype(cfg)
    unit = cfg.block_unit
    n_rep = cfg.n_layers // len(unit)

    def entry(kind):
        if kind == "attn":
            shape = (batch_size, cache_len, cfg.n_kv_heads, cfg.head_dim)
            return {"k": jnp.zeros(shape, dt), "v": jnp.zeros(shape, dt)}
        if kind == "local":
            # Ring buffer is always window-sized (prefill allocates the
            # same, so init_cache and prefill caches are interchangeable).
            w = cfg.attn_window
            shape = (batch_size, w, cfg.n_kv_heads, cfg.head_dim)
            return {"k": jnp.zeros(shape, dt), "v": jnp.zeros(shape, dt)}
        if kind == "rec":
            return rglru_init_state(batch_size, cfg.lru_width,
                                    cfg.conv1d_width, dt)
        if kind == "mlstm":
            return mlstm_init_state(batch_size, cfg.n_heads,
                                    2 * cfg.d_model // cfg.n_heads)
        if kind == "slstm":
            return slstm_init_state(batch_size, cfg.d_model)
        raise ValueError(kind)

    stacked = jax.tree.map(
        lambda *xs: jnp.stack(xs),
        *[tuple(entry(k) for k in unit) for _ in range(n_rep)]) \
        if n_rep > 1 else jax.tree.map(lambda x: x[None],
                                       tuple(entry(k) for k in unit))
    n_tail = cfg.n_layers - n_rep * len(unit)
    tail = tuple(entry(k) for k in cfg.blocks[cfg.n_layers - n_tail:])
    return {"layers": stacked, "tail": tail,
            "length": jnp.zeros((batch_size,), jnp.int32)}


def cache_axes(cfg: ModelConfig) -> dict:
    """Logical-axes tree aligned with :func:`init_cache`'s output.

    The "seq" axis of KV caches is what decode-mode sharding rules map to the
    "model" mesh axis (32k-deep caches do not fit per-device otherwise); all
    recurrent states shard on batch only.
    """
    def entry(kind):
        if kind in ("attn", "local"):
            kv = ("batch", "seq", "kv_heads", None)
            return {"k": kv, "v": kv}
        if kind == "rec":
            return {"h": ("batch", "lru"), "conv": ("batch", None, "lru")}
        if kind == "mlstm":
            return (("batch", "heads", None, None),
                    ("batch", "heads", None), ("batch", "heads"))
        if kind == "slstm":
            return tuple(("batch", None) for _ in range(4))
        raise ValueError(kind)

    unit = cfg.block_unit
    n_rep = cfg.n_layers // len(unit)
    is_axes = lambda x: isinstance(x, tuple) and len(x) > 0 and all(
        isinstance(e, (str, type(None))) for e in x)
    stacked = jax.tree.map(lambda a: ("layers",) + a,
                           tuple(entry(k) for k in unit), is_leaf=is_axes)
    n_tail = cfg.n_layers - n_rep * len(unit)
    tail = tuple(entry(k) for k in cfg.blocks[cfg.n_layers - n_tail:])
    return {"layers": stacked, "tail": tail, "length": ("batch",)}


def decode_step(cfg: ModelConfig, params: Params, token: jax.Array,
                cache: dict, positions_thw: jax.Array | None = None
                ) -> tuple[jax.Array, dict]:
    """One decode step. token (B,) int32 -> (logits (B,V), new cache).

    ``positions_thw`` (B, 3) overrides the M-RoPE position of the new token
    for VLM archs (text continuation positions depend on the image grid);
    default is (length, length, length).
    """
    batch = {"tokens": token[:, None]}
    if not cfg.embed_inputs:
        raise ValueError(f"{cfg.name}: encoder-only model has no decode step")
    x = _embed(cfg, params, batch)
    length = cache["length"]
    positions = length[:, None]                      # (B,1) per-batch position
    if cfg.mrope_sections is not None:
        if positions_thw is None:
            thw = jnp.broadcast_to(positions[..., None],
                                   positions.shape + (3,)).astype(jnp.int32)
        else:
            thw = positions_thw[:, None, :].astype(jnp.int32)
        cos, sin = mrope_angles(thw, cfg.mrope_sections, cfg.head_dim,
                                cfg.rope_theta)
    else:
        cos, sin = rope_angles(positions, cfg.head_dim, cfg.rope_theta)

    unit = cfg.block_unit

    def step(x, xs):
        unit_params, unit_cache = xs
        new_entries = []
        for kind, p, c in zip(unit, unit_params, unit_cache):
            x, e = _block_decode(cfg, kind, p, x, c, length, cos, sin)
            new_entries.append(e)
        return x, tuple(new_entries)

    if cfg.scan_layers:
        x, new_stacked = jax.lax.scan(
            step, x, (params["layers"], cache["layers"]))
    else:  # unrolled (roofline analysis variants)
        n_rep = cfg.n_layers // len(unit)
        outs = []
        for i in range(n_rep):
            sl = jax.tree.map(lambda p: p[i], params["layers"])
            cl = jax.tree.map(lambda c: c[i], cache["layers"])
            x, o = step(x, (sl, cl))
            outs.append(o)
        new_stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *outs)
    new_tail = []
    tail_params = params.get("tail", ())
    tail_kinds = cfg.blocks[cfg.n_layers - len(tail_params):]
    for kind, p, c in zip(tail_kinds, tail_params, cache["tail"]):
        x, e = _block_decode(cfg, kind, p, x, c, length, cos, sin)
        new_tail.append(e)
    logits = _head(cfg, params, x)[:, 0]
    return logits, {"layers": new_stacked, "tail": tuple(new_tail),
                    "length": length + 1}
