"""Public model API: losses, batch construction, input specs.

``input_specs(cfg, shape)`` returns ShapeDtypeStruct stand-ins for every model
input of an (architecture x input-shape) pair — the dry-run lowers against
these without allocating anything.  ``make_batch`` builds the matching
concrete random batch for CPU smoke tests.  ``loss_fn`` dispatches between
next-token LM loss (decoder archs) and masked-prediction loss (encoder-only
audio archs), always computed in fp32 with a logsumexp cross-entropy.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from ..configs.base import InputShape, ModelConfig
from . import transformer
from .transformer import (decode_step, forward_train, init_cache, init_params,
                          param_dtype, prefill)

__all__ = ["init_params", "forward_train", "prefill", "decode_step",
           "init_cache", "loss_fn", "input_specs", "make_batch",
           "cache_len_for", "state_bytes"]

# Vision stub geometry for VLM input specs: fraction of the sequence that is
# image patches (dynamic-resolution stand-in).
_VISION_FRACTION = 0.25


def cache_len_for(cfg: ModelConfig, shape: InputShape) -> int:
    """Decode-cache length for a shape (cache covers the full context)."""
    return shape.seq_len


def _pick(logits: jax.Array, targets: jax.Array) -> jax.Array:
    """logits[..., targets] via a masked reduction over the vocab axis.

    A gather (take_along_axis) over the model-sharded vocab axis would make
    GSPMD all-gather the full logits tensor (hundreds of GB at train_4k
    scale); the iota-mask reduction keeps the contraction local + a scalar
    all-reduce.
    """
    v = logits.shape[-1]
    iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1)
    hit = iota == targets[..., None]
    return jnp.sum(jnp.where(hit, logits, 0.0), axis=-1)


def _lm_loss(cfg: ModelConfig, logits: jax.Array, tokens: jax.Array
             ) -> jax.Array:
    """Next-token cross entropy: predict tokens[:, 1:] from logits[:, :-1]."""
    logits = logits[:, :-1].astype(jnp.float32)
    targets = tokens[:, 1:]
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    picked = _pick(logits, targets)
    return jnp.mean(lse - picked)


def _masked_loss(cfg: ModelConfig, logits: jax.Array, labels: jax.Array,
                 mask: jax.Array) -> jax.Array:
    """Masked-prediction CE over the codebook (HuBERT-style)."""
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    picked = _pick(logits, labels)
    per_tok = (lse - picked) * mask
    return per_tok.sum() / jnp.maximum(mask.sum(), 1.0)


def loss_fn(cfg: ModelConfig, params: Any, batch: dict) -> tuple[jax.Array, dict]:
    """Training loss (+ metrics dict). Differentiable in ``params``."""
    logits, moe_aux = forward_train(cfg, params, batch)
    if cfg.embed_inputs:
        loss = _lm_loss(cfg, logits, batch["tokens"])
    else:
        loss = _masked_loss(cfg, logits, batch["labels"], batch["mask"])
    total = loss + cfg.router_aux_coef * moe_aux
    return total, {"loss": loss, "moe_aux": moe_aux}


# ---------------------------------------------------------------------------
# Input specs / batches
# ---------------------------------------------------------------------------

def _batch_shapes(cfg: ModelConfig, shape: InputShape) -> dict[str, tuple]:
    """(shape, dtype) for each input of the *training/prefill* batch."""
    b, s = shape.global_batch, shape.seq_len
    dt = param_dtype(cfg)
    if not cfg.embed_inputs:  # audio encoder: frame embeddings + targets
        out = {"frames": ((b, s, cfg.d_model), dt),
               "labels": ((b, s), jnp.int32),
               "mask": ((b, s), jnp.bool_)}
        return out
    out = {"tokens": ((b, s), jnp.int32)}
    if cfg.mrope_sections is not None:  # VLM: patches + 3-D positions
        n_patches = int(s * _VISION_FRACTION)
        out["vision_embeds"] = ((b, n_patches, cfg.d_model), dt)
        out["vision_mask"] = ((b, s), jnp.bool_)
        out["positions_thw"] = ((b, s, 3), jnp.int32)
    return out


def input_specs(cfg: ModelConfig, shape: InputShape,
                sharding_fn=None) -> dict[str, Any]:
    """ShapeDtypeStruct stand-ins for the batch of (cfg, shape).

    For decode shapes the spec is {"token": (B,), "cache": ...} matching
    ``serve_step``.  ``sharding_fn(shape_tuple, kind)`` may attach shardings.
    """
    def sds(shp, dt):
        return jax.ShapeDtypeStruct(shp, dt)

    if shape.kind == "decode":
        cache = init_cache_specs(cfg, shape.global_batch,
                                 cache_len_for(cfg, shape))
        return {"token": sds((shape.global_batch,), jnp.int32),
                "cache": cache}
    return {k: sds(*v) for k, v in _batch_shapes(cfg, shape).items()}


def init_cache_specs(cfg: ModelConfig, batch: int, cache_len: int) -> Any:
    """ShapeDtypeStruct tree matching :func:`transformer.init_cache`."""
    return jax.eval_shape(
        lambda: init_cache(cfg, batch, cache_len))


def make_batch(cfg: ModelConfig, shape: InputShape, key: jax.Array) -> dict:
    """Concrete random batch (CPU smoke tests)."""
    b, s = shape.global_batch, shape.seq_len
    dt = param_dtype(cfg)
    ks = jax.random.split(key, 4)
    if not cfg.embed_inputs:
        return {
            "frames": jax.random.normal(ks[0], (b, s, cfg.d_model), dt),
            "labels": jax.random.randint(ks[1], (b, s), 0, cfg.vocab_size),
            "mask": jax.random.bernoulli(ks[2], 0.35, (b, s)),
        }
    out = {"tokens": jax.random.randint(ks[0], (b, s), 0, cfg.vocab_size)}
    if cfg.mrope_sections is not None:
        n_patches = max(1, int(s * _VISION_FRACTION))
        out["vision_embeds"] = jax.random.normal(
            ks[1], (b, n_patches, cfg.d_model), dt)
        # First n_patches positions are vision tokens (simple interleave stub).
        pos = jnp.arange(s)
        out["vision_mask"] = jnp.broadcast_to(pos < n_patches, (b, s))
        # Text positions continue after the (t,h,w) grid of the image.
        grid = int(n_patches ** 0.5) + 1
        t = jnp.where(pos < n_patches, 0, pos - n_patches + grid)
        h = jnp.where(pos < n_patches, (pos // grid) % grid,
                      pos - n_patches + grid)
        w = jnp.where(pos < n_patches, pos % grid, pos - n_patches + grid)
        out["positions_thw"] = jnp.broadcast_to(
            jnp.stack([t, h, w], axis=-1), (b, s, 3)).astype(jnp.int32)
    return out


def state_bytes(params: Any, opt_state: Any = None) -> int:
    """Total bytes of a (params, optimizer) state tree (checkpoint payload)."""
    total = 0
    for leaf in jax.tree.leaves(params) + (
            jax.tree.leaves(opt_state) if opt_state is not None else []):
        total += leaf.size * leaf.dtype.itemsize
    return total
