"""Model definitions: layers, MoE, recurrent blocks, transformer assembly."""

from . import layers, model, moe, rglru, transformer, xlstm
from .model import (decode_step, forward_train, init_cache, init_params,
                    input_specs, loss_fn, make_batch, prefill)

__all__ = ["layers", "model", "moe", "rglru", "transformer", "xlstm",
           "init_params", "forward_train", "prefill", "decode_step",
           "init_cache", "loss_fn", "input_specs", "make_batch"]
