"""RG-LRU recurrent block (RecurrentGemma / Griffin, arXiv:2402.19427).

The block is: RMSNorm -> two parallel branches
  * gate branch:      linear d -> w, GeLU
  * recurrent branch: linear d -> w, short temporal conv1d (width 4), RG-LRU
then elementwise product and a linear w -> d back.

RG-LRU recurrence (per channel):
    r_t = sigmoid(W_a y_t + b_a)          (recurrence gate)
    i_t = sigmoid(W_x y_t + b_x)          (input gate)
    a_t = exp(c * r_t * log sigmoid(Lambda))   (c = -8 in the paper)
    h_t = a_t h_{t-1} + sqrt(1 - a_t^2) * (i_t * y_t)

Training uses a parallel associative scan over time (the recurrence is a
first-order linear one, so ``jax.lax.associative_scan`` applies); decode
carries ``h`` plus the last (conv_width - 1) conv inputs as state — O(1)
per token, which is what makes long_500k viable for this family.
"""

from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from .layers import Axes, Params, dense_init, merge

__all__ = ["rglru_block_init", "rglru_block_apply", "rglru_decode_step",
           "rglru_init_state"]

_C = -8.0  # paper's fixed exponent scale


def rglru_block_init(key: jax.Array, d: int, w: int, conv_width: int,
                     dtype: Any) -> tuple[Params, Axes]:
    k1, k2, k3, k4, k5, k6 = jax.random.split(key, 6)
    # Lambda init so that a = sigmoid(Lambda)^(c*r) covers slow/fast decays:
    # uniform a^2 in [0.9, 0.999] as in the Griffin paper.
    u = jax.random.uniform(k6, (w,), jnp.float32, 0.9, 0.999)
    lam = jnp.log(jnp.sqrt(u) / (1.0 - jnp.sqrt(u)))  # sigmoid^-1(sqrt(u))
    conv = jax.random.normal(k3, (conv_width, w), jnp.float32) \
        * (1.0 / math.sqrt(conv_width))
    params, axes = merge({
        "w_gate": dense_init(k1, d, w, ("embed", "lru"), dtype),
        "w_in": dense_init(k2, d, w, ("embed", "lru"), dtype),
        "w_out": dense_init(k4, w, d, ("lru", "embed"), dtype),
        "w_rg": dense_init(k5, w, 2 * w, ("lru", "lru"), dtype,
                           scale=1.0 / math.sqrt(w)),
    })
    params["conv"] = conv.astype(dtype)
    axes["conv"] = ("conv", "lru")
    params["lambda"] = lam  # keep fp32: gate parameter
    axes["lambda"] = ("lru",)
    params["b_rg"] = jnp.zeros((2 * w,), jnp.float32)
    axes["b_rg"] = ("lru",)
    return params, axes


def _causal_conv(y: jax.Array, conv: jax.Array,
                 prefix: jax.Array | None = None) -> jax.Array:
    """Depthwise causal conv over time. y (B,S,w); conv (cw, w).

    ``prefix`` (B, cw-1, w) supplies the state left of t=0 (zeros if None).
    """
    cw = conv.shape[0]
    if prefix is None:
        prefix = jnp.zeros(y.shape[:1] + (cw - 1,) + y.shape[2:], y.dtype)
    ypad = jnp.concatenate([prefix, y], axis=1)  # (B, S+cw-1, w)
    out = jnp.zeros_like(y)
    for i in range(cw):  # cw is 4: unrolled taps
        out = out + ypad[:, i:i + y.shape[1], :] * conv[cw - 1 - i]
    return out


def _rg_gates(params: Params, y: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Returns (log_a, gated_input) for the RG-LRU recurrence, fp32."""
    w = params["lambda"].shape[0]
    rg = y.astype(jnp.float32) @ params["w_rg"].astype(jnp.float32) \
        + params["b_rg"]
    r, i = rg[..., :w], rg[..., w:]
    r = jax.nn.sigmoid(r)
    i = jax.nn.sigmoid(i)
    log_a = _C * r * jax.nn.log_sigmoid(params["lambda"])  # (..., w) <= 0
    a = jnp.exp(log_a)
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.square(a), 1e-12))
    return log_a, beta * i * y.astype(jnp.float32)


def _linear_scan(log_a: jax.Array, b: jax.Array,
                 h0: jax.Array | None = None) -> jax.Array:
    """h_t = exp(log_a_t) h_{t-1} + b_t over axis 1 via associative scan."""
    if h0 is not None:
        # Fold the carry into the first step.
        b = b.at[:, 0].add(jnp.exp(log_a[:, 0]) * h0)

    def combine(x, y):
        la_x, bx = x
        la_y, by = y
        return la_x + la_y, jnp.exp(la_y) * bx + by

    _, h = jax.lax.associative_scan(combine, (log_a, b), axis=1)
    return h


def rglru_block_apply(params: Params, x: jax.Array,
                      state: Params | None = None,
                      ) -> tuple[jax.Array, Params]:
    """Full-sequence apply. x (B,S,d) -> (B,S,d), final recurrent state.

    ``state`` = {"h": (B,w), "conv": (B,cw-1,w)} carries across segments.
    """
    dtype = x.dtype
    gate = jax.nn.gelu(x @ params["w_gate"])
    y = x @ params["w_in"]                       # (B,S,w)
    prefix = state["conv"].astype(y.dtype) if state else None
    yc = _causal_conv(y, params["conv"], prefix)
    log_a, b = _rg_gates(params, yc)
    h0 = state["h"] if state else None
    h = _linear_scan(log_a, b, h0)               # (B,S,w) fp32
    out = (gate.astype(jnp.float32) * h).astype(dtype) @ params["w_out"]
    cw = params["conv"].shape[0]
    if prefix is None:
        prefix = jnp.zeros((y.shape[0], cw - 1, y.shape[2]), y.dtype)
    ytail = jnp.concatenate([prefix, y], axis=1)[:, -(cw - 1):, :]
    new_state = {"h": h[:, -1], "conv": ytail}
    return out, new_state


def rglru_init_state(batch: int, w: int, conv_width: int,
                     dtype: Any) -> Params:
    return {
        "h": jnp.zeros((batch, w), jnp.float32),
        "conv": jnp.zeros((batch, conv_width - 1, w), dtype),
    }


def rglru_decode_step(params: Params, x: jax.Array,
                      state: Params) -> tuple[jax.Array, Params]:
    """One-token step. x (B,1,d); state from :func:`rglru_init_state`."""
    dtype = x.dtype
    gate = jax.nn.gelu(x @ params["w_gate"])[:, 0]   # (B,w)
    y = (x @ params["w_in"])[:, 0]                   # (B,w)
    cw = params["conv"].shape[0]
    hist = jnp.concatenate([state["conv"], y[:, None, :]], axis=1)  # (B,cw,w)
    yc = jnp.einsum("bcw,cw->bw", hist.astype(jnp.float32),
                    params["conv"].astype(jnp.float32))
    log_a, b = _rg_gates(params, yc)
    h = jnp.exp(log_a) * state["h"] + b              # (B,w)
    out = (gate.astype(jnp.float32) * h).astype(dtype)[:, None, :] \
        @ params["w_out"]
    return out, {"h": h, "conv": hist[:, 1:, :].astype(state["conv"].dtype)}
