"""Checkpointing with fault prediction (paper §4).

Implements:
  * predictor algebra (recall r, precision p, event rates mu_P/mu_NP/mu_e);
  * the simple fixed-probability-q policy waste (Eq. 14) and the result that
    the optimal q is 0 or 1;
  * the refined policy: Theorem 1 (single breakpoint beta_lim = C_p / p);
  * the two-branch waste WASTE1/WASTE2 (Eq. 15) and its exact minimization
    (§4.3): convex analysis on [C, C_p/p] and cubic root-finding on
    [max(C, C_p/p), +inf);
  * the large-mu asymptotic period sqrt(2 mu C / (1 - r));
  * the post-proactive *cadence* correction: Eq. 15 implicitly restarts
    the period after every proactive checkpoint, while all three engines
    keep the original periodic cadence (``cadence="continue"``).  The
    first-order gap is :func:`cadence_correction`; ``waste2``/``t_pred``/
    ``optimal_period_with_prediction`` accept ``cadence="restart"``
    (paper, default) or ``"continue"`` (engine-faithful).
"""

from __future__ import annotations

import dataclasses
import math

import numpy as np

from .waste import ALPHA_CAP, Platform, t_rfo

__all__ = [
    "Predictor",
    "PredictedPlatform",
    "waste_simple_policy",
    "optimal_q",
    "beta_lim",
    "waste1",
    "waste2",
    "cadence_correction",
    "waste_with_prediction",
    "t_nopred",
    "t_pred",
    "optimal_period_with_prediction",
    "t_pred_asymptotic",
]


@dataclasses.dataclass(frozen=True)
class Predictor:
    """A fault predictor characterized by recall r and precision p (§2.2).

    recall r   = True_P / (True_P + False_N)  — fraction of faults predicted.
    precision p = True_P / (True_P + False_P) — fraction of predictions real.

    Predictions whose lead time is < C_p are classified as unpredicted faults
    (paper §2.2), which is a *recall adjustment* done by the caller.
    """

    recall: float
    precision: float

    def __post_init__(self) -> None:
        if not (0.0 <= self.recall <= 1.0):
            raise ValueError(f"recall must be in [0,1], got {self.recall}")
        if not (0.0 < self.precision <= 1.0):
            raise ValueError(f"precision must be in (0,1], got {self.precision}")

    # -- event rates (paper §2.3) -------------------------------------------
    def mu_np(self, mu: float) -> float:
        """Mean time between *unpredicted* faults: mu / (1 - r)."""
        if self.recall >= 1.0:
            return math.inf
        return mu / (1.0 - self.recall)

    def mu_p(self, mu: float) -> float:
        """Mean time between predictions (true or false): p mu / r."""
        if self.recall <= 0.0:
            return math.inf
        return self.precision * mu / self.recall

    def mu_e(self, mu: float) -> float:
        """Mean time between events of any kind: 1/mu_e = 1/mu_P + 1/mu_NP."""
        inv = 0.0
        if self.recall > 0.0:
            inv += 1.0 / self.mu_p(mu)
        if self.recall < 1.0:
            inv += 1.0 / self.mu_np(mu)
        return math.inf if inv == 0.0 else 1.0 / inv

    def mu_false(self, mu: float) -> float:
        """Mean time between *false* predictions: mu_P / (1-p) = p mu / (r (1-p))."""
        if self.precision >= 1.0 or self.recall <= 0.0:
            return math.inf
        return self.mu_p(mu) / (1.0 - self.precision)


@dataclasses.dataclass(frozen=True)
class PredictedPlatform:
    """Platform + predictor + proactive checkpoint cost C_p."""

    platform: Platform
    predictor: Predictor
    cp: float  # proactive checkpoint duration C_p

    def __post_init__(self) -> None:
        if self.cp <= 0:
            raise ValueError(f"C_p must be positive, got {self.cp}")


def beta_lim(pp: PredictedPlatform) -> float:
    """Trust breakpoint beta_lim = C_p / p (Theorem 1).

    A prediction arriving t seconds after the last periodic checkpoint should
    be acted upon iff t >= beta_lim.
    """
    return pp.cp / pp.predictor.precision


# ---------------------------------------------------------------------------
# Simple policy (§4.1): trust with fixed probability q
# ---------------------------------------------------------------------------

def waste_simple_policy(t: float, q: float, pp: PredictedPlatform) -> float:
    """Total waste of the simple policy (Eq. 14 plugged into Eq. 11)."""
    plat, pred = pp.platform, pp.predictor
    mu, c, cp = plat.mu, plat.c, pp.cp
    r, p = pred.recall, pred.precision
    if t < c:
        raise ValueError(f"T={t} < C={c}")
    wff = c / t
    wfault = (1.0 / mu) * (
        (1.0 - r * q) * t / 2.0
        + plat.d + plat.r
        + q * r / p * cp
        - q * r * cp * cp / (p * t) * (1.0 - p / 2.0)
    )
    return wff + wfault - wff * wfault


def optimal_q(t: float, pp: PredictedPlatform) -> int:
    """Optimal fixed trust probability: 0 or 1 (waste is linear in q).

    Compares the waste at q=0 and q=1 for the given period.
    """
    w0 = waste_simple_policy(t, 0.0, pp)
    w1 = waste_simple_policy(t, 1.0, pp)
    return 0 if w0 <= w1 else 1


# ---------------------------------------------------------------------------
# Refined policy (§4.2/§4.3): WASTE1 / WASTE2 and their minimization
# ---------------------------------------------------------------------------

def waste1(t: float, pp: PredictedPlatform) -> float:
    """WASTE1(T): no proactive action taken (valid when T <= C_p/p). Eq. 15."""
    plat = pp.platform
    mu, c = plat.mu, plat.c
    return (c * (1.0 - (plat.d + plat.r) / mu)) / t \
        + (plat.d + plat.r - c / 2.0) / mu \
        + t / (2.0 * mu)


def _waste2_coeffs(pp: PredictedPlatform) -> tuple[float, float, float, float]:
    """Coefficients (u, v, w, x) of WASTE2(T) = u/T^2 + v/T + w + x*T."""
    plat, pred = pp.platform, pp.predictor
    mu, c, cp = plat.mu, plat.c, pp.cp
    r, p = pred.recall, pred.precision
    dr = plat.d + plat.r
    u = r * c * cp * cp / (2.0 * mu * p * p)
    v = c * (1.0 - (r * cp / p + dr) / mu) - r * cp * cp / (2.0 * mu * p * p)
    w = (-(1.0 - r) * c / 2.0 + r * cp / p + dr) / mu
    x = (1.0 - r) / (2.0 * mu)
    return u, v, w, x


def cadence_correction(t: float, pp: PredictedPlatform) -> float:
    """First-order waste delta of the engines' continued periodic cadence.

    Eq. 15's WASTE2 implicitly *restarts* the period after every
    proactive checkpoint, so an unpredicted fault always loses T/2 on
    average — its re-execution term is (1-r) T / (2 mu).  The engines
    instead keep the original cadence (``simulator._complete_phase``:
    "Period continues"): an acted prediction at offset tau from the last
    periodic checkpoint *splits* the period's loss window into [0, tau]
    and [tau, T], and an unpredicted fault striking later in the same
    period rolls back only to the proactive save.  The time-averaged
    time-since-last-save over a split period is

        (tau^2/2 + (T - tau)^2/2) / T  =  T/2 - tau (T - tau) / T,

    so each acted prediction shaves E[tau (T - tau)] / T off the mean
    loss.  With acted offsets uniform on [beta_lim, T],
    E[tau (T - tau)] = (T - beta_lim)(T + 2 beta_lim) / 6, and acted
    predictions hit a period with expected multiplicity
    q = min(1, (T - beta_lim) / mu_P) (arrival rate 1/mu_P; clamped to
    one split per period — the regime the split formula models — which
    also keeps the corrected objective coercive in T).  The correction is

        Delta(T) = - (1-r)/mu * q * (T - beta_lim)(T + 2 beta_lim) / (6T)

    Delta <= 0 always: continued cadence *reduces* waste relative to the
    restart model, because the proactive save keeps protecting the rest
    of the period — this is the large-r/p model-vs-engine gap of ROADMAP
    item 6 (the restart model overestimates engine waste).  Returns 0
    when T <= beta_lim (no acted predictions), the predictor never fires
    (recall 0), or every fault is predicted (recall 1: no unpredicted
    faults to lose re-execution on).
    """
    plat, pred = pp.platform, pp.predictor
    beta = beta_lim(pp)
    if t <= beta or pred.recall <= 0.0 or pred.recall >= 1.0:
        return 0.0
    mu_p = pred.mu_p(plat.mu)
    q = min(1.0, (t - beta) / mu_p)
    split = (t - beta) * (t + 2.0 * beta) / (6.0 * t)
    return -(1.0 - pred.recall) / plat.mu * q * split


def _check_cadence(cadence: str) -> None:
    if cadence not in ("restart", "continue"):
        raise ValueError(f"cadence must be 'restart' or 'continue', "
                         f"got {cadence!r}")


def waste2(t: float, pp: PredictedPlatform, *,
           cadence: str = "restart") -> float:
    """WASTE2(T): proactive action for predictions in [C_p/p, T]. Eq. 15.

    ``cadence="restart"`` is the paper's model (period restarts after a
    proactive checkpoint); ``"continue"`` adds :func:`cadence_correction`
    to match the engines' continued periodic cadence.
    """
    _check_cadence(cadence)
    u, v, w, x = _waste2_coeffs(pp)
    base = u / (t * t) + v / t + w + x * t
    if cadence == "continue":
        base += cadence_correction(t, pp)
    return base


def waste_with_prediction(t: float, pp: PredictedPlatform, *,
                          cadence: str = "restart") -> float:
    """Waste of the optimal (Theorem 1) strategy at period T: the two-branch Eq. 15."""
    if t <= beta_lim(pp):
        return waste1(t, pp)
    return waste2(t, pp, cadence=cadence)


def t_nopred(pp: PredictedPlatform, alpha: float = ALPHA_CAP,
             enforce_cap: bool = False) -> float:
    """Minimizer of WASTE1 on [C, C_p/p] (Eq. 16): clamp T_RFO to the interval.

    When ``beta_lim(pp) < C`` the validity interval is empty — every legal
    period exceeds the trust breakpoint, so the WASTE1 branch does not exist
    and :func:`optimal_period_with_prediction` skips it.  The clamp below
    still returns C in that regime (callers that only need a feasible period
    keep working), but WASTE1 evaluated there is out of domain.
    """
    plat = pp.platform
    hi = beta_lim(pp)
    t = t_rfo(plat)
    if enforce_cap:
        t = min(t, alpha * plat.mu)
    return max(plat.c, min(t, hi))


def t_pred(pp: PredictedPlatform, *, cadence: str = "restart") -> float:
    """Minimizer of WASTE2 on [max(C, C_p/p), +inf) (Eq. 17).

    dWASTE2/dT = -2u/T^3 - v/T^2 + x = 0  <=>  x T^3 - v T - 2u = 0.
    Handles both the convex case (v >= 0: unique positive root) and the
    general case (v < 0: inspect all real roots and interval bounds).

    With ``cadence="continue"`` the corrected objective has no closed
    form; the cubic root seeds a deterministic grid + ternary refinement
    over [lo, ALPHA_CAP * mu].
    """
    _check_cadence(cadence)
    u, v, _, x = _waste2_coeffs(pp)
    lo = max(pp.platform.c, beta_lim(pp))
    if x <= 0.0:
        # r == 1: no unpredicted faults, so the linear term vanishes.  The
        # stationary point solves -2u/T^3 - v/T^2 = 0 -> T = -2u/v (v<0);
        # with v >= 0 waste2 = u/T^2 + v/T + w decreases monotonically —
        # periodic checkpoints are pure overhead — so return the paper's
        # rigor cap alpha*mu rather than the interval's (worst) low end.
        if v < 0.0 and u > 0.0:
            cubic = max(lo, -2.0 * u / v)
        else:
            cubic = max(lo, ALPHA_CAP * pp.platform.mu)
        candidates = [cubic]
    else:
        roots = np.roots([x, 0.0, -v, -2.0 * u])
        candidates = [lo]
        for root in roots:
            if abs(root.imag) < 1e-9 * max(1.0, abs(root.real)) \
                    and root.real > lo:
                candidates.append(float(root.real))
    if cadence == "restart":
        return min(candidates, key=lambda t: waste2(t, pp))

    # Continued cadence: minimize the corrected objective numerically.
    def f(t: float) -> float:
        return waste2(t, pp, cadence="continue")

    hi = max(ALPHA_CAP * pp.platform.mu, lo * 1.001, *candidates)
    grid = list(np.geomspace(lo, hi, 512)) + candidates
    grid = sorted(set(float(t) for t in grid))
    i = min(range(len(grid)), key=lambda j: f(grid[j]))
    a = grid[max(0, i - 1)]
    b = grid[min(len(grid) - 1, i + 1)]
    for _ in range(200):
        m1 = a + (b - a) / 3.0
        m2 = b - (b - a) / 3.0
        if f(m1) <= f(m2):
            b = m2
        else:
            a = m1
    t_best = 0.5 * (a + b)
    return min(grid[i], t_best, key=f)


def optimal_period_with_prediction(
        pp: PredictedPlatform, *,
        cadence: str = "restart") -> tuple[float, float, bool]:
    """Optimal period for the refined policy (§4.3).

    Returns (T*, waste(T*), use_predictions) where ``use_predictions`` tells
    whether the optimal regime is the WASTE2 branch (act on predictions past
    beta_lim) or the WASTE1 branch (ignore the predictor entirely).

    When ``beta_lim(pp) < C`` the WASTE1 validity interval [C, C_p/p] is
    empty — any legal period sits past the breakpoint, so the policy always
    acts and only the WASTE2 branch exists.

    ``cadence="continue"`` scores (and optimizes) the WASTE2 branch under
    the engines' continued periodic cadence; the WASTE1 branch never acts
    on predictions, so it needs no correction.
    """
    _check_cadence(cadence)
    tp = t_pred(pp, cadence=cadence)
    w2 = waste2(tp, pp, cadence=cadence)
    if beta_lim(pp) < pp.platform.c:
        return tp, w2, True
    tn = t_nopred(pp)
    w1 = waste1(tn, pp)
    if w1 <= w2:
        return tn, w1, False
    return tp, w2, True


def t_pred_asymptotic(pp: PredictedPlatform) -> float:
    """Large-mu approximation of the optimal period: sqrt(2 mu C / (1 - r)).

    (paper §4.3 closing remark — equivalent to RFO with mu -> mu/(1-r).)
    """
    r = pp.predictor.recall
    if r >= 1.0:
        return math.inf
    return math.sqrt(2.0 * pp.platform.mu * pp.platform.c / (1.0 - r))
