"""Failure / prediction trace generation (paper §5.1).

Produces the three event streams the simulator consumes:
  * fault times           — renewal process (Exponential, Weibull, Uniform,
                            log-based Empirical), either one platform-level
                            stream scaled to the platform MTBF mu, or the
                            superposition of N per-processor streams;
  * predicted flags       — emitted by a generative predictor model
                            (:mod:`repro.predictors`); the default
                            ``oracle`` predicts each fault with
                            probability r (recall);
  * false-prediction times — also predictor-emitted; the oracle uses a
                            renewal process with mean mu_P/(1-p)
                            = p mu /(r (1-p)).

Event encoding used throughout: structured arrays (time, kind) with kinds
  FAULT_UNPRED  actual fault, not predicted
  FAULT_PRED    actual fault, predicted (prediction date == fault date; the
                simulator adds the uncertainty window for InexactPrediction)
  FALSE_PRED    prediction that does not materialize
  SILENT        silent data corruption (arXiv:1310.8486): the strike is
                *latent* — the simulator only learns about it at the next
                verification point (or a detected fail-stop fault), and
                rolls back past any checkpoints taken while corrupted

Prediction *windows* (companion paper, arXiv:1302.4558): with ``window=I``
each prediction event additionally carries the announced interval length I
(``EventTrace.windows``) — the predictor promises the fault anywhere in
[t, t+I], and the simulator draws the materialization date from the lane
RNG.  ``window=0`` leaves ``windows`` unset, reproducing exact-date traces
bit-for-bit.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable, Iterable

import numpy as np

__all__ = [
    "FAULT_UNPRED",
    "FAULT_PRED",
    "FALSE_PRED",
    "SILENT",
    "EventTrace",
    "Distribution",
    "Exponential",
    "Weibull",
    "UniformDist",
    "LogNormalDist",
    "Empirical",
    "renewal_trace",
    "renewal_trace_bank",
    "superposed_trace",
    "superposed_trace_bank",
    "make_event_trace",
    "make_event_trace_bank",
    "lanl_like_log",
]

FAULT_UNPRED = 0
FAULT_PRED = 1
FALSE_PRED = 2
SILENT = 3


# ---------------------------------------------------------------------------
# Inter-arrival distributions (all parameterized by their MEAN, so that they
# can be rescaled to any platform MTBF as the paper does).
# ---------------------------------------------------------------------------

class Distribution:
    """Base class: inter-arrival time distribution with a controllable mean."""

    mean: float

    def sample(self, rng: np.random.Generator, size: int) -> np.ndarray:
        raise NotImplementedError

    def rescaled(self, mean: float) -> "Distribution":
        raise NotImplementedError


@dataclasses.dataclass(frozen=True)
class Exponential(Distribution):
    mean: float

    def sample(self, rng: np.random.Generator, size: int) -> np.ndarray:
        return rng.exponential(self.mean, size)

    def rescaled(self, mean: float) -> "Exponential":
        return Exponential(mean)


@dataclasses.dataclass(frozen=True)
class Weibull(Distribution):
    """Weibull with shape k; scale chosen so that the mean is ``mean``."""

    shape: float
    mean: float

    @property
    def scale(self) -> float:
        return self.mean / math.gamma(1.0 + 1.0 / self.shape)

    def sample(self, rng: np.random.Generator, size: int) -> np.ndarray:
        return self.scale * rng.weibull(self.shape, size)

    def rescaled(self, mean: float) -> "Weibull":
        return Weibull(self.shape, mean)


@dataclasses.dataclass(frozen=True)
class UniformDist(Distribution):
    """Uniform on [0, 2*mean] (used for false-prediction traces, Appendix B)."""

    mean: float

    def sample(self, rng: np.random.Generator, size: int) -> np.ndarray:
        return rng.uniform(0.0, 2.0 * self.mean, size)

    def rescaled(self, mean: float) -> "UniformDist":
        return UniformDist(mean)


@dataclasses.dataclass(frozen=True)
class LogNormalDist(Distribution):
    """LogNormal with given sigma; mu chosen to match the mean (extension)."""

    sigma: float
    mean: float

    def sample(self, rng: np.random.Generator, size: int) -> np.ndarray:
        mu = math.log(self.mean) - 0.5 * self.sigma ** 2
        return rng.lognormal(mu, self.sigma, size)

    def rescaled(self, mean: float) -> "LogNormalDist":
        return LogNormalDist(self.sigma, mean)


@dataclasses.dataclass(frozen=True)
class Empirical(Distribution):
    """Empirical distribution over observed availability intervals (paper §5.1,
    log-based traces).  Sampling = resampling the interval set, which realizes
    exactly the conditional law P(X >= t | X >= tau) described in the paper.
    """

    samples: tuple[float, ...]

    @property
    def mean(self) -> float:  # type: ignore[override]
        return float(np.mean(self.samples))

    def sample(self, rng: np.random.Generator, size: int) -> np.ndarray:
        arr = np.asarray(self.samples, dtype=np.float64)
        return rng.choice(arr, size=size, replace=True)

    def rescaled(self, mean: float) -> "Empirical":
        cur = self.mean
        return Empirical(tuple(float(s) * mean / cur for s in self.samples))


# ---------------------------------------------------------------------------
# Renewal processes
# ---------------------------------------------------------------------------

def renewal_trace(dist: Distribution, horizon: float,
                  rng: np.random.Generator) -> np.ndarray:
    """Arrival times of a renewal process on [0, horizon)."""
    if horizon <= 0:
        return np.empty(0, dtype=np.float64)
    # Draw in batches until the horizon is exceeded.
    est = max(16, int(horizon / max(dist.mean, 1e-12) * 1.5) + 8)
    chunks: list[np.ndarray] = []
    total = 0.0
    while total < horizon:
        draws = dist.sample(rng, est)
        draws = np.maximum(draws, 1e-9)  # guard zero inter-arrivals
        chunks.append(draws)
        total += float(draws.sum())
        est = max(16, est // 2)
    times = np.cumsum(np.concatenate(chunks))
    return times[times < horizon]


def renewal_trace_bank(dist: Distribution, horizon: float,
                       rng: np.random.Generator,
                       n_traces: int) -> list[np.ndarray]:
    """A whole bank of independent renewal traces from one generator.

    Each sampling wave draws a ``(still-running traces, est)`` matrix in a
    single RNG call instead of one batch per trace, so generating a
    200-trace bank costs a handful of vectorized draws.  The bank is
    statistically identical to ``[renewal_trace(dist, horizon, rng_i)]``
    but draws from one shared stream, so it is *not* sample-for-sample
    reproducible against per-trace seeded generation.
    """
    if horizon <= 0 or n_traces <= 0:
        return [np.empty(0, dtype=np.float64) for _ in range(n_traces)]
    est = max(16, int(horizon / max(dist.mean, 1e-12) * 1.5) + 8)
    chunks: list[list[np.ndarray]] = [[] for _ in range(n_traces)]
    totals = np.zeros(n_traces, dtype=np.float64)
    live = np.arange(n_traces)
    while live.size:
        draws = dist.sample(rng, live.size * est).reshape(live.size, est)
        draws = np.maximum(draws, 1e-9)
        for row, tr in enumerate(live):
            chunks[tr].append(draws[row])
        totals[live] += draws.sum(axis=1)
        live = live[totals[live] < horizon]
        est = max(16, est // 2)
    out = []
    for tr in range(n_traces):
        times = np.cumsum(np.concatenate(chunks[tr]))
        out.append(times[times < horizon])
    return out


def superposed_trace(dist_ind: Distribution, n: int, horizon: float,
                     rng: np.random.Generator) -> np.ndarray:
    """Superposition of n i.i.d. per-processor renewal processes (paper §5.1).

    Vectorized wave sampling: processors that have not yet exceeded the
    horizon draw their next inter-arrival together.
    """
    t = np.zeros(n, dtype=np.float64)
    out: list[np.ndarray] = []
    active = np.arange(n)
    while active.size:
        draws = np.maximum(dist_ind.sample(rng, active.size), 1e-9)
        t[active] = t[active] + draws
        hit = t[active] < horizon
        out.append(t[active][hit])
        active = active[hit]
    if not out:
        return np.empty(0, dtype=np.float64)
    return np.sort(np.concatenate(out))


def superposed_trace_bank(dist_ind: Distribution, n: int, horizon: float,
                          rng: np.random.Generator,
                          n_traces: int) -> list[np.ndarray]:
    """A bank of superposed traces: all ``n_traces * n`` processor streams
    advance in shared sampling waves (one RNG call per wave for the whole
    bank), then events are split back per trace and sorted."""
    if n_traces <= 0:
        return []
    # The surviving streams are carried as compacted (index, clock) pairs —
    # no scatter back into the full n_traces*n array, whose first wave would
    # dominate the cost for paper-sized platforms (2^16 procs per trace).
    t = np.maximum(dist_ind.sample(rng, n_traces * n), 1e-9)
    hit = t < horizon
    active = np.flatnonzero(hit)
    t = t[active]
    times_out: list[np.ndarray] = [t]
    owner_out: list[np.ndarray] = [active // n]
    while active.size:
        draws = np.maximum(dist_ind.sample(rng, active.size), 1e-9)
        t = t + draws
        hit = t < horizon
        t = t[hit]
        active = active[hit]
        times_out.append(t)
        owner_out.append(active // n)
    if not any(part.size for part in times_out):
        return [np.empty(0, dtype=np.float64) for _ in range(n_traces)]
    times = np.concatenate(times_out)
    owner = np.concatenate(owner_out)
    order = np.lexsort((times, owner))
    times, owner = times[order], owner[order]
    counts = np.bincount(owner, minlength=n_traces)
    return np.split(times, np.cumsum(counts)[:-1])


# ---------------------------------------------------------------------------
# Full event traces
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class EventTrace:
    """Merged, time-sorted platform event stream.

    ``windows`` (optional) carries the announced prediction-window length I
    per event: a FAULT_PRED / FALSE_PRED event at time t promises the fault
    in [t, t+I].  ``None`` means exact-date predictions (the simulator's
    ``inexact_window`` argument then acts as the per-run fallback width).
    """

    times: np.ndarray  # float64, ascending
    kinds: np.ndarray  # int8, FAULT_UNPRED/FAULT_PRED/FALSE_PRED/SILENT
    horizon: float
    windows: np.ndarray | None = None  # float64 per-event window length

    def __post_init__(self) -> None:
        if self.times.shape != self.kinds.shape:
            raise ValueError("times/kinds shape mismatch")
        if self.windows is not None and self.windows.shape != self.times.shape:
            raise ValueError("times/windows shape mismatch")

    @property
    def fault_times(self) -> np.ndarray:
        """Fail-stop fault dates (silent corruptions are not fail-stop)."""
        return self.times[(self.kinds == FAULT_UNPRED)
                          | (self.kinds == FAULT_PRED)]

    @property
    def n_faults(self) -> int:
        return int(np.sum((self.kinds == FAULT_UNPRED)
                          | (self.kinds == FAULT_PRED)))

    @property
    def silent_times(self) -> np.ndarray:
        return self.times[self.kinds == SILENT]

    @property
    def n_silent(self) -> int:
        return int(np.sum(self.kinds == SILENT))

    def empirical_mtbf(self) -> float:
        n = self.n_faults
        return math.inf if n == 0 else self.horizon / n


def make_event_trace(
    fault_dist: Distribution,
    mu: float,
    recall: float,
    precision: float,
    horizon: float,
    rng: np.random.Generator,
    *,
    false_pred_dist: Distribution | None = None,
    n_processors: int | None = None,
    window: float = 0.0,
    predictor_model=None,
    silent_mu: float | None = None,
    silent_dist: Distribution | None = None,
) -> EventTrace:
    """Build the merged event trace for one simulated instance (paper §5.1).

    If ``n_processors`` is given, faults come from the superposition of
    per-processor streams using ``fault_dist`` as the *individual* law
    (its mean is interpreted as mu_ind = mu * n).  Otherwise a single
    platform-level stream rescaled to mean ``mu`` is used.

    The prediction stream is generated by ``predictor_model`` (a
    :class:`repro.predictors.PredictorModel`), defaulting to the paper's
    ``oracle`` stamping: each fault predicted with probability r, false
    predictions from one renewal stream of ``false_pred_dist`` (same
    family as the fault distribution by default, per §5.2) rescaled to
    mean p*mu/(r*(1-p)).

    ``window > 0`` stamps every prediction event with the announced window
    length I (arXiv:1302.4558): the fault materializes in [t, t+I], the
    offset being drawn by the simulator.  ``window=0`` produces exact-date
    traces identical to before.  Per-event windows emitted by the
    predictor model (e.g. ``lead_time`` sampled leads) take precedence
    over the constant stamping.

    ``silent_mu`` (finite, positive) adds a silent-data-corruption stream
    (kind ``SILENT``) drawn from ``silent_dist`` (default Exponential)
    rescaled to that platform-level MTBF.  The stream is drawn *after* all
    other streams, so ``silent_mu=None`` (or infinite) reproduces the
    silent-free trace bit-for-bit from the same generator state.
    """
    if n_processors:
        faults = superposed_trace(fault_dist.rescaled(mu * n_processors),
                                  n_processors, horizon, rng)
    else:
        faults = renewal_trace(fault_dist.rescaled(mu), horizon, rng)

    if predictor_model is None:
        from repro.predictors.models import OraclePredictor
        predictor_model = OraclePredictor(recall, precision)
    stream = predictor_model.predict(
        faults, mu=mu, horizon=horizon, rng=rng,
        false_dist=false_pred_dist or fault_dist)

    silents = _silent_stream(silent_mu, silent_dist, horizon, rng)
    return _merge_events(faults, stream.kinds, stream.false_times, horizon,
                         window=window, true_windows=stream.true_windows,
                         false_windows=stream.false_windows, silents=silents)


def _silent_stream(silent_mu: float | None, silent_dist: Distribution | None,
                   horizon: float, rng: np.random.Generator
                   ) -> np.ndarray | None:
    """The silent-corruption renewal stream, or None when the rate is 0."""
    if silent_mu is None or not math.isfinite(silent_mu):
        return None
    if silent_mu <= 0.0:
        raise ValueError(f"silent_mu must be positive, got {silent_mu}")
    dist = (silent_dist or Exponential(1.0)).rescaled(silent_mu)
    return renewal_trace(dist, horizon, rng)


def _merge_events(faults: np.ndarray, kinds: np.ndarray,
                  false_preds: np.ndarray, horizon: float,
                  window: float = 0.0,
                  true_windows: np.ndarray | None = None,
                  false_windows: np.ndarray | None = None,
                  silents: np.ndarray | None = None) -> EventTrace:
    if silents is None:
        silents = np.empty(0, dtype=np.float64)
    times = np.concatenate([faults, false_preds, silents])
    all_kinds = np.concatenate(
        [kinds, np.full(false_preds.size, FALSE_PRED, dtype=np.int8),
         np.full(silents.size, SILENT, dtype=np.int8)])
    order = np.argsort(times, kind="stable")
    times, all_kinds = times[order], all_kinds[order]
    windows = None
    if window > 0.0 or true_windows is not None or false_windows is not None:
        # Prediction events (true and false) announce [t, t+I]; plain
        # faults and silent corruptions carry no window.  Per-event model
        # windows win over the constant stamping.
        wf = (np.asarray(true_windows, dtype=np.float64)
              if true_windows is not None
              else np.full(kinds.size, float(window)))
        wf = np.where(kinds == FAULT_UNPRED, 0.0, wf)
        wfp = (np.asarray(false_windows, dtype=np.float64)
               if false_windows is not None
               else np.full(false_preds.size, float(window)))
        windows = np.concatenate([wf, wfp, np.zeros(silents.size)])[order]
    return EventTrace(times, all_kinds, horizon, windows=windows)


def make_event_trace_bank(
    fault_dist: Distribution,
    mu: float,
    recall: float,
    precision: float,
    horizon: float,
    rng: np.random.Generator,
    *,
    false_pred_dist: Distribution | None = None,
    n_processors: int | None = None,
    n_traces: int = 1,
    window: float = 0.0,
    predictor_model=None,
    silent_mu: float | None = None,
    silent_dist: Distribution | None = None,
) -> list[EventTrace]:
    """A whole bank of merged event traces sampled from one generator.

    The vectorized counterpart of calling :func:`make_event_trace` once per
    trace: fault streams (including the N-processor superposition path)
    and the predictor model's bank-level prediction streams are each drawn
    in shared RNG waves.  Statistically identical to per-trace generation,
    but the draw order differs, so banks are reproducible per
    ``(rng seed, n_traces)`` — not per trace index.
    """
    if n_processors:
        fault_bank = superposed_trace_bank(
            fault_dist.rescaled(mu * n_processors), n_processors, horizon,
            rng, n_traces)
    else:
        fault_bank = renewal_trace_bank(fault_dist.rescaled(mu), horizon,
                                        rng, n_traces)

    if predictor_model is None:
        from repro.predictors.models import OraclePredictor
        predictor_model = OraclePredictor(recall, precision)
    streams = predictor_model.predict_bank(
        fault_bank, mu=mu, horizon=horizon, rng=rng,
        false_dist=false_pred_dist or fault_dist)

    # Silent streams are drawn last (one bank-level wave) so silent-free
    # banks stay bit-for-bit identical from the same generator state.
    if silent_mu is not None and math.isfinite(silent_mu):
        if silent_mu <= 0.0:
            raise ValueError(f"silent_mu must be positive, got {silent_mu}")
        sdist = (silent_dist or Exponential(1.0)).rescaled(silent_mu)
        silent_bank = renewal_trace_bank(sdist, horizon, rng, n_traces)
    else:
        silent_bank = [None] * n_traces

    return [_merge_events(f, s.kinds, s.false_times, horizon, window=window,
                          true_windows=s.true_windows,
                          false_windows=s.false_windows, silents=sil)
            for f, s, sil in zip(fault_bank, streams, silent_bank)]


def lanl_like_log(rng: np.random.Generator, n_intervals: int = 3010,
                  mu_ind_days: float = 691.0, shape: float = 0.6) -> Empirical:
    """Synthesize a LANL-18-like availability-interval log (see DESIGN.md §7).

    The real Failure Trace Archive files are not available offline; we generate
    an interval set once from a Weibull(k=0.6) whose mean matches the published
    per-processor MTBF, then treat it as an *empirical discrete distribution*
    exactly the way the paper treats the LANL logs.
    """
    base = Weibull(shape, mu_ind_days * 86400.0)
    samples = np.maximum(base.sample(rng, n_intervals), 60.0)
    return Empirical(tuple(float(s) for s in samples))
