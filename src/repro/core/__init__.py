"""Core analytical models + simulator for the paper
"Checkpointing algorithms and fault prediction" (Aupy et al., JPDC 2013).

Layers:
  waste.py       Young/Daly/RFO periods, first-order waste model, exact
                 Exponential optimum (Lambert W).
  prediction.py  predictor algebra, WASTE1/WASTE2 (Eq. 15), Theorem 1
                 breakpoint beta_lim = C_p/p, optimal periods.
  traces.py      fault / false-prediction trace generation (Exponential,
                 Weibull, Uniform, Empirical/log-based).
  simulator.py   discrete-event execution engine (paper §5 mechanics).
  batch.py       lane-parallel batched engine: all (candidate x trace)
                 lanes advanced together as SoA NumPy state, bit-for-bit
                 vs simulator.py (optional jax backend in batch_jax.py).
  policies.py    the compared strategies incl. BestPeriod search.
  windows.py     prediction *windows* (arXiv:1302.4558): waste formulas,
                 optimal periods and strategies for the interval [t, t+I]
                 prediction family (ignore / instant / within modes).
  exact.py       exact-Exponential renewal analysis (arXiv:1207.6936):
                 exact waste/makespan with and without prediction, the
                 exact trust threshold and numeric (T*, beta*) optimizers.
"""

from . import (batch, exact, policies, prediction, simulator, traces, waste,
               windows)
from .batch import BatchResult, simulate_batch
from .exact import (ExactPlan, beta_lim_exact, optimal_period_exact,
                    t_exact_nopred, waste_exact_nopred,
                    waste_exact_prediction)
from .prediction import (PredictedPlatform, Predictor, beta_lim,
                         optimal_period_with_prediction, t_pred,
                         t_pred_asymptotic, waste1, waste2,
                         waste_with_prediction)
from .simulator import SimResult, simulate
from .traces import EventTrace, Exponential, UniformDist, Weibull, make_event_trace
from .waste import Platform, platform_mtbf, t_daly, t_rfo, t_young, waste
from .windows import (WindowPlan, beta_lim_window, optimal_window_plan,
                      t_window_period, waste_window, window_strategy)

__all__ = [
    "batch", "exact", "policies", "prediction", "simulator", "traces",
    "waste", "windows",
    "BatchResult", "simulate_batch",
    "ExactPlan", "beta_lim_exact", "optimal_period_exact", "t_exact_nopred",
    "waste_exact_nopred", "waste_exact_prediction",
    "Platform", "Predictor", "PredictedPlatform", "EventTrace", "SimResult",
    "Exponential", "Weibull", "UniformDist",
    "platform_mtbf", "t_young", "t_daly", "t_rfo", "beta_lim",
    "optimal_period_with_prediction", "t_pred", "t_pred_asymptotic",
    "waste1", "waste2", "waste_with_prediction", "make_event_trace", "simulate",
    "WindowPlan", "beta_lim_window", "optimal_window_plan", "t_window_period",
    "waste_window", "window_strategy",
]
