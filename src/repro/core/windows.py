"""Checkpointing with prediction *windows* (Aupy et al., arXiv:1302.4558).

The companion paper generalizes exact-date predictions to time intervals:
the predictor announces that a fault will strike somewhere in [t, t+I].
This module mirrors :mod:`repro.core.prediction` for the window family —
first-order waste formulas and closed-form optimal periods for the three
action modes the simulator implements:

  * ``ignore``   — never act on predictions; the plain RFO analysis
                   (WASTE1) applies since every fault rolls back T/2 work
                   on average;
  * ``instant``  — take one proactive checkpoint completing at the window
                   start t, then work normally until the fault strikes at
                   t + U(0, I): the work done inside the window is lost,
                   adding r·I/2 expected re-execution per fault over the
                   exact-date WASTE2;
  * ``within``   — additionally keep taking proactive checkpoints of
                   length C_p every T_p seconds while the window is open,
                   bounding the work at risk to W_p = T_p - C_p at the
                   price of I·C_p/T_p checkpointing overhead per window.

All formulas are first-order (O(1/mu) fault rates, like Eq. 15) and
collapse to the exact-date results of :mod:`repro.core.prediction` at
I = 0, which the regression tests pin.
"""

from __future__ import annotations

import dataclasses
import math

from .policies import Strategy
from .prediction import PredictedPlatform, beta_lim, t_pred, waste2
from .simulator import NeverTrust, ThresholdTrust
from .waste import t_rfo

__all__ = [
    "WINDOW_STRATEGY_MODES",
    "WindowPlan",
    "beta_lim_window",
    "waste_window_ignore",
    "waste_window_instant",
    "waste_window_within",
    "waste_window",
    "t_window_period",
    "optimal_window_plan",
    "window_strategy",
]

# Simulator modes are ("instant", "within"); "ignore" is realized as a
# NeverTrust strategy, so it only exists at this analytic/strategy level.
WINDOW_STRATEGY_MODES = ("ignore", "instant", "within")


def _kappa(precision: float) -> float:
    """Expected in-window dwell fraction weight: a true prediction spends
    I/2 in the window on average, a false one the full I; per *trusted*
    prediction that is p·(I/2) + (1-p)·I = I·(2-p)/(2p) of window time per
    true-prediction-equivalent (normalizing by precision)."""
    return (2.0 - precision) / (2.0 * precision)


def beta_lim_window(pp: PredictedPlatform, window: float,
                    window_period: float | None = None) -> float:
    """Trust breakpoint for window predictions (Theorem-1 analogue).

    Acting on a prediction at offset ``o`` in the period saves p·o of
    expected rollback but costs the proactive checkpoint(s).  For
    ``instant`` mode the in-window loss I/2 is paid whether or not we act,
    so the breakpoint stays C_p/p.  For ``within`` mode (pass the
    in-window period T_p) acting also buys back the in-window loss
    (I/2 - min(W_p, I)/2) at the price of the in-window checkpointing
    overhead, shifting the breakpoint to

        C_p/p + I·C_p·(2-p)/(2p·T_p) - I/2 + min(T_p - C_p, I)/2

    clamped at 0.  Continuous in I, and equal to beta_lim at I = 0.
    """
    base = beta_lim(pp)
    if window <= 0.0 or window_period is None:
        return base
    cp, p = pp.cp, pp.predictor.precision
    wp = window_period - cp
    thr = base + window * cp * _kappa(p) / window_period \
        - window / 2.0 + min(wp, window) / 2.0
    return max(0.0, thr)


def waste_window_ignore(t: float, pp: PredictedPlatform,
                        window: float = 0.0) -> float:
    """Waste when predictions are ignored: WASTE1 for any T (the window
    length is irrelevant — every fault rolls back normally)."""
    from .prediction import waste1
    return waste1(t, pp)


def waste_window_instant(t: float, pp: PredictedPlatform,
                         window: float) -> float:
    """Waste of checkpoint-at-window-start: exact-date WASTE2 plus the
    expected in-window re-execution r·I/2 per fault."""
    r = pp.predictor.recall
    return waste2(t, pp) + r * window / (2.0 * pp.platform.mu)


def waste_window_within(t: float, pp: PredictedPlatform, window: float,
                        window_period: float) -> float:
    """Waste of periodic proactive checkpointing inside the window.

    Over the exact-date WASTE2: each *true* prediction loses only the work
    since the last in-window save (min(W_p, I)/2 in expectation, instead
    of I/2) but pays the in-window checkpoint overhead C_p/T_p for its
    expected dwell I/2; each *false* prediction pays the overhead for the
    full window I.  Rates: true predictions r/mu, false r(1-p)/(p·mu).
    """
    plat, pred = pp.platform, pp.predictor
    r, p = pred.recall, pred.precision
    cp = pp.cp
    wp = window_period - cp
    over = cp / window_period
    extra = r * (min(wp, window) / 2.0 + (window / 2.0) * over) \
        + (r * (1.0 - p) / p) * window * over
    return waste2(t, pp) + extra / plat.mu


def waste_window(t: float, pp: PredictedPlatform, window: float, mode: str,
                 window_period: float | None = None) -> float:
    """Dispatch on the window action mode (mirrors waste_with_prediction)."""
    if mode == "ignore":
        return waste_window_ignore(t, pp, window)
    if mode == "instant":
        return waste_window_instant(t, pp, window)
    if mode == "within":
        if window_period is None:
            raise ValueError("mode 'within' needs window_period")
        return waste_window_within(t, pp, window, window_period)
    raise ValueError(f"unknown window mode {mode!r} "
                     f"(expected one of {WINDOW_STRATEGY_MODES})")


def t_window_period(pp: PredictedPlatform, window: float) -> float:
    """Optimal in-window proactive period T_p* = sqrt(I·C_p·(2-p)/p).

    Minimizer of the T_p-dependent waste terms
    r·(T_p - C_p)/2 + r·I·C_p·kappa/T_p (valid while W_p <= I): balancing
    the work at risk against the in-window overhead, the exact analogue of
    the sqrt(2·mu·C) trade-off.  Returns inf when the window is empty.
    The caller decides degeneracy: T_p* <= C_p (window too small to fit
    work between checkpoints) or W_p* >= I (at most the initial checkpoint
    fits) both mean the ``instant`` mode is already optimal.
    """
    if window <= 0.0:
        return math.inf
    p = pp.predictor.precision
    return math.sqrt(2.0 * window * pp.cp * _kappa(p))


@dataclasses.dataclass(frozen=True)
class WindowPlan:
    """One mode's optimized operating point (mirrors
    optimal_period_with_prediction's tuple, plus the in-window period)."""

    mode: str
    period: float
    window_period: float  # inf when the mode takes no in-window checkpoints
    waste: float

    @property
    def use_predictions(self) -> bool:
        return self.mode != "ignore"


def _plan_for_mode(pp: PredictedPlatform, window: float,
                   mode: str) -> WindowPlan:
    c = pp.platform.c
    if mode == "ignore":
        t = max(c, t_rfo(pp.platform))
        return WindowPlan("ignore", t, math.inf,
                          waste_window_ignore(t, pp, window))
    if mode == "instant":
        t = t_pred(pp)
        return WindowPlan("instant", t, math.inf,
                          waste_window_instant(t, pp, window))
    # "within": the T and T_p optimizations separate (the extra waste terms
    # are T-free), so T* = t_pred and T_p* has the closed form above.
    tp = t_window_period(pp, window)
    if not math.isfinite(tp) or tp <= pp.cp or tp - pp.cp >= window:
        # Degenerate window: in-window checkpoints cannot pay off; the
        # instant plan is the within-mode optimum.
        t = t_pred(pp)
        return WindowPlan("instant", t, math.inf,
                          waste_window_instant(t, pp, window))
    t = t_pred(pp)
    return WindowPlan("within", t, tp,
                      waste_window_within(t, pp, window, tp))


def optimal_window_plan(pp: PredictedPlatform, window: float,
                        mode: str | None = None) -> WindowPlan:
    """The best plan for a window length I, over all modes or one mode.

    Mirrors :func:`repro.core.prediction.optimal_period_with_prediction`:
    compares the acting plans against ignoring the predictor and returns
    the winner (ties prefer not acting, like the WASTE1-first comparison).
    """
    if mode is not None:
        if mode not in WINDOW_STRATEGY_MODES:
            raise ValueError(f"unknown window mode {mode!r}")
        return _plan_for_mode(pp, window, mode)
    plans = [_plan_for_mode(pp, window, m) for m in WINDOW_STRATEGY_MODES]
    return min(plans, key=lambda pl: (pl.waste, pl.use_predictions))


def window_strategy(pp: PredictedPlatform, window: float, mode: str,
                    window_period: float | None = None) -> Strategy:
    """Build the simulator-ready strategy for a window mode.

    The strategy's ``inexact_window`` doubles as the fallback window width
    for traces without per-event windows, so the same strategy object runs
    against window-bearing banks (``ScenarioSpec.window``) and plain ones.
    """
    if mode == "ignore":
        plan = _plan_for_mode(pp, window, "ignore")
        return Strategy("WindowIgnore", plan.period, NeverTrust(),
                        inexact_window=window)
    if mode == "instant":
        plan = _plan_for_mode(pp, window, "instant")
        return Strategy("WindowStart", plan.period,
                        ThresholdTrust(beta_lim_window(pp, window)),
                        inexact_window=window)
    if mode == "within":
        plan = _plan_for_mode(pp, window, "within")
        if window_period is not None:
            # Fail here, at construction, rather than mid-sweep inside the
            # engines' own window_period validation.
            if window_period <= pp.cp:
                raise ValueError(f"window_period {window_period} <= C_p "
                                 f"{pp.cp}: no work fits between in-window "
                                 f"checkpoints")
            plan = dataclasses.replace(
                plan, mode="within", window_period=window_period,
                waste=waste_window_within(plan.period, pp, window,
                                          window_period))
        if plan.mode != "within":
            # Degenerate window: run as checkpoint-at-start under the
            # proactive strategy's name so sweep rows stay comparable.
            return Strategy("WindowProactive", plan.period,
                            ThresholdTrust(beta_lim_window(pp, window)),
                            inexact_window=window)
        thr = beta_lim_window(pp, window, plan.window_period)
        return Strategy("WindowProactive", plan.period, ThresholdTrust(thr),
                        inexact_window=window, window_mode="within",
                        window_period=plan.window_period)
    raise ValueError(f"unknown window mode {mode!r} "
                     f"(expected one of {WINDOW_STRATEGY_MODES})")
