"""Exact expected-makespan analysis under Exponential faults *with prediction*.

The first-order model of :mod:`repro.core.waste` / :mod:`repro.core.prediction`
(Eqs. 12/15) drops every O((T/mu)^2) term: it is the C/mu -> 0 limit.  The
companion research report "Impact of fault prediction on checkpointing
strategies" (Aupy et al., arXiv:1207.6936) keeps the full Exponential
expressions instead and derives the *exact* expected makespan of the
threshold policy, recovering the first-order formulas in the limit.  This
module is that exact layer, built as a renewal-reward analysis of the very
mechanics the simulator executes:

  * **cycles** run from one save (periodic checkpoint, proactive checkpoint,
    or completed recovery) to the next.  With Exponential faults every save
    is a regeneration point, so the renewal-reward theorem gives the exact
    steady-state waste  1 - E[work per cycle] / E[time per cycle];
  * within a cycle of span T = W + C the relevant event streams are Poisson:
    unpredicted faults (rate (1-r)/mu), true predictions (rate r/mu) and
    false predictions (rate r(1-p)/(p mu), relevant only where the policy
    acts on them); the *first event by date* decides the cycle outcome —
    exactly how the simulator's date-ordered queue resolves competing
    events;
  * a prediction announced for date offset ``o`` is acted upon iff
    ``o >= max(beta, C_p)`` and the proactive checkpoint fits before the
    periodic one (``o < W + C_p``): the machine saves ``o - C_p`` of work at
    ``o``, then either the fault strikes (true prediction: downtime follows,
    zero work lost) or it does not (false prediction: the C_p was the whole
    price);
  * repair is simulator-faithful: downtime D restarts on faults, recovery R
    sends the machine back to downtime, so the expected repair time is
    (e^{(D+R)/mu} - 1) mu — slightly different from the Bougeret et al.
    model cited in :func:`repro.core.waste.expected_makespan_exponential`,
    where downtime is fault-free (the two agree to O(((D+R)/mu)^2)).

Modeling deltas vs. the discrete-event engines (all second-order at the
paper's scales, bounded by the cross-validation tests):

  * the engines do *not* restart the periodic cadence after a proactive
    checkpoint (the next periodic checkpoint comes W - (o - C_p) later, not
    W) — the renewal model assumes a fresh period at every save;
  * when C_p > C a prediction dated shortly after T can still preempt the
    periodic checkpoint; the model caps the acting region at the cycle span;
  * first/last-period boundary effects, O(1/n_periods).

No closed form exists for the exact optimal (T, beta) in general: the
optimizers below use the Lambert-W solution where it exists (the
no-prediction branch) and bracketed golden-section minimization of the
closed-form waste everywhere else, per the paper's numerical approach.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable

from .prediction import PredictedPlatform, beta_lim, t_pred
from .waste import Platform, t_exact_exponential

__all__ = [
    "ExactPlan",
    "repair_time_exact",
    "expected_cycle_nopred",
    "waste_exact_nopred",
    "expected_makespan_exact_nopred",
    "t_exact_nopred",
    "exact_cycle_prediction",
    "waste_exact_prediction",
    "expected_makespan_exact_prediction",
    "beta_lim_exact",
    "optimal_period_exact_nopred",
    "optimal_period_exact",
    "minimize_scalar",
]


# ---------------------------------------------------------------------------
# Repair and the no-prediction branch (exact WASTE1 analogue)
# ---------------------------------------------------------------------------

def repair_time_exact(p: Platform) -> float:
    """Expected downtime-and-recovery time, faults restarting the downtime.

    The machine needs a fault-free span of D + R measured from the last
    restart (faults during D restart D; faults during R send it back to D),
    so  E = mu (e^{(D+R)/mu} - 1).  First order: D + R.
    """
    return p.mu * math.expm1((p.d + p.r) / p.mu)


def expected_cycle_nopred(t: float, p: Platform) -> float:
    """Exact expected time of one T-second cycle with no proactive action.

    Classic renewal argument: attempts until a fault-free span of T, each
    failed attempt costing the time to the fault plus the repair:
    E = (mu + Delta)(e^{T/mu} - 1).
    """
    if t <= p.c:
        raise ValueError(f"period T={t} must exceed C={p.c}")
    return (p.mu + repair_time_exact(p)) * math.expm1(t / p.mu)


def waste_exact_nopred(t: float, p: Platform) -> float:
    """Exact waste of the periodic policy ignoring all predictions.

    1 - (T - C)/E[cycle]; the exact analogue of WASTE1 (Eq. 15 left
    branch), to which it converges as C/mu -> 0.
    """
    return 1.0 - (t - p.c) / expected_cycle_nopred(t, p)


def expected_makespan_exact_nopred(t: float, time_base: float,
                                   p: Platform) -> float:
    """Exact expected makespan: time_base / (T - C) cycles of E[cycle]."""
    return time_base * expected_cycle_nopred(t, p) / (t - p.c)


def t_exact_nopred(p: Platform) -> float:
    """Exact optimal period, Lambert-W closed form.

    The repair prefactor (mu + Delta) is T-free, so the minimizer of
    E[cycle]/(T - C) is the same T* = C + mu (1 + W(-e^{-(C/mu + 1)})) as
    :func:`repro.core.waste.t_exact_exponential`.
    """
    return t_exact_exponential(p)


def optimal_period_exact_nopred(p: Platform) -> "ExactPlan":
    """The no-prediction exact plan (Lambert-W period, never trust)."""
    t = t_exact_nopred(p)
    return ExactPlan(period=t, threshold=math.inf,
                     waste=waste_exact_nopred(t, p), use_predictions=False)


# ---------------------------------------------------------------------------
# The prediction branch (exact WASTE2 analogue)
# ---------------------------------------------------------------------------

def _segment_integrals(s0: float, k: float, x0: float,
                       x1: float) -> tuple[float, float, float]:
    """(S(x1), int S, int S*o) over [x0, x1) for S(o) = s0 e^{-k (o - x0)}."""
    length = x1 - x0
    if length <= 0.0:
        return s0, 0.0, 0.0
    decay = math.exp(-k * length)
    i0 = s0 * -math.expm1(-k * length) / k
    # int_0^L e^{-k u} u du = (1 - e^{-kL})/k^2 - L e^{-kL}/k
    i1 = x0 * i0 + s0 * (-math.expm1(-k * length) / (k * k)
                         - length * decay / k)
    return s0 * decay, i0, i1


def exact_cycle_prediction(t: float, pp: PredictedPlatform,
                           beta: float) -> tuple[float, float]:
    """Exact (E[time], E[work]) of one cycle under the threshold policy.

    ``beta`` is the trust threshold: a prediction announced for date offset
    ``o`` (from the last save) triggers a proactive checkpoint completing
    at ``o`` iff ``o >= max(beta, C_p)`` and ``o < W + C_p`` (the engines'
    ignored-by-necessity regions).  Derivation in the module docstring; the
    three Poisson streams race, the first event by date decides:

      * unpredicted fault at ``o``  -> time o + Delta, no work secured;
      * true prediction at ``o``    -> acted: save o - C_p then the fault
        strikes (time o + Delta); not acted: plain fault at ``o``;
      * false prediction at ``o``   -> acted: save o - C_p, renew (time o);
        not acted: no effect (the stream is thinned to the acting region);
      * no event by T = W + C       -> the periodic save (time T, work W).
    """
    plat, pred = pp.platform, pp.predictor
    mu, c, cp = plat.mu, plat.c, pp.cp
    r, p = pred.recall, pred.precision
    if t <= c:
        raise ValueError(f"period T={t} must exceed C={c}")
    w = t - c
    lam = 1.0 / mu                       # all actual faults
    lam_t = r * lam                      # true predictions
    lam_f = r * lam * (1.0 - p) / p      # false predictions
    delta = repair_time_exact(plat)

    lo = max(beta, cp)                   # acting region [lo, hi)
    hi = min(w + cp, t)
    if lo >= hi:                         # the policy never acts
        ey = expected_cycle_nopred(t, plat) * math.exp(-t / mu)
        # expected_cycle_nopred is per *completed* cycle: convert to the
        # renewal-reward pair (E[Y], E[Z]) with E[Z] = W P(no fault).
        return ey, w * math.exp(-t / mu)

    # Survival S(o) piecewise: rate lam outside the acting region, lam +
    # lam_f inside (acted false predictions end the cycle there).
    s_lo, i0_a, i1_a = _segment_integrals(1.0, lam, 0.0, lo)
    s_hi, i0_b, i1_b = _segment_integrals(s_lo, lam + lam_f, lo, hi)
    s_t, i0_c, i1_c = _segment_integrals(s_hi, lam, hi, t)

    i0 = i0_a + i0_b + i0_c
    i1 = i1_a + i1_b + i1_c

    # E[time]: survival-to-T cycle, faults (true predictions included: the
    # fault strikes whether or not the proactive checkpoint was taken) and
    # acted false predictions.
    ey = s_t * t + lam * (i1 + delta * i0) + lam_f * i1_b
    # E[work]: the periodic save, plus o - C_p banked by every *acted*
    # prediction (true or false) in [lo, hi).
    ez = s_t * w + (lam_t + lam_f) * (i1_b - cp * i0_b)
    return ey, ez


def waste_exact_prediction(t: float, pp: PredictedPlatform,
                           beta: float | None = None) -> float:
    """Exact waste of the threshold policy (the WASTE2 analogue).

    ``beta`` defaults to the first-order Theorem-1 breakpoint C_p/p; pass
    :func:`beta_lim_exact` for the exact threshold.  Converges to
    :func:`repro.core.prediction.waste2` as C/mu -> 0.
    """
    beta = beta_lim(pp) if beta is None else beta
    ey, ez = exact_cycle_prediction(t, pp, beta)
    return 1.0 - ez / ey


def expected_makespan_exact_prediction(t: float, time_base: float,
                                       pp: PredictedPlatform,
                                       beta: float | None = None) -> float:
    """Exact expected makespan under the threshold policy."""
    beta = beta_lim(pp) if beta is None else beta
    ey, ez = exact_cycle_prediction(t, pp, beta)
    return time_base * ey / ez


# ---------------------------------------------------------------------------
# Numeric optimizers (no scipy: grid pre-scan + golden section)
# ---------------------------------------------------------------------------

_INVPHI = (math.sqrt(5.0) - 1.0) / 2.0


def minimize_scalar(f: Callable[[float], float], lo: float, hi: float,
                    *, n_scan: int = 48, tol: float = 1e-10) -> float:
    """Argmin of ``f`` on [lo, hi]: log-spaced grid scan to bracket the
    basin, then golden-section refinement.  Robust to the mild kinks of the
    piecewise-smooth exact waste (the scan pins the right basin; golden
    section needs only local unimodality)."""
    if hi <= lo:
        return lo
    if lo <= 0.0:
        grid = [lo + (hi - lo) * i / (n_scan - 1) for i in range(n_scan)]
    else:
        ratio = (hi / lo) ** (1.0 / (n_scan - 1))
        grid = [lo * ratio ** i for i in range(n_scan)]
    best_i = min(range(n_scan), key=lambda i: f(grid[i]))
    a = grid[max(0, best_i - 1)]
    b = grid[min(n_scan - 1, best_i + 1)]
    # Golden section on [a, b].
    x1 = b - _INVPHI * (b - a)
    x2 = a + _INVPHI * (b - a)
    f1, f2 = f(x1), f(x2)
    while (b - a) > tol * (1.0 + abs(a) + abs(b)):
        if f1 <= f2:
            b, x2, f2 = x2, x1, f1
            x1 = b - _INVPHI * (b - a)
            f1 = f(x1)
        else:
            a, x1, f1 = x1, x2, f2
            x2 = a + _INVPHI * (b - a)
            f2 = f(x2)
    return 0.5 * (a + b)


def beta_lim_exact(pp: PredictedPlatform, t: float | None = None) -> float:
    """Exact trust threshold: the beta minimizing the exact waste at T.

    The exact analogue of Theorem 1's beta_lim = C_p/p, to which it
    converges as C/mu -> 0 (the exact threshold also prices the work
    already banked when a false prediction forces an early save).  ``t``
    defaults to the exact optimal period at the first-order threshold.
    """
    if t is None:
        t = _best_period_at(pp, max(beta_lim(pp), pp.cp))
    hi = min(t - pp.platform.c + pp.cp, t)
    if hi <= pp.cp:
        return pp.cp
    return minimize_scalar(lambda b: waste_exact_prediction(t, pp, b),
                           pp.cp, hi)


def _best_period_at(pp: PredictedPlatform, beta: float) -> float:
    """Exact-waste-optimal period at a fixed trust threshold."""
    plat = pp.platform
    lo = plat.c * 1.0001
    hi = max(20.0 * max(t_pred(pp), t_exact_nopred(plat)), 4.0 * lo)
    return minimize_scalar(lambda t: waste_exact_prediction(t, pp, beta),
                           lo, hi)


@dataclasses.dataclass(frozen=True)
class ExactPlan:
    """One exact operating point (mirrors optimal_period_with_prediction's
    tuple, with the trust threshold made explicit)."""

    period: float
    threshold: float  # trust threshold beta; +inf = never trust
    waste: float
    use_predictions: bool


def optimal_period_exact(pp: PredictedPlatform,
                         refine_threshold: bool = True) -> ExactPlan:
    """Exact optimal plan: jointly optimized (T*, beta*) vs. never trusting.

    Coordinate descent on the closed-form exact waste — period at the
    Theorem-1 threshold, then the threshold at that period, then the period
    again (``refine_threshold=False`` keeps beta = C_p/p, the exact
    analogue of the paper's §4.3 procedure) — compared against the
    Lambert-W no-prediction optimum, ties preferring not to act.
    """
    ignore = optimal_period_exact_nopred(pp.platform)
    if pp.predictor.recall <= 0.0:
        return ignore
    beta = max(beta_lim(pp), pp.cp)
    t = _best_period_at(pp, beta)
    if refine_threshold:
        beta = beta_lim_exact(pp, t)
        t = _best_period_at(pp, beta)
    w = waste_exact_prediction(t, pp, beta)
    if w < ignore.waste:
        return ExactPlan(period=t, threshold=beta, waste=w,
                         use_predictions=True)
    return ignore
