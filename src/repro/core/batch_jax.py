"""JAX backend for the lane-parallel batched simulator (flagship engine).

The lane fleet advances inside a jitted ``lax.while_loop`` whose body is
the same pop / arrival / lockstep-schedule step as the NumPy engine in
:mod:`repro.core.batch`, structured as:

  * a **vmapped per-lane step** for the event pop and event arrival
    sections (each lane is a small scalar program over its own state and
    deferred-fault slots; ``jax.vmap`` lifts it over the lane axis), and
  * the **event-advance kernel** (:mod:`repro.kernels.event_step`) for
    the hot schedule step that touches every lane each iteration — a pure
    ``jnp`` reference by default, or the Pallas kernel
    (``REPRO_JAX_PALLAS=interpret|compile``) behind the compat shim.

Feature parity with the NumPy engine is complete: all four standard trust
policies, exact/inexact windows, per-event window tensors
(``EventTrace.windows``), both window action modes ("instant"/"within"),
and adaptive re-planning.  Lane randomness (FixedProbability trust draws,
in-window fault offsets) is **pre-drawn** per lane into stream-prefix
tables: every scalar-engine draw consumes exactly one float64 from the
lane's ``default_rng(seed)`` stream (``uniform(0, w)`` is bit-for-bit
``w * random()``), so the loop carries one cursor per lane and consumes
``table[lane, cursor]`` at exactly the scalar engine's draw sites.

Adaptive re-planning runs the estimator counters (and the online-MTBF
gap statistics of ``AdaptiveConfig(estimate_mu=True)``) on-device at the
same event-pop sites as the other engines; a vectorized prefilter
replays the confidence gate + hysteresis, and the few lanes that fire
re-plan on the host through the shared
:func:`repro.predictors.estimator.maybe_replan` via ``jax.pure_callback``
inside ``lax.cond`` — so replan points and plans are bit-for-bit the
scalar engine's.

Scale: the lane grid is **chunked** (``REPRO_JAX_CHUNK`` or the
``chunk`` argument; one XLA compilation serves all chunks, input buffers
are donated, so per-chunk memory stays flat) and each chunk can be
**sharded across devices** with ``jax.experimental.shard_map``
(``REPRO_JAX_SHARD=auto|0|1``; every device runs the while-loop on its
lane shard).  Host callbacks are unreliable inside ``shard_map``, so the
sharded path is used only for non-adaptive grids; adaptive grids take
the plain chunked path.

Requires ``jax_enable_x64`` so the float64 op sequence matches the
scalar engine bit-for-bit (float32 drifts far beyond the 1e-9
equivalence contract).  Each (chunk-size, event-width, table-width)
shape triggers one XLA compilation; reuse bank sizes across calls to
amortize it.
"""

from __future__ import annotations

import os
import time
from typing import Any, Sequence

import numpy as np

from .simulator import _CKPT, _DOWN, _PROCKPT, _RECOVER, _VERIFY, _WORK
from .traces import FALSE_PRED, FAULT_PRED, FAULT_UNPRED, SILENT
from .waste import Platform

__all__ = ["run_lanes_jax"]

_TRUST_NEVER, _TRUST_ALWAYS, _TRUST_THRESHOLD, _TRUST_FIXED_Q = range(4)
_WMODE_INSTANT, _WMODE_WITHIN = range(2)
_PC_POP, _PC_FAULT, _PC_PRED, _PC_FINAL, _PC_SILENT = range(5)
_DEF_SLOTS = 8          # deferred-fault capacity; overflow is detected
_BIG_SEQ = np.iinfo(np.int32).max
_ADV_PASSES = 4         # schedule steps per loop iteration (cf. numpy's 6)


def _draw_tables(bank, lane_trace: np.ndarray, lane_kind: np.ndarray,
                 lane_window: np.ndarray,
                 lane_seed: np.ndarray) -> np.ndarray:
    """Per-lane stream-prefix tables of pre-drawn uniforms.

    A lane consumes at most one draw per true prediction whose effective
    window is positive (the in-window fault offset) plus one per
    prediction event (the FixedProbability trust draw, consumed only when
    the decision is actually reached).  Per-event windows make the bound
    per *trace*: true predictions carrying their own positive window
    always draw; sentinel (-1) events draw iff the lane's fallback window
    is positive; explicit zero windows never draw.  The first ``need``
    values of the lane's ``default_rng(seed)`` stream bound every draw
    the scalar engine can make, in consumption order.
    """
    is_true = bank.kinds == FAULT_PRED
    n_pred = (is_true | (bank.kinds == FALSE_PRED)).sum(axis=1)
    if bank.windows is None:
        cnt_own = np.zeros(bank.kinds.shape[0], dtype=np.int64)
        cnt_fb = is_true.sum(axis=1)
    else:
        cnt_own = (is_true & (bank.windows > 0.0)).sum(axis=1)
        cnt_fb = (is_true & (bank.windows < 0.0)).sum(axis=1)
    need = (cnt_own[lane_trace]
            + cnt_fb[lane_trace] * (lane_window > 0.0)
            + n_pred[lane_trace] * (lane_kind == _TRUST_FIXED_Q)
            ).astype(np.int64)
    width = max(1, int(need.max()) if need.size else 1)
    tab = np.zeros((lane_trace.size, width), dtype=np.float64)
    for i in np.nonzero(need)[0]:
        n = int(need[i])
        tab[i, :n] = np.random.default_rng(int(lane_seed[i])).random(n)
    return tab


def _resolve_impl() -> str:
    """Event-step kernel implementation from ``REPRO_JAX_PALLAS``."""
    v = os.environ.get("REPRO_JAX_PALLAS", "").strip().lower()
    if v in ("", "0", "off", "ref"):
        return "ref"
    if v in ("interpret", "interpreter"):
        return "pallas_interpret"
    if v in ("1", "compile", "tpu", "pallas"):
        return "pallas"
    raise ValueError(f"unknown REPRO_JAX_PALLAS value {v!r}")


def run_lanes_jax(bank, platform: Platform, time_base: float,
                  lane_trace: np.ndarray, lane_period: np.ndarray,
                  lane_kind: np.ndarray, lane_param: np.ndarray,
                  lane_window: np.ndarray, lane_seed: np.ndarray,
                  cp: float,
                  lane_wmode: np.ndarray | None = None,
                  lane_wperiod: np.ndarray | None = None,
                  lane_adaptive: Sequence | None = None,
                  lane_nverify: np.ndarray | None = None,
                  lane_vcost: np.ndarray | None = None,
                  lane_keep: np.ndarray | None = None,
                  chunk: int | None = None) -> dict[str, Any]:
    import jax
    import jax.numpy as jnp
    from jax import lax

    from repro.kernels.event_step import (F_DONE, F_NOW, F_PERIOD, F_PHEND,
                                          F_PSTART, F_SAVED, F_SVCLEAN,
                                          F_TARGET, F_TCKPT, F_TDOWN,
                                          F_TDOWNT, F_TLOST, F_TPROC,
                                          F_TRECOV, F_TVERIFY, F_VREM,
                                          F_VWP, F_WINEND, F_WINREM, F_WPP,
                                          F_WREM, F_WWP, I_CORR, I_FIN,
                                          I_NCKPT, I_NDEEP, I_NDIRTY,
                                          I_NPROC, I_NROLL, I_NVERIF,
                                          I_PHASE, I_VTC, event_step)

    if not jax.config.jax_enable_x64:
        raise RuntimeError(
            "the jax backend needs float64 state for the scalar-equivalence "
            "contract; enable it (jax.config.update('jax_enable_x64', True) "
            "or JAX_ENABLE_X64=1) or use backend='numpy'")
    if np.any(lane_period < platform.c):
        raise ValueError(f"period below checkpoint {platform.c}")

    L = int(lane_trace.size)
    K = _DEF_SLOTS
    width = bank.times.shape[1]
    c, d, r = platform.c, platform.d, platform.r
    impl = _resolve_impl()

    lane_period = np.asarray(lane_period, dtype=np.float64).copy()
    lane_kind = np.asarray(lane_kind, dtype=np.int32).copy()
    lane_param = np.asarray(lane_param, dtype=np.float64).copy()
    lane_window = np.asarray(lane_window, dtype=np.float64)
    if lane_wmode is None:
        lane_wmode = np.zeros(L, dtype=np.int8)
    if lane_wperiod is None:
        lane_wperiod = np.zeros(L, dtype=np.float64)
    if lane_adaptive is None:
        lane_adaptive = [None] * L
    if lane_nverify is None:
        lane_nverify = np.zeros(L, dtype=np.int32)
    if lane_vcost is None:
        lane_vcost = np.zeros(L, dtype=np.float64)
    if lane_keep is None:
        lane_keep = np.ones(L, dtype=np.int32)
    lane_nverify = np.asarray(lane_nverify).astype(np.int32)
    lane_vcost = np.asarray(lane_vcost, dtype=np.float64)
    lane_keep = np.asarray(lane_keep).astype(np.int32)
    if np.any(lane_nverify < 0):
        raise ValueError("n_verify must be >= 0")
    if np.any(~np.isfinite(lane_vcost)) or np.any(lane_vcost < 0.0):
        raise ValueError("verify_cost must be finite and >= 0")
    if np.any(lane_keep < 1):
        raise ValueError("keep_ckpts must be >= 1")

    within = np.asarray(lane_wmode) == _WMODE_WITHIN
    if np.any(within & (lane_wperiod <= cp)):
        bad = float(np.asarray(lane_wperiod)[within & (lane_wperiod <= cp)][0])
        raise ValueError(f"window_period {bad} <= C_p {cp}: no work fits "
                         f"between in-window checkpoints")
    lane_wwp = np.where(within, lane_wperiod - cp, np.inf)

    # Adaptive lanes (mirrors the NumPy engine's setup: plan state is
    # per-lane, Never-trust adaptive lanes become Threshold(+inf)).
    ad_act = np.array([a is not None for a in lane_adaptive], dtype=bool)
    has_adaptive = bool(ad_act.any())
    if has_adaptive:
        bad_trust = ad_act & ~np.isin(lane_kind,
                                      (_TRUST_NEVER, _TRUST_THRESHOLD))
        if bad_trust.any():
            raise ValueError(
                "adaptive re-planning requires a Threshold or Never trust "
                "policy (the plan sets the threshold)")
        never = ad_act & (lane_kind == _TRUST_NEVER)
        lane_kind[never] = _TRUST_THRESHOLD
        lane_param[never] = np.inf
        ad_minp = np.array([(a.min_preds if a else np.inf)
                            for a in lane_adaptive], dtype=np.float64)
        ad_minf = np.array([(a.min_faults if a else np.inf)
                            for a in lane_adaptive], dtype=np.float64)
        ad_tol = np.array([(a.tol if a else 0.0)
                           for a in lane_adaptive], dtype=np.float64)
        ad_dec = np.array([(a.decay if a else 1.0)
                           for a in lane_adaptive], dtype=np.float64)
        ad_estmu = np.array(
            [bool(a is not None and getattr(a, "estimate_mu", False))
             for a in lane_adaptive], dtype=bool)
        ad_pr0 = np.array([(a.prior_recall if a else 0.0)
                           for a in lane_adaptive], dtype=np.float64)
        ad_pp0 = np.array([(a.prior_precision if a else 0.0)
                           for a in lane_adaptive], dtype=np.float64)
        from repro.predictors.estimator import P_HAT_MIN, maybe_replan
    else:
        ad_estmu = np.zeros(L, dtype=bool)

    tab = _draw_tables(bank, lane_trace, lane_kind, lane_window, lane_seed)
    TW = tab.shape[1]

    times2d = jnp.asarray(bank.times)
    kinds2d = jnp.asarray(bank.kinds.astype(np.int32))
    wins2d = jnp.asarray(bank.windows if bank.windows is not None
                         else np.full_like(bank.times, -1.0))
    n_ev = bank.n_events[lane_trace].astype(np.int32)

    # -- chunking / sharding layout -----------------------------------------
    env_chunk = os.environ.get("REPRO_JAX_CHUNK", "").strip()
    if chunk is None and env_chunk:
        chunk = int(env_chunk)
    CL = L if (chunk is None or chunk <= 0) else min(int(chunk), L)
    CL = max(CL, 1)

    shard_env = os.environ.get("REPRO_JAX_SHARD", "auto").strip().lower()
    devices = jax.devices()
    use_shard = (not has_adaptive and shard_env != "0"
                 and (len(devices) > 1 or shard_env in ("1", "force")))
    n_shards = len(devices) if use_shard else 1
    if use_shard and CL % n_shards:
        CL += n_shards - CL % n_shards

    # -- per-lane step: event pop -------------------------------------------
    def _push_one(def_time, def_seq, next_seq, overflow, push, date):
        empty = jnp.isinf(def_time)
        overflow = overflow | (push & ~empty.any())
        slot = empty.argmax()
        onehot = (jnp.arange(K) == slot) & push
        def_time = jnp.where(onehot, date, def_time)
        def_seq = jnp.where(onehot, next_seq, def_seq)
        next_seq = jnp.where(push, next_seq + 1, next_seq)
        return def_time, def_seq, next_seq, overflow

    def _pop_one(s, k):
        pop = ~s["finished"] & (s["pc"] == _PC_POP)
        col = jnp.minimum(s["cursor"], width - 1)
        have = s["cursor"] < k["n_ev"]
        t_tr = jnp.where(have, times2d[k["tr"], col], jnp.inf)
        k_tr = jnp.where(have, kinds2d[k["tr"], col], -1)
        w_ev = jnp.where(have, wins2d[k["tr"], col], -1.0)
        min_t = s["def_time"].min()
        tie = s["def_time"] == min_t
        seqm = jnp.where(tie, s["def_seq"], _BIG_SEQ)
        slot = seqm.argmin()

        none_left = pop & jnp.isinf(t_tr) & jnp.isinf(min_t)
        pc = jnp.where(none_left, _PC_FINAL, s["pc"])
        target = jnp.where(none_left, jnp.inf, s["target"])

        take_trace = pop & ~none_left & (t_tr <= min_t)
        cursor = jnp.where(take_trace, s["cursor"] + 1, s["cursor"])
        take_def = pop & ~none_left & ~take_trace
        clear = (jnp.arange(K) == slot) & take_def
        def_time = jnp.where(clear, jnp.inf, s["def_time"])
        def_seq = jnp.where(clear, _BIG_SEQ, s["def_seq"])

        # Deferred pops were already counted at announcement; only trace
        # faults count here (mirrors the scalar engine's counting).
        uf = take_trace & (k_tr == FAULT_UNPRED)
        is_fault = take_def | uf
        n_faults = s["n_faults"] + uf
        f_t = jnp.where(take_def, min_t, t_tr)
        target = jnp.where(is_fault, f_t, target)
        pc = jnp.where(is_fault, _PC_FAULT, pc)

        # Silent-error strikes route to their own arrival state: the lane
        # advances to the strike date, then flips its latent-corruption
        # flag there (no immediate downtime).
        is_sil = take_trace & (k_tr == SILENT)
        target = jnp.where(is_sil, t_tr, target)
        pc = jnp.where(is_sil, _PC_SILENT, pc)

        is_pred = take_trace & (k_tr != FAULT_UNPRED) & (k_tr != SILENT)
        n_predictions = s["n_predictions"] + is_pred
        is_true = is_pred & (k_tr == FAULT_PRED)
        n_faults = n_faults + is_true      # counted at announcement
        out = {"pc": pc, "target": target, "cursor": cursor,
               "def_time": def_time, "def_seq": def_seq,
               "n_faults": n_faults, "n_predictions": n_predictions}

        if has_adaptive:
            # Decay-then-increment must round the product *before* the
            # add, as the other engines' two statements do.  The runtime
            # zero (now - now; unfoldable by the compiler) caps each
            # product so the worst FMA contraction is fma(x, dec, 0) —
            # the plain rounded product (cf. the fault-date guard in
            # `_body`; selects sharing a predicate get merged by XLA's
            # simplifier, re-exposing mul+add to LLVM).
            zero = s["now"] - s["now"]
            # Every actual fault is an MTBF observation for estimate_mu
            # lanes (decay-then-increment at the scalar engine's site).
            mu_site = k["ad_act"] & k["ad_estmu"] & is_fault
            obs = mu_site & (s["ad_lastf"] > -jnp.inf)
            gs_d = s["ad_gs"] * k["ad_dec"] + zero
            gn_d = s["ad_gn"] * k["ad_dec"] + zero
            out["ad_gs"] = jnp.where(obs, gs_d + (f_t - s["ad_lastf"]),
                                     s["ad_gs"])
            out["ad_gn"] = jnp.where(obs, gn_d + 1.0, s["ad_gn"])
            out["ad_lastf"] = jnp.where(mu_site, f_t, s["ad_lastf"])
            # (r, p) counters: unpredicted faults and announced
            # predictions age-then-increment, as in both other engines.
            upd_uf = uf & k["ad_act"]
            upd_p = is_pred & k["ad_act"]
            upd = upd_uf | upd_p
            ntp = jnp.where(upd, s["ad_ntp"] * k["ad_dec"] + zero,
                            s["ad_ntp"])
            nfp = jnp.where(upd, s["ad_nfp"] * k["ad_dec"] + zero,
                            s["ad_nfp"])
            nuf = jnp.where(upd, s["ad_nuf"] * k["ad_dec"] + zero,
                            s["ad_nuf"])
            nuf = jnp.where(upd_uf, nuf + 1.0, nuf)
            ntp = jnp.where(upd_p & is_true, ntp + 1.0, ntp)
            nfp = jnp.where(upd_p & ~is_true, nfp + 1.0, nfp)
            out["ad_ntp"], out["ad_nfp"], out["ad_nuf"] = ntp, nfp, nuf
            # Replan sites: every counter-updating pop, plus deferred
            # strikes that moved mu-hat (a mu-only replan site).
            out["replan_eval"] = k["ad_act"] & (is_pred | uf
                                                | (take_def & obs))

        # Prediction announced for date t: draw the in-window fault
        # offset (per-event window, falling back to the lane window) from
        # the pre-drawn stream, decide honourability.  The fault date
        # itself (t + w * u) is computed *outside* the vmapped step (see
        # `_body`) so an optimization barrier can split the mul from the
        # add — XLA:CPU otherwise contracts them into an FMA whose single
        # rounding breaks bitwise parity with numpy's `t + uniform(0, w)`.
        w_eff = jnp.where(w_ev < 0.0, k["window"], w_ev)
        draw_win = is_true & (w_eff > 0.0)
        u = k["tab"][jnp.minimum(s["cur"], TW - 1)]
        cur = s["cur"] + draw_win
        ckpt_start = t_tr - cp
        honour = is_pred & (ckpt_start >= s["now"])
        out["pc"] = jnp.where(honour, _PC_PRED, out["pc"])
        out["target"] = jnp.where(honour, ckpt_start, out["target"])
        out["pred_t"] = jnp.where(honour, t_tr, s["pred_t"])
        out["pred_true"] = jnp.where(honour, is_true, s["pred_true"])
        out["pred_win"] = jnp.where(honour, w_eff, s["pred_win"])
        out["cur"] = cur
        ignored = is_pred & ~honour
        out["n_ignored"] = s["n_ignored"] + ignored
        tmp = {"t_tr": t_tr, "w_eff": w_eff, "u": u, "draw": draw_win,
               "honour": honour, "push": ignored & is_true}
        return dict(s, **out), tmp

    # -- adaptive replan fixup (between pop and arrival) --------------------
    if has_adaptive:
        holder: dict[str, Any] = {"cfgs": list(lane_adaptive)}

        def _host_replan(fire, ntp, nfp, nuf, gs, gn, pr, pp, pmu, period,
                         tparam, n_replans):
            pr, pp, pmu = np.array(pr), np.array(pp), np.array(pmu)
            period, tparam = np.array(period), np.array(tparam)
            n_replans = np.array(n_replans)
            for lane in np.nonzero(fire)[0]:
                cfg = holder["cfgs"][lane]
                if cfg is None:      # pragma: no cover - prefilter is exact
                    continue
                mu_hat = None
                if getattr(cfg, "estimate_mu", False) and gn[lane] > 0.0:
                    mu_hat = float(gs[lane]) / float(gn[lane])
                plan = maybe_replan(cfg, platform, cp, float(ntp[lane]),
                                    float(nfp[lane]), float(nuf[lane]),
                                    float(pr[lane]), float(pp[lane]),
                                    mu_hat=mu_hat,
                                    planned_mu=float(pmu[lane]))
                if plan is None:     # pragma: no cover - prefilter is exact
                    continue
                pr[lane], pp[lane], period[lane], tparam[lane] = plan
                if mu_hat is not None:
                    pmu[lane] = mu_hat
                n_replans[lane] += 1
            return pr, pp, pmu, period, tparam, n_replans

        def _fixup(s, kc):
            """Vectorized gate + hysteresis prefilter (the same float ops
            as ``maybe_replan``), then the host re-plans the lanes that
            fire through that very function — plans are bit-for-bit."""
            ntp, nfp, nuf = s["ad_ntp"], s["ad_nfp"], s["ad_nuf"]
            npred, nflt = ntp + nfp, ntp + nuf
            gate = (npred >= kc["ad_minp"]) & (nflt >= kc["ad_minf"])
            r_hat = ntp / jnp.where(gate, nflt, 1.0)
            p_hat = jnp.maximum(ntp / jnp.where(gate, npred, 1.0), P_HAT_MIN)
            has_mu = kc["ad_estmu"] & (s["ad_gn"] > 0.0)
            mu_hat = s["ad_gs"] / jnp.where(s["ad_gn"] > 0.0, s["ad_gn"], 1.0)
            moved = (jnp.abs(r_hat - s["ad_pr"]) > kc["ad_tol"]) \
                | (jnp.abs(p_hat - s["ad_pp"]) > kc["ad_tol"]) \
                | (has_mu & (jnp.abs(mu_hat - s["ad_pmu"])
                             > kc["ad_tol"] * s["ad_pmu"]))
            fire = s["replan_eval"] & gate & moved
            n = ntp.shape[0]
            shapes = tuple([jax.ShapeDtypeStruct((n,), jnp.float64)] * 5
                           + [jax.ShapeDtypeStruct((n,), jnp.int32)])
            args = (fire, ntp, nfp, nuf, s["ad_gs"], s["ad_gn"], s["ad_pr"],
                    s["ad_pp"], s["ad_pmu"], s["period"], s["tparam"],
                    s["n_replans"])

            def _do(a):
                return jax.pure_callback(_host_replan, shapes, *a)

            def _skip(a):
                return a[6], a[7], a[8], a[9], a[10], a[11]

            pr, pp, pmu, period, tparam, n_rep = lax.cond(
                fire.any(), _do, _skip, args)
            return dict(s, ad_pr=pr, ad_pp=pp, ad_pmu=pmu, period=period,
                        tparam=tparam, n_replans=n_rep,
                        replan_eval=jnp.zeros_like(fire))

    # -- per-lane step: event arrivals --------------------------------------
    def _arrive_one(s, k):
        active = ~s["finished"]
        now, phase, phase_end = s["now"], s["phase"], s["phase_end"]
        target = s["target"]

        # Fault arrival (the vectorized `_Machine.fault`).  A lane whose
        # retained ring holds dirty snapshots rolls back past them to the
        # newest clean state (deep rollback).
        arr_f = active & (s["pc"] == _PC_FAULT) & (now >= target)
        deep = s["n_dirty"] > 0
        base = jnp.where(deep, s["saved_clean"], s["saved"])
        lost = s["done"] - base
        in_phase = (phase != _WORK) & ~jnp.isinf(phase_end)
        dur = jnp.select([phase == _CKPT, phase == _PROCKPT,
                          phase == _DOWN, phase == _RECOVER,
                          phase == _VERIFY],
                         [c, cp, d, r, k["vcost"]], 0.0)
        elapsed = dur - (phase_end - now)
        ckpt_like = in_phase & ((phase == _CKPT) | (phase == _PROCKPT)
                                | (phase == _VERIFY))
        lost = lost + jnp.where(ckpt_like, jnp.maximum(0.0, elapsed), 0.0)
        time_down = s["time_down"] + jnp.where(
            arr_f & in_phase & ~ckpt_like, jnp.maximum(0.0, elapsed), 0.0)
        time_downtime = s["time_downtime"] + jnp.where(
            arr_f & in_phase & (phase == _DOWN),
            jnp.maximum(0.0, elapsed), 0.0)
        time_recovery = s["time_recovery"] + jnp.where(
            arr_f & in_phase & (phase == _RECOVER),
            jnp.maximum(0.0, elapsed), 0.0)
        time_lost = s["time_lost"] + jnp.where(arr_f, lost, 0.0)
        n_faults_hit = s["n_faults_hit"] + arr_f
        n_rollbacks = s["n_rollbacks"] + (arr_f & (lost > 0.0))
        n_deep_rollbacks = s["n_deep_rollbacks"] + (arr_f & deep)
        saved = jnp.where(arr_f & deep, s["saved_clean"], s["saved"])
        n_dirty = jnp.where(arr_f, 0, s["n_dirty"])
        corrupted = s["corrupted"] & ~arr_f
        done = jnp.where(arr_f, saved, s["done"])
        phase = jnp.where(arr_f, _DOWN, phase)
        phase_end = jnp.where(arr_f, target + d, phase_end)
        # A fault ends any active prediction window.
        win_end = jnp.where(arr_f, -jnp.inf, s["win_end"])
        win_rem = jnp.where(arr_f, jnp.inf, s["win_rem"])
        pc = jnp.where(arr_f, _PC_POP, s["pc"])
        target = jnp.where(arr_f, -jnp.inf, target)

        # Silent-error strike: flip the latent-corruption flag if the
        # lane is computing or saving (strikes during downtime/recovery
        # hit no application state, as in the scalar engine).
        arr_s = active & (pc == _PC_SILENT) & (now >= target)
        hit = arr_s & ((phase == _WORK) | (phase == _CKPT)
                       | (phase == _PROCKPT) | (phase == _VERIFY))
        n_silent = s["n_silent"] + hit
        corrupted = corrupted | hit
        pc = jnp.where(arr_s, _PC_POP, pc)
        target = jnp.where(arr_s, -jnp.inf, target)

        # Prediction arrival: the trust decision at the checkpoint-start
        # date.  FixedProbability lanes draw only when the decision is
        # reached (phase == WORK), so the cursor advances exactly there.
        arr_p = active & (pc == _PC_PRED) & (now >= target)
        working = arr_p & (phase == _WORK)
        offset = s["pred_t"] - s["period_start"]
        draw_q = working & (k["kind"] == _TRUST_FIXED_Q)
        u2 = k["tab"][jnp.minimum(s["cur"], TW - 1)]
        cur = s["cur"] + draw_q
        trusted = working & ((k["kind"] == _TRUST_ALWAYS)
                             | ((k["kind"] == _TRUST_THRESHOLD)
                                & (offset >= s["tparam"]))
                             | (draw_q & (u2 < s["tparam"])))
        phase = jnp.where(trusted, _PROCKPT, phase)
        phase_end = jnp.where(trusted, s["pred_t"], phase_end)
        n_trusted = s["n_trusted"] + trusted
        n_trusted_true = s["n_trusted_true"] + (trusted & s["pred_true"])
        # Arm the prediction window on trusting "within" lanes: keep
        # proactive-checkpointing until pred_t + window.
        arm = trusted & k["within"] & (s["pred_win"] > 0.0)
        win_end = jnp.where(arm, s["pred_t"] + s["pred_win"], win_end)
        n_ignored = s["n_ignored"] + (arr_p & ~working)
        push2 = arr_p & s["pred_true"]
        def_time, def_seq, next_seq, overflow = _push_one(
            s["def_time"], s["def_seq"], s["next_seq"], s["overflow"],
            push2, s["pred_fd"])
        pc = jnp.where(arr_p, _PC_POP, pc)
        target = jnp.where(arr_p, -jnp.inf, target)

        return dict(s, now=now, done=done, saved=saved, phase=phase,
                    phase_end=phase_end,
                    win_end=win_end, win_rem=win_rem, pc=pc, target=target,
                    cur=cur, time_down=time_down, time_downtime=time_downtime,
                    time_recovery=time_recovery, time_lost=time_lost,
                    n_faults_hit=n_faults_hit, n_rollbacks=n_rollbacks,
                    n_deep_rollbacks=n_deep_rollbacks, n_silent=n_silent,
                    n_dirty=n_dirty, corrupted=corrupted,
                    n_trusted=n_trusted,
                    n_trusted_true=n_trusted_true, n_ignored=n_ignored,
                    def_time=def_time, def_seq=def_seq, next_seq=next_seq,
                    overflow=overflow)

    # -- the loop body -------------------------------------------------------
    def _advance(s, kc):
        fs = jnp.stack([s["now"], s["done"], s["saved"], s["period_start"],
                        s["phase_end"], s["wpp"], s["w_rem"], s["win_end"],
                        s["win_rem"], s["target"], s["time_ckpt"],
                        s["time_prockpt"], s["time_down"], s["period"],
                        kc["wwp"], s["time_downtime"], s["time_recovery"],
                        s["time_lost"], s["time_verify"], s["v_wp"],
                        s["v_rem"], kc["vcost"], s["saved_clean"]])
        is_ = jnp.stack([s["phase"], s["finished"].astype(jnp.int32),
                         s["n_periodic_ckpts"], s["n_prockpts"],
                         s["n_rollbacks"], s["n_verifications"],
                         s["n_deep_rollbacks"], s["n_dirty"],
                         s["corrupted"].astype(jnp.int32),
                         s["verify_then_ckpt"].astype(jnp.int32),
                         kc["nv"], kc["keep"]])
        for _ in range(_ADV_PASSES):
            fs, is_ = event_step(fs, is_, c=c, cp=cp, d=d, r=r,
                                 time_base=time_base, impl=impl)
        return dict(s, now=fs[F_NOW], done=fs[F_DONE], saved=fs[F_SAVED],
                    period_start=fs[F_PSTART], phase_end=fs[F_PHEND],
                    wpp=fs[F_WPP], w_rem=fs[F_WREM], win_end=fs[F_WINEND],
                    win_rem=fs[F_WINREM], time_ckpt=fs[F_TCKPT],
                    time_prockpt=fs[F_TPROC], time_down=fs[F_TDOWN],
                    time_downtime=fs[F_TDOWNT], time_recovery=fs[F_TRECOV],
                    time_lost=fs[F_TLOST], time_verify=fs[F_TVERIFY],
                    v_wp=fs[F_VWP], v_rem=fs[F_VREM],
                    saved_clean=fs[F_SVCLEAN],
                    phase=is_[I_PHASE], finished=is_[I_FIN] != 0,
                    n_periodic_ckpts=is_[I_NCKPT], n_prockpts=is_[I_NPROC],
                    n_rollbacks=is_[I_NROLL], n_verifications=is_[I_NVERIF],
                    n_deep_rollbacks=is_[I_NDEEP], n_dirty=is_[I_NDIRTY],
                    corrupted=is_[I_CORR] != 0,
                    verify_then_ckpt=is_[I_VTC] != 0)

    def _push_all(s, push, date):
        """Full-array deferred-fault insert (the pop-site pushes)."""
        empty = jnp.isinf(s["def_time"])
        overflow = s["overflow"] | (push & ~empty.any(axis=1))
        slot = empty.argmax(axis=1)
        onehot = (jnp.arange(K)[None, :] == slot[:, None]) & push[:, None]
        return dict(s,
                    def_time=jnp.where(onehot, date[:, None], s["def_time"]),
                    def_seq=jnp.where(onehot, s["next_seq"][:, None],
                                      s["def_seq"]),
                    next_seq=jnp.where(push, s["next_seq"] + 1,
                                       s["next_seq"]),
                    overflow=overflow)

    def _body(s, kc):
        s, tmp = jax.vmap(_pop_one, in_axes=(0, 0))(s, kc)
        # In-window fault date, guarded against FMA contraction (see
        # `_pop_one`): the runtime zero (now - now; unfoldable, now could
        # be non-finite for all the compiler knows) caps the product in
        # an add, so the worst contraction is fma(w, u, 0) — the plain
        # rounded product — and the outer add has no mul operand to fuse
        # with.  HLO-level barriers don't survive LLVM's contraction.
        zero = s["now"] - s["now"]
        off = tmp["w_eff"] * tmp["u"] + zero
        fd = jnp.where(tmp["draw"], tmp["t_tr"] + off, tmp["t_tr"])
        s = dict(s, pred_fd=jnp.where(tmp["honour"], fd, s["pred_fd"]))
        s = _push_all(s, tmp["push"], fd)
        if has_adaptive:
            s = _fixup(s, kc)
        s = jax.vmap(_arrive_one, in_axes=(0, 0))(s, kc)
        return _advance(s, kc)

    def _loop(state, kc):
        return lax.while_loop(
            lambda s: ~(jnp.all(s["finished"]) | jnp.any(s["overflow"])),
            lambda s: _body(s, kc), state)

    run = _loop
    if use_shard:
        from jax.experimental.shard_map import shard_map
        from jax.sharding import Mesh
        from jax.sharding import PartitionSpec as P
        mesh = Mesh(np.asarray(devices), ("i",))

        def _specs(tree):
            return jax.tree_util.tree_map(
                lambda v: P("i") if np.ndim(v) == 1 else P("i", None), tree)

    # -- chunk driver --------------------------------------------------------
    def _init_chunk(sl: slice, n_real: int):
        n = CL
        f8, i4 = np.float64, np.int32

        def pad1(a, fill, dtype):
            out = np.full(n, fill, dtype=dtype)
            out[:n_real] = a[sl]
            return out

        period = pad1(lane_period, c, f8)
        wpp0 = period - c
        nv = pad1(lane_nverify, 0, i4)
        vwp0 = np.where(nv >= 1, wpp0 / np.maximum(nv, 1), np.inf)
        state = {
            "now": np.zeros(n, f8), "done": np.zeros(n, f8),
            "saved": np.zeros(n, f8), "period_start": np.zeros(n, f8),
            "phase": np.full(n, _WORK, i4),
            "phase_end": np.full(n, np.inf, f8),
            "wpp": wpp0, "w_rem": np.minimum(wpp0, time_base),
            "win_end": np.full(n, -np.inf, f8),
            "win_rem": np.full(n, np.inf, f8),
            "finished": np.zeros(n, bool),
            "pc": np.full(n, _PC_POP, i4),
            "target": np.full(n, -np.inf, f8),
            "cursor": np.zeros(n, i4), "cur": np.zeros(n, i4),
            "pred_t": np.zeros(n, f8), "pred_fd": np.zeros(n, f8),
            "pred_true": np.zeros(n, bool), "pred_win": np.zeros(n, f8),
            "def_time": np.full((n, K), np.inf, f8),
            "def_seq": np.full((n, K), _BIG_SEQ, i4),
            "next_seq": pad1(n_ev, 0, i4),
            "overflow": np.zeros(n, bool),
            "period": period, "tparam": pad1(lane_param, 0.0, f8),
            "n_faults": np.zeros(n, i4), "n_faults_hit": np.zeros(n, i4),
            "n_predictions": np.zeros(n, i4), "n_trusted": np.zeros(n, i4),
            "n_trusted_true": np.zeros(n, i4), "n_ignored": np.zeros(n, i4),
            "n_periodic_ckpts": np.zeros(n, i4),
            "n_prockpts": np.zeros(n, i4), "n_rollbacks": np.zeros(n, i4),
            "n_replans": np.zeros(n, i4),
            "time_ckpt": np.zeros(n, f8), "time_prockpt": np.zeros(n, f8),
            "time_down": np.zeros(n, f8), "time_lost": np.zeros(n, f8),
            "time_downtime": np.zeros(n, f8),
            "time_recovery": np.zeros(n, f8),
            "time_verify": np.zeros(n, f8),
            "v_wp": vwp0, "v_rem": vwp0.copy(),
            "saved_clean": np.zeros(n, f8),
            "n_dirty": np.zeros(n, i4),
            "corrupted": np.zeros(n, bool),
            "verify_then_ckpt": np.zeros(n, bool),
            "n_silent": np.zeros(n, i4),
            "n_verifications": np.zeros(n, i4),
            "n_deep_rollbacks": np.zeros(n, i4),
        }
        state["finished"][n_real:] = True
        kc = {
            "tr": pad1(lane_trace, 0, i4), "n_ev": pad1(n_ev, 0, i4),
            "kind": pad1(lane_kind, _TRUST_NEVER, i4),
            "window": pad1(lane_window, 0.0, f8),
            "within": pad1(within, False, bool),
            "wwp": pad1(lane_wwp, np.inf, f8),
            "nv": nv, "vcost": pad1(lane_vcost, 0.0, f8),
            "keep": pad1(lane_keep, 1, i4),
            "tab": np.zeros((n, TW), f8),
        }
        kc["tab"][:n_real] = tab[sl]
        if has_adaptive:
            state.update(
                ad_ntp=np.zeros(n, f8), ad_nfp=np.zeros(n, f8),
                ad_nuf=np.zeros(n, f8),
                ad_pr=pad1(ad_pr0, 0.0, f8), ad_pp=pad1(ad_pp0, 0.0, f8),
                ad_gs=np.zeros(n, f8), ad_gn=np.zeros(n, f8),
                ad_lastf=np.full(n, -np.inf, f8),
                ad_pmu=np.full(n, platform.mu, f8),
                replan_eval=np.zeros(n, bool),
            )
            kc.update(
                ad_act=pad1(ad_act, False, bool),
                ad_estmu=pad1(ad_estmu, False, bool),
                ad_minp=pad1(ad_minp, np.inf, f8),
                ad_minf=pad1(ad_minf, np.inf, f8),
                ad_tol=pad1(ad_tol, 0.0, f8),
                ad_dec=pad1(ad_dec, 1.0, f8),
            )
        return state, kc

    run_jit = None
    out_keys = ("now", "n_faults", "n_faults_hit", "n_predictions",
                "n_trusted", "n_trusted_true", "n_ignored",
                "n_periodic_ckpts", "n_prockpts", "n_rollbacks",
                "time_ckpt", "time_prockpt", "time_down",
                "time_lost", "time_downtime", "time_recovery",
                "n_silent", "n_verifications", "n_deep_rollbacks",
                "time_verify", "n_replans", "period", "tparam")
    ad_keys = ("ad_ntp", "ad_nfp", "ad_nuf", "ad_gs", "ad_gn")
    acc = {k: np.zeros(L, np.float64) for k in out_keys}
    acc.update({k: np.zeros(L, np.float64) for k in ad_keys})

    from repro.obs.metrics import get_registry
    reg = get_registry()
    wall0 = time.perf_counter()
    for lo in range(0, L, CL):
        n_real = min(CL, L - lo)
        sl = slice(lo, lo + n_real)
        state, kc = _init_chunk(sl, n_real)
        if has_adaptive:
            cfgs = list(lane_adaptive[lo:lo + n_real])
            holder["cfgs"] = cfgs + [None] * (CL - n_real)
        first_chunk = run_jit is None
        if run_jit is None:
            if use_shard:
                run_jit = jax.jit(shard_map(
                    run, mesh=mesh, in_specs=(_specs(state), _specs(kc)),
                    out_specs=_specs(state), check_rep=False),
                    donate_argnums=0)
            else:
                run_jit = jax.jit(run, donate_argnums=0)
        t0 = time.perf_counter()
        final = jax.device_get(run_jit(state, kc))
        # The first chunk pays the XLA compilation; later chunks reuse it.
        reg.add_time("jax.compile_s" if first_chunk else "jax.run_s",
                     time.perf_counter() - t0)
        reg.count("jax.chunks")
        if final["overflow"].any():
            reg.count("engine.deferred_overflows")
            raise RuntimeError(
                f"deferred-fault capacity ({K} slots) exceeded in the jax "
                f"backend; rerun with backend='numpy'")
        for key in out_keys:
            acc[key][sl] = final[key][:n_real]
        if has_adaptive:
            for key in ad_keys:
                acc[key][sl] = final[key][:n_real]
    wall = time.perf_counter() - wall0
    if wall > 0.0:
        reg.gauge("jax.lanes_per_s", L / wall)

    # -- final-plan / estimator diagnostics (mirrors the NumPy engine) ------
    er = np.full(L, -1.0)
    ep = np.full(L, -1.0)
    em = np.full(L, -1.0)
    if has_adaptive:
        denom_f = acc["ad_ntp"] + acc["ad_nuf"]
        denom_p = acc["ad_ntp"] + acc["ad_nfp"]
        np.divide(acc["ad_ntp"], denom_f, out=er,
                  where=ad_act & (denom_f > 0))
        np.divide(acc["ad_ntp"], denom_p, out=ep,
                  where=ad_act & (denom_p > 0))
        np.divide(acc["ad_gs"], acc["ad_gn"], out=em,
                  where=ad_estmu & (acc["ad_gn"] > 0))
    return {
        "makespan": acc["now"],
        "n_faults": acc["n_faults"].astype(np.int64),
        "n_faults_hit": acc["n_faults_hit"].astype(np.int64),
        "n_predictions": acc["n_predictions"].astype(np.int64),
        "n_trusted": acc["n_trusted"].astype(np.int64),
        "n_trusted_true": acc["n_trusted_true"].astype(np.int64),
        "n_ignored": acc["n_ignored"].astype(np.int64),
        "n_periodic_ckpts": acc["n_periodic_ckpts"].astype(np.int64),
        "n_proactive_ckpts": acc["n_prockpts"].astype(np.int64),
        "n_rollbacks": acc["n_rollbacks"].astype(np.int64),
        "time_ckpt": acc["time_ckpt"],
        "time_prockpt": acc["time_prockpt"],
        "time_down": acc["time_down"],
        "time_lost": acc["time_lost"],
        "time_downtime": acc["time_downtime"],
        "time_recovery": acc["time_recovery"],
        "n_silent": acc["n_silent"].astype(np.int64),
        "n_verifications": acc["n_verifications"].astype(np.int64),
        "n_deep_rollbacks": acc["n_deep_rollbacks"].astype(np.int64),
        "time_verify": acc["time_verify"],
        "n_replans": acc["n_replans"].astype(np.int64),
        "final_period": acc["period"],
        "final_threshold": np.where(ad_act, acc["tparam"], -1.0),
        "est_recall": er,
        "est_precision": ep,
        "est_mu": em,
    }
